//! Document cleanup — the recognition-pipeline workload the paper's
//! introduction motivates (document/credit-card recognition systems).
//!
//! Takes a synthetic scanned page with salt-and-pepper noise and:
//!   1. removes the noise with a closing∘opening pair,
//!   2. extracts text-line masks with a wide horizontal erosion,
//!   3. computes a morphological gradient as a cheap edge map,
//!   4. re-runs the text-line erosion on just the page's central band
//!      through the zero-copy ROI API (`erode_roi` reads a borrowed
//!      haloed view — no full-image pass, same pixels),
//! reporting per-stage timings on the §5.3 hybrid implementation versus
//! the scalar vHGW baseline.
//!
//! ```bash
//! cargo run --release --example document_cleanup [-- /path/to/page.pgm]
//! ```

use neon_morph::image::{read_pgm, synth, write_pgm, Image};
use neon_morph::morphology::{
    self, Border, HybridThresholds, MorphConfig, PassMethod, VerticalStrategy,
};
use neon_morph::neon::Native;

fn count_dark(img: &Image<u8>) -> usize {
    (0..img.height())
        .flat_map(|y| img.row(y).iter())
        .filter(|&&v| v < 128)
        .count()
}

fn main() -> anyhow::Result<()> {
    let arg = std::env::args().nth(1);
    let page = match &arg {
        Some(path) => read_pgm(path)?,
        None => synth::document(600, 800, 2024),
    };
    println!(
        "page: {}x{} ({} dark pixels)",
        page.height(),
        page.width(),
        count_dark(&page)
    );

    let hybrid = MorphConfig::default();
    let baseline = MorphConfig {
        method: PassMethod::Vhgw,
        vertical: VerticalStrategy::Transpose,
        simd: false,
        border: Border::Identity,
        thresholds: HybridThresholds::paper(),
        ..MorphConfig::default()
    };

    // 1. despeckle: closing kills pepper (dark specks), opening kills salt
    let b = &mut Native;
    let t = std::time::Instant::now();
    let closed = morphology::closing(b, &page, 3, 3, &hybrid);
    let despeckled = morphology::opening(b, &closed, 3, 3, &hybrid);
    let t_hybrid = t.elapsed();
    let t = std::time::Instant::now();
    let closed_base = morphology::closing(b, &page, 3, 3, &baseline);
    let _ = morphology::opening(b, &closed_base, 3, 3, &baseline);
    let t_base = t.elapsed();
    println!(
        "despeckle 3x3 closing+opening: hybrid {:?} vs scalar-vHGW {:?} ({:.1}x)",
        t_hybrid,
        t_base,
        t_base.as_secs_f64() / t_hybrid.as_secs_f64()
    );

    // 2. text-line mask: wide horizontal SE merges glyphs into lines
    let t = std::time::Instant::now();
    let lines = morphology::erode(&despeckled, 61, 3);
    println!("text-line mask 61x3 erosion: {:?}", t.elapsed());

    // 3. edge map
    let t = std::time::Instant::now();
    let edges = morphology::gradient(b, &despeckled, 3, 3, &hybrid);
    println!("gradient 3x3: {:?}", t.elapsed());

    // 4. region of interest: the same text-line erosion on just the
    // central band of the page — erode_roi filters a borrowed haloed
    // view (work bounded by ROI + halo, not the full page),
    // pixel-identical to cropping the full result
    let roi = morphology::Roi::new(
        page.height() / 4,
        page.width() / 4,
        page.height() / 2,
        page.width() / 2,
    );
    let t = std::time::Instant::now();
    let lines_roi = morphology::erode_roi(&despeckled, 61, 3, roi);
    println!(
        "text-line mask on {}x{} ROI: {:?} (zero-copy haloed view)",
        roi.height,
        roi.width,
        t.elapsed()
    );
    let want = lines
        .view()
        .sub_rect(roi.y, roi.x, roi.height, roi.width)
        .to_image();
    assert!(
        lines_roi.same_pixels(&want),
        "ROI result must equal the cropped full result"
    );

    let dir = std::env::temp_dir();
    write_pgm(&page, dir.join("doc_input.pgm"))?;
    write_pgm(&despeckled, dir.join("doc_despeckled.pgm"))?;
    write_pgm(&lines, dir.join("doc_textlines.pgm"))?;
    write_pgm(&edges, dir.join("doc_edges.pgm"))?;
    println!(
        "wrote doc_{{input,despeckled,textlines,edges}}.pgm to {}",
        dir.display()
    );

    // the despeckle must remove isolated impulses: salt noise in the
    // synthetic page is isolated, so dark-pixel count may only drop
    // toward the true text mass
    println!(
        "dark pixels: input {} -> despeckled {}",
        count_dark(&page),
        count_dark(&despeckled)
    );
    Ok(())
}
