//! Regenerate every table and figure of the paper in one run, as
//! markdown — the source for EXPERIMENTS.md's measured columns.
//!
//! ```bash
//! cargo run --release --example paper_tables [-- --quick]
//! ```

use neon_morph::bench_harness::{self, e2e, fig3, fig4, table1};
use neon_morph::costmodel::CostModel;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let model = CostModel::exynos5422();
    let windows = if quick {
        bench_harness::window_sweep_quick()
    } else {
        bench_harness::window_sweep()
    };
    let iters = if quick { 2 } else { 5 };

    println!("# Paper evaluation artifacts — regenerated\n");

    let rows = table1::run(&model);
    print!("{}", table1::render(&rows).to_markdown());
    println!();

    let f3 = fig3::run(&model, &windows, iters);
    print!(
        "{}",
        fig3::render("Figure 3 — horizontal pass (cost model, ns)", &f3, "model").to_markdown()
    );
    println!(
        "\ncrossover w_y0: model={} host={} paper=69\n",
        f3.crossover_model, f3.crossover_host
    );

    let f4 = fig4::run(&model, &windows, iters);
    print!(
        "{}",
        fig4::render("Figure 4 — vertical pass (cost model, ns)", &f4, "model").to_markdown()
    );
    println!(
        "\ncrossover w_x0: model={} host={} paper=59\n",
        f4.crossover_model, f4.crossover_host
    );

    let e2e_rows = e2e::run(&model, if quick { &[7, 15] } else { &[3, 7, 15, 31, 61] }, iters);
    print!("{}", e2e::render(&e2e_rows).to_markdown());
    println!();

    let s = e2e::serve_native(if quick { 32 } else { 128 }, 4, 7)?;
    println!(
        "serving (native, 4 workers): {:.1} req/s, p50 {:.2} ms, p99 {:.2} ms, mean batch {:.2}",
        s.throughput_rps,
        s.p50_us / 1e3,
        s.p99_us / 1e3,
        s.mean_batch
    );
    Ok(())
}
