//! STREAMING SERVING DRIVER: the position-independent-plan serving
//! architecture end to end, no artifacts required.
//!
//! Three producer threads each open a [`SubmitStream`] on one shared
//! coordinator and pump a mixed workload — full-image ops at two pixel
//! depths plus a same-shape ROI crop *sweep* (the document-pipeline
//! pattern: many crops of one geometry at scattered offsets).  Workers
//! pull key-grouped batches and drain each same-key run through one
//! pinned, position-independent plan; the FIFO-aged queue keeps any one
//! hot key from starving the rest.
//!
//! The driver then proves the architecture's two claims:
//!
//! * **bit-identity** — every streamed response equals the fire-and-wait
//!   `submit` oracle for the same spec, and
//! * **plan economy** — the crop sweep resolves one plan per worker at
//!   most, not one per offset (printed as resolutions/request).
//!
//! ```bash
//! cargo run --release --example streaming_serve
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use neon_morph::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use neon_morph::image::synth;
use neon_morph::morphology::{FilterOp, FilterSpec, Roi};

const PRODUCERS: usize = 3;
const PER_PRODUCER: usize = 48;
const H: usize = 200;
const W: usize = 260;

/// The mixed request stream each producer pumps: a full-image erode, a
/// u16 gradient, and an interior 48×64 tophat crop sweep (tophat 5×5
/// halo = 2·wing = (4, 4) — every position below keeps the full halo,
/// so the whole sweep canonicalizes to ONE plan key).
fn spec_of(i: usize) -> (FilterSpec, bool) {
    match i % 3 {
        0 => (FilterSpec::new(FilterOp::Erode, 7, 7), false),
        1 => (FilterSpec::new(FilterOp::Gradient, 5, 5), true),
        _ => {
            let y = 4 + (i * 7) % (H - 48 - 8);
            let x = 4 + (i * 11) % (W - 64 - 8);
            (
                FilterSpec::new(FilterOp::TopHat, 5, 5).with_roi(Roi::new(y, x, 48, 64)),
                false,
            )
        }
    }
}

fn main() -> anyhow::Result<()> {
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 2,
        queue_capacity: PRODUCERS * PER_PRODUCER + 16,
        max_batch: 16,
        backend: BackendChoice::NativeOnly,
        artifact_dir: None,
        ..CoordinatorConfig::default()
    })?;
    let img8 = Arc::new(synth::document(H, W, 11));
    let img16 = Arc::new(synth::noise_u16(H, W, 12));

    let t0 = std::time::Instant::now();
    let results: Vec<(u64, FilterSpec, bool, neon_morph::coordinator::request::FilterOutput)> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..PRODUCERS)
                .map(|p| {
                    let coord = &coord;
                    let img8 = &img8;
                    let img16 = &img16;
                    scope.spawn(move || {
                        let mut stream = coord.stream();
                        let mut meta = HashMap::new();
                        for i in 0..PER_PRODUCER {
                            let (spec, is_u16) = spec_of(p * PER_PRODUCER + i);
                            let id = if is_u16 {
                                stream.send(spec, img16.clone()).expect("queue sized")
                            } else {
                                stream.send(spec, img8.clone()).expect("queue sized")
                            };
                            meta.insert(id, (spec, is_u16));
                        }
                        // responses arrive in completion order, tagged by id
                        stream
                            .drain()
                            .into_iter()
                            .map(|r| {
                                let (spec, is_u16) = meta.remove(&r.id).expect("known id");
                                (r.id, spec, is_u16, r.result.expect("request succeeds"))
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
    let wall = t0.elapsed().as_secs_f64();

    anyhow::ensure!(results.len() == PRODUCERS * PER_PRODUCER, "every request completes");

    // verify EVERY streamed response against the fire-and-wait oracle
    let mut oracle_cache: HashMap<FilterSpec, neon_morph::coordinator::request::FilterOutput> =
        HashMap::new();
    for (id, spec, is_u16, out) in &results {
        let want = oracle_cache.entry(*spec).or_insert_with(|| {
            let payload: neon_morph::coordinator::request::ImagePayload = if *is_u16 {
                img16.clone().into()
            } else {
                img8.clone().into()
            };
            coord
                .filter_spec(*spec, payload)
                .expect("oracle submit")
                .result
                .expect("oracle succeeds")
        });
        let same = match (out, &*want) {
            (
                neon_morph::coordinator::request::FilterOutput::U8(a),
                neon_morph::coordinator::request::FilterOutput::U8(b),
            ) => a.same_pixels(b),
            (
                neon_morph::coordinator::request::FilterOutput::U16(a),
                neon_morph::coordinator::request::FilterOutput::U16(b),
            ) => a.same_pixels(b),
            _ => false,
        };
        anyhow::ensure!(same, "request {id} disagrees with the submit oracle");
    }

    let snap = coord.metrics();
    println!("all {} streamed responses verified against submit ✓", results.len());
    println!(
        "throughput: {:.1} req/s over {:.2}s ({} producers x {} reqs, 2 workers)",
        results.len() as f64 / wall,
        wall,
        PRODUCERS,
        PER_PRODUCER
    );
    println!("{snap}");
    anyhow::ensure!(snap.failed == 0, "no request may fail");
    // plan economy: 3 plan families (+1 oracle round) on 2 workers — the
    // ROI sweep must NOT re-plan per offset.  Generous bound: every
    // family resolved once per worker, twice over (stream + oracle).
    let max_resolutions = 2 * 2 * 3;
    anyhow::ensure!(
        snap.plan_resolutions <= max_resolutions,
        "plan churn: {} resolutions for 3 plan families ({} allowed)",
        snap.plan_resolutions,
        max_resolutions
    );
    println!(
        "plan economy: {} resolutions / {} completed = {:.4} resolutions/req ✓",
        snap.plan_resolutions,
        snap.completed,
        snap.plan_resolutions_per_request()
    );
    coord.shutdown();
    println!("streaming_serve OK");
    Ok(())
}
