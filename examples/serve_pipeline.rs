//! END-TO-END DRIVER: proves all three layers compose on a real
//! workload.
//!
//!   L1 Pallas kernels (python, compile time)
//!     → L2 JAX separable-morphology graph (python, compile time)
//!       → HLO-text artifacts (`make artifacts`)
//!         → L3 rust coordinator: router → dynamic batcher → worker
//!           pool → PJRT CPU client executing the artifacts,
//!           cross-checked against the native rust engine.
//!
//! Serves a mixed batch of requests against both artifact shapes
//! (256×256 and the paper's 800×600), reports throughput, latency
//! percentiles, batching effectiveness and the backend mix, and
//! verifies every single response against the native implementation.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_pipeline
//! ```

use std::sync::Arc;

use neon_morph::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use neon_morph::image::synth;
use neon_morph::runtime::NativeEngine;

fn main() -> anyhow::Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(96);

    let coord = Coordinator::start(CoordinatorConfig {
        workers: 4,
        queue_capacity: requests + 16,
        max_batch: 16,
        backend: BackendChoice::Auto,
        artifact_dir: Some("artifacts".into()),
        precompile: false, // compile lazily; affinity batching amortizes it
        ..CoordinatorConfig::default()
    })?;
    let manifest = coord
        .manifest()
        .ok_or_else(|| anyhow::anyhow!("artifacts missing — run `make artifacts` first"))?;

    // the workload: every morphology artifact over both shapes, round-robin
    let metas: Vec<_> = manifest
        .names()
        .filter_map(|n| manifest.get(n))
        .filter(|m| m.kind == "morphology")
        .cloned()
        .collect();
    println!("serving {} requests over {} artifact variants", requests, metas.len());

    let img_small = Arc::new(synth::document(256, 256, 7));
    let img_paper = Arc::new(synth::document(600, 800, 8));

    let t0 = std::time::Instant::now();
    let submitted: Vec<_> = (0..requests)
        .map(|i| {
            let m = &metas[i % metas.len()];
            let img = if m.height == 256 { &img_small } else { &img_paper };
            let op: neon_morph::morphology::FilterOp = m.op.parse().expect("manifest op");
            let spec = neon_morph::morphology::FilterSpec::new(op, m.w_x, m.w_y);
            (m.clone(), img.clone(), coord.submit(spec, img.clone()))
        })
        .collect();

    let mut native = NativeEngine::default();
    let mut by_backend = std::collections::BTreeMap::<&'static str, usize>::new();
    let mut verified = 0usize;
    for (meta, img, ticket) in submitted {
        let resp = ticket?.wait()?;
        let out = resp.result?.into_u8()?;
        *by_backend.entry(resp.backend).or_default() += 1;
        // verify EVERY response against the native engine
        let want = native.run(&meta, &img)?;
        anyhow::ensure!(
            out.same_pixels(&want),
            "response {} from {} disagrees with native",
            meta.name,
            resp.backend
        );
        verified += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = coord.metrics();

    println!("\nall {verified} responses verified against the native engine ✓");
    println!("backend mix: {by_backend:?}");
    println!(
        "throughput: {:.1} req/s over {:.2}s ({} workers)",
        snap.completed as f64 / wall,
        wall,
        4
    );
    println!("{snap}");
    anyhow::ensure!(snap.failed == 0, "no request may fail");
    anyhow::ensure!(
        by_backend.get("xla-pjrt").copied().unwrap_or(0) == requests,
        "every request should have hit the XLA backend"
    );
    coord.shutdown();
    println!("serve_pipeline OK");
    Ok(())
}
