//! Plan–execute pipeline demo: build ONE [`FilterSpec`], resolve it
//! ONCE into a [`FilterPlan`], and drive a whole batch of same-shape
//! images through it — the API shape morphological serving wants
//! (document pipelines are chains of erosions/dilations over streams of
//! same-size pages).
//!
//! Shows, end to end:
//!
//! 1. a derived-op *chain* spec (`closing → tophat`) planned once and
//!    reused over a batch (the plan's scratch arena makes run N
//!    allocate no intermediate images),
//! 2. the zero-allocation `run(src, dst)` form writing into a
//!    caller-owned destination,
//! 3. a ROI spec — the same plan machinery computing exactly
//!    `crop(chain(full), roi)` from a haloed block, and
//! 4. the identical pipeline at `u16` depth (8 SIMD lanes, 8×8.16
//!    transpose tiles) from the *same* depth-generic spec.
//!
//! Runs in CI (`bench-smoke` job):
//!
//! ```bash
//! cargo run --release --example pipeline_demo
//! ```

use neon_morph::image::{synth, Image};
use neon_morph::morphology::{self, FilterOp, FilterSpec, MorphConfig, Roi};
use neon_morph::neon::Native;

fn main() -> anyhow::Result<()> {
    let (h, w) = (480, 640);
    let batch: Vec<Image<u8>> = (0..8).map(|i| synth::document(h, w, 100 + i)).collect();

    // 1. one spec, one plan, many runs -----------------------------------
    let spec = FilterSpec::new(FilterOp::Close, 3, 3).then(FilterOp::TopHat);
    let mut plan = spec.plan::<u8>(h, w)?;
    println!(
        "spec {:?} planned for {h}x{w} u8 (out {:?})",
        spec.ops(),
        plan.out_dims()
    );

    let t0 = std::time::Instant::now();
    let mut checksum = 0u64;
    let mut dst = Image::<u8>::zeros(h, w);
    for img in &batch {
        // 2. zero-allocation form: intermediates live in the plan arena,
        //    output lands in the caller's buffer
        plan.run(img, dst.view_mut());
        checksum = checksum.wrapping_add(dst.mean() as u64);
    }
    println!(
        "ran {} images through one reused plan in {:.2} ms (checksum {checksum})",
        batch.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );

    // cross-check one batch element against the legacy composition
    let cfg = MorphConfig::default();
    let c = morphology::closing(&mut Native, &batch[7], 3, 3, &cfg);
    let want = morphology::tophat(&mut Native, &c, 3, 3, &cfg);
    anyhow::ensure!(dst.same_pixels(&want), "plan must equal legacy composition");

    // 3. the same machinery with a ROI: only the haloed block is read ----
    let roi = Roi::new(h / 4, w / 4, h / 2, w / 2);
    let mut roi_plan = spec.with_roi(roi).plan::<u8>(h, w)?;
    let crop = roi_plan.run_owned(&batch[0]);
    let full = spec.run_once::<u8>(&batch[0])?;
    anyhow::ensure!(
        crop.same_pixels(
            &full
                .view()
                .sub_rect(roi.y, roi.x, roi.height, roi.width)
                .to_image()
        ),
        "ROI plan must equal cropped full chain"
    );
    println!(
        "ROI plan {}x{} @({},{}) verified against the cropped full chain",
        roi.height, roi.width, roi.y, roi.x
    );

    // 4. the identical spec at 16-bit depth ------------------------------
    let img16 = synth::noise_u16(h, w, 9);
    let mut plan16 = spec.plan::<u16>(h, w)?;
    let out16 = plan16.run_owned(&img16);
    let c16 = morphology::closing(&mut Native, &img16, 3, 3, &cfg);
    let want16 = morphology::tophat(&mut Native, &c16, 3, 3, &cfg);
    anyhow::ensure!(out16.same_pixels(&want16), "u16 plan must match too");
    println!("same spec re-planned at u16: verified");

    println!("pipeline_demo OK");
    Ok(())
}
