//! Crossover sweep — reproduce the §5.3 dispatch thresholds.
//!
//! Sweeps the window size for both passes, prices the *counted*
//! instruction mixes with the Exynos-5422 cost model, finds the
//! linear/vHGW crossovers, and compares with the paper's measured
//! w_y⁰ = 69 / w_x⁰ = 59.  Also prints this host's wall-clock
//! crossovers for contrast (different silicon, different constants —
//! same qualitative shape).
//!
//! ```bash
//! cargo run --release --example crossover_sweep
//! ```

use neon_morph::bench_harness::{fig3, fig4, window_sweep};
use neon_morph::costmodel::CostModel;
use neon_morph::morphology::{PAPER_WX0, PAPER_WY0};

fn main() {
    let model = CostModel::exynos5422();
    let windows = window_sweep();

    println!("sweeping horizontal (rows) pass, {} windows ...", windows.len());
    let f3 = fig3::run(&model, &windows, 3);
    println!("sweeping vertical (cols) pass ...");
    let f4 = fig4::run(&model, &windows, 3);

    println!("\n{}", fig3::render("Fig 3 sweep (cost model, ns)", &f3, "model").to_tsv());
    println!("{}", fig4::render("Fig 4 sweep (cost model, ns)", &f4, "model").to_tsv());

    println!("crossovers:");
    println!(
        "  horizontal w_y0: model {:>3}  host {:>3}  paper {:>3}",
        f3.crossover_model, f3.crossover_host, PAPER_WY0
    );
    println!(
        "  vertical   w_x0: model {:>3}  host {:>3}  paper {:>3}",
        f4.crossover_model, f4.crossover_host, PAPER_WX0
    );
    println!(
        "  asymmetry (w_x0 < w_y0): model {}  paper {}",
        f4.crossover_model < f3.crossover_model,
        PAPER_WX0 < PAPER_WY0
    );
}
