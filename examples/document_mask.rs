//! Document ink-mask pipeline — the scenario the RLE engine exists for.
//!
//! Binarized document pages are overwhelmingly background: a few percent
//! ink means a few foreground runs per row, so interval arithmetic does
//! per-run work where the dense engine does per-pixel work.  This
//! example:
//!   1. binarizes a synthetic page with Otsu's threshold (ink = FG),
//!   2. despeckles the ink mask with a 3×3 opening on the **RLE**
//!      engine (`Representation::Rle`) and proves it bit-identical to
//!      the dense path,
//!   3. fills enclosed holes in the glyphs with morphological
//!      reconstruction by dilation (seed = border background; the
//!      complement of the fixpoint is the filled mask),
//! and reports run counts, density, and the sweeps the reconstruction
//! needed to reach stability.
//!
//! ```bash
//! cargo run --release --example document_mask [-- /path/to/page.pgm]
//! ```

use neon_morph::image::{read_pgm, synth, write_pgm, Image};
use neon_morph::morphology::binary::{is_binary, otsu_threshold, FG};
use neon_morph::morphology::{
    reconstruct_by_dilation, FilterOp, FilterSpec, MorphConfig, Representation, RleImage,
};

fn main() -> anyhow::Result<()> {
    let arg = std::env::args().nth(1);
    let page = match &arg {
        Some(path) => read_pgm(path)?,
        None => synth::document(600, 800, 77),
    };
    let (h, w) = (page.height(), page.width());

    // 1. binarize: ink is dark, so the mask is the *below*-threshold set
    let t = otsu_threshold(&page);
    let ink = Image::from_fn(h, w, |y, x| if page.get(y, x) < t { FG } else { 0 });
    assert!(is_binary(&ink));
    let rle = RleImage::from_view(&ink).expect("a 0/255 mask always converts");
    println!(
        "page {w}x{h}, otsu t={t}: ink density {:.1}% in {} runs ({:.2} runs/row)",
        100.0 * rle.density(),
        rle.run_count(),
        rle.run_count() as f64 / h as f64
    );

    // 2. despeckle on the interval engine, then prove the dense path
    // computes the very same pixels (the RLE engine's contract)
    let spec = FilterSpec::new(FilterOp::Open, 3, 3);
    let rle_cfg = MorphConfig {
        representation: Representation::Rle,
        ..MorphConfig::default()
    };
    let dense_cfg = MorphConfig {
        representation: Representation::Dense,
        ..MorphConfig::default()
    };
    let t0 = std::time::Instant::now();
    let cleaned = spec.with_config(rle_cfg).run_once(&ink)?;
    let t_rle = t0.elapsed();
    let t0 = std::time::Instant::now();
    let cleaned_dense = spec.with_config(dense_cfg).run_once(&ink)?;
    let t_dense = t0.elapsed();
    assert!(
        cleaned.same_pixels(&cleaned_dense),
        "RLE opening must be bit-identical to the dense engine"
    );
    println!(
        "open 3x3 despeckle: rle {t_rle:?} vs dense {t_dense:?} — outputs bit-identical"
    );

    // 3. hole fill: reconstruct the background from the border inward;
    // background not reachable from the border is a hole, so the
    // complement of the fixpoint is the ink mask with holes filled
    let bg = Image::from_fn(h, w, |y, x| FG - cleaned.get(y, x));
    let seed = Image::from_fn(h, w, |y, x| {
        if y == 0 || y == h - 1 || x == 0 || x == w - 1 {
            bg.get(y, x)
        } else {
            0
        }
    });
    let (outside, sweeps) = reconstruct_by_dilation(&seed, &bg, 3, 3, &MorphConfig::default())?;
    let filled = Image::from_fn(h, w, |y, x| FG - outside.get(y, x));
    assert!(is_binary(&filled));
    let fg_before = RleImage::from_view(&cleaned).unwrap().fg_pixels();
    let fg_after = RleImage::from_view(&filled).unwrap().fg_pixels();
    assert!(fg_after >= fg_before, "hole filling only adds foreground");
    println!(
        "hole fill: border reconstruction stabilized in {sweeps} sweeps, \
         ink {fg_before} -> {fg_after} px (+{} filled)",
        fg_after - fg_before
    );

    let dir = std::env::temp_dir();
    write_pgm(&page, dir.join("mask_input.pgm"))?;
    write_pgm(&cleaned, dir.join("mask_ink.pgm"))?;
    write_pgm(&filled, dir.join("mask_filled.pgm"))?;
    println!("wrote mask_{{input,ink,filled}}.pgm to {}", dir.display());
    Ok(())
}
