//! Quickstart: the 60-second tour of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use neon_morph::coordinator::Coordinator;
use neon_morph::image::{synth, write_pgm};
use neon_morph::morphology::{self, FilterOp, FilterSpec, MorphConfig};
use neon_morph::neon::Native;

fn main() -> anyhow::Result<()> {
    // 1. An image — the paper's 800x600 8-bit gray workload.
    let img = synth::paper_image(42);
    println!("image: {}x{} u8, mean {:.1}", img.height(), img.width(), img.mean());

    // 2. One-call morphology (paper §5.3 final hybrid implementation).
    let t = std::time::Instant::now();
    let eroded = morphology::erode(&img, 7, 7);
    println!("erode 7x7     : {:?} (native hybrid)", t.elapsed());
    let t = std::time::Instant::now();
    let dilated = morphology::dilate(&img, 7, 7);
    println!("dilate 7x7    : {:?}", t.elapsed());

    // 3. Derived operations.
    let cfg = MorphConfig::default();
    let grad = morphology::gradient(&mut Native, &img, 5, 5, &cfg);
    println!(
        "gradient 5x5  : range {:?} (0 on flat regions, bright on edges)",
        grad.min_max().unwrap()
    );

    // 4. Sanity: erosion <= original <= dilation, pointwise.
    let ok = (0..img.height()).all(|y| {
        (0..img.width()).all(|x| {
            eroded.get(y, x) <= img.get(y, x) && img.get(y, x) <= dilated.get(y, x)
        })
    });
    println!("erode <= img <= dilate everywhere: {ok}");
    assert!(ok);

    // 5. Plan once, run many: a FilterSpec resolved into a FilterPlan
    //    reuses its scratch arena across a batch of same-shape images.
    let spec = FilterSpec::new(FilterOp::TopHat, 5, 5);
    let mut plan = spec.plan::<u8>(img.height(), img.width())?;
    let t = std::time::Instant::now();
    let th = plan.run_owned(&img);
    println!(
        "tophat 5x5    : {:?} via a reused FilterPlan, range {:?}",
        t.elapsed(),
        th.min_max().unwrap()
    );

    // 6. The same through the serving layer (router + batcher + workers).
    let coord = Coordinator::start_native(2)?;
    let resp = coord.filter_spec(FilterSpec::new(FilterOp::Erode, 7, 7), Arc::new(img.clone()))?;
    let served = resp.result?.into_u8()?;
    println!(
        "served erode  : backend={} queue={} µs exec={} µs",
        resp.backend,
        resp.queue_ns / 1000,
        resp.exec_ns / 1000
    );
    assert!(served.same_pixels(&eroded), "service must equal direct call");
    coord.shutdown();

    // 7. Write results for eyeballing.
    let dir = std::env::temp_dir();
    write_pgm(&img, dir.join("quickstart_input.pgm"))?;
    write_pgm(&eroded, dir.join("quickstart_eroded.pgm"))?;
    write_pgm(&grad, dir.join("quickstart_gradient.pgm"))?;
    println!("wrote quickstart_{{input,eroded,gradient}}.pgm to {}", dir.display());
    Ok(())
}
