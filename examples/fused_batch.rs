//! FUSED-BATCH DRIVER: a whole same-shape batch as ONE banded
//! execution.
//!
//! Small-image traffic (many document crops, not one huge frame) pays
//! the fork-join and per-band overhead once per image when served
//! one at a time.  A [`FusedPlan`] stacks the batch into a virtual
//! `n·h`-row image — band cuts may span image boundaries, but every
//! per-image segment halos against its *own* rows, so no reduction
//! window crosses a seam — and runs ONE fork-join for the whole batch.
//!
//! The driver proves the two claims end to end, no artifacts required:
//!
//! * **bit-identity** — at batch 1/8/64, every fused output equals the
//!   per-image [`FilterPlan`] run of the same source, and
//! * **serving integration** — a 64-request same-key stream through one
//!   coordinator worker fuses inside the worker (`fused_batches` /
//!   `fused_requests` metrics) while still resolving exactly one plan
//!   family.
//!
//! ```bash
//! cargo run --release --example fused_batch
//! ```
//!
//! [`FusedPlan`]: neon_morph::morphology::FusedPlan
//! [`FilterPlan`]: neon_morph::morphology::FilterPlan

use std::sync::Arc;
use std::time::Instant;

use neon_morph::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use neon_morph::image::{synth, Image, ImageView};
use neon_morph::morphology::{FilterOp, FilterSpec};

const H: usize = 120;
const W: usize = 160;
const BATCHES: [usize; 3] = [1, 8, 64];

fn main() -> anyhow::Result<()> {
    let spec = FilterSpec::new(FilterOp::TopHat, 5, 5);
    let imgs: Vec<Image<u8>> = (0..64)
        .map(|i| synth::document(H, W, 0xF0 + i as u64))
        .collect();

    // library layer: the fused super-pass vs the per-image plan, bit
    // for bit, with the arena growing once to the high-water batch
    let mut single = spec.plan::<u8>(H, W)?;
    let mut fused = spec.plan_fused::<u8>(H, W, 1)?;
    for n in BATCHES {
        let batch: Vec<ImageView<'_, u8>> = imgs[..n].iter().map(|im| im.view()).collect();
        let t = Instant::now();
        let outs = fused.run_batch_owned(&batch);
        let fused_t = t.elapsed();
        let t = Instant::now();
        let per: Vec<Image<u8>> = batch.iter().map(|v| single.run_owned(*v)).collect();
        let per_t = t.elapsed();
        for (i, (a, b)) in outs.iter().zip(&per).enumerate() {
            anyhow::ensure!(a.same_pixels(b), "batch {n}, image {i} diverges from per-image");
        }
        println!(
            "batch {n:2}: fused {fused_t:>10.1?} vs per-image {per_t:>10.1?} \
             (arena {:4} KiB) bit-identical ✓",
            fused.scratch_bytes() / 1024
        );
    }

    // serving layer: one worker, 64 same-key requests streamed in —
    // the worker routes every multi-request pull through the fused path
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        queue_capacity: 80,
        max_batch: 16,
        backend: BackendChoice::NativeOnly,
        artifact_dir: None,
        ..CoordinatorConfig::default()
    })?;
    let img = Arc::new(synth::document(H, W, 7));
    let mut stream = coord.submit_many((0..64).map(|_| (spec, img.clone().into())));
    anyhow::ensure!(stream.shed() == 0, "queue sized for the full stream");
    let mut done = 0u64;
    while let Some(resp) = stream.recv() {
        resp.result?;
        done += 1;
    }
    drop(stream);
    let snap = coord.metrics();
    coord.shutdown();
    anyhow::ensure!(done == 64 && snap.failed == 0, "every request completes");
    anyhow::ensure!(snap.plan_resolutions == 1, "one family must resolve one plan");
    // split-dependent but safe: enqueue is ~ns, execution ~µs, so a
    // 64-deep same-key backlog cannot drain in singleton pulls only
    anyhow::ensure!(snap.fused_batches >= 1, "stream must fuse at least once");
    anyhow::ensure!(snap.fused_requests >= 2 * snap.fused_batches);
    println!("{snap}");
    println!(
        "serving: {done} requests drained in {} fused batches ({} requests fused), \
         1 plan resolution ✓",
        snap.fused_batches, snap.fused_requests
    );
    println!("fused_batch OK");
    Ok(())
}
