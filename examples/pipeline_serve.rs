//! STAGED-PIPELINE SERVING DRIVER: backpressure, per-key admission
//! budgets and exactly-once replies under a saturating producer.
//!
//! One stream fires four 64-request bursts — four slow plan families
//! (15×15 windows on 240×320, one with an interior ROI, one u16) — at
//! a pipeline with tiny stage channels and a per-key admission budget.
//! The producer outruns the lanes by orders of magnitude, so the
//! driver proves the contracts the staged redesign is for:
//!
//! * **admission-only shedding** — every request either sheds at
//!   `send` (full channel or exhausted per-key budget, counted on the
//!   stream) or is answered; accepted work is never dropped;
//! * **bounded stages** — per-stage depth peaks stay within
//!   `stage_capacity` + sender/batch slack, and blocked inter-stage
//!   sends show backpressure actually propagating;
//! * **bit-identity** — every reply equals the one-shot library call
//!   for its family, saturation or not;
//! * **budget release** — once replies land, the hot keys admit again.
//!
//! ```bash
//! cargo run --release --example pipeline_serve
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use neon_morph::coordinator::request::{FilterOutput, ImagePayload};
use neon_morph::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use neon_morph::image::synth;
use neon_morph::morphology::{self, FilterOp, FilterSpec, MorphConfig, Roi};

const BURST: usize = 64;
const BUDGET: usize = 8;
const STAGE_CAP: usize = 4;
const MAX_BATCH: usize = 8;
const H: usize = 240;
const W: usize = 320;

fn main() -> anyhow::Result<()> {
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 2,
        queue_capacity: 4 * BURST,
        max_batch: MAX_BATCH,
        backend: BackendChoice::NativeOnly,
        artifact_dir: None,
        admission_budget: BUDGET,
        stage_capacity: STAGE_CAP,
        ..CoordinatorConfig::default()
    })?;
    let img8 = Arc::new(synth::noise(H, W, 0xA1));
    let img16 = Arc::new(synth::noise_u16(H, W, 0xA2));
    let cfg = MorphConfig::default();

    // four slow plan families and their one-shot library oracles
    let families: Vec<(FilterSpec, ImagePayload, FilterOutput)> = vec![
        (
            FilterSpec::new(FilterOp::Open, 15, 15),
            img8.clone().into(),
            FilterOutput::U8(morphology::parallel::opening_native(img8.view(), 15, 15, &cfg)),
        ),
        (
            FilterSpec::new(FilterOp::Erode, 15, 15).with_roi(Roi::new(8, 8, 64, 80)),
            img8.clone().into(),
            FilterOutput::U8(
                morphology::erode(img8.view(), 15, 15).view().sub_rect(8, 8, 64, 80).to_image(),
            ),
        ),
        (
            FilterSpec::new(FilterOp::Close, 15, 15),
            img8.clone().into(),
            FilterOutput::U8(morphology::parallel::closing_native(img8.view(), 15, 15, &cfg)),
        ),
        (
            FilterSpec::new(FilterOp::Dilate, 15, 15),
            img16.clone().into(),
            FilterOutput::U16(morphology::dilate(img16.view(), 15, 15)),
        ),
    ];

    let t0 = std::time::Instant::now();
    let mut stream = coord.stream();
    let mut family_of: HashMap<u64, usize> = HashMap::new();
    for (fi, (spec, payload, _)) in families.iter().enumerate() {
        for _ in 0..BURST {
            if let Ok(id) = stream.send(*spec, payload.clone()) {
                family_of.insert(id, fi);
            }
        }
    }
    let accepted = stream.sent();
    let shed = stream.shed();
    anyhow::ensure!(
        accepted + shed == (4 * BURST) as u64,
        "every request is accounted: accepted or shed"
    );
    anyhow::ensure!(shed > 0, "saturating bursts must shed at admission");
    println!(
        "admission: {accepted} accepted + {shed} shed = {} submitted \
         (budget {BUDGET}/key, {BURST}-req bursts x {} keys)",
        4 * BURST,
        families.len()
    );

    // exactly-once + bit-identity: every accepted request is answered,
    // and every answer equals its family's library oracle
    let responses = stream.drain();
    anyhow::ensure!(responses.len() as u64 == accepted, "every accepted request answers once");
    for r in responses {
        let fi = family_of.remove(&r.id).expect("known id, never answered twice");
        let got = r.result?;
        let want = &families[fi].2;
        let same = match (&got, want) {
            (FilterOutput::U8(a), FilterOutput::U8(b)) => a.same_pixels(b),
            (FilterOutput::U16(a), FilterOutput::U16(b)) => a.same_pixels(b),
            _ => false,
        };
        anyhow::ensure!(same, "request {} diverges from the library oracle", r.id);
    }
    anyhow::ensure!(family_of.is_empty());
    let wall = t0.elapsed().as_secs_f64();
    println!("all {accepted} replies verified against the library oracles ✓ ({wall:.2}s)");

    // bounded stages + propagated backpressure
    let snap = coord.metrics();
    println!("{snap}");
    anyhow::ensure!(snap.shed == shed && snap.completed == accepted && snap.failed == 0);
    let peak = snap.stage_peak;
    // resolve: one channel of STAGE_CAP + the stage thread's holding
    // slot; execute: per-lane queue + in-flight batch, two lanes
    anyhow::ensure!(
        peak[1] <= (STAGE_CAP + 1) as u64 && peak[2] <= (2 * (STAGE_CAP + MAX_BATCH)) as u64,
        "stage depths must stay bounded: {peak:?}"
    );
    anyhow::ensure!(
        snap.stage_blocked_sends.iter().sum::<u64>() > 0,
        "a saturating producer must block some handoff"
    );
    println!(
        "stage peaks [in/res/exec/reply] {:?} within bounds, {} blocked handoffs ✓",
        peak,
        snap.stage_blocked_sends.iter().sum::<u64>()
    );

    // budget release: with everything replied, a hot key admits again
    let (spec, payload, _) = &families[0];
    let t = coord.submit(*spec, payload.clone())?;
    anyhow::ensure!(t.wait()?.result.is_ok(), "freed budget must admit and serve");
    println!("budget slots released after replies ✓");
    coord.shutdown();
    println!("pipeline_serve OK");
    Ok(())
}
