//! Integration tests driving the `neon-morph` binary end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_neon-morph"))
}

fn tmpdir() -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("neon_morph_cli_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn help_prints_usage() {
    let out = bin().arg("--help").output().unwrap();
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("USAGE"));
    assert!(s.contains("bench"));
}

#[test]
fn unknown_command_fails() {
    let out = bin().arg("explode").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn demo_then_filter_round_trip() {
    let dir = tmpdir();
    let out = bin()
        .args(["demo", "--outdir"])
        .arg(&dir)
        .args(["--height", "120", "--width", "160"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let input = dir.join("demo_input.pgm");
    assert!(input.exists());

    let output = dir.join("filtered.pgm");
    let out = bin()
        .args(["filter", "--op", "dilate", "--wx", "5", "--wy", "3", "--backend", "native"])
        .arg("--input")
        .arg(&input)
        .arg("--output")
        .arg(&output)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // verify the CLI result equals the library call
    let img = neon_morph::image::read_pgm(&input).unwrap();
    let want = neon_morph::morphology::dilate(&img, 5, 3);
    let got = neon_morph::image::read_pgm(&output).unwrap();
    assert!(got.same_pixels(&want));
}

#[test]
fn filter_rejects_missing_input() {
    let out = bin()
        .args(["filter", "--input", "/nonexistent.pgm", "--output", "/tmp/x.pgm"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn bench_table1_runs() {
    let out = bin().args(["bench", "table1"]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("Table 1"));
    assert!(s.contains("16x16"));
}

#[test]
fn bench_rejects_unknown_target() {
    let out = bin().args(["bench", "fig9"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn calibrate_small_window_runs() {
    let out = bin().args(["calibrate", "--max-window", "9"]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("w_y0"));
    assert!(s.contains("w_x0"));
}

#[test]
fn info_reports_manifest_or_absence() {
    let out = bin().arg("info").output().unwrap();
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("manifest") || s.contains("no manifest"));
}

#[test]
fn serve_native_small_load() {
    let out = bin()
        .args(["serve", "--backend", "native", "--requests", "12", "--workers", "2", "--window", "3"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("completed 12 requests"));
}
