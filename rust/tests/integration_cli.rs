//! Integration tests driving the `neon-morph` binary end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_neon-morph"))
}

fn tmpdir() -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("neon_morph_cli_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn help_prints_usage() {
    let out = bin().arg("--help").output().unwrap();
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("USAGE"));
    assert!(s.contains("bench"));
}

#[test]
fn unknown_command_fails() {
    let out = bin().arg("explode").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn demo_then_filter_round_trip() {
    let dir = tmpdir();
    let out = bin()
        .args(["demo", "--outdir"])
        .arg(&dir)
        .args(["--height", "120", "--width", "160"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let input = dir.join("demo_input.pgm");
    assert!(input.exists());

    let output = dir.join("filtered.pgm");
    let out = bin()
        .args(["filter", "--op", "dilate", "--wx", "5", "--wy", "3", "--backend", "native"])
        .arg("--input")
        .arg(&input)
        .arg("--output")
        .arg(&output)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // verify the CLI result equals the library call
    let img = neon_morph::image::read_pgm(&input).unwrap();
    let want = neon_morph::morphology::dilate(&img, 5, 3);
    let got = neon_morph::image::read_pgm(&output).unwrap();
    assert!(got.same_pixels(&want));
}

#[test]
fn filter_rejects_missing_input() {
    let out = bin()
        .args(["filter", "--input", "/nonexistent.pgm", "--output", "/tmp/x.pgm"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn bench_table1_runs() {
    let out = bin().args(["bench", "table1"]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("Table 1"));
    assert!(s.contains("16x16"));
}

#[test]
fn bench_rejects_unknown_target() {
    let out = bin().args(["bench", "fig9"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn bench_smoke_then_gate_round_trip() {
    if cfg!(debug_assertions) {
        eprintln!("SKIP in debug: 800x600 counting sweeps (runs under --release)");
        return;
    }
    let dir = tmpdir();
    let out_dir = dir.join("bench_out");
    let base_dir = dir.join("baselines");

    // smoke writes the machine-readable reports and (here) baselines
    let out = bin()
        .args(["bench", "smoke", "--update-baselines", "--out"])
        .arg(&out_dir)
        .arg("--baselines")
        .arg(&base_dir)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(out_dir.join("BENCH_fig3.json").exists());
    assert!(out_dir.join("BENCH_fig3_u16.json").exists());
    assert!(out_dir.join("BENCH_fig4.json").exists());
    assert!(out_dir.join("BENCH_table1.json").exists());
    assert!(out_dir.join("BENCH_scaling.json").exists());
    assert!(base_dir.join("BENCH_scaling.json").exists());
    assert!(base_dir.join("BENCH_table1.json").exists());
    assert!(base_dir.join("BENCH_fig3_u16.json").exists());

    // the gate passes against the just-written baselines
    let out = bin()
        .args(["bench", "gate", "--out"])
        .arg(&out_dir)
        .arg("--baselines")
        .arg(&base_dir)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("perf gate passed"));

    // seed a 20% drift into one baseline ratio: the gate must fail
    let path = base_dir.join("BENCH_scaling.json");
    let doc = std::fs::read_to_string(&path).unwrap();
    let drifted = doc.replacen("\"speedup_at_2\":", "\"speedup_at_2\":1.2e0,\"was\":", 1);
    assert_ne!(doc, drifted, "fixture edit must apply");
    std::fs::write(&path, drifted).unwrap();
    let out = bin()
        .args(["bench", "gate", "--out"])
        .arg(&out_dir)
        .arg("--baselines")
        .arg(&base_dir)
        .output()
        .unwrap();
    assert!(!out.status.success(), "gate must fail on seeded drift");
    assert!(String::from_utf8_lossy(&out.stdout).contains("speedup_at_2"));
}

#[test]
fn filter_parallel_flag_is_bit_identical() {
    // own subdir: tests run concurrently and `demo` writes fixed names
    let dir = tmpdir().join("parallel_flag");
    std::fs::create_dir_all(&dir).unwrap();
    let input = dir.join("par_input.pgm");
    let demo = bin()
        .args(["demo", "--outdir"])
        .arg(&dir)
        .args(["--height", "90", "--width", "130"])
        .output()
        .unwrap();
    assert!(demo.status.success());
    std::fs::rename(dir.join("demo_input.pgm"), &input).unwrap();

    let run = |parallel: &str, name: &str| {
        let output = dir.join(name);
        let out = bin()
            .args(["filter", "--op", "erode", "--wx", "7", "--wy", "5"])
            .args(["--backend", "native", "--parallel", parallel])
            .arg("--input")
            .arg(&input)
            .arg("--output")
            .arg(&output)
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        neon_morph::image::read_pgm(&output).unwrap()
    };
    let seq = run("off", "seq.pgm");
    let banded = run("4", "banded.pgm");
    let auto = run("auto", "auto.pgm");
    assert!(banded.same_pixels(&seq), "--parallel 4 must be bit-identical");
    assert!(auto.same_pixels(&seq), "--parallel auto must be bit-identical");
}

#[test]
fn filter_roi_flag_equals_cropped_full_filter() {
    // own subdir: tests run concurrently and `demo` writes fixed names
    let dir = tmpdir().join("roi_flag");
    std::fs::create_dir_all(&dir).unwrap();
    let demo = bin()
        .args(["demo", "--outdir"])
        .arg(&dir)
        .args(["--height", "80", "--width", "110"])
        .output()
        .unwrap();
    assert!(demo.status.success());
    let input = dir.join("demo_input.pgm");

    let roi_out = dir.join("roi.pgm");
    let out = bin()
        .args(["filter", "--op", "erode", "--wx", "5", "--wy", "7"])
        .args(["--roi", "10,20,32,48"])
        .arg("--input")
        .arg(&input)
        .arg("--output")
        .arg(&roi_out)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("roi 10,20,32x48"));

    let img = neon_morph::image::read_pgm(&input).unwrap();
    let got = neon_morph::image::read_pgm(&roi_out).unwrap();
    assert_eq!((got.height(), got.width()), (32, 48));
    let full = neon_morph::morphology::erode(&img, 5, 7);
    let want = full.view().sub_rect(10, 20, 32, 48).to_image();
    assert!(got.same_pixels(&want), "--roi must equal cropped full filter");

    // malformed and out-of-bounds ROIs fail cleanly
    let bad = bin()
        .args(["filter", "--op", "erode", "--roi", "1,2,3"])
        .arg("--input")
        .arg(&input)
        .arg("--output")
        .arg(dir.join("bad.pgm"))
        .output()
        .unwrap();
    assert!(!bad.status.success());
    let oob = bin()
        .args(["filter", "--op", "erode", "--roi", "70,100,30,30"])
        .arg("--input")
        .arg(&input)
        .arg("--output")
        .arg(dir.join("oob.pgm"))
        .output()
        .unwrap();
    assert!(!oob.status.success());
    // derived ops compose with --roi since the plan-execute redesign:
    // crop(gradient(full), roi) through a haloed block
    let grad = bin()
        .args(["filter", "--op", "gradient", "--wx", "5", "--wy", "7"])
        .args(["--roi", "5,6,24,30"])
        .arg("--input")
        .arg(&input)
        .arg("--output")
        .arg(dir.join("grad.pgm"))
        .output()
        .unwrap();
    assert!(
        grad.status.success(),
        "{}",
        String::from_utf8_lossy(&grad.stderr)
    );
    let got_g = neon_morph::image::read_pgm(dir.join("grad.pgm")).unwrap();
    let full_g = neon_morph::morphology::gradient(
        &mut neon_morph::neon::Native,
        &img,
        5,
        7,
        &neon_morph::morphology::MorphConfig::default(),
    );
    assert!(
        got_g.same_pixels(&full_g.view().sub_rect(5, 6, 24, 30).to_image()),
        "--op gradient --roi must equal cropped full gradient"
    );
    // the ROI path is native-only: an explicit --backend xla must be
    // rejected, not silently ignored
    let xla = bin()
        .args(["filter", "--op", "erode", "--roi", "0,0,8,8", "--backend", "xla"])
        .arg("--input")
        .arg(&input)
        .arg("--output")
        .arg(dir.join("xla.pgm"))
        .output()
        .unwrap();
    assert!(!xla.status.success());
    assert!(String::from_utf8_lossy(&xla.stderr).contains("native engine"));
}

#[test]
fn filter_op_chain_runs_left_to_right() {
    let dir = tmpdir().join("chain_flag");
    std::fs::create_dir_all(&dir).unwrap();
    let demo = bin()
        .args(["demo", "--outdir"])
        .arg(&dir)
        .args(["--height", "60", "--width", "90"])
        .output()
        .unwrap();
    assert!(demo.status.success());
    let input = dir.join("demo_input.pgm");
    let output = dir.join("chained.pgm");
    let out = bin()
        .args(["filter", "--op", "opening,gradient", "--wx", "3", "--wy", "3"])
        .args(["--backend", "native"])
        .arg("--input")
        .arg(&input)
        .arg("--output")
        .arg(&output)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let img = neon_morph::image::read_pgm(&input).unwrap();
    let cfg = neon_morph::morphology::MorphConfig::default();
    let b = &mut neon_morph::neon::Native;
    let o = neon_morph::morphology::opening(b, &img, 3, 3, &cfg);
    let want = neon_morph::morphology::gradient(b, &o, 3, 3, &cfg);
    let got = neon_morph::image::read_pgm(&output).unwrap();
    assert!(got.same_pixels(&want), "--op opening,gradient must chain");
    // unknown chain element fails with the op list intact
    let bad = bin()
        .args(["filter", "--op", "opening,sharpen"])
        .arg("--input")
        .arg(&input)
        .arg("--output")
        .arg(dir.join("bad.pgm"))
        .output()
        .unwrap();
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("unknown op"));
}

#[test]
fn calibrate_small_window_runs() {
    let out = bin().args(["calibrate", "--max-window", "9"]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("w_y0"));
    assert!(s.contains("w_x0"));
}

#[test]
fn info_reports_manifest_or_absence() {
    let out = bin().arg("info").output().unwrap();
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("manifest") || s.contains("no manifest"));
}

#[test]
fn serve_native_small_load() {
    let out = bin()
        .args(["serve", "--backend", "native", "--requests", "12", "--workers", "2", "--window", "3"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("completed 12 requests"));
}
