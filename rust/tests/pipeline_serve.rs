//! Staged-pipeline serving suite: the backpressure, isolation and
//! exactly-once contracts of the admit → ingress → resolve → execute →
//! reply pipeline, end to end through the public API.
//!
//! What this file pins (beyond `streaming_serve.rs`, which covers
//! bit-identity under the *default* configuration):
//!
//! * **admission-only shedding** — under a saturating producer every
//!   request either sheds at `send` or is answered, per-stage depths
//!   stay bounded by `stage_capacity` + the stage's sender/batch count,
//!   and blocked inter-stage sends (the backpressure-propagation
//!   signal) actually fire;
//! * **per-key admission budget** — a hot key sheds with the budget
//!   error while other keys pass, and replies free the slots;
//! * **panic isolation** — a fault injected into serving (the hidden
//!   `debug_fault_op` hook, both the fused and per-request paths)
//!   answers *those* requests with an error and leaves the lane
//!   serving;
//! * **bit-identity under constrained stages** — the seven-way op mix
//!   streamed through tiny stage channels equals the fire-and-wait
//!   `submit` oracle bit for bit;
//! * **warm-ahead accounting** — `G` same-family requests score
//!   exactly 1 plan resolution + `2G − 1` hits.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use neon_morph::coordinator::metrics::{
    STAGE_EXECUTE, STAGE_INGRESS, STAGE_REPLY, STAGE_RESOLVE,
};
use neon_morph::coordinator::request::{FilterOutput, ImagePayload};
use neon_morph::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use neon_morph::image::{synth, Image};
use neon_morph::morphology::{Border, FilterOp, FilterSpec, MorphConfig, Parallelism, Roi};

#[test]
fn saturating_producer_sheds_only_at_admission_with_bounded_stages() {
    const BURST: usize = 64;
    const QUEUE: usize = 8;
    const STAGE_CAP: usize = 2;
    const MAX_BATCH: usize = 4;
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        queue_capacity: QUEUE,
        max_batch: MAX_BATCH,
        backend: BackendChoice::NativeOnly,
        artifact_dir: None,
        stage_capacity: STAGE_CAP,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    // slow requests so the producer outruns the pipeline by far
    let img = Arc::new(synth::noise(240, 320, 0x5A7));
    let spec = FilterSpec::new(FilterOp::Open, 15, 15);
    let mut stream = coord.stream();
    for _ in 0..BURST {
        let _ = stream.send(spec, img.clone());
    }
    let accepted = stream.sent();
    let shed = stream.shed();
    assert_eq!(accepted + shed, BURST as u64);
    assert!(shed > 0, "a {BURST}-deep burst must overrun queue {QUEUE}");
    assert!(accepted > 0, "admission must accept up to its bounds");

    // exactly-once: every accepted request is answered, each id once
    let responses = stream.drain();
    assert_eq!(responses.len() as u64, accepted);
    let ids: HashSet<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(ids.len() as u64, accepted, "no id may be answered twice");
    assert!(responses.iter().all(|r| r.result.is_ok()));
    drop(stream);

    let snap = coord.metrics();
    assert_eq!(snap.shed, shed, "sheds happen only at admission");
    assert_eq!(snap.completed, accepted);
    assert_eq!(snap.failed, 0);
    // bounded stage depths: capacity + the stage's sender/batch slack
    let peak = snap.stage_peak;
    assert!(peak[STAGE_INGRESS] <= (QUEUE + 1) as u64, "ingress peak {}", peak[STAGE_INGRESS]);
    assert!(peak[STAGE_RESOLVE] <= (STAGE_CAP + 1) as u64, "resolve peak {}", peak[STAGE_RESOLVE]);
    assert!(
        peak[STAGE_EXECUTE] <= (STAGE_CAP + MAX_BATCH) as u64,
        "execute peak {}",
        peak[STAGE_EXECUTE]
    );
    assert!(peak[STAGE_REPLY] <= (STAGE_CAP + 4) as u64, "reply peak {}", peak[STAGE_REPLY]);
    // backpressure really propagated: some inter-stage send had to wait
    assert!(
        snap.stage_blocked_sends.iter().sum::<u64>() > 0,
        "a saturating producer must block at least one handoff: {:?}",
        snap.stage_blocked_sends
    );
    coord.shutdown();
}

#[test]
fn admission_budget_throttles_hot_key_only() {
    const BUDGET: usize = 3;
    const BURST: usize = 32;
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        queue_capacity: 2 * BURST, // never Shed::Full — isolate the budget
        max_batch: 2,
        backend: BackendChoice::NativeOnly,
        artifact_dir: None,
        admission_budget: BUDGET,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let img = Arc::new(synth::noise(240, 320, 0xB0D));
    let hot = FilterSpec::new(FilterOp::Open, 15, 15);
    let mut stream = coord.stream();
    let mut budget_errors = 0u64;
    for _ in 0..BURST {
        if let Err(e) = stream.send(hot, img.clone()) {
            assert!(
                format!("{e:#}").contains("admission budget"),
                "queue sized out of the way, only the budget may shed: {e:#}"
            );
            budget_errors += 1;
        }
    }
    assert!(budget_errors > 0, "a fast burst must outrun budget {BUDGET}");
    assert_eq!(stream.shed(), budget_errors);
    // a different key is not throttled by the hot key's budget
    let cold = stream
        .send(FilterSpec::new(FilterOp::Erode, 3, 3), Arc::new(synth::noise(16, 16, 1)))
        .expect("cold key must admit while the hot key sheds");
    let responses = stream.drain();
    assert_eq!(responses.len() as u64, stream.sent());
    assert!(responses.iter().all(|r| r.result.is_ok()));
    assert!(responses.iter().any(|r| r.id == cold));
    drop(stream);
    // every reply released its slot: the hot key admits again
    let t = coord.submit(hot, img).unwrap();
    assert!(t.wait().unwrap().result.is_ok());
    assert_eq!(coord.metrics().shed, budget_errors);
    coord.shutdown();
}

#[test]
fn injected_panic_is_isolated_and_answered() {
    let faulty = FilterSpec::new(FilterOp::Gradient, 3, 3);
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        backend: BackendChoice::NativeOnly,
        artifact_dir: None,
        debug_fault_op: Some(FilterOp::Gradient),
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let img = Arc::new(synth::noise(32, 32, 0xFA));

    // per-request path: the ticket completes with the panic error
    let resp = coord.filter_spec(faulty, img.clone()).unwrap();
    assert_eq!(resp.backend, "panic");
    let err = resp.result.unwrap_err();
    assert!(format!("{err:#}").contains("panicked"), "{err:#}");

    // the lane survived: a healthy request serves right after
    let ok = coord
        .filter_spec(FilterSpec::new(FilterOp::Erode, 3, 3), img.clone())
        .unwrap();
    assert_eq!(ok.backend, "native");
    assert!(ok.result.is_ok());

    // fused/batched path: a same-key burst of faulty requests — every
    // one is answered (exactly once) with an error, none hangs
    let mut stream = coord.submit_many(
        (0..6).map(|_| (faulty, ImagePayload::from(img.clone()))),
    );
    assert_eq!(stream.shed(), 0);
    let responses = stream.drain();
    assert_eq!(responses.len(), 6);
    assert!(responses.iter().all(|r| r.result.is_err() && r.backend == "panic"));
    drop(stream);

    // and the pipeline still serves afterwards
    let ok = coord
        .filter_spec(FilterSpec::new(FilterOp::Close, 5, 5), img)
        .unwrap();
    assert!(ok.result.is_ok());

    let snap = coord.metrics();
    assert_eq!(snap.failed, 7, "1 per-request + 6 burst panics");
    assert_eq!(snap.completed, 2);
    assert_eq!(snap.shed, 0, "panics are failures, never sheds");
    coord.shutdown();
}

// ---- bit-identity under constrained stages ------------------------------

const H: usize = 72;
const W: usize = 84;

/// The seven-way mixed request stream (`streaming_serve.rs`): op
/// chains, both depths, both borders, interior and edge-clamped ROIs,
/// explicit parallelism.
fn spec_of(i: usize) -> (FilterSpec, bool) {
    let seq = MorphConfig {
        parallelism: Parallelism::Sequential,
        ..MorphConfig::default()
    };
    let repl = MorphConfig {
        border: Border::Replicate,
        ..MorphConfig::default()
    };
    match i % 7 {
        0 => (FilterSpec::new(FilterOp::Erode, 7, 5), false),
        1 => (FilterSpec::new(FilterOp::Gradient, 5, 5), true), // u16
        2 => {
            // interior crop sweep: tophat halo = (4, 4); positions vary
            let y = 4 + (i * 5) % (H - 24 - 8);
            let x = 4 + (i * 3) % (W - 30 - 8);
            (
                FilterSpec::new(FilterOp::TopHat, 5, 5).with_roi(Roi::new(y, x, 24, 30)),
                false,
            )
        }
        3 => (
            FilterSpec::new(FilterOp::Erode, 5, 5).with_roi(Roi::new(0, 0, 20, 20)),
            false,
        ),
        4 => (
            FilterSpec::new(FilterOp::Open, 3, 3)
                .then(FilterOp::Gradient)
                .with_config(seq),
            false,
        ),
        5 => (FilterSpec::new(FilterOp::Close, 5, 7).with_config(repl), true),
        _ => (FilterSpec::new(FilterOp::BlackHat, 3, 3), false),
    }
}

fn payload(is_u16: bool, img8: &Arc<Image<u8>>, img16: &Arc<Image<u16>>) -> ImagePayload {
    if is_u16 {
        img16.clone().into()
    } else {
        img8.clone().into()
    }
}

fn same_output(a: &FilterOutput, b: &FilterOutput) -> bool {
    match (a, b) {
        (FilterOutput::U8(x), FilterOutput::U8(y)) => x.same_pixels(y),
        (FilterOutput::U16(x), FilterOutput::U16(y)) => x.same_pixels(y),
        _ => false,
    }
}

#[test]
fn constrained_stages_stay_bit_identical_to_submit() {
    // tiny stage channels force blocking handoffs on every request, but
    // must never change a pixel (or lose a request: admission is sized
    // out of the way, so nothing sheds)
    const N: usize = 42;
    let img8 = Arc::new(synth::noise(H, W, 0x91));
    let img16 = Arc::new(synth::noise_u16(H, W, 0x92));

    let oracle_coord = Coordinator::start_native(2).unwrap();
    let mut oracles: HashMap<FilterSpec, FilterOutput> = HashMap::new();
    for i in 0..N {
        let (spec, is_u16) = spec_of(i);
        oracles.entry(spec).or_insert_with(|| {
            oracle_coord
                .filter_spec(spec, payload(is_u16, &img8, &img16))
                .unwrap()
                .result
                .unwrap()
        });
    }
    oracle_coord.shutdown();

    let coord = Coordinator::start(CoordinatorConfig {
        workers: 2,
        queue_capacity: N + 8,
        max_batch: 4,
        backend: BackendChoice::NativeOnly,
        artifact_dir: None,
        stage_capacity: 2,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let mut stream = coord.stream();
    let mut by_id = HashMap::new();
    for i in 0..N {
        let (spec, is_u16) = spec_of(i);
        let id = stream
            .send(spec, payload(is_u16, &img8, &img16))
            .expect("admission sized for the full load");
        by_id.insert(id, spec);
    }
    for r in stream.drain() {
        let spec = by_id.remove(&r.id).expect("known id");
        let got = r.result.unwrap();
        assert!(
            same_output(&got, &oracles[&spec]),
            "pipeline result for {spec:?} differs from the submit oracle"
        );
    }
    assert!(by_id.is_empty(), "every request must be answered");
    drop(stream);
    let snap = coord.metrics();
    assert_eq!(snap.completed, N as u64);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.shed, 0);
    coord.shutdown();
}

#[test]
fn warm_ahead_scores_one_resolution_and_2n_minus_1_hits() {
    // the resolve stage warms each request's plan on its lane before
    // execute touches it: G same-family requests = 1 resolution +
    // (2G − 1) hits, independent of how the queue splits batches
    const G: usize = 10;
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        backend: BackendChoice::NativeOnly,
        artifact_dir: None,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let img = Arc::new(synth::noise(48, 48, 0xAB));
    let spec = FilterSpec::new(FilterOp::Close, 5, 5);
    let want = {
        let cfg = MorphConfig::default();
        neon_morph::morphology::parallel::closing_native(img.view(), 5, 5, &cfg)
    };
    let mut stream = coord.stream();
    for _ in 0..G {
        stream.send(spec, img.clone()).unwrap();
    }
    for r in stream.drain() {
        assert!(r.result.unwrap().into_u8().unwrap().same_pixels(&want));
    }
    drop(stream);
    let snap = coord.metrics();
    assert_eq!(snap.completed, G as u64);
    assert_eq!(snap.plan_resolutions, 1, "one family, one resolution");
    assert_eq!(snap.plan_hits, (2 * G - 1) as u64, "{G} warms + {G} executions − 1 resolution");
    coord.shutdown();
}
