//! Counting-backend regression tests for the u16 path: the instruction
//! mixes must reflect 8 lanes per 128-bit op (`vminq_u16`/`vmaxq_u16`,
//! §4's 8×8.16 shape) so the cost model's prices stay honest, and the
//! u16 vertical transpose sandwich must demonstrably run on the 8×8.16
//! NEON tiles.

use neon_morph::costmodel::{simd_lanes, CostModel};
use neon_morph::image::synth;
use neon_morph::morphology::{
    linear, separable, vhgw, HybridThresholds, MorphOp, PassMethod, VerticalStrategy,
};
use neon_morph::neon::{Counting, InstrClass};

/// Same dimensions, same window, both depths: the u16 pass must issue
/// exactly 2× the vector min/max, loads and stores (8 lanes vs 16).
#[test]
fn u16_linear_rows_issues_double_the_vector_ops() {
    // 64 divides by both lane counts, so there is no scalar tail and
    // the 2x relation is exact
    let img8 = synth::noise(64, 64, 11);
    let img16 = synth::noise_u16(64, 64, 11);
    for window in [3usize, 9, 15] {
        for op in [MorphOp::Erode, MorphOp::Dilate] {
            let mut c8 = Counting::new();
            let _ = linear::rows_simd_linear(&mut c8, &img8, window, op);
            let mut c16 = Counting::new();
            let _ = linear::rows_simd_linear(&mut c16, &img16, window, op);
            for class in [
                InstrClass::SimdMinMax,
                InstrClass::SimdLoad,
                InstrClass::SimdStore,
            ] {
                assert_eq!(
                    c16.mix.get(class),
                    2 * c8.mix.get(class),
                    "w={window} {op:?} {class:?}: u16 must be exactly 2x u8 (8 vs 16 lanes)"
                );
            }
            assert!(c16.mix.get(InstrClass::SimdMinMax) > 0);
            // streamed bytes also double (2-byte elements)
            assert_eq!(c16.mix.stream_read, 2 * c8.mix.stream_read);
            assert_eq!(c16.mix.stream_written, 2 * c8.mix.stream_written);
        }
    }
}

/// Exact census of a minimal fully-vectorized u16 case: 2×8 image,
/// window 3.  One 8-lane chunk, rows 0 and 1 share the whole window:
/// 2 vector loads, 1 vminq_u16, 2 vector stores.
#[test]
fn u16_minimal_case_exact_census() {
    let img = synth::noise_u16(2, 8, 1);
    let mut c = Counting::new();
    let _ = linear::rows_simd_linear(&mut c, &img, 3, MorphOp::Erode);
    assert_eq!(c.mix.get(InstrClass::SimdLoad), 2);
    assert_eq!(c.mix.get(InstrClass::SimdMinMax), 1);
    assert_eq!(c.mix.get(InstrClass::SimdStore), 2);
    assert_eq!(c.mix.get(InstrClass::ScalarLoad), 0, "no scalar tail at w=8");
}

/// §5.2.2 vertical pass: unaligned load count per row is
/// `window × width/LANES` — lanes = 8 for u16, so 2× the u8 count.
#[test]
fn u16_cols_linear_unaligned_load_census() {
    let img8 = synth::noise(8, 16, 2);
    let img16 = synth::noise_u16(8, 16, 2);
    let window = 5;
    let mut c8 = Counting::new();
    let _ = linear::cols_simd_linear(&mut c8, &img8, window, MorphOp::Erode);
    let mut c16 = Counting::new();
    let _ = linear::cols_simd_linear(&mut c16, &img16, window, MorphOp::Erode);
    // u8: 1 chunk of 16 lanes per row; u16: 2 chunks of 8 lanes
    assert_eq!(c8.mix.get(InstrClass::SimdLoadUnaligned), 8 * window as u64);
    assert_eq!(
        c16.mix.get(InstrClass::SimdLoadUnaligned),
        8 * 2 * window as u64
    );
}

/// The u16 vertical vHGW path must run through the §4 8×8.16 transpose
/// tiles: on a 64×64 image each transpose is 64 tiles, each tile is
/// exactly 8 vtrn (4 `vtrnq_u16` + 4 `vtrnq_u32` → SimdPermute) and
/// 24 vget/vcombine (SimdCombine); the vHGW rows pass between the two
/// transposes contributes zero permutes.  This pins the dispatch: if
/// the u16 sandwich ever fell back to scalar transpose or 16×16 tiles,
/// these exact counts would break.
#[test]
fn u16_transpose_sandwich_uses_8x8_16_tiles() {
    let img = synth::noise_u16(64, 64, 3);
    let mut c = Counting::new();
    let out = separable::pass_cols(
        &mut c,
        &img,
        15,
        MorphOp::Erode,
        PassMethod::Vhgw,
        true,
        VerticalStrategy::Transpose,
        HybridThresholds::paper(),
    );
    assert_eq!((out.height(), out.width()), (64, 64));
    let tiles = (64 / 8) * (64 / 8); // per transpose
    assert_eq!(
        c.mix.get(InstrClass::SimdPermute),
        (2 * tiles * 8) as u64,
        "2 transposes x 64 tiles x (4 vtrn.16 + 4 vtrn.32)"
    );
    assert_eq!(
        c.mix.get(InstrClass::SimdCombine),
        (2 * tiles * 24) as u64,
        "2 transposes x 64 tiles x (16 vget + 8 vcombine)"
    );
    assert_eq!(
        c.mix.get(InstrClass::ScalarLoad),
        0,
        "64x64 u16 is fully tiled — no scalar edge work"
    );
    assert!(c.mix.get(InstrClass::SimdMinMax) > 0, "vHGW combines present");
}

/// The cost model's lane table and the counted mixes agree: pricing a
/// u16 mix per pixel lands at ~2× the u8 price on equal dimensions.
#[test]
fn lane_table_consistent_with_counted_prices() {
    assert_eq!(simd_lanes("u8"), Some(16));
    assert_eq!(simd_lanes("u16"), Some(8));
    let model = CostModel::exynos5422();
    let img8 = synth::noise(64, 64, 7);
    let img16 = synth::noise_u16(64, 64, 7);
    let mut c8 = Counting::new();
    let _ = vhgw::rows_simd_vhgw(&mut c8, &img8, 15, MorphOp::Erode);
    let mut c16 = Counting::new();
    let _ = vhgw::rows_simd_vhgw(&mut c16, &img16, 15, MorphOp::Erode);
    let r = model.price_ns_per_pixel(&c16.mix, 64 * 64)
        / model.price_ns_per_pixel(&c8.mix, 64 * 64);
    assert!(
        (1.7..=2.3).contains(&r),
        "u16 vHGW should price ~2x u8 per pixel, got {r}"
    );
}
