//! Plan-equivalence property suite: `FilterPlan::run` must be
//! **bit-identical** to the legacy entry points for every spec.
//!
//! The oracle is deliberately the *non-plan* implementation: the
//! backend-generic sequential composition (`separable::morphology` and
//! the generic derived ops, which execute the lowered chain through
//! owned-image composition) — so the arena-backed executor, the banded
//! `_into` paths and the ROI block arithmetic are checked against an
//! independently-running implementation, across
//! op × method × vertical × simd × border × depth × ROI, on strided
//! sources and degenerate shapes.  The coordinator-level wrappers are
//! covered by `coordinator::tests` and `integration_coordinator.rs`.

use neon_morph::image::{synth, Image};
use neon_morph::morphology::{
    self, separable, Border, FilterOp, FilterSpec, HybridThresholds, MorphConfig, MorphOp,
    MorphPixel, Parallelism, PassMethod, Representation, Roi, VerticalStrategy,
};
use neon_morph::neon::Native;

fn configs(parallelism: Parallelism) -> Vec<MorphConfig> {
    let mut out = Vec::new();
    for method in [PassMethod::Linear, PassMethod::Vhgw, PassMethod::Hybrid] {
        for vertical in [VerticalStrategy::Transpose, VerticalStrategy::Direct] {
            for simd in [false, true] {
                for border in [Border::Identity, Border::Replicate] {
                    out.push(MorphConfig {
                        method,
                        vertical,
                        simd,
                        border,
                        thresholds: HybridThresholds::paper(),
                        parallelism,
                        representation: Representation::Dense,
                    });
                }
            }
        }
    }
    out
}

/// The non-plan oracle for one op under one config.
fn legacy<P: MorphPixel>(
    img: &Image<P>,
    op: FilterOp,
    wx: usize,
    wy: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    let b = &mut Native;
    match op {
        FilterOp::Erode => separable::morphology(b, img, MorphOp::Erode, wx, wy, cfg),
        FilterOp::Dilate => separable::morphology(b, img, MorphOp::Dilate, wx, wy, cfg),
        FilterOp::Open => morphology::opening(b, img, wx, wy, cfg),
        FilterOp::Close => morphology::closing(b, img, wx, wy, cfg),
        FilterOp::Gradient => morphology::gradient(b, img, wx, wy, cfg),
        FilterOp::TopHat => morphology::tophat(b, img, wx, wy, cfg),
        FilterOp::BlackHat => morphology::blackhat(b, img, wx, wy, cfg),
        FilterOp::Transpose | FilterOp::Reconstruct => unreachable!(),
    }
}

fn sweep_ops<P: MorphPixel>(img: &Image<P>, windows: &[(usize, usize)], parallelism: Parallelism) {
    for cfg in configs(parallelism) {
        for &(wx, wy) in windows {
            for op in [
                FilterOp::Erode,
                FilterOp::Dilate,
                FilterOp::Open,
                FilterOp::Close,
                FilterOp::Gradient,
                FilterOp::TopHat,
                FilterOp::BlackHat,
            ] {
                let want = legacy(img, op, wx, wy, &cfg);
                let got = FilterSpec::new(op, wx, wy)
                    .with_config(cfg)
                    .run_once::<P>(img)
                    .unwrap();
                assert!(
                    got.same_pixels(&want),
                    "{op:?} {wx}x{wy} cfg={cfg:?}: {:?}",
                    got.first_diff(&want)
                );
            }
        }
    }
}

#[test]
fn every_op_matches_legacy_u8() {
    let img = synth::noise(28, 33, 0x91A);
    sweep_ops(&img, &[(3, 5), (5, 3)], Parallelism::Sequential);
}

#[test]
fn every_op_matches_legacy_u16() {
    let img = synth::noise_u16(20, 24, 0xB0B);
    sweep_ops(&img, &[(3, 3)], Parallelism::Sequential);
}

#[test]
fn banded_plans_match_legacy() {
    // Fixed(3) forces the banded _into executors through the pool
    let img = synth::noise(40, 48, 0x3AD);
    sweep_ops(&img, &[(5, 7)], Parallelism::Fixed(3));
    let img16 = synth::noise_u16(36, 40, 0x3AE);
    sweep_ops(&img16, &[(5, 5)], Parallelism::Fixed(3));
}

#[test]
fn degenerate_shapes_and_windows() {
    for &(h, w) in &[(1, 1), (1, 17), (17, 1), (2, 2), (16, 16)] {
        let img = synth::noise(h, w, (h * 31 + w) as u64);
        for &(wx, wy) in &[(1, 1), (1, 5), (5, 1), (21, 21)] {
            for op in [FilterOp::Erode, FilterOp::TopHat] {
                let cfg = MorphConfig::default();
                let want = legacy(&img, op, wx, wy, &cfg);
                let got = FilterSpec::new(op, wx, wy).run_once::<u8>(&img).unwrap();
                assert!(
                    got.same_pixels(&want),
                    "{op:?} {wx}x{wy} on {h}x{w}: {:?}",
                    got.first_diff(&want)
                );
            }
        }
    }
    let empty = Image::<u8>::zeros(0, 9);
    let out = FilterSpec::new(FilterOp::Gradient, 3, 3).run_once::<u8>(&empty).unwrap();
    assert_eq!((out.height(), out.width()), (0, 9));
}

#[test]
fn strided_sources_match_compact() {
    let img = synth::noise(24, 30, 0x57);
    let padded = img.with_stride(48, 0xEE);
    for op in [FilterOp::Erode, FilterOp::Gradient, FilterOp::BlackHat] {
        let want = FilterSpec::new(op, 5, 3).run_once::<u8>(&img).unwrap();
        let got = FilterSpec::new(op, 5, 3).run_once::<u8>(&padded).unwrap();
        assert!(got.same_pixels(&want), "{op:?} via strided view");
    }
}

#[test]
fn roi_specs_match_cropped_legacy() {
    let img = synth::noise(34, 39, 0x201);
    let rois = [
        Roi::new(0, 0, 9, 11),
        Roi::new(0, 28, 8, 11),
        Roi::new(25, 0, 9, 8),
        Roi::new(8, 10, 14, 17),
        Roi::full(34, 39),
    ];
    for border in [Border::Identity, Border::Replicate] {
        let cfg = MorphConfig {
            border,
            parallelism: Parallelism::Sequential,
            ..MorphConfig::default()
        };
        for op in [FilterOp::Erode, FilterOp::Dilate, FilterOp::TopHat, FilterOp::Gradient] {
            let full = legacy(&img, op, 5, 7, &cfg);
            for roi in rois {
                let want = full.view().sub_rect(roi.y, roi.x, roi.height, roi.width).to_image();
                let got = FilterSpec::new(op, 5, 7)
                    .with_config(cfg)
                    .with_roi(roi)
                    .run_once::<u8>(&img)
                    .unwrap();
                assert!(
                    got.same_pixels(&want),
                    "{op:?} {border:?} {roi:?}: {:?}",
                    got.first_diff(&want)
                );
            }
        }
    }
}

#[test]
fn roi_wrappers_still_equal_plans() {
    // the legacy ROI entry points are wrappers over one-shot plans;
    // pin the equivalence explicitly
    let img = synth::noise_u16(30, 30, 0x88);
    let roi = Roi::new(4, 5, 12, 13);
    let a = morphology::erode_roi(&img, 5, 5, roi);
    let b = FilterSpec::new(FilterOp::Erode, 5, 5)
        .with_roi(roi)
        .run_once::<u16>(&img)
        .unwrap();
    assert!(a.same_pixels(&b));
}

#[test]
fn chains_match_manual_composition() {
    let img = synth::noise(26, 31, 0xCC);
    let cfg = MorphConfig::default();
    let got = FilterSpec::chain(&[FilterOp::Close, FilterOp::TopHat, FilterOp::Dilate], 3, 3)
        .unwrap()
        .run_once::<u8>(&img)
        .unwrap();
    let c = legacy(&img, FilterOp::Close, 3, 3, &cfg);
    let t = legacy(&c, FilterOp::TopHat, 3, 3, &cfg);
    let want = legacy(&t, FilterOp::Dilate, 3, 3, &cfg);
    assert!(got.same_pixels(&want));
}

#[test]
fn one_plan_serves_every_interior_position() {
    // the position-independence property against the non-plan oracle:
    // ONE plan (resolved at the canonical anchor via canonical_for) +
    // run_at reproduces crop(legacy(full), roi) at every interior
    // position, across ops × borders × depths
    let img8 = synth::noise(44, 50, 0x9D1);
    let img16 = synth::noise_u16(44, 50, 0x9D2);
    for border in [Border::Identity, Border::Replicate] {
        let cfg = MorphConfig {
            border,
            parallelism: Parallelism::Sequential,
            ..MorphConfig::default()
        };
        for op in [FilterOp::Erode, FilterOp::TopHat, FilterOp::Gradient] {
            let base = FilterSpec::new(op, 5, 3).with_config(cfg);
            let (hx, hy) = base.roi_halo();
            let positions = [
                (hy, hx),
                (hy + 7, hx + 11),
                (44 - 12 - hy, 50 - 14 - hx),
            ];
            // u8
            let full = legacy(&img8, op, 5, 3, &cfg);
            let canon = base
                .with_roi(Roi::new(positions[0].0, positions[0].1, 12, 14))
                .canonical_for(44, 50);
            let mut plan = canon.plan::<u8>(44, 50).unwrap();
            for &(y, x) in &positions {
                let want = full.view().sub_rect(y, x, 12, 14).to_image();
                let got = plan.run_owned_at(&img8, Roi::new(y, x, 12, 14));
                assert!(
                    got.same_pixels(&want),
                    "u8 {op:?} {border:?} ({y},{x}): {:?}",
                    got.first_diff(&want)
                );
            }
            // u16
            let full = legacy(&img16, op, 5, 3, &cfg);
            let mut plan = canon.plan::<u16>(44, 50).unwrap();
            for &(y, x) in &positions {
                let want = full.view().sub_rect(y, x, 12, 14).to_image();
                let got = plan.run_owned_at(&img16, Roi::new(y, x, 12, 14));
                assert!(got.same_pixels(&want), "u16 {op:?} {border:?} ({y},{x})");
            }
        }
    }
}

#[test]
fn reused_plan_is_bit_stable_across_images() {
    let spec = FilterSpec::new(FilterOp::Gradient, 5, 5);
    let mut plan = spec.plan::<u8>(32, 40).unwrap();
    for seed in 0..6u64 {
        let img = synth::noise(32, 40, seed);
        let want = legacy(&img, FilterOp::Gradient, 5, 5, &MorphConfig::default());
        let got = plan.run_owned(&img);
        assert!(got.same_pixels(&want), "seed {seed}");
    }
}

#[test]
fn run_into_matches_run_owned() {
    let img = synth::noise(22, 27, 0xF0);
    let spec = FilterSpec::new(FilterOp::Open, 5, 3).with_roi(Roi::new(2, 3, 15, 18));
    let mut plan = spec.plan::<u8>(22, 27).unwrap();
    let owned = plan.run_owned(&img);
    let mut dst = Image::<u8>::filled(15, 18, 0xAB);
    plan.run(&img, dst.view_mut());
    assert!(dst.same_pixels(&owned));
}

#[test]
fn transpose_spec_matches_legacy_both_depths() {
    let img = synth::noise(18, 25, 1);
    let got = FilterSpec::new(FilterOp::Transpose, 0, 0).run_once::<u8>(&img).unwrap();
    assert!(got.same_pixels(&img.transposed()));
    let img16 = synth::noise_u16(18, 25, 1);
    let got = FilterSpec::new(FilterOp::Transpose, 0, 0).run_once::<u16>(&img16).unwrap();
    assert!(got.same_pixels(&img16.transposed()));
}
