//! Property suite for the zero-copy view API:
//!
//! * **ROI correctness** — `filter_roi(img, roi) ==
//!   crop(filter_native(img), roi)` across op × method × vertical ×
//!   simd × border × depth, for random (including edge- and
//!   corner-touching) ROIs.  This is the halo-containment theorem the
//!   banded executor also rests on, exercised through the public API.
//! * **Strided sources** — every pass must read through the view's
//!   stride, so padded images filter identically to compact ones.
//! * **`split_at_rows_mut` disjointness smoke** — randomized plans,
//!   concurrent writers on the shared band pool, every cell written
//!   exactly once (run under the seeded `util::prop` harness like the
//!   rest of the differential tests).

use neon_morph::image::{synth, Image, ImageView};
use neon_morph::morphology::{
    self, parallel, Border, HybridThresholds, MorphConfig, MorphOp, MorphPixel, Parallelism,
    PassMethod, Representation, Roi, VerticalStrategy,
};
use neon_morph::util::prop::{dims, forall, odd_window};

fn crop_of<P: MorphPixel>(full: &Image<P>, roi: Roi) -> Image<P> {
    full.view()
        .sub_rect(roi.y, roi.x, roi.height, roi.width)
        .to_image()
}

fn random_roi(rng: &mut synth::Rng, h: usize, w: usize) -> Roi {
    let rh = 1 + rng.below(h);
    let rw = 1 + rng.below(w);
    Roi::new(rng.below(h - rh + 1), rng.below(w - rw + 1), rh, rw)
}

fn configs() -> Vec<MorphConfig> {
    let mut out = Vec::new();
    for method in [PassMethod::Linear, PassMethod::Vhgw, PassMethod::Hybrid] {
        for vertical in [VerticalStrategy::Direct, VerticalStrategy::Transpose] {
            for simd in [false, true] {
                for border in [Border::Identity, Border::Replicate] {
                    out.push(MorphConfig {
                        method,
                        vertical,
                        simd,
                        border,
                        // low thresholds so Hybrid exercises vHGW at
                        // small test windows
                        thresholds: HybridThresholds { wy0: 5, wx0: 5 },
                        parallelism: Parallelism::Sequential,
                        representation: Representation::Dense,
                    });
                }
            }
        }
    }
    out
}

fn check_roi_grid<P: MorphPixel>(img: &Image<P>, w_x: usize, w_y: usize, roi: Roi, label: &str) {
    for op in [MorphOp::Erode, MorphOp::Dilate] {
        for cfg in configs() {
            let full = parallel::filter_native(img, op, w_x, w_y, &cfg);
            let want = crop_of(&full, roi);
            let got = parallel::filter_roi(img, op, w_x, w_y, &cfg, roi);
            assert!(
                got.same_pixels(&want),
                "{label} {op:?} {w_x}x{w_y} roi={roi:?} cfg={cfg:?}: {:?}",
                got.first_diff(&want)
            );
        }
    }
}

#[test]
fn roi_equals_cropped_filter_u8_grid() {
    let img = synth::noise(25, 31, 0x201A);
    // interior, corner-touching, full-width band
    for roi in [Roi::new(8, 9, 11, 13), Roi::new(0, 0, 9, 10), Roi::new(10, 0, 7, 31)] {
        check_roi_grid(&img, 5, 7, roi, "u8");
    }
}

#[test]
fn roi_equals_cropped_filter_u16_grid() {
    let img = synth::noise_u16(21, 24, 0x201B);
    for roi in [Roi::new(6, 5, 10, 12), Roi::new(14, 16, 7, 8)] {
        check_roi_grid(&img, 7, 5, roi, "u16");
    }
}

#[test]
fn prop_roi_matches_crop_random_everything() {
    // randomized shapes, windows, ROI positions and depths; banded and
    // sequential execution; failing cases replay from the printed seed
    forall(0x5EED_201, 60, |rng, _case| {
        let (h, w) = dims(rng, 30, 34);
        let w_x = odd_window(rng, 9);
        let w_y = odd_window(rng, 9);
        let roi = random_roi(rng, h, w);
        let op = if rng.below(2) == 0 { MorphOp::Erode } else { MorphOp::Dilate };
        let parallelism = if rng.below(2) == 0 {
            Parallelism::Sequential
        } else {
            Parallelism::Fixed(1 + rng.below(4))
        };
        let border = if rng.below(2) == 0 { Border::Identity } else { Border::Replicate };
        let cfg = MorphConfig {
            parallelism,
            border,
            ..MorphConfig::default()
        };
        if rng.below(2) == 0 {
            let img = synth::noise(h, w, rng.next_u64());
            let want = crop_of(&parallel::filter_native(&img, op, w_x, w_y, &cfg), roi);
            let got = parallel::filter_roi(&img, op, w_x, w_y, &cfg, roi);
            assert!(
                got.same_pixels(&want),
                "u8 {h}x{w} SE {w_x}x{w_y} {roi:?} {op:?} {cfg:?}: {:?}",
                got.first_diff(&want)
            );
        } else {
            let img = synth::noise_u16(h, w, rng.next_u64());
            let want = crop_of(&parallel::filter_native(&img, op, w_x, w_y, &cfg), roi);
            let got = parallel::filter_roi(&img, op, w_x, w_y, &cfg, roi);
            assert!(
                got.same_pixels(&want),
                "u16 {h}x{w} SE {w_x}x{w_y} {roi:?} {op:?} {cfg:?}: {:?}",
                got.first_diff(&want)
            );
        }
    });
}

#[test]
fn prop_simple_roi_api_and_strided_sources() {
    forall(0x5EED_202, 40, |rng, _case| {
        let (h, w) = dims(rng, 26, 26);
        let w_x = odd_window(rng, 7);
        let w_y = odd_window(rng, 7);
        let roi = random_roi(rng, h, w);
        let img = synth::noise(h, w, rng.next_u64());
        // public one-call ROI API
        let want = crop_of(&morphology::erode(&img, w_x, w_y), roi);
        let got = morphology::erode_roi(&img, w_x, w_y, roi);
        assert!(got.same_pixels(&want), "erode_roi {roi:?}");
        let wantd = crop_of(&morphology::dilate(&img, w_x, w_y), roi);
        let gotd = morphology::dilate_roi(&img, w_x, w_y, roi);
        assert!(gotd.same_pixels(&wantd), "dilate_roi {roi:?}");
        // a padded (strided) source must filter identically
        let padded = img.with_stride(w + 1 + rng.below(17), 0xA5u8);
        let got_padded = morphology::erode(&padded, w_x, w_y);
        assert!(
            got_padded.same_pixels(&morphology::erode(&img, w_x, w_y)),
            "strided source {h}x{w} stride {}",
            padded.stride()
        );
    });
}

#[test]
fn prop_split_at_rows_mut_disjoint_concurrent_writes() {
    // UB/disjointness smoke: random band plans, every band written by a
    // different pool job, every cell of the image written exactly once
    // with its band index — overlap or a missed row would corrupt the
    // pattern (and MIRI/TSan-style aliasing bugs would show as torn
    // values under the concurrent writers)
    let pool = parallel::BandPool::global();
    forall(0x5EED_203, 40, |rng, _case| {
        let (h, w) = dims(rng, 40, 24);
        let bands = 1 + rng.below(h + 3);
        let plan = parallel::split_bands(h, bands);
        let mut img = Image::<u8>::filled(h, w, 0xFF);
        {
            let chunks = img.view_mut().split_rows_mut(&plan);
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (i, mut chunk) in chunks.into_iter().enumerate() {
                jobs.push(Box::new(move || {
                    for y in 0..chunk.height() {
                        chunk.row_mut(y).fill(i as u8);
                    }
                }));
            }
            pool.scope(jobs);
        }
        for (i, band) in plan.iter().enumerate() {
            for y in band.clone() {
                assert!(
                    img.row(y).iter().all(|&v| v == i as u8),
                    "row {y} not exclusively owned by band {i} (plan {plan:?})"
                );
            }
        }
    });
}

#[test]
fn sub_views_share_storage_with_parent() {
    // zero-copy sanity: a sub-view reads the parent's bytes (same
    // addresses), so constructing one cannot allocate or copy pixels
    let img = synth::noise(16, 20, 5);
    let v: ImageView<'_, u8> = img.view();
    let sub = v.sub_rect(3, 4, 8, 9);
    assert!(std::ptr::eq(&sub.row(0)[0], &img.row(3)[4]));
    assert!(std::ptr::eq(&sub.row(7)[8], &img.row(10)[12]));
    let band = v.sub_rows(5..11);
    assert!(std::ptr::eq(&band.row(0)[0], &img.row(5)[0]));
}
