//! Differential suite for band-sharded parallel execution: band output
//! must be **byte-identical** to the sequential oracle for every pass ×
//! method (naive/linear/vHGW/hybrid) × depth (u8/u16) × border, across
//! band counts (1, 2, 7, rows, > rows) and degenerate shapes (bands >
//! rows, window > band height, single-row images).  The same contract
//! covers the banded §4 tile transpose: column-stripe output must match
//! [`Image::transposed`] for dense and strided sources, standalone
//! [`FilterOp::Transpose`] plans, and the full §5.2.1 sandwich.

use neon_morph::image::{synth, ImageView};
use neon_morph::morphology::parallel::{
    self, morphology_banded, pass_cols_banded, pass_rows_banded, transpose_image_banded_into,
    BandPool,
};
use neon_morph::morphology::plan::{FilterOp, FilterSpec};
use neon_morph::morphology::{
    separable, Border, HybridThresholds, MorphConfig, MorphOp, MorphPixel, Parallelism,
    PassMethod, Representation, VerticalStrategy,
};
use neon_morph::neon::Native;
use neon_morph::util::prop;
use neon_morph::Image;

fn pool() -> &'static BandPool {
    BandPool::global()
}

/// Band counts exercising even splits, odd splits, one band per row,
/// and more bands than rows.
fn band_counts(rows: usize) -> Vec<usize> {
    vec![1, 2, 7, rows.max(1), rows + 5]
}

fn configs() -> Vec<MorphConfig> {
    let mut out = Vec::new();
    for method in [PassMethod::Linear, PassMethod::Vhgw, PassMethod::Hybrid] {
        for vertical in [VerticalStrategy::Transpose, VerticalStrategy::Direct] {
            for simd in [false, true] {
                for border in [Border::Identity, Border::Replicate] {
                    out.push(MorphConfig {
                        method,
                        vertical,
                        simd,
                        border,
                        // low thresholds so Hybrid actually exercises
                        // the vHGW branch at small test windows
                        thresholds: HybridThresholds { wy0: 5, wx0: 5 },
                        parallelism: Parallelism::Sequential,
                        representation: Representation::Dense,
                    });
                }
            }
        }
    }
    out
}

fn check_morph<P: MorphPixel>(img: &Image<P>, w_x: usize, w_y: usize, label: &str) {
    for op in [MorphOp::Erode, MorphOp::Dilate] {
        for cfg in configs() {
            let want = separable::morphology(&mut Native, img, op, w_x, w_y, &cfg);
            for &bands in &band_counts(img.height()) {
                let got = morphology_banded(pool(), img, op, w_x, w_y, &cfg, bands);
                assert!(
                    got.same_pixels(&want),
                    "{label} {op:?} {w_x}x{w_y} bands={bands} cfg={cfg:?}: {:?}",
                    got.first_diff(&want)
                );
            }
        }
    }
}

#[test]
fn banded_morphology_identical_u8() {
    let img = synth::noise(23, 29, 0xB0B);
    check_morph(&img, 5, 7, "u8");
}

#[test]
fn banded_morphology_identical_u16() {
    let img = synth::noise_u16(19, 21, 0xB0B16);
    check_morph(&img, 7, 5, "u16");
}

#[test]
fn banded_rows_pass_identical_all_methods() {
    let th = HybridThresholds { wy0: 7, wx0: 7 };
    for &(h, w) in &[(1usize, 20usize), (2, 33), (5, 16), (31, 47)] {
        let img = synth::noise(h, w, (h * 1000 + w) as u64);
        for &window in &[3usize, 9, 15] {
            for method in [PassMethod::Linear, PassMethod::Vhgw, PassMethod::Hybrid] {
                for simd in [false, true] {
                    for op in [MorphOp::Erode, MorphOp::Dilate] {
                        let want = separable::pass_rows(
                            &mut Native,
                            &img,
                            window,
                            op,
                            method,
                            simd,
                            th,
                        );
                        for &bands in &band_counts(h) {
                            let got = pass_rows_banded(
                                pool(),
                                &img,
                                window,
                                op,
                                method,
                                simd,
                                th,
                                bands,
                            );
                            assert!(
                                got.same_pixels(&want),
                                "rows {h}x{w} win={window} {method:?} simd={simd} \
                                 bands={bands}: {:?}",
                                got.first_diff(&want)
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn banded_cols_pass_identical_all_methods() {
    let th = HybridThresholds { wy0: 7, wx0: 7 };
    for &(h, w) in &[(1usize, 20usize), (6, 17), (24, 40)] {
        let img = synth::noise(h, w, (h * 77 + w) as u64);
        for &window in &[3usize, 9, 15] {
            for method in [PassMethod::Linear, PassMethod::Vhgw, PassMethod::Hybrid] {
                for vertical in [VerticalStrategy::Direct, VerticalStrategy::Transpose] {
                    for simd in [false, true] {
                        let op = MorphOp::Erode;
                        let want = separable::pass_cols(
                            &mut Native,
                            &img,
                            window,
                            op,
                            method,
                            simd,
                            vertical,
                            th,
                        );
                        for &bands in &band_counts(h) {
                            let got = pass_cols_banded(
                                pool(),
                                &img,
                                window,
                                op,
                                method,
                                simd,
                                vertical,
                                th,
                                bands,
                            );
                            assert!(
                                got.same_pixels(&want),
                                "cols {h}x{w} win={window} {method:?}/{vertical:?} \
                                 simd={simd} bands={bands}: {:?}",
                                got.first_diff(&want)
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn window_larger_than_band_height() {
    // every band is 1-2 rows tall while the window spans 15 rows: the
    // halo covers (almost) the whole image per band
    let img = synth::noise(9, 24, 0x7A11);
    let th = HybridThresholds::paper();
    for op in [MorphOp::Erode, MorphOp::Dilate] {
        let want = separable::pass_rows(&mut Native, &img, 15, op, PassMethod::Linear, true, th);
        let got = pass_rows_banded(pool(), &img, 15, op, PassMethod::Linear, true, th, 9);
        assert!(got.same_pixels(&want), "{op:?}: {:?}", got.first_diff(&want));
    }
}

#[test]
fn seeded_property_banding_is_invisible() {
    // randomized shapes, windows, band counts and depths, against the
    // sequential path; failing cases replay from the printed seed
    prop::forall(0xBAD9E0, 40, |rng, _case| {
        let (h, w) = prop::dims(rng, 28, 36);
        let w_x = prop::odd_window(rng, 9);
        let w_y = prop::odd_window(rng, 9);
        let bands = 1 + rng.below(h + 4);
        let op = if rng.below(2) == 0 { MorphOp::Erode } else { MorphOp::Dilate };
        let cfg = MorphConfig::default();
        if rng.below(2) == 0 {
            let img = synth::noise(h, w, rng.next_u64());
            let want = separable::morphology(&mut Native, &img, op, w_x, w_y, &cfg);
            let got = morphology_banded(pool(), &img, op, w_x, w_y, &cfg, bands);
            assert!(
                got.same_pixels(&want),
                "u8 {h}x{w} SE {w_x}x{w_y} bands={bands} {op:?}: {:?}",
                got.first_diff(&want)
            );
        } else {
            let img = synth::noise_u16(h, w, rng.next_u64());
            let want = separable::morphology(&mut Native, &img, op, w_x, w_y, &cfg);
            let got = morphology_banded(pool(), &img, op, w_x, w_y, &cfg, bands);
            assert!(
                got.same_pixels(&want),
                "u16 {h}x{w} SE {w_x}x{w_y} bands={bands} {op:?}: {:?}",
                got.first_diff(&want)
            );
        }
    });
}

#[test]
fn filter_native_auto_equals_sequential_on_paper_image() {
    // the production entry point on a workload large enough for Auto to
    // actually shard (800x600, w=31 prices ~ms on the model)
    let img = synth::paper_image(0xF11);
    let auto_cfg = MorphConfig::default();
    let seq_cfg = MorphConfig {
        parallelism: Parallelism::Sequential,
        ..auto_cfg
    };
    let got = parallel::filter_native(&img, MorphOp::Erode, 31, 31, &auto_cfg);
    let want = parallel::filter_native(&img, MorphOp::Erode, 31, 31, &seq_cfg);
    assert!(got.same_pixels(&want));
    // Auto must actually pick bands > 1 here (the crossover fires) —
    // unless this machine only has one core to offer
    let bands = parallel::effective_bands::<u8>(600, 800, 31, 31, &auto_cfg);
    if BandPool::global().size() > 1 {
        assert!(bands > 1, "Auto should shard the paper workload, got {bands}");
    }
}

// ---------------------------------------------------------------------------
// banded §4 tile transpose
// ---------------------------------------------------------------------------

#[test]
fn banded_transpose_identical_u8() {
    // shapes: tile-exact, off-tile both axes, 1-row, 1-col, tall/thin
    for &(h, w) in &[
        (1usize, 20usize),
        (20, 1),
        (16, 16),
        (17, 33),
        (48, 64),
        (23, 5),
        (5, 23),
        (50, 47),
    ] {
        let img = synth::noise(h, w, (h * 1009 + w) as u64);
        let want = img.transposed();
        for &bands in &band_counts(h) {
            let mut got = Image::<u8>::zeros(w, h);
            transpose_image_banded_into(pool(), img.view(), got.view_mut(), bands);
            assert!(
                got.same_pixels(&want),
                "u8 {h}x{w} bands={bands}: {:?}",
                got.first_diff(&want)
            );
        }
    }
}

#[test]
fn banded_transpose_identical_u16() {
    for &(h, w) in &[(1usize, 9usize), (8, 8), (19, 27), (40, 24), (9, 40)] {
        let img = synth::noise_u16(h, w, (h * 31 + w) as u64);
        let want = img.transposed();
        for &bands in &band_counts(h) {
            let mut got = Image::<u16>::zeros(w, h);
            transpose_image_banded_into(pool(), img.view(), got.view_mut(), bands);
            assert!(
                got.same_pixels(&want),
                "u16 {h}x{w} bands={bands}: {:?}",
                got.first_diff(&want)
            );
        }
    }
}

#[test]
fn banded_transpose_reads_strided_sources() {
    // a borrowed view whose stride exceeds its width (e.g. a sub-rect
    // of a larger image) must band exactly like a dense image
    let (h, w, stride) = (21usize, 37usize, 50usize);
    let backing: Vec<u8> = (0..h * stride).map(|i| (i * 131 % 251) as u8).collect();
    let view = ImageView::from_slice(&backing, h, w, stride);
    let dense = view.to_image();
    let want = dense.transposed();
    for &bands in &band_counts(h) {
        let mut got = Image::<u8>::zeros(w, h);
        transpose_image_banded_into(pool(), view, got.view_mut(), bands);
        assert!(
            got.same_pixels(&want),
            "strided bands={bands}: {:?}",
            got.first_diff(&want)
        );
    }
}

#[test]
fn standalone_transpose_spec_bands_are_invisible() {
    // the FilterOp::Transpose plan under every parallelism policy must
    // reproduce Image::transposed at both depths
    let img8 = synth::noise(45, 61, 0x7E57);
    let img16 = synth::noise_u16(33, 29, 0x7E57_16);
    for parallelism in [
        Parallelism::Sequential,
        Parallelism::Fixed(2),
        Parallelism::Fixed(7),
        Parallelism::Fixed(64),
        Parallelism::Auto,
    ] {
        let cfg = MorphConfig {
            parallelism,
            ..MorphConfig::default()
        };
        let got8 = FilterSpec::new(FilterOp::Transpose, 0, 0)
            .with_config(cfg)
            .run_once::<u8>(&img8)
            .unwrap();
        assert!(got8.same_pixels(&img8.transposed()), "u8 {parallelism:?}");
        let got16 = FilterSpec::new(FilterOp::Transpose, 0, 0)
            .with_config(cfg)
            .run_once::<u16>(&img16)
            .unwrap();
        assert!(got16.same_pixels(&img16.transposed()), "u16 {parallelism:?}");
    }
}

#[test]
fn sandwich_plan_fixed_bands_bit_identical() {
    // the plan-arena sandwich (run_cols_pass: banded transpose ∘ banded
    // rows ∘ banded transpose) against the sequential plan, at a window
    // that forces vHGW through the transpose sandwich and a Linear one
    // forced through it explicitly
    let img = synth::noise(37, 53, 0x5A9D);
    for method in [PassMethod::Vhgw, PassMethod::Linear] {
        let base = MorphConfig {
            method,
            vertical: VerticalStrategy::Transpose,
            simd: true,
            parallelism: Parallelism::Sequential,
            ..MorphConfig::default()
        };
        let want = parallel::filter_native(&img, MorphOp::Erode, 9, 9, &base);
        for bands in [2usize, 5, 37, 64] {
            let cfg = MorphConfig {
                parallelism: Parallelism::Fixed(bands),
                ..base
            };
            let got = parallel::filter_native(&img, MorphOp::Erode, 9, 9, &cfg);
            assert!(
                got.same_pixels(&want),
                "{method:?} bands={bands}: {:?}",
                got.first_diff(&want)
            );
        }
    }
}
