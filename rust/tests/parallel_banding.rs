//! Differential suite for band-sharded parallel execution: band output
//! must be **byte-identical** to the sequential oracle for every pass ×
//! method (naive/linear/vHGW/hybrid) × depth (u8/u16) × border, across
//! band counts (1, 2, 7, rows, > rows) and degenerate shapes (bands >
//! rows, window > band height, single-row images).

use neon_morph::image::synth;
use neon_morph::morphology::parallel::{
    self, morphology_banded, pass_cols_banded, pass_rows_banded, BandPool,
};
use neon_morph::morphology::{
    separable, Border, HybridThresholds, MorphConfig, MorphOp, MorphPixel, Parallelism,
    PassMethod, Representation, VerticalStrategy,
};
use neon_morph::neon::Native;
use neon_morph::util::prop;
use neon_morph::Image;

fn pool() -> &'static BandPool {
    BandPool::global()
}

/// Band counts exercising even splits, odd splits, one band per row,
/// and more bands than rows.
fn band_counts(rows: usize) -> Vec<usize> {
    vec![1, 2, 7, rows.max(1), rows + 5]
}

fn configs() -> Vec<MorphConfig> {
    let mut out = Vec::new();
    for method in [PassMethod::Linear, PassMethod::Vhgw, PassMethod::Hybrid] {
        for vertical in [VerticalStrategy::Transpose, VerticalStrategy::Direct] {
            for simd in [false, true] {
                for border in [Border::Identity, Border::Replicate] {
                    out.push(MorphConfig {
                        method,
                        vertical,
                        simd,
                        border,
                        // low thresholds so Hybrid actually exercises
                        // the vHGW branch at small test windows
                        thresholds: HybridThresholds { wy0: 5, wx0: 5 },
                        parallelism: Parallelism::Sequential,
                        representation: Representation::Dense,
                    });
                }
            }
        }
    }
    out
}

fn check_morph<P: MorphPixel>(img: &Image<P>, w_x: usize, w_y: usize, label: &str) {
    for op in [MorphOp::Erode, MorphOp::Dilate] {
        for cfg in configs() {
            let want = separable::morphology(&mut Native, img, op, w_x, w_y, &cfg);
            for &bands in &band_counts(img.height()) {
                let got = morphology_banded(pool(), img, op, w_x, w_y, &cfg, bands);
                assert!(
                    got.same_pixels(&want),
                    "{label} {op:?} {w_x}x{w_y} bands={bands} cfg={cfg:?}: {:?}",
                    got.first_diff(&want)
                );
            }
        }
    }
}

#[test]
fn banded_morphology_identical_u8() {
    let img = synth::noise(23, 29, 0xB0B);
    check_morph(&img, 5, 7, "u8");
}

#[test]
fn banded_morphology_identical_u16() {
    let img = synth::noise_u16(19, 21, 0xB0B16);
    check_morph(&img, 7, 5, "u16");
}

#[test]
fn banded_rows_pass_identical_all_methods() {
    let th = HybridThresholds { wy0: 7, wx0: 7 };
    for &(h, w) in &[(1usize, 20usize), (2, 33), (5, 16), (31, 47)] {
        let img = synth::noise(h, w, (h * 1000 + w) as u64);
        for &window in &[3usize, 9, 15] {
            for method in [PassMethod::Linear, PassMethod::Vhgw, PassMethod::Hybrid] {
                for simd in [false, true] {
                    for op in [MorphOp::Erode, MorphOp::Dilate] {
                        let want = separable::pass_rows(
                            &mut Native,
                            &img,
                            window,
                            op,
                            method,
                            simd,
                            th,
                        );
                        for &bands in &band_counts(h) {
                            let got = pass_rows_banded(
                                pool(),
                                &img,
                                window,
                                op,
                                method,
                                simd,
                                th,
                                bands,
                            );
                            assert!(
                                got.same_pixels(&want),
                                "rows {h}x{w} win={window} {method:?} simd={simd} \
                                 bands={bands}: {:?}",
                                got.first_diff(&want)
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn banded_cols_pass_identical_all_methods() {
    let th = HybridThresholds { wy0: 7, wx0: 7 };
    for &(h, w) in &[(1usize, 20usize), (6, 17), (24, 40)] {
        let img = synth::noise(h, w, (h * 77 + w) as u64);
        for &window in &[3usize, 9, 15] {
            for method in [PassMethod::Linear, PassMethod::Vhgw, PassMethod::Hybrid] {
                for vertical in [VerticalStrategy::Direct, VerticalStrategy::Transpose] {
                    for simd in [false, true] {
                        let op = MorphOp::Erode;
                        let want = separable::pass_cols(
                            &mut Native,
                            &img,
                            window,
                            op,
                            method,
                            simd,
                            vertical,
                            th,
                        );
                        for &bands in &band_counts(h) {
                            let got = pass_cols_banded(
                                pool(),
                                &img,
                                window,
                                op,
                                method,
                                simd,
                                vertical,
                                th,
                                bands,
                            );
                            assert!(
                                got.same_pixels(&want),
                                "cols {h}x{w} win={window} {method:?}/{vertical:?} \
                                 simd={simd} bands={bands}: {:?}",
                                got.first_diff(&want)
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn window_larger_than_band_height() {
    // every band is 1-2 rows tall while the window spans 15 rows: the
    // halo covers (almost) the whole image per band
    let img = synth::noise(9, 24, 0x7A11);
    let th = HybridThresholds::paper();
    for op in [MorphOp::Erode, MorphOp::Dilate] {
        let want = separable::pass_rows(&mut Native, &img, 15, op, PassMethod::Linear, true, th);
        let got = pass_rows_banded(pool(), &img, 15, op, PassMethod::Linear, true, th, 9);
        assert!(got.same_pixels(&want), "{op:?}: {:?}", got.first_diff(&want));
    }
}

#[test]
fn seeded_property_banding_is_invisible() {
    // randomized shapes, windows, band counts and depths, against the
    // sequential path; failing cases replay from the printed seed
    prop::forall(0xBAD9E0, 40, |rng, _case| {
        let (h, w) = prop::dims(rng, 28, 36);
        let w_x = prop::odd_window(rng, 9);
        let w_y = prop::odd_window(rng, 9);
        let bands = 1 + rng.below(h + 4);
        let op = if rng.below(2) == 0 { MorphOp::Erode } else { MorphOp::Dilate };
        let cfg = MorphConfig::default();
        if rng.below(2) == 0 {
            let img = synth::noise(h, w, rng.next_u64());
            let want = separable::morphology(&mut Native, &img, op, w_x, w_y, &cfg);
            let got = morphology_banded(pool(), &img, op, w_x, w_y, &cfg, bands);
            assert!(
                got.same_pixels(&want),
                "u8 {h}x{w} SE {w_x}x{w_y} bands={bands} {op:?}: {:?}",
                got.first_diff(&want)
            );
        } else {
            let img = synth::noise_u16(h, w, rng.next_u64());
            let want = separable::morphology(&mut Native, &img, op, w_x, w_y, &cfg);
            let got = morphology_banded(pool(), &img, op, w_x, w_y, &cfg, bands);
            assert!(
                got.same_pixels(&want),
                "u16 {h}x{w} SE {w_x}x{w_y} bands={bands} {op:?}: {:?}",
                got.first_diff(&want)
            );
        }
    });
}

#[test]
fn filter_native_auto_equals_sequential_on_paper_image() {
    // the production entry point on a workload large enough for Auto to
    // actually shard (800x600, w=31 prices ~ms on the model)
    let img = synth::paper_image(0xF11);
    let auto_cfg = MorphConfig::default();
    let seq_cfg = MorphConfig {
        parallelism: Parallelism::Sequential,
        ..auto_cfg
    };
    let got = parallel::filter_native(&img, MorphOp::Erode, 31, 31, &auto_cfg);
    let want = parallel::filter_native(&img, MorphOp::Erode, 31, 31, &seq_cfg);
    assert!(got.same_pixels(&want));
    // Auto must actually pick bands > 1 here (the crossover fires) —
    // unless this machine only has one core to offer
    let bands = parallel::effective_bands::<u8>(600, 800, 31, 31, &auto_cfg);
    if BandPool::global().size() > 1 {
        assert!(bands > 1, "Auto should shard the paper workload, got {bands}");
    }
}
