//! Integration tests over the full L3 path: coordinator → router →
//! (XLA | native) engines, with concurrency, mixed backends and
//! failure handling.

use std::sync::Arc;

use neon_morph::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use neon_morph::image::synth;
use neon_morph::morphology::{self, FilterOp, FilterSpec, MorphConfig};
use neon_morph::neon::Native;
use neon_morph::runtime::Manifest;

fn artifacts_built() -> bool {
    Manifest::load("artifacts").is_ok()
}

fn auto_coordinator(workers: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers,
        backend: BackendChoice::Auto,
        artifact_dir: Some("artifacts".into()),
        ..CoordinatorConfig::default()
    })
    .unwrap()
}

#[test]
fn auto_routes_artifact_shapes_to_xla_and_others_to_native() {
    if !artifacts_built() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let coord = auto_coordinator(1);
    // 256x256 erode w3x3 has an artifact -> xla
    let img = Arc::new(synth::noise(256, 256, 11));
    let r = coord.filter_spec(FilterSpec::parse_op("erode", 3, 3).unwrap(), img.clone()).unwrap();
    assert_eq!(r.backend, "xla-pjrt");
    let want = morphology::erode(img.view(), 3, 3);
    assert!(r.result.unwrap().into_u8().unwrap().same_pixels(&want));

    // 100x100 has no artifact -> native
    let img2 = Arc::new(synth::noise(100, 100, 12));
    let r2 = coord.filter_spec(FilterSpec::parse_op("erode", 3, 3).unwrap(), img2.clone()).unwrap();
    assert_eq!(r2.backend, "native");
    let out2 = r2.result.unwrap().into_u8().unwrap();
    assert!(out2.same_pixels(&morphology::erode(img2.view(), 3, 3)));
    coord.shutdown();
}

#[test]
fn xla_only_fails_for_uncompiled_shape() {
    if !artifacts_built() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        backend: BackendChoice::XlaOnly,
        artifact_dir: Some("artifacts".into()),
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let img = Arc::new(synth::noise(100, 100, 13));
    let r = coord.filter_spec(FilterSpec::parse_op("erode", 3, 3).unwrap(), img).unwrap();
    assert!(r.result.is_err(), "no artifact for 100x100 -> must fail");
    let ok = Arc::new(synth::noise(256, 256, 14));
    let r2 = coord.filter_spec(FilterSpec::parse_op("erode", 3, 3).unwrap(), ok).unwrap();
    assert_eq!(r2.backend, "xla-pjrt");
    assert!(r2.result.is_ok());
    coord.shutdown();
}

#[test]
fn mixed_concurrent_load_from_many_threads() {
    if !artifacts_built() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let coord = Arc::new(auto_coordinator(4));
    let img_art = Arc::new(synth::noise(256, 256, 15));
    let img_nat = Arc::new(synth::noise(64, 64, 16));
    let mut handles = Vec::new();
    for t in 0..6 {
        let coord = coord.clone();
        let img_art = img_art.clone();
        let img_nat = img_nat.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..6 {
                let (op, img) = match (t + i) % 3 {
                    0 => ("erode", img_art.clone()),
                    1 => ("dilate", img_art.clone()),
                    _ => ("gradient", img_nat.clone()),
                };
                let w = if img.height() == 256 { 3 } else { 5 };
                let r = coord
                    .filter_spec(FilterSpec::parse_op(op, w, w).unwrap(), img)
                    .unwrap();
                r.result.unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = coord.metrics();
    assert_eq!(snap.completed, 36);
    assert_eq!(snap.failed, 0);
    assert!(snap.mean_batch_size() >= 1.0);
}

#[test]
fn native_fallback_when_artifact_dir_missing() {
    // Auto + nonexistent dir must degrade to native, not fail
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        backend: BackendChoice::Auto,
        artifact_dir: Some("/nonexistent/artifacts".into()),
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let img = Arc::new(synth::noise(32, 32, 17));
    let r = coord.filter_spec(FilterSpec::parse_op("erode", 3, 3).unwrap(), img.clone()).unwrap();
    assert_eq!(r.backend, "native");
    assert!(r.result.unwrap().into_u8().unwrap().same_pixels(&morphology::erode(img.view(), 3, 3)));
    coord.shutdown();
}

#[test]
fn xla_only_without_artifacts_fails_to_start() {
    let r = Coordinator::start(CoordinatorConfig {
        workers: 1,
        backend: BackendChoice::XlaOnly,
        artifact_dir: Some("/nonexistent/artifacts".into()),
        ..CoordinatorConfig::default()
    });
    assert!(r.is_err());
}

#[test]
fn derived_ops_through_full_xla_path() {
    if !artifacts_built() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let coord = auto_coordinator(2);
    let img = Arc::new(synth::document(256, 256, 18));
    let cfg = MorphConfig::default();
    for (op, wx, wy) in [("opening", 7usize, 7usize), ("closing", 7, 7), ("gradient", 15, 15)] {
        let r = coord
            .filter_spec(FilterSpec::parse_op(op, wx, wy).unwrap(), img.clone())
            .unwrap();
        assert_eq!(r.backend, "xla-pjrt", "{op}");
        let got = r.result.unwrap().into_u8().unwrap();
        let want = match op {
            "opening" => morphology::opening(&mut Native, img.view(), wx, wy, &cfg),
            "closing" => morphology::closing(&mut Native, img.view(), wx, wy, &cfg),
            _ => morphology::gradient(&mut Native, img.view(), wx, wy, &cfg),
        };
        assert!(got.same_pixels(&want), "{op} xla != native");
    }
    coord.shutdown();
}

#[test]
fn batching_stays_fair_when_bands_and_requests_contend_for_the_pool() {
    use neon_morph::morphology::Parallelism;
    // Two coordinator workers serve two request keys while every
    // request band-shards across the shared band pool (Fixed(3) forces
    // banding even for small images).  Same-key batching must stay
    // fair: both keys complete fully, nothing is shed, and neither key
    // starves the other even though bands and requests contend for the
    // same cores.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 2,
        queue_capacity: 256,
        max_batch: 4,
        backend: BackendChoice::NativeOnly,
        artifact_dir: None,
        morph: MorphConfig {
            parallelism: Parallelism::Fixed(3),
            ..MorphConfig::default()
        },
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let img = Arc::new(synth::noise(120, 160, 0xFA17));
    let banded = MorphConfig {
        parallelism: Parallelism::Fixed(3),
        ..MorphConfig::default()
    };
    let mut tickets = Vec::new();
    for i in 0..32 {
        let op = if i % 2 == 0 { FilterOp::Erode } else { FilterOp::Dilate };
        let spec = FilterSpec::new(op, 7, 7).with_config(banded);
        tickets.push((op, coord.submit(spec, img.clone()).unwrap()));
    }
    let want_e = morphology::erode(img.view(), 7, 7);
    let want_d = morphology::dilate(img.view(), 7, 7);
    let (mut done_e, mut done_d) = (0u32, 0u32);
    for (op, t) in tickets {
        let r = t.wait().unwrap();
        let out = r.result.unwrap().into_u8().unwrap();
        if op == FilterOp::Erode {
            assert!(out.same_pixels(&want_e), "banded erode under contention");
            done_e += 1;
        } else {
            assert!(out.same_pixels(&want_d), "banded dilate under contention");
            done_d += 1;
        }
    }
    assert_eq!((done_e, done_d), (16, 16));
    let snap = coord.metrics();
    assert_eq!(snap.completed, 32);
    assert_eq!(snap.shed, 0);
    // same-key grouping actually happened (batches < requests): 32
    // quick submissions against slow banded executions must coalesce
    assert!(
        snap.mean_batch_size() > 1.0,
        "expected same-key batching under contention, mean {}",
        snap.mean_batch_size()
    );
    coord.shutdown();
}

#[test]
fn queue_latency_reported_nonzero_under_load() {
    let coord = Coordinator::start_native(1).unwrap();
    let img = Arc::new(synth::paper_image(19));
    let tickets: Vec<_> = (0..8)
        .map(|_| {
            coord
                .submit(FilterSpec::new(FilterOp::Open, 9, 9), img.clone())
                .unwrap()
        })
        .collect();
    for t in tickets {
        t.wait().unwrap().result.unwrap();
    }
    let snap = coord.metrics();
    assert_eq!(snap.completed, 8);
    // with a single worker the later requests must have queued
    assert!(snap.queue_p99_us > 0.0);
    coord.shutdown();
}
