//! Algebraic laws of mathematical morphology, checked at BOTH pixel
//! depths (the dilation-as-convolution equivalences of Sridhar et al.,
//! arXiv:2305.03018, rest on the same lattice identities):
//!
//! * duality:      `dilate(img) == invert(erode(invert(img)))` under
//!                  identity borders (invert = dtype-MAX − v),
//! * composition:  `opening == dilate ∘ erode`,
//!                 `closing == erode ∘ dilate`,
//! * idempotence:  `opening ∘ opening == opening`,
//!                 `closing ∘ closing == closing`.

use neon_morph::image::synth::{self, Rng};
use neon_morph::image::Image;
use neon_morph::morphology::{self, MorphConfig, MorphOp, MorphPixel};
use neon_morph::neon::Native;
use neon_morph::util::prop::{dims, forall, odd_window};

fn invert<P: MorphPixel>(img: &Image<P>) -> Image<P> {
    Image::from_fn(img.height(), img.width(), |y, x| img.get(y, x).invert())
}

fn check_duality<P: MorphPixel>(img: &Image<P>, w_x: usize, w_y: usize) {
    let d = morphology::dilate(img, w_x, w_y);
    let e_dual = invert(&morphology::erode(&invert(img), w_x, w_y));
    assert!(
        d.same_pixels(&e_dual),
        "dilate != !erode(!img) at {w_x}x{w_y}: {:?}",
        d.first_diff(&e_dual)
    );
}

fn check_composition_laws<P: MorphPixel>(img: &Image<P>, w_x: usize, w_y: usize) {
    let cfg = MorphConfig::default();
    let b = &mut Native;

    // opening = dilate ∘ erode, closing = erode ∘ dilate
    let o = morphology::opening(b, img, w_x, w_y, &cfg);
    let e = morphology::morphology(b, img, MorphOp::Erode, w_x, w_y, &cfg);
    let de = morphology::morphology(b, &e, MorphOp::Dilate, w_x, w_y, &cfg);
    assert!(o.same_pixels(&de), "opening != dilate∘erode");

    let c = morphology::closing(b, img, w_x, w_y, &cfg);
    let d = morphology::morphology(b, img, MorphOp::Dilate, w_x, w_y, &cfg);
    let ed = morphology::morphology(b, &d, MorphOp::Erode, w_x, w_y, &cfg);
    assert!(c.same_pixels(&ed), "closing != erode∘dilate");

    // idempotence
    let oo = morphology::opening(b, &o, w_x, w_y, &cfg);
    assert!(oo.same_pixels(&o), "opening not idempotent");
    let cc = morphology::closing(b, &c, w_x, w_y, &cfg);
    assert!(cc.same_pixels(&c), "closing not idempotent");

    // sandwich: opening <= img <= closing
    for y in 0..img.height() {
        for x in 0..img.width() {
            assert!(o.get(y, x) <= img.get(y, x), "opening anti-extensive");
            assert!(c.get(y, x) >= img.get(y, x), "closing extensive");
        }
    }
}

fn random_u16(rng: &mut Rng, max_h: usize, max_w: usize) -> Image<u16> {
    let (h, w) = dims(rng, max_h, max_w);
    let seed = rng.next_u64();
    synth::noise_u16(h, w, seed)
}

#[test]
fn prop_duality_u8() {
    forall(301, 30, |rng, _| {
        let (h, w) = dims(rng, 32, 32);
        let img = synth::noise(h, w, rng.next_u64());
        check_duality(&img, odd_window(rng, 9), odd_window(rng, 9));
    });
}

#[test]
fn prop_duality_u16() {
    forall(302, 30, |rng, _| {
        let img = random_u16(rng, 32, 32);
        check_duality(&img, odd_window(rng, 9), odd_window(rng, 9));
    });
}

#[test]
fn prop_composition_and_idempotence_u8() {
    forall(303, 15, |rng, _| {
        let (h, w) = dims(rng, 28, 28);
        let img = synth::noise(h, w, rng.next_u64());
        check_composition_laws(&img, odd_window(rng, 7), odd_window(rng, 7));
    });
}

#[test]
fn prop_composition_and_idempotence_u16() {
    forall(304, 15, |rng, _| {
        let img = random_u16(rng, 28, 28);
        check_composition_laws(&img, odd_window(rng, 7), odd_window(rng, 7));
    });
}

#[test]
fn duality_survives_full_range_u16() {
    // extreme values: 0 and 65535 must round-trip through the inversion
    let mut img = Image::filled(16, 16, 65_535u16);
    img.set(3, 3, 0);
    img.set(12, 12, 40_000);
    check_duality(&img, 5, 3);
    check_composition_laws(&img, 3, 5);
}
