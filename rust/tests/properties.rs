//! Property-based tests over the morphology/transpose invariants.
//!
//! Uses the in-crate harness (`util::prop`) — random cases from a
//! deterministic seed, failing case seeds reported in the panic.

use std::sync::Arc;

use neon_morph::image::synth::{self, Rng};
use neon_morph::image::Image;
use neon_morph::morphology::{
    self, naive, Border, HybridThresholds, MorphConfig, MorphOp, Parallelism, PassMethod,
    Representation, VerticalStrategy,
};
use neon_morph::neon::Native;
use neon_morph::util::prop::{dims, forall, odd_window};

fn random_image(rng: &mut Rng, max_h: usize, max_w: usize) -> Image<u8> {
    let (h, w) = dims(rng, max_h, max_w);
    let seed = rng.next_u64();
    synth::noise(h, w, seed)
}

fn all_configs() -> Vec<MorphConfig> {
    let mut out = Vec::new();
    for method in [PassMethod::Linear, PassMethod::Vhgw, PassMethod::Hybrid] {
        for vertical in [VerticalStrategy::Transpose, VerticalStrategy::Direct] {
            for simd in [false, true] {
                out.push(MorphConfig {
                    method,
                    vertical,
                    simd,
                    border: Border::Identity,
                    thresholds: HybridThresholds::paper(),
                    parallelism: Parallelism::Sequential,
                    representation: Representation::Dense,
                });
            }
        }
    }
    out
}

#[test]
fn prop_every_config_matches_naive_2d() {
    forall(101, 40, |rng, _| {
        let img = random_image(rng, 40, 56);
        let w_x = odd_window(rng, 11);
        let w_y = odd_window(rng, 11);
        let op = if rng.below(2) == 0 { MorphOp::Erode } else { MorphOp::Dilate };
        let want = naive::morph2d_naive(&mut Native, &img, w_x, w_y, op);
        for cfg in all_configs() {
            let got = morphology::morphology(&mut Native, &img, op, w_x, w_y, &cfg);
            assert!(
                got.same_pixels(&want),
                "cfg {cfg:?} op {op:?} se {w_x}x{w_y} img {}x{} diff {:?}",
                img.height(),
                img.width(),
                got.first_diff(&want)
            );
        }
    });
}

#[test]
fn prop_erosion_below_dilation_above() {
    forall(102, 60, |rng, _| {
        let img = random_image(rng, 48, 48);
        let w_x = odd_window(rng, 9);
        let w_y = odd_window(rng, 9);
        let e = morphology::erode(&img, w_x, w_y);
        let d = morphology::dilate(&img, w_x, w_y);
        for y in 0..img.height() {
            for x in 0..img.width() {
                assert!(e.get(y, x) <= img.get(y, x));
                assert!(d.get(y, x) >= img.get(y, x));
            }
        }
    });
}

#[test]
fn prop_duality_erode_dilate() {
    forall(103, 60, |rng, _| {
        let img = random_image(rng, 40, 40);
        let w_x = odd_window(rng, 9);
        let w_y = odd_window(rng, 9);
        let inv = Image::from_fn(img.height(), img.width(), |y, x| 255 - img.get(y, x));
        let e = morphology::erode(&img, w_x, w_y);
        let d = morphology::dilate(&inv, w_x, w_y);
        for y in 0..img.height() {
            for x in 0..img.width() {
                assert_eq!(e.get(y, x), 255 - d.get(y, x), "at ({y},{x})");
            }
        }
    });
}

#[test]
fn prop_erosion_monotone_in_image() {
    // img1 <= img2 pointwise  =>  erode(img1) <= erode(img2)
    forall(104, 40, |rng, _| {
        let a = random_image(rng, 32, 32);
        let deltas = Image::from_fn(a.height(), a.width(), |_, _| rng.next_u8() % 40);
        let b = Image::from_fn(a.height(), a.width(), |y, x| {
            a.get(y, x).saturating_add(deltas.get(y, x))
        });
        let w = odd_window(rng, 9);
        let ea = morphology::erode(&a, w, w);
        let eb = morphology::erode(&b, w, w);
        for y in 0..a.height() {
            for x in 0..a.width() {
                assert!(ea.get(y, x) <= eb.get(y, x));
            }
        }
    });
}

#[test]
fn prop_erosion_decreasing_in_window() {
    // larger SE => smaller (or equal) erosion everywhere
    forall(105, 40, |rng, _| {
        let img = random_image(rng, 36, 36);
        let w1 = odd_window(rng, 7);
        let w2 = w1 + 2 * (1 + rng.below(3));
        let e1 = morphology::erode(&img, w1, w1);
        let e2 = morphology::erode(&img, w2, w2);
        for y in 0..img.height() {
            for x in 0..img.width() {
                assert!(e2.get(y, x) <= e1.get(y, x));
            }
        }
    });
}

#[test]
fn prop_opening_closing_idempotent_and_sandwich() {
    forall(106, 25, |rng, _| {
        let img = random_image(rng, 32, 32);
        let w = odd_window(rng, 7);
        let cfg = MorphConfig::default();
        let o = morphology::opening(&mut Native, &img, w, w, &cfg);
        let c = morphology::closing(&mut Native, &img, w, w, &cfg);
        let oo = morphology::opening(&mut Native, &o, w, w, &cfg);
        let cc = morphology::closing(&mut Native, &c, w, w, &cfg);
        assert!(oo.same_pixels(&o), "opening idempotence");
        assert!(cc.same_pixels(&c), "closing idempotence");
        for y in 0..img.height() {
            for x in 0..img.width() {
                assert!(o.get(y, x) <= img.get(y, x), "opening anti-extensive");
                assert!(c.get(y, x) >= img.get(y, x), "closing extensive");
            }
        }
    });
}

#[test]
fn prop_transpose_involution_and_tile_equivalence() {
    forall(107, 60, |rng, _| {
        let img = random_image(rng, 70, 70);
        let t = neon_morph::transpose::transpose_image(&mut Native, &img);
        assert_eq!(t.height(), img.width());
        assert_eq!(t.width(), img.height());
        let tt = neon_morph::transpose::transpose_image(&mut Native, &t);
        assert!(tt.same_pixels(&img), "involution");
        let ts = neon_morph::transpose::transpose_image_scalar(&mut Native, &img);
        assert!(t.same_pixels(&ts), "neon tiles == scalar");
    });
}

#[test]
fn prop_cols_pass_equals_transpose_sandwich() {
    // cols-pass(img) == transpose(rows-pass(transpose(img))) for the
    // linear method — the identity §5.2.1 relies on
    forall(108, 30, |rng, _| {
        let img = random_image(rng, 40, 40);
        let w = odd_window(rng, 11);
        let op = if rng.below(2) == 0 { MorphOp::Erode } else { MorphOp::Dilate };
        let direct = morphology::linear::cols_simd_linear(&mut Native, &img, w, op);
        let t = img.transposed();
        let rows = morphology::linear::rows_simd_linear(&mut Native, &t, w, op);
        let sandwich = rows.transposed();
        assert!(direct.same_pixels(&sandwich), "{:?}", direct.first_diff(&sandwich));
    });
}

#[test]
fn prop_gradient_zero_on_flat() {
    forall(109, 25, |rng, _| {
        let (h, w) = dims(rng, 24, 24);
        let flat = Image::filled(h, w, rng.next_u8());
        let wz = odd_window(rng, 7);
        let g = morphology::gradient(&mut Native, &flat, wz, wz, &MorphConfig::default());
        assert_eq!(g.min_max().map(|(_, mx)| mx), Some(0), "flat image has zero gradient");
    });
}

#[test]
fn prop_replicate_border_never_exceeds_identity_for_erosion() {
    forall(110, 25, |rng, _| {
        let img = random_image(rng, 28, 28);
        let w = odd_window(rng, 9);
        let mut cfg = MorphConfig::default();
        let ident = morphology::morphology(&mut Native, &img, MorphOp::Erode, w, w, &cfg);
        cfg.border = Border::Replicate;
        let repl = morphology::morphology(&mut Native, &img, MorphOp::Erode, w, w, &cfg);
        for y in 0..img.height() {
            for x in 0..img.width() {
                assert!(repl.get(y, x) <= ident.get(y, x));
            }
        }
    });
}

#[test]
fn prop_pgm_round_trip() {
    forall(111, 25, |rng, _| {
        let img = random_image(rng, 30, 30);
        let dir = std::env::temp_dir().join("neon_morph_prop_pgm");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("t{}.pgm", rng.next_u64()));
        neon_morph::image::write_pgm(&img, &path).unwrap();
        let back = neon_morph::image::read_pgm(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(back.same_pixels(&img));
    });
}

#[test]
fn prop_coordinator_results_equal_direct_calls() {
    let coord = neon_morph::coordinator::Coordinator::start_native(3).unwrap();
    forall(112, 20, |rng, _| {
        let img = Arc::new(random_image(rng, 40, 40));
        let w_x = odd_window(rng, 9);
        let w_y = odd_window(rng, 9);
        let op = ["erode", "dilate", "gradient"][rng.below(3)];
        let spec = neon_morph::morphology::FilterSpec::parse_op(op, w_x, w_y).unwrap();
        let resp = coord.filter_spec(spec, img.clone()).unwrap();
        let got = resp.result.unwrap().into_u8().unwrap();
        let cfg = MorphConfig::default();
        let want = match op {
            "erode" => morphology::erode(img.view(), w_x, w_y),
            "dilate" => morphology::dilate(img.view(), w_x, w_y),
            _ => morphology::gradient(&mut Native, img.view(), w_x, w_y, &cfg),
        };
        assert!(got.same_pixels(&want), "{op} {w_x}x{w_y}");
    });
    coord.shutdown();
}

#[test]
fn prop_instruction_mix_scales_linearly_with_pixels() {
    // the basis of the cost-model substitution: mixes are linear in
    // image size, so crossovers derived on probes transfer to the
    // paper's workload
    use neon_morph::neon::{Backend as _, Counting};
    forall(113, 10, |rng, _| {
        let w = odd_window(rng, 9).max(3);
        let img1 = synth::noise(32, 64, 1);
        let img2 = synth::noise(64, 64, 2); // 2x the rows
        let count = |img: &Image<u8>| {
            let mut c = Counting::new();
            let _ = morphology::linear::rows_simd_linear(&mut c, img, w, MorphOp::Erode);
            c.mix.simd_total() as f64
        };
        let r = count(&img2) / count(&img1);
        assert!((r - 2.0).abs() < 0.25, "expected ~2x ops for 2x rows, got {r}");
    });
}
