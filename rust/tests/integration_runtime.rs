//! Integration tests over the PJRT runtime: the python-AOT-lowered
//! artifacts must agree bit-for-bit with the native rust implementation.
//!
//! Requires `make artifacts`; every test skips cleanly (with a notice)
//! when the manifest is missing so `cargo test` works pre-build.

use neon_morph::image::synth;
use neon_morph::runtime::{Manifest, NativeEngine, XlaRuntime};

fn runtime_or_skip() -> Option<XlaRuntime> {
    match XlaRuntime::new("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: artifacts not built ({e:#})");
            None
        }
    }
}

#[test]
fn manifest_contains_expected_grid() {
    let Ok(m) = Manifest::load("artifacts") else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    // aot.py default grid: 2 shapes x (5 ops x 3 windows + transpose)
    assert!(m.len() >= 32, "expected >=32 artifacts, got {}", m.len());
    for op in ["erode", "dilate", "opening", "closing", "gradient"] {
        for (wx, wy) in [(3, 3), (7, 7), (15, 15)] {
            assert!(
                m.find(op, 256, 256, wx, wy).is_some(),
                "missing {op} 256x256 w{wx}x{wy}"
            );
            assert!(
                m.find(op, 600, 800, wx, wy).is_some(),
                "missing {op} 600x800 w{wx}x{wy}"
            );
        }
    }
    assert!(m.get("transpose_256x256").is_some());
    assert!(m.get("transpose_600x800").is_some());
}

#[test]
fn xla_artifacts_match_native_on_256() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut native = NativeEngine::default();
    let img = synth::noise(256, 256, 4242);
    let metas: Vec<_> = rt
        .manifest()
        .ops_for_shape(256, 256)
        .into_iter()
        .cloned()
        .collect();
    assert!(!metas.is_empty());
    for meta in metas {
        let got = rt.run_u8(&meta, &img).unwrap_or_else(|e| panic!("{}: {e:#}", meta.name));
        let want = native.run(&meta, &img).unwrap();
        assert!(
            got.same_pixels(&want),
            "{} disagrees with native: {:?}",
            meta.name,
            got.first_diff(&want)
        );
    }
}

#[test]
fn xla_paper_shape_artifact_matches_native() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut native = NativeEngine::default();
    let img = synth::paper_image(7);
    let meta = rt
        .manifest()
        .find("erode", 600, 800, 7, 7)
        .expect("600x800 erode w7x7 artifact")
        .clone();
    let got = rt.run_u8(&meta, &img).unwrap();
    let want = native.run(&meta, &img).unwrap();
    assert!(got.same_pixels(&want), "{:?}", got.first_diff(&want));
}

#[test]
fn xla_transpose_artifact() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let img = synth::noise(256, 256, 5);
    let meta = rt.manifest().get("transpose_256x256").unwrap().clone();
    let got = rt.run_u8(&meta, &img).unwrap();
    assert!(got.same_pixels(&img.transposed()));
}

#[test]
fn xla_rejects_wrong_shape() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let meta = rt.manifest().find("erode", 256, 256, 3, 3).unwrap().clone();
    let img = synth::noise(100, 100, 6);
    assert!(rt.run_u8(&meta, &img).is_err());
}

#[test]
fn strided_images_are_compacted_before_upload() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let meta = rt.manifest().find("dilate", 256, 256, 3, 3).unwrap().clone();
    let img = synth::noise(256, 256, 7);
    let strided = img.with_stride(320, 0xAB);
    let got = rt.run_u8(&meta, &strided).unwrap();
    let want = rt.run_u8(&meta, &img).unwrap();
    assert!(got.same_pixels(&want));
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let meta = rt.manifest().find("erode", 256, 256, 3, 3).unwrap().clone();
    let img = synth::noise(256, 256, 8);
    assert_eq!(rt.compiled_count(), 0);
    let _ = rt.run_u8(&meta, &img).unwrap();
    assert_eq!(rt.compiled_count(), 1);
    let t = std::time::Instant::now();
    for _ in 0..3 {
        let _ = rt.run_u8(&meta, &img).unwrap();
    }
    let warm = t.elapsed();
    assert_eq!(rt.compiled_count(), 1, "no recompilation");
    // warm executions must be far below compile time (~100ms each)
    assert!(warm.as_millis() < 3000, "warm runs too slow: {warm:?}");
}

#[test]
fn precompile_warms_all_256_artifacts() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = rt
        .precompile(|m| m.height == 256 && m.kind == "morphology")
        .unwrap();
    assert!(n >= 15, "expected >=15 morphology artifacts at 256, got {n}");
    assert_eq!(rt.compiled_count(), n);
}
