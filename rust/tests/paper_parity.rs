//! Paper-parity tests: the experiment-level claims of the paper, checked
//! against this reproduction's cost model (DESIGN.md per-experiment
//! index).  The slow full-resolution sweeps only run in release
//! (`cargo test --release`); debug builds run reduced versions.

use neon_morph::bench_harness::{e2e, fig3, fig4, table1};
use neon_morph::costmodel::CostModel;
use neon_morph::image::synth;
use neon_morph::morphology::hybrid::calibrate_thresholds;

/// T1 — Table 1: transpose times and speedups.
#[test]
fn t1_transpose_table() {
    let rows = table1::run(&CostModel::exynos5422());
    let r8 = &rows[0];
    let r16 = &rows[1];
    // paper: 114/20 ns (5.7x) and 565/47 ns (12x).  Model must land
    // within 2x absolute and ±35% on the ratio.
    for (r, s, v) in [
        (r8, r8.paper_scalar_ns, r8.model_scalar_ns),
        (r8, r8.paper_simd_ns, r8.model_simd_ns),
        (r16, r16.paper_scalar_ns, r16.model_scalar_ns),
        (r16, r16.paper_simd_ns, r16.model_simd_ns),
    ] {
        assert!(
            v > s / 2.0 && v < s * 2.0,
            "{}: model {v:.0} ns vs paper {s:.0} ns",
            r.case
        );
    }
    assert!((r8.model_ratio() / 5.7 - 1.0).abs() < 0.35, "8x8 ratio {}", r8.model_ratio());
    assert!((r16.model_ratio() / 12.0 - 1.0).abs() < 0.35, "16x16 ratio {}", r16.model_ratio());
    // SIMD wins on the host too (shape check on real silicon) — only
    // meaningful with optimizations on (debug never vectorizes the lanes)
    if !cfg!(debug_assertions) {
        assert!(r8.host_ratio() > 1.0, "host 8x8 SIMD should win: {}", r8.host_ratio());
        assert!(r16.host_ratio() > 1.0, "host 16x16 SIMD should win: {}", r16.host_ratio());
    }
}

/// F3 — Figure 3 shapes: speedups at w=3, vHGW SIMD gain, crossover.
#[test]
fn f3_horizontal_pass_shapes() {
    let model = CostModel::exynos5422();
    let windows: Vec<usize> = if cfg!(debug_assertions) {
        vec![3, 15, 61, 69, 75, 81, 91]
    } else {
        (1..=60).map(|k| 2 * k + 1).collect()
    };
    let s = fig3::run(&model, &windows, 1);
    let p3 = &s.points[0];
    assert_eq!(p3.window, 3);
    // paper: linear at w=3 is 14x over scalar vHGW (we accept >=8x)
    let lin3 = p3.model_ns[0] / p3.model_ns[2];
    assert!(lin3 >= 8.0, "linear w=3 speedup {lin3:.1} (paper 14x)");
    // paper: SIMD speeds vHGW >3x (we accept >=2.5x)
    let mid = s.points.iter().find(|p| p.window >= 15).unwrap();
    let vh = mid.model_ns[0] / mid.model_ns[1];
    assert!(vh >= 2.5, "vhgw simd speedup {vh:.1} (paper >3x)");
    // paper: crossover w_y0 = 69; ours within ±16
    assert!(
        (53..=85).contains(&s.crossover_model),
        "w_y0 = {} (paper 69)",
        s.crossover_model
    );
}

/// F4 — Figure 4 shapes: vertical pass, crossover below horizontal.
#[test]
fn f4_vertical_pass_shapes() {
    let model = CostModel::exynos5422();
    let windows: Vec<usize> = if cfg!(debug_assertions) {
        vec![3, 15, 51, 55, 59, 63, 67, 91]
    } else {
        (1..=60).map(|k| 2 * k + 1).collect()
    };
    let s = fig4::run(&model, &windows, 1);
    let p3 = &s.points[0];
    // paper: linear at w=3 is 11x over scalar vHGW (we accept >=5x)
    let lin3 = p3.model_ns[0] / p3.model_ns[2];
    assert!(lin3 >= 5.0, "linear w=3 speedup {lin3:.1} (paper 11x)");
    // paper: crossover w_x0 = 59; ours within ±14
    assert!(
        (45..=73).contains(&s.crossover_model),
        "w_x0 = {} (paper 59)",
        s.crossover_model
    );
}

/// §5.3 — both crossovers from the calibration API, and their ordering
/// ("passes work with memory asymmetrically" → w_x0 < w_y0).
#[test]
fn crossover_calibration_matches_paper() {
    if cfg!(debug_assertions) {
        eprintln!("SKIP in debug: full 800x600 sweep is release-only");
        return;
    }
    let model = CostModel::exynos5422();
    let probe = synth::paper_image(7);
    let t = calibrate_thresholds(&model, &probe, 121);
    assert!((53..=85).contains(&t.wy0), "w_y0 = {} (paper 69)", t.wy0);
    assert!((45..=73).contains(&t.wx0), "w_x0 = {} (paper 59)", t.wx0);
    assert!(t.wx0 < t.wy0, "asymmetry: w_x0 {} < w_y0 {}", t.wx0, t.wy0);
}

/// C1 — conclusion headline: final hybrid >=3x over vHGW-without-SIMD.
#[test]
fn c1_headline_speedup() {
    let model = CostModel::exynos5422();
    let results = e2e::run(&model, &[3, 7, 15, 31], 1);
    for r in &results {
        assert!(
            r.model_speedup() >= 3.0,
            "w={}: hybrid speedup {:.2} (paper >=3x)",
            r.w,
            r.model_speedup()
        );
    }
    // host shape: hybrid must also win on this machine's silicon —
    // release-only (debug builds don't vectorize the Native backend)
    if !cfg!(debug_assertions) {
        let host_wins = results.iter().filter(|r| r.host_speedup() > 1.0).count();
        assert!(
            host_wins >= results.len() - 1,
            "hybrid should beat the scalar baseline on the host almost everywhere"
        );
    }
}
