//! Property suite for fused multi-image super-passes.
//!
//! The contract under test ([`FusedPlan`]): a batch of `n` same-shape
//! images run as ONE banded execution — bands spanning image boundaries
//! over the fused `n·h`-row virtual image, per-image halo fences at
//! every seam — is **bit-identical**, image for image, to running the
//! per-image [`FilterPlan`] `n` times.  The sweep crosses op × resolved
//! method × border × depth × batch size (including the 1-row degenerate
//! where every fused row is its own image and every band cut lands on a
//! seam), and the engine-level fallback for mixed-shape batches.
//!
//! Band geometry itself (tiling, seam-aligned cuts) is pinned by the
//! unit tests in `morphology::parallel` and mirrored in
//! `python/tests/test_fused_geometry.py`.
//!
//! [`FusedPlan`]: neon_morph::morphology::FusedPlan
//! [`FilterPlan`]: neon_morph::morphology::FilterPlan

use neon_morph::image::{synth, Image, ImageView};
use neon_morph::morphology::{
    Border, FilterOp, FilterSpec, MorphConfig, MorphPixel, Parallelism, PassMethod,
    VerticalStrategy,
};
use neon_morph::runtime::NativeEngine;

/// Run `spec` fused at each batch size in `batches` (images cycled from
/// `imgs`) and compare every output against the per-image plan.
fn check_batches<P: MorphPixel>(spec: FilterSpec, imgs: &[Image<P>], batches: &[usize], label: &str) {
    let (h, w) = (imgs[0].height(), imgs[0].width());
    let mut fused = spec.plan_fused::<P>(h, w, 1).unwrap();
    let mut single = spec.plan::<P>(h, w).unwrap();
    for &n in batches {
        let batch: Vec<ImageView<'_, P>> = (0..n).map(|i| imgs[i % imgs.len()].view()).collect();
        let got = fused.run_batch_owned(&batch);
        assert_eq!(got.len(), n, "{label}: n={n}");
        for (i, (src, out)) in batch.iter().zip(&got).enumerate() {
            let want = single.run_owned(*src);
            assert!(
                out.same_pixels(&want),
                "{label}: batch {n}, image {i} diverges from the per-image plan"
            );
        }
    }
}

#[test]
fn fused_matches_per_image_across_ops_methods_borders() {
    let (h, w) = (17, 23);
    let imgs: Vec<Image<u8>> = (0..4).map(|i| synth::noise(h, w, 0xFA + i as u64)).collect();
    let ops = [
        FilterOp::Erode,
        FilterOp::Dilate,
        FilterOp::Open,
        FilterOp::Gradient,
        FilterOp::TopHat,
    ];
    let methods = [PassMethod::Hybrid, PassMethod::Linear, PassMethod::Vhgw];
    let borders = [Border::Identity, Border::Replicate];
    for op in ops {
        for method in methods {
            for border in borders {
                let cfg = MorphConfig {
                    method,
                    border,
                    parallelism: Parallelism::Fixed(3),
                    ..MorphConfig::default()
                };
                let spec = FilterSpec::new(op, 5, 3).with_config(cfg);
                check_batches(spec, &imgs, &[1, 2, 8], &format!("{op:?}/{method:?}/{border:?}"));
            }
        }
    }
}

#[test]
fn fused_matches_per_image_at_batch_64() {
    // the headline batch size, on the two shapes the smoke families use
    let imgs: Vec<Image<u8>> = (0..8).map(|i| synth::noise(15, 20, 0xB64 + i as u64)).collect();
    for op in [FilterOp::Erode, FilterOp::TopHat] {
        let spec = FilterSpec::new(op, 7, 7);
        check_batches(spec, &imgs, &[64], &format!("{op:?} batch64"));
    }
}

#[test]
fn fused_matches_per_image_u16() {
    let imgs: Vec<Image<u16>> = (0..3).map(|i| synth::noise_u16(14, 19, 0x16 + i as u64)).collect();
    for border in [Border::Identity, Border::Replicate] {
        let cfg = MorphConfig {
            border,
            parallelism: Parallelism::Fixed(4),
            ..MorphConfig::default()
        };
        for op in [FilterOp::Dilate, FilterOp::Gradient] {
            let spec = FilterSpec::new(op, 3, 5).with_config(cfg);
            check_batches(spec, &imgs, &[1, 2, 8], &format!("u16 {op:?}/{border:?}"));
        }
    }
}

#[test]
fn fused_transpose_sandwich_matches_per_image() {
    // forced transpose sandwich: the cols pass runs as ONE fused rows
    // pass over per-image transposed stacks
    let imgs: Vec<Image<u8>> = (0..3).map(|i| synth::noise(13, 21, 0x5A + i as u64)).collect();
    let cfg = MorphConfig {
        method: PassMethod::Linear,
        vertical: VerticalStrategy::Transpose,
        parallelism: Parallelism::Fixed(3),
        ..MorphConfig::default()
    };
    let spec = FilterSpec::new(FilterOp::Erode, 9, 5).with_config(cfg);
    check_batches(spec, &imgs, &[1, 2, 8], "transpose sandwich");
}

#[test]
fn fused_one_row_images_respect_seam_fences() {
    // degenerate h=1: every fused row is its own image, every band cut
    // is a seam — a cols window must never reduce across neighbors
    let imgs: Vec<Image<u8>> = (0..6).map(|i| synth::noise(1, 31, 0x1A + i as u64)).collect();
    for border in [Border::Identity, Border::Replicate] {
        let cfg = MorphConfig {
            border,
            parallelism: Parallelism::Fixed(4),
            ..MorphConfig::default()
        };
        let spec = FilterSpec::new(FilterOp::Dilate, 5, 1).with_config(cfg);
        check_batches(spec, &imgs, &[1, 2, 8, 64], &format!("1-row/{border:?}"));
    }
}

#[test]
fn fused_plan_rejects_roi_and_transpose_specs() {
    let roi = FilterSpec::new(FilterOp::Erode, 3, 3)
        .with_roi(neon_morph::morphology::Roi::new(2, 2, 4, 4));
    assert!(roi.plan_fused::<u8>(16, 16, 2).is_err());
    let t = FilterSpec::new(FilterOp::Transpose, 0, 0);
    assert!(t.plan_fused::<u8>(16, 16, 2).is_err());
}

#[test]
fn engine_serves_mixed_shape_batches_per_image() {
    // a BatchKey bucket never mixes shapes in the coordinator, but the
    // engine API can be handed one — it must degrade, not fuse
    let mut e = NativeEngine::default();
    let spec = FilterSpec::new(FilterOp::Erode, 5, 5);
    let a = synth::noise(20, 24, 1);
    let b = synth::noise(24, 20, 2);
    let c = synth::noise(20, 24, 3);
    let (outs, fused) = e.run_spec_batch(&spec, &[&a, &b, &c]).unwrap();
    assert!(!fused, "mixed shapes must not fuse");
    let mut plan_a = spec.plan::<u8>(20, 24).unwrap();
    let mut plan_b = spec.plan::<u8>(24, 20).unwrap();
    assert!(outs[0].same_pixels(&plan_a.run_owned(&a)));
    assert!(outs[1].same_pixels(&plan_b.run_owned(&b)));
    assert!(outs[2].same_pixels(&plan_a.run_owned(&c)));
    // …and a uniform batch through the same engine does fuse, matching
    let (outs2, fused2) = e.run_spec_batch(&spec, &[&a, &c]).unwrap();
    assert!(fused2);
    assert!(outs2[0].same_pixels(&outs[0]));
    assert!(outs2[1].same_pixels(&outs[2]));
}

#[test]
fn fused_arena_grows_once_and_serves_smaller_batches() {
    // capacity is a high-water mark: after reserve(8), batches of any
    // size ≤ 8 reuse the arena; a later larger batch grows it
    let imgs: Vec<Image<u8>> = (0..8).map(|i| synth::noise(11, 13, 0xCA + i as u64)).collect();
    let spec = FilterSpec::new(FilterOp::TopHat, 3, 3);
    let mut fused = spec.plan_fused::<u8>(11, 13, 8).unwrap();
    assert_eq!(fused.capacity(), 8);
    let bytes_at_8 = fused.scratch_bytes();
    let mut single = spec.plan::<u8>(11, 13).unwrap();
    for n in [1usize, 3, 8] {
        let batch: Vec<ImageView<'_, u8>> = imgs[..n].iter().map(|im| im.view()).collect();
        for (src, out) in batch.iter().zip(fused.run_batch_owned(&batch)) {
            assert!(out.same_pixels(&single.run_owned(*src)));
        }
        assert_eq!(fused.capacity(), 8, "smaller batches must not shrink the arena");
        assert_eq!(fused.scratch_bytes(), bytes_at_8);
    }
    let batch: Vec<ImageView<'_, u8>> = (0..12).map(|i| imgs[i % 8].view()).collect();
    let _ = fused.run_batch_owned(&batch);
    assert_eq!(fused.capacity(), 12);
}
