//! Differential / property test harness for the 16-bit morphology path
//! (oracle-testing discipline à la Ehrensperger et al., arXiv:1504.01052).
//!
//! Every generic pass — {linear, vhgw} × {scalar, SIMD} × {horizontal,
//! vertical} — and the full separable composition under every
//! `MorphConfig` × both borders are checked against the naive 2-D
//! oracle on random u16 images from a seeded PRNG (no external deps),
//! including stride-padded inputs and degenerate (1×N, N×1, 1×1)
//! shapes.

use neon_morph::image::synth::{self, Rng};
use neon_morph::image::Image;
use neon_morph::morphology::{
    self, linear, naive, vhgw, Border, HybridThresholds, MorphConfig, MorphOp, Parallelism,
    PassMethod, Representation, VerticalStrategy,
};
use neon_morph::neon::Native;
use neon_morph::util::prop::{dims, forall, odd_window};

fn random_u16(rng: &mut Rng, max_h: usize, max_w: usize) -> Image<u16> {
    let (h, w) = dims(rng, max_h, max_w);
    let seed = rng.next_u64();
    synth::noise_u16(h, w, seed)
}

fn ops() -> [MorphOp; 2] {
    [MorphOp::Erode, MorphOp::Dilate]
}

fn all_configs() -> Vec<MorphConfig> {
    let mut out = Vec::new();
    for method in [PassMethod::Linear, PassMethod::Vhgw, PassMethod::Hybrid] {
        for vertical in [VerticalStrategy::Transpose, VerticalStrategy::Direct] {
            for simd in [false, true] {
                for border in [Border::Identity, Border::Replicate] {
                    out.push(MorphConfig {
                        method,
                        vertical,
                        simd,
                        border,
                        thresholds: HybridThresholds::paper(),
                        parallelism: Parallelism::Sequential,
                        representation: Representation::Dense,
                    });
                }
            }
        }
    }
    out
}

/// Replicate-border oracle: replicate-pad, identity-border naive, crop.
fn naive_replicate(img: &Image<u16>, w_x: usize, w_y: usize, op: MorphOp) -> Image<u16> {
    let (wing_x, wing_y) = (w_x / 2, w_y / 2);
    let (h, w) = (img.height(), img.width());
    let padded = Image::from_fn(h + 2 * wing_y, w + 2 * wing_x, |y, x| {
        let sy = y.saturating_sub(wing_y).min(h - 1);
        let sx = x.saturating_sub(wing_x).min(w - 1);
        img.get(sy, sx)
    });
    let full = naive::morph2d_naive(&mut Native, &padded, w_x, w_y, op);
    Image::from_fn(h, w, |y, x| full.get(y + wing_y, x + wing_x))
}

#[test]
fn prop_u16_individual_passes_match_oracle() {
    // linear/vhgw × scalar/simd × rows/cols, identity borders
    forall(201, 30, |rng, _| {
        let img = random_u16(rng, 36, 44);
        let window = odd_window(rng, 11);
        for op in ops() {
            let want_rows = naive::rows_naive(&mut Native, &img, window, op);
            let want_cols = naive::cols_naive(&mut Native, &img, window, op);

            let cases: [(&str, Image<u16>, &Image<u16>); 6] = [
                (
                    "rows linear simd",
                    linear::rows_simd_linear(&mut Native, &img, window, op),
                    &want_rows,
                ),
                (
                    "rows linear scalar",
                    linear::rows_scalar_linear(&mut Native, &img, window, op),
                    &want_rows,
                ),
                (
                    "rows vhgw simd",
                    vhgw::rows_simd_vhgw(&mut Native, &img, window, op),
                    &want_rows,
                ),
                (
                    "rows vhgw scalar",
                    vhgw::rows_scalar_vhgw(&mut Native, &img, window, op),
                    &want_rows,
                ),
                (
                    "cols linear simd",
                    linear::cols_simd_linear(&mut Native, &img, window, op),
                    &want_cols,
                ),
                (
                    "cols vhgw scalar",
                    vhgw::cols_scalar_vhgw(&mut Native, &img, window, op),
                    &want_cols,
                ),
            ];
            for (name, got, want) in &cases {
                assert!(
                    got.same_pixels(want),
                    "{name} {op:?} w={window} img {}x{}: {:?}",
                    img.height(),
                    img.width(),
                    got.first_diff(want)
                );
            }
        }
    });
}

#[test]
fn prop_u16_every_config_and_border_matches_oracle() {
    forall(202, 25, |rng, _| {
        let img = random_u16(rng, 30, 34);
        let w_x = odd_window(rng, 9);
        let w_y = odd_window(rng, 9);
        for op in ops() {
            let want_ident = naive::morph2d_naive(&mut Native, &img, w_x, w_y, op);
            let want_repl = naive_replicate(&img, w_x, w_y, op);
            for cfg in all_configs() {
                let got = morphology::morphology(&mut Native, &img, op, w_x, w_y, &cfg);
                let want = match cfg.border {
                    Border::Identity => &want_ident,
                    Border::Replicate => &want_repl,
                };
                assert!(
                    got.same_pixels(want),
                    "cfg {cfg:?} op {op:?} se {w_x}x{w_y} img {}x{} diff {:?}",
                    img.height(),
                    img.width(),
                    got.first_diff(want)
                );
            }
        }
    });
}

#[test]
fn prop_u16_stride_padded_inputs_match_compact() {
    // passes read rows through Image::row (padding-agnostic); a padded
    // clone must produce identical pixels, with poison in the padding
    forall(203, 20, |rng, _| {
        let img = random_u16(rng, 24, 28);
        let extra = 1 + rng.below(19);
        let padded = img.with_stride(img.width() + extra, 0xABCD);
        let w_x = odd_window(rng, 7);
        let w_y = odd_window(rng, 7);
        for op in ops() {
            for cfg in [
                MorphConfig::default(),
                MorphConfig {
                    method: PassMethod::Vhgw,
                    vertical: VerticalStrategy::Transpose,
                    simd: true,
                    border: Border::Identity,
                    thresholds: HybridThresholds::paper(),
                    parallelism: Parallelism::Sequential,
                    representation: Representation::Dense,
                },
            ] {
                let a = morphology::morphology(&mut Native, &img, op, w_x, w_y, &cfg);
                let b = morphology::morphology(&mut Native, &padded, op, w_x, w_y, &cfg);
                assert!(
                    a.same_pixels(&b),
                    "strided input changed the result: {op:?} {w_x}x{w_y} {:?}",
                    a.first_diff(&b)
                );
            }
        }
    });
}

#[test]
fn degenerate_shapes_all_passes() {
    // 1×N, N×1 and 1×1 at both depths' worth of windows
    for &(h, w) in &[(1usize, 1usize), (1, 17), (17, 1), (1, 40), (40, 1), (2, 2)] {
        let img = synth::noise_u16(h, w, (h * 131 + w) as u64);
        for &window in &[1, 3, 7] {
            for op in ops() {
                let want_r = naive::rows_naive(&mut Native, &img, window, op);
                let want_c = naive::cols_naive(&mut Native, &img, window, op);
                assert!(
                    linear::rows_simd_linear(&mut Native, &img, window, op).same_pixels(&want_r),
                    "rows linear {h}x{w} w={window}"
                );
                assert!(
                    vhgw::rows_simd_vhgw(&mut Native, &img, window, op).same_pixels(&want_r),
                    "rows vhgw {h}x{w} w={window}"
                );
                assert!(
                    linear::cols_simd_linear(&mut Native, &img, window, op).same_pixels(&want_c),
                    "cols linear {h}x{w} w={window}"
                );
                assert!(
                    vhgw::cols_scalar_vhgw(&mut Native, &img, window, op).same_pixels(&want_c),
                    "cols vhgw {h}x{w} w={window}"
                );
                for cfg in all_configs() {
                    let got = morphology::morphology(&mut Native, &img, op, window, window, &cfg);
                    assert_eq!((got.height(), got.width()), (h, w), "{cfg:?}");
                }
            }
        }
    }
}

#[test]
fn u16_values_above_u8_range_survive() {
    // a plateau at 40_000 with a pit at 30_000: u8 arithmetic would
    // truncate both; the filtered extrema must be exact u16 values
    let mut img = Image::filled(20, 20, 40_000u16);
    img.set(10, 10, 30_000);
    let e = morphology::erode(&img, 5, 5);
    let d = morphology::dilate(&img, 5, 5);
    assert_eq!(e.get(10, 10), 30_000);
    assert_eq!(e.get(10, 12), 30_000); // window reaches the pit
    assert_eq!(e.get(0, 0), 40_000);
    assert_eq!(d.get(10, 10), 40_000);
    assert_eq!(d.min_max(), Some((40_000, 40_000)));
}

#[test]
fn prop_u16_separability_matches_2d() {
    // rows∘cols == 2-D window, the §5 separability claim at 16-bit
    forall(204, 25, |rng, _| {
        let img = random_u16(rng, 28, 28);
        let w_x = odd_window(rng, 9);
        let w_y = odd_window(rng, 9);
        for op in ops() {
            let two_d = naive::morph2d_naive(&mut Native, &img, w_x, w_y, op);
            let rows = naive::rows_naive(&mut Native, &img, w_y, op);
            let sep = naive::cols_naive(&mut Native, &rows, w_x, op);
            assert!(sep.same_pixels(&two_d));
        }
    });
}
