//! Allocation-count proof that banded passes are zero-copy.
//!
//! The PR-2 executor staged every band: a haloed input slab copied in,
//! the sequential kernel's owned output allocated, and core rows
//! stitched out — ≥ 2 extra image-sized heap allocations per banded
//! pass.  The view-based executor borrows haloed [`ImageView`]s and
//! writes disjoint `ImageViewMut` bands in place, so a banded linear
//! pass allocates exactly what the sequential pass does (the
//! destination image) plus small per-job bookkeeping (job boxes, the
//! band plan, the scope latch, the cols pass's row-sized scratch
//! buffer).
//!
//! The test measures heap bytes allocated during the calls with a
//! counting global allocator and pins the banded-minus-sequential
//! overhead to a small constant — one hidden image copy (64 KiB here)
//! would blow the budget by an order of magnitude.
//!
//! [`ImageView`]: neon_morph::image::ImageView

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use neon_morph::image::synth;
use neon_morph::morphology::parallel::{pass_cols_banded, pass_rows_banded, BandPool};
use neon_morph::morphology::{HybridThresholds, MorphOp, PassMethod, VerticalStrategy};
use neon_morph::neon::Native;

struct CountingAlloc;

static ENABLED: AtomicBool = AtomicBool::new(false);
static ALLOCATED: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOCATED.fetch_add(layout.size() as u64, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOCATED.fetch_add(new_size as u64, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Heap bytes allocated (on any thread) while running `f`.
fn allocated_during<R>(f: impl FnOnce() -> R) -> (u64, R) {
    ALLOCATED.store(0, Ordering::SeqCst);
    ENABLED.store(true, Ordering::SeqCst);
    let out = f();
    ENABLED.store(false, Ordering::SeqCst);
    (ALLOCATED.load(Ordering::SeqCst), out)
}

// Single #[test] so no sibling test's allocations pollute the counters
// (the test harness runs tests in one process, possibly concurrently).
#[test]
fn banded_passes_allocate_no_staging_copies() {
    const H: usize = 128;
    const W: usize = 512; // dst = 64 KiB at u8
    const BANDS: usize = 4;
    let img = synth::noise(H, W, 0xA110C);
    let th = HybridThresholds::paper();
    // dedicated pool, created (threads spawned, channel set up) before
    // measurement starts; one warm-up banded call settles lazy state
    let pool = BandPool::new(BANDS);
    let warm = pass_rows_banded(
        &pool,
        &img,
        9,
        MorphOp::Erode,
        PassMethod::Linear,
        true,
        th,
        BANDS,
    );

    // sequential baseline: allocates the destination (+ tiny locals)
    let (seq_bytes, seq_out) = allocated_during(|| {
        neon_morph::morphology::separable::pass_rows(
            &mut Native,
            &img,
            9,
            MorphOp::Erode,
            PassMethod::Linear,
            true,
            th,
        )
    });

    // banded rows pass: same dst, plus per-job bookkeeping only
    let (rows_bytes, rows_out) = allocated_during(|| {
        pass_rows_banded(
            &pool,
            &img,
            9,
            MorphOp::Erode,
            PassMethod::Linear,
            true,
            th,
            BANDS,
        )
    });
    assert!(rows_out.same_pixels(&seq_out));
    assert!(rows_out.same_pixels(&warm));

    // banded direct cols pass: dst + the kernel's own row-sized scratch
    // buffer per band
    let (cols_bytes, _) = allocated_during(|| {
        pass_cols_banded(
            &pool,
            &img,
            9,
            MorphOp::Erode,
            PassMethod::Linear,
            true,
            VerticalStrategy::Direct,
            th,
            BANDS,
        )
    });

    let dst_bytes = (H * W) as u64;
    assert!(
        seq_bytes >= dst_bytes,
        "sequential pass must at least allocate dst: {seq_bytes} < {dst_bytes}"
    );
    // Budget: the old staging executor allocated ≥ 2 × (dst + halos)
    // beyond dst (slab in + kernel output per band), i.e. ≥ 128 KiB of
    // staging on this shape.  Allow 16 KiB for job boxes / plan /
    // latch / channel nodes — a single hidden image copy (64 KiB)
    // fails loudly.
    let slack = 16 * 1024;
    assert!(
        rows_bytes <= seq_bytes + slack,
        "banded rows pass allocated {rows_bytes} B vs sequential {seq_bytes} B — \
         staging copies are back?"
    );
    // cols: per-band scratch row (W + window - 1 + LANES bytes each)
    let scratch = (BANDS * (W + 64)) as u64;
    assert!(
        cols_bytes <= dst_bytes + scratch + slack,
        "banded cols pass allocated {cols_bytes} B (budget {})",
        dst_bytes + scratch + slack
    );
}
