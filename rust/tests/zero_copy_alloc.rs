//! Allocation-count proof that banded passes are zero-copy.
//!
//! The PR-2 executor staged every band: a haloed input slab copied in,
//! the sequential kernel's owned output allocated, and core rows
//! stitched out — ≥ 2 extra image-sized heap allocations per banded
//! pass.  The view-based executor borrows haloed [`ImageView`]s and
//! writes disjoint `ImageViewMut` bands in place, so a banded linear
//! pass allocates exactly what the sequential pass does (the
//! destination image) plus small per-job bookkeeping (job boxes, the
//! band plan, the scope latch, the cols pass's row-sized scratch
//! buffer).
//!
//! The tests measure heap bytes allocated during the calls with a
//! counting global allocator and pin the overheads to small constants —
//! one hidden image copy (64 KiB here) would blow every budget by an
//! order of magnitude.  Three properties are pinned:
//!
//! 1. banded passes are zero-copy (no staging slab / stitch),
//! 2. a reused [`FilterPlan`]'s Nth run allocates **zero per-call heap
//!    bytes** when it dispatches sequentially — since the
//!    plan-owned-scratch redesign this includes forced-vHGW specs,
//!    whose image-sized `R` buffer (the algorithm's "2× extra memory")
//!    lives in the arena's per-band slots, AND the cols linear kernel's
//!    row-sized staging buffer (the last per-call residual, now an
//!    arena slot too); banded runs add only fork bookkeeping (job
//!    boxes, the band plan, the scope latch), and
//! 3. the coordinator's typed `BatchKey` is built and compared without
//!    any heap allocation (the pre-plan era formatted a `String` per
//!    submit and per pull).
//!
//! All measuring tests serialize on one lock so a sibling test's
//! allocations never pollute the counters (the harness runs tests
//! concurrently in one process).
//!
//! [`ImageView`]: neon_morph::image::ImageView
//! [`FilterPlan`]: neon_morph::morphology::FilterPlan

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use neon_morph::image::{synth, Image};
use neon_morph::morphology::parallel::{pass_cols_banded, pass_rows_banded, BandPool};
use neon_morph::morphology::{
    FilterOp, FilterSpec, HybridThresholds, MorphConfig, MorphOp, Parallelism, PassMethod,
    VerticalStrategy,
};
use neon_morph::neon::Native;

struct CountingAlloc;

static ENABLED: AtomicBool = AtomicBool::new(false);
static ALLOCATED: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOCATED.fetch_add(layout.size() as u64, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOCATED.fetch_add(new_size as u64, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Serializes the measuring sections: every test in this binary takes
/// this lock for its whole body, so another test's allocations can
/// never land inside a measurement window.
static MEASURE: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    MEASURE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Heap bytes allocated (on any thread) while running `f`.
fn allocated_during<R>(f: impl FnOnce() -> R) -> (u64, R) {
    ALLOCATED.store(0, Ordering::SeqCst);
    ENABLED.store(true, Ordering::SeqCst);
    let out = f();
    ENABLED.store(false, Ordering::SeqCst);
    (ALLOCATED.load(Ordering::SeqCst), out)
}

#[test]
fn banded_passes_allocate_no_staging_copies() {
    let _guard = lock();
    const H: usize = 128;
    const W: usize = 512; // dst = 64 KiB at u8
    const BANDS: usize = 4;
    let img = synth::noise(H, W, 0xA110C);
    let th = HybridThresholds::paper();
    // dedicated pool, created (threads spawned, channel set up) before
    // measurement starts; one warm-up banded call settles lazy state
    let pool = BandPool::new(BANDS);
    let warm = pass_rows_banded(
        &pool,
        &img,
        9,
        MorphOp::Erode,
        PassMethod::Linear,
        true,
        th,
        BANDS,
    );

    // sequential baseline: allocates the destination (+ tiny locals)
    let (seq_bytes, seq_out) = allocated_during(|| {
        neon_morph::morphology::separable::pass_rows(
            &mut Native,
            &img,
            9,
            MorphOp::Erode,
            PassMethod::Linear,
            true,
            th,
        )
    });

    // banded rows pass: same dst, plus per-job bookkeeping only
    let (rows_bytes, rows_out) = allocated_during(|| {
        pass_rows_banded(
            &pool,
            &img,
            9,
            MorphOp::Erode,
            PassMethod::Linear,
            true,
            th,
            BANDS,
        )
    });
    assert!(rows_out.same_pixels(&seq_out));
    assert!(rows_out.same_pixels(&warm));

    // banded direct cols pass: dst + the kernel's own row-sized scratch
    // buffer per band
    let (cols_bytes, _) = allocated_during(|| {
        pass_cols_banded(
            &pool,
            &img,
            9,
            MorphOp::Erode,
            PassMethod::Linear,
            true,
            VerticalStrategy::Direct,
            th,
            BANDS,
        )
    });

    let dst_bytes = (H * W) as u64;
    assert!(
        seq_bytes >= dst_bytes,
        "sequential pass must at least allocate dst: {seq_bytes} < {dst_bytes}"
    );
    // Budget: the old staging executor allocated ≥ 2 × (dst + halos)
    // beyond dst (slab in + kernel output per band), i.e. ≥ 128 KiB of
    // staging on this shape.  Allow 16 KiB for job boxes / plan /
    // latch / channel nodes — a single hidden image copy (64 KiB)
    // fails loudly.
    let slack = 16 * 1024;
    assert!(
        rows_bytes <= seq_bytes + slack,
        "banded rows pass allocated {rows_bytes} B vs sequential {seq_bytes} B — \
         staging copies are back?"
    );
    // cols: per-band scratch row (W + window - 1 + LANES bytes each)
    let scratch = (BANDS * (W + 64)) as u64;
    assert!(
        cols_bytes <= dst_bytes + scratch + slack,
        "banded cols pass allocated {cols_bytes} B (budget {})",
        dst_bytes + scratch + slack
    );
}

#[test]
fn reused_plan_runs_allocate_no_intermediate_images() {
    let _guard = lock();
    const H: usize = 128;
    const W: usize = 512; // every intermediate image would be 64 KiB at u8
    let img = synth::noise(H, W, 0x9147);
    // sequential dispatch reuses the arena for EVERY buffer — the vHGW
    // `R` rows and the cols-linear staging row included — so run N > 1
    // is pinned to literally zero heap bytes (a single escaped staging
    // row, 536 B here, fails); banded dispatch still forks per call
    // (job boxes, band plan, split chunks, scope latch, channel nodes),
    // budgeted an order of magnitude under an escaped intermediate
    // image or per-call vHGW R buffer (≥ 64 KiB on this shape)
    let seq_slack = 0u64;
    let banded_slack = 8 * 1024u64;

    // (a) hybrid-small spec (rows+cols resolve to Linear, direct
    //     vertical): the plan's after_rows arena absorbs the rows→cols
    //     intermediate
    // (b) forced transpose sandwich: both w×h transpose buffers live in
    //     the arena too
    // (c) a derived chain (tophat = 3 steps, 3 slots + sub)
    // (d) forced vHGW, sequential: the image-sized R buffer (~(H+2w)·W
    //     B here, an order of magnitude over the budget) must come from
    //     the arena's vHGW slots — the closed ROADMAP residual
    // (e) forced vHGW, banded: one R slot per band, all arena-owned
    let sandwich_cfg = MorphConfig {
        method: PassMethod::Linear,
        vertical: VerticalStrategy::Transpose,
        parallelism: Parallelism::Sequential,
        ..MorphConfig::default()
    };
    let seq_cfg = MorphConfig {
        parallelism: Parallelism::Sequential,
        ..MorphConfig::default()
    };
    let vhgw_cfg = MorphConfig {
        method: PassMethod::Vhgw,
        parallelism: Parallelism::Sequential,
        ..MorphConfig::default()
    };
    let vhgw_banded_cfg = MorphConfig {
        method: PassMethod::Vhgw,
        parallelism: Parallelism::Fixed(4),
        ..MorphConfig::default()
    };
    let specs = [
        (FilterSpec::new(FilterOp::Erode, 9, 9).with_config(seq_cfg), seq_slack),
        (FilterSpec::new(FilterOp::Dilate, 9, 9).with_config(sandwich_cfg), seq_slack),
        (FilterSpec::new(FilterOp::TopHat, 9, 9).with_config(seq_cfg), seq_slack),
        (FilterSpec::new(FilterOp::Erode, 9, 9).with_config(vhgw_cfg), seq_slack),
        (
            FilterSpec::new(FilterOp::Erode, 9, 9).with_config(vhgw_banded_cfg),
            banded_slack,
        ),
    ];
    for (spec, slack) in specs {
        let mut plan = spec.plan::<u8>(H, W).unwrap();
        let mut dst = Image::<u8>::zeros(H, W);
        // first run may settle lazy state (incl. growing the arena's
        // vHGW R slots to their high-water mark); the claim is about
        // run N > 1
        plan.run(&img, dst.view_mut());
        let (bytes, ()) = allocated_during(|| plan.run(&img, dst.view_mut()));
        assert!(
            bytes <= slack,
            "{spec:?}: reused plan run allocated {bytes} B (budget {slack}) — \
             an intermediate image escaped the scratch arena?"
        );
        // and the result is still right
        let want = neon_morph::morphology::parallel::filter_native(
            &img,
            MorphOp::Erode,
            9,
            9,
            &seq_cfg,
        );
        if spec.single_op() == Some(FilterOp::Erode) {
            assert!(dst.same_pixels(&want));
        }
    }
}

#[test]
fn typed_batch_keys_allocate_nothing() {
    let _guard = lock();
    use neon_morph::coordinator::request::BatchKey;
    use neon_morph::morphology::Roi;
    let spec = FilterSpec::new(FilterOp::TopHat, 5, 3)
        .then(FilterOp::Dilate)
        .with_roi(Roi::new(2, 3, 40, 50));
    // warm up (nothing to warm, but symmetric with the others)
    let k0 = BatchKey::of(&spec, neon_morph::coordinator::request::PixelDepth::U8, 100, 200);
    let (bytes, ()) = allocated_during(|| {
        for i in 0..1000usize {
            let k = BatchKey::of(
                &spec,
                neon_morph::coordinator::request::PixelDepth::U8,
                100 + (i % 3),
                200,
            );
            std::hint::black_box(&k);
            // affinity comparison — the per-pull hot path
            std::hint::black_box(k == k0);
        }
    });
    assert_eq!(
        bytes, 0,
        "building/comparing 1000 typed batch keys must not allocate \
         (the stringly keys allocated per call)"
    );
}
