//! Differential suite for the scenario engines: the RLE interval engine
//! against the dense oracle on randomized masks, and geodesic
//! reconstruction against a naive sweep oracle — plus the end-to-end
//! pipeline path ([`Coordinator::submit_with_marker`]) against the
//! library call.
//!
//! The RLE contract under test: for every 0/255 image and every rect-SE
//! chain of erode/dilate steps, the interval engine is **bit-identical**
//! to the dense separable path (whole image, either border — replicate
//! and identity agree on whole-image rect-SE min/max).  The
//! reconstruction contract: the banded plan sweeps reach the same
//! fixpoint in the same number of sweeps as a pixel-by-pixel oracle.

use std::sync::Arc;

use neon_morph::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use neon_morph::image::synth::{self, Rng};
use neon_morph::image::Image;
use neon_morph::morphology::{
    reconstruct_by_dilation, Border, FilterOp, FilterSpec, MorphConfig, Parallelism,
    Representation, RleImage,
};
use neon_morph::util::prop::{dims, forall, odd_window};

fn cfg_with(repr: Representation, border: Border) -> MorphConfig {
    MorphConfig {
        representation: repr,
        border,
        parallelism: Parallelism::Sequential,
        ..MorphConfig::default()
    }
}

/// Bernoulli 0/255 mask at `fg_percent`% foreground.
fn random_mask(rng: &mut Rng, h: usize, w: usize, fg_percent: usize) -> Image<u8> {
    Image::from_fn(h, w, |_, _| if rng.below(100) < fg_percent { 255 } else { 0 })
}

#[test]
fn rle_representation_matches_dense_on_randomized_masks() {
    // densities from empty through solid, including the 1% regime where
    // rows are mostly empty and runs are mostly single pixels
    let densities = [0usize, 1, 5, 20, 50, 80, 100];
    forall(0xA11CE, 28, |rng, i| {
        let (h, w) = dims(rng, 36, 44);
        let mask = random_mask(rng, h, w, densities[i % densities.len()]);
        let wx = odd_window(rng, 9);
        let wy = odd_window(rng, 9);
        for op in [FilterOp::Erode, FilterOp::Dilate, FilterOp::Open, FilterOp::Close] {
            for border in [Border::Identity, Border::Replicate] {
                let spec = FilterSpec::new(op, wx, wy);
                let dense = spec
                    .with_config(cfg_with(Representation::Dense, border))
                    .run_once::<u8>(&mask)
                    .unwrap();
                for repr in [Representation::Rle, Representation::Auto] {
                    let got = spec
                        .with_config(cfg_with(repr, border))
                        .run_once::<u8>(&mask)
                        .unwrap();
                    assert!(
                        got.same_pixels(&dense),
                        "{op:?} {wx}x{wy} {border:?} {repr:?} on {h}x{w}: {:?}",
                        got.first_diff(&dense)
                    );
                }
            }
        }
    });
}

#[test]
fn rle_handles_strided_sources_and_gray_fallback() {
    let mask = random_mask(&mut Rng::new(0x57E), 24, 30, 10);
    let padded = mask.with_stride(48, 0xEE);
    let spec = FilterSpec::new(FilterOp::Open, 5, 3);
    let want = spec
        .with_config(cfg_with(Representation::Dense, Border::Identity))
        .run_once::<u8>(&mask)
        .unwrap();
    let got = spec
        .with_config(cfg_with(Representation::Rle, Border::Identity))
        .run_once::<u8>(&padded)
        .unwrap();
    assert!(got.same_pixels(&want), "strided RLE source");

    // a gray source is not representable as intervals: the Rle knob
    // must fall back to the dense engine, not corrupt pixels
    let gray = synth::noise(20, 26, 9);
    let want = spec
        .with_config(cfg_with(Representation::Dense, Border::Identity))
        .run_once::<u8>(&gray)
        .unwrap();
    let got = spec
        .with_config(cfg_with(Representation::Rle, Border::Identity))
        .run_once::<u8>(&gray)
        .unwrap();
    assert!(got.same_pixels(&want), "gray fallback");
}

#[test]
fn direct_interval_ops_match_dense_and_round_trip() {
    forall(0xB0B5, 24, |rng, _| {
        let (h, w) = dims(rng, 30, 36);
        let mask = random_mask(rng, h, w, rng.below(101));
        let rle = RleImage::from_view(&mask).expect("binary mask converts");
        // lossless round trip, exact run bookkeeping
        assert!(rle.to_image().same_pixels(&mask));
        let fg = (0..h).flat_map(|y| mask.row(y).iter()).filter(|&&v| v > 0).count();
        assert_eq!(rle.fg_pixels(), fg);
        // interval erode/dilate against the dense engine (identity
        // semantics: valid for whole images under either border)
        let wx = odd_window(rng, 7);
        let wy = odd_window(rng, 7);
        for (op, fop) in [
            (neon_morph::morphology::MorphOp::Erode, FilterOp::Erode),
            (neon_morph::morphology::MorphOp::Dilate, FilterOp::Dilate),
        ] {
            let want = FilterSpec::new(fop, wx, wy)
                .with_config(cfg_with(Representation::Dense, Border::Identity))
                .run_once::<u8>(&mask)
                .unwrap();
            let got = rle.apply(op, wx, wy).to_image();
            assert!(
                got.same_pixels(&want),
                "direct {op:?} {wx}x{wy} on {h}x{w}: {:?}",
                got.first_diff(&want)
            );
        }
    });
}

#[test]
fn rle_edge_geometries() {
    // hand-built pathologies: single-pixel runs on alternating rows,
    // full rows, empty rows, and runs touching both borders
    let img = Image::from_fn(9, 12, |y, x| match y {
        0 => 255,                                // full row
        1 => 0,                                  // empty row
        2 => u8::from(x % 2 == 0) * 255,         // 1-px runs
        3 => u8::from(x == 0 || x == 11) * 255,  // both edges
        4 => u8::from(x < 3) * 255,              // left-anchored
        5 => u8::from(x >= 9) * 255,             // right-anchored
        6 => u8::from((3..9).contains(&x)) * 255, // interior run
        _ => u8::from(x == 5) * 255,             // lone pixel
    });
    let rle = RleImage::from_view(&img).unwrap();
    assert!(rle.to_image().same_pixels(&img));
    for (wx, wy) in [(1, 1), (3, 1), (1, 3), (3, 3), (5, 7), (13, 3)] {
        for op in [FilterOp::Erode, FilterOp::Dilate, FilterOp::Open, FilterOp::Close] {
            let spec = FilterSpec::new(op, wx, wy);
            let want = spec
                .with_config(cfg_with(Representation::Dense, Border::Identity))
                .run_once::<u8>(&img)
                .unwrap();
            let got = spec
                .with_config(cfg_with(Representation::Rle, Border::Identity))
                .run_once::<u8>(&img)
                .unwrap();
            assert!(got.same_pixels(&want), "{op:?} {wx}x{wy}: {:?}", got.first_diff(&want));
        }
    }
}

/// Pixel-by-pixel reconstruction oracle with the library's sweep
/// accounting: every executed sweep counts, including the final one
/// that proves the fixpoint.
fn naive_reconstruct(
    marker: &Image<u8>,
    mask: &Image<u8>,
    w_x: usize,
    w_y: usize,
) -> (Image<u8>, usize) {
    let (h, w) = (mask.height(), mask.width());
    let (wing_x, wing_y) = (w_x / 2, w_y / 2);
    let mut cur = Image::from_fn(h, w, |y, x| marker.get(y, x).min(mask.get(y, x)));
    let mut sweeps = 0usize;
    loop {
        sweeps += 1;
        let next = Image::from_fn(h, w, |y, x| {
            let mut m = 0u8;
            for yy in y.saturating_sub(wing_y)..(y + wing_y + 1).min(h) {
                for xx in x.saturating_sub(wing_x)..(x + wing_x + 1).min(w) {
                    m = m.max(cur.get(yy, xx));
                }
            }
            m.min(mask.get(y, x))
        });
        if next.same_pixels(&cur) {
            return (cur, sweeps);
        }
        cur = next;
    }
}

#[test]
fn reconstruction_matches_naive_oracle() {
    forall(0x6E0, 12, |rng, _| {
        let (h, w) = (4 + rng.below(26), 4 + rng.below(30));
        let mask = random_mask(rng, h, w, 30 + rng.below(40));
        // marker: a random subset of the mask (a few seed points)
        let marker =
            Image::from_fn(h, w, |y, x| if rng.below(20) == 0 { mask.get(y, x) } else { 0 });
        let wx = odd_window(rng, 5);
        let wy = odd_window(rng, 5);
        let (want, want_sweeps) = naive_reconstruct(&marker, &mask, wx, wy);
        for parallelism in [Parallelism::Sequential, Parallelism::Fixed(4)] {
            for border in [Border::Identity, Border::Replicate] {
                let cfg = MorphConfig {
                    parallelism,
                    border,
                    ..MorphConfig::default()
                };
                let (got, sweeps) =
                    reconstruct_by_dilation(&marker, &mask, wx, wy, &cfg).unwrap();
                assert!(
                    got.same_pixels(&want),
                    "{parallelism:?} {border:?} {wx}x{wy} on {h}x{w}: {:?}",
                    got.first_diff(&want)
                );
                assert_eq!(sweeps, want_sweeps, "{parallelism:?} {border:?} sweep count");
            }
        }
    });
}

#[test]
fn pipeline_serves_reconstruct_bit_identically() {
    // a mask with structure (checkerboard) and a top-row seed: the
    // fixpoint takes many sweeps, so this really exercises the plan's
    // sweep loop through the staged pipeline
    let mask = Arc::new(synth::checkerboard(40, 56, 6));
    let marker = Arc::new(Image::from_fn(40, 56, |y, x| {
        if y == 0 {
            mask.get(0, x)
        } else {
            0
        }
    }));
    let spec = FilterSpec::new(FilterOp::Reconstruct, 3, 3);
    let (want, _) =
        reconstruct_by_dilation(&*marker, &*mask, 3, 3, &MorphConfig::default()).unwrap();

    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        backend: BackendChoice::NativeOnly,
        artifact_dir: None,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    const G: u64 = 6;
    let tickets: Vec<_> = (0..G)
        .map(|_| coord.submit_with_marker(spec, mask.clone(), marker.clone()).unwrap())
        .collect();
    for t in tickets {
        let resp = t.wait().unwrap();
        assert_eq!(resp.backend, "native");
        let out = resp.result.unwrap().into_u8().unwrap();
        assert!(
            out.same_pixels(&want),
            "pipeline reconstruct diverged from the library: {:?}",
            out.first_diff(&want)
        );
    }
    let snap = coord.metrics();
    assert_eq!(snap.completed, G);
    assert_eq!(snap.failed, 0);
    // one plan family: the resolve stage warms it once, every request
    // is warm + execute — the same 1 + (2G − 1) contract as filter ops
    assert_eq!(snap.plan_resolutions, 1, "reconstruct plans must cache");
    assert_eq!(snap.plan_hits, 2 * G - 1);
    coord.shutdown();
}
