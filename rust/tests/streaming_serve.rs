//! Streaming-serving stress suite: [`SubmitStream`] must be
//! **bit-identical** to per-ticket `submit` under concurrent mixed load,
//! and the coordinator must shut down gracefully with streams in flight.
//!
//! The workload crosses producers × op chains × pixel depths × ROI
//! positions (interior *and* edge-clamped) × configs — the mix a
//! recognition-pipeline front end would generate — and every response
//! is checked against an oracle computed through the fire-and-wait
//! `submit` path on a separate coordinator (so the two submission paths
//! are genuinely independent executions).  A second test pins the
//! plan-economy claim end to end: an interior same-shape crop sweep
//! across MANY positions resolves one plan per worker at most.
//!
//! [`SubmitStream`]: neon_morph::coordinator::SubmitStream

use std::collections::HashMap;
use std::sync::Arc;

use neon_morph::coordinator::request::{FilterOutput, ImagePayload};
use neon_morph::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use neon_morph::image::{synth, Image};
use neon_morph::morphology::{Border, FilterOp, FilterSpec, MorphConfig, Parallelism, Roi};

const H: usize = 72;
const W: usize = 84;

fn native_coord(workers: usize, capacity: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers,
        queue_capacity: capacity,
        max_batch: 8,
        backend: BackendChoice::NativeOnly,
        artifact_dir: None,
        ..CoordinatorConfig::default()
    })
    .unwrap()
}

/// The mixed request stream: op chains, both depths, both borders,
/// interior and edge-clamped ROIs, explicit parallelism.
fn spec_of(i: usize) -> (FilterSpec, bool) {
    let seq = MorphConfig {
        parallelism: Parallelism::Sequential,
        ..MorphConfig::default()
    };
    let repl = MorphConfig {
        border: Border::Replicate,
        ..MorphConfig::default()
    };
    match i % 7 {
        0 => (FilterSpec::new(FilterOp::Erode, 7, 5), false),
        1 => (FilterSpec::new(FilterOp::Gradient, 5, 5), true), // u16
        2 => {
            // interior crop sweep: tophat halo = (4, 4); positions vary
            let y = 4 + (i * 5) % (H - 24 - 8);
            let x = 4 + (i * 3) % (W - 30 - 8);
            (
                FilterSpec::new(FilterOp::TopHat, 5, 5).with_roi(Roi::new(y, x, 24, 30)),
                false,
            )
        }
        3 => (
            // edge-clamped crop (its own plan family)
            FilterSpec::new(FilterOp::Erode, 5, 5).with_roi(Roi::new(0, 0, 20, 20)),
            false,
        ),
        4 => (
            FilterSpec::new(FilterOp::Open, 3, 3)
                .then(FilterOp::Gradient)
                .with_config(seq),
            false,
        ),
        5 => (FilterSpec::new(FilterOp::Close, 5, 7).with_config(repl), true),
        _ => (FilterSpec::new(FilterOp::BlackHat, 3, 3), false),
    }
}

fn payload(is_u16: bool, img8: &Arc<Image<u8>>, img16: &Arc<Image<u16>>) -> ImagePayload {
    if is_u16 {
        img16.clone().into()
    } else {
        img8.clone().into()
    }
}

fn same_output(a: &FilterOutput, b: &FilterOutput) -> bool {
    match (a, b) {
        (FilterOutput::U8(x), FilterOutput::U8(y)) => x.same_pixels(y),
        (FilterOutput::U16(x), FilterOutput::U16(y)) => x.same_pixels(y),
        _ => false,
    }
}

#[test]
fn streamed_responses_are_bit_identical_to_submit() {
    const PRODUCERS: usize = 4;
    const PER_PRODUCER: usize = 35;
    let img8 = Arc::new(synth::noise(H, W, 0x57A));
    let img16 = Arc::new(synth::noise_u16(H, W, 0x57B));

    // oracle coordinator: the fire-and-wait path, one spec each
    let oracle_coord = native_coord(2, 64);
    let mut oracles: HashMap<FilterSpec, FilterOutput> = HashMap::new();
    for i in 0..PRODUCERS * PER_PRODUCER {
        let (spec, is_u16) = spec_of(i);
        oracles.entry(spec).or_insert_with(|| {
            oracle_coord
                .filter_spec(spec, payload(is_u16, &img8, &img16))
                .unwrap()
                .result
                .unwrap()
        });
    }
    oracle_coord.shutdown();

    // streaming coordinator: concurrent producers, each its own stream
    let coord = native_coord(3, PRODUCERS * PER_PRODUCER + 8);
    let all: Vec<(FilterSpec, FilterOutput)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let coord = &coord;
                let img8 = &img8;
                let img16 = &img16;
                scope.spawn(move || {
                    let mut stream = coord.stream();
                    let mut by_id = HashMap::new();
                    for i in 0..PER_PRODUCER {
                        let (spec, is_u16) = spec_of(p * PER_PRODUCER + i);
                        let id = stream
                            .send(spec, payload(is_u16, img8, img16))
                            .expect("queue sized for the full load");
                        by_id.insert(id, spec);
                    }
                    assert_eq!(stream.sent(), PER_PRODUCER as u64);
                    assert_eq!(stream.shed(), 0);
                    let out: Vec<_> = stream
                        .drain()
                        .into_iter()
                        .map(|r| (by_id.remove(&r.id).expect("known id"), r.result.unwrap()))
                        .collect();
                    assert!(by_id.is_empty(), "every send must be answered once");
                    assert_eq!(stream.in_flight(), 0);
                    out
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });

    assert_eq!(all.len(), PRODUCERS * PER_PRODUCER);
    for (spec, got) in &all {
        let want = &oracles[spec];
        assert!(
            same_output(got, want),
            "streamed result for {spec:?} differs from the submit oracle"
        );
    }
    let snap = coord.metrics();
    assert_eq!(snap.completed, (PRODUCERS * PER_PRODUCER) as u64);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.shed, 0);
    coord.shutdown();
}

#[test]
fn interior_crop_sweep_streams_through_one_plan_per_worker() {
    const WORKERS: usize = 2;
    const SWEEP: usize = 40;
    let coord = native_coord(WORKERS, SWEEP + 8);
    let img = Arc::new(synth::noise(96, 96, 0xC0FE));
    let base = FilterSpec::new(FilterOp::Erode, 7, 7); // halo (3, 3)
    let full = neon_morph::morphology::erode(img.view(), 7, 7);
    let mut stream = coord.stream();
    let mut wants = HashMap::new();
    for i in 0..SWEEP {
        let y = 3 + (i * 7) % (96 - 32 - 6);
        let x = 3 + (i * 11) % (96 - 32 - 6);
        let id = stream
            .send(base.with_roi(Roi::new(y, x, 32, 32)), img.clone())
            .unwrap();
        wants.insert(id, full.view().sub_rect(y, x, 32, 32).to_image());
    }
    for r in stream.drain() {
        let got = r.result.unwrap().into_u8().unwrap();
        assert!(got.same_pixels(&wants[&r.id]), "request {}", r.id);
    }
    drop(stream);
    let snap = coord.metrics();
    assert_eq!(snap.completed, SWEEP as u64);
    // each worker's cache resolves the canonical plan at most once —
    // NOT once per position (the pre-redesign behaviour was one
    // resolution per distinct offset)
    assert!(
        snap.plan_resolutions <= WORKERS as u64,
        "{} resolutions for an interior sweep on {WORKERS} workers",
        snap.plan_resolutions
    );
    // the pipeline touches each request's plan twice (resolve-stage
    // warm + execute), so touches = 2·SWEEP across both lane caches
    assert_eq!(snap.plan_resolutions + snap.plan_hits, 2 * SWEEP as u64);
    coord.shutdown();
}

#[test]
fn shutdown_mid_stream_is_graceful() {
    // drop a stream with work still queued, then shut down: workers
    // must drain the queue (discarding unreceivable replies) and join
    let coord = native_coord(2, 256);
    let img = Arc::new(synth::paper_image(0xD1E));
    {
        let mut stream = coord.stream();
        for _ in 0..48 {
            stream
                .send(FilterSpec::new(FilterOp::Close, 9, 9), img.clone())
                .unwrap();
        }
        // receive a few, abandon the rest mid-flight
        for _ in 0..3 {
            let r = stream.recv_timeout(std::time::Duration::from_secs(60));
            assert!(r.is_some_and(|r| r.result.is_ok()));
        }
        assert!(stream.in_flight() > 0, "work must still be in flight");
    } // stream (and its reply receiver) dropped here
    coord.shutdown(); // must not hang or panic
}

#[test]
fn stream_shed_requests_never_produce_responses() {
    // overload a tiny queue: the stream must account every request as
    // either answered or shed, with no response for shed ones
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        queue_capacity: 2,
        max_batch: 1,
        backend: BackendChoice::NativeOnly,
        artifact_dir: None,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let img = Arc::new(synth::paper_image(7));
    let mut stream = coord.stream();
    let mut errors = 0u64;
    for _ in 0..40 {
        if stream
            .send(FilterSpec::new(FilterOp::Open, 15, 15), img.clone())
            .is_err()
        {
            errors += 1;
        }
    }
    assert_eq!(stream.shed(), errors);
    assert!(errors > 0, "the tiny queue must shed under this load");
    let responses = stream.drain();
    assert_eq!(responses.len() as u64, 40 - errors);
    assert!(responses.iter().all(|r| r.result.is_ok()));
    drop(stream);
    coord.shutdown();
}
