//! Minimal, dependency-free subset of the `anyhow` API, vendored because
//! the offline build cannot reach a crate registry.
//!
//! Provides exactly what this workspace uses:
//!
//! * [`Error`] — an owned error with a context chain (outermost first);
//!   `{}` displays the outermost message, `{:#}` the full chain joined
//!   by `": "` (matching anyhow's alternate formatting).
//! * [`Result<T>`] — alias with [`Error`] as the default error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`, so the blanket `From<E: std::error::Error>`
//! impl can coexist with the reflexive `From<Error>`.

use std::fmt;

/// An error with a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Attach context to the error variant of a `Result` or to a `None`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_outer_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: no such file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        assert_eq!(Some(1).context("x").unwrap(), 1);
    }

    #[test]
    fn macros_build_errors() {
        fn fails(n: u32) -> Result<u32> {
            ensure!(n < 10, "n too big: {n}");
            if n == 5 {
                bail!("five is right out");
            }
            Ok(n)
        }
        assert_eq!(fails(3).unwrap(), 3);
        assert_eq!(format!("{}", fails(5).unwrap_err()), "five is right out");
        assert_eq!(format!("{}", fails(11).unwrap_err()), "n too big: 11");
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xFF])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e: Error = io_err().into();
        let e = e.context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("no such file"));
    }
}
