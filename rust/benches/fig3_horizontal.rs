//! Bench: regenerate the paper's Figure 3 (horizontal-pass erosion time
//! vs w_y on 800×600 u8; series vHGW / vHGW+SIMD / linear+SIMD /
//! hybrid).
//!
//! Run: `cargo bench --bench fig3_horizontal`
//! Env: `NEON_MORPH_QUICK=1` reduces the sweep.

use neon_morph::bench_harness::{self, fig3};
use neon_morph::costmodel::CostModel;

fn main() {
    let quick = std::env::var("NEON_MORPH_QUICK").is_ok();
    let windows = if quick {
        bench_harness::window_sweep_quick()
    } else {
        bench_harness::window_sweep()
    };
    let model = CostModel::exynos5422();
    let s = fig3::run(&model, &windows, if quick { 2 } else { 5 });
    print!(
        "{}",
        fig3::render(
            "Figure 3 — horizontal pass erosion, cost model (Exynos-5422 ns)",
            &s,
            "model"
        )
        .to_markdown()
    );
    println!();
    print!(
        "{}",
        fig3::render("Figure 3 — host wall-clock (ns)", &s, "host").to_markdown()
    );
    println!(
        "\ncrossover w_y0: model={} host={} (paper: 69)",
        s.crossover_model, s.crossover_host
    );
}
