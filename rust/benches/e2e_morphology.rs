//! Bench: the conclusion's headline claim — final hybrid erosion vs the
//! vHGW-without-SIMD baseline, end to end (2-D, 800×600), plus the
//! coordinator serving benchmark (throughput/latency through L3).
//!
//! Run: `cargo bench --bench e2e_morphology`
//! Env: `NEON_MORPH_QUICK=1` for a reduced run.

use neon_morph::bench_harness::e2e;
use neon_morph::costmodel::CostModel;

fn main() {
    let quick = std::env::var("NEON_MORPH_QUICK").is_ok();
    let model = CostModel::exynos5422();
    let windows = if quick { vec![7, 15] } else { vec![3, 7, 15, 31, 61, 91] };
    let results = e2e::run(&model, &windows, if quick { 2 } else { 5 });
    print!("{}", e2e::render(&results).to_markdown());
    println!();

    for &workers in if quick { &[2usize][..] } else { &[1usize, 2, 4, 8][..] } {
        let s = e2e::serve_native(if quick { 32 } else { 192 }, workers, 7)
            .expect("serving bench");
        println!(
            "serve: {:>3} reqs x {} workers -> {:>7.1} req/s | p50 {:>7.2} ms | p99 {:>7.2} ms | mean batch {:.2}",
            s.requests, s.workers, s.throughput_rps, s.p50_us / 1e3, s.p99_us / 1e3, s.mean_batch
        );
    }
}
