//! Bench: band-parallel scaling sweep — modeled speedup of the §5.3
//! hybrid erosion vs band count (compute scales ~1/P, the memory term
//! does not, so the curve saturates at the memory-bandwidth ceiling),
//! plus host wall-clock of the real banded execution on this machine.
//!
//! Run: `cargo bench --bench scaling`
//! Env: `NEON_MORPH_QUICK=1` reduces host iterations.

use neon_morph::bench_harness::scaling;
use neon_morph::costmodel::CostModel;
use neon_morph::image::synth;

fn main() {
    let quick = std::env::var("NEON_MORPH_QUICK").is_ok();
    let model = CostModel::exynos5422();
    let s = scaling::run(
        &model,
        synth::PAPER_HEIGHT,
        synth::PAPER_WIDTH,
        scaling::SCALING_WINDOW,
        16,
        if quick { 1 } else { 5 },
    );
    print!("{}", scaling::render(&s).to_markdown());
    println!(
        "\nmodeled saturation: P={} (speedup {:.2}x, ceiling {:.2}x)",
        s.saturation,
        s.speedup_at(s.saturation),
        s.ceiling
    );
}
