//! Bench: regenerate the paper's Table 1 (matrix transpose, scalar vs
//! NEON; 8×8.16 and 16×16.8).
//!
//! Run: `cargo bench --bench table1_transpose`
//! Env: `NEON_MORPH_QUICK=1` for fewer host-timing repetitions.

use neon_morph::bench_harness::table1;
use neon_morph::costmodel::CostModel;

fn main() {
    let model = CostModel::exynos5422();
    let rows = table1::run(&model);
    print!("{}", table1::render(&rows).to_markdown());
    println!();
    for r in &rows {
        println!(
            "{}.{}: paper {:.1}x | model {:.1}x | host {:.1}x",
            r.case,
            if r.case == "8x8" { "u16" } else { "u8" },
            r.paper_ratio(),
            r.model_ratio(),
            r.host_ratio()
        );
    }
}
