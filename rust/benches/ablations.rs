//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  A. the §5.1.2 *two-row trick* (shared window reduction) vs a naive
//!     one-row-at-a-time linear pass;
//!  B. the vertical strategy: §5.2.2 direct (unaligned loads) vs
//!     §5.2.1 transpose sandwich, for the linear method across windows;
//!  C. batching/affinity in the coordinator: max_batch 1 vs 16 on a
//!     mixed artifact workload (XLA backend when artifacts exist).
//!
//! Run: `cargo bench --bench ablations`

use std::sync::Arc;

use neon_morph::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use neon_morph::costmodel::CostModel;
use neon_morph::image::synth;
use neon_morph::morphology::{linear, MorphOp};
use neon_morph::neon::{Counting, Native};
use neon_morph::runtime::Manifest;
use neon_morph::util::timing;

fn main() {
    let model = CostModel::exynos5422();
    let img = synth::paper_image(0xAB1);

    println!("## A. two-row trick (rows linear pass, 800x600)\n");
    println!("| w | paired model ns | single model ns | paired host ns | single host ns | model gain |");
    println!("|---|----------------|-----------------|----------------|----------------|-----------|");
    for w in [3usize, 7, 15, 31, 61] {
        let mut c = Counting::new();
        let _ = linear::rows_simd_linear(&mut c, &img, w, MorphOp::Erode);
        let paired = model.price_ns(&c.mix);
        let mut c = Counting::new();
        let _ = linear::rows_simd_linear_single(&mut c, &img, w, MorphOp::Erode);
        let single = model.price_ns(&c.mix);
        let hp = timing::bench(1, 5, || linear::rows_simd_linear(&mut Native, &img, w, MorphOp::Erode)).min_ns;
        let hs = timing::bench(1, 5, || {
            linear::rows_simd_linear_single(&mut Native, &img, w, MorphOp::Erode)
        })
        .min_ns;
        println!(
            "| {w} | {paired:.0} | {single:.0} | {hp:.0} | {hs:.0} | {:.2}x |",
            single / paired
        );
    }

    println!("\n## B. vertical strategy: direct vs transpose sandwich (linear, 800x600)\n");
    println!("| w | direct model ns | sandwich model ns | direct host ns | sandwich host ns |");
    println!("|---|-----------------|-------------------|----------------|------------------|");
    for w in [3usize, 7, 15, 31, 61] {
        let mut c = Counting::new();
        let _ = linear::cols_simd_linear(&mut c, &img, w, MorphOp::Erode);
        let direct = model.price_ns(&c.mix);
        let mut c = Counting::new();
        let t = neon_morph::transpose::transpose_image(&mut c, &img);
        let f = linear::rows_simd_linear(&mut c, &t, w, MorphOp::Erode);
        let _ = neon_morph::transpose::transpose_image(&mut c, &f);
        let sandwich = model.price_ns(&c.mix);
        let hd = timing::bench(1, 5, || linear::cols_simd_linear(&mut Native, &img, w, MorphOp::Erode)).min_ns;
        let hs = timing::bench(1, 5, || {
            let t = neon_morph::transpose::transpose_image(&mut Native, &img);
            let f = linear::rows_simd_linear(&mut Native, &t, w, MorphOp::Erode);
            neon_morph::transpose::transpose_image(&mut Native, &f)
        })
        .min_ns;
        println!("| {w} | {direct:.0} | {sandwich:.0} | {hd:.0} | {hs:.0} |");
    }

    println!("\n## C. coordinator batching: max_batch 1 vs 16 (xla backend)\n");
    if Manifest::load("artifacts").is_err() {
        println!("(skipped: artifacts not built)");
        return;
    }
    for max_batch in [1usize, 16] {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            queue_capacity: 512,
            max_batch,
            backend: BackendChoice::Auto,
            artifact_dir: Some("artifacts".into()),
            precompile: false,
            ..CoordinatorConfig::default()
        })
        .expect("coordinator");
        let img = Arc::new(synth::noise(256, 256, 3));
        let ops = [
            neon_morph::morphology::FilterOp::Erode,
            neon_morph::morphology::FilterOp::Dilate,
            neon_morph::morphology::FilterOp::Gradient,
        ];
        let t0 = std::time::Instant::now();
        let tickets: Vec<_> = (0..48)
            .map(|i| {
                let spec = neon_morph::morphology::FilterSpec::new(ops[i % 3], 3, 3);
                coord.submit(spec, img.clone()).unwrap()
            })
            .collect();
        for t in tickets {
            t.wait().unwrap().result.unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let snap = coord.metrics();
        println!(
            "max_batch={max_batch:>2}: {:.1} req/s, mean batch {:.2}, exec p50 {:.1} ms",
            48.0 / wall,
            snap.mean_batch_size(),
            snap.exec_p50_us / 1e3
        );
        coord.shutdown();
    }
}
