//! Bench: the Fig. 3 sweep on the 800×600 **u16** workload — the §4
//! 8×8.16 scenario (8 SIMD lanes per op instead of 16, 2× streamed
//! bytes; series shapes match the u8 sweep, absolute prices ~2×).
//!
//! Run: `cargo bench --bench fig3_u16`
//! Env: `NEON_MORPH_QUICK=1` reduces the sweep.

use neon_morph::bench_harness::{self, fig3};
use neon_morph::costmodel::CostModel;

fn main() {
    let quick = std::env::var("NEON_MORPH_QUICK").is_ok();
    let windows = if quick {
        bench_harness::window_sweep_quick()
    } else {
        bench_harness::window_sweep()
    };
    let model = CostModel::exynos5422();
    let s = fig3::run_u16(&model, &windows, if quick { 2 } else { 5 });
    print!(
        "{}",
        fig3::render(
            "Figure 3 (u16) — horizontal pass erosion on 800x600 u16, cost model (ns)",
            &s,
            "model"
        )
        .to_markdown()
    );
    println!();
    print!(
        "{}",
        fig3::render("Figure 3 (u16) — host wall-clock (ns)", &s, "host").to_markdown()
    );
    println!(
        "\nu16 crossover w_y0: model={} host={}",
        s.crossover_model, s.crossover_host
    );
}
