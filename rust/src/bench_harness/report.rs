//! Tabular report rendering (markdown and TSV) shared by all harnesses.

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&line(&self.headers));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
        }
        out
    }

    /// Render as TSV (for plotting).
    pub fn to_tsv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join("\t"));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join("\t"));
            out.push('\n');
        }
        out
    }
}

/// Format ns with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.1} µs", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_renders_aligned() {
        let mut t = Table::new("T", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| a "));
        assert!(md.contains("| long_header |"));
        assert!(md.lines().count() >= 4);
    }

    #[test]
    fn tsv_renders() {
        let mut t = Table::new("", &["x", "y"]);
        t.row(vec!["3".into(), "9".into()]);
        assert_eq!(t.to_tsv(), "x\ty\n3\t9\n");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("", &["x", "y"]);
        t.row(vec!["3".into()]);
    }

    #[test]
    fn ns_formatting() {
        assert_eq!(fmt_ns(120.0), "120 ns");
        assert_eq!(fmt_ns(4500.0), "4.5 µs");
        assert_eq!(fmt_ns(3_200_000.0), "3.20 ms");
        assert_eq!(fmt_ns(2_500_000_000.0), "2.500 s");
    }
}
