//! The perf-baseline regression gate.
//!
//! CI runs the deterministic cost-model sweeps (`bench smoke`), writes
//! `BENCH_fig3.json` / `BENCH_scaling.json`, and compares their
//! `headline` sections against the committed baselines in
//! `rust/benches/baselines/` (`bench gate`).  A headline ratio drifting
//! beyond the tolerance (±10%) **fails the job** — cost-model numbers
//! are exact functions of the counted instruction mixes, so any drift
//! is a real change to the modeled performance of the kernels (or to
//! the model itself) and must be acknowledged by regenerating the
//! baselines (`bench smoke --update-baselines`, which re-runs the
//! sweeps and commits the new numbers).

use crate::util::json::Json;

/// Relative tolerance of the CI gate.
pub const GATE_TOLERANCE: f64 = 0.10;

/// Compare `measured` against `baseline`, returning one message per
/// violation (empty = gate passes).
///
/// The walk is driven by the **baseline**: every numeric leaf in it
/// must exist in `measured` within `tol` relative error (absolute error
/// for baselines near zero), and every string leaf must match exactly.
/// Keys present only in `measured` are ignored, so benches may add
/// informational fields without invalidating committed baselines.
pub fn compare(baseline: &Json, measured: &Json, tol: f64) -> Vec<String> {
    let mut failures = Vec::new();
    walk("", baseline, Some(measured), tol, &mut failures);
    failures
}

fn walk(path: &str, base: &Json, meas: Option<&Json>, tol: f64, out: &mut Vec<String>) {
    let Some(meas) = meas else {
        out.push(format!("{path}: present in baseline but missing from measurement"));
        return;
    };
    match base {
        Json::Obj(map) => {
            for (k, v) in map {
                let child = format!("{path}/{k}");
                walk(&child, v, meas.get(k), tol, out);
            }
        }
        Json::Arr(items) => {
            let got = meas.as_arr().unwrap_or(&[]);
            if got.len() != items.len() {
                out.push(format!(
                    "{path}: baseline has {} entries, measurement has {}",
                    items.len(),
                    got.len()
                ));
                return;
            }
            for (i, (b, m)) in items.iter().zip(got).enumerate() {
                walk(&format!("{path}[{i}]"), b, Some(m), tol, out);
            }
        }
        Json::Num(b) => match meas.as_f64() {
            None => out.push(format!("{path}: expected a number, got {meas:?}")),
            Some(m) => {
                // relative error, degrading to absolute error (scale 1)
                // for baselines below 1 so near-zero values don't demand
                // an exact match
                let scale = b.abs().max(1.0);
                let rel = (m - b).abs() / scale;
                if rel > tol {
                    out.push(format!(
                        "{path}: {m:.6} drifted {:.1}% from baseline {b:.6} (tolerance {:.0}%)",
                        rel * 100.0,
                        tol * 100.0
                    ));
                }
            }
        },
        Json::Str(b) => {
            if meas.as_str() != Some(b.as_str()) {
                out.push(format!("{path}: expected {b:?}, got {meas:?}"));
            }
        }
        Json::Bool(b) => {
            if meas != &Json::Bool(*b) {
                out.push(format!("{path}: expected {b}, got {meas:?}"));
            }
        }
        Json::Null => {
            if meas != &Json::Null {
                out.push(format!("{path}: expected null, got {meas:?}"));
            }
        }
    }
}

/// Extract the gated subset of a bench report: the `bench` tag and the
/// `headline` section.  This is what `--update-baselines` commits —
/// baselines deliberately exclude the informational `points` series so
/// adding sweep points never invalidates them.
pub fn headline_subset(report: &Json) -> Json {
    let mut out = std::collections::BTreeMap::new();
    if let Some(b) = report.get("bench") {
        out.insert("bench".to_string(), b.clone());
    }
    if let Some(w) = report.get("workload") {
        out.insert("workload".to_string(), w.clone());
    }
    if let Some(h) = report.get("headline") {
        out.insert("headline".to_string(), h.clone());
    }
    Json::Obj(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn baseline() -> Json {
        parse(
            r#"{"bench":"fig3","headline":{"vhgw_simd_speedup_w31":3.0,"linear_speedup_w3":10.0}}"#,
        )
        .unwrap()
    }

    #[test]
    fn identical_reports_pass() {
        let b = baseline();
        assert!(compare(&b, &b, GATE_TOLERANCE).is_empty());
    }

    #[test]
    fn drift_within_tolerance_passes() {
        let b = baseline();
        let m = parse(
            r#"{"bench":"fig3","headline":{"vhgw_simd_speedup_w31":3.2,"linear_speedup_w3":9.3,"extra_info":42}}"#,
        )
        .unwrap();
        assert!(compare(&b, &m, GATE_TOLERANCE).is_empty());
    }

    #[test]
    fn seeded_ten_percent_drift_fails() {
        let b = baseline();
        // 15% drift on one ratio: the gate must catch exactly that key
        let m = parse(
            r#"{"bench":"fig3","headline":{"vhgw_simd_speedup_w31":3.45,"linear_speedup_w3":10.0}}"#,
        )
        .unwrap();
        let fails = compare(&b, &m, GATE_TOLERANCE);
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("vhgw_simd_speedup_w31"));
        assert!(fails[0].contains("15.0%"));
    }

    #[test]
    fn missing_headline_key_fails() {
        let b = baseline();
        let m = parse(r#"{"bench":"fig3","headline":{"vhgw_simd_speedup_w31":3.0}}"#).unwrap();
        let fails = compare(&b, &m, GATE_TOLERANCE);
        assert_eq!(fails.len(), 1);
        assert!(fails[0].contains("linear_speedup_w3"));
        assert!(fails[0].contains("missing"));
    }

    #[test]
    fn bench_tag_mismatch_fails() {
        let b = baseline();
        let m = parse(r#"{"bench":"fig4","headline":{"vhgw_simd_speedup_w31":3.0,"linear_speedup_w3":10.0}}"#)
            .unwrap();
        assert!(!compare(&b, &m, GATE_TOLERANCE).is_empty());
    }

    #[test]
    fn headline_subset_drops_points() {
        let full = parse(
            r#"{"bench":"scaling","workload":"x","headline":{"saturation_workers":5},"points":[{"workers":1}]}"#,
        )
        .unwrap();
        let sub = headline_subset(&full);
        assert!(sub.get("points").is_none());
        assert_eq!(
            sub.get("headline").unwrap().usize_field("saturation_workers"),
            Some(5)
        );
        // the subset gates against the full report
        assert!(compare(&sub, &full, GATE_TOLERANCE).is_empty());
    }
}
