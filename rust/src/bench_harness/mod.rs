//! Regenerates every table and figure of the paper's evaluation.
//!
//! | artifact | function | paper |
//! |----------|----------|-------|
//! | Table 1  | [`table1::run`] | 8×8.16 / 16×16.8 transpose, scalar vs NEON |
//! | Figure 3 | [`fig3::run`]   | horizontal-pass erosion time vs `w_y` |
//! | Fig 3 u16 | [`fig3::run_u16`] | the same sweep on the 800×600 u16 workload (8 lanes/op) |
//! | Figure 4 | [`fig4::run`]   | vertical-pass erosion time vs `w_x` |
//! | headline | [`e2e::run`]    | final hybrid vs vHGW-no-SIMD, ≥3× |
//! | scaling  | [`scaling::run`] | band-parallel speedup vs workers (extension) |
//! | transpose | [`transpose::run_model`] | banded §4 tile-transpose throughput + speedups (extension) |
//!
//! [`scaling`] also emits the machine-readable `BENCH_fig3.json` /
//! `BENCH_fig4.json` / `BENCH_table1.json` / `BENCH_scaling.json`
//! reports whose `headline` ratios CI pins against the committed
//! baselines in `rust/benches/baselines/` via [`gate`] (±10%; see
//! `bench smoke` / `bench gate`).  The deterministic Table 1 form is
//! [`table1::run_model`].  [`serve`] adds the serving-side report
//! (`BENCH_serve.json`): count-exact plan-cache headlines of a streamed
//! coordinator workload (plan resolutions per request).  [`rle`] adds
//! the scenario-engine report (`BENCH_rle.json`): modeled RLE-vs-dense
//! ratios plus a live reconstruction sweep count.  [`transpose`] adds
//! the banded-transpose report (`BENCH_transpose.json`): closed-form
//! tile-network throughput at both depths plus the banded/in-sandwich
//! speedup and Auto-demotion headlines.
//!
//! Every experiment reports **two** measurements side by side:
//!
//! * `model` — the calibrated Exynos-5422 cost model applied to the
//!   *counted* instruction mix of the simulated NEON implementation
//!   (this is the reproduction of the paper's numbers; see DESIGN.md
//!   §Substitutions), and
//! * `host` — real wall-clock time of the same algorithm running
//!   through the zero-cost [`crate::neon::Native`] backend on this
//!   machine (different silicon, same code — shapes should agree,
//!   absolute values will not).
//!
//! The binaries under `rust/benches/` and the `neon-morph bench` CLI
//! subcommand are thin wrappers over these functions.

pub mod e2e;
pub mod fig3;
pub mod fig4;
pub mod gate;
pub mod report;
pub mod rle;
pub mod scaling;
pub mod serve;
pub mod table1;
pub mod transpose;

/// Default odd-window sweep used by Fig. 3 / Fig. 4 (the paper sweeps
/// roughly 3..120).
pub fn window_sweep() -> Vec<usize> {
    let mut v: Vec<usize> = (1..=15).map(|k| 2 * k + 1).collect(); // 3..31
    v.extend([35, 41, 47, 53, 59, 65, 69, 75, 81, 91, 101, 111, 121]);
    v
}

/// Smaller sweep for smoke tests / debug builds.
pub fn window_sweep_quick() -> Vec<usize> {
    vec![3, 7, 15, 31, 61, 91]
}
