//! Figure 4: vertical-pass erosion time vs `w_x` (800×600 u8).
//!
//! Series: vHGW without SIMD (direct scalar per-row), vHGW with SIMD
//! (the §5.2.1 baseline: NEON transpose → SIMD rows pass → transpose),
//! linear with SIMD (§5.2.2 direct, unaligned offset loads), hybrid.
//! Paper observations: SIMD vHGW ≈ 3× over scalar for `w_x ≥ 3`; linear
//! at `w_x = 3` is 11× over scalar vHGW; crossover `w_x⁰ = 59` — lower
//! than Fig. 3's 69 "because passes work with memory asymmetrically".

use crate::costmodel::CostModel;
use crate::image::Image;
use crate::morphology::{linear, vhgw, MorphOp};
use crate::neon::{Backend, Counting, Native};
use crate::transpose;

use super::fig3::{sweep_generic, PassRunner, Sweep};
use super::report::Table;

pub const SERIES: [&str; 4] = ["vhgw", "vhgw_simd_transpose", "linear_simd", "hybrid"];

fn pass<B: Backend>(b: &mut B, img: &Image<u8>, window: usize, series: usize) -> Image<u8> {
    match series {
        0 => vhgw::cols_scalar_vhgw(b, img, window, MorphOp::Erode),
        1 => {
            // §5.2.1: transpose sandwich with the §4 NEON tiles
            let t = transpose::transpose_image(b, img);
            let f = vhgw::rows_simd_vhgw(b, &t, window, MorphOp::Erode);
            transpose::transpose_image(b, &f)
        }
        2 => linear::cols_simd_linear(b, img, window, MorphOp::Erode),
        _ => unreachable!(),
    }
}

struct ColsRunner;

impl PassRunner<u8> for ColsRunner {
    fn run_counting(
        &self,
        b: &mut Counting,
        img: &Image<u8>,
        w: usize,
        series: usize,
    ) -> Image<u8> {
        pass(b, img, w, series)
    }

    fn run_native(&self, b: &mut Native, img: &Image<u8>, w: usize, series: usize) -> Image<u8> {
        pass(b, img, w, series)
    }
}

/// Run the Fig. 4 sweep.
pub fn run(model: &CostModel, windows: &[usize], host_iters: usize) -> Sweep {
    let img = crate::image::synth::paper_image(0xF16);
    sweep_generic(
        model,
        &img,
        windows,
        host_iters,
        crate::morphology::PAPER_WX0,
        ColsRunner,
    )
}

/// Render (same layout as Fig. 3, vertical-series names).
pub fn render(title: &str, sweep: &Sweep, mode: &str) -> Table {
    let mut t = Table::new(
        title,
        &["w", "vhgw_ns", "vhgw_simd_T_ns", "linear_simd_ns", "hybrid_ns"],
    );
    for p in &sweep.points {
        let v = if mode == "host" { &p.host_ns } else { &p.model_ns };
        t.row(vec![
            p.window.to_string(),
            format!("{:.0}", v[0]),
            format!("{:.0}", v[1]),
            format!("{:.0}", v[2]),
            format!("{:.0}", v[3]),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_shapes_match_paper() {
        if cfg!(debug_assertions) {
            eprintln!("SKIP in debug: 800x600 instruction-counting sweep (runs under --release / make test)");
            return;
        }
        let model = CostModel::exynos5422();
        // dense near the expected crossover so its position resolves
        let s = run(&model, &[3, 31, 51, 55, 59, 63, 67, 91], 1);
        let at = |w: usize| s.points.iter().find(|p| p.window == w).unwrap();

        // linear at w=3 beats scalar vHGW decisively (paper: 11x)
        let p3 = at(3);
        let lin_speedup = p3.model_ns[0] / p3.model_ns[2];
        assert!(lin_speedup > 4.0, "linear w=3 speedup {lin_speedup}");

        // crossover near the paper's 59
        assert!(
            (39..=79).contains(&s.crossover_model),
            "crossover {} (paper 59)",
            s.crossover_model
        );

        // the transpose-sandwich vHGW is ~flat in window size
        let flat = at(91).model_ns[1] / at(31).model_ns[1];
        let _ = at(3);
        assert!(flat < 1.3, "vhgw+transpose should be ~flat: {flat}");
    }

    #[test]
    fn vertical_crossover_below_horizontal() {
        if cfg!(debug_assertions) {
            eprintln!("SKIP in debug: full dual sweep (runs under --release / make test)");
            return;
        }
        // §5.3: "values w_x0 and w_y0 are different, because passes work
        // with memory asymmetrically" — w_x0 < w_y0
        let model = CostModel::exynos5422();
        let windows: Vec<usize> = (1..=60).map(|k| 2 * k + 1).collect();
        let f3 = super::super::fig3::run(&model, &windows, 1);
        let f4 = run(&model, &windows, 1);
        assert!(
            f4.crossover_model < f3.crossover_model,
            "wx0 {} should be < wy0 {}",
            f4.crossover_model,
            f3.crossover_model
        );
    }
}
