//! Band-parallel scaling sweep: modeled (and optionally measured)
//! speedup of a full §5.2.1 sandwich erosion as the band count grows.
//!
//! The workload is a large-window (`w = 121`) linear erosion with the
//! vertical pass forced through the transpose sandwich — every phase
//! the banded executors cover (rows pass, both §4 tile transposes,
//! middle pass over the transposed buffer) appears in the mix, so the
//! sweep prices exactly what `Parallelism::Fixed(P)` executes.  The
//! model series is fully deterministic: one Counting run produces the
//! instruction mix of the sequential pass, and
//! [`crate::costmodel::CostModel::parallel_price_ns`] prices it at each
//! worker count — compute scales ~1/P, the memory/bandwidth term does
//! not, so the curve grows and then **saturates at the
//! memory-bandwidth ceiling**; the saturation point is part of the CI
//! perf baseline (`rust/benches/baselines/BENCH_scaling.json`).
//!
//! Two ceiling headlines are gated.  `ceiling` is the memory-bandwidth
//! limit `(C + M) / M` with *all* compute banded — reachable since the
//! banded transpose landed.  `ceiling_serial_transpose` re-prices the
//! limit with the two transposes' compute pinned serial,
//! `(C + M) / (M + C_t)` — the ceiling the pre-banded-transpose
//! executor was stuck under (Amdahl on the serial §4 tile networks);
//! their ratio `transpose_ceiling_lift` is the scaling headroom the
//! banded transpose bought.  The host series wall-clocks the real
//! banded execution
//! ([`crate::morphology::parallel::morphology_banded`]) and is
//! reported for information only (never gated — wall clocks are not
//! deterministic).

use std::collections::BTreeMap;

use crate::costmodel::CostModel;
use crate::image::synth;
use crate::morphology::{
    self, parallel, MorphConfig, MorphOp, Parallelism, PassMethod, VerticalStrategy,
};
use crate::neon::{Counting, InstrMix};
use crate::util::json::Json;
use crate::util::timing;

use super::report::Table;

/// Windows of the deterministic CI smoke sweep (`bench smoke`): the
/// paper's headline small window, the mid-range SIMD-speedup anchor,
/// and two points bracketing the §5.3 crossover.
pub const SMOKE_WINDOWS: [usize; 4] = [3, 31, 61, 91];

/// Window of the scaling workload: a large square SE whose linear
/// passes carry enough compute to make banding bite, run with the
/// vertical pass forced through the §5.2.1 transpose sandwich so the
/// banded tile transposes are part of the priced mix.
pub const SCALING_WINDOW: usize = 121;

/// One point of the scaling sweep.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub workers: usize,
    pub model_ns: f64,
    pub speedup: f64,
    /// Host wall-clock of the banded execution (0 when not measured).
    pub host_ns: f64,
}

/// Full sweep result.
#[derive(Clone, Debug)]
pub struct ScalingSweep {
    pub workload: String,
    pub points: Vec<ScalingPoint>,
    /// Modeled saturation point (first worker count with < 5% marginal
    /// gain) — the headline number the CI gate pins.
    pub saturation: usize,
    /// Memory-bandwidth ceiling `(compute + memory) / memory` with all
    /// compute banded (the banded-transpose executor's limit).
    pub ceiling: f64,
    /// The same limit with the two §5.2.1 transposes' compute pinned
    /// serial, `(compute + memory) / (memory + transpose_compute)` —
    /// what the pre-banded-transpose sandwich saturated at.
    pub ceiling_serial_transpose: f64,
    pub mix: InstrMix,
}

impl ScalingSweep {
    pub fn speedup_at(&self, workers: usize) -> f64 {
        self.points
            .iter()
            .find(|p| p.workers == workers)
            .map_or(1.0, |p| p.speedup)
    }
}

/// Run the scaling sweep on an `h × w` u8 noise image with a linear
/// `window × window` erosion whose vertical pass is forced through the
/// §5.2.1 transpose sandwich.  `host_iters > 0` also wall-clocks the
/// real banded execution at each worker count.
pub fn run(
    model: &CostModel,
    h: usize,
    w: usize,
    window: usize,
    max_workers: usize,
    host_iters: usize,
) -> ScalingSweep {
    let img = synth::noise(h, w, 0x5CA11);
    let cfg = MorphConfig {
        parallelism: Parallelism::Sequential,
        method: PassMethod::Linear,
        vertical: VerticalStrategy::Transpose,
        ..MorphConfig::default()
    };
    let mut c = Counting::new();
    let _ = morphology::morphology(&mut c, &img, MorphOp::Erode, window, window, &cfg);
    let mix = c.mix;
    let seq_ns = model.price_ns(&mix);
    // compute of the two §4 tile transposes (h×w forward, w×h back) —
    // the serial fraction of the pre-banded-transpose executor
    let transpose_compute_ns = model.transpose_breakdown(h, w, 16, 1, 1).compute_ns
        + model.transpose_breakdown(w, h, 16, 1, 1).compute_ns;
    let b = model.breakdown(&mix);
    let total = b.compute_ns + b.memory_ns;
    let ceiling = total / b.memory_ns;
    let ceiling_serial_transpose = total / (b.memory_ns + transpose_compute_ns);

    let mut points = Vec::with_capacity(max_workers);
    for p in 1..=max_workers.max(1) {
        let model_ns = model.parallel_price_ns(&mix, p);
        let host_ns = if host_iters > 0 {
            // pool fetched lazily: model-only sweeps never spawn it
            let pool = parallel::BandPool::global();
            timing::bench(1, host_iters, || {
                parallel::morphology_banded(pool, &img, MorphOp::Erode, window, window, &cfg, p)
            })
            .min_ns
        } else {
            0.0
        };
        points.push(ScalingPoint {
            workers: p,
            model_ns,
            speedup: seq_ns / model_ns,
            host_ns,
        });
    }
    ScalingSweep {
        workload: format!("erode {window}x{window} linear transpose-sandwich on {h}x{w} u8"),
        saturation: model.saturation_workers(&mix, max_workers),
        ceiling,
        ceiling_serial_transpose,
        points,
        mix,
    }
}

/// Render the sweep as a table.
pub fn render(sweep: &ScalingSweep) -> Table {
    let mut t = Table::new(
        &format!(
            "Band-parallel scaling — {} (model saturates at P={}, ceiling {:.2}x)",
            sweep.workload, sweep.saturation, sweep.ceiling
        ),
        &["workers", "model_ns", "model_speedup", "host_ns"],
    );
    for p in &sweep.points {
        t.row(vec![
            p.workers.to_string(),
            format!("{:.0}", p.model_ns),
            format!("{:.3}x", p.speedup),
            if p.host_ns > 0.0 {
                format!("{:.0}", p.host_ns)
            } else {
                "-".to_string()
            },
        ]);
    }
    t
}

/// Machine-readable form (`BENCH_scaling.json`): a gated `headline`
/// section plus the full informational point list.
pub fn to_json(sweep: &ScalingSweep) -> Json {
    let mut headline = BTreeMap::new();
    headline.insert(
        "saturation_workers".to_string(),
        Json::Num(sweep.saturation as f64),
    );
    headline.insert("speedup_at_2".to_string(), Json::Num(sweep.speedup_at(2)));
    headline.insert("speedup_at_4".to_string(), Json::Num(sweep.speedup_at(4)));
    headline.insert(
        "speedup_at_saturation".to_string(),
        Json::Num(sweep.speedup_at(sweep.saturation)),
    );
    headline.insert("ceiling".to_string(), Json::Num(sweep.ceiling));
    headline.insert(
        "ceiling_serial_transpose".to_string(),
        Json::Num(sweep.ceiling_serial_transpose),
    );
    headline.insert(
        "transpose_ceiling_lift".to_string(),
        Json::Num(sweep.ceiling / sweep.ceiling_serial_transpose),
    );

    let points = sweep
        .points
        .iter()
        .map(|p| {
            let mut o = BTreeMap::new();
            o.insert("workers".to_string(), Json::Num(p.workers as f64));
            o.insert("model_ns".to_string(), Json::Num(p.model_ns));
            o.insert("speedup".to_string(), Json::Num(p.speedup));
            if p.host_ns > 0.0 {
                o.insert("host_ns".to_string(), Json::Num(p.host_ns));
            }
            Json::Obj(o)
        })
        .collect();

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("scaling".to_string()));
    root.insert("workload".to_string(), Json::Str(sweep.workload.clone()));
    root.insert("headline".to_string(), Json::Obj(headline));
    root.insert("points".to_string(), Json::Arr(points));
    Json::Obj(root)
}

/// Machine-readable form of a Fig-3 sweep (`BENCH_fig3.json`): the
/// paper's headline ratios (vHGW+SIMD speedup, linear-vs-scalar-vHGW at
/// w = 3, the sparse-grid crossover) under `headline`, plus the model
/// series per window.
pub fn fig3_json(sweep: &super::fig3::Sweep) -> Json {
    let at = |w: usize| sweep.points.iter().find(|p| p.window == w);
    let mut headline = BTreeMap::new();
    if let Some(p) = at(31) {
        headline.insert(
            "vhgw_simd_speedup_w31".to_string(),
            Json::Num(p.model_ns[0] / p.model_ns[1]),
        );
    }
    if let Some(p) = at(3) {
        headline.insert(
            "linear_speedup_w3".to_string(),
            Json::Num(p.model_ns[0] / p.model_ns[2]),
        );
    }
    headline.insert(
        "crossover_wy0".to_string(),
        Json::Num(sweep.crossover_model as f64),
    );

    let points = sweep
        .points
        .iter()
        .map(|p| {
            let mut o = BTreeMap::new();
            o.insert("window".to_string(), Json::Num(p.window as f64));
            for (i, series) in super::fig3::SERIES.iter().enumerate() {
                o.insert(format!("{series}_model_ns"), Json::Num(p.model_ns[i]));
            }
            Json::Obj(o)
        })
        .collect();

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("fig3".to_string()));
    root.insert(
        "workload".to_string(),
        Json::Str("horizontal erosion on 800x600 u8".to_string()),
    );
    root.insert("headline".to_string(), Json::Obj(headline));
    root.insert("points".to_string(), Json::Arr(points));
    Json::Obj(root)
}

/// Machine-readable form of the u16 Fig-3 sweep (`BENCH_fig3_u16.json`):
/// the same headline ratios as [`fig3_json`] measured on the 800×600
/// **u16** workload (8 SIMD lanes/op, 2× streamed bytes) — the ROADMAP
/// "perf-gate breadth, u16" item.  The ratio headlines are gated ±10%
/// against `rust/benches/baselines/BENCH_fig3_u16.json`; the discrete
/// smoke-grid crossover is reported as an **informational** top-level
/// field only (same cliff rationale as Fig 4's `crossover_wx0_info`).
pub fn fig3u16_json(sweep: &super::fig3::Sweep) -> Json {
    let at = |w: usize| sweep.points.iter().find(|p| p.window == w);
    let mut headline = BTreeMap::new();
    if let Some(p) = at(31) {
        headline.insert(
            "vhgw_simd_speedup_w31".to_string(),
            Json::Num(p.model_ns[0] / p.model_ns[1]),
        );
    }
    if let Some(p) = at(3) {
        headline.insert(
            "linear_speedup_w3".to_string(),
            Json::Num(p.model_ns[0] / p.model_ns[2]),
        );
    }
    if let (Some(p31), Some(p61)) = (at(31), at(61)) {
        // continuous anchors of the u16 series shapes: linear grows with
        // w, vHGW stays ~flat — gated without a discrete crossover cliff
        headline.insert(
            "linear_w61_over_w31".to_string(),
            Json::Num(p61.model_ns[2] / p31.model_ns[2]),
        );
        headline.insert(
            "vhgw_simd_w61_over_w31".to_string(),
            Json::Num(p61.model_ns[1] / p31.model_ns[1]),
        );
    }

    let points = sweep
        .points
        .iter()
        .map(|p| {
            let mut o = BTreeMap::new();
            o.insert("window".to_string(), Json::Num(p.window as f64));
            for (i, series) in super::fig3::SERIES.iter().enumerate() {
                o.insert(format!("{series}_model_ns"), Json::Num(p.model_ns[i]));
            }
            Json::Obj(o)
        })
        .collect();

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("fig3u16".to_string()));
    root.insert(
        "workload".to_string(),
        Json::Str("horizontal erosion on 800x600 u16".to_string()),
    );
    root.insert("headline".to_string(), Json::Obj(headline));
    // informational only: the u16 crossover sits on the same sparse grid
    root.insert(
        "crossover_wy0_info".to_string(),
        Json::Num(sweep.crossover_model as f64),
    );
    root.insert("points".to_string(), Json::Arr(points));
    Json::Obj(root)
}

/// Machine-readable form of a Fig-4 sweep (`BENCH_fig4.json`): the
/// vertical-pass headline ratios — scalar vHGW over the §5.2.1
/// transpose sandwich at w = 31, scalar vHGW over §5.2.2 direct linear
/// at w = 3, and the *continuous* linear-vs-sandwich ratio at w = 61 —
/// plus the model series per window.  Gated like Fig 3 (±10% vs
/// `rust/benches/baselines/BENCH_fig4.json`).
///
/// The sparse-grid crossover `w_x⁰` is reported as an **informational**
/// top-level field, deliberately outside the gated `headline`: on the
/// smoke grid the w = 61 linear/sandwich margin is only ~1%, so the
/// step-function crossover could flip 31 → 61 on a legitimately tiny
/// count change — the smooth w = 61 ratio gates the same property
/// without that cliff.
pub fn fig4_json(sweep: &super::fig3::Sweep) -> Json {
    let at = |w: usize| sweep.points.iter().find(|p| p.window == w);
    let mut headline = BTreeMap::new();
    if let Some(p) = at(31) {
        headline.insert(
            "vhgw_sandwich_speedup_w31".to_string(),
            Json::Num(p.model_ns[0] / p.model_ns[1]),
        );
    }
    if let Some(p) = at(3) {
        headline.insert(
            "linear_speedup_w3".to_string(),
            Json::Num(p.model_ns[0] / p.model_ns[2]),
        );
    }
    if let Some(p) = at(61) {
        headline.insert(
            "linear_vs_sandwich_w61".to_string(),
            Json::Num(p.model_ns[2] / p.model_ns[1]),
        );
    }

    let points = sweep
        .points
        .iter()
        .map(|p| {
            let mut o = BTreeMap::new();
            o.insert("window".to_string(), Json::Num(p.window as f64));
            for (i, series) in super::fig4::SERIES.iter().enumerate() {
                o.insert(format!("{series}_model_ns"), Json::Num(p.model_ns[i]));
            }
            Json::Obj(o)
        })
        .collect();

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("fig4".to_string()));
    root.insert(
        "workload".to_string(),
        Json::Str("vertical erosion on 800x600 u8".to_string()),
    );
    root.insert("headline".to_string(), Json::Obj(headline));
    // informational only (never in the committed/gated baseline subset):
    // the discrete smoke-grid crossover sits on a ~1% margin at w = 61
    root.insert(
        "crossover_wx0_info".to_string(),
        Json::Num(sweep.crossover_model as f64),
    );
    root.insert("points".to_string(), Json::Arr(points));
    Json::Obj(root)
}

/// Machine-readable form of the deterministic Table 1 rows
/// (`BENCH_table1.json`): scalar/SIMD model prices and ratios of the §4
/// tile transposes.  Gated ±10% vs
/// `rust/benches/baselines/BENCH_table1.json`.
pub fn table1_json(rows: &[super::table1::Row]) -> Json {
    let mut headline = BTreeMap::new();
    for r in rows {
        headline.insert(format!("scalar_ns_{}", r.case), Json::Num(r.model_scalar_ns));
        headline.insert(format!("simd_ns_{}", r.case), Json::Num(r.model_simd_ns));
        headline.insert(format!("ratio_{}", r.case), Json::Num(r.model_ratio()));
    }
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("table1".to_string()));
    root.insert(
        "workload".to_string(),
        Json::Str("tile transpose 8x8.16 / 16x16.8".to_string()),
    );
    root.insert("headline".to_string(), Json::Obj(headline));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_json_matches_committed_baseline_shape() {
        // exact values the python mirror bakes into the committed
        // baseline: scalar 8x8 = 64 ld + 64 st at 1.8 cyc / 2 GHz
        let rows = super::super::table1::run_model(&CostModel::exynos5422());
        let j = table1_json(&rows);
        let h = j.get("headline").unwrap();
        let near = |k: &str, v: f64| {
            let got = h.get(k).unwrap().as_f64().unwrap();
            assert!((got - v).abs() < 1e-9, "{k}: {got} != {v}");
        };
        near("scalar_ns_8x8", 115.2);
        near("simd_ns_8x8", 18.4);
        near("scalar_ns_16x16", 460.8);
        near("simd_ns_16x16", 40.8);
        assert_eq!(j.get("bench").unwrap().as_str(), Some("table1"));
    }

    #[test]
    fn fig4_json_has_gated_headline() {
        if cfg!(debug_assertions) {
            eprintln!("SKIP in debug: 800x600 fig4 counting sweep (runs under --release / make test)");
            return;
        }
        let model = CostModel::exynos5422();
        let s = super::super::fig4::run(&model, &SMOKE_WINDOWS, 0);
        let j = fig4_json(&s);
        let h = j.get("headline").unwrap();
        assert!(h.get("vhgw_sandwich_speedup_w31").unwrap().as_f64().unwrap() > 1.0);
        assert!(h.get("linear_speedup_w3").unwrap().as_f64().unwrap() > 3.0);
        // the continuous near-crossover ratio is gated; the discrete
        // crossover is informational only (outside `headline`)
        assert!(h.get("linear_vs_sandwich_w61").unwrap().as_f64().unwrap() > 0.5);
        assert!(h.get("crossover_wx0").is_none(), "crossover must not be gated");
        assert!(j.get("crossover_wx0_info").unwrap().as_f64().unwrap() >= 3.0);
        let again = crate::util::json::parse(&crate::util::json::write(&j)).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn fig3u16_json_has_gated_headline() {
        if cfg!(debug_assertions) {
            eprintln!("SKIP in debug: 800x600 u16 counting sweep (runs under --release / make test)");
            return;
        }
        let model = CostModel::exynos5422();
        let s = super::super::fig3::run_u16(&model, &SMOKE_WINDOWS, 0);
        let j = fig3u16_json(&s);
        let h = j.get("headline").unwrap();
        assert!(h.get("vhgw_simd_speedup_w31").unwrap().as_f64().unwrap() > 1.0);
        assert!(h.get("linear_speedup_w3").unwrap().as_f64().unwrap() > 2.0);
        // linear grows with w, vHGW stays ~flat — the gated series shapes
        assert!(h.get("linear_w61_over_w31").unwrap().as_f64().unwrap() > 1.3);
        assert!(h.get("vhgw_simd_w61_over_w31").unwrap().as_f64().unwrap() < 1.3);
        assert!(h.get("crossover_wy0").is_none(), "crossover must not be gated");
        assert!(j.get("crossover_wy0_info").unwrap().as_f64().unwrap() >= 3.0);
        assert_eq!(j.get("bench").unwrap().as_str(), Some("fig3u16"));
        let again = crate::util::json::parse(&crate::util::json::write(&j)).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn scaling_sweep_grows_then_saturates() {
        if cfg!(debug_assertions) {
            eprintln!("SKIP in debug: scaling counting sweep (runs under --release / make test)");
            return;
        }
        let model = CostModel::exynos5422();
        let s = run(&model, 600, 800, SCALING_WINDOW, 16, 0);
        assert_eq!(s.points.len(), 16);
        assert!((s.speedup_at(1) - 1.0).abs() < 1e-12);
        // speedup grows with workers up to the saturation point…
        for w in s.points.windows(2) {
            if w[1].workers <= s.saturation {
                assert!(w[1].speedup > w[0].speedup, "p={}", w[1].workers);
            }
        }
        // …and never exceeds the memory-bandwidth ceiling
        for p in &s.points {
            assert!(p.speedup < s.ceiling, "p={} exceeds ceiling", p.workers);
        }
        assert!((2..=16).contains(&s.saturation), "saturation {}", s.saturation);
    }

    #[test]
    fn json_has_gated_headline() {
        if cfg!(debug_assertions) {
            eprintln!("SKIP in debug: scaling counting sweep (runs under --release / make test)");
            return;
        }
        let model = CostModel::exynos5422();
        let s = run(&model, 600, 800, SCALING_WINDOW, 8, 0);
        let j = to_json(&s);
        let h = j.get("headline").unwrap();
        assert!(h.get("saturation_workers").unwrap().as_f64().unwrap() >= 1.0);
        assert!(h.get("speedup_at_4").unwrap().as_f64().unwrap() > 1.0);
        // the serial-transpose ceiling must sit strictly below the
        // banded-transpose ceiling, and the lift headline is their ratio
        let ceiling = h.get("ceiling").unwrap().as_f64().unwrap();
        let serial = h.get("ceiling_serial_transpose").unwrap().as_f64().unwrap();
        let lift = h.get("transpose_ceiling_lift").unwrap().as_f64().unwrap();
        assert!(serial < ceiling, "serial {serial} !< banded {ceiling}");
        assert!(lift > 1.0 && (lift - ceiling / serial).abs() < 1e-12);
        // round-trips through the serializer
        let again = crate::util::json::parse(&crate::util::json::write(&j)).unwrap();
        assert_eq!(j, again);
    }
}
