//! Figure 3: horizontal-pass erosion time vs `w_y` (800×600).
//!
//! Series, exactly the paper's: van Herk/Gil-Werman without SIMD,
//! vHGW with SIMD, linear with SIMD, and the §5.3 hybrid.  The paper's
//! observations to reproduce: SIMD speeds vHGW up >3×; linear at
//! `w_y = 3` is ~14× over scalar vHGW; the linear/vHGW+SIMD crossover
//! sits at `w_y⁰ = 69`.
//!
//! The sweep machinery is generic over the pixel depth: [`run`] prices
//! the paper's u8 workload, [`run_u16`] the same-shape u16 workload
//! (8 SIMD lanes/op instead of 16, 2× the streamed bytes — the series
//! shapes persist, the absolute prices roughly double).

use crate::costmodel::CostModel;
use crate::image::{synth, Image};
use crate::morphology::{linear, vhgw, MorphOp, MorphPixel};
use crate::neon::{Backend, Counting, Native};
use crate::util::timing;

use super::report::Table;

pub const SERIES: [&str; 4] = ["vhgw", "vhgw_simd", "linear_simd", "hybrid"];

/// One sweep point: per-series times in ns.
#[derive(Clone, Debug)]
pub struct Point {
    pub window: usize,
    pub model_ns: [f64; 4],
    pub host_ns: [f64; 4],
}

/// Sweep result with derived crossovers.
#[derive(Clone, Debug)]
pub struct Sweep {
    pub points: Vec<Point>,
    /// Largest window where linear_simd <= vhgw_simd (cost model).
    pub crossover_model: usize,
    /// Same, from host wall-clock.
    pub crossover_host: usize,
}

fn pass<P: MorphPixel, B: Backend>(
    b: &mut B,
    img: &Image<P>,
    window: usize,
    series: usize,
) -> Image<P> {
    match series {
        0 => vhgw::rows_scalar_vhgw(b, img, window, MorphOp::Erode),
        1 => vhgw::rows_simd_vhgw(b, img, window, MorphOp::Erode),
        2 => linear::rows_simd_linear(b, img, window, MorphOp::Erode),
        _ => unreachable!(),
    }
}

pub(super) fn sweep_generic<P: MorphPixel>(
    model: &CostModel,
    img: &Image<P>,
    windows: &[usize],
    host_iters: usize,
    threshold: usize,
    run_pass: impl PassRunner<P>,
) -> Sweep {
    let mut points = Vec::new();
    for &w in windows {
        let mut model_ns = [0.0f64; 4];
        let mut host_ns = [0.0f64; 4];
        for s in 0..3 {
            let mut c = Counting::new();
            let out = run_pass.run_counting(&mut c, img, w, s);
            std::hint::black_box(out);
            model_ns[s] = model.price_ns(&c.mix);
            // host_iters == 0 skips wall-clocking entirely (the
            // deterministic `bench smoke` sweep reads only model_ns)
            host_ns[s] = if host_iters == 0 {
                0.0
            } else {
                timing::bench(1, host_iters, || run_pass.run_native(&mut Native, img, w, s)).min_ns
            };
        }
        // hybrid: the §5.3 dispatch — linear below threshold, vHGW above
        let pick = if w <= threshold { 2 } else { 1 };
        model_ns[3] = model_ns[pick];
        host_ns[3] = host_ns[pick];
        points.push(Point {
            window: w,
            model_ns,
            host_ns,
        });
    }
    let crossover = |get: &dyn Fn(&Point) -> (f64, f64)| {
        points
            .iter()
            .filter(|p| {
                let (lin, vh) = get(p);
                lin <= vh
            })
            .map(|p| p.window)
            .max()
            .unwrap_or(1)
    };
    Sweep {
        crossover_model: crossover(&|p: &Point| (p.model_ns[2], p.model_ns[1])),
        // 0 = "not measured" — with host timing skipped the all-zero
        // series would otherwise report the largest window as a crossover
        crossover_host: if host_iters == 0 {
            0
        } else {
            crossover(&|p: &Point| (p.host_ns[2], p.host_ns[1]))
        },
        points,
    }
}

/// Trait gluing the counting/native runs of one figure's pass set at one
/// pixel depth.
pub trait PassRunner<P: MorphPixel> {
    fn run_counting(&self, b: &mut Counting, img: &Image<P>, w: usize, series: usize)
        -> Image<P>;
    fn run_native(&self, b: &mut Native, img: &Image<P>, w: usize, series: usize) -> Image<P>;
}

struct RowsRunner;

impl<P: MorphPixel> PassRunner<P> for RowsRunner {
    fn run_counting(
        &self,
        b: &mut Counting,
        img: &Image<P>,
        w: usize,
        series: usize,
    ) -> Image<P> {
        pass(b, img, w, series)
    }

    fn run_native(&self, b: &mut Native, img: &Image<P>, w: usize, series: usize) -> Image<P> {
        pass(b, img, w, series)
    }
}

/// Run the Fig. 3 sweep on the paper's u8 workload.
pub fn run(model: &CostModel, windows: &[usize], host_iters: usize) -> Sweep {
    let img = synth::paper_image(0xF16);
    sweep_generic(
        model,
        &img,
        windows,
        host_iters,
        crate::morphology::PAPER_WY0,
        RowsRunner,
    )
}

/// Run the Fig. 3 sweep on the same-shape u16 workload (the §4 8×8.16
/// scenario): 8 lanes per vector op, 2× streamed bytes.
pub fn run_u16(model: &CostModel, windows: &[usize], host_iters: usize) -> Sweep {
    let img = synth::paper_image_u16(0xF16);
    sweep_generic(
        model,
        &img,
        windows,
        host_iters,
        crate::morphology::PAPER_WY0,
        RowsRunner,
    )
}

/// Render a sweep as a table (`mode` = "model" or "host").
pub fn render(title: &str, sweep: &Sweep, mode: &str) -> Table {
    let mut t = Table::new(
        title,
        &["w", "vhgw_ns", "vhgw_simd_ns", "linear_simd_ns", "hybrid_ns"],
    );
    for p in &sweep.points {
        let v = if mode == "host" { &p.host_ns } else { &p.model_ns };
        t.row(vec![
            p.window.to_string(),
            format!("{:.0}", v[0]),
            format!("{:.0}", v[1]),
            format!("{:.0}", v[2]),
            format!("{:.0}", v[3]),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shapes_match_paper() {
        if cfg!(debug_assertions) {
            eprintln!("SKIP in debug: 800x600 instruction-counting sweep (runs under --release / make test)");
            return;
        }
        let model = CostModel::exynos5422();
        let s = run(&model, &[3, 31, 61, 91, 121], 1);
        let at = |w: usize| s.points.iter().find(|p| p.window == w).unwrap();

        // SIMD speeds up vHGW substantially (paper: >3x)
        let p = at(31);
        let simd_speedup = p.model_ns[0] / p.model_ns[1];
        assert!(simd_speedup > 2.5, "vhgw simd speedup {simd_speedup}");

        // linear at w=3 crushes scalar vHGW (paper: 14x)
        let p3 = at(3);
        let lin_speedup = p3.model_ns[0] / p3.model_ns[2];
        assert!(lin_speedup > 6.0, "linear w=3 speedup {lin_speedup}");

        // crossover exists and is in the paper's neighborhood
        assert!(
            (45..=95).contains(&s.crossover_model),
            "crossover {} (paper 69)",
            s.crossover_model
        );

        // hybrid is the min of the two SIMD series everywhere
        for p in &s.points {
            assert!(p.model_ns[3] <= p.model_ns[1] * 1.001);
            if p.window <= 61 {
                assert!((p.model_ns[3] - p.model_ns[2]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn fig3_u16_sweep_shapes() {
        if cfg!(debug_assertions) {
            eprintln!("SKIP in debug: 800x600 u16 counting sweep (runs under --release / make test)");
            return;
        }
        let model = CostModel::exynos5422();
        let s16 = run_u16(&model, &[3, 31, 91], 1);
        let s8 = run(&model, &[3, 31, 91], 1);
        for (p16, p8) in s16.points.iter().zip(&s8.points) {
            // SIMD series (1 = vhgw_simd, 2 = linear_simd) halve their
            // lanes at u16, so they price ~2x; the scalar series (0)
            // issues identical instruction counts — only its streamed
            // bytes double, so it lands well below 1.5x
            for series in 1..3 {
                let r = p16.model_ns[series] / p8.model_ns[series];
                assert!(
                    (1.5..=2.5).contains(&r),
                    "w={} series {}: u16/u8 ratio {r}",
                    p16.window,
                    series
                );
            }
            let r0 = p16.model_ns[0] / p8.model_ns[0];
            assert!(
                (1.0..1.5).contains(&r0),
                "w={} scalar series: only memory doubles, ratio {r0}",
                p16.window
            );
        }
        let lin3 = s16.points[0].model_ns[2];
        let lin31 = s16.points[1].model_ns[2];
        assert!(lin31 > 1.4 * lin3, "u16 linear should scale with w");
    }
}
