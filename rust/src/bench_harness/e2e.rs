//! Headline (conclusion) experiment: the final §5.3 hybrid erosion /
//! dilation is ≥3× faster than the vHGW implementation without SIMD,
//! end to end on the 800×600 workload — plus a coordinator-level
//! serving benchmark (throughput / latency through the full L3 path).

use std::sync::Arc;

use crate::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use crate::costmodel::CostModel;
use crate::image::synth;
use crate::morphology::{
    self, Border, HybridThresholds, MorphConfig, MorphOp, Parallelism, PassMethod, Representation,
    VerticalStrategy,
};
use crate::neon::{Counting, Native};
use crate::util::timing;

use super::report::Table;

/// End-to-end 2-D erosion comparison.
#[derive(Clone, Debug)]
pub struct E2eResult {
    pub w: usize,
    pub baseline_model_ns: f64,
    pub hybrid_model_ns: f64,
    pub baseline_host_ns: f64,
    pub hybrid_host_ns: f64,
}

impl E2eResult {
    pub fn model_speedup(&self) -> f64 {
        self.baseline_model_ns / self.hybrid_model_ns
    }

    pub fn host_speedup(&self) -> f64 {
        self.baseline_host_ns / self.hybrid_host_ns
    }
}

fn cfg_baseline() -> MorphConfig {
    MorphConfig {
        method: PassMethod::Vhgw,
        vertical: VerticalStrategy::Transpose,
        simd: false,
        border: Border::Identity,
        thresholds: HybridThresholds::paper(),
        parallelism: Parallelism::Sequential,
        representation: Representation::Dense,
    }
}

/// Run full 2-D erosion (square `w × w` SE) both ways for each window.
pub fn run(model: &CostModel, windows: &[usize], host_iters: usize) -> Vec<E2eResult> {
    let img = synth::paper_image(0xE2E);
    let base_cfg = cfg_baseline();
    let hybrid_cfg = MorphConfig::default();

    windows
        .iter()
        .map(|&w| {
            let mut c = Counting::new();
            let _ = morphology::morphology(&mut c, &img, MorphOp::Erode, w, w, &base_cfg);
            let baseline_model_ns = model.price_ns(&c.mix);
            let mut c = Counting::new();
            let _ = morphology::morphology(&mut c, &img, MorphOp::Erode, w, w, &hybrid_cfg);
            let hybrid_model_ns = model.price_ns(&c.mix);

            let baseline_host_ns = timing::bench(1, host_iters, || {
                morphology::morphology(&mut Native, &img, MorphOp::Erode, w, w, &base_cfg)
            })
            .min_ns;
            let hybrid_host_ns = timing::bench(1, host_iters, || {
                morphology::morphology(&mut Native, &img, MorphOp::Erode, w, w, &hybrid_cfg)
            })
            .min_ns;
            E2eResult {
                w,
                baseline_model_ns,
                hybrid_model_ns,
                baseline_host_ns,
                hybrid_host_ns,
            }
        })
        .collect()
}

pub fn render(results: &[E2eResult]) -> Table {
    let mut t = Table::new(
        "Headline — 2-D erosion w×w: vHGW-no-SIMD baseline vs §5.3 hybrid (paper claim: ≥3×)",
        &[
            "w", "model baseline", "model hybrid", "model x", "host baseline",
            "host hybrid", "host x",
        ],
    );
    for r in results {
        t.row(vec![
            r.w.to_string(),
            format!("{:.0}", r.baseline_model_ns),
            format!("{:.0}", r.hybrid_model_ns),
            format!("{:.1}x", r.model_speedup()),
            format!("{:.0}", r.baseline_host_ns),
            format!("{:.0}", r.hybrid_host_ns),
            format!("{:.1}x", r.host_speedup()),
        ]);
    }
    t
}

/// Serving benchmark result.
#[derive(Clone, Copy, Debug)]
pub struct ServeResult {
    pub requests: u64,
    pub workers: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub mean_batch: f64,
    pub shed: u64,
    /// Fresh plan resolutions across all workers (cache misses).
    pub plan_resolutions: u64,
    /// Plan-cache hits across all workers.
    pub plan_hits: u64,
    /// Per-stage depth high-water marks
    /// (ingress/resolve/execute/reply).
    pub stage_peak: [u64; crate::coordinator::metrics::PIPELINE_STAGES],
}

impl ServeResult {
    /// Resolutions per completed request — the streaming headline.
    pub fn plan_resolutions_per_request(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.plan_resolutions as f64 / self.requests as f64
        }
    }
}

/// Drive the coordinator with `requests` mixed requests on the paper
/// workload through the **streaming** submit path
/// ([`Coordinator::stream`]) and report throughput, latency percentiles
/// and plan-cache traffic.
pub fn serve_native(requests: usize, workers: usize, w: usize) -> anyhow::Result<ServeResult> {
    let coord = Coordinator::start(CoordinatorConfig {
        workers,
        queue_capacity: requests + 8,
        max_batch: 16,
        backend: BackendChoice::NativeOnly,
        artifact_dir: None,
        ..CoordinatorConfig::default()
    })?;
    let img = Arc::new(synth::paper_image(0x5E57E));
    let ops = [
        crate::morphology::FilterOp::Erode,
        crate::morphology::FilterOp::Dilate,
        crate::morphology::FilterOp::Gradient,
    ];
    let t0 = std::time::Instant::now();
    let mut stream = coord.stream();
    for i in 0..requests {
        stream.send(
            crate::morphology::FilterSpec::new(ops[i % ops.len()], w, w),
            img.clone(),
        )?;
    }
    while let Some(resp) = stream.recv() {
        resp.result?;
    }
    drop(stream); // release the coordinator borrow before shutdown
    let wall_s = t0.elapsed().as_secs_f64();
    let snap = coord.metrics();
    let out = ServeResult {
        requests: snap.completed,
        workers,
        wall_s,
        throughput_rps: snap.completed as f64 / wall_s,
        p50_us: snap.total_p50_us,
        p99_us: snap.total_p99_us,
        mean_batch: snap.mean_batch_size(),
        shed: snap.shed,
        plan_resolutions: snap.plan_resolutions,
        plan_hits: snap.plan_hits,
        stage_peak: snap.stage_peak,
    };
    coord.shutdown();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_speedup_holds() {
        if cfg!(debug_assertions) {
            eprintln!("SKIP in debug: 800x600 2-D counting runs (runs under --release / make test)");
            return;
        }
        // the conclusion's claim: final implementation ≥3× over
        // vHGW-without-SIMD — checked on the cost model at mid windows
        let model = CostModel::exynos5422();
        let results = run(&model, &[7, 15], 1);
        for r in &results {
            assert!(
                r.model_speedup() > 3.0,
                "w={} model speedup {:.2} (paper: >=3x)",
                r.w,
                r.model_speedup()
            );
        }
    }

    #[test]
    fn serving_completes_all() {
        let n = if cfg!(debug_assertions) { 6 } else { 24 };
        let s = serve_native(n, 3, 5).unwrap();
        assert_eq!(s.requests, n as u64);
        assert_eq!(s.shed, 0);
        assert!(s.throughput_rps > 0.0);
        assert!(s.p50_us <= s.p99_us);
    }
}
