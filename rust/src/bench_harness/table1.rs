//! Table 1: execution time of matrix transpose, scalar vs NEON.
//!
//! Paper values (Samsung Exynos 5422): 8×8.16 — 114 ns scalar, 20 ns
//! SIMD (5.7×); 16×16.8 — 565 ns scalar, 47 ns SIMD (12×).

use crate::costmodel::CostModel;
use crate::neon::{Counting, Native};
use crate::transpose;
use crate::util::timing;

use super::report::Table;

/// One Table 1 row.
#[derive(Clone, Debug)]
pub struct Row {
    pub case: &'static str,
    pub dtype: &'static str,
    /// Paper's measured numbers (ns).
    pub paper_scalar_ns: f64,
    pub paper_simd_ns: f64,
    /// Cost-model prices of our counted instruction mixes (ns).
    pub model_scalar_ns: f64,
    pub model_simd_ns: f64,
    /// Wall-clock on this host (ns / call, batched).
    pub host_scalar_ns: f64,
    pub host_simd_ns: f64,
}

impl Row {
    pub fn paper_ratio(&self) -> f64 {
        self.paper_scalar_ns / self.paper_simd_ns
    }

    pub fn model_ratio(&self) -> f64 {
        self.model_scalar_ns / self.model_simd_ns
    }

    pub fn host_ratio(&self) -> f64 {
        self.host_scalar_ns / self.host_simd_ns
    }
}

/// Measure both Table 1 cases.
pub fn run(model: &CostModel) -> Vec<Row> {
    // --- 8x8 u16 ---
    let src16: Vec<u16> = (0..64).map(|i| (i * 2654435761u64 % 65536) as u16).collect();
    let mut dst16 = vec![0u16; 64];

    let mut c = Counting::new();
    transpose::transpose8x8_u16_scalar(&mut c, &src16, &mut dst16);
    let m_scalar_8 = model.price_ns_marginal(&c.mix);
    let mut c = Counting::new();
    transpose::transpose8x8_u16(&mut c, &src16, &mut dst16);
    let m_simd_8 = model.price_ns_marginal(&c.mix);

    let h_scalar_8 = timing::bench_batched(3, 15, 20_000, || {
        let mut d = [0u16; 64];
        transpose::transpose8x8_u16_scalar(&mut Native, &src16, &mut d);
        d[63]
    })
    .p50_ns;
    let h_simd_8 = timing::bench_batched(3, 15, 20_000, || {
        let mut d = [0u16; 64];
        transpose::transpose8x8_u16(&mut Native, &src16, &mut d);
        d[63]
    })
    .p50_ns;

    // --- 16x16 u8 ---
    let src8: Vec<u8> = (0..256).map(|i| (i * 37 % 251) as u8).collect();
    let mut dst8 = vec![0u8; 256];

    let mut c = Counting::new();
    transpose::transpose16x16_u8_scalar(&mut c, &src8, &mut dst8);
    let m_scalar_16 = model.price_ns_marginal(&c.mix);
    let mut c = Counting::new();
    transpose::transpose16x16_u8(&mut c, &src8, &mut dst8);
    let m_simd_16 = model.price_ns_marginal(&c.mix);

    let h_scalar_16 = timing::bench_batched(3, 15, 10_000, || {
        let mut d = [0u8; 256];
        transpose::transpose16x16_u8_scalar(&mut Native, &src8, &mut d);
        d[255]
    })
    .p50_ns;
    let h_simd_16 = timing::bench_batched(3, 15, 10_000, || {
        let mut d = [0u8; 256];
        transpose::transpose16x16_u8(&mut Native, &src8, &mut d);
        d[255]
    })
    .p50_ns;

    vec![
        Row {
            case: "8x8",
            dtype: "16-bit unsigned int",
            paper_scalar_ns: 114.0,
            paper_simd_ns: 20.0,
            model_scalar_ns: m_scalar_8,
            model_simd_ns: m_simd_8,
            host_scalar_ns: h_scalar_8,
            host_simd_ns: h_simd_8,
        },
        Row {
            case: "16x16",
            dtype: "8-bit unsigned int",
            paper_scalar_ns: 565.0,
            paper_simd_ns: 47.0,
            model_scalar_ns: m_scalar_16,
            model_simd_ns: m_simd_16,
            host_scalar_ns: h_scalar_16,
            host_simd_ns: h_simd_16,
        },
    ]
}

/// Render the rows as the paper's table plus our two measurement modes.
pub fn render(rows: &[Row]) -> Table {
    let mut t = Table::new(
        "Table 1 — matrix transpose execution time (paper: Exynos 5422)",
        &[
            "Matrix", "Data type", "paper scalar", "paper SIMD", "paper x",
            "model scalar", "model SIMD", "model x", "host scalar", "host SIMD",
            "host x",
        ],
    );
    for r in rows {
        t.row(vec![
            r.case.to_string(),
            r.dtype.to_string(),
            format!("{:.0} ns", r.paper_scalar_ns),
            format!("{:.0} ns", r.paper_simd_ns),
            format!("{:.1}x", r.paper_ratio()),
            format!("{:.0} ns", r.model_scalar_ns),
            format!("{:.0} ns", r.model_simd_ns),
            format!("{:.1}x", r.model_ratio()),
            format!("{:.0} ns", r.host_scalar_ns),
            format!("{:.0} ns", r.host_simd_ns),
            format!("{:.1}x", r.host_ratio()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_reproduces_paper_ratios() {
        if cfg!(debug_assertions) {
            eprintln!("SKIP in debug: batched host timing (runs under --release / make test)");
            return;
        }
        let rows = run(&CostModel::exynos5422());
        let r8 = &rows[0];
        // paper: 5.7x — model must land within ±35%
        assert!(
            (r8.model_ratio() / r8.paper_ratio() - 1.0).abs() < 0.35,
            "8x8 ratio {} vs paper {}",
            r8.model_ratio(),
            r8.paper_ratio()
        );
        let r16 = &rows[1];
        assert!(
            (r16.model_ratio() / r16.paper_ratio() - 1.0).abs() < 0.35,
            "16x16 ratio {} vs paper {}",
            r16.model_ratio(),
            r16.paper_ratio()
        );
        // absolute scale: within 2x of the paper's nanoseconds
        for r in &rows {
            assert!(r.model_scalar_ns > r.paper_scalar_ns / 2.0);
            assert!(r.model_scalar_ns < r.paper_scalar_ns * 2.0);
            assert!(r.model_simd_ns > r.paper_simd_ns / 2.0);
            assert!(r.model_simd_ns < r.paper_simd_ns * 2.0);
        }
    }

    #[test]
    fn render_has_both_rows() {
        let rows = run(&CostModel::exynos5422());
        let md = render(&rows).to_markdown();
        assert!(md.contains("8x8"));
        assert!(md.contains("16x16"));
    }
}
