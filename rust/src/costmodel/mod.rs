//! Exynos-5422-like cost model: price an [`InstrMix`] in nanoseconds.
//!
//! We have no ARM silicon here, so the paper's absolute timings are
//! reproduced through a calibrated analytical model (see DESIGN.md
//! §Substitutions):
//!
//! ```text
//! time_ns = max-free sum of
//!   compute_ns = Σ_class count(class) · cycles(class) / freq_ghz
//!   memory_ns  = stream_bytes / (bw_bytes_per_cycle · freq_ghz)
//!   overhead_ns (fixed per-call cost: function entry, edge handling)
//! time = compute + memory + overhead       (in-order A15-like: additive)
//! ```
//!
//! The per-class cycle costs are *calibrated* against the paper's own
//! anchors rather than invented: Table 1 (scalar/SIMD transpose times),
//! the Fig. 3/Fig. 4 headline ratios (vHGW+SIMD ≈ 3× over scalar vHGW;
//! linear 14×/11× at w = 3) and the measured crossovers (w_y⁰ = 69,
//! w_x⁰ = 59).  [`calibrate`] re-derives the constants from those
//! anchors; [`CostModel::exynos5422`] ships the baked result so the
//! benches are deterministic.

use crate::neon::{InstrClass, InstrMix};

/// SIMD lane count of one 128-bit vector op at a given pixel dtype:
/// `u8` ops process 16 lanes, `u16` ops 8 (the §4 tile shapes 16×16.8
/// and 8×8.16).  A u16 pass therefore needs ~2× the vector instructions
/// and streams 2× the bytes per pixel — the counted mixes already
/// reflect this, so the same per-instruction-class prices stay honest
/// across depths (asserted in `rust/tests/counting_u16.rs`).
pub fn simd_lanes(dtype: &str) -> Option<usize> {
    use crate::morphology::MorphPixel;
    if dtype == <u8 as MorphPixel>::DTYPE {
        Some(<u8 as MorphPixel>::LANES)
    } else if dtype == <u16 as MorphPixel>::DTYPE {
        Some(<u16 as MorphPixel>::LANES)
    } else {
        None
    }
}

/// Marginal speedup below which adding one more band is considered
/// saturated (see [`CostModel::saturation_workers`]).
pub const SATURATION_EPSILON: f64 = 0.05;

/// Per-instruction-class cycle costs + memory system parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    /// Core clock in GHz (Exynos 5422 Cortex-A15 cluster: 2.0 GHz).
    pub freq_ghz: f64,
    /// Issue cost in cycles per instruction class (same order as
    /// [`InstrClass::ALL`]).
    pub cycles: [f64; 11],
    /// Sustained DRAM streaming bandwidth in bytes per core cycle
    /// (LPDDR3-933 single-core streaming on the 5422 is ~2-3 GB/s;
    /// calibrated at 1.1 B/cycle = 2.2 GB/s).
    pub bw_bytes_per_cycle: f64,
    /// Fixed overhead per priced call, ns (entry/exit, edge rows).
    pub call_overhead_ns: f64,
    /// Fixed cost of one band-parallel dispatch, ns (waking the shared
    /// worker pool + the fork-join latch round trip).
    pub fork_ns: f64,
    /// Per-band overhead, ns: job boxing, channel send, completion-latch
    /// countdown and view-split bookkeeping — **nothing else**.  Band
    /// jobs are zero-copy (borrowed haloed reads, disjoint in-place
    /// writes through `split_at_rows_mut`), so no staging traffic hides
    /// in this constant.  Re-derived for the view-based executor: the
    /// pre-view value (4 µs/band) was a fudge that also absorbed the
    /// haloed-slab copy-in + core-row copy-out the PR-2 executor
    /// performed per band; with those copies deleted, what remains is a
    /// `Box::new` + `mpsc` send + `Condvar` latch hit, ~1.2 µs on the
    /// modeled A15-class core.
    pub band_overhead_ns: f64,
}

/// Itemized price of a mix — useful in reports and for perf analysis.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostBreakdown {
    pub compute_ns: f64,
    pub memory_ns: f64,
    pub overhead_ns: f64,
}

impl CostBreakdown {
    pub fn total_ns(&self) -> f64 {
        self.compute_ns + self.memory_ns + self.overhead_ns
    }
}

impl CostModel {
    /// Baked Exynos 5422 calibration (see module docs and
    /// EXPERIMENTS.md §T1 for the anchor-by-anchor comparison).
    pub fn exynos5422() -> Self {
        let mut cycles = [0.0f64; 11];
        // SIMD pipeline: NEON on the A15 dual-issues simple ops; loads
        // ~1 cycle throughput, unaligned crossing loads pay ~1.4x.
        cycles[InstrClass::SimdLoad as usize] = 1.1;
        cycles[InstrClass::SimdLoadUnaligned as usize] = 1.58;
        cycles[InstrClass::SimdStore as usize] = 1.0;
        cycles[InstrClass::SimdMinMax as usize] = 0.62;
        cycles[InstrClass::SimdPermute as usize] = 1.0;
        cycles[InstrClass::SimdCombine as usize] = 0.5;
        cycles[InstrClass::SimdReinterpret as usize] = 0.0; // §4: free
        // Scalar side: in-order pipe, L1-hit loads ~1.8 cycles effective
        // (address gen + use stall), cmp folded ~0.8.
        cycles[InstrClass::ScalarLoad as usize] = 1.8;
        cycles[InstrClass::ScalarStore as usize] = 1.8;
        cycles[InstrClass::ScalarCmp as usize] = 0.8;
        cycles[InstrClass::ScalarAlu as usize] = 0.5;
        CostModel {
            freq_ghz: 2.0,
            cycles,
            bw_bytes_per_cycle: 1.1,
            call_overhead_ns: 18.0,
            fork_ns: 15_000.0,
            band_overhead_ns: 1_200.0,
        }
    }

    /// Price a mix, itemized.
    pub fn breakdown(&self, mix: &InstrMix) -> CostBreakdown {
        let mut cyc = 0.0f64;
        for &c in &InstrClass::ALL {
            cyc += mix.get(c) as f64 * self.cycles[c as usize];
        }
        let mem_cyc = mix.stream_total() as f64 / self.bw_bytes_per_cycle;
        CostBreakdown {
            compute_ns: cyc / self.freq_ghz,
            memory_ns: mem_cyc / self.freq_ghz,
            overhead_ns: self.call_overhead_ns,
        }
    }

    /// Price a mix in nanoseconds.
    pub fn price_ns(&self, mix: &InstrMix) -> f64 {
        self.breakdown(mix).total_ns()
    }

    /// Price in nanoseconds without the fixed call overhead — for
    /// per-pixel / per-element comparisons.
    pub fn price_ns_marginal(&self, mix: &InstrMix) -> f64 {
        let b = self.breakdown(mix);
        b.compute_ns + b.memory_ns
    }

    /// Marginal price per pixel — the unit for cross-depth comparisons
    /// (a u16 pass should land near 2× the u8 per-pixel price on the
    /// same dimensions: half the lanes per op, twice the bytes).
    pub fn price_ns_per_pixel(&self, mix: &InstrMix, pixels: usize) -> f64 {
        if pixels == 0 {
            return 0.0;
        }
        self.price_ns_marginal(mix) / pixels as f64
    }

    // -- band-parallel execution --------------------------------------------

    /// Price a mix executed as `workers` parallel bands.
    ///
    /// The parallel term models a shared-memory-bus machine: **compute
    /// scales ~1/P** (bands are independent), the **memory/bandwidth
    /// term does not** (every band streams over the same bus), and the
    /// dispatch pays a fixed fork cost plus a per-band overhead.  The
    /// term has always assumed zero-copy bands — and since the
    /// `ImageView` executor rewrite that *is* the real geometry: band
    /// jobs read borrowed haloed views and write disjoint views in
    /// place, so no staging traffic needs modeling.  The model predicts
    /// speedup that grows with workers and saturates at the
    /// memory-bandwidth ceiling ([`CostModel::parallel_ceiling`]);
    /// `workers <= 1` is exactly the sequential price.
    pub fn parallel_breakdown(&self, mix: &InstrMix, workers: usize) -> CostBreakdown {
        let base = self.breakdown(mix);
        if workers <= 1 {
            return base;
        }
        CostBreakdown {
            compute_ns: base.compute_ns / workers as f64,
            memory_ns: base.memory_ns,
            overhead_ns: self.parallel_overhead_ns(workers),
        }
    }

    /// Fixed + per-band dispatch overhead of a `workers`-band execution
    /// (includes the per-call overhead) — the single source of the
    /// parallel overhead formula shared by [`CostModel::parallel_breakdown`]
    /// and [`CostModel::plan_workers`].
    fn parallel_overhead_ns(&self, workers: usize) -> f64 {
        self.call_overhead_ns + self.fork_ns + self.band_overhead_ns * workers as f64
    }

    /// Total parallel price in nanoseconds.
    pub fn parallel_price_ns(&self, mix: &InstrMix, workers: usize) -> f64 {
        self.parallel_breakdown(mix, workers).total_ns()
    }

    /// Modeled speedup of `workers` bands over sequential execution.
    pub fn parallel_speedup(&self, mix: &InstrMix, workers: usize) -> f64 {
        self.price_ns(mix) / self.parallel_price_ns(mix, workers)
    }

    /// Upper bound on parallel speedup: with infinite workers only the
    /// unscaled memory term remains, so speedup saturates at
    /// `(compute + memory) / memory` — the memory-bandwidth ceiling.
    pub fn parallel_ceiling(&self, mix: &InstrMix) -> f64 {
        let b = self.breakdown(mix);
        if b.memory_ns <= 0.0 {
            return f64::INFINITY;
        }
        (b.compute_ns + b.memory_ns) / b.memory_ns
    }

    /// First worker count whose marginal gain over the previous one
    /// falls below [`SATURATION_EPSILON`] — the saturation point of the
    /// modeled scaling curve (capped at `max_workers`).
    pub fn saturation_workers(&self, mix: &InstrMix, max_workers: usize) -> usize {
        let mut p = 1usize;
        while p < max_workers {
            let cur = self.parallel_price_ns(mix, p);
            let nxt = self.parallel_price_ns(mix, p + 1);
            if nxt >= cur * (1.0 - SATURATION_EPSILON) {
                return p;
            }
            p += 1;
        }
        max_workers.max(1)
    }

    /// Band count to use for a pass with the given compute/memory split
    /// (from [`CostModel::estimate_separable_cost`] or a counted mix):
    /// the argmin of the modeled parallel price over `1..=max_workers`,
    /// demoted to 1 unless it beats sequential by ≥10% — the dispatch
    /// crossover that keeps small images off the worker pool.
    pub fn plan_workers(&self, compute_ns: f64, memory_ns: f64, max_workers: usize) -> usize {
        let seq = compute_ns + memory_ns + self.call_overhead_ns;
        let par = |p: usize| compute_ns / p as f64 + memory_ns + self.parallel_overhead_ns(p);
        let mut best = 1usize;
        let mut best_ns = seq;
        for p in 2..=max_workers.max(1) {
            let t = par(p);
            if t < best_ns {
                best = p;
                best_ns = t;
            }
        }
        if best_ns > seq * 0.9 {
            1
        } else {
            best
        }
    }

    // -- §4 tile transpose --------------------------------------------------

    /// Itemized price of one whole-image §4 tile transpose executed as
    /// `workers` tile-row bands (`workers <= 1` = the sequential
    /// driver).  Unlike [`CostModel::estimate_separable_cost`] this is
    /// **loop-exact**, not a heuristic: the tile census is the §4
    /// instruction count the networks are pinned to
    /// (16×16.8: 16 ld + 16 st + 24 permute + 48 combine; 8×8.16:
    /// 8 + 8 + 8 + 24 — reinterprets are free), the edge census is one
    /// scalar load + store per right/bottom-edge pixel, and the memory
    /// term is the `2·h·w·px_bytes` stream the drivers record — so the
    /// breakdown of a counted transpose mix and this closed form agree
    /// exactly (asserted in the module tests and mirrored in
    /// `python/tools/mirror_counts.py::transpose_breakdown`).
    ///
    /// The parallel shape is the crate-wide banding model: per-tile
    /// compute scales ÷P (tile-rows are independent; the banded driver
    /// runs the identical tiles), the memory term does **not** (one
    /// bus), and a `workers`-band dispatch pays the fork + per-band
    /// cost.  Because a transpose is strongly memory-bound (~0.3–0.6
    /// compute cycles/px vs ~0.9–1.8 memory cycles/px), [`CostModel::
    /// plan_transpose_workers`] keeps paper-sized standalone transposes
    /// sequential — banding only pays on huge images, or inside a
    /// sandwich whose fork the rows pass has already justified.
    pub fn transpose_breakdown(
        &self,
        h: usize,
        w: usize,
        lanes: usize,
        px_bytes: usize,
        workers: usize,
    ) -> CostBreakdown {
        let cyc = |c: InstrClass| self.cycles[c as usize];
        // §4 per-tile census by tile edge (= SIMD lanes at this depth)
        let (loads, stores, permutes, combines) = match lanes {
            16 => (16u64, 16u64, 24u64, 48u64),
            8 => (8, 8, 8, 24),
            _ => (0, 0, 0, 0), // no tile network at this depth: all scalar
        };
        let tile_cycles = loads as f64 * cyc(InstrClass::SimdLoad)
            + stores as f64 * cyc(InstrClass::SimdStore)
            + permutes as f64 * cyc(InstrClass::SimdPermute)
            + combines as f64 * cyc(InstrClass::SimdCombine);
        let t = if loads == 0 { 1 } else { lanes };
        let (th, tw) = (h - h % t, w - w % t);
        let tiles = if loads == 0 { 0 } else { (th / t) * (tw / t) };
        let edge_px = if loads == 0 {
            h * w
        } else {
            h * (w - tw) + (h - th) * tw
        };
        let edge_cycles =
            edge_px as f64 * (cyc(InstrClass::ScalarLoad) + cyc(InstrClass::ScalarStore));
        let compute_ns = (tiles as f64 * tile_cycles + edge_cycles) / self.freq_ghz;
        let stream_bytes = 2.0 * (h * w * px_bytes) as f64;
        let memory_ns = stream_bytes / self.bw_bytes_per_cycle / self.freq_ghz;
        if workers <= 1 {
            CostBreakdown {
                compute_ns,
                memory_ns,
                overhead_ns: self.call_overhead_ns,
            }
        } else {
            CostBreakdown {
                compute_ns: compute_ns / workers as f64,
                memory_ns,
                overhead_ns: self.parallel_overhead_ns(workers),
            }
        }
    }

    /// Band count for a **standalone** `h×w` transpose at the given
    /// depth: [`CostModel::plan_workers`] over the loop-exact
    /// [`CostModel::transpose_breakdown`] split — the same ≥10%
    /// crossover every other pass uses, which demotes paper-sized
    /// images to sequential (the transpose is memory-bound).
    pub fn plan_transpose_workers(
        &self,
        h: usize,
        w: usize,
        lanes: usize,
        px_bytes: usize,
        max_workers: usize,
    ) -> usize {
        let b = self.transpose_breakdown(h, w, lanes, px_bytes, 1);
        self.plan_workers(b.compute_ns, b.memory_ns, max_workers)
    }

    /// Closed-form (compute_ns, memory_ns) estimate of one separable
    /// 2-D morphology at native speed — the *dispatch heuristic* behind
    /// `Parallelism::Auto`.  Mirrors the pass selection of
    /// `separable::pass_rows`/`pass_cols` (`method` resolved per pass
    /// against `thresholds`, `vertical` choosing the §5.2.2 direct form
    /// vs the §5.2.1 sandwich) but prices it coarsely (interior-only
    /// chunk census, vHGW padding approximated as `h + w`); the counted
    /// mixes remain the source of truth for reproduction numbers, and
    /// the tests only pin this estimate to the counted price within a
    /// small factor.
        pub fn estimate_separable_cost(
        &self,
        h: usize,
        w: usize,
        w_x: usize,
        w_y: usize,
        lanes: usize,
        px_bytes: usize,
        simd: bool,
        method: crate::morphology::PassMethod,
        vertical: crate::morphology::VerticalStrategy,
        thresholds: &crate::morphology::HybridThresholds,
    ) -> (f64, f64) {
        use crate::morphology::{hybrid::resolve_method, PassMethod};
        let cyc = |c: InstrClass| self.cycles[c as usize];
        let (ld, ldu, st, mm) = (
            cyc(InstrClass::SimdLoad),
            cyc(InstrClass::SimdLoadUnaligned),
            cyc(InstrClass::SimdStore),
            cyc(InstrClass::SimdMinMax),
        );
        let (sld, sst, scmp, salu) = (
            cyc(InstrClass::ScalarLoad),
            cyc(InstrClass::ScalarStore),
            cyc(InstrClass::ScalarCmp),
            cyc(InstrClass::ScalarAlu),
        );
        if h == 0 || w == 0 {
            return (0.0, 0.0);
        }
        let pixels = (h * w) as f64;
        let lanes_f = lanes as f64;
        let mut compute_cycles = 0.0f64;
        let mut stream_bytes = 0.0f64;

        if w_y > 1 {
            let m = resolve_method(method, w_y, thresholds.wy0);
            let wy = w_y as f64;
            let per_px = if !simd {
                // scalar two-row structure: ~ (wy+1)/2 loads + wy/2 cmps
                // + 1 store + ~wy/2 alu per pixel
                match m {
                    PassMethod::Linear => {
                        ((wy + 1.0) * sld + wy * scmp + 2.0 * sst + wy * salu) / 2.0
                    }
                    _ => 5.0 * sld + 3.0 * scmp + 3.0 * sst + 2.0 * salu,
                }
            } else {
                match m {
                    PassMethod::Linear => {
                        ((wy + 1.0) * ld + wy * mm + 2.0 * st + 2.0 * salu) / (2.0 * lanes_f)
                    }
                    // vHGW R+S chunk census over ~(h + wy)/h padded rows
                    _ => (5.0 * ld + 3.0 * mm + 3.0 * st + 2.0 * salu) / lanes_f
                        * ((h as f64 + wy) / h as f64),
                }
            };
            compute_cycles += per_px * pixels;
            stream_bytes += match m {
                PassMethod::Linear => 2.0 * pixels * px_bytes as f64,
                _ => 5.0 * pixels * px_bytes as f64,
            };
        }
        if w_x > 1 {
            let m = resolve_method(method, w_x, thresholds.wx0);
            let wx = w_x as f64;
            // the §5.2.1 sandwich applies exactly when `separable::pass_cols`
            // would take it (shared predicate)
            let sandwich = crate::morphology::separable::takes_sandwich(m, simd, vertical);
            // two tiled transposes: ~2 load/store + 4 permutes per vector
            let transpose_px = 2.0 * (2.0 * (ld + st) / 2.0 + 4.0) / lanes_f;
            let per_px = if !simd {
                match m {
                    PassMethod::Linear => wx * sld + wx * scmp + sst + wx * salu,
                    _ => 5.0 * sld + 3.0 * scmp + 3.0 * sst + 2.0 * salu,
                }
            } else if !sandwich {
                // §5.2.2 direct: all window loads unaligned
                (wx * ldu + (wx - 1.0) * mm + st + 2.0 * salu) / lanes_f
            } else if m == PassMethod::Linear {
                // sandwich around an aligned two-row linear mid pass
                transpose_px
                    + ((wx + 1.0) * ld + wx * mm + 2.0 * st + 2.0 * salu) / (2.0 * lanes_f)
            } else {
                // sandwich around a vHGW mid pass on the transposed image
                transpose_px
                    + (5.0 * ld + 3.0 * mm + 3.0 * st + 2.0 * salu) / lanes_f
                        * ((w as f64 + wx) / w as f64)
            };
            compute_cycles += per_px * pixels;
            stream_bytes += if !simd || !sandwich {
                2.0 * pixels * px_bytes as f64
            } else if m == PassMethod::Linear {
                (2.0 + 4.0) * pixels * px_bytes as f64
            } else {
                (5.0 + 4.0) * pixels * px_bytes as f64
            };
        }
        (
            compute_cycles / self.freq_ghz,
            stream_bytes / self.bw_bytes_per_cycle / self.freq_ghz,
        )
    }

    // -- run-length representation dispatch ---------------------------------

    /// Modeled cost of one binary-morphology request served through the
    /// run-length path ([`crate::morphology::RleImage`]): encode + decode
    /// stream the image twice and pay a per-pixel scan, then each chain
    /// step pays per-run interval arithmetic (horizontal shrink/grow)
    /// plus a `w_y`-way per-run merge (vertical intersection/union).
    /// Returns total nanoseconds.  The run census uses the Bernoulli
    /// expectation [`runs_per_row`]; like
    /// [`CostModel::estimate_separable_cost`] this is a *dispatch
    /// heuristic*, not a reproduction number.
    pub fn estimate_rle_cost(
        &self,
        h: usize,
        w: usize,
        w_y: usize,
        steps: usize,
        density: f64,
        px_bytes: usize,
    ) -> f64 {
        if h == 0 || w == 0 {
            return 0.0;
        }
        let pixels = (h * w) as f64;
        let runs = runs_per_row(w, density);
        let convert_ns = 2.0 * pixels * px_bytes as f64 / self.bw_bytes_per_cycle / self.freq_ghz
            + pixels * RLE_SCAN_CYCLES / self.freq_ghz;
        let per_step_cycles =
            h as f64 * runs * RLE_RUN_CYCLES + h as f64 * w_y as f64 * runs * RLE_MERGE_CYCLES;
        convert_ns + steps as f64 * per_step_cycles / self.freq_ghz
    }

    /// Modeled speedup of the RLE path over the dense separable path for
    /// a `steps`-op binary chain on an `h`×`w` image of the given
    /// foreground `density` — the `Representation::Auto` dispatch
    /// predicate (`> 1.0` routes to RLE).  The dense side prices each
    /// chain step with [`CostModel::estimate_separable_cost`] under the
    /// request's own config.
    #[allow(clippy::too_many_arguments)]
    pub fn rle_speedup(
        &self,
        h: usize,
        w: usize,
        w_x: usize,
        w_y: usize,
        steps: usize,
        density: f64,
        px_bytes: usize,
        cfg: &crate::morphology::MorphConfig,
    ) -> f64 {
        let rle = self.estimate_rle_cost(h, w, w_y, steps, density, px_bytes);
        if rle <= 0.0 {
            return 1.0;
        }
        let lanes = simd_lanes(if px_bytes == 2 { "u16" } else { "u8" }).unwrap_or(1);
        let (comp, mem) = self.estimate_separable_cost(
            h,
            w,
            w_x,
            w_y,
            lanes,
            px_bytes,
            cfg.simd,
            cfg.method,
            cfg.vertical,
            &cfg.thresholds,
        );
        steps as f64 * (comp + mem) / rle
    }

    /// First foreground density (scanned in steps of 0.005) at which the
    /// modeled RLE cost reaches the dense cost — i.e. where the sparse
    /// representation stops winning.  Returns 1.0 if RLE wins at every
    /// density.
    #[allow(clippy::too_many_arguments)]
    pub fn rle_crossover_density(
        &self,
        h: usize,
        w: usize,
        w_x: usize,
        w_y: usize,
        steps: usize,
        px_bytes: usize,
        cfg: &crate::morphology::MorphConfig,
    ) -> f64 {
        let mut d = 0.0f64;
        while d <= 1.0 {
            if self.rle_speedup(h, w, w_x, w_y, steps, d, px_bytes, cfg) <= 1.0 {
                return d;
            }
            d += 0.005;
        }
        1.0
    }
}

/// Calibrated per-pixel scan cost of the RLE encoder/decoder (run
/// detection over a row, amortized across the streaming copy — the
/// byte traffic itself is priced separately through the bandwidth term).
pub const RLE_SCAN_CYCLES: f64 = 0.5;
/// Per-run cost of one horizontal interval shrink/grow (branch + two
/// clamped adds + a bounds check).
pub const RLE_RUN_CYCLES: f64 = 8.0;
/// Per-run-per-window-row cost of the vertical k-way merge (two-pointer
/// intersection / sort-free union advance).
pub const RLE_MERGE_CYCLES: f64 = 3.0;

/// Expected maximal foreground runs per row of a width-`w` row whose
/// pixels are i.i.d. foreground with probability `density`: a run starts
/// at a FG pixel preceded by BG (or the row edge), so
/// `E[runs] = (w-1)·d·(1-d) + d`.
pub fn runs_per_row(w: usize, density: f64) -> f64 {
    if w == 0 {
        return 0.0;
    }
    let d = density.clamp(0.0, 1.0);
    (w as f64 - 1.0) * d * (1.0 - d) + d
}

impl Default for CostModel {
    fn default() -> Self {
        Self::exynos5422()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::{Backend, Counting};

    #[test]
    fn pricing_is_linear_in_counts() {
        let m = CostModel::exynos5422();
        let mut a = InstrMix::new();
        a.bump(InstrClass::SimdLoad, 10);
        let mut b = InstrMix::new();
        b.bump(InstrClass::SimdLoad, 20);
        let pa = m.price_ns_marginal(&a);
        let pb = m.price_ns_marginal(&b);
        assert!((pb - 2.0 * pa).abs() < 1e-9);
    }

    #[test]
    fn reinterprets_are_free() {
        let m = CostModel::exynos5422();
        let mut mix = InstrMix::new();
        mix.bump(InstrClass::SimdReinterpret, 1000);
        assert_eq!(m.price_ns_marginal(&mix), 0.0);
    }

    #[test]
    fn memory_term_uses_stream_bytes() {
        let m = CostModel::exynos5422();
        let mut c = Counting::new();
        c.record_stream(1_000_000, 0);
        let ns = m.price_ns_marginal(&c.mix);
        // 1 MB at 1.1 B/cycle, 2 GHz → ~455 µs
        assert!((ns - 1_000_000.0 / 1.1 / 2.0).abs() < 1.0);
    }

    #[test]
    fn unaligned_loads_cost_more() {
        let m = CostModel::exynos5422();
        let mut a = InstrMix::new();
        a.bump(InstrClass::SimdLoad, 100);
        let mut u = InstrMix::new();
        u.bump(InstrClass::SimdLoadUnaligned, 100);
        assert!(m.price_ns_marginal(&u) > m.price_ns_marginal(&a));
    }

    #[test]
    fn lanes_table_matches_paper_tiles() {
        assert_eq!(simd_lanes("u8"), Some(16));
        assert_eq!(simd_lanes("u16"), Some(8));
        assert_eq!(simd_lanes("f32"), None);
    }

    #[test]
    fn u16_pass_prices_about_double_per_pixel() {
        // half the lanes per op + double the streamed bytes ⇒ the u16
        // per-pixel price lands near 2× the u8 one on equal dimensions
        use crate::image::synth;
        use crate::morphology::{linear, MorphOp};
        let m = CostModel::exynos5422();
        let px = 64 * 64;
        let mut c8 = Counting::new();
        let _ = linear::rows_simd_linear(&mut c8, &synth::noise(64, 64, 4), 9, MorphOp::Erode);
        let mut c16 = Counting::new();
        let _ =
            linear::rows_simd_linear(&mut c16, &synth::noise_u16(64, 64, 4), 9, MorphOp::Erode);
        let r = m.price_ns_per_pixel(&c16.mix, px) / m.price_ns_per_pixel(&c8.mix, px);
        assert!((1.7..=2.3).contains(&r), "u16/u8 per-pixel price ratio {r}");
    }

    #[test]
    fn parallel_speedup_grows_then_saturates_at_memory_ceiling() {
        let m = CostModel::exynos5422();
        // compute-heavy mix with a real memory term
        let mut mix = InstrMix::new();
        mix.bump(InstrClass::SimdMinMax, 4_000_000);
        mix.bump(InstrClass::SimdLoad, 4_000_000);
        mix.stream_read = 480_000;
        mix.stream_written = 480_000;
        let mut last = 0.0;
        for p in 1..=16 {
            let s = m.parallel_speedup(&mix, p);
            assert!(s >= last - 1e-9, "speedup must be non-decreasing early");
            last = s;
        }
        let ceiling = m.parallel_ceiling(&mix);
        assert!(m.parallel_speedup(&mix, 16) < ceiling);
        let sat = m.saturation_workers(&mix, 16);
        assert!((2..=16).contains(&sat), "saturation {sat}");
        // beyond saturation the marginal gain is < epsilon
        let gain = m.parallel_price_ns(&mix, sat) / m.parallel_price_ns(&mix, sat + 1);
        assert!(gain < 1.0 / (1.0 - SATURATION_EPSILON) + 1e-9);
    }

    #[test]
    fn parallel_price_of_one_worker_is_sequential() {
        let m = CostModel::exynos5422();
        let mut mix = InstrMix::new();
        mix.bump(InstrClass::SimdLoad, 1000);
        mix.stream_read = 4096;
        assert_eq!(m.parallel_price_ns(&mix, 1), m.price_ns(&mix));
        assert!(m.parallel_price_ns(&mix, 0) == m.price_ns(&mix));
    }

    #[test]
    fn memory_bound_mixes_refuse_to_parallelize() {
        let m = CostModel::exynos5422();
        // pure memory: compute/P saves nothing, fork costs are real
        assert_eq!(m.plan_workers(0.0, 1_000_000.0, 8), 1);
        // tiny work: overhead dominates
        assert_eq!(m.plan_workers(5_000.0, 1_000.0, 8), 1);
        // compute-heavy large work parallelizes
        let p = m.plan_workers(2_000_000.0, 500_000.0, 8);
        assert!(p > 1, "expected banding for 2ms compute, got {p}");
    }

    #[test]
    fn estimate_tracks_counted_price_loosely() {
        use crate::image::synth;
        use crate::morphology::{
            self, HybridThresholds, MorphConfig, MorphOp, Parallelism, PassMethod,
            VerticalStrategy,
        };
        let m = CostModel::exynos5422();
        let img = synth::noise(120, 160, 9);
        let cfg = MorphConfig {
            parallelism: Parallelism::Sequential,
            ..MorphConfig::default()
        };
        let estimate = |h: usize, w: usize, method: PassMethod| {
            m.estimate_separable_cost(
                h,
                w,
                9,
                9,
                16,
                1,
                true,
                method,
                VerticalStrategy::Direct,
                &HybridThresholds::paper(),
            )
        };
        let mut c = Counting::new();
        let _ = morphology::morphology(&mut c, &img, MorphOp::Erode, 9, 9, &cfg);
        let counted = m.price_ns_marginal(&c.mix);
        let (comp, mem) = estimate(120, 160, PassMethod::Hybrid);
        let est = comp + mem;
        let ratio = est / counted;
        assert!(
            (0.2..=5.0).contains(&ratio),
            "estimate {est} vs counted {counted} (ratio {ratio})"
        );
        // estimator must scale with pixels (dispatch monotonicity)
        let (c2, m2) = estimate(240, 320, PassMethod::Hybrid);
        assert!(c2 > comp * 3.0 && m2 > mem * 3.0);
        // a forced-vHGW config prices its extra streaming (sandwich)
        let (_, mem_vhgw) = estimate(120, 160, PassMethod::Vhgw);
        assert!(mem_vhgw > mem * 2.0, "vhgw must stream more than linear");
    }

    #[test]
    fn runs_per_row_is_a_bernoulli_expectation() {
        assert_eq!(runs_per_row(100, 0.0), 0.0);
        assert_eq!(runs_per_row(0, 0.5), 0.0);
        // full row is exactly one run
        assert!((runs_per_row(100, 1.0) - 1.0).abs() < 1e-12);
        // sparse rows: runs ≈ w·d (isolated pixels)
        assert!((runs_per_row(1000, 0.01) - 1000.0 * 0.01).abs() < 1.0);
        // densest fragmentation near d=0.5
        assert!(runs_per_row(100, 0.5) > runs_per_row(100, 0.1));
        assert!(runs_per_row(100, 0.5) > runs_per_row(100, 0.9));
    }

    #[test]
    fn rle_wins_sparse_and_loses_dense() {
        use crate::morphology::MorphConfig;
        let m = CostModel::exynos5422();
        let cfg = MorphConfig::default();
        // headline workload: 600×800 u8 erode 7×7 at 5% foreground
        let sparse = m.rle_speedup(600, 800, 7, 7, 1, 0.05, 1, &cfg);
        assert!(sparse > 1.0, "sparse speedup {sparse}");
        // mid-density masks fragment into ~w·d·(1-d) runs — dense wins
        let mid = m.rle_speedup(600, 800, 7, 7, 1, 0.5, 1, &cfg);
        assert!(mid < 1.0, "mid-density speedup {mid}");
        assert!(sparse > mid);
        // crossover sits strictly between, and speedup is monotone
        // around it
        let x = m.rle_crossover_density(600, 800, 7, 7, 1, 1, &cfg);
        assert!(x > 0.01 && x < 0.5, "crossover {x}");
        assert!(m.rle_speedup(600, 800, 7, 7, 1, x - 0.005, 1, &cfg) > 1.0);
        // degenerate shapes price to the neutral 1.0
        assert_eq!(m.rle_speedup(0, 800, 7, 7, 1, 0.05, 1, &cfg), 1.0);
    }

    #[test]
    fn transpose_breakdown_is_loop_exact_against_counted_mix() {
        use crate::image::synth;
        let m = CostModel::exynos5422();
        for &(h, w) in &[(64usize, 64usize), (600, 800), (18, 18), (50, 33)] {
            let img = synth::noise(h, w, 3);
            let mut c = Counting::new();
            let _ = crate::transpose::transpose_image(&mut c, &img);
            let counted = m.breakdown(&c.mix);
            let closed = m.transpose_breakdown(h, w, 16, 1, 1);
            assert!(
                (counted.compute_ns - closed.compute_ns).abs() < 1e-6
                    && (counted.memory_ns - closed.memory_ns).abs() < 1e-6,
                "u8 {h}x{w}: counted {counted:?} vs closed {closed:?}"
            );
        }
        let img16 = synth::noise_u16(100, 80, 5);
        let mut c = Counting::new();
        let _ = crate::transpose::transpose_image_u16(&mut c, &img16);
        let counted = m.breakdown(&c.mix);
        let closed = m.transpose_breakdown(100, 80, 8, 2, 1);
        assert!(
            (counted.compute_ns - closed.compute_ns).abs() < 1e-6
                && (counted.memory_ns - closed.memory_ns).abs() < 1e-6,
            "u16: counted {counted:?} vs closed {closed:?}"
        );
    }

    #[test]
    fn standalone_transpose_banding_demotes_paper_sizes() {
        let m = CostModel::exynos5422();
        // paper-sized: memory-bound, banding gains < 10% → sequential
        assert_eq!(m.plan_transpose_workers(600, 800, 16, 1, 8), 1);
        assert_eq!(m.plan_transpose_workers(64, 64, 16, 1, 8), 1);
        // the compute share and the fork amortization both grow with
        // the image; a large-enough u16 transpose crosses the 10% bar
        // (u16 tiles carry ~2x the compute per pixel)
        let big = m.plan_transpose_workers(8192, 8192, 8, 2, 8);
        assert!(big >= 1); // shape-dependent; must at least be well-defined
        // monotonic sanity: banding never beats sequential on tiny work
        assert_eq!(m.plan_transpose_workers(16, 16, 16, 1, 8), 1);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let m = CostModel::exynos5422();
        let mut mix = InstrMix::new();
        mix.bump(InstrClass::SimdLoad, 7);
        mix.stream_read = 128;
        let b = m.breakdown(&mix);
        assert!((b.total_ns() - m.price_ns(&mix)).abs() < 1e-12);
        assert!(b.compute_ns > 0.0 && b.memory_ns > 0.0 && b.overhead_ns > 0.0);
    }
}
