//! Exynos-5422-like cost model: price an [`InstrMix`] in nanoseconds.
//!
//! We have no ARM silicon here, so the paper's absolute timings are
//! reproduced through a calibrated analytical model (see DESIGN.md
//! §Substitutions):
//!
//! ```text
//! time_ns = max-free sum of
//!   compute_ns = Σ_class count(class) · cycles(class) / freq_ghz
//!   memory_ns  = stream_bytes / (bw_bytes_per_cycle · freq_ghz)
//!   overhead_ns (fixed per-call cost: function entry, edge handling)
//! time = compute + memory + overhead       (in-order A15-like: additive)
//! ```
//!
//! The per-class cycle costs are *calibrated* against the paper's own
//! anchors rather than invented: Table 1 (scalar/SIMD transpose times),
//! the Fig. 3/Fig. 4 headline ratios (vHGW+SIMD ≈ 3× over scalar vHGW;
//! linear 14×/11× at w = 3) and the measured crossovers (w_y⁰ = 69,
//! w_x⁰ = 59).  [`calibrate`] re-derives the constants from those
//! anchors; [`CostModel::exynos5422`] ships the baked result so the
//! benches are deterministic.

use crate::neon::{InstrClass, InstrMix};

/// SIMD lane count of one 128-bit vector op at a given pixel dtype:
/// `u8` ops process 16 lanes, `u16` ops 8 (the §4 tile shapes 16×16.8
/// and 8×8.16).  A u16 pass therefore needs ~2× the vector instructions
/// and streams 2× the bytes per pixel — the counted mixes already
/// reflect this, so the same per-instruction-class prices stay honest
/// across depths (asserted in `rust/tests/counting_u16.rs`).
pub fn simd_lanes(dtype: &str) -> Option<usize> {
    use crate::morphology::MorphPixel;
    if dtype == <u8 as MorphPixel>::DTYPE {
        Some(<u8 as MorphPixel>::LANES)
    } else if dtype == <u16 as MorphPixel>::DTYPE {
        Some(<u16 as MorphPixel>::LANES)
    } else {
        None
    }
}

/// Per-instruction-class cycle costs + memory system parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    /// Core clock in GHz (Exynos 5422 Cortex-A15 cluster: 2.0 GHz).
    pub freq_ghz: f64,
    /// Issue cost in cycles per instruction class (same order as
    /// [`InstrClass::ALL`]).
    pub cycles: [f64; 11],
    /// Sustained DRAM streaming bandwidth in bytes per core cycle
    /// (LPDDR3-933 single-core streaming on the 5422 is ~2-3 GB/s;
    /// calibrated at 1.1 B/cycle = 2.2 GB/s).
    pub bw_bytes_per_cycle: f64,
    /// Fixed overhead per priced call, ns (entry/exit, edge rows).
    pub call_overhead_ns: f64,
}

/// Itemized price of a mix — useful in reports and for perf analysis.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostBreakdown {
    pub compute_ns: f64,
    pub memory_ns: f64,
    pub overhead_ns: f64,
}

impl CostBreakdown {
    pub fn total_ns(&self) -> f64 {
        self.compute_ns + self.memory_ns + self.overhead_ns
    }
}

impl CostModel {
    /// Baked Exynos 5422 calibration (see module docs and
    /// EXPERIMENTS.md §T1 for the anchor-by-anchor comparison).
    pub fn exynos5422() -> Self {
        let mut cycles = [0.0f64; 11];
        // SIMD pipeline: NEON on the A15 dual-issues simple ops; loads
        // ~1 cycle throughput, unaligned crossing loads pay ~1.4x.
        cycles[InstrClass::SimdLoad as usize] = 1.1;
        cycles[InstrClass::SimdLoadUnaligned as usize] = 1.58;
        cycles[InstrClass::SimdStore as usize] = 1.0;
        cycles[InstrClass::SimdMinMax as usize] = 0.62;
        cycles[InstrClass::SimdPermute as usize] = 1.0;
        cycles[InstrClass::SimdCombine as usize] = 0.5;
        cycles[InstrClass::SimdReinterpret as usize] = 0.0; // §4: free
        // Scalar side: in-order pipe, L1-hit loads ~1.8 cycles effective
        // (address gen + use stall), cmp folded ~0.8.
        cycles[InstrClass::ScalarLoad as usize] = 1.8;
        cycles[InstrClass::ScalarStore as usize] = 1.8;
        cycles[InstrClass::ScalarCmp as usize] = 0.8;
        cycles[InstrClass::ScalarAlu as usize] = 0.5;
        CostModel {
            freq_ghz: 2.0,
            cycles,
            bw_bytes_per_cycle: 1.1,
            call_overhead_ns: 18.0,
        }
    }

    /// Price a mix, itemized.
    pub fn breakdown(&self, mix: &InstrMix) -> CostBreakdown {
        let mut cyc = 0.0f64;
        for &c in &InstrClass::ALL {
            cyc += mix.get(c) as f64 * self.cycles[c as usize];
        }
        let mem_cyc = mix.stream_total() as f64 / self.bw_bytes_per_cycle;
        CostBreakdown {
            compute_ns: cyc / self.freq_ghz,
            memory_ns: mem_cyc / self.freq_ghz,
            overhead_ns: self.call_overhead_ns,
        }
    }

    /// Price a mix in nanoseconds.
    pub fn price_ns(&self, mix: &InstrMix) -> f64 {
        self.breakdown(mix).total_ns()
    }

    /// Price in nanoseconds without the fixed call overhead — for
    /// per-pixel / per-element comparisons.
    pub fn price_ns_marginal(&self, mix: &InstrMix) -> f64 {
        let b = self.breakdown(mix);
        b.compute_ns + b.memory_ns
    }

    /// Marginal price per pixel — the unit for cross-depth comparisons
    /// (a u16 pass should land near 2× the u8 per-pixel price on the
    /// same dimensions: half the lanes per op, twice the bytes).
    pub fn price_ns_per_pixel(&self, mix: &InstrMix, pixels: usize) -> f64 {
        if pixels == 0 {
            return 0.0;
        }
        self.price_ns_marginal(mix) / pixels as f64
    }
}

impl Default for CostModel {
    fn default() -> Self {
        Self::exynos5422()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::{Backend, Counting};

    #[test]
    fn pricing_is_linear_in_counts() {
        let m = CostModel::exynos5422();
        let mut a = InstrMix::new();
        a.bump(InstrClass::SimdLoad, 10);
        let mut b = InstrMix::new();
        b.bump(InstrClass::SimdLoad, 20);
        let pa = m.price_ns_marginal(&a);
        let pb = m.price_ns_marginal(&b);
        assert!((pb - 2.0 * pa).abs() < 1e-9);
    }

    #[test]
    fn reinterprets_are_free() {
        let m = CostModel::exynos5422();
        let mut mix = InstrMix::new();
        mix.bump(InstrClass::SimdReinterpret, 1000);
        assert_eq!(m.price_ns_marginal(&mix), 0.0);
    }

    #[test]
    fn memory_term_uses_stream_bytes() {
        let m = CostModel::exynos5422();
        let mut c = Counting::new();
        c.record_stream(1_000_000, 0);
        let ns = m.price_ns_marginal(&c.mix);
        // 1 MB at 1.1 B/cycle, 2 GHz → ~455 µs
        assert!((ns - 1_000_000.0 / 1.1 / 2.0).abs() < 1.0);
    }

    #[test]
    fn unaligned_loads_cost_more() {
        let m = CostModel::exynos5422();
        let mut a = InstrMix::new();
        a.bump(InstrClass::SimdLoad, 100);
        let mut u = InstrMix::new();
        u.bump(InstrClass::SimdLoadUnaligned, 100);
        assert!(m.price_ns_marginal(&u) > m.price_ns_marginal(&a));
    }

    #[test]
    fn lanes_table_matches_paper_tiles() {
        assert_eq!(simd_lanes("u8"), Some(16));
        assert_eq!(simd_lanes("u16"), Some(8));
        assert_eq!(simd_lanes("f32"), None);
    }

    #[test]
    fn u16_pass_prices_about_double_per_pixel() {
        // half the lanes per op + double the streamed bytes ⇒ the u16
        // per-pixel price lands near 2× the u8 one on equal dimensions
        use crate::image::synth;
        use crate::morphology::{linear, MorphOp};
        let m = CostModel::exynos5422();
        let px = 64 * 64;
        let mut c8 = Counting::new();
        let _ = linear::rows_simd_linear(&mut c8, &synth::noise(64, 64, 4), 9, MorphOp::Erode);
        let mut c16 = Counting::new();
        let _ =
            linear::rows_simd_linear(&mut c16, &synth::noise_u16(64, 64, 4), 9, MorphOp::Erode);
        let r = m.price_ns_per_pixel(&c16.mix, px) / m.price_ns_per_pixel(&c8.mix, px);
        assert!((1.7..=2.3).contains(&r), "u16/u8 per-pixel price ratio {r}");
    }

    #[test]
    fn breakdown_sums_to_total() {
        let m = CostModel::exynos5422();
        let mut mix = InstrMix::new();
        mix.bump(InstrClass::SimdLoad, 7);
        mix.stream_read = 128;
        let b = m.breakdown(&mix);
        assert!((b.total_ns() - m.price_ns(&mix)).abs() < 1e-12);
        assert!(b.compute_ns > 0.0 && b.memory_ns > 0.0 && b.overhead_ns > 0.0);
    }
}
