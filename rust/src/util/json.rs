//! Strict, dependency-free JSON: a recursive-descent parser and a small
//! writer.  Covers the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null); numbers are kept as f64 — the
//! manifest only uses integers within f64's exact range.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj[key]`, if this is an object containing `key`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Convenience: `self[key]` as &str.
    pub fn str_field(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    /// Convenience: `self[key]` as usize.
    pub fn usize_field(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(Json::as_usize)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (rejects trailing garbage).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let cp = self.unicode_escape()?;
                            out.push(cp);
                            continue; // unicode_escape advanced pos itself
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = &self.bytes[self.pos..];
                    let step = utf8_len(s[0]);
                    if s.len() < step {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&s[..step])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                    self.pos += step;
                }
            }
        }
    }

    /// Parse `uXXXX` (pos at 'u'), including surrogate pairs.
    fn unicode_escape(&mut self) -> Result<char, ParseError> {
        let hex4 = |p: &mut Self| -> Result<u32, ParseError> {
            p.pos += 1; // consume 'u'
            if p.bytes.len() < p.pos + 4 {
                return Err(p.err("truncated \\u escape"));
            }
            let s = std::str::from_utf8(&p.bytes[p.pos..p.pos + 4])
                .map_err(|_| p.err("bad \\u escape"))?;
            let v = u32::from_str_radix(s, 16).map_err(|_| p.err("bad \\u escape"))?;
            p.pos += 4;
            Ok(v)
        };
        let hi = hex4(self)?;
        if (0xD800..0xDC00).contains(&hi) {
            // surrogate pair: expect \uXXXX low
            if self.peek() == Some(b'\\') {
                self.pos += 1;
                if self.peek() == Some(b'u') {
                    let lo = hex4(self)?;
                    if (0xDC00..0xE000).contains(&lo) {
                        let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                        return char::from_u32(cp).ok_or_else(|| self.err("bad surrogate"));
                    }
                }
            }
            return Err(self.err("unpaired surrogate"));
        }
        char::from_u32(hi).ok_or_else(|| self.err("bad codepoint"))
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Serialize (compact).  Strings are escaped minimally.
pub fn write(v: &Json) -> String {
    let mut out = String::new();
    write_into(v, &mut out);
    out
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Json::Str(k.clone()), out);
                out.push(':');
                write_into(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "format": 1,
            "dtype": "u8",
            "artifacts": [
                {"name": "erode_600x800_w3x3", "height": 600, "width": 800,
                 "w_x": 3, "w_y": 3, "file": "erode_600x800_w3x3.hlo.txt"}
            ]
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.usize_field("format"), Some(1));
        assert_eq!(v.str_field("dtype"), Some("u8"));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].usize_field("height"), Some(600));
        assert_eq!(arts[0].str_field("file"), Some("erode_600x800_w3x3.hlo.txt"));
    }

    #[test]
    fn round_trips() {
        let doc = r#"{"a":[1,2.5,-3,true,false,null,"s\n\"x\""],"b":{"c":1e3}}"#;
        let v = parse(doc).unwrap();
        let again = parse(&write(&v)).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé😀""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé😀"));
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "01x", "\"unterminated",
            "{\"a\":1}garbage", "[1 2]", "\"\\q\"",
        ] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn nested_structures() {
        let v = parse("[[[[1]]]]").unwrap();
        let inner = v.as_arr().unwrap()[0].as_arr().unwrap()[0].as_arr().unwrap()[0]
            .as_arr()
            .unwrap()[0]
            .as_f64()
            .unwrap();
        assert_eq!(inner, 1.0);
    }

    #[test]
    fn numbers_edge_cases() {
        assert_eq!(parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(parse("1e-3").unwrap().as_f64(), Some(0.001));
        assert_eq!(parse("123456789012").unwrap().as_usize(), Some(123456789012));
        assert_eq!(parse("1.5").unwrap().as_usize(), None);
        assert_eq!(parse("-1").unwrap().as_usize(), None);
    }
}
