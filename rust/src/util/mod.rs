//! Small self-contained utilities.
//!
//! The offline build has no serde / criterion / proptest, so this module
//! provides the pieces the rest of the crate needs:
//!
//! * [`json`] — a strict recursive-descent JSON parser (for
//!   `artifacts/manifest.json`) and a minimal writer.
//! * [`timing`] — measurement helpers used by the bench harness
//!   (warmup + repetition with min/mean/p50 reporting).
//! * [`prop`] — a tiny property-testing loop over the deterministic
//!   [`crate::image::synth::Rng`]: random cases, shrink-free but
//!   seed-reported so failures reproduce exactly.

pub mod json;
pub mod prop;
pub mod timing;
