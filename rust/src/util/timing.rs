//! Measurement helpers for the bench harness (criterion is unavailable
//! offline): warmup, repeated timing, robust statistics.

use std::time::Instant;

/// Statistics over repeated timings (nanoseconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Stats {
    pub iters: usize,
    pub min_ns: f64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        Stats {
            iters: n,
            min_ns: ns[0],
            mean_ns: ns.iter().sum::<f64>() / n as f64,
            p50_ns: ns[n / 2],
            max_ns: ns[n - 1],
        }
    }
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
/// Each run is timed individually (use [`bench_batched`] for sub-µs
/// functions).
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_nanos() as f64);
    }
    Stats::from_samples(samples)
}

/// Time `f` in batches of `batch` calls per sample — for fast functions
/// where a single call is below timer resolution.  Reported numbers are
/// per call.
pub fn bench_batched<T>(
    warmup: usize,
    samples: usize,
    batch: usize,
    mut f: impl FnMut() -> T,
) -> Stats {
    let batch = batch.max(1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t = Instant::now();
        for _ in 0..batch {
            std::hint::black_box(f());
        }
        out.push(t.elapsed().as_nanos() as f64 / batch as f64);
    }
    Stats::from_samples(out)
}

/// Pick a batch size so one sample takes roughly `target_us`
/// microseconds.
pub fn auto_batch<T>(target_us: f64, mut f: impl FnMut() -> T) -> usize {
    let t = Instant::now();
    std::hint::black_box(f());
    let one = t.elapsed().as_nanos().max(1) as f64;
    ((target_us * 1000.0 / one).ceil() as usize).clamp(1, 10_000_000)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench(1, 16, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.min_ns > 0.0);
        assert!(s.min_ns <= s.p50_ns);
        assert!(s.p50_ns <= s.max_ns);
        assert!(s.min_ns <= s.mean_ns && s.mean_ns <= s.max_ns);
        assert_eq!(s.iters, 16);
    }

    #[test]
    fn batched_reports_per_call() {
        let single = bench(2, 8, || std::hint::black_box(3u64).pow(7));
        let batched = bench_batched(2, 8, 1000, || std::hint::black_box(3u64).pow(7));
        // batched per-call time must not exceed raw single-call timing
        // (which includes timer overhead)
        assert!(batched.p50_ns <= single.p50_ns * 2.0 + 100.0);
    }

    #[test]
    fn auto_batch_positive() {
        let b = auto_batch(100.0, || 1 + 1);
        assert!(b >= 1);
    }
}
