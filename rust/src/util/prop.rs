//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! [`forall`] runs a property over `n` random cases drawn from a
//! deterministic seed; on failure it panics with the failing case seed
//! so the exact case replays.  No shrinking — generators are kept small
//! instead.

use crate::image::synth::Rng;

/// Run `prop(case_rng, case_index)` for `n` cases.  Each case gets its
/// own deterministically-derived RNG.
pub fn forall(seed: u64, n: usize, mut prop: impl FnMut(&mut Rng, usize)) {
    for i in 0..n {
        let case_seed = seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(i as u64 + 1);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, i);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed at case {i} (root seed {seed}, case seed {case_seed}): {msg}");
        }
    }
}

/// Random odd window in `[1, max]`.
pub fn odd_window(rng: &mut Rng, max: usize) -> usize {
    let max = max.max(1);
    let k = rng.below(max.div_ceil(2));
    2 * k + 1
}

/// Random image dimensions `(h, w)` within `[1, max_h] × [1, max_w]`.
pub fn dims(rng: &mut Rng, max_h: usize, max_w: usize) -> (usize, usize) {
    (1 + rng.below(max_h), 1 + rng.below(max_w))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall(42, 25, |_, _| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn forall_reports_failing_case() {
        forall(42, 10, |rng, _| {
            assert!(rng.below(10) != usize::MAX); // always true
            assert!(rng.below(3) < 2, "sometimes false");
        });
    }

    #[test]
    fn odd_window_is_odd_and_bounded() {
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let w = odd_window(&mut rng, 31);
            assert!(w % 2 == 1 && (1..=31).contains(&w));
        }
    }

    #[test]
    fn dims_in_range() {
        let mut rng = Rng::new(9);
        for _ in 0..200 {
            let (h, w) = dims(&mut rng, 40, 60);
            assert!((1..=40).contains(&h) && (1..=60).contains(&w));
        }
    }
}
