//! Morphological filtering (paper §2, §5): erosion / dilation with
//! rectangular structuring elements, separable implementation.
//!
//! Algorithm inventory (all generic over [`crate::neon::Backend`], so
//! the same code runs at native speed or with instruction accounting):
//!
//! | pass | algorithm | SIMD | module | paper |
//! |------|-----------|------|--------|-------|
//! | rows (horizontal, SE `1×w_y`) | linear | scalar + NEON | [`linear`] | §5.1.2 |
//! | rows | vHGW | scalar + NEON | [`vhgw`] | §5.1.1 |
//! | cols (vertical, SE `w_x×1`) | linear (direct, unaligned) | scalar + NEON | [`linear`] | §5.2.2 |
//! | cols | vHGW direct | scalar | [`vhgw`] | §5.2 baseline (no SIMD) |
//! | cols | transpose ∘ rows-vHGW ∘ transpose | NEON | [`separable`] | §5.2.1 |
//! | 2-D | naive sliding window | scalar | [`naive`] | §2 definition |
//! | 2-D | separable composition + hybrid dispatch | both | [`separable`], [`hybrid`] | §5.3 |
//!
//! Conventions (identical to `python/compile/kernels/ref.py` and the HLO
//! artifacts): images are `[row, col]`, the SE is `w_x` columns × `w_y`
//! rows with odd sides and centered anchor, out-of-image samples take
//! the reduction identity (min → 255, max → 0), output size == input
//! size.

pub mod binary;
pub mod derived;
pub mod hybrid;
pub mod linear;
pub mod naive;
pub mod separable;
pub mod vhgw;

use crate::image::Image;
use crate::neon::Backend;

pub use derived::{blackhat, closing, gradient, opening, tophat};
pub use hybrid::{HybridThresholds, PAPER_WX0, PAPER_WY0};
pub use separable::{dilate, erode, morphology};

/// Which reduction a pass performs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MorphOp {
    /// Windowed minimum.
    Erode,
    /// Windowed maximum.
    Dilate,
}

impl MorphOp {
    /// The reduction identity — the padding value for out-of-image taps.
    #[inline(always)]
    pub fn identity(self) -> u8 {
        match self {
            MorphOp::Erode => u8::MAX,
            MorphOp::Dilate => u8::MIN,
        }
    }

    /// Scalar combine (accounted through the backend).
    #[inline(always)]
    pub fn scalar<B: Backend>(self, b: &mut B, x: u8, y: u8) -> u8 {
        match self {
            MorphOp::Erode => b.scalar_min_u8(x, y),
            MorphOp::Dilate => b.scalar_max_u8(x, y),
        }
    }

    /// Vector combine (accounted through the backend).
    #[inline(always)]
    pub fn simd<B: Backend>(
        self,
        b: &mut B,
        x: crate::neon::U8x16,
        y: crate::neon::U8x16,
    ) -> crate::neon::U8x16 {
        match self {
            MorphOp::Erode => b.vminq_u8(x, y),
            MorphOp::Dilate => b.vmaxq_u8(x, y),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MorphOp::Erode => "erode",
            MorphOp::Dilate => "dilate",
        }
    }

    /// The dual operation (erosion ↔ dilation).
    pub fn dual(self) -> MorphOp {
        match self {
            MorphOp::Erode => MorphOp::Dilate,
            MorphOp::Dilate => MorphOp::Erode,
        }
    }
}

/// Per-pass algorithm selection (paper §5).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PassMethod {
    /// O(w) combines per pixel, branch-free, SIMD-perfect (§5.1.2/§5.2.2).
    Linear,
    /// van Herk/Gil-Werman: O(1) combines per pixel, 2× extra memory
    /// (§5.1.1).
    Vhgw,
    /// §5.3 policy: Linear below the crossover threshold, Vhgw above.
    Hybrid,
}

impl PassMethod {
    pub fn name(self) -> &'static str {
        match self {
            PassMethod::Linear => "linear",
            PassMethod::Vhgw => "vhgw",
            PassMethod::Hybrid => "hybrid",
        }
    }
}

/// How the vertical (cols-window) pass is realized (paper §5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum VerticalStrategy {
    /// §5.2.1 baseline: transpose → rows pass → transpose, reusing the
    /// SIMD-friendly horizontal code and the §4 NEON transpose tiles.
    Transpose,
    /// §5.2.2: operate in place with offset (unaligned) loads.
    Direct,
}

impl VerticalStrategy {
    pub fn name(self) -> &'static str {
        match self {
            VerticalStrategy::Transpose => "transpose",
            VerticalStrategy::Direct => "direct",
        }
    }
}

/// Border handling.  The whole stack's canonical semantics is
/// [`Border::Identity`]; [`Border::Replicate`] is provided as an
/// extension (implemented by pre-padding with replicated edges).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Border {
    /// Out-of-image taps contribute the reduction identity (255 for
    /// erode, 0 for dilate) — reduction over the window∩image.
    Identity,
    /// Out-of-image taps replicate the nearest edge pixel.
    Replicate,
}

/// Full configuration of a separable morphology invocation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MorphConfig {
    pub method: PassMethod,
    pub vertical: VerticalStrategy,
    /// Use the SIMD implementations (false = the paper's "without SIMD"
    /// baselines).
    pub simd: bool,
    pub border: Border,
    /// Crossover thresholds used when `method == Hybrid`.
    pub thresholds: HybridThresholds,
}

impl Default for MorphConfig {
    /// The paper's §5.3 "final fast morphology implementation": hybrid
    /// dispatch; the vertical pass resolves to the *direct* §5.2.2 form
    /// for linear windows (below the crossover) and to the §5.2.1
    /// transpose sandwich for vHGW windows (above it) — vHGW always
    /// sandwiches regardless of this setting.  `Direct` measured
    /// 1.7-3.5x faster end-to-end than forcing the sandwich for linear
    /// too (EXPERIMENTS.md §Perf, iteration 1).
    fn default() -> Self {
        MorphConfig {
            method: PassMethod::Hybrid,
            vertical: VerticalStrategy::Direct,
            simd: true,
            border: Border::Identity,
            thresholds: HybridThresholds::paper(),
        }
    }
}

/// Validate an odd window size, returning its wing.
pub(crate) fn wing_of(window: usize, what: &str) -> usize {
    assert!(
        window >= 1 && window % 2 == 1,
        "{what} window must be odd and >= 1, got {window}"
    );
    window / 2
}

/// Pre-pad an image by (wing_x, wing_y) replicated edges — the
/// [`Border::Replicate`] lowering.  The result is filtered with identity
/// borders and cropped back by the caller.
pub(crate) fn replicate_pad(img: &Image<u8>, wing_x: usize, wing_y: usize) -> Image<u8> {
    let (h, w) = (img.height(), img.width());
    if h == 0 || w == 0 {
        return img.clone();
    }
    Image::from_fn(h + 2 * wing_y, w + 2 * wing_x, |y, x| {
        let sy = y.saturating_sub(wing_y).min(h - 1);
        let sx = x.saturating_sub(wing_x).min(w - 1);
        img.get(sy, sx)
    })
}

/// Crop the center `h × w` region starting at (wing_y, wing_x).
pub(crate) fn crop(img: &Image<u8>, wing_y: usize, wing_x: usize, h: usize, w: usize) -> Image<u8> {
    Image::from_fn(h, w, |y, x| img.get(y + wing_y, x + wing_x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_values() {
        assert_eq!(MorphOp::Erode.identity(), 255);
        assert_eq!(MorphOp::Dilate.identity(), 0);
        assert_eq!(MorphOp::Erode.dual(), MorphOp::Dilate);
    }

    #[test]
    #[should_panic(expected = "window must be odd")]
    fn even_window_rejected() {
        wing_of(4, "test");
    }

    #[test]
    fn replicate_pad_and_crop_round_trip() {
        let img = Image::from_fn(3, 4, |y, x| (10 * y + x) as u8);
        let p = replicate_pad(&img, 2, 1);
        assert_eq!(p.height(), 5);
        assert_eq!(p.width(), 8);
        assert_eq!(p.get(0, 0), img.get(0, 0)); // corner replication
        assert_eq!(p.get(0, 7), img.get(0, 3));
        assert_eq!(p.get(4, 0), img.get(2, 0));
        let c = crop(&p, 1, 2, 3, 4);
        assert!(c.same_pixels(&img));
    }

    #[test]
    fn default_config_is_paper_final() {
        let c = MorphConfig::default();
        assert_eq!(c.method, PassMethod::Hybrid);
        assert_eq!(c.vertical, VerticalStrategy::Direct);
        assert!(c.simd);
        assert_eq!(c.thresholds.wy0, PAPER_WY0);
        assert_eq!(c.thresholds.wx0, PAPER_WX0);
    }
}
