//! Morphological filtering (paper §2, §5): erosion / dilation with
//! rectangular structuring elements, separable implementation.
//!
//! Algorithm inventory (all generic over [`crate::neon::Backend`], so
//! the same code runs at native speed or with instruction accounting,
//! and over [`MorphPixel`], so the same code runs on `u8` and `u16`
//! images):
//!
//! | pass | algorithm | SIMD | module | paper |
//! |------|-----------|------|--------|-------|
//! | rows (horizontal, SE `1×w_y`) | linear | scalar + NEON | [`linear`] | §5.1.2 |
//! | rows | vHGW | scalar + NEON | [`vhgw`] | §5.1.1 |
//! | cols (vertical, SE `w_x×1`) | linear (direct, unaligned) | scalar + NEON | [`linear`] | §5.2.2 |
//! | cols | vHGW direct | scalar | [`vhgw`] | §5.2 baseline (no SIMD) |
//! | cols | transpose ∘ rows-vHGW ∘ transpose | NEON | [`separable`] | §5.2.1 |
//! | 2-D | naive sliding window | scalar | [`naive`] | §2 definition |
//! | 2-D | separable composition + hybrid dispatch | both | [`separable`], [`hybrid`] | §5.3 |
//! | any pass | band-sharded parallel execution (row bands with `w-1` halos, tile-aligned stripes for the sandwich) | — | [`parallel`] | extension |
//! | pipeline | plan–execute: [`FilterSpec`] → [`FilterPlan`] (one-time method/band resolution + scratch arena, op chains, ROI) | — | [`plan`] | extension |
//! | 0/255 scenario | run-length interval arithmetic (per-row foreground runs; erode = shrink + k-row intersect, dilate = grow + union) | — | [`rle`] | extension (arXiv 1504.01052) |
//! | any scenario | geodesic dilation/erosion + morphological reconstruction (banded sweeps iterated to stability) | — | [`geodesic`] | extension (arXiv 1911.13074) |
//!
//! Band-sharding is bit-identical to sequential execution and applies
//! only to native-speed runs ([`parallel::filter_native`]); counted
//! (Counting-backend) runs always execute sequentially so instruction
//! mixes stay deterministic.  See [`parallel`] for the halo math and
//! [`Parallelism`] for the dispatch knob.
//!
//! ## Pixel depth dispatch
//!
//! The paper's §4 fast transpose exists in two shapes — 16×16 tiles of
//! 8-bit elements and 8×8 tiles of 16-bit elements — precisely because
//! morphology is needed at both depths.  [`MorphPixel`] carries
//! everything a pass needs to be depth-polymorphic:
//!
//! * the reduction identities (`Pixel::MAX_VALUE` / `Pixel::MIN_VALUE`),
//! * the associated 128-bit SIMD lane type ([`crate::neon::U8x16`] with
//!   16 lanes for `u8`, [`crate::neon::U16x8`] with 8 lanes for `u16`)
//!   and the matching `vminq`/`vmaxq`/load/store intrinsics,
//! * the whole-image NEON transpose at the right tile shape (16×16.8
//!   for `u8`, 8×8.16 for `u16`), used by the
//!   [`VerticalStrategy::Transpose`] sandwich.
//!
//! A u16 pass therefore issues exactly 2× the vector instructions per
//! pixel of the u8 pass (8 lanes/op instead of 16) and streams 2× the
//! bytes; the cost model prices both honestly from the counted mix (see
//! `rust/tests/counting_u16.rs`).
//!
//! ## View contract
//!
//! Every pass takes a borrowed [`crate::image::ImageView`] as its
//! source (a `&Image` coerces through `From` at each call site), and
//! the 1-D passes have `_into` forms writing straight into a
//! caller-provided [`crate::image::ImageViewMut`].  This is what lets
//! [`parallel`] run band jobs with **zero staging copies** (overlapping
//! haloed reads, disjoint in-place writes) and what powers the
//! region-of-interest entry points ([`separable::erode_roi`] /
//! [`separable::dilate_roi`] over a [`Roi`] rectangle).
//!
//! Conventions (identical to `python/compile/kernels/ref.py` and the HLO
//! artifacts): images are `[row, col]`, the SE is `w_x` columns × `w_y`
//! rows with odd sides and centered anchor, out-of-image samples take
//! the reduction identity (min → dtype MAX, max → 0), output size ==
//! input size.

pub mod binary;
pub mod derived;
pub mod geodesic;
pub mod hybrid;
pub mod linear;
pub mod naive;
pub mod parallel;
pub mod plan;
pub mod rle;
pub mod separable;
pub mod vhgw;

use crate::image::{Image, ImageView, ImageViewMut, Pixel};
use crate::neon::{Backend, U16x8, U8x16};

pub use derived::{blackhat, closing, gradient, opening, tophat};
pub use geodesic::{
    geodesic_dilate, geodesic_erode, reconstruct_by_dilation, reconstruct_by_erosion,
};
pub use hybrid::{HybridThresholds, PAPER_WX0, PAPER_WY0};
pub use parallel::{filter_native, filter_roi, BandPool};
pub use plan::{FilterOp, FilterPlan, FilterSpec, FusedPlan, OpChain, PlanError, MAX_CHAIN};
pub use rle::RleImage;
pub use separable::{dilate, dilate_roi, erode, erode_roi, morphology};

/// A pixel depth the morphology stack can filter: scalar + SIMD min/max,
/// loads/stores at both alignments, and the §4 tiled transpose for this
/// element width.  Implemented for `u8` (16 lanes) and `u16` (8 lanes).
pub trait MorphPixel: Pixel {
    /// The 128-bit SIMD register view holding [`MorphPixel::LANES`]
    /// elements of this depth.
    type Vec: Copy + std::fmt::Debug + PartialEq;

    /// Elements per 128-bit vector op: 16 for `u8`, 8 for `u16` — the
    /// §4 tile shapes 16×16.8 and 8×8.16.
    const LANES: usize;

    /// dtype tag used in batch keys, manifests and reports.
    const DTYPE: &'static str;

    /// Aligned vector load of [`MorphPixel::LANES`] elements.
    fn vload<B: Backend>(b: &mut B, src: &[Self]) -> Self::Vec;

    /// Unaligned (offset) vector load — the §5.2.2 vertical pattern.
    fn vload_unaligned<B: Backend>(b: &mut B, src: &[Self]) -> Self::Vec;

    /// Vector store of [`MorphPixel::LANES`] elements.
    fn vstore<B: Backend>(b: &mut B, dst: &mut [Self], v: Self::Vec);

    /// Lane-wise `vminq`.
    fn vmin<B: Backend>(b: &mut B, x: Self::Vec, y: Self::Vec) -> Self::Vec;

    /// Lane-wise `vmaxq`.
    fn vmax<B: Backend>(b: &mut B, x: Self::Vec, y: Self::Vec) -> Self::Vec;

    /// Accounted scalar element load.
    fn load<B: Backend>(b: &mut B, src: &[Self], idx: usize) -> Self;

    /// Accounted scalar element store.
    fn store<B: Backend>(b: &mut B, dst: &mut [Self], idx: usize, v: Self);

    /// Accounted scalar min.
    fn min_s<B: Backend>(b: &mut B, x: Self, y: Self) -> Self;

    /// Accounted scalar max.
    fn max_s<B: Backend>(b: &mut B, x: Self, y: Self) -> Self;

    /// Whole-image NEON tiled transpose at this depth (§4): 16×16.8
    /// tiles for `u8`, 8×8.16 tiles for `u16`, reading any borrowed
    /// strided view.  This is what the [`VerticalStrategy::Transpose`]
    /// sandwich dispatches through.
    fn transpose_image<B: Backend>(b: &mut B, img: ImageView<'_, Self>) -> Image<Self>;

    /// [`MorphPixel::transpose_image`] writing into a caller-provided
    /// `w × h` destination — the zero-allocation form
    /// [`plan::FilterPlan`] runs its §5.2.1 sandwich through (the
    /// transpose buffers live in the plan's scratch arena).
    fn transpose_image_into<B: Backend>(
        b: &mut B,
        img: ImageView<'_, Self>,
        dst: ImageViewMut<'_, Self>,
    );

    /// One **band** of the depth-dispatched §4 tile transpose: source
    /// row band `[band.start, band.end)` of the full view `img` into
    /// `dst`, the matching `w × band.len()` destination *column stripe*
    /// (an [`ImageViewMut::split_cols_mut`] stripe).  Tile-rows are
    /// independent, so band jobs run concurrently — this is what
    /// [`parallel::transpose_image_banded_into`] forks per stripe.
    /// One `[0, h)` band is exactly [`MorphPixel::transpose_image_into`].
    fn transpose_band_into<B: Backend>(
        b: &mut B,
        img: ImageView<'_, Self>,
        dst: &mut ImageViewMut<'_, Self>,
        band: std::ops::Range<usize>,
    );

    /// Saturating subtraction (derived operations).
    fn sat_sub(self, other: Self) -> Self;

    /// Value inversion `MAX - v` (erosion/dilation duality).
    fn invert(self) -> Self;
}

impl MorphPixel for u8 {
    type Vec = U8x16;
    const LANES: usize = 16;
    const DTYPE: &'static str = "u8";

    #[inline(always)]
    fn vload<B: Backend>(b: &mut B, src: &[u8]) -> U8x16 {
        b.vld1q_u8(src)
    }

    #[inline(always)]
    fn vload_unaligned<B: Backend>(b: &mut B, src: &[u8]) -> U8x16 {
        b.vld1q_u8_unaligned(src)
    }

    #[inline(always)]
    fn vstore<B: Backend>(b: &mut B, dst: &mut [u8], v: U8x16) {
        b.vst1q_u8(dst, v);
    }

    #[inline(always)]
    fn vmin<B: Backend>(b: &mut B, x: U8x16, y: U8x16) -> U8x16 {
        b.vminq_u8(x, y)
    }

    #[inline(always)]
    fn vmax<B: Backend>(b: &mut B, x: U8x16, y: U8x16) -> U8x16 {
        b.vmaxq_u8(x, y)
    }

    #[inline(always)]
    fn load<B: Backend>(b: &mut B, src: &[u8], idx: usize) -> u8 {
        b.scalar_load_u8(src, idx)
    }

    #[inline(always)]
    fn store<B: Backend>(b: &mut B, dst: &mut [u8], idx: usize, v: u8) {
        b.scalar_store_u8(dst, idx, v);
    }

    #[inline(always)]
    fn min_s<B: Backend>(b: &mut B, x: u8, y: u8) -> u8 {
        b.scalar_min_u8(x, y)
    }

    #[inline(always)]
    fn max_s<B: Backend>(b: &mut B, x: u8, y: u8) -> u8 {
        b.scalar_max_u8(x, y)
    }

    fn transpose_image<B: Backend>(b: &mut B, img: ImageView<'_, u8>) -> Image<u8> {
        crate::transpose::transpose_image(b, img)
    }

    fn transpose_image_into<B: Backend>(
        b: &mut B,
        img: ImageView<'_, u8>,
        dst: ImageViewMut<'_, u8>,
    ) {
        crate::transpose::transpose_image_into(b, img, dst);
    }

    fn transpose_band_into<B: Backend>(
        b: &mut B,
        img: ImageView<'_, u8>,
        dst: &mut ImageViewMut<'_, u8>,
        band: std::ops::Range<usize>,
    ) {
        crate::transpose::transpose_band_into(b, img, dst, band);
    }

    #[inline(always)]
    fn sat_sub(self, other: u8) -> u8 {
        self.saturating_sub(other)
    }

    #[inline(always)]
    fn invert(self) -> u8 {
        u8::MAX - self
    }
}

impl MorphPixel for u16 {
    type Vec = U16x8;
    const LANES: usize = 8;
    const DTYPE: &'static str = "u16";

    #[inline(always)]
    fn vload<B: Backend>(b: &mut B, src: &[u16]) -> U16x8 {
        b.vld1q_u16(src)
    }

    #[inline(always)]
    fn vload_unaligned<B: Backend>(b: &mut B, src: &[u16]) -> U16x8 {
        b.vld1q_u16_unaligned(src)
    }

    #[inline(always)]
    fn vstore<B: Backend>(b: &mut B, dst: &mut [u16], v: U16x8) {
        b.vst1q_u16(dst, v);
    }

    #[inline(always)]
    fn vmin<B: Backend>(b: &mut B, x: U16x8, y: U16x8) -> U16x8 {
        b.vminq_u16(x, y)
    }

    #[inline(always)]
    fn vmax<B: Backend>(b: &mut B, x: U16x8, y: U16x8) -> U16x8 {
        b.vmaxq_u16(x, y)
    }

    #[inline(always)]
    fn load<B: Backend>(b: &mut B, src: &[u16], idx: usize) -> u16 {
        b.scalar_load_u16(src, idx)
    }

    #[inline(always)]
    fn store<B: Backend>(b: &mut B, dst: &mut [u16], idx: usize, v: u16) {
        b.scalar_store_u16(dst, idx, v);
    }

    #[inline(always)]
    fn min_s<B: Backend>(b: &mut B, x: u16, y: u16) -> u16 {
        b.scalar_min_u16(x, y)
    }

    #[inline(always)]
    fn max_s<B: Backend>(b: &mut B, x: u16, y: u16) -> u16 {
        b.scalar_max_u16(x, y)
    }

    fn transpose_image<B: Backend>(b: &mut B, img: ImageView<'_, u16>) -> Image<u16> {
        crate::transpose::transpose_image_u16(b, img)
    }

    fn transpose_image_into<B: Backend>(
        b: &mut B,
        img: ImageView<'_, u16>,
        dst: ImageViewMut<'_, u16>,
    ) {
        crate::transpose::transpose_image_u16_into(b, img, dst);
    }

    fn transpose_band_into<B: Backend>(
        b: &mut B,
        img: ImageView<'_, u16>,
        dst: &mut ImageViewMut<'_, u16>,
        band: std::ops::Range<usize>,
    ) {
        crate::transpose::transpose_band_u16_into(b, img, dst, band);
    }

    #[inline(always)]
    fn sat_sub(self, other: u16) -> u16 {
        self.saturating_sub(other)
    }

    #[inline(always)]
    fn invert(self) -> u16 {
        u16::MAX - self
    }
}

/// Which reduction a pass performs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MorphOp {
    /// Windowed minimum.
    Erode,
    /// Windowed maximum.
    Dilate,
}

impl MorphOp {
    /// The reduction identity — the padding value for out-of-image taps
    /// (dtype MAX for erode, dtype MIN for dilate).
    #[inline(always)]
    pub fn identity<P: MorphPixel>(self) -> P {
        match self {
            MorphOp::Erode => P::MAX_VALUE,
            MorphOp::Dilate => P::MIN_VALUE,
        }
    }

    /// Scalar combine (accounted through the backend).
    #[inline(always)]
    pub fn scalar<P: MorphPixel, B: Backend>(self, b: &mut B, x: P, y: P) -> P {
        match self {
            MorphOp::Erode => P::min_s(b, x, y),
            MorphOp::Dilate => P::max_s(b, x, y),
        }
    }

    /// Vector combine (accounted through the backend).  `P` is not
    /// inferable from `P::Vec` alone, so call sites use
    /// `op.simd::<P, _>(..)`.
    #[inline(always)]
    pub fn simd<P: MorphPixel, B: Backend>(self, b: &mut B, x: P::Vec, y: P::Vec) -> P::Vec {
        match self {
            MorphOp::Erode => P::vmin(b, x, y),
            MorphOp::Dilate => P::vmax(b, x, y),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MorphOp::Erode => "erode",
            MorphOp::Dilate => "dilate",
        }
    }

    /// The dual operation (erosion ↔ dilation).
    pub fn dual(self) -> MorphOp {
        match self {
            MorphOp::Erode => MorphOp::Dilate,
            MorphOp::Dilate => MorphOp::Erode,
        }
    }
}

/// Per-pass algorithm selection (paper §5).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PassMethod {
    /// O(w) combines per pixel, branch-free, SIMD-perfect (§5.1.2/§5.2.2).
    Linear,
    /// van Herk/Gil-Werman: O(1) combines per pixel, 2× extra memory
    /// (§5.1.1).
    Vhgw,
    /// §5.3 policy: Linear below the crossover threshold, Vhgw above.
    Hybrid,
}

impl PassMethod {
    pub fn name(self) -> &'static str {
        match self {
            PassMethod::Linear => "linear",
            PassMethod::Vhgw => "vhgw",
            PassMethod::Hybrid => "hybrid",
        }
    }
}

/// How the vertical (cols-window) pass is realized (paper §5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum VerticalStrategy {
    /// §5.2.1 baseline: transpose → rows pass → transpose, reusing the
    /// SIMD-friendly horizontal code and the §4 NEON transpose tiles
    /// (16×16.8 for u8, 8×8.16 for u16 — dispatched through
    /// [`MorphPixel::transpose_image`]).
    Transpose,
    /// §5.2.2: operate in place with offset (unaligned) loads.
    Direct,
}

impl VerticalStrategy {
    pub fn name(self) -> &'static str {
        match self {
            VerticalStrategy::Transpose => "transpose",
            VerticalStrategy::Direct => "direct",
        }
    }
}

/// Border handling.  The whole stack's canonical semantics is
/// [`Border::Identity`]; [`Border::Replicate`] is provided as an
/// extension (implemented by pre-padding with replicated edges).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Border {
    /// Out-of-image taps contribute the reduction identity (dtype MAX
    /// for erode, dtype MIN for dilate) — reduction over the
    /// window∩image.
    Identity,
    /// Out-of-image taps replicate the nearest edge pixel.
    Replicate,
}

/// Intra-image band-sharding policy for *native* executions (the
/// generic, backend-accounted [`separable::morphology`] is always
/// sequential so counted instruction mixes stay deterministic; banding
/// applies to [`parallel::filter_native`] and everything routed through
/// it — `erode`/`dilate`, the `NativeEngine`, the coordinator workers).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Parallelism {
    /// Never shard: one thread per pass.
    Sequential,
    /// Always shard into exactly this many bands (1 = sequential).
    Fixed(usize),
    /// Cost-model crossover: shard only when the modeled parallel price
    /// (compute ÷ P, memory unscaled, plus fork overhead) beats the
    /// sequential price by ≥10%, with the band count the model picks
    /// (see [`crate::costmodel::CostModel::plan_workers`]).
    Auto,
}

/// Image-representation dispatch for binary-eligible plans (the RLE
/// scenario engine, arXiv 1504.01052).  A plan built with `Rle` or
/// `Auto` probes its source at *run* time: a 0/255 image converts to
/// per-row foreground intervals and the whole morph chain runs as
/// interval arithmetic ([`rle`]), bit-identical to the dense passes; a
/// non-binary image silently falls back to the dense path.  `Auto`
/// additionally asks the cost model
/// ([`crate::costmodel::CostModel::rle_speedup`]) whether interval
/// arithmetic beats the dense passes at the *measured* foreground
/// density and only then switches representation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Representation {
    /// Always run the dense separable passes (the paper's path).
    Dense,
    /// Run binary sources as run-length interval arithmetic; dense
    /// fallback for non-binary sources.
    Rle,
    /// Cost-model dispatch: RLE only when the modeled interval price at
    /// the measured density beats the dense price.
    Auto,
}

impl Representation {
    pub fn name(self) -> &'static str {
        match self {
            Representation::Dense => "dense",
            Representation::Rle => "rle",
            Representation::Auto => "auto",
        }
    }
}

impl std::str::FromStr for Representation {
    type Err = String;

    /// `dense` / `rle` / `auto` — the CLI `--repr` values.
    fn from_str(s: &str) -> Result<Representation, String> {
        Ok(match s.trim() {
            "dense" => Representation::Dense,
            "rle" => Representation::Rle,
            "auto" => Representation::Auto,
            other => return Err(format!("unknown representation {other:?} (dense|rle|auto)")),
        })
    }
}

/// Full configuration of a separable morphology invocation.  `Eq` +
/// `Hash` so it can ride inside [`FilterSpec`] batch/plan-cache keys.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MorphConfig {
    pub method: PassMethod,
    pub vertical: VerticalStrategy,
    /// Use the SIMD implementations (false = the paper's "without SIMD"
    /// baselines).
    pub simd: bool,
    pub border: Border,
    /// Crossover thresholds used when `method == Hybrid`.
    pub thresholds: HybridThresholds,
    /// Intra-image band-sharding policy (native executions only).
    pub parallelism: Parallelism,
    /// Dense vs run-length representation dispatch for binary-eligible
    /// plans (see [`Representation`]).
    pub representation: Representation,
}

impl Default for MorphConfig {
    /// The paper's §5.3 "final fast morphology implementation": hybrid
    /// dispatch; the vertical pass resolves to the *direct* §5.2.2 form
    /// for linear windows (below the crossover) and to the §5.2.1
    /// transpose sandwich for vHGW windows (above it) — vHGW always
    /// sandwiches regardless of this setting.  `Direct` measured
    /// 1.7-3.5x faster end-to-end than forcing the sandwich for linear
    /// too (EXPERIMENTS.md §Perf, iteration 1).
    fn default() -> Self {
        MorphConfig {
            method: PassMethod::Hybrid,
            vertical: VerticalStrategy::Direct,
            simd: true,
            border: Border::Identity,
            thresholds: HybridThresholds::paper(),
            parallelism: Parallelism::Auto,
            representation: Representation::Dense,
        }
    }
}

/// A region of interest: the `height × width` rectangle whose top-left
/// corner sits at image coordinates `(y, x)`.
///
/// ROI filtering ([`separable::erode_roi`] / [`separable::dilate_roi`]
/// / [`parallel::filter_roi`]) computes exactly the pixels
/// `crop(filter(full), roi)` would produce — the implementation filters
/// a borrowed haloed sub-view of the source, so all reads and compute
/// are bounded by `(height + w_y - 1) × (width + w_x - 1)` pixels
/// rather than the full image.
///
/// Parses from the CLI shape `"Y,X,H,W"` (`--roi 10,20,100,200`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Roi {
    pub y: usize,
    pub x: usize,
    pub height: usize,
    pub width: usize,
}

impl Roi {
    pub fn new(y: usize, x: usize, height: usize, width: usize) -> Roi {
        Roi {
            y,
            x,
            height,
            width,
        }
    }

    /// The whole-image ROI.
    pub fn full(height: usize, width: usize) -> Roi {
        Roi::new(0, 0, height, width)
    }
}

impl std::str::FromStr for Roi {
    type Err = String;

    /// `"Y,X,H,W"` — four comma-separated non-negative integers.
    fn from_str(s: &str) -> Result<Roi, String> {
        let parts: Vec<&str> = s.split(',').map(str::trim).collect();
        if parts.len() != 4 {
            return Err(format!("expected Y,X,H,W, got {s:?}"));
        }
        let mut nums = [0usize; 4];
        for (slot, part) in nums.iter_mut().zip(&parts) {
            *slot = part
                .parse()
                .map_err(|_| format!("invalid ROI component {part:?} in {s:?}"))?;
        }
        Ok(Roi::new(nums[0], nums[1], nums[2], nums[3]))
    }
}

/// Validate an odd window size, returning its wing.
pub(crate) fn wing_of(window: usize, what: &str) -> usize {
    assert!(
        window >= 1 && window % 2 == 1,
        "{what} window must be odd and >= 1, got {window}"
    );
    window / 2
}

/// Pre-pad a view by (wing_x, wing_y) replicated edges — the
/// [`Border::Replicate`] lowering.  The result is filtered with identity
/// borders and cropped back by the caller.
pub(crate) fn replicate_pad<P: Pixel>(
    img: ImageView<'_, P>,
    wing_x: usize,
    wing_y: usize,
) -> Image<P> {
    let (h, w) = (img.height(), img.width());
    if h == 0 || w == 0 {
        return img.to_image();
    }
    let mut out = Image::zeros(h + 2 * wing_y, w + 2 * wing_x);
    replicate_pad_into(img, wing_x, wing_y, out.view_mut());
    out
}

/// [`replicate_pad`] writing into a caller-provided
/// `(h + 2·wing_y) × (w + 2·wing_x)` destination — the allocation-free
/// form [`plan::FilterPlan`] stages its replicate borders through.
/// Single source of the replicate clamping semantics.
pub(crate) fn replicate_pad_into<P: Pixel>(
    src: ImageView<'_, P>,
    wing_x: usize,
    wing_y: usize,
    mut dst: ImageViewMut<'_, P>,
) {
    let (h, w) = (src.height(), src.width());
    debug_assert_eq!(
        (dst.height(), dst.width()),
        (h + 2 * wing_y, w + 2 * wing_x)
    );
    if h == 0 || w == 0 {
        return;
    }
    for y in 0..h + 2 * wing_y {
        let sy = y.saturating_sub(wing_y).min(h - 1);
        let drow = dst.row_mut(y);
        let srow = src.row(sy);
        for (x, slot) in drow.iter_mut().enumerate() {
            let sx = x.saturating_sub(wing_x).min(w - 1);
            *slot = srow[sx];
        }
    }
}

/// Crop the `h × w` region starting at (wing_y, wing_x) — a borrowed
/// sub-rectangle materialized compactly.
pub(crate) fn crop<P: Pixel>(
    img: ImageView<'_, P>,
    wing_y: usize,
    wing_x: usize,
    h: usize,
    w: usize,
) -> Image<P> {
    img.sub_rect(wing_y, wing_x, h, w).to_image()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_values_per_depth() {
        assert_eq!(MorphOp::Erode.identity::<u8>(), 255);
        assert_eq!(MorphOp::Dilate.identity::<u8>(), 0);
        assert_eq!(MorphOp::Erode.identity::<u16>(), 65535);
        assert_eq!(MorphOp::Dilate.identity::<u16>(), 0);
        assert_eq!(MorphOp::Erode.dual(), MorphOp::Dilate);
    }

    #[test]
    fn lane_constants_match_paper_tiles() {
        // §4: 16×16 tiles of 8-bit elements, 8×8 tiles of 16-bit ones
        assert_eq!(<u8 as MorphPixel>::LANES, 16);
        assert_eq!(<u16 as MorphPixel>::LANES, 8);
        assert_eq!(<u8 as MorphPixel>::DTYPE, "u8");
        assert_eq!(<u16 as MorphPixel>::DTYPE, "u16");
    }

    #[test]
    fn sat_sub_and_invert() {
        assert_eq!(MorphPixel::sat_sub(3u8, 5u8), 0);
        assert_eq!(MorphPixel::sat_sub(5u16, 3u16), 2);
        assert_eq!(7u8.invert(), 248);
        assert_eq!(7u16.invert(), 65528);
    }

    #[test]
    #[should_panic(expected = "window must be odd")]
    fn even_window_rejected() {
        wing_of(4, "test");
    }

    #[test]
    fn replicate_pad_and_crop_round_trip() {
        let img = Image::from_fn(3, 4, |y, x| (10 * y + x) as u8);
        let p = replicate_pad(img.view(), 2, 1);
        assert_eq!(p.height(), 5);
        assert_eq!(p.width(), 8);
        assert_eq!(p.get(0, 0), img.get(0, 0)); // corner replication
        assert_eq!(p.get(0, 7), img.get(0, 3));
        assert_eq!(p.get(4, 0), img.get(2, 0));
        let c = crop(p.view(), 1, 2, 3, 4);
        assert!(c.same_pixels(&img));
    }

    #[test]
    fn replicate_pad_works_on_u16() {
        let img = Image::from_fn(2, 2, |y, x| (1000 * y + x) as u16);
        let p = replicate_pad(img.view(), 1, 1);
        assert_eq!(p.get(0, 0), img.get(0, 0));
        assert_eq!(p.get(3, 3), img.get(1, 1));
        assert!(crop(p.view(), 1, 1, 2, 2).same_pixels(&img));
    }

    #[test]
    fn roi_parses_from_cli_shape() {
        let r: Roi = "10,20,100,200".parse().unwrap();
        assert_eq!(r, Roi::new(10, 20, 100, 200));
        let r: Roi = " 0, 0, 5, 6 ".parse().unwrap();
        assert_eq!(r, Roi::new(0, 0, 5, 6));
        assert!("1,2,3".parse::<Roi>().is_err());
        assert!("1,2,3,x".parse::<Roi>().is_err());
        assert_eq!(Roi::full(4, 7), Roi::new(0, 0, 4, 7));
    }

    #[test]
    fn default_config_is_paper_final() {
        let c = MorphConfig::default();
        assert_eq!(c.method, PassMethod::Hybrid);
        assert_eq!(c.vertical, VerticalStrategy::Direct);
        assert!(c.simd);
        assert_eq!(c.thresholds.wy0, PAPER_WY0);
        assert_eq!(c.thresholds.wx0, PAPER_WX0);
        // banding is opportunistic by default: the cost-model crossover
        // keeps small images sequential, results stay bit-identical
        assert_eq!(c.parallelism, Parallelism::Auto);
        // the dense paper path stays the default; RLE is opt-in per spec
        assert_eq!(c.representation, Representation::Dense);
    }

    #[test]
    fn representation_parses_from_cli_names() {
        for r in [Representation::Dense, Representation::Rle, Representation::Auto] {
            assert_eq!(r.name().parse::<Representation>().unwrap(), r);
        }
        assert!("sparse".parse::<Representation>().is_err());
    }
}
