//! Binary morphology — extension on top of the paper's gray-scale
//! operators.
//!
//! Document-recognition pipelines (the paper's motivating domain)
//! typically binarize before structural analysis.  For 0/255 images,
//! gray erosion/dilation specialize to set erosion/dilation, so the fast
//! §5.3 hybrid machinery is reused unchanged; this module adds the
//! binarization boundary and the common binary compositions.
//!
//! The compositions ([`open_binary`], [`close_binary`], [`boundary`])
//! run through one-shot [`FilterSpec`] plans — the same plan layer that
//! serves every other multi-step pipeline, with its arena-owned
//! intermediates — instead of hand-chaining backend calls (the historic
//! plan bypass).  Outputs are bit-identical to the composed calls; the
//! single-step wrappers ([`erode_binary`], [`dilate_binary`]) stay
//! backend-generic so counting backends can still price them.
//! Thresholding ([`threshold`], [`otsu_threshold`]) remains a pre-step
//! outside the plan.

use super::{morphology, FilterOp, FilterSpec, MorphConfig, MorphOp, PlanError};
use crate::image::{Image, ImageView};
use crate::neon::Backend;

/// Foreground value of a binary image (background is 0).
pub const FG: u8 = 255;

/// Threshold to a binary image: `>= thresh` → foreground.
pub fn threshold<'a>(src: impl Into<ImageView<'a, u8>>, thresh: u8) -> Image<u8> {
    let src = src.into();
    Image::from_fn(src.height(), src.width(), |y, x| {
        if src.get(y, x) >= thresh {
            FG
        } else {
            0
        }
    })
}

/// Otsu's threshold (maximal between-class variance) — the standard
/// automatic binarizer for document images.
pub fn otsu_threshold<'a>(src: impl Into<ImageView<'a, u8>>) -> u8 {
    let src = src.into();
    let mut hist = [0u64; 256];
    for y in 0..src.height() {
        for &v in src.row(y) {
            hist[v as usize] += 1;
        }
    }
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 128;
    }
    let sum_all: f64 = hist.iter().enumerate().map(|(v, &c)| v as f64 * c as f64).sum();
    let (mut w_bg, mut sum_bg) = (0f64, 0f64);
    let (mut best_t, mut best_var) = (128u8, -1f64);
    for t in 0..256 {
        w_bg += hist[t] as f64;
        if w_bg == 0.0 {
            continue;
        }
        let w_fg = total as f64 - w_bg;
        if w_fg == 0.0 {
            break;
        }
        sum_bg += t as f64 * hist[t] as f64;
        let mean_bg = sum_bg / w_bg;
        let mean_fg = (sum_all - sum_bg) / w_fg;
        let var = w_bg * w_fg * (mean_bg - mean_fg) * (mean_bg - mean_fg);
        if var > best_var {
            best_var = var;
            best_t = t as u8;
        }
    }
    best_t.saturating_add(1)
}

/// True iff every pixel is 0 or [`FG`].
pub fn is_binary<'a>(img: impl Into<ImageView<'a, u8>>) -> bool {
    let img = img.into();
    (0..img.height()).all(|y| img.row(y).iter().all(|&v| v == 0 || v == FG))
}

/// Binary erosion: foreground survives only where the whole SE fits.
pub fn erode_binary<'a, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, u8>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<u8> {
    let src = src.into();
    debug_assert!(is_binary(src), "erode_binary expects a 0/255 image");
    morphology(b, src, MorphOp::Erode, w_x, w_y, cfg)
}

/// Binary dilation: foreground grows by the SE footprint.
pub fn dilate_binary<'a, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, u8>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<u8> {
    let src = src.into();
    debug_assert!(is_binary(src), "dilate_binary expects a 0/255 image");
    morphology(b, src, MorphOp::Dilate, w_x, w_y, cfg)
}

/// Run a binary composition as a one-shot [`FilterSpec`] plan.  The
/// 0/255 precondition is enforced in release builds too — a gray image
/// here means the caller skipped binarization, and the "binary" result
/// would silently be gray morphology.
fn run_composition(
    src: ImageView<'_, u8>,
    op: FilterOp,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Result<Image<u8>, PlanError> {
    if !is_binary(src) {
        return Err(PlanError(format!(
            "binary {} composition expects a 0/255 image",
            op.name()
        )));
    }
    FilterSpec::new(op, w_x, w_y).with_config(*cfg).run_once(src)
}

/// Remove foreground components thinner than the SE (binary opening).
/// One [`FilterSpec`] plan (erode → dilate, arena-owned intermediate).
/// Errors on non-binary input or an invalid window.
pub fn open_binary<'a>(
    src: impl Into<ImageView<'a, u8>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Result<Image<u8>, PlanError> {
    run_composition(src.into(), FilterOp::Open, w_x, w_y, cfg)
}

/// Fill background gaps thinner than the SE (binary closing).  One
/// [`FilterSpec`] plan (dilate → erode, arena-owned intermediate).
/// Errors on non-binary input or an invalid window.
pub fn close_binary<'a>(
    src: impl Into<ImageView<'a, u8>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Result<Image<u8>, PlanError> {
    run_composition(src.into(), FilterOp::Close, w_x, w_y, cfg)
}

/// Boundary extraction: src − erosion (one-SE-thick outline).  The
/// erosion runs as a one-shot [`FilterSpec`] plan; the subtraction has
/// no single [`FilterOp`], so it stays a pixelwise post-step.  Errors on
/// non-binary input or an invalid window.
pub fn boundary<'a>(
    src: impl Into<ImageView<'a, u8>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Result<Image<u8>, PlanError> {
    let src = src.into();
    let e = run_composition(src, FilterOp::Erode, w_x, w_y, cfg)?;
    Ok(Image::from_fn(src.height(), src.width(), |y, x| {
        src.get(y, x).saturating_sub(e.get(y, x))
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::neon::Native;

    fn cfg() -> MorphConfig {
        MorphConfig::default()
    }

    fn square(n: usize, y0: usize, x0: usize, side: usize) -> Image<u8> {
        Image::from_fn(n, n, |y, x| {
            if (y0..y0 + side).contains(&y) && (x0..x0 + side).contains(&x) {
                FG
            } else {
                0
            }
        })
    }

    #[test]
    fn threshold_splits_at_value() {
        let img = Image::from_vec(1, 4, vec![0u8, 99, 100, 255]);
        let t = threshold(&img, 100);
        assert_eq!(t.to_vec(), vec![0, 0, FG, FG]);
        assert!(is_binary(&t));
    }

    #[test]
    fn otsu_separates_bimodal() {
        // bimodal image: dark text (~30) on light paper (~220)
        let img = Image::from_fn(40, 40, |y, x| if (y + x) % 5 == 0 { 30 } else { 220 });
        let t = otsu_threshold(&img);
        assert!(t > 30 && t <= 220, "otsu threshold {t}");
        let b = threshold(&img, t);
        assert!(is_binary(&b));
        assert_eq!(b.get(0, 0), 0); // dark -> background
        assert_eq!(b.get(0, 1), FG); // light -> foreground
    }

    #[test]
    fn binary_erosion_shrinks_by_wing() {
        let img = square(20, 5, 5, 8); // 8x8 square
        let e = erode_binary(&mut Native, &img, 3, 3, &cfg());
        // 3x3 SE removes a 1-pixel rim: 6x6 survives at (6,6)
        let want = square(20, 6, 6, 6);
        assert!(e.same_pixels(&want), "{:?}", e.first_diff(&want));
    }

    #[test]
    fn binary_dilation_grows_by_wing() {
        let img = square(20, 8, 8, 4);
        let d = dilate_binary(&mut Native, &img, 3, 3, &cfg());
        let want = square(20, 7, 7, 6);
        assert!(d.same_pixels(&want));
    }

    #[test]
    fn opening_removes_thin_bridge() {
        // two 5x5 blobs joined by a 1-px bridge; 3x3 opening cuts the bridge
        let mut img = square(20, 3, 2, 5);
        let right = square(20, 3, 12, 5);
        for y in 0..20 {
            for x in 0..20 {
                if right.get(y, x) == FG {
                    img.set(y, x, FG);
                }
            }
        }
        for x in 7..12 {
            img.set(5, x, FG); // the bridge
        }
        let opened = open_binary(&img, 3, 3, &cfg()).unwrap();
        assert_eq!(opened.get(5, 9), 0, "bridge must be cut");
        assert_eq!(opened.get(5, 4), FG, "left blob survives");
        assert_eq!(opened.get(5, 14), FG, "right blob survives");
    }

    #[test]
    fn closing_fills_small_hole() {
        let mut img = square(20, 4, 4, 10);
        img.set(8, 8, 0); // pinhole
        let closed = close_binary(&img, 3, 3, &cfg()).unwrap();
        assert_eq!(closed.get(8, 8), FG);
    }

    #[test]
    fn boundary_is_one_pixel_ring() {
        let img = square(21, 5, 5, 9);
        let ring = boundary(&img, 3, 3, &cfg()).unwrap();
        assert_eq!(ring.get(5, 5), FG); // corner on the ring
        assert_eq!(ring.get(9, 9), 0); // interior removed
        assert_eq!(ring.get(0, 0), 0); // background stays empty
    }

    #[test]
    fn plan_routed_compositions_match_hand_chained_calls() {
        // the closed plan bypass: one-shot FilterSpec plans must be
        // bit-identical to composing the backend-generic single steps
        let page = synth::document(60, 80, 4);
        let bin = threshold(&page, otsu_threshold(&page));
        for (wx, wy) in [(3usize, 3usize), (5, 3), (3, 7)] {
            let e = erode_binary(&mut Native, &bin, wx, wy, &cfg());
            let d = dilate_binary(&mut Native, &bin, wx, wy, &cfg());
            let open_want = dilate_binary(&mut Native, &e, wx, wy, &cfg());
            let close_want = erode_binary(&mut Native, &d, wx, wy, &cfg());
            assert!(
                open_binary(&bin, wx, wy, &cfg()).unwrap().same_pixels(&open_want),
                "open {wx}x{wy}"
            );
            assert!(
                close_binary(&bin, wx, wy, &cfg()).unwrap().same_pixels(&close_want),
                "close {wx}x{wy}"
            );
            let ring_want = Image::from_fn(bin.height(), bin.width(), |y, x| {
                bin.get(y, x).saturating_sub(e.get(y, x))
            });
            assert!(
                boundary(&bin, wx, wy, &cfg()).unwrap().same_pixels(&ring_want),
                "boundary {wx}x{wy}"
            );
        }
    }

    #[test]
    fn compositions_reject_bad_inputs_as_errors() {
        // gray input: the 0/255 precondition holds in release builds too
        let gray = synth::noise(16, 16, 3);
        assert!(!is_binary(&gray));
        assert!(open_binary(&gray, 3, 3, &cfg()).is_err());
        assert!(close_binary(&gray, 3, 3, &cfg()).is_err());
        assert!(boundary(&gray, 3, 3, &cfg()).is_err());
        // invalid windows surface as plan errors, not panics
        let bin = square(16, 4, 4, 6);
        assert!(open_binary(&bin, 4, 4, &cfg()).is_err());
    }

    #[test]
    fn pipeline_binarize_then_clean_document() {
        let page = synth::document(120, 160, 9);
        let t = otsu_threshold(&page);
        let bin = threshold(&page, t);
        let cleaned = close_binary(&bin, 3, 3, &cfg()).unwrap();
        assert!(is_binary(&cleaned));
        // structure preserved: still has both classes
        let (mn, mx) = cleaned.min_max().unwrap();
        assert_eq!((mn, mx), (0, FG));
    }
}
