//! Plan–execute morphology (the crate's one-description API).
//!
//! A [`FilterSpec`] is a *depth-generic, allocation-free* description of
//! a morphological pipeline: an op chain ([`FilterOp`] — the primitive
//! `Erode`/`Dilate` plus every derived op, each lowered to primitive
//! erode/dilate/subtract steps), one `w_x × w_y` structuring element, a
//! [`MorphConfig`] (method, vertical strategy, SIMD, border, hybrid
//! thresholds, parallelism hint) and an optional [`Roi`].  `FilterSpec`
//! is `Copy + Eq + Hash`, so it doubles as a batch/plan cache key with
//! no per-use heap allocation.
//!
//! [`FilterSpec::plan`] resolves the spec **once** against a concrete
//! pixel depth and image shape into a [`FilterPlan`]:
//!
//! * every hybrid pass choice is resolved to a concrete
//!   [`PassMethod`] (rows against `wy0`, cols against `wx0`),
//! * the §5.2.1 transpose-sandwich predicate is evaluated per cols pass,
//! * the band count is fixed by the cost-model crossover
//!   ([`super::parallel::effective_bands`]) for the plan's shape,
//! * the ROI is expanded to its haloed block (halo = chain morph-depth ×
//!   wing, clamped at the image edges — the 2-D halo-containment
//!   argument of [`super::parallel::filter_roi`] lifted to chains), and
//! * a **scratch arena** is preallocated: per-slot intermediate images,
//!   the rows→cols buffer, the two transpose-sandwich buffers, the
//!   replicate-border staging pair, and the per-band vHGW `R`-buffer
//!   slots (the algorithm's "2× extra memory", grown to their
//!   high-water mark on the first run).
//!
//! [`FilterPlan::run`] / [`FilterPlan::run_owned`] then execute the
//! resolved steps with the zero-copy `_into` kernels, reusing the arena
//! on every call: after the first run, a reused plan allocates **zero
//! per-call heap bytes** for *any* method — vHGW's image-sized `R`
//! buffer and the cols linear kernel's row-sized staging buffer both
//! live in the arena's per-band scratch slots (pinned by
//! `rust/tests/zero_copy_alloc.rs`).
//!
//! ## Position independence
//!
//! Plan resolution is a function of the ROI's haloed-block **shape**,
//! never of its absolute origin: [`FilterPlan::run`] executes the
//! spec's own ROI, and [`FilterPlan::run_at`] takes the block origin at
//! call time, so **one plan serves every interior position of a
//! same-shape crop sweep** (an edge-clamped position resolves different
//! block geometry and keeps its own plan).
//! [`FilterSpec::canonical_for`] is the cache-key side of the same
//! rule: it rewrites interior ROIs to the canonical anchor
//! `(halo_y, halo_x)`, which is how the engine's plan cache and the
//! coordinator's plan-pinned workers collapse an ROI sweep to a single
//! resolution (asserted by the hit-count tests in
//! `rust/src/runtime/engine.rs` and the `BENCH_serve.json` headline).
//!
//! ## Bit-identity contract
//!
//! For every spec, `FilterPlan::run` is bit-identical to composing the
//! legacy entry points (`erode`/`dilate`/`opening`/…/`filter_roi`) with
//! the same configuration — the plan executes the *same* resolved
//! kernels over the same values, banding is bit-identical to sequential
//! by the halo argument, and the ROI block reproduces
//! `crop(chain(full), roi)` exactly.  The legacy entry points are thin
//! wrappers over one-shot plans (see [`super::parallel::filter_native`])
//! and `rust/tests/plan_equivalence.rs` pins the equivalence across
//! op × method × vertical × simd × border × depth × ROI.
//!
//! ## Counted (instruction-accounted) runs
//!
//! Plans always execute at native speed.  Counting-backend runs keep
//! using the generic sequential composition ([`run_chain`] →
//! [`super::separable::morphology`]) so instruction mixes stay
//! deterministic; both paths execute the same lowered step sequence
//! ([`lower`]), which is the single source of derived-op structure.

use std::fmt;

use super::hybrid::resolve_method;
use super::{
    derived, geodesic, parallel, rle, separable, Border, MorphConfig, MorphOp, MorphPixel,
    PassMethod, Representation, Roi,
};
use crate::image::{Image, ImageView, ImageViewMut};
use crate::neon::{Backend, Native};

/// Maximum ops in one [`FilterSpec`] chain (keeps the spec `Copy` and
/// heap-free; derived ops count as one entry each).
pub const MAX_CHAIN: usize = 8;

/// One high-level operation of a [`FilterSpec`] chain.  Derived ops are
/// lowered to primitive erode/dilate/subtract steps by [`lower`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum FilterOp {
    /// Windowed minimum.
    Erode,
    /// Windowed maximum.
    Dilate,
    /// Opening: dilation of the erosion.
    Open,
    /// Closing: erosion of the dilation.
    Close,
    /// Morphological gradient: dilation − erosion.
    Gradient,
    /// White top-hat: src − opening.
    TopHat,
    /// Black top-hat: closing − src.
    BlackHat,
    /// Whole-image §4 tiled transpose (must be the only chain element;
    /// ignores the window; output shape is `w × h`).
    Transpose,
    /// Morphological reconstruction by dilation: iterate geodesic
    /// dilations of a **marker** under the request image (the mask) to
    /// stability, with the spec's `w_x × w_y` SE per sweep (see
    /// [`super::geodesic`]).  Must be the only chain element; carries a
    /// second image — the marker — through the request path, so it is
    /// served via [`FilterPlan::run_reconstruct`] rather than
    /// [`FilterPlan::run`].
    Reconstruct,
}

impl FilterOp {
    /// Canonical name (the coordinator's historical op strings).
    pub fn name(self) -> &'static str {
        match self {
            FilterOp::Erode => "erode",
            FilterOp::Dilate => "dilate",
            FilterOp::Open => "opening",
            FilterOp::Close => "closing",
            FilterOp::Gradient => "gradient",
            FilterOp::TopHat => "tophat",
            FilterOp::BlackHat => "blackhat",
            FilterOp::Transpose => "transpose",
            FilterOp::Reconstruct => "reconstruct",
        }
    }

    /// Longest erode/dilate dependency chain through this op — the ROI
    /// halo of a chain is `Σ morph_depth × wing` per axis.
    fn morph_depth(self) -> usize {
        match self {
            FilterOp::Erode | FilterOp::Dilate | FilterOp::Gradient => 1,
            FilterOp::Open | FilterOp::Close | FilterOp::TopHat | FilterOp::BlackHat => 2,
            // transpose moves no windows; reconstruct iterates to an
            // unbounded depth — both reject ROIs at validation, so
            // neither contributes halo
            FilterOp::Transpose | FilterOp::Reconstruct => 0,
        }
    }

    /// Every op, in declaration order (test sweeps).
    pub const ALL: [FilterOp; 9] = [
        FilterOp::Erode,
        FilterOp::Dilate,
        FilterOp::Open,
        FilterOp::Close,
        FilterOp::Gradient,
        FilterOp::TopHat,
        FilterOp::BlackHat,
        FilterOp::Transpose,
        FilterOp::Reconstruct,
    ];
}

impl fmt::Display for FilterOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for FilterOp {
    type Err = PlanError;

    fn from_str(s: &str) -> Result<FilterOp, PlanError> {
        Ok(match s {
            "erode" => FilterOp::Erode,
            "dilate" => FilterOp::Dilate,
            "open" | "opening" => FilterOp::Open,
            "close" | "closing" => FilterOp::Close,
            "gradient" => FilterOp::Gradient,
            "tophat" => FilterOp::TopHat,
            "blackhat" => FilterOp::BlackHat,
            "transpose" => FilterOp::Transpose,
            "reconstruct" => FilterOp::Reconstruct,
            other => return Err(PlanError(format!("unknown op {other:?}"))),
        })
    }
}

/// Fixed-capacity op chain — `Copy`, `Eq`, `Hash`, no heap.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct OpChain {
    len: u8,
    ops: [FilterOp; MAX_CHAIN],
}

impl OpChain {
    /// A one-op chain.
    pub fn single(op: FilterOp) -> OpChain {
        // the fill value beyond `len` must be the same canonical op as
        // `from_slice` uses, or Eq/Hash would distinguish identically
        // built chains
        let mut ops = [FilterOp::Erode; MAX_CHAIN];
        ops[0] = op;
        OpChain { len: 1, ops }
    }

    /// A chain from a slice (1..=[`MAX_CHAIN`] ops).
    pub fn from_slice(ops: &[FilterOp]) -> Result<OpChain, PlanError> {
        if ops.is_empty() {
            return Err(PlanError("op chain must not be empty".into()));
        }
        if ops.len() > MAX_CHAIN {
            return Err(PlanError(format!(
                "op chain of {} exceeds MAX_CHAIN = {MAX_CHAIN}",
                ops.len()
            )));
        }
        // the fill value beyond `len` is fixed so Eq/Hash see one
        // canonical representation
        let mut chain = OpChain {
            len: ops.len() as u8,
            ops: [FilterOp::Erode; MAX_CHAIN],
        };
        chain.ops[..ops.len()].copy_from_slice(ops);
        Ok(chain)
    }

    /// Append an op (errors past [`MAX_CHAIN`]).
    pub fn push(&mut self, op: FilterOp) -> Result<(), PlanError> {
        if (self.len as usize) >= MAX_CHAIN {
            return Err(PlanError(format!(
                "op chain already holds MAX_CHAIN = {MAX_CHAIN} ops"
            )));
        }
        self.ops[self.len as usize] = op;
        self.len += 1;
        Ok(())
    }

    pub fn as_slice(&self) -> &[FilterOp] {
        &self.ops[..self.len as usize]
    }

    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl fmt::Debug for OpChain {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_list().entries(self.as_slice()).finish()
    }
}

impl fmt::Display for OpChain {
    /// `erode+dilate` — the batch-key / log rendering.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, op) in self.as_slice().iter().enumerate() {
            if i > 0 {
                f.write_str("+")?;
            }
            f.write_str(op.name())?;
        }
        Ok(())
    }
}

/// Spec validation / planning error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanError(pub String);

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for PlanError {}

/// Depth-generic description of a morphology pipeline: op chain +
/// window + configuration + optional ROI.  `Copy`/`Eq`/`Hash` with no
/// heap allocation — see the module docs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FilterSpec {
    pub ops: OpChain,
    /// SE width (cols window), applied to every op in the chain.
    pub w_x: usize,
    /// SE height (rows window).
    pub w_y: usize,
    pub config: MorphConfig,
    /// Compute only this output rectangle (`crop(chain(full), roi)`).
    pub roi: Option<Roi>,
}

impl FilterSpec {
    /// A single-op spec with the default (§5.3 paper) configuration.
    pub fn new(op: FilterOp, w_x: usize, w_y: usize) -> FilterSpec {
        FilterSpec {
            ops: OpChain::single(op),
            w_x,
            w_y,
            config: MorphConfig::default(),
            roi: None,
        }
    }

    /// A multi-op spec (ops run left to right).
    pub fn chain(ops: &[FilterOp], w_x: usize, w_y: usize) -> Result<FilterSpec, PlanError> {
        Ok(FilterSpec {
            ops: OpChain::from_slice(ops)?,
            w_x,
            w_y,
            config: MorphConfig::default(),
            roi: None,
        })
    }

    /// Append an op to the chain (builder; panics past [`MAX_CHAIN`]).
    pub fn then(mut self, op: FilterOp) -> FilterSpec {
        self.ops.push(op).unwrap_or_else(|e| panic!("{e}"));
        self
    }

    /// Replace the configuration (builder).
    pub fn with_config(mut self, config: MorphConfig) -> FilterSpec {
        self.config = config;
        self
    }

    /// Restrict to a region of interest (builder).
    pub fn with_roi(mut self, roi: Roi) -> FilterSpec {
        self.roi = Some(roi);
        self
    }

    pub fn ops(&self) -> &[FilterOp] {
        self.ops.as_slice()
    }

    /// The chain's single op, if it has exactly one.
    pub fn single_op(&self) -> Option<FilterOp> {
        match self.ops.as_slice() {
            [op] => Some(*op),
            _ => None,
        }
    }

    /// Whether this spec is the whole-image transpose.
    pub fn is_transpose(&self) -> bool {
        self.single_op() == Some(FilterOp::Transpose)
    }

    /// Whether this spec is a morphological reconstruction — the one op
    /// that carries a second (marker) image and is served through
    /// [`FilterPlan::run_reconstruct`].
    pub fn is_reconstruct(&self) -> bool {
        self.single_op() == Some(FilterOp::Reconstruct)
    }

    /// The single op this spec denotes when it is expressible as one
    /// canonical (identity-border, whole-image) kernel — the only form
    /// the AOT artifact pipeline lowers, so this is the shared
    /// eligibility predicate of every compiled-artifact router.  Border
    /// is the one config knob that changes output *pixels*;
    /// method/strategy/parallelism choices are all bit-identical.
    /// Reconstruction is excluded: its iterate-to-stability loop and
    /// marker payload have no single-kernel artifact form.
    pub fn single_identity_op(&self) -> Option<FilterOp> {
        let op = self.single_op()?;
        if self.roi.is_some()
            || self.config.border != Border::Identity
            || op == FilterOp::Reconstruct
        {
            return None;
        }
        Some(op)
    }

    /// The ROI halo this spec's chain needs per axis, `(halo_x,
    /// halo_y)` — morph depth × wing (see [`FilterSpec::morph_depth`]).
    pub fn roi_halo(&self) -> (usize, usize) {
        let depth = self.morph_depth();
        (depth * (self.w_x / 2), depth * (self.w_y / 2))
    }

    /// The **cache-canonical** form of this spec for an `h × w` image —
    /// the position-independence rule of the plan cache.
    ///
    /// A [`FilterPlan`] is position-independent: its resolution (pass
    /// methods, band count, scratch arena) depends only on the ROI's
    /// haloed-block *shape*, and [`FilterPlan::run_at`] takes the block
    /// origin at call time.  An **interior** ROI (full halo on every
    /// side) therefore resolves the same plan at every position, and
    /// this method rewrites it to the canonical anchor
    /// `(halo_y, halo_x)` so a same-shape crop sweep collapses to one
    /// cache key.  Edge-clamped ROIs keep their own position (their
    /// blocks resolve different clamped geometry), as do specs without
    /// a ROI and out-of-bounds ROIs (left for [`FilterSpec::validate`]
    /// to reject).
    pub fn canonical_for(&self, h: usize, w: usize) -> FilterSpec {
        let Some(roi) = self.roi else { return *self };
        if self.ops.as_slice().contains(&FilterOp::Transpose) {
            return *self;
        }
        let (hx, hy) = self.roi_halo();
        if roi_is_interior(roi, h, w, hx, hy) {
            let mut s = *self;
            s.roi = Some(Roi::new(hy, hx, roi.height, roi.width));
            s
        } else {
            *self
        }
    }

    /// Build a single-op spec from an op **name** — the string-typed
    /// client entry point (CLI flags, config files).  Replaces the
    /// removed `Coordinator::filter`/`filter_u16` wrappers: parse once
    /// at the edge (unknown names fail here, before anything is
    /// enqueued), then submit the typed spec.
    ///
    /// ```
    /// use neon_morph::morphology::{FilterOp, FilterSpec};
    /// let spec = FilterSpec::parse_op("erode", 7, 5).unwrap();
    /// assert_eq!(spec.single_op(), Some(FilterOp::Erode));
    /// assert!(FilterSpec::parse_op("sharpen", 3, 3).is_err());
    /// ```
    pub fn parse_op(s: &str, w_x: usize, w_y: usize) -> Result<FilterSpec, PlanError> {
        Ok(FilterSpec::new(s.trim().parse()?, w_x, w_y))
    }

    /// Parse a CLI op chain: `"erode"` or `"erode,dilate,tophat"`.
    pub fn parse_ops(s: &str) -> Result<OpChain, PlanError> {
        let mut chain: Option<OpChain> = None;
        for part in s.split(',') {
            let op: FilterOp = part.trim().parse()?;
            match chain.as_mut() {
                None => chain = Some(OpChain::single(op)),
                Some(c) => c.push(op)?,
            }
        }
        chain.ok_or_else(|| PlanError(format!("empty op chain {s:?}")))
    }

    /// Longest erode/dilate dependency chain through the spec — the ROI
    /// halo per axis is this times the wing.
    pub fn morph_depth(&self) -> usize {
        self.ops.as_slice().iter().map(|o| o.morph_depth()).sum()
    }

    /// Output shape for an `h × w` input (transpose swaps, ROI crops).
    pub fn out_dims(&self, h: usize, w: usize) -> (usize, usize) {
        if self.is_transpose() {
            return (w, h);
        }
        match self.roi {
            Some(r) => (r.height, r.width),
            None => (h, w),
        }
    }

    /// Check the spec against an `h × w` input without building a plan.
    pub fn validate(&self, h: usize, w: usize) -> Result<(), PlanError> {
        if self.ops.is_empty() {
            return Err(PlanError("op chain must not be empty".into()));
        }
        if self.ops.as_slice().contains(&FilterOp::Transpose) {
            if !self.is_transpose() {
                return Err(PlanError(
                    "transpose must be the only op in a chain".into(),
                ));
            }
            if self.roi.is_some() {
                return Err(PlanError("transpose does not support a ROI".into()));
            }
            return Ok(());
        }
        if self.ops.as_slice().contains(&FilterOp::Reconstruct) {
            if !self.is_reconstruct() {
                return Err(PlanError(
                    "reconstruct must be the only op in a chain".into(),
                ));
            }
            if self.roi.is_some() {
                return Err(PlanError("reconstruct does not support a ROI".into()));
            }
            // fall through: the sweep SE windows validate like any
            // other morph spec
        }
        for (window, what) in [(self.w_x, "w_x"), (self.w_y, "w_y")] {
            if window < 1 || window % 2 == 0 {
                return Err(PlanError(format!(
                    "{what} window must be odd and >= 1, got {window}"
                )));
            }
        }
        if let Some(roi) = self.roi {
            // overflow-proof bounds check (fields are caller-supplied)
            let fits = roi.height <= h
                && roi.y <= h - roi.height
                && roi.width <= w
                && roi.x <= w - roi.width;
            if !fits {
                return Err(PlanError(format!("ROI {roi:?} exceeds image {h}x{w}")));
            }
        }
        Ok(())
    }

    /// Resolve the spec against a pixel depth and image shape: one-time
    /// method/strategy/banding resolution + scratch-arena allocation.
    pub fn plan<P: MorphPixel>(&self, h: usize, w: usize) -> Result<FilterPlan<P>, PlanError> {
        FilterPlan::build(*self, h, w)
    }

    /// Resolve the spec against a pixel depth, a per-image shape and an
    /// initial batch capacity into a [`FusedPlan`] — ONE banded
    /// execution over a whole same-spec, same-shape batch (bands span
    /// image boundaries behind per-image halo fences).  Full-image
    /// specs only: a ROI or transpose spec is rejected (those batches
    /// run per image).
    pub fn plan_fused<P: MorphPixel>(
        &self,
        h: usize,
        w: usize,
        n: usize,
    ) -> Result<FusedPlan<P>, PlanError> {
        FusedPlan::build(*self, h, w, n)
    }

    /// Convenience: plan and run once (native speed).
    pub fn run_once<'a, P: MorphPixel>(
        &self,
        src: impl Into<ImageView<'a, P>>,
    ) -> Result<Image<P>, PlanError> {
        let src = src.into();
        let mut plan = self.plan::<P>(src.height(), src.width())?;
        Ok(plan.run_owned(src))
    }
}

/// Whether `roi`'s chain halo fits inside the `h × w` image on every
/// side (overflow-proof; implies the ROI itself is in bounds).  Interior
/// ROIs share one position-independent plan; clamped ones do not.
pub(crate) fn roi_is_interior(roi: Roi, h: usize, w: usize, hx: usize, hy: usize) -> bool {
    roi.y >= hy
        && roi.x >= hx
        && roi.height <= h
        && roi.y <= h - roi.height
        && h - roi.y - roi.height >= hy
        && roi.width <= w
        && roi.x <= w - roi.width
        && w - roi.x - roi.width >= hx
}

/// The haloed source block a ROI resolves to: the ROI grown by
/// `(hx, hy)` per side, clamped at the image edges.  Wherever the halo
/// is clamped the block edge *coincides* with the image edge, which is
/// what makes the block's border handling reproduce the full-image
/// behaviour (the 2-D halo-containment argument; python-verified in
/// `python/tests/test_plan_geometry.py`).
pub(crate) fn haloed_block(roi: Roi, h: usize, w: usize, hx: usize, hy: usize) -> Roi {
    let y0 = roi.y.saturating_sub(hy);
    let x0 = roi.x.saturating_sub(hx);
    let y1 = (roi.y + roi.height + hy).min(h);
    let x1 = (roi.x + roi.width + hx).min(w);
    Roi::new(y0, x0, y1 - y0, x1 - x0)
}

// ---------------------------------------------------------------------------
// lowering: op chain -> primitive steps over virtual slots
// ---------------------------------------------------------------------------

/// A value slot of the lowered program: the borrowed source view or a
/// numbered intermediate (arena-backed in [`FilterPlan`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Slot {
    /// The (possibly ROI-block) source view — read-only.
    Src,
    /// Intermediate image `i`.
    Tmp(usize),
}

/// One primitive step of a lowered chain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimStep {
    /// Separable 2-D erosion/dilation `src → dst`.
    Morph { op: MorphOp, src: Slot, dst: Slot },
    /// Saturating pixelwise subtraction `a − b → dst`.
    Sub { a: Slot, b: Slot, dst: Slot },
}

impl PrimStep {
    fn dst(&self) -> Slot {
        match *self {
            PrimStep::Morph { dst, .. } | PrimStep::Sub { dst, .. } => dst,
        }
    }
}

/// Lower an op chain to primitive steps.  Returns `(steps, tmp_slots)`;
/// the final step's `dst` is the chain output.  Every `dst` is a fresh
/// slot, so steps never overwrite a value still to be read.
pub fn lower(ops: &[FilterOp]) -> (Vec<PrimStep>, usize) {
    let mut steps = Vec::new();
    let mut n = 0usize;
    let fresh = |n: &mut usize| {
        let s = Slot::Tmp(*n);
        *n += 1;
        s
    };
    let mut cur = Slot::Src;
    for &o in ops {
        cur = match o {
            FilterOp::Erode | FilterOp::Dilate => {
                let op = if o == FilterOp::Erode {
                    MorphOp::Erode
                } else {
                    MorphOp::Dilate
                };
                let d = fresh(&mut n);
                steps.push(PrimStep::Morph { op, src: cur, dst: d });
                d
            }
            FilterOp::Open => {
                let e = fresh(&mut n);
                steps.push(PrimStep::Morph {
                    op: MorphOp::Erode,
                    src: cur,
                    dst: e,
                });
                let d = fresh(&mut n);
                steps.push(PrimStep::Morph {
                    op: MorphOp::Dilate,
                    src: e,
                    dst: d,
                });
                d
            }
            FilterOp::Close => {
                let d = fresh(&mut n);
                steps.push(PrimStep::Morph {
                    op: MorphOp::Dilate,
                    src: cur,
                    dst: d,
                });
                let e = fresh(&mut n);
                steps.push(PrimStep::Morph {
                    op: MorphOp::Erode,
                    src: d,
                    dst: e,
                });
                e
            }
            FilterOp::Gradient => {
                let d = fresh(&mut n);
                steps.push(PrimStep::Morph {
                    op: MorphOp::Dilate,
                    src: cur,
                    dst: d,
                });
                let e = fresh(&mut n);
                steps.push(PrimStep::Morph {
                    op: MorphOp::Erode,
                    src: cur,
                    dst: e,
                });
                let s = fresh(&mut n);
                steps.push(PrimStep::Sub { a: d, b: e, dst: s });
                s
            }
            FilterOp::TopHat => {
                let e = fresh(&mut n);
                steps.push(PrimStep::Morph {
                    op: MorphOp::Erode,
                    src: cur,
                    dst: e,
                });
                let o = fresh(&mut n);
                steps.push(PrimStep::Morph {
                    op: MorphOp::Dilate,
                    src: e,
                    dst: o,
                });
                let s = fresh(&mut n);
                steps.push(PrimStep::Sub { a: cur, b: o, dst: s });
                s
            }
            FilterOp::BlackHat => {
                let d = fresh(&mut n);
                steps.push(PrimStep::Morph {
                    op: MorphOp::Dilate,
                    src: cur,
                    dst: d,
                });
                let c = fresh(&mut n);
                steps.push(PrimStep::Morph {
                    op: MorphOp::Erode,
                    src: d,
                    dst: c,
                });
                let s = fresh(&mut n);
                steps.push(PrimStep::Sub {
                    a: c,
                    b: cur,
                    dst: s,
                });
                s
            }
            FilterOp::Transpose => {
                unreachable!("transpose is validated to never reach lowering")
            }
            FilterOp::Reconstruct => {
                unreachable!("reconstruct is validated to never reach lowering")
            }
        };
    }
    (steps, n)
}

/// Execute a lowered chain with a *generic* backend via the sequential
/// composition ([`separable::morphology`]) — the counted path.  The
/// derived ops ([`super::derived`]) are wrappers over this, so counted
/// instruction mixes keep their historical, deterministic shape while
/// the step structure has a single source ([`lower`]).
pub fn run_chain<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    ops: &[FilterOp],
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    let src = src.into();
    assert!(!ops.is_empty(), "op chain must not be empty");
    assert!(
        !ops.contains(&FilterOp::Transpose),
        "transpose has no generic chain form"
    );
    assert!(
        !ops.contains(&FilterOp::Reconstruct),
        "reconstruct has no generic chain form (needs a marker image)"
    );
    let (steps, slots) = lower(ops);
    let mut tmp: Vec<Option<Image<P>>> = (0..slots).map(|_| None).collect();
    for step in &steps {
        match *step {
            PrimStep::Morph { op, src: s, dst } => {
                let out = match s {
                    Slot::Src => separable::morphology(b, src, op, w_x, w_y, cfg),
                    Slot::Tmp(i) => {
                        separable::morphology(b, tmp[i].as_ref().unwrap(), op, w_x, w_y, cfg)
                    }
                };
                let Slot::Tmp(d) = dst else { unreachable!() };
                tmp[d] = Some(out);
            }
            PrimStep::Sub { a, b: bb, dst } => {
                let av = match a {
                    Slot::Src => src,
                    Slot::Tmp(i) => tmp[i].as_ref().unwrap().view(),
                };
                let bv = match bb {
                    Slot::Src => src,
                    Slot::Tmp(i) => tmp[i].as_ref().unwrap().view(),
                };
                let out = derived::pixelwise_sub(av, bv);
                let Slot::Tmp(d) = dst else { unreachable!() };
                tmp[d] = Some(out);
            }
        }
    }
    let Slot::Tmp(last) = steps.last().unwrap().dst() else {
        unreachable!()
    };
    tmp[last].take().unwrap()
}

// ---------------------------------------------------------------------------
// the resolved plan
// ---------------------------------------------------------------------------

/// Resolved rows pass: concrete method (never `Hybrid`).
#[derive(Clone, Copy, Debug)]
struct RowsPass {
    window: usize,
    method: PassMethod,
}

/// Resolved cols pass: concrete method + the §5.2.1 sandwich decision.
#[derive(Clone, Copy, Debug)]
struct ColsPass {
    window: usize,
    method: PassMethod,
    sandwich: bool,
}

/// One executable step of a [`FilterPlan`].
#[derive(Clone, Copy, Debug)]
enum ExecStep {
    Morph {
        op: MorphOp,
        src: Slot,
        dst: Slot,
        rows: Option<RowsPass>,
        cols: Option<ColsPass>,
        bands: usize,
    },
    Sub {
        a: Slot,
        b: Slot,
        dst: Slot,
    },
}

/// Preallocated intermediates, sized once at plan time.
#[derive(Debug)]
struct Scratch<P> {
    /// Block-shaped slot images (`block.h × block.w` each; the final
    /// slot stays empty when the last step writes straight to the
    /// caller's destination).
    slots: Vec<Vec<P>>,
    /// rows→cols intermediate at the execution shape (padded under
    /// [`Border::Replicate`]).
    after_rows: Vec<P>,
    /// §5.2.1 sandwich buffers (transposed execution shape).
    t_a: Vec<P>,
    t_b: Vec<P>,
    /// Replicate-border staging pair (padded shape).
    pad_in: Vec<P>,
    pad_out: Vec<P>,
    /// Per-band vHGW `R`-buffer slots (the algorithm's "2× extra
    /// memory"), grown lazily to each band's high-water mark on the
    /// first run and reused verbatim after — the arena absorbing these
    /// is what makes vHGW-method plans allocation-free on reuse.
    /// Linear-method plans leave them empty.
    vhgw: Vec<Vec<P>>,
}

impl<P: MorphPixel> Scratch<P> {
    /// The all-empty arena (transpose and reconstruct plans own no
    /// step scratch — reconstruct state lives in [`ReconScratch`]).
    fn empty() -> Scratch<P> {
        Scratch {
            slots: Vec::new(),
            after_rows: Vec::new(),
            t_a: Vec::new(),
            t_b: Vec::new(),
            pad_in: Vec::new(),
            pad_out: Vec::new(),
            vhgw: Vec::new(),
        }
    }
}

/// Reconstruction plan state: the inner elementary-sweep plan (a
/// single-op dilate at the spec's SE and config — banding, method and
/// arena all resolved once) plus the two ping-pong buffers the
/// iterate-to-stability loop flips between.  Boxed inside
/// [`FilterPlan`] so non-reconstruct plans pay one `Option` tag.
#[derive(Debug)]
struct ReconScratch<P: MorphPixel> {
    sweep: FilterPlan<P>,
    cur: Vec<P>,
    next: Vec<P>,
}

/// A [`FilterSpec`] resolved against a pixel depth and image shape —
/// method/strategy/band choices fixed, scratch preallocated.  Build
/// with [`FilterSpec::plan`]; reuse freely across same-shape images.
///
/// Plans are **position-independent**: resolution depends on the ROI's
/// haloed-block *shape*, never its absolute origin — [`FilterPlan::run`]
/// executes the spec's own ROI, and [`FilterPlan::run_at`] takes a
/// different same-shape ROI position at call time (one plan serves a
/// whole crop sweep; see [`FilterSpec::canonical_for`]).
#[derive(Debug)]
pub struct FilterPlan<P: MorphPixel> {
    spec: FilterSpec,
    src_h: usize,
    src_w: usize,
    out_h: usize,
    out_w: usize,
    /// Chain halo per axis (`halo_x`, `halo_y`).
    halo: (usize, usize),
    /// Source region the spec's own ROI resolves to (haloed block, or
    /// full) — `run_at` recomputes the origin per call; only the
    /// *shape* is baked into the arena.
    block: Roi,
    steps: Vec<ExecStep>,
    scratch: Scratch<P>,
    /// Whether the spec's chain may switch to run-length interval
    /// arithmetic at run time (no ROI, a pure erode/dilate lowering,
    /// and a non-`Dense` representation knob) — the final binary-source
    /// check happens per run ([`rle::try_run_chain_rle`]).
    rle_eligible: bool,
    /// Band count for a standalone [`FilterOp::Transpose`] spec, priced
    /// once at build time by [`parallel::effective_transpose_bands`]
    /// (1 = sequential; unused for every other spec — sandwich
    /// transposes ride their step's `bands`).
    transpose_bands: usize,
    /// Reconstruction-only state ([`FilterOp::Reconstruct`] specs).
    recon: Option<Box<ReconScratch<P>>>,
}

impl<P: MorphPixel> FilterPlan<P> {
    fn build(spec: FilterSpec, h: usize, w: usize) -> Result<FilterPlan<P>, PlanError> {
        spec.validate(h, w)?;
        let (out_h, out_w) = spec.out_dims(h, w);
        if spec.is_transpose() {
            let transpose_bands = parallel::effective_transpose_bands::<P>(h, w, &spec.config);
            return Ok(FilterPlan {
                spec,
                src_h: h,
                src_w: w,
                out_h,
                out_w,
                halo: (0, 0),
                block: Roi::full(h, w),
                steps: Vec::new(),
                scratch: Scratch::empty(),
                rle_eligible: false,
                transpose_bands,
                recon: None,
            });
        }
        if spec.is_reconstruct() {
            // the sweep is an ordinary single-op dilate plan at the
            // spec's SE and config (banding, method, arena resolved
            // once); the reconstruction loop ping-pongs between the
            // boxed cur/next buffers — zero per-run allocation
            let sweep_spec = FilterSpec {
                ops: OpChain::single(FilterOp::Dilate),
                w_x: spec.w_x,
                w_y: spec.w_y,
                config: spec.config,
                roi: None,
            };
            let sweep = FilterPlan::build(sweep_spec, h, w)?;
            let px = h * w;
            return Ok(FilterPlan {
                spec,
                src_h: h,
                src_w: w,
                out_h,
                out_w,
                halo: (0, 0),
                block: Roi::full(h, w),
                steps: Vec::new(),
                scratch: Scratch::empty(),
                rle_eligible: false,
                transpose_bands: 1,
                recon: Some(Box::new(ReconScratch {
                    sweep,
                    cur: vec![P::MIN_VALUE; px],
                    next: vec![P::MIN_VALUE; px],
                })),
            });
        }

        let cfg = &spec.config;
        let wing_x = spec.w_x / 2;
        let wing_y = spec.w_y / 2;

        // ROI -> haloed block (chain depth × wing per axis, clamped);
        // only the block *shape* feeds the resolution below — `run_at`
        // recomputes the origin per call
        let (hx, hy) = spec.roi_halo();
        let block = match spec.roi {
            None => Roi::full(h, w),
            Some(roi) => haloed_block(roi, h, w, hx, hy),
        };
        let (hb, wb) = (block.height, block.width);

        // execution shape: padded under Replicate
        let replicate = cfg.border == Border::Replicate;
        let (he, we) = if replicate {
            (hb + 2 * wing_y, wb + 2 * wing_x)
        } else {
            (hb, wb)
        };

        // resolve the pass set once (same windows for every morph step)
        let rows = (spec.w_y > 1).then(|| RowsPass {
            window: spec.w_y,
            method: resolve_method(cfg.method, spec.w_y, cfg.thresholds.wy0),
        });
        let cols = (spec.w_x > 1).then(|| {
            let m = resolve_method(cfg.method, spec.w_x, cfg.thresholds.wx0);
            ColsPass {
                window: spec.w_x,
                method: m,
                sandwich: separable::takes_sandwich(m, cfg.simd, cfg.vertical),
            }
        });
        let bands = parallel::effective_bands::<P>(hb, wb, spec.w_x, spec.w_y, cfg);

        let (prim, n_slots) = lower(spec.ops.as_slice());
        let steps: Vec<ExecStep> = prim
            .iter()
            .map(|s| match *s {
                PrimStep::Morph { op, src, dst } => ExecStep::Morph {
                    op,
                    src,
                    dst,
                    rows,
                    cols,
                    bands,
                },
                PrimStep::Sub { a, b, dst } => ExecStep::Sub { a, b, dst },
            })
            .collect();

        // scratch arena: the final slot is skipped when the last step
        // can write straight into the caller's destination (no ROI crop)
        let Slot::Tmp(final_slot) = prim.last().unwrap().dst() else {
            unreachable!()
        };
        let direct_out = spec.roi.is_none();
        let slot_px = hb * wb;
        let slots: Vec<Vec<P>> = (0..n_slots)
            .map(|i| {
                if direct_out && i == final_slot {
                    Vec::new()
                } else {
                    vec![P::default(); slot_px]
                }
            })
            .collect();
        let needs_mid = rows.is_some() && cols.is_some();
        let needs_sandwich = cols.is_some_and(|c| c.sandwich);
        let exec_px = he * we;
        // does any step actually run a pass? (1×1 SEs degrade to copies
        // and need no replicate staging)
        let has_pass = rows.is_some() || cols.is_some();
        let morph_steps = has_pass && steps.iter().any(|s| matches!(s, ExecStep::Morph { .. }));
        let rle_eligible = spec.roi.is_none()
            && spec.config.representation != Representation::Dense
            && rle::rle_op_sequence(spec.ops.as_slice()).is_some();
        Ok(FilterPlan {
            spec,
            src_h: h,
            src_w: w,
            out_h,
            out_w,
            halo: (hx, hy),
            block,
            steps,
            scratch: Scratch {
                slots,
                after_rows: if needs_mid { vec![P::default(); exec_px] } else { Vec::new() },
                t_a: if needs_sandwich { vec![P::default(); exec_px] } else { Vec::new() },
                t_b: if needs_sandwich { vec![P::default(); exec_px] } else { Vec::new() },
                pad_in: if replicate && morph_steps {
                    vec![P::default(); exec_px]
                } else {
                    Vec::new()
                },
                pad_out: if replicate && morph_steps {
                    vec![P::default(); exec_px]
                } else {
                    Vec::new()
                },
                // vHGW R slots grow to their per-band high-water mark on
                // the first run (the band plan is fixed here, so the
                // sizes are stable from run 2 on)
                vhgw: Vec::new(),
            },
            rle_eligible,
            transpose_bands: 1,
            recon: None,
        })
    }

    /// The spec this plan resolves.
    pub fn spec(&self) -> &FilterSpec {
        &self.spec
    }

    /// Expected input shape.
    pub fn src_dims(&self) -> (usize, usize) {
        (self.src_h, self.src_w)
    }

    /// Output shape of every run.
    pub fn out_dims(&self) -> (usize, usize) {
        (self.out_h, self.out_w)
    }

    /// Bytes retained by the scratch arena — what a plan cache pays to
    /// keep this plan resident (a multi-slot chain on a large image can
    /// hold several image-sized buffers).
    pub fn scratch_bytes(&self) -> usize {
        let elems = self.scratch.slots.iter().map(Vec::len).sum::<usize>()
            + self.scratch.after_rows.len()
            + self.scratch.t_a.len()
            + self.scratch.t_b.len()
            + self.scratch.pad_in.len()
            + self.scratch.pad_out.len()
            + self.scratch.vhgw.iter().map(Vec::len).sum::<usize>();
        let recon = self.recon.as_ref().map_or(0, |r| {
            (r.cur.len() + r.next.len()) * std::mem::size_of::<P>() + r.sweep.scratch_bytes()
        });
        elems * std::mem::size_of::<P>() + recon
    }

    /// Execute the plan into a caller-provided destination (the
    /// zero-allocation form).  `src` must match [`FilterPlan::src_dims`]
    /// and `dst` [`FilterPlan::out_dims`].
    pub fn run<'a>(&mut self, src: impl Into<ImageView<'a, P>>, dst: ImageViewMut<'_, P>) {
        let roi = self.spec.roi;
        self.run_with(src.into(), dst, roi);
    }

    /// Execute a [`FilterOp::Reconstruct`] plan: iterate geodesic
    /// dilations of `marker` under `mask` (the request image) to
    /// stability, writing the fixpoint into `dst`, and return the
    /// executed sweep count.  Both images must match
    /// [`FilterPlan::src_dims`].  Bit-identical to
    /// [`super::geodesic::reconstruct_by_dilation`] with the spec's SE
    /// and config; sweeps reuse the plan-owned ping-pong buffers and
    /// inner sweep arena, so reruns allocate nothing.
    pub fn run_reconstruct<'a, 'b>(
        &mut self,
        mask: impl Into<ImageView<'a, P>>,
        marker: impl Into<ImageView<'b, P>>,
        mut dst: ImageViewMut<'_, P>,
    ) -> usize {
        let mask = mask.into();
        let marker = marker.into();
        assert_eq!(
            (mask.height(), mask.width()),
            (self.src_h, self.src_w),
            "plan was resolved for a {}x{} source",
            self.src_h,
            self.src_w
        );
        let recon = self
            .recon
            .as_mut()
            .expect("run_reconstruct requires a FilterOp::Reconstruct plan");
        geodesic::reconstruct_with_plan(
            &mut recon.sweep,
            MorphOp::Dilate,
            marker,
            mask,
            &mut recon.cur,
            &mut recon.next,
            &mut dst,
        )
    }

    /// [`FilterPlan::run_reconstruct`] allocating the output image.
    pub fn run_reconstruct_owned<'a, 'b>(
        &mut self,
        mask: impl Into<ImageView<'a, P>>,
        marker: impl Into<ImageView<'b, P>>,
    ) -> (Image<P>, usize) {
        let mut out = Image::zeros(self.out_h, self.out_w);
        let sweeps = self.run_reconstruct(mask, marker, out.view_mut());
        (out, sweeps)
    }

    /// Execute the plan against a **different ROI position** of the same
    /// shape — the position-independent serving form.  The plan must
    /// have been resolved from a ROI spec; `roi` must have the spec
    /// ROI's shape and resolve a haloed block of the same shape (every
    /// *interior* position qualifies; an edge-clamped position needs the
    /// plan resolved for its own clamped geometry — see
    /// [`FilterSpec::canonical_for`]).  Output is bit-identical to
    /// planning `spec.with_roi(roi)` from scratch.
    pub fn run_at<'a>(
        &mut self,
        src: impl Into<ImageView<'a, P>>,
        dst: ImageViewMut<'_, P>,
        roi: Roi,
    ) {
        let spec_roi = self
            .spec
            .roi
            .expect("run_at requires a plan resolved from a ROI spec");
        assert_eq!(
            (roi.height, roi.width),
            (spec_roi.height, spec_roi.width),
            "plan was resolved for a {}x{} ROI",
            spec_roi.height,
            spec_roi.width
        );
        self.run_with(src.into(), dst, Some(roi));
    }

    /// [`FilterPlan::run_at`] allocating the output image.
    pub fn run_owned_at<'a>(&mut self, src: impl Into<ImageView<'a, P>>, roi: Roi) -> Image<P> {
        let mut out = Image::zeros(self.out_h, self.out_w);
        self.run_at(src.into(), out.view_mut(), roi);
        out
    }

    fn run_with(&mut self, src: ImageView<'_, P>, mut dst: ImageViewMut<'_, P>, roi: Option<Roi>) {
        assert_eq!(
            (src.height(), src.width()),
            (self.src_h, self.src_w),
            "plan was resolved for a {}x{} source",
            self.src_h,
            self.src_w
        );
        assert_eq!(
            (dst.height(), dst.width()),
            (self.out_h, self.out_w),
            "plan output is {}x{}",
            self.out_h,
            self.out_w
        );
        if self.spec.is_transpose() {
            if self.transpose_bands > 1 {
                parallel::transpose_image_banded_into(
                    parallel::BandPool::global(),
                    src,
                    dst,
                    self.transpose_bands,
                );
            } else {
                P::transpose_image_into(&mut Native, src, dst);
            }
            return;
        }
        assert!(
            !self.spec.is_reconstruct(),
            "reconstruct plans carry a marker payload; run via FilterPlan::run_reconstruct"
        );
        // resolve the block origin at CALL time (position independence):
        // the arena only fixed the block's shape
        let (hx, hy) = self.halo;
        let block_roi = match roi {
            None => Roi::full(self.src_h, self.src_w),
            Some(r) => {
                assert!(
                    r.height <= self.src_h
                        && r.y <= self.src_h - r.height
                        && r.width <= self.src_w
                        && r.x <= self.src_w - r.width,
                    "ROI {r:?} exceeds the {}x{} image",
                    self.src_h,
                    self.src_w
                );
                haloed_block(r, self.src_h, self.src_w, hx, hy)
            }
        };
        assert_eq!(
            (block_roi.height, block_roi.width),
            (self.block.height, self.block.width),
            "plan was resolved for a {}x{} block; ROI {roi:?} resolves {}x{} here \
             (edge-clamped positions need their own plan)",
            self.block.height,
            self.block.width,
            block_roi.height,
            block_roi.width
        );
        let block = src.sub_rect(block_roi.y, block_roi.x, block_roi.height, block_roi.width);
        // empty output (degenerate source or empty ROI): nothing to
        // compute — and a nonzero output implies a nonzero block, since
        // the ROI is validated to fit inside the image
        if self.out_h == 0 || self.out_w == 0 {
            return;
        }
        // representation dispatch: a plan built with `Rle`/`Auto` on a
        // binary-eligible chain probes the source at run time (cheap
        // scan) and routes through interval arithmetic when it wins.
        // Non-binary sources and losing `Auto` probes fall through to
        // the dense steps below, bit-identically.
        if self.rle_eligible && roi.is_none() && rle::try_run_chain_rle(&self.spec, block, &mut dst)
        {
            return;
        }

        let n_steps = self.steps.len();
        for i in 0..n_steps {
            let step = self.steps[i];
            let direct_out = roi.is_none() && i == n_steps - 1;
            match step {
                ExecStep::Morph {
                    op,
                    src: s,
                    dst: d,
                    rows,
                    cols,
                    bands,
                } => {
                    self.exec_morph(block, s, d, direct_out, &mut dst, op, rows, cols, bands);
                }
                ExecStep::Sub { a, b, dst: d } => {
                    self.exec_sub(block, a, b, d, direct_out, &mut dst);
                }
            }
        }

        if let Some(r) = roi {
            let Slot::Tmp(last) = self.steps.last().unwrap().dst_slot() else {
                unreachable!()
            };
            let (hb, wb) = (self.block.height, self.block.width);
            let full = ImageView::from_slice(&self.scratch.slots[last], hb, wb, wb);
            dst.copy_rows_from(
                full.sub_rect(r.y - block_roi.y, r.x - block_roi.x, r.height, r.width),
                0,
            );
        }
    }

    /// Execute the plan, allocating the output image.
    pub fn run_owned<'a>(&mut self, src: impl Into<ImageView<'a, P>>) -> Image<P> {
        let mut out = Image::zeros(self.out_h, self.out_w);
        self.run(src.into(), out.view_mut());
        out
    }

    /// Resolve a read slot to a view over the block or an arena buffer.
    fn slot_view<'s>(&'s self, block: ImageView<'s, P>, s: Slot) -> ImageView<'s, P> {
        match s {
            Slot::Src => block,
            Slot::Tmp(i) => {
                let (hb, wb) = (self.block.height, self.block.width);
                ImageView::from_slice(&self.scratch.slots[i], hb, wb, wb)
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_morph(
        &mut self,
        block: ImageView<'_, P>,
        s: Slot,
        d: Slot,
        direct_out: bool,
        out: &mut ImageViewMut<'_, P>,
        op: MorphOp,
        rows: Option<RowsPass>,
        cols: Option<ColsPass>,
        bands: usize,
    ) {
        let (hb, wb) = (self.block.height, self.block.width);
        let Slot::Tmp(di) = d else { unreachable!() };
        // take the destination buffer out of the arena so reads can
        // borrow the rest of it (a lowered dst is always a fresh slot)
        let mut dstbuf = if direct_out {
            Vec::new()
        } else {
            std::mem::take(&mut self.scratch.slots[di])
        };
        let mut after_rows = std::mem::take(&mut self.scratch.after_rows);
        let mut t_a = std::mem::take(&mut self.scratch.t_a);
        let mut t_b = std::mem::take(&mut self.scratch.t_b);
        let mut pad_in = std::mem::take(&mut self.scratch.pad_in);
        let mut pad_out = std::mem::take(&mut self.scratch.pad_out);
        let mut vhgw = std::mem::take(&mut self.scratch.vhgw);
        {
            let sv = self.slot_view(block, s);
            let cfg = &self.spec.config;
            let mut tv = if direct_out {
                out.reborrow()
            } else {
                ImageViewMut::from_slice_mut(&mut dstbuf, hb, wb, wb)
            };
            if rows.is_none() && cols.is_none() {
                // 1×1 SE: identity at both borders
                tv.copy_rows_from(sv, 0);
            } else if cfg.border == Border::Replicate {
                let wing_x = self.spec.w_x / 2;
                let wing_y = self.spec.w_y / 2;
                let (he, we) = (hb + 2 * wing_y, wb + 2 * wing_x);
                super::replicate_pad_into(
                    sv,
                    wing_x,
                    wing_y,
                    ImageViewMut::from_slice_mut(&mut pad_in, he, we, we),
                );
                exec_morph_ident(
                    ImageView::from_slice(&pad_in, he, we, we),
                    ImageViewMut::from_slice_mut(&mut pad_out, he, we, we),
                    op,
                    rows,
                    cols,
                    bands,
                    cfg,
                    &mut after_rows,
                    &mut t_a,
                    &mut t_b,
                    &mut vhgw,
                );
                tv.copy_rows_from(
                    ImageView::from_slice(&pad_out, he, we, we).sub_rect(wing_y, wing_x, hb, wb),
                    0,
                );
            } else {
                exec_morph_ident(
                    sv,
                    tv,
                    op,
                    rows,
                    cols,
                    bands,
                    cfg,
                    &mut after_rows,
                    &mut t_a,
                    &mut t_b,
                    &mut vhgw,
                );
            }
        }
        self.scratch.after_rows = after_rows;
        self.scratch.t_a = t_a;
        self.scratch.t_b = t_b;
        self.scratch.pad_in = pad_in;
        self.scratch.pad_out = pad_out;
        self.scratch.vhgw = vhgw;
        if !direct_out {
            self.scratch.slots[di] = dstbuf;
        }
    }

    fn exec_sub(
        &mut self,
        block: ImageView<'_, P>,
        a: Slot,
        b: Slot,
        d: Slot,
        direct_out: bool,
        out: &mut ImageViewMut<'_, P>,
    ) {
        let (hb, wb) = (self.block.height, self.block.width);
        let Slot::Tmp(di) = d else { unreachable!() };
        let mut dstbuf = if direct_out {
            Vec::new()
        } else {
            std::mem::take(&mut self.scratch.slots[di])
        };
        {
            let av = self.slot_view(block, a);
            let bv = self.slot_view(block, b);
            let tv = if direct_out {
                out.reborrow()
            } else {
                ImageViewMut::from_slice_mut(&mut dstbuf, hb, wb, wb)
            };
            derived::pixelwise_sub_into(av, bv, tv);
        }
        if !direct_out {
            self.scratch.slots[di] = dstbuf;
        }
    }
}

impl ExecStep {
    fn dst_slot(&self) -> Slot {
        match *self {
            ExecStep::Morph { dst, .. } | ExecStep::Sub { dst, .. } => dst,
        }
    }
}

/// One separable erosion/dilation with identity borders into `tv`,
/// using the plan's resolved passes and band count.  `vhgw` is the
/// arena's per-band vHGW `R`-slot pool, shared by every pass of the
/// step (passes run sequentially, and the slots regrow to each pass's
/// high-water mark exactly once).
#[allow(clippy::too_many_arguments)]
fn exec_morph_ident<P: MorphPixel>(
    sv: ImageView<'_, P>,
    mut tv: ImageViewMut<'_, P>,
    op: MorphOp,
    rows: Option<RowsPass>,
    cols: Option<ColsPass>,
    bands: usize,
    cfg: &MorphConfig,
    after_rows: &mut [P],
    t_a: &mut [P],
    t_b: &mut [P],
    vhgw: &mut Vec<Vec<P>>,
) {
    let (h, w) = (sv.height(), sv.width());
    match (rows, cols) {
        (None, None) => tv.copy_rows_from(sv, 0),
        (Some(r), None) => run_rows_pass(sv, tv, op, r, bands, cfg, 1, vhgw),
        (None, Some(c)) => run_cols_pass(sv, tv, op, c, bands, cfg, t_a, t_b, vhgw),
        (Some(r), Some(c)) => {
            let mid = &mut after_rows[..h * w];
            run_rows_pass(
                sv,
                ImageViewMut::from_slice_mut(mid, h, w, w),
                op,
                r,
                bands,
                cfg,
                1,
                vhgw,
            );
            run_cols_pass(
                ImageView::from_slice(mid, h, w, w),
                tv.reborrow(),
                op,
                c,
                bands,
                cfg,
                t_a,
                t_b,
                vhgw,
            );
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_rows_pass<P: MorphPixel>(
    sv: ImageView<'_, P>,
    tv: ImageViewMut<'_, P>,
    op: MorphOp,
    r: RowsPass,
    bands: usize,
    cfg: &MorphConfig,
    align: usize,
    vhgw: &mut Vec<Vec<P>>,
) {
    if bands > 1 {
        parallel::pass_rows_banded_into(
            parallel::BandPool::global(),
            sv,
            tv,
            r.window,
            op,
            r.method,
            cfg.simd,
            cfg.thresholds,
            bands,
            align,
            vhgw,
        );
    } else {
        if vhgw.is_empty() {
            vhgw.push(Vec::new());
        }
        separable::pass_rows_into(
            &mut Native,
            sv,
            tv,
            0,
            r.window,
            op,
            r.method,
            cfg.simd,
            cfg.thresholds,
            &mut vhgw[0],
        );
    }
}

/// One §5.2.1 sandwich transpose at the plan's band count: banded over
/// destination column stripes when the enclosing pass is banded
/// (`bands > 1` — the fork is already paid for the middle pass, so the
/// transposes ride the same partition), sequential otherwise.
fn run_sandwich_transpose<P: MorphPixel>(
    sv: ImageView<'_, P>,
    tv: ImageViewMut<'_, P>,
    bands: usize,
) {
    if bands > 1 {
        parallel::transpose_image_banded_into(parallel::BandPool::global(), sv, tv, bands);
    } else {
        P::transpose_image_into(&mut Native, sv, tv);
    }
}

#[allow(clippy::too_many_arguments)]
fn run_cols_pass<P: MorphPixel>(
    sv: ImageView<'_, P>,
    tv: ImageViewMut<'_, P>,
    op: MorphOp,
    c: ColsPass,
    bands: usize,
    cfg: &MorphConfig,
    t_a: &mut [P],
    t_b: &mut [P],
    vhgw: &mut Vec<Vec<P>>,
) {
    let (h, w) = (sv.height(), sv.width());
    if c.sandwich {
        // §5.2.1, banded end-to-end: banded transpose ∘ banded rows
        // pass ∘ banded transpose, every phase striped over the
        // transposed buffer in the same LANES-aligned bands (sandwich
        // passes are always SIMD; vHGW resolves here because it has no
        // direct form).  Each transpose band writes a disjoint column
        // stripe of its destination arena buffer — zero-copy, no halo.
        let ta = &mut t_a[..h * w];
        run_sandwich_transpose(sv, ImageViewMut::from_slice_mut(ta, w, h, h), bands);
        let tb = &mut t_b[..h * w];
        run_rows_pass(
            ImageView::from_slice(ta, w, h, h),
            ImageViewMut::from_slice_mut(tb, w, h, h),
            op,
            RowsPass {
                window: c.window,
                method: c.method,
            },
            bands,
            cfg,
            P::LANES,
            vhgw,
        );
        run_sandwich_transpose(ImageView::from_slice(tb, w, h, h), tv, bands);
    } else if bands > 1 {
        parallel::pass_cols_direct_banded_into(
            parallel::BandPool::global(),
            sv,
            tv,
            c.window,
            op,
            c.method,
            cfg.simd,
            cfg.vertical,
            cfg.thresholds,
            bands,
            vhgw,
        );
    } else {
        if vhgw.is_empty() {
            vhgw.push(Vec::new());
        }
        separable::pass_cols_direct_into(
            &mut Native,
            sv,
            tv,
            c.window,
            op,
            c.method,
            cfg.simd,
            cfg.vertical,
            cfg.thresholds,
            &mut vhgw[0],
        );
    }
}

// ---------------------------------------------------------------------------
// fused batch plans (one banded execution across a same-key batch)
// ---------------------------------------------------------------------------

/// A [`FilterSpec`] resolved for **fused batch execution**: a batch of
/// `n` same-shape images runs as ONE banded execution per pass — bands
/// span image boundaries over the fused `n × h`-row virtual image
/// ([`parallel::split_fused_bands`]), one fork-join covers the whole
/// batch, and the scratch arena owns fused (batch-capacity-sized)
/// intermediates.  Per-image **halo fences** keep every output
/// bit-identical to running a [`FilterPlan`] per image (pinned by
/// `rust/tests/fused_batch.rs`); what fusion buys is *amortization* —
/// one fork instead of `n`, which is the §5.2 banding gain recovered
/// for the paper's many-small-crops document workload.
///
/// Build with [`FilterSpec::plan_fused`]; the arena grows once to the
/// largest batch seen ([`FusedPlan::reserve`]) and is reused
/// allocation-free after.  Full-image specs only (no ROI, no
/// transpose — those batches run per image).
#[derive(Debug)]
pub struct FusedPlan<P: MorphPixel> {
    spec: FilterSpec,
    h: usize,
    w: usize,
    /// High-water batch size the arena is sized for.
    capacity: usize,
    rows: Option<RowsPass>,
    cols: Option<ColsPass>,
    steps: Vec<PrimStep>,
    /// The lowered chain's final slot — never materialized (the last
    /// step always writes straight to the caller's destinations).
    final_slot: usize,
    scratch: Scratch<P>,
}

impl<P: MorphPixel> FusedPlan<P> {
    fn build(spec: FilterSpec, h: usize, w: usize, n: usize) -> Result<FusedPlan<P>, PlanError> {
        spec.validate(h, w)?;
        if spec.is_transpose() {
            return Err(PlanError(
                "fused plans do not serve transpose specs (run per image)".into(),
            ));
        }
        if spec.is_reconstruct() {
            return Err(PlanError(
                "fused plans do not serve reconstruct specs (marker payloads run per request)"
                    .into(),
            ));
        }
        if spec.roi.is_some() {
            return Err(PlanError(
                "fused plans serve full-image specs; ROI batches run per image".into(),
            ));
        }
        let cfg = &spec.config;
        let rows = (spec.w_y > 1).then(|| RowsPass {
            window: spec.w_y,
            method: resolve_method(cfg.method, spec.w_y, cfg.thresholds.wy0),
        });
        let cols = (spec.w_x > 1).then(|| {
            let m = resolve_method(cfg.method, spec.w_x, cfg.thresholds.wx0);
            ColsPass {
                window: spec.w_x,
                method: m,
                sandwich: separable::takes_sandwich(m, cfg.simd, cfg.vertical),
            }
        });
        let (steps, n_slots) = lower(spec.ops.as_slice());
        let Slot::Tmp(final_slot) = steps.last().unwrap().dst() else {
            unreachable!()
        };
        let mut plan = FusedPlan {
            spec,
            h,
            w,
            capacity: 0,
            rows,
            cols,
            steps,
            final_slot,
            scratch: Scratch {
                slots: (0..n_slots).map(|_| Vec::new()).collect(),
                after_rows: Vec::new(),
                t_a: Vec::new(),
                t_b: Vec::new(),
                pad_in: Vec::new(),
                pad_out: Vec::new(),
                vhgw: Vec::new(),
            },
        };
        plan.reserve(n);
        Ok(plan)
    }

    /// The spec this plan resolves.
    pub fn spec(&self) -> &FilterSpec {
        &self.spec
    }

    /// Per-image input (and output) shape.
    pub fn src_dims(&self) -> (usize, usize) {
        (self.h, self.w)
    }

    /// Largest batch the arena currently holds buffers for.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Bytes retained by the fused scratch arena (scales with the
    /// high-water batch size — what a plan cache pays to keep this plan
    /// resident).
    pub fn scratch_bytes(&self) -> usize {
        let elems = self.scratch.slots.iter().map(Vec::len).sum::<usize>()
            + self.scratch.after_rows.len()
            + self.scratch.t_a.len()
            + self.scratch.t_b.len()
            + self.scratch.pad_in.len()
            + self.scratch.pad_out.len()
            + self.scratch.vhgw.iter().map(Vec::len).sum::<usize>();
        elems * std::mem::size_of::<P>()
    }

    /// Grow the fused arena to serve batches of `n` images (no-op when
    /// already large enough; run N > 1 at or under the high-water
    /// capacity allocates nothing).
    pub fn reserve(&mut self, n: usize) {
        if n <= self.capacity {
            return;
        }
        let px = self.h * self.w;
        let replicate = self.spec.config.border == Border::Replicate;
        let (he, we) = if replicate {
            (self.h + 2 * (self.spec.w_y / 2), self.w + 2 * (self.spec.w_x / 2))
        } else {
            (self.h, self.w)
        };
        let epx = he * we;
        let needs_mid = self.rows.is_some() && self.cols.is_some();
        let needs_sandwich = self.cols.is_some_and(|c| c.sandwich);
        let has_pass = self.rows.is_some() || self.cols.is_some();
        let morph_steps =
            has_pass && self.steps.iter().any(|s| matches!(s, PrimStep::Morph { .. }));
        for (i, slot) in self.scratch.slots.iter_mut().enumerate() {
            if i != self.final_slot {
                slot.resize(n * px, P::default());
            }
        }
        if needs_mid {
            self.scratch.after_rows.resize(n * epx, P::default());
        }
        if needs_sandwich {
            self.scratch.t_a.resize(n * epx, P::default());
            self.scratch.t_b.resize(n * epx, P::default());
        }
        if replicate && morph_steps {
            self.scratch.pad_in.resize(n * epx, P::default());
            self.scratch.pad_out.resize(n * epx, P::default());
        }
        self.capacity = n;
    }

    /// Execute the whole batch as fused super-passes into
    /// caller-provided destinations.  Every source and destination must
    /// have the plan's per-image shape; `srcs[i]` writes `dsts[i]`.
    /// Bit-identical, image for image, to running [`FilterPlan::run`]
    /// per image.
    pub fn run_batch(&mut self, srcs: &[ImageView<'_, P>], dsts: Vec<ImageViewMut<'_, P>>) {
        let n = srcs.len();
        assert_eq!(n, dsts.len(), "fused batch: src/dst counts differ");
        for (s, d) in srcs.iter().zip(&dsts) {
            assert_eq!(
                (s.height(), s.width()),
                (self.h, self.w),
                "fused plan was resolved for {}x{} images",
                self.h,
                self.w
            );
            assert_eq!((d.height(), d.width()), (self.h, self.w));
        }
        if n == 0 || self.h == 0 || self.w == 0 {
            return;
        }
        self.reserve(n);
        // band count priced per call on the FUSED extent — this is the
        // point of fusion: n small images band like one n·h-row image
        let bands = parallel::effective_bands::<P>(
            n * self.h,
            self.w,
            self.spec.w_x,
            self.spec.w_y,
            &self.spec.config,
        );
        let px = self.h * self.w;
        let n_steps = self.steps.len();
        let mut finals = Some(dsts);
        for i in 0..n_steps {
            let step = self.steps[i];
            let last = i == n_steps - 1;
            match step {
                PrimStep::Morph { op, src: s, dst: d } => {
                    let Slot::Tmp(di) = d else { unreachable!() };
                    let mut dstbuf = if last {
                        Vec::new()
                    } else {
                        std::mem::take(&mut self.scratch.slots[di])
                    };
                    let mut after_rows = std::mem::take(&mut self.scratch.after_rows);
                    let mut t_a = std::mem::take(&mut self.scratch.t_a);
                    let mut t_b = std::mem::take(&mut self.scratch.t_b);
                    let mut pad_in = std::mem::take(&mut self.scratch.pad_in);
                    let mut pad_out = std::mem::take(&mut self.scratch.pad_out);
                    let mut vhgw = std::mem::take(&mut self.scratch.vhgw);
                    {
                        let src_views = self.fused_slot_views(srcs, s, n);
                        let dst_views: Vec<ImageViewMut<'_, P>> = if last {
                            finals.take().unwrap()
                        } else {
                            dstbuf[..n * px]
                                .chunks_exact_mut(px)
                                .map(|c| ImageViewMut::from_slice_mut(c, self.h, self.w, self.w))
                                .collect()
                        };
                        fused_exec_morph(
                            &self.spec,
                            &src_views,
                            dst_views,
                            op,
                            self.rows,
                            self.cols,
                            bands,
                            &mut after_rows,
                            &mut t_a,
                            &mut t_b,
                            &mut pad_in,
                            &mut pad_out,
                            &mut vhgw,
                        );
                    }
                    self.scratch.after_rows = after_rows;
                    self.scratch.t_a = t_a;
                    self.scratch.t_b = t_b;
                    self.scratch.pad_in = pad_in;
                    self.scratch.pad_out = pad_out;
                    self.scratch.vhgw = vhgw;
                    if !last {
                        self.scratch.slots[di] = dstbuf;
                    }
                }
                PrimStep::Sub { a, b, dst: d } => {
                    let Slot::Tmp(di) = d else { unreachable!() };
                    let mut dstbuf = if last {
                        Vec::new()
                    } else {
                        std::mem::take(&mut self.scratch.slots[di])
                    };
                    {
                        let av = self.fused_slot_views(srcs, a, n);
                        let bv = self.fused_slot_views(srcs, b, n);
                        let dv: Vec<ImageViewMut<'_, P>> = if last {
                            finals.take().unwrap()
                        } else {
                            dstbuf[..n * px]
                                .chunks_exact_mut(px)
                                .map(|c| ImageViewMut::from_slice_mut(c, self.h, self.w, self.w))
                                .collect()
                        };
                        for ((a_img, b_img), d_img) in av.into_iter().zip(bv).zip(dv) {
                            derived::pixelwise_sub_into(a_img, b_img, d_img);
                        }
                    }
                    if !last {
                        self.scratch.slots[di] = dstbuf;
                    }
                }
            }
        }
    }

    /// [`FusedPlan::run_batch`] allocating the output images.
    pub fn run_batch_owned(&mut self, srcs: &[ImageView<'_, P>]) -> Vec<Image<P>> {
        let mut out: Vec<Image<P>> = srcs.iter().map(|_| Image::zeros(self.h, self.w)).collect();
        let dsts: Vec<ImageViewMut<'_, P>> = out.iter_mut().map(|im| im.view_mut()).collect();
        self.run_batch(srcs, dsts);
        out
    }

    /// Per-image views of a read slot: the caller's sources, or the
    /// arena slot buffer chunked into its `n` fused segments.
    fn fused_slot_views<'s>(
        &'s self,
        srcs: &[ImageView<'s, P>],
        s: Slot,
        n: usize,
    ) -> Vec<ImageView<'s, P>> {
        let px = self.h * self.w;
        match s {
            Slot::Src => srcs.to_vec(),
            Slot::Tmp(i) => self.scratch.slots[i][..n * px]
                .chunks_exact(px)
                .map(|c| ImageView::from_slice(c, self.h, self.w, self.w))
                .collect(),
        }
    }
}

/// One fused erosion/dilation over the whole batch, dispatching on
/// border: identity runs the fused passes directly; replicate pads each
/// image into the fused `pad_in` stack (per-image geometry — the padded
/// seams are fences too), runs the identity path over the padded stack,
/// and crops each image back out.
#[allow(clippy::too_many_arguments)]
fn fused_exec_morph<P: MorphPixel>(
    spec: &FilterSpec,
    srcs: &[ImageView<'_, P>],
    dsts: Vec<ImageViewMut<'_, P>>,
    op: MorphOp,
    rows: Option<RowsPass>,
    cols: Option<ColsPass>,
    bands: usize,
    after_rows: &mut [P],
    t_a: &mut [P],
    t_b: &mut [P],
    pad_in: &mut [P],
    pad_out: &mut [P],
    vhgw: &mut Vec<Vec<P>>,
) {
    let n = srcs.len();
    let (h, w) = (srcs[0].height(), srcs[0].width());
    let cfg = &spec.config;
    if rows.is_none() && cols.is_none() {
        // 1×1 SE: identity at both borders
        for (s, mut d) in srcs.iter().zip(dsts) {
            d.copy_rows_from(*s, 0);
        }
        return;
    }
    if cfg.border == Border::Replicate {
        let (wing_x, wing_y) = (spec.w_x / 2, spec.w_y / 2);
        let (he, we) = (h + 2 * wing_y, w + 2 * wing_x);
        let epx = he * we;
        for (j, s) in srcs.iter().enumerate() {
            super::replicate_pad_into(
                *s,
                wing_x,
                wing_y,
                ImageViewMut::from_slice_mut(&mut pad_in[j * epx..(j + 1) * epx], he, we, we),
            );
        }
        {
            let pin: Vec<ImageView<'_, P>> = pad_in[..n * epx]
                .chunks_exact(epx)
                .map(|c| ImageView::from_slice(c, he, we, we))
                .collect();
            let pout: Vec<ImageViewMut<'_, P>> = pad_out[..n * epx]
                .chunks_exact_mut(epx)
                .map(|c| ImageViewMut::from_slice_mut(c, he, we, we))
                .collect();
            fused_morph_ident(&pin, pout, op, rows, cols, bands, cfg, after_rows, t_a, t_b, vhgw);
        }
        for (j, mut d) in dsts.into_iter().enumerate() {
            d.copy_rows_from(
                ImageView::from_slice(&pad_out[j * epx..(j + 1) * epx], he, we, we)
                    .sub_rect(wing_y, wing_x, h, w),
                0,
            );
        }
        return;
    }
    fused_morph_ident(srcs, dsts, op, rows, cols, bands, cfg, after_rows, t_a, t_b, vhgw);
}

/// Identity-border fused separable step: rows super-pass, mid buffer,
/// cols super-pass — each ONE fork-join over the whole batch.
#[allow(clippy::too_many_arguments)]
fn fused_morph_ident<P: MorphPixel>(
    srcs: &[ImageView<'_, P>],
    dsts: Vec<ImageViewMut<'_, P>>,
    op: MorphOp,
    rows: Option<RowsPass>,
    cols: Option<ColsPass>,
    bands: usize,
    cfg: &MorphConfig,
    after_rows: &mut [P],
    t_a: &mut [P],
    t_b: &mut [P],
    vhgw: &mut Vec<Vec<P>>,
) {
    let n = srcs.len();
    let (h, w) = (srcs[0].height(), srcs[0].width());
    let px = h * w;
    let pool = parallel::BandPool::global();
    match (rows, cols) {
        (None, None) => {
            for (s, mut d) in srcs.iter().zip(dsts) {
                d.copy_rows_from(*s, 0);
            }
        }
        (Some(r), None) => parallel::pass_rows_fused_into(
            pool,
            srcs,
            dsts,
            r.window,
            op,
            r.method,
            cfg.simd,
            cfg.thresholds,
            bands,
            1,
            vhgw,
        ),
        (None, Some(c)) => {
            run_cols_fused(pool, srcs, dsts, op, c, bands, cfg, t_a, t_b, vhgw);
        }
        (Some(r), Some(c)) => {
            let mid = &mut after_rows[..n * px];
            {
                let mid_dsts: Vec<ImageViewMut<'_, P>> = mid
                    .chunks_exact_mut(px)
                    .map(|ch| ImageViewMut::from_slice_mut(ch, h, w, w))
                    .collect();
                parallel::pass_rows_fused_into(
                    pool,
                    srcs,
                    mid_dsts,
                    r.window,
                    op,
                    r.method,
                    cfg.simd,
                    cfg.thresholds,
                    bands,
                    1,
                    vhgw,
                );
            }
            let mid_srcs: Vec<ImageView<'_, P>> = mid
                .chunks_exact(px)
                .map(|ch| ImageView::from_slice(ch, h, w, w))
                .collect();
            run_cols_fused(pool, &mid_srcs, dsts, op, c, bands, cfg, t_a, t_b, vhgw);
        }
    }
}

/// Fused cols pass: the §5.2.1 sandwich is banded end-to-end — each
/// image is transposed into the fused `t_a` stack by
/// [`parallel::transpose_fused_banded_into`] (one fork-join for the
/// whole batch, image-local [`MorphPixel::LANES`]-aligned cuts so no §4
/// tile straddles a seam), ONE fused rows super-pass runs over the
/// transposed stack, and the batch is transposed back the same way;
/// direct forms run the fused zero-halo executor.
#[allow(clippy::too_many_arguments)]
fn run_cols_fused<P: MorphPixel>(
    pool: &parallel::BandPool,
    srcs: &[ImageView<'_, P>],
    dsts: Vec<ImageViewMut<'_, P>>,
    op: MorphOp,
    c: ColsPass,
    bands: usize,
    cfg: &MorphConfig,
    t_a: &mut [P],
    t_b: &mut [P],
    vhgw: &mut Vec<Vec<P>>,
) {
    let n = srcs.len();
    let (h, w) = (srcs[0].height(), srcs[0].width());
    let px = h * w;
    if c.sandwich {
        {
            let ta_dsts: Vec<ImageViewMut<'_, P>> = t_a[..n * px]
                .chunks_exact_mut(px)
                .map(|ch| ImageViewMut::from_slice_mut(ch, w, h, h))
                .collect();
            parallel::transpose_fused_banded_into(pool, srcs, ta_dsts, bands);
        }
        {
            let ta: Vec<ImageView<'_, P>> = t_a[..n * px]
                .chunks_exact(px)
                .map(|ch| ImageView::from_slice(ch, w, h, h))
                .collect();
            let tb: Vec<ImageViewMut<'_, P>> = t_b[..n * px]
                .chunks_exact_mut(px)
                .map(|ch| ImageViewMut::from_slice_mut(ch, w, h, h))
                .collect();
            parallel::pass_rows_fused_into(
                pool,
                &ta,
                tb,
                c.window,
                op,
                c.method,
                cfg.simd,
                cfg.thresholds,
                bands,
                P::LANES,
                vhgw,
            );
        }
        let tb_srcs: Vec<ImageView<'_, P>> = t_b[..n * px]
            .chunks_exact(px)
            .map(|ch| ImageView::from_slice(ch, w, h, h))
            .collect();
        parallel::transpose_fused_banded_into(pool, &tb_srcs, dsts, bands);
    } else {
        parallel::pass_cols_direct_fused_into(
            pool,
            srcs,
            dsts,
            c.window,
            op,
            c.method,
            cfg.simd,
            cfg.vertical,
            cfg.thresholds,
            bands,
            vhgw,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::morphology::{HybridThresholds, Parallelism, PassMethod, VerticalStrategy};

    #[test]
    fn filter_op_parse_round_trip() {
        for op in FilterOp::ALL {
            assert_eq!(op.name().parse::<FilterOp>().unwrap(), op);
        }
        assert_eq!("open".parse::<FilterOp>().unwrap(), FilterOp::Open);
        assert_eq!("close".parse::<FilterOp>().unwrap(), FilterOp::Close);
        assert!("sharpen".parse::<FilterOp>().is_err());
    }

    #[test]
    fn op_chain_is_canonical_for_hash_eq() {
        let a = OpChain::from_slice(&[FilterOp::Open, FilterOp::Dilate]).unwrap();
        let mut b = OpChain::single(FilterOp::Open);
        b.push(FilterOp::Dilate).unwrap();
        assert_eq!(a, b);
        assert_eq!(format!("{a}"), "opening+dilate");
        assert_eq!(a.as_slice(), &[FilterOp::Open, FilterOp::Dilate]);
        assert!(OpChain::from_slice(&[]).is_err());
        assert!(OpChain::from_slice(&[FilterOp::Erode; MAX_CHAIN + 1]).is_err());
        let mut full = OpChain::from_slice(&[FilterOp::Erode; MAX_CHAIN]).unwrap();
        assert!(full.push(FilterOp::Dilate).is_err());
    }

    #[test]
    fn parse_ops_chains() {
        let c = FilterSpec::parse_ops("erode, dilate ,tophat").unwrap();
        assert_eq!(
            c.as_slice(),
            &[FilterOp::Erode, FilterOp::Dilate, FilterOp::TopHat]
        );
        assert!(FilterSpec::parse_ops("erode,,dilate").is_err());
        assert!(FilterSpec::parse_ops("nope").is_err());
    }

    #[test]
    fn lowering_shapes() {
        let (s, n) = lower(&[FilterOp::Erode]);
        assert_eq!(s.len(), 1);
        assert_eq!(n, 1);
        let (s, n) = lower(&[FilterOp::TopHat]);
        assert_eq!(s.len(), 3);
        assert_eq!(n, 3);
        assert!(matches!(s[2], PrimStep::Sub { a: Slot::Src, .. }));
        let (s, _) = lower(&[FilterOp::Open, FilterOp::Close]);
        assert_eq!(s.len(), 4);
        // every dst is fresh
        let mut seen = Vec::new();
        for st in &s {
            assert!(!seen.contains(&st.dst()));
            seen.push(st.dst());
        }
    }

    #[test]
    fn spec_validation() {
        assert!(FilterSpec::new(FilterOp::Erode, 4, 3).validate(10, 10).is_err());
        assert!(FilterSpec::new(FilterOp::Erode, 3, 0).validate(10, 10).is_err());
        assert!(FilterSpec::new(FilterOp::Transpose, 0, 0).validate(10, 10).is_ok());
        let chain = FilterSpec::new(FilterOp::Erode, 3, 3).then(FilterOp::Transpose);
        assert!(chain.validate(10, 10).is_err());
        let roi_oob = FilterSpec::new(FilterOp::Erode, 3, 3).with_roi(Roi::new(5, 5, 8, 8));
        assert!(roi_oob.validate(10, 10).is_err());
        let roi_ok = FilterSpec::new(FilterOp::Erode, 3, 3).with_roi(Roi::new(5, 5, 5, 5));
        assert!(roi_ok.validate(10, 10).is_ok());
    }

    #[test]
    fn single_identity_op_is_the_artifact_predicate() {
        let e = FilterSpec::new(FilterOp::Erode, 3, 3);
        assert_eq!(e.single_identity_op(), Some(FilterOp::Erode));
        assert_eq!(e.then(FilterOp::Dilate).single_identity_op(), None);
        assert_eq!(e.with_roi(Roi::new(0, 0, 2, 2)).single_identity_op(), None);
        let mut repl = MorphConfig::default();
        repl.border = Border::Replicate;
        assert_eq!(e.with_config(repl).single_identity_op(), None);
    }

    #[test]
    fn out_dims_follow_spec() {
        let s = FilterSpec::new(FilterOp::Erode, 3, 3);
        assert_eq!(s.out_dims(10, 20), (10, 20));
        assert_eq!(
            s.with_roi(Roi::new(1, 2, 3, 4)).out_dims(10, 20),
            (3, 4)
        );
        assert_eq!(FilterSpec::new(FilterOp::Transpose, 0, 0).out_dims(10, 20), (20, 10));
    }

    #[test]
    fn morph_depth_counts_longest_path() {
        assert_eq!(FilterSpec::new(FilterOp::Erode, 3, 3).morph_depth(), 1);
        assert_eq!(FilterSpec::new(FilterOp::Gradient, 3, 3).morph_depth(), 1);
        assert_eq!(FilterSpec::new(FilterOp::TopHat, 3, 3).morph_depth(), 2);
        assert_eq!(
            FilterSpec::new(FilterOp::Open, 3, 3)
                .then(FilterOp::Close)
                .morph_depth(),
            4
        );
    }

    #[test]
    fn plan_matches_legacy_single_ops() {
        let img = synth::noise(30, 37, 0x9A);
        for (op, fop) in [(MorphOp::Erode, FilterOp::Erode), (MorphOp::Dilate, FilterOp::Dilate)] {
            for &(wx, wy) in &[(3, 5), (5, 3), (1, 7), (7, 1), (1, 1)] {
                let want = separable::morphology(
                    &mut Native,
                    &img,
                    op,
                    wx,
                    wy,
                    &MorphConfig::default(),
                );
                let got = FilterSpec::new(fop, wx, wy).run_once::<u8>(&img).unwrap();
                assert!(
                    got.same_pixels(&want),
                    "{fop:?} {wx}x{wy}: {:?}",
                    got.first_diff(&want)
                );
            }
        }
    }

    #[test]
    fn plan_reuse_is_stable() {
        let spec = FilterSpec::new(FilterOp::TopHat, 5, 3);
        let mut plan = spec.plan::<u8>(24, 31).unwrap();
        // tophat = 3 slots (minus the direct-out final) + after_rows:
        // the arena must report its resident footprint for cache bounds
        assert!(plan.scratch_bytes() >= 3 * 24 * 31);
        let a = synth::noise(24, 31, 1);
        let b = synth::noise(24, 31, 2);
        let ra1 = plan.run_owned(&a);
        let rb = plan.run_owned(&b);
        let ra2 = plan.run_owned(&a);
        assert!(ra1.same_pixels(&ra2), "runs must not leak state");
        let want_b = derived::tophat(&mut Native, &b, 5, 3, &MorphConfig::default());
        assert!(rb.same_pixels(&want_b));
    }

    #[test]
    fn plan_chain_matches_composition() {
        let img = synth::noise(22, 26, 7);
        let cfg = MorphConfig::default();
        let got = FilterSpec::chain(&[FilterOp::Open, FilterOp::Gradient], 3, 3)
            .unwrap()
            .run_once::<u8>(&img)
            .unwrap();
        let o = derived::opening(&mut Native, &img, 3, 3, &cfg);
        let want = derived::gradient(&mut Native, &o, 3, 3, &cfg);
        assert!(got.same_pixels(&want));
    }

    #[test]
    fn plan_roi_equals_cropped_chain() {
        let img = synth::noise(40, 44, 0x717);
        let roi = Roi::new(6, 9, 18, 22);
        for op in [FilterOp::Erode, FilterOp::TopHat, FilterOp::Gradient] {
            let full = FilterSpec::new(op, 5, 7).run_once::<u8>(&img).unwrap();
            let want = full.view().sub_rect(roi.y, roi.x, roi.height, roi.width).to_image();
            let got = FilterSpec::new(op, 5, 7)
                .with_roi(roi)
                .run_once::<u8>(&img)
                .unwrap();
            assert!(got.same_pixels(&want), "{op:?}: {:?}", got.first_diff(&want));
        }
    }

    #[test]
    fn canonical_for_groups_interior_positions_only() {
        let base = FilterSpec::new(FilterOp::TopHat, 5, 7); // halo (4, 6)
        // interior positions of one shape collapse to one canonical spec
        let a = base.with_roi(Roi::new(6, 4, 10, 12)).canonical_for(40, 40);
        let b = base.with_roi(Roi::new(20, 19, 10, 12)).canonical_for(40, 40);
        assert_eq!(a, b);
        assert_eq!(a.roi, Some(Roi::new(6, 4, 10, 12)));
        // canonicalization is idempotent
        assert_eq!(a.canonical_for(40, 40), a);
        // an edge-clamped position keeps its own key
        let edge = base.with_roi(Roi::new(0, 0, 10, 12)).canonical_for(40, 40);
        assert_eq!(edge.roi, Some(Roi::new(0, 0, 10, 12)));
        assert_ne!(a, edge);
        // a different shape keys separately
        let other = base.with_roi(Roi::new(6, 4, 10, 13)).canonical_for(40, 40);
        assert_ne!(a, other);
        // no-ROI and out-of-bounds specs pass through untouched
        assert_eq!(base.canonical_for(40, 40), base);
        let oob = base.with_roi(Roi::new(35, 35, 10, 12));
        assert_eq!(oob.canonical_for(40, 40), oob);
    }

    #[test]
    fn run_at_matches_per_position_plans() {
        // ONE plan (resolved at the canonical anchor) must reproduce the
        // per-position plan output at every interior position, for a
        // chain with subtraction steps and at both borders
        let img = synth::noise(48, 52, 0xA11);
        for border in [Border::Identity, Border::Replicate] {
            let cfg = MorphConfig {
                border,
                parallelism: Parallelism::Sequential,
                ..MorphConfig::default()
            };
            let base = FilterSpec::new(FilterOp::Gradient, 5, 7).with_config(cfg);
            let (hx, hy) = base.roi_halo();
            let shape = Roi::new(hy, hx, 14, 16);
            let mut plan = base
                .with_roi(shape)
                .canonical_for(48, 52)
                .plan::<u8>(48, 52)
                .unwrap();
            for roi in [
                Roi::new(hy, hx, 14, 16),
                Roi::new(20, 19, 14, 16),
                Roi::new(48 - 14 - hy, 52 - 16 - hx, 14, 16),
            ] {
                let want = base.with_roi(roi).run_once::<u8>(&img).unwrap();
                let got = plan.run_owned_at(&img, roi);
                assert!(
                    got.same_pixels(&want),
                    "{border:?} {roi:?}: {:?}",
                    got.first_diff(&want)
                );
            }
        }
    }

    #[test]
    fn run_at_rejects_mismatched_positions() {
        let img = synth::noise(30, 30, 1);
        let spec = FilterSpec::new(FilterOp::Erode, 5, 5).with_roi(Roi::new(4, 4, 10, 10));
        let mut plan = spec.plan::<u8>(30, 30).unwrap();
        // wrong shape
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.run_owned_at(&img, Roi::new(4, 4, 10, 11))
        }));
        assert!(r.is_err(), "shape mismatch must panic");
        // edge-clamped position under an interior plan
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.run_owned_at(&img, Roi::new(0, 0, 10, 10))
        }));
        assert!(r.is_err(), "clamped block shape must panic");
        // out-of-bounds position
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.run_owned_at(&img, Roi::new(25, 25, 10, 10))
        }));
        assert!(r.is_err(), "out-of-bounds ROI must panic");
        // run_at on a no-ROI plan
        let mut full = FilterSpec::new(FilterOp::Erode, 5, 5).plan::<u8>(30, 30).unwrap();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            full.run_owned_at(&img, Roi::new(4, 4, 10, 10))
        }));
        assert!(r.is_err(), "run_at requires a ROI spec");
    }

    #[test]
    fn vhgw_plans_reuse_their_arena_r_buffers() {
        // a forced-vHGW plan must produce correct results across reuse
        // (the R slots grow once and are reused verbatim)
        let cfg = MorphConfig {
            method: PassMethod::Vhgw,
            parallelism: Parallelism::Sequential,
            ..MorphConfig::default()
        };
        let spec = FilterSpec::new(FilterOp::Close, 9, 9).with_config(cfg);
        let mut plan = spec.plan::<u8>(33, 41).unwrap();
        for seed in 0..3u64 {
            let img = synth::noise(33, 41, seed);
            let want = derived::closing(&mut Native, &img, 9, 9, &cfg);
            let got = plan.run_owned(&img);
            assert!(got.same_pixels(&want), "seed {seed}");
        }
        // the arena now retains the R slots it grew
        assert!(plan.scratch_bytes() > 33 * 41, "vHGW R slots must be arena-resident");
    }

    #[test]
    fn plan_transpose_and_empty() {
        let img = synth::noise_u16(10, 20, 3);
        let got = FilterSpec::new(FilterOp::Transpose, 0, 0)
            .run_once::<u16>(&img)
            .unwrap();
        assert!(got.same_pixels(&img.transposed()));
        let empty = Image::<u8>::zeros(0, 5);
        let out = FilterSpec::new(FilterOp::Erode, 3, 3).run_once::<u8>(&empty).unwrap();
        assert_eq!((out.height(), out.width()), (0, 5));
        let er = FilterSpec::new(FilterOp::Erode, 3, 3)
            .with_roi(Roi::new(2, 2, 0, 3))
            .run_once::<u8>(&synth::noise(10, 10, 1))
            .unwrap();
        assert_eq!(er.pixels(), 0);
    }

    #[test]
    fn run_chain_matches_plan_on_counting_shapes() {
        // generic chain runner (counted path) == plan (native path)
        let img = synth::noise(18, 23, 5);
        let cfg = MorphConfig {
            parallelism: Parallelism::Sequential,
            ..MorphConfig::default()
        };
        for op in [FilterOp::Open, FilterOp::BlackHat, FilterOp::Gradient] {
            let a = run_chain(&mut Native, &img, &[op], 5, 3, &cfg);
            let b = FilterSpec::new(op, 5, 3)
                .with_config(cfg)
                .run_once::<u8>(&img)
                .unwrap();
            assert!(a.same_pixels(&b), "{op:?}");
        }
    }

    #[test]
    fn plan_respects_explicit_configs() {
        let img = synth::noise(26, 29, 0xC0);
        for method in [PassMethod::Linear, PassMethod::Vhgw, PassMethod::Hybrid] {
            for vertical in [VerticalStrategy::Direct, VerticalStrategy::Transpose] {
                for simd in [false, true] {
                    for border in [Border::Identity, Border::Replicate] {
                        let cfg = MorphConfig {
                            method,
                            vertical,
                            simd,
                            border,
                            thresholds: HybridThresholds::paper(),
                            parallelism: Parallelism::Sequential,
                            representation: Representation::Dense,
                        };
                        let want = separable::morphology(
                            &mut Native,
                            &img,
                            MorphOp::Erode,
                            5,
                            7,
                            &cfg,
                        );
                        let got = FilterSpec::new(FilterOp::Erode, 5, 7)
                            .with_config(cfg)
                            .run_once::<u8>(&img)
                            .unwrap();
                        assert!(
                            got.same_pixels(&want),
                            "{method:?}/{vertical:?}/simd={simd}/{border:?}: {:?}",
                            got.first_diff(&want)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn reconstruct_specs_validate_their_shape() {
        // reconstruct must be a lone op with no ROI; windows validate
        // like any separable spec (they are the sweep SE)
        assert!(FilterSpec::new(FilterOp::Reconstruct, 3, 3).validate(10, 10).is_ok());
        let multi = FilterSpec {
            ops: OpChain::from_slice(&[FilterOp::Erode, FilterOp::Reconstruct]).unwrap(),
            ..FilterSpec::new(FilterOp::Reconstruct, 3, 3)
        };
        assert!(multi.validate(10, 10).is_err());
        assert!(FilterSpec::new(FilterOp::Reconstruct, 3, 3)
            .with_roi(Roi::new(1, 1, 4, 4))
            .validate(10, 10)
            .is_err());
        assert!(FilterSpec::new(FilterOp::Reconstruct, 4, 3).validate(10, 10).is_err());
        // fused batches refuse reconstruct outright
        assert!(FilterSpec::new(FilterOp::Reconstruct, 3, 3).plan_fused::<u8>(10, 10, 4).is_err());
    }

    #[test]
    fn reconstruct_plan_matches_geodesic_library_call() {
        let mask = synth::noise(21, 34, 11);
        let mut marker = Image::<u8>::zeros(21, 34);
        marker.view_mut().row_mut(0).copy_from_slice(mask.view().row(0));
        let cfg = MorphConfig::default();
        let (want, want_sweeps) =
            geodesic::reconstruct_by_dilation(&marker, &mask, 3, 3, &cfg).unwrap();
        let spec = FilterSpec::new(FilterOp::Reconstruct, 3, 3);
        let mut plan = spec.plan::<u8>(21, 34).unwrap();
        // plan-owned buffers: reruns reuse them bit-identically
        for round in 0..2 {
            let (got, sweeps) = plan.run_reconstruct_owned(&mask, &marker);
            assert_eq!(sweeps, want_sweeps, "round {round}");
            assert!(got.same_pixels(&want), "round {round}");
        }
        assert!(plan.scratch_bytes() >= 2 * 21 * 34);
    }

    #[test]
    fn rle_representation_plans_match_dense_bitwise() {
        let cfg_rle = MorphConfig {
            representation: Representation::Rle,
            parallelism: Parallelism::Sequential,
            ..MorphConfig::default()
        };
        for density in [0u32, 5, 50, 100] {
            let noise = synth::noise(19, 27, u64::from(density) * 7 + 1);
            let img = Image::from_fn(19, 27, |y, x| {
                if u32::from(noise.view().get(y, x)) * 100 < density * 255 {
                    255u8
                } else {
                    0
                }
            });
            for op in [FilterOp::Erode, FilterOp::Dilate, FilterOp::Open, FilterOp::Close] {
                let dense = FilterSpec::new(op, 5, 3).run_once::<u8>(&img).unwrap();
                let rle = FilterSpec::new(op, 5, 3)
                    .with_config(cfg_rle)
                    .run_once::<u8>(&img)
                    .unwrap();
                assert!(rle.same_pixels(&dense), "{op:?} density {density}");
            }
        }
        // chains RLE can't serve (Gradient needs subtraction) and
        // non-binary sources both fall back to the dense path
        let gray = synth::noise(19, 27, 9);
        for op in [FilterOp::Gradient, FilterOp::Erode] {
            let dense = FilterSpec::new(op, 3, 3).run_once::<u8>(&gray).unwrap();
            let rle = FilterSpec::new(op, 3, 3)
                .with_config(cfg_rle)
                .run_once::<u8>(&gray)
                .unwrap();
            assert!(rle.same_pixels(&dense), "fallback {op:?}");
        }
    }

    #[test]
    fn auto_representation_is_always_bit_identical() {
        // Auto may pick either route; output must not depend on it
        let cfg = MorphConfig {
            representation: Representation::Auto,
            parallelism: Parallelism::Sequential,
            ..MorphConfig::default()
        };
        for (h, w) in [(16, 16), (64, 96)] {
            let img = Image::from_fn(h, w, |y, x| if (y * w + x) % 19 == 0 { 255u8 } else { 0 });
            let dense = FilterSpec::new(FilterOp::Open, 3, 3).run_once::<u8>(&img).unwrap();
            let auto = FilterSpec::new(FilterOp::Open, 3, 3)
                .with_config(cfg)
                .run_once::<u8>(&img)
                .unwrap();
            assert!(auto.same_pixels(&dense), "{h}x{w}");
        }
    }
}
