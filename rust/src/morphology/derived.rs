//! Derived morphological operations (§2: "other morphological
//! operations, such as opening, closing, morphological gradient, can be
//! expressed via erosion, dilation and arithmetical operations") —
//! generic over the pixel depth.
//!
//! Since the plan–execute redesign these are thin wrappers: each op is
//! a one-element [`FilterOp`] chain executed through the *same lowered
//! step sequence* ([`super::plan::lower`]) the native [`FilterPlan`]
//! executor runs — one source of derived-op structure for both the
//! counted (backend-generic, sequential) and native (arena-backed,
//! banded) paths.  Native callers that run an op more than once should
//! plan a [`super::plan::FilterSpec`] instead and reuse it — derived
//! ops gain their `_into` form for free via [`FilterPlan::run`].
//!
//! [`FilterOp`]: super::plan::FilterOp
//! [`FilterPlan`]: super::plan::FilterPlan
//! [`FilterPlan::run`]: super::plan::FilterPlan::run

use super::plan::{run_chain, FilterOp};
use super::{MorphConfig, MorphPixel};
use crate::image::{Image, ImageView, ImageViewMut};
use crate::neon::Backend;

/// Opening: dilation of the erosion.  Removes bright structures smaller
/// than the SE.
pub fn opening<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    run_chain(b, src, &[FilterOp::Open], w_x, w_y, cfg)
}

/// Closing: erosion of the dilation.  Removes dark structures smaller
/// than the SE.
pub fn closing<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    run_chain(b, src, &[FilterOp::Close], w_x, w_y, cfg)
}

/// Morphological gradient: dilation − erosion (edge strength).
pub fn gradient<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    run_chain(b, src, &[FilterOp::Gradient], w_x, w_y, cfg)
}

/// White top-hat: src − opening (bright details smaller than the SE).
pub fn tophat<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    run_chain(b, src, &[FilterOp::TopHat], w_x, w_y, cfg)
}

/// Black top-hat: closing − src (dark details smaller than the SE).
pub fn blackhat<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    run_chain(b, src, &[FilterOp::BlackHat], w_x, w_y, cfg)
}

/// Saturating pixelwise subtraction `a - b` (clamped at 0).  Shared
/// with the generic chain runner in [`super::plan`].
pub(crate) fn pixelwise_sub<P: MorphPixel>(a: ImageView<'_, P>, b: ImageView<'_, P>) -> Image<P> {
    assert_eq!(a.height(), b.height());
    assert_eq!(a.width(), b.width());
    Image::from_fn(a.height(), a.width(), |y, x| {
        a.get(y, x).sat_sub(b.get(y, x))
    })
}

/// [`pixelwise_sub`] writing into a caller-provided destination — the
/// allocation-free form the plan executor's `Sub` steps use.
pub(crate) fn pixelwise_sub_into<P: MorphPixel>(
    a: ImageView<'_, P>,
    b: ImageView<'_, P>,
    mut dst: ImageViewMut<'_, P>,
) {
    assert_eq!(a.height(), b.height());
    assert_eq!(a.width(), b.width());
    assert_eq!((dst.height(), dst.width()), (a.height(), a.width()));
    for y in 0..a.height() {
        let (ra, rb, rd) = (a.row(y), b.row(y), dst.row_mut(y));
        for (x, slot) in rd.iter_mut().enumerate() {
            *slot = ra[x].sat_sub(rb[x]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::neon::Native;

    fn cfg() -> MorphConfig {
        MorphConfig::default()
    }

    #[test]
    fn opening_is_antiextensive_closing_extensive() {
        let img = synth::noise(30, 40, 14);
        let o = opening(&mut Native, &img, 5, 5, &cfg());
        let c = closing(&mut Native, &img, 5, 5, &cfg());
        for y in 0..30 {
            for x in 0..40 {
                assert!(o.get(y, x) <= img.get(y, x), "opening must shrink");
                assert!(c.get(y, x) >= img.get(y, x), "closing must grow");
            }
        }
    }

    #[test]
    fn opening_closing_idempotent() {
        let img = synth::document(64, 96, 4);
        let o1 = opening(&mut Native, &img, 5, 3, &cfg());
        let o2 = opening(&mut Native, &o1, 5, 3, &cfg());
        assert!(o1.same_pixels(&o2), "opening idempotence");
        let c1 = closing(&mut Native, &img, 5, 3, &cfg());
        let c2 = closing(&mut Native, &c1, 5, 3, &cfg());
        assert!(c1.same_pixels(&c2), "closing idempotence");
    }

    #[test]
    fn gradient_zero_on_flat_image() {
        let img = crate::image::Image::filled(20, 20, 77u8);
        let g = gradient(&mut Native, &img, 5, 5, &cfg());
        assert_eq!(g.min_max(), Some((0, 0)));
    }

    #[test]
    fn gradient_zero_on_flat_image_u16() {
        let img = crate::image::Image::filled(20, 20, 40_000u16);
        let g = gradient(&mut Native, &img, 5, 5, &cfg());
        assert_eq!(g.min_max(), Some((0, 0)));
    }

    #[test]
    fn gradient_positive_at_edges() {
        let img = synth::checkerboard(32, 32, 8);
        let g = gradient(&mut Native, &img, 3, 3, &cfg());
        assert_eq!(g.get(8, 8), 255); // block corner is an edge
        assert_eq!(g.get(4, 4), 0); // block interior is flat
    }

    #[test]
    fn tophat_extracts_small_bright_speck() {
        let mut img = crate::image::Image::filled(21, 21, 10u8);
        img.set(10, 10, 200); // speck smaller than SE
        let t = tophat(&mut Native, &img, 5, 5, &cfg());
        assert_eq!(t.get(10, 10), 190);
        assert_eq!(t.get(0, 0), 0);
    }

    #[test]
    fn tophat_extracts_speck_above_u8_range() {
        // a u16 speck whose contrast exceeds 255 — impossible at u8 depth
        let mut img = crate::image::Image::filled(21, 21, 1_000u16);
        img.set(10, 10, 60_000);
        let t = tophat(&mut Native, &img, 5, 5, &cfg());
        assert_eq!(t.get(10, 10), 59_000);
        assert_eq!(t.get(0, 0), 0);
    }

    #[test]
    fn blackhat_extracts_small_dark_speck() {
        let mut img = crate::image::Image::filled(21, 21, 200u8);
        img.set(10, 10, 15);
        let bh = blackhat(&mut Native, &img, 5, 5, &cfg());
        assert_eq!(bh.get(10, 10), 185);
        assert_eq!(bh.get(20, 20), 0);
    }
}
