//! §5.3 hybrid dispatch: linear below the crossover window, vHGW above.
//!
//! The paper measured the crossovers on the Exynos 5422 as `w_y⁰ = 69`
//! (horizontal/rows pass) and `w_x⁰ = 59` (vertical/cols pass) — they
//! differ "because passes work with memory asymmetrically".
//! [`calibrate_thresholds`] re-derives both numbers on *this* stack by
//! pricing the counted instruction mixes of both algorithms across the
//! window sweep with the cost model — the reproduction of the §5.3
//! claim (see `EXPERIMENTS.md`).  Pricing and calibration are generic
//! over the pixel depth: a `u16` probe counts 8-lane vector ops and 2×
//! the streamed bytes, so its crossovers may legitimately differ from
//! the u8 ones.
//!
//! [`resolve_method`] is the single resolution point for hybrid
//! dispatch: the sequential passes call it per invocation, while
//! [`super::plan::FilterSpec::plan`] calls it **once** per pass when
//! resolving a [`super::plan::FilterPlan`] — plan runs never re-resolve.

use super::{linear, vhgw, MorphOp, MorphPixel, PassMethod};
use crate::costmodel::CostModel;
use crate::image::ImageView;
use crate::neon::Counting;

/// Paper values (Exynos 5422, 800×600 u8).
pub const PAPER_WY0: usize = 69;
pub const PAPER_WX0: usize = 59;

/// Crossover thresholds for hybrid dispatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct HybridThresholds {
    /// Rows (horizontal) pass: use linear while `w_y <= wy0`.
    pub wy0: usize,
    /// Cols (vertical) pass: use linear while `w_x <= wx0`.
    pub wx0: usize,
}

impl HybridThresholds {
    /// The paper's measured thresholds.
    ///
    /// These are u8 measurements, but they are also the default for u16
    /// dispatch: both SIMD series scale near-uniformly (~2×) when lanes
    /// halve and bytes double (see `fig3::run_u16`), so the crossover
    /// is approximately depth-stable.  Workloads that care can re-derive
    /// exact u16 thresholds with [`calibrate_thresholds`] on a u16
    /// probe and put them in [`crate::morphology::MorphConfig`].
    pub fn paper() -> Self {
        HybridThresholds {
            wy0: PAPER_WY0,
            wx0: PAPER_WX0,
        }
    }
}

impl Default for HybridThresholds {
    fn default() -> Self {
        Self::paper()
    }
}

/// Resolve a possibly-hybrid method to a concrete one for this window.
pub fn resolve_method(method: PassMethod, window: usize, threshold: usize) -> PassMethod {
    match method {
        PassMethod::Hybrid => {
            if window <= threshold {
                PassMethod::Linear
            } else {
                PassMethod::Vhgw
            }
        }
        m => m,
    }
}

/// Cost-model price (ns) of one SIMD rows pass at `window` on a probe
/// view — used by calibration and the Fig. 3 harness.
pub fn price_rows_pass<P: MorphPixel>(
    model: &CostModel,
    probe: ImageView<'_, P>,
    window: usize,
    method: PassMethod,
) -> f64 {
    let mut c = Counting::new();
    match method {
        PassMethod::Linear => {
            let _ = linear::rows_simd_linear(&mut c, probe, window, MorphOp::Erode);
        }
        PassMethod::Vhgw => {
            let _ = vhgw::rows_simd_vhgw(&mut c, probe, window, MorphOp::Erode);
        }
        PassMethod::Hybrid => panic!("price a concrete method"),
    }
    model.price_ns(&c.mix)
}

/// Cost-model price (ns) of one SIMD cols pass at `window` on a probe
/// view (linear = §5.2.2 direct; vHGW = §5.2.1 transpose sandwich at
/// this pixel depth).
pub fn price_cols_pass<P: MorphPixel>(
    model: &CostModel,
    probe: ImageView<'_, P>,
    window: usize,
    method: PassMethod,
) -> f64 {
    let mut c = Counting::new();
    match method {
        PassMethod::Linear => {
            let _ = linear::cols_simd_linear(&mut c, probe, window, MorphOp::Erode);
        }
        PassMethod::Vhgw => {
            let t = P::transpose_image(&mut c, probe);
            let f = vhgw::rows_simd_vhgw(&mut c, &t, window, MorphOp::Erode);
            let _ = P::transpose_image(&mut c, f.view());
        }
        PassMethod::Hybrid => panic!("price a concrete method"),
    }
    model.price_ns(&c.mix)
}

/// Find the largest odd window for which linear is still no slower than
/// vHGW under the cost model (scanning odd windows up to `max_window`).
fn crossover<'a, P: MorphPixel>(
    model: &CostModel,
    probe: ImageView<'a, P>,
    max_window: usize,
    price: impl Fn(&CostModel, ImageView<'a, P>, usize, PassMethod) -> f64,
) -> usize {
    let mut last_linear_win = 1;
    let mut w = 3;
    while w <= max_window {
        let lin = price(model, probe, w, PassMethod::Linear);
        let vh = price(model, probe, w, PassMethod::Vhgw);
        if lin <= vh {
            last_linear_win = w;
        } else if w > last_linear_win + 8 {
            // robust stop: vHGW has won for several sizes in a row
            break;
        }
        w += 2;
    }
    last_linear_win
}

/// Re-derive the §5.3 crossovers from the instruction mixes + cost model.
///
/// `probe` should share the workload's aspect *and dtype*; size only
/// needs to be large enough to amortize per-call overhead (mixes scale
/// linearly in pixels, so the crossover is size-stable — verified in
/// tests).
pub fn calibrate_thresholds<'a, P: MorphPixel>(
    model: &CostModel,
    probe: impl Into<ImageView<'a, P>>,
    max_window: usize,
) -> HybridThresholds {
    let probe = probe.into();
    HybridThresholds {
        wy0: crossover(model, probe, max_window, price_rows_pass),
        wx0: crossover(model, probe, max_window, price_cols_pass),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;

    #[test]
    fn resolve_switches_at_threshold() {
        assert_eq!(resolve_method(PassMethod::Hybrid, 69, 69), PassMethod::Linear);
        assert_eq!(resolve_method(PassMethod::Hybrid, 71, 69), PassMethod::Vhgw);
        assert_eq!(resolve_method(PassMethod::Linear, 999, 69), PassMethod::Linear);
        assert_eq!(resolve_method(PassMethod::Vhgw, 3, 69), PassMethod::Vhgw);
    }

    #[test]
    fn linear_price_grows_with_window_vhgw_flat() {
        if cfg!(debug_assertions) {
            eprintln!("SKIP in debug: paper-sized probe pricing (runs under --release / make test)");
            return;
        }
        // shapes on the paper-sized workload: linear scales with w,
        // vHGW stays ~flat, and linear wins small windows outright
        let model = CostModel::exynos5422();
        let probe = synth::paper_image(2);
        let lin3 = price_rows_pass(&model, probe.view(), 3, PassMethod::Linear);
        let lin31 = price_rows_pass(&model, probe.view(), 31, PassMethod::Linear);
        assert!(lin31 > 1.4 * lin3, "linear should scale with w: {lin3} {lin31}");
        let vh3 = price_rows_pass(&model, probe.view(), 3, PassMethod::Vhgw);
        let vh31 = price_rows_pass(&model, probe.view(), 31, PassMethod::Vhgw);
        assert!(vh31 < 1.4 * vh3, "vhgw should be ~flat in w: {vh3} {vh31}");
        assert!(lin3 < vh3, "linear must win small windows (rows)");
        let cl3 = price_cols_pass(&model, probe.view(), 3, PassMethod::Linear);
        let cv3 = price_cols_pass(&model, probe.view(), 3, PassMethod::Vhgw);
        assert!(cl3 < cv3, "linear must win small windows (cols)");
    }

    #[test]
    fn u16_pass_prices_higher_than_u8() {
        // 8 lanes/op instead of 16, 2x the streamed bytes: a u16 pass on
        // the same dimensions must price higher than the u8 one
        let model = CostModel::exynos5422();
        let probe8 = synth::noise(60, 80, 3);
        let probe16 = synth::noise_u16(60, 80, 3);
        let p8 = price_rows_pass(&model, probe8.view(), 9, PassMethod::Linear);
        let p16 = price_rows_pass(&model, probe16.view(), 9, PassMethod::Linear);
        assert!(
            p16 > 1.5 * p8,
            "u16 rows pass should price ~2x u8: {p8} vs {p16}"
        );
    }

    // The full crossover sweep (w up to 121 on the 800x600 workload) is
    // minutes-slow without optimization, so the exact §5.3 reproduction
    // lives in tests/paper_parity.rs and runs in release; this smoke
    // check only verifies the calibration machinery on a short sweep.
    #[test]
    fn calibrate_thresholds_smoke() {
        let model = CostModel::exynos5422();
        let probe = synth::noise(150, 200, 7);
        let t = calibrate_thresholds(&model, &probe, 21);
        // linear wins everywhere this far below the crossover
        assert_eq!(t.wy0, 21);
        assert_eq!(t.wx0, 21);
    }

    #[test]
    fn calibrate_thresholds_u16_smoke() {
        let model = CostModel::exynos5422();
        let probe = synth::noise_u16(100, 120, 7);
        let t = calibrate_thresholds(&model, &probe, 13);
        assert_eq!(t.wy0, 13, "linear must win small u16 windows (rows)");
        assert_eq!(t.wx0, 13, "linear must win small u16 windows (cols)");
    }
}
