//! Intra-image band-sharded parallel execution of the separable passes
//! — **zero-copy**: band jobs borrow their inputs and outputs as
//! strided views, never staging a slab.
//!
//! The paper's 1-D passes are embarrassingly parallel *within* one
//! image: every output row of the rows-window pass depends only on the
//! `window` input rows around it, and every row of the direct
//! cols-window pass depends only on itself.  This module splits a pass
//! into contiguous **row bands** and executes the bands concurrently on
//! a shared worker pool, producing output that is **bit-identical** to
//! the sequential pass (asserted exhaustively in
//! `rust/tests/parallel_banding.rs`).
//!
//! ## Band / halo geometry
//!
//! For a rows-window pass with window `w` (wing `r = w/2`), output rows
//! `[b0, b1)` of a band read input rows `[b0 - r, b1 + r) ∩ [0, h)` —
//! the band plus a `w - 1`-row **halo** (`r` rows on each side, clamped
//! at the image edges).  Each band job takes
//!
//! * a borrowed [`ImageView`] of its haloed input rows
//!   ([`ImageView::sub_rows`] — no pixels copied), and
//! * its disjoint [`crate::image::ImageViewMut`] slice of the
//!   destination ([`crate::image::ImageViewMut::split_at_rows_mut`]),
//!
//! and runs the sequential kernel's `_into` form
//! ([`separable::pass_rows_into`]) with the halo offset, writing core
//! rows in place.  Bit-identity follows from the reduction structure:
//! every output pixel is the exact min/max over `window ∩ image` with
//! identity padding, and the haloed view contains precisely that window
//! for every core row — the view edge coincides with the image edge
//! exactly where the original pass would have clamped (proved
//! case-by-case in the module tests; mirrored in
//! `python/tests/test_band_geometry.py`).
//!
//! ## Why the aliasing is sound
//!
//! Adjacent bands' *input* views overlap (their halos share rows) while
//! their *output* views are disjoint.  Overlapping reads are plain
//! shared `&[P]` borrows — many `ImageView`s may alias.  Disjoint
//! writes come in two shapes, both owned by
//! [`crate::image::ImageViewMut`]:
//!
//! * **row bands** ([`crate::image::ImageViewMut::split_rows_mut`]):
//!   contiguous destination spans, partitioned exactly as
//!   `slice::split_at_mut` would — a band can never write another
//!   band's rows;
//! * **column stripes**
//!   ([`crate::image::ImageViewMut::split_cols_mut`], used by the
//!   banded transpose): the stripes interleave in memory (stripe `i`
//!   owns columns `[c0, c1)` of *every* row), which no partition of a
//!   `&mut [P]` can express, so `ImageViewMut` carries a raw base
//!   pointer and each stripe addresses only `row_base + x` for its own
//!   `x ∈ [c0, c1)` — disjointness is by the column plan (asserted to
//!   tile `[0, w)` contiguously), not the borrow checker.
//!
//! (Since PR 2 re-used the owned-`&Image` kernels, it had to *copy* a
//! haloed slab in and stitch core rows out of every band — two full
//! image copies per banded pass; the view-based rewrite deleted both,
//! which is also what the cost model's zero-copy parallel term always
//! assumed.)
//!
//! The direct cols-window pass (window across columns) is banded with a
//! **zero halo** — rows are independent
//! ([`separable::pass_cols_direct_into`]).  The §5.2.1 transpose
//! sandwich is banded **end-to-end**: both whole-image transposes run
//! through [`transpose_image_banded_into`] — each source row band
//! (tile-aligned, [`MorphPixel::LANES`]-row multiples) is transposed by
//! one job into its disjoint destination *column stripe*, zero-copy and
//! bit-identical to the sequential §4 tile network for any partition —
//! and the middle rows pass is striped **in place over the transposed
//! buffer** in the same tile-aligned bands, so no §4 transpose tile
//! ever straddles a band boundary in either phase.
//!
//! ## Execution model
//!
//! Bands run on a process-wide [`BandPool`] of `std::thread` workers
//! ([`BandPool::global`]).  A banded pass submits its band jobs with
//! [`BandPool::scope`] — a fork-join primitive that runs the first job
//! on the calling thread, queues the rest, and blocks until every job
//! has completed (so jobs may borrow the caller's stack — here, the
//! source view and the split destination views).  Band jobs never spawn
//! nested scopes, so a scope can never deadlock on pool capacity;
//! coordinator workers are separate threads that *share* the band pool,
//! so intra-image bands and cross-request concurrency contend for the
//! same cores instead of oversubscribing them.
//!
//! ## Dispatch
//!
//! Banding pays a fork cost (pool wake-up + per-band job bookkeeping),
//! so [`filter_native`] consults the cost model before sharding: the
//! sequential pass is priced with
//! [`crate::costmodel::CostModel::estimate_separable_cost`] and
//! [`crate::costmodel::CostModel::plan_workers`] picks the band count
//! whose modeled parallel price (compute ÷ P, memory *not* scaled — the
//! bands share one memory bus) beats sequential by ≥10%; small images
//! therefore stay sequential.  [`super::Parallelism`] in
//! [`super::MorphConfig`] overrides the policy (`Sequential`, `Fixed`,
//! `Auto`).
//!
//! ## Fused multi-image super-passes
//!
//! Small-image batches pay the fork cost per image under per-image
//! banding — exactly the document-recognition workload (many small
//! crops) the paper targets.  The fused executors
//! ([`pass_rows_fused_into`] / [`pass_cols_direct_fused_into`]) treat a
//! batch of `n` same-shape images as one **fused virtual image** of
//! `n × h` rows: [`split_fused_bands`] cuts the fused extent into
//! bands that may span image boundaries, each band job walks its
//! per-image segments, and ONE fork-join covers the whole batch.  The
//! **seam-fence invariant** keeps the result bit-identical to per-image
//! execution: a band carries an image seam only as a segment boundary —
//! every segment is haloed against its *own* image's rows (clamped at
//! that image's edges), so no reduction window ever reads across a
//! seam.  Pinned against the per-image path in
//! `rust/tests/fused_batch.rs` and mirrored in
//! `python/tests/test_fused_geometry.py`.
//!
//! ## Region of interest
//!
//! [`filter_roi`] composes the same view machinery in 2-D: it filters
//! the borrowed haloed sub-rectangle around a [`Roi`] and returns
//! exactly the pixels `crop(filter(full), roi)` would produce, at both
//! pixel depths and under both borders.
//!
//! ## Relation to the plan–execute API
//!
//! Since the [`super::plan`] redesign this module provides the banded
//! **executors** ([`pass_rows_banded_into`] /
//! [`pass_cols_direct_banded_into`] — zero-copy, caller-provided
//! destinations) plus the [`BandPool`] and the cost-model dispatch
//! ([`effective_bands`]); the entry points ([`filter_native`],
//! [`filter_roi`], the `*_native` derived ops) are thin wrappers over
//! one-shot [`super::plan::FilterSpec`] plans, which resolve banding
//! once and drive these executors against their scratch arenas.

use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use super::hybrid::resolve_method;
use super::plan::{FilterOp, FilterSpec};
use super::{
    separable, HybridThresholds, MorphConfig, MorphOp, MorphPixel, Parallelism, PassMethod, Roi,
    VerticalStrategy,
};
use crate::costmodel::CostModel;
use crate::image::{Image, ImageView, ImageViewMut};
use crate::neon::Native;

// ---------------------------------------------------------------------------
// band geometry
// ---------------------------------------------------------------------------

/// Split `len` items into at most `parts` contiguous, non-empty,
/// near-even ranges covering `[0, len)`.
pub fn split_bands(len: usize, parts: usize) -> Vec<Range<usize>> {
    split_bands_aligned(len, parts, 1)
}

/// Like [`split_bands`], but every interior band boundary is rounded
/// down to a multiple of `align` (tile-aligned stripes: no §4 transpose
/// tile straddles a boundary when `align == LANES`).
pub fn split_bands_aligned(len: usize, parts: usize, align: usize) -> Vec<Range<usize>> {
    let align = align.max(1);
    let parts = parts.max(1);
    if len == 0 {
        return Vec::new();
    }
    let mut out = Vec::with_capacity(parts.min(len));
    let mut start = 0usize;
    for i in 1..=parts {
        let mut end = i * len / parts;
        if i != parts {
            end = end / align * align;
        } else {
            end = len;
        }
        if end > start {
            out.push(start..end);
            start = end;
        }
    }
    out
}

/// Input range a band needs: the band plus a `wing`-sized halo on each
/// side, clamped to `[0, len)`.
pub fn halo(band: &Range<usize>, wing: usize, len: usize) -> Range<usize> {
    band.start.saturating_sub(wing)..(band.end + wing).min(len)
}

// ---------------------------------------------------------------------------
// the shared worker pool
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send>;

/// Process-wide pool of band workers (fork-join via [`BandPool::scope`]).
pub struct BandPool {
    tx: Sender<Job>,
    threads: usize,
}

/// Per-scope completion state: outstanding job count + panic flag.
struct ScopeSync {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl ScopeSync {
    fn new(n: usize) -> Self {
        ScopeSync {
            remaining: Mutex::new(n),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        }
    }

    fn count_down(&self) {
        let mut left = self.remaining.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut left = self.remaining.lock().unwrap();
        while *left > 0 {
            left = self.done.wait(left).unwrap();
        }
    }
}

/// Counts a job as finished even if it panics (the scope must never
/// block forever on a job that unwound).
struct CompletionGuard(Arc<ScopeSync>);

impl Drop for CompletionGuard {
    fn drop(&mut self) {
        self.0.count_down();
    }
}

/// Pool size used by [`BandPool::global`]: `available_parallelism`
/// clamped to 16, overridable with the `NEON_MORPH_BAND_WORKERS`
/// environment variable (serving deployments size the band pool to the
/// cores they actually own; see
/// [`crate::coordinator::CoordinatorConfig::max_bands_per_request`] for
/// the coordinator-side coupling).
pub fn default_pool_threads() -> usize {
    if let Some(n) = std::env::var("NEON_MORPH_BAND_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        return n.clamp(1, 64);
    }
    std::thread::available_parallelism().map_or(2, |n| n.get()).clamp(1, 16)
}

impl BandPool {
    /// A new pool with `threads` workers.  Workers live until the pool
    /// (its job sender) is dropped.
    pub fn new(threads: usize) -> BandPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            std::thread::Builder::new()
                .name(format!("morph-band-{i}"))
                .spawn(move || loop {
                    // hold the lock only while receiving, never while
                    // running a job
                    let job = match rx.lock() {
                        Ok(guard) => guard.recv(),
                        Err(_) => return,
                    };
                    match job {
                        Ok(job) => job(),
                        Err(_) => return, // pool dropped
                    }
                })
                .expect("spawning band worker");
        }
        BandPool { tx, threads }
    }

    /// An explicitly-sized pool — the serving-deployment constructor
    /// (`workers × max_bands_per_request ≤ cores`; the name matches the
    /// coordinator-side knob).  Identical to [`BandPool::new`].
    pub fn with_workers(workers: usize) -> BandPool {
        BandPool::new(workers)
    }

    /// Worker count (an upper bound on useful band counts).
    pub fn size(&self) -> usize {
        self.threads
    }

    /// The process-wide shared pool, created on first use.
    pub fn global() -> &'static BandPool {
        static POOL: OnceLock<BandPool> = OnceLock::new();
        POOL.get_or_init(|| BandPool::new(default_pool_threads()))
    }

    /// Fork-join: run every job, returning only when all have finished.
    ///
    /// The first job runs on the calling thread (the caller is a worker
    /// too); the rest are queued on the pool.  Jobs may borrow from the
    /// caller's stack — the scope blocks on a completion latch before
    /// returning, even when a job panics (panics are re-raised here).
    pub fn scope<'s>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 's>>) {
        let n = jobs.len();
        if n == 0 {
            return;
        }
        let sync = Arc::new(ScopeSync::new(n - 1));
        let mut iter = jobs.into_iter();
        let first = iter.next().unwrap();
        for job in iter {
            // SAFETY: the job may borrow data living on the caller's
            // stack ('s).  `scope` does not return — on any path,
            // including panics — until `sync.wait()` has observed every
            // queued job's CompletionGuard drop, so all borrows in
            // `job` strictly outlive its execution.  Erasing 's to
            // 'static is therefore sound.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 's>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            let sync = Arc::clone(&sync);
            let wrapped: Job = Box::new(move || {
                let guard = CompletionGuard(sync);
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    guard.0.panicked.store(true, Ordering::SeqCst);
                }
            });
            if let Err(send_err) = self.tx.send(wrapped) {
                // pool shut down (impossible for the global pool):
                // degrade to inline execution, keeping the latch exact
                (send_err.0)();
            }
        }
        let first_result = catch_unwind(AssertUnwindSafe(first));
        sync.wait();
        if sync.panicked.load(Ordering::SeqCst) {
            panic!("a band job panicked on the worker pool");
        }
        if let Err(payload) = first_result {
            resume_unwind(payload);
        }
    }
}

// ---------------------------------------------------------------------------
// banded passes (zero-copy: borrowed haloed reads, disjoint in-place writes)
// ---------------------------------------------------------------------------

/// Grow a per-band scratch pool to `n` slots and return them.  Empty
/// `Vec`s cost nothing; each band job gets its own slot, so the vHGW
/// `R` buffers are disjoint across concurrent bands and — when the
/// caller retains the pool (a plan arena) — allocation-free on reuse.
fn scratch_slots<P>(scratch: &mut Vec<Vec<P>>, n: usize) -> &mut [Vec<P>] {
    if scratch.len() < n {
        scratch.resize_with(n, Vec::new);
    }
    &mut scratch[..n]
}

/// Rows-window pass executed as `bands` haloed row bands on `pool`.
/// Bit-identical to [`separable::pass_rows`] with the same arguments.
pub fn pass_rows_banded<'a, P: MorphPixel>(
    pool: &BandPool,
    src: impl Into<ImageView<'a, P>>,
    window: usize,
    op: MorphOp,
    method: PassMethod,
    simd: bool,
    thresholds: HybridThresholds,
    bands: usize,
) -> Image<P> {
    let src = src.into();
    pass_rows_banded_aligned(pool, src, window, op, method, simd, thresholds, bands, 1)
}

/// [`pass_rows_banded`] with band boundaries aligned to `align`-row
/// multiples (tile-aligned stripes for the transpose sandwich).
fn pass_rows_banded_aligned<P: MorphPixel>(
    pool: &BandPool,
    src: ImageView<'_, P>,
    window: usize,
    op: MorphOp,
    method: PassMethod,
    simd: bool,
    thresholds: HybridThresholds,
    bands: usize,
    align: usize,
) -> Image<P> {
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.to_image();
    }
    let mut dst = Image::zeros(h, w);
    pass_rows_banded_into(
        pool,
        src,
        dst.view_mut(),
        window,
        op,
        method,
        simd,
        thresholds,
        bands,
        align,
        &mut Vec::new(),
    );
    dst
}

/// Rows-window pass banded **into** a caller-provided destination — the
/// zero-allocation executor [`super::plan::FilterPlan`] runs on its
/// scratch arena.  `dst` must match `src`'s shape; interior band
/// boundaries are rounded to `align`-row multiples.  Degrades to the
/// sequential `_into` kernel when the plan collapses to one band.
/// `scratch` holds one vHGW `R`-buffer slot per band (grown on first
/// use; retained callers reuse them allocation-free — linear bands
/// leave their slots empty).
#[allow(clippy::too_many_arguments)]
pub fn pass_rows_banded_into<P: MorphPixel>(
    pool: &BandPool,
    src: ImageView<'_, P>,
    mut dst: ImageViewMut<'_, P>,
    window: usize,
    op: MorphOp,
    method: PassMethod,
    simd: bool,
    thresholds: HybridThresholds,
    bands: usize,
    align: usize,
    scratch: &mut Vec<Vec<P>>,
) {
    let (h, w) = (src.height(), src.width());
    debug_assert_eq!((dst.height(), dst.width()), (h, w));
    if h == 0 || w == 0 {
        return;
    }
    if window == 1 {
        dst.copy_rows_from(src, 0);
        return;
    }
    let plan = split_bands_aligned(h, bands, align);
    let slots = scratch_slots(scratch, plan.len().max(1));
    if plan.len() <= 1 {
        separable::pass_rows_into(
            &mut Native,
            src,
            dst,
            0,
            window,
            op,
            method,
            simd,
            thresholds,
            &mut slots[0],
        );
        return;
    }
    let wing = window / 2;
    // disjoint per-band output views — no staging slab, no stitch
    let chunks = dst.split_rows_mut(&plan);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(plan.len());
    for ((band, chunk), slot) in plan.iter().cloned().zip(chunks).zip(slots.iter_mut()) {
        jobs.push(Box::new(move || {
            let input = halo(&band, wing, h);
            let skip = band.start - input.start;
            separable::pass_rows_into(
                &mut Native,
                src.sub_rows(input),
                chunk,
                skip,
                window,
                op,
                method,
                simd,
                thresholds,
                slot,
            );
        }));
    }
    pool.scope(jobs);
}

/// §4 tile-network transpose executed as row bands on `pool`, each band
/// writing its disjoint destination **column stripe** — zero-copy and
/// bit-identical to [`MorphPixel::transpose_image_into`] for any band
/// count (pinned in `rust/tests/parallel_banding.rs`).
///
/// Source row band `[y0, y1)` (tile-aligned by [`split_bands_aligned`]
/// with `align == P::LANES`, so no §4 tile straddles a cut) becomes
/// destination columns `[y0, y1)` across all `w` destination rows.  The
/// stripes are carved with [`ImageViewMut::split_cols_mut`]; they
/// interleave in memory but are index-disjoint, and each band job runs
/// the sequential tile network [`MorphPixel::transpose_band_into`] over
/// its own stripe.  With one band (or a degenerate shape) the
/// sequential whole-image kernel runs on the caller thread — same
/// instruction census, no fork.
pub fn transpose_image_banded_into<P: MorphPixel>(
    pool: &BandPool,
    src: ImageView<'_, P>,
    dst: ImageViewMut<'_, P>,
    bands: usize,
) {
    let (h, w) = (src.height(), src.width());
    debug_assert_eq!(
        (dst.height(), dst.width()),
        (w, h),
        "transpose destination must be the source's transpose shape"
    );
    if h == 0 || w == 0 {
        return;
    }
    let plan = split_bands_aligned(h, bands, P::LANES);
    if plan.len() <= 1 {
        P::transpose_image_into(&mut Native, src, dst);
        return;
    }
    let stripes = dst.split_cols_mut(&plan);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(plan.len());
    for (band, mut stripe) in plan.iter().cloned().zip(stripes) {
        jobs.push(Box::new(move || {
            P::transpose_band_into(&mut Native, src, &mut stripe, band);
        }));
    }
    pool.scope(jobs);
}

/// Fused-batch form of [`transpose_image_banded_into`]: transposes `n`
/// images with ONE fork-join covering every image's column stripes.
/// The band budget is spread `bands.div_ceil(n)` per image, and each
/// image's cuts come from its own [`split_bands_aligned`] — **image-
/// local, tile-aligned** — so no §4 tile ever straddles a batch seam
/// (the fused seam-fence invariant holds trivially: a transpose band
/// never reads outside its own image).  With a band budget of 1 the
/// per-image sequential kernels run on the caller thread.
pub fn transpose_fused_banded_into<P: MorphPixel>(
    pool: &BandPool,
    srcs: &[ImageView<'_, P>],
    dsts: Vec<ImageViewMut<'_, P>>,
    bands: usize,
) {
    debug_assert_eq!(srcs.len(), dsts.len());
    let n = srcs.len();
    if n == 0 {
        return;
    }
    if bands <= 1 {
        for (src, dst) in srcs.iter().zip(dsts) {
            P::transpose_image_into(&mut Native, *src, dst);
        }
        return;
    }
    let per_img = bands.div_ceil(n);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(per_img * n);
    for (src, dst) in srcs.iter().copied().zip(dsts) {
        let (h, w) = (src.height(), src.width());
        debug_assert_eq!((dst.height(), dst.width()), (w, h));
        if h == 0 || w == 0 {
            continue;
        }
        let plan = split_bands_aligned(h, per_img, P::LANES);
        for (band, mut stripe) in plan.iter().cloned().zip(dst.split_cols_mut(&plan)) {
            jobs.push(Box::new(move || {
                P::transpose_band_into(&mut Native, src, &mut stripe, band);
            }));
        }
    }
    pool.scope(jobs);
}

/// Cols-window pass executed as row bands on `pool`.  Bit-identical to
/// [`separable::pass_cols`] with the same arguments.
///
/// * direct forms (scalar, and SIMD-linear §5.2.2) shard rows with a
///   zero halo — the window runs across columns, so rows are
///   independent; each band reads its borrowed row view and writes its
///   disjoint destination band in place;
/// * the §5.2.1 transpose sandwich is banded end-to-end: both
///   transposes run through [`transpose_image_banded_into`] and the
///   middle rows pass is striped in place over the *transposed* buffer,
///   all in the same [`MorphPixel::LANES`]-aligned bands (16-/8-column
///   stripes of the original image).
pub fn pass_cols_banded<'a, P: MorphPixel>(
    pool: &BandPool,
    src: impl Into<ImageView<'a, P>>,
    window: usize,
    op: MorphOp,
    method: PassMethod,
    simd: bool,
    vertical: VerticalStrategy,
    thresholds: HybridThresholds,
    bands: usize,
) -> Image<P> {
    let src = src.into();
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.to_image();
    }
    let m = resolve_method(method, window, thresholds.wx0);
    if separable::takes_sandwich(m, simd, vertical) {
        // §5.2.1: banded transpose ∘ banded rows pass ∘ banded
        // transpose, every phase striped to the §4 tile height of
        // this depth
        let mut t = Image::zeros(w, h);
        transpose_image_banded_into(pool, src, t.view_mut(), bands);
        let mid = pass_rows_banded_aligned(
            pool,
            t.view(),
            window,
            op,
            m,
            true,
            thresholds,
            bands,
            P::LANES,
        );
        let mut out = Image::zeros(h, w);
        transpose_image_banded_into(pool, mid.view(), out.view_mut(), bands);
        return out;
    }
    // direct forms: rows are independent, zero halo
    let mut dst = Image::zeros(h, w);
    pass_cols_direct_banded_into(
        pool,
        src,
        dst.view_mut(),
        window,
        op,
        m,
        simd,
        vertical,
        thresholds,
        bands,
        &mut Vec::new(),
    );
    dst
}

/// The *direct* (non-sandwich) cols-window pass banded **into** a
/// caller-provided destination with a zero halo (rows are independent).
/// Callers must have excluded the §5.2.1 sandwich case with
/// [`separable::takes_sandwich`] — the sandwich is banded over the
/// *transposed* buffer instead (see [`super::plan::FilterPlan`]).
/// `scratch` holds one vHGW `R`-row slot per band, as in
/// [`pass_rows_banded_into`].
#[allow(clippy::too_many_arguments)]
pub fn pass_cols_direct_banded_into<P: MorphPixel>(
    pool: &BandPool,
    src: ImageView<'_, P>,
    mut dst: ImageViewMut<'_, P>,
    window: usize,
    op: MorphOp,
    method: PassMethod,
    simd: bool,
    vertical: VerticalStrategy,
    thresholds: HybridThresholds,
    bands: usize,
    scratch: &mut Vec<Vec<P>>,
) {
    let (h, w) = (src.height(), src.width());
    debug_assert_eq!((dst.height(), dst.width()), (h, w));
    if h == 0 || w == 0 {
        return;
    }
    if window == 1 {
        dst.copy_rows_from(src, 0);
        return;
    }
    let m = resolve_method(method, window, thresholds.wx0);
    debug_assert!(
        !separable::takes_sandwich(m, simd, vertical),
        "sandwich configurations are banded over the transposed buffer"
    );
    let plan = split_bands(h, bands);
    let slots = scratch_slots(scratch, plan.len().max(1));
    if plan.len() <= 1 {
        separable::pass_cols_direct_into(
            &mut Native,
            src,
            dst,
            window,
            op,
            m,
            simd,
            vertical,
            thresholds,
            &mut slots[0],
        );
        return;
    }
    let chunks = dst.split_rows_mut(&plan);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(plan.len());
    for ((band, chunk), slot) in plan.iter().cloned().zip(chunks).zip(slots.iter_mut()) {
        jobs.push(Box::new(move || {
            separable::pass_cols_direct_into(
                &mut Native,
                src.sub_rows(band),
                chunk,
                window,
                op,
                m,
                simd,
                vertical,
                thresholds,
                slot,
            );
        }));
    }
    pool.scope(jobs);
}

// ---------------------------------------------------------------------------
// fused multi-image super-passes (bands span image boundaries)
// ---------------------------------------------------------------------------

/// One band of a fused multi-image pass: the per-image row segments
/// `(image index, local rows)` a single band job covers, in fused-row
/// order (image `i` contributes fused rows `[i·h, (i+1)·h)`).
pub type FusedBand = Vec<(usize, Range<usize>)>;

/// Split the fused extent of `n` stacked `h`-row images into at most
/// `parts` bands of contiguous *fused* rows, decomposed into per-image
/// segments.  Interior cut points are snapped down to a multiple of
/// `align` **within the image they fall in** — image seams (`i·h`) are
/// always legal cuts, so per-image segment boundaries have exactly the
/// geometry [`split_bands_aligned`] would produce for some band count
/// of that image.  That is the seam-fence invariant: a band never
/// *merges* rows across a seam into one kernel call; it carries the
/// seam as a segment boundary, and each segment is haloed against its
/// own image only.
pub fn split_fused_bands(n: usize, h: usize, parts: usize, align: usize) -> Vec<FusedBand> {
    let align = align.max(1);
    let parts = parts.max(1);
    let total = n * h;
    if total == 0 {
        return Vec::new();
    }
    let mut cuts = vec![0usize];
    for i in 1..parts {
        let g = i * total / parts;
        // snap within the image the cut lands in (g % h is the local
        // offset); a snapped cut never crosses its image's seam
        let snapped = g - (g % h) % align;
        if snapped > *cuts.last().unwrap() {
            cuts.push(snapped);
        }
    }
    cuts.push(total);
    let mut out = Vec::with_capacity(cuts.len() - 1);
    for pair in cuts.windows(2) {
        let (a, b) = (pair[0], pair[1]);
        let mut band = FusedBand::new();
        let mut pos = a;
        while pos < b {
            let img = pos / h;
            let lo = pos - img * h;
            let hi = (b - img * h).min(h);
            band.push((img, lo..hi));
            pos = img * h + hi;
        }
        out.push(band);
    }
    out
}

/// Shared skeleton of the fused passes: build the fused band plan over
/// the `n × h`-row virtual image, split every destination into its
/// per-band chunks, and run ONE fork-join where each band job walks its
/// per-image segments through `kernel` (haloed borrowed source view,
/// disjoint destination chunk, halo skip, per-band scratch slot).
#[allow(clippy::too_many_arguments)]
fn fused_pass_into<P: MorphPixel, K>(
    pool: &BandPool,
    srcs: &[ImageView<'_, P>],
    dsts: Vec<ImageViewMut<'_, P>>,
    window: usize,
    wing: usize,
    bands: usize,
    align: usize,
    scratch: &mut Vec<Vec<P>>,
    kernel: K,
) where
    K: Fn(ImageView<'_, P>, ImageViewMut<'_, P>, usize, &mut Vec<P>) + Copy + Send,
{
    let n = srcs.len();
    assert_eq!(n, dsts.len(), "fused batch: src/dst counts differ");
    if n == 0 {
        return;
    }
    let (h, w) = (srcs[0].height(), srcs[0].width());
    for (s, d) in srcs.iter().zip(&dsts) {
        assert_eq!((s.height(), s.width()), (h, w), "fused batch must share one shape");
        assert_eq!((d.height(), d.width()), (h, w), "fused batch must share one shape");
    }
    if h == 0 || w == 0 {
        return;
    }
    if window == 1 {
        for (s, mut d) in srcs.iter().zip(dsts) {
            d.copy_rows_from(*s, 0);
        }
        return;
    }
    let plan = split_fused_bands(n, h, bands, align);
    // each image's segments appear in increasing row order across the
    // (ordered) bands and tile [0, h) contiguously, so one
    // `split_rows_mut` per destination yields every band chunk
    let mut per_img: Vec<Vec<Range<usize>>> = vec![Vec::new(); n];
    for band in &plan {
        for (img, rows) in band {
            per_img[*img].push(rows.clone());
        }
    }
    let mut chunk_queues: Vec<std::collections::VecDeque<ImageViewMut<'_, P>>> = dsts
        .into_iter()
        .zip(&per_img)
        .map(|(d, rows)| d.split_rows_mut(rows).into())
        .collect();
    let slots = scratch_slots(scratch, plan.len().max(1));
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(plan.len());
    for (band, slot) in plan.iter().zip(slots.iter_mut()) {
        // seam fence: every segment is haloed against its OWN image's
        // rows, clamped at [0, h) — a window never reads across a seam
        let segs: Vec<(ImageView<'_, P>, ImageViewMut<'_, P>, usize)> = band
            .iter()
            .map(|(img, rows)| {
                let input = halo(rows, wing, h);
                let skip = rows.start - input.start;
                let chunk = chunk_queues[*img].pop_front().expect("band order");
                (srcs[*img].sub_rows(input), chunk, skip)
            })
            .collect();
        jobs.push(Box::new(move || {
            for (sv, chunk, skip) in segs {
                kernel(sv, chunk, skip, slot);
            }
        }));
    }
    pool.scope(jobs);
}

/// Rows-window pass over a **fused batch** of same-shape images: one
/// band plan spans the whole `n × h`-row stack ([`split_fused_bands`]),
/// one fork-join executes it, and per-image halo fences keep every
/// output bit-identical to running [`pass_rows_banded_into`] (or the
/// sequential kernel) per image.  `scratch` holds one slot per fused
/// band, arena-retained exactly like the per-image executors.
#[allow(clippy::too_many_arguments)]
pub fn pass_rows_fused_into<P: MorphPixel>(
    pool: &BandPool,
    srcs: &[ImageView<'_, P>],
    dsts: Vec<ImageViewMut<'_, P>>,
    window: usize,
    op: MorphOp,
    method: PassMethod,
    simd: bool,
    thresholds: HybridThresholds,
    bands: usize,
    align: usize,
    scratch: &mut Vec<Vec<P>>,
) {
    let wing = window / 2;
    fused_pass_into(
        pool,
        srcs,
        dsts,
        window,
        wing,
        bands,
        align,
        scratch,
        move |sv, chunk, skip, slot| {
            separable::pass_rows_into(
                &mut Native,
                sv,
                chunk,
                skip,
                window,
                op,
                method,
                simd,
                thresholds,
                slot,
            );
        },
    );
}

/// Direct (non-sandwich) cols-window pass over a fused batch — zero
/// halo, segments never read outside their own image by construction.
/// Callers must have excluded the §5.2.1 sandwich case
/// ([`separable::takes_sandwich`]); the fused sandwich is banded over
/// the transposed stack instead (see [`super::plan::FusedPlan`]).
#[allow(clippy::too_many_arguments)]
pub fn pass_cols_direct_fused_into<P: MorphPixel>(
    pool: &BandPool,
    srcs: &[ImageView<'_, P>],
    dsts: Vec<ImageViewMut<'_, P>>,
    window: usize,
    op: MorphOp,
    method: PassMethod,
    simd: bool,
    vertical: VerticalStrategy,
    thresholds: HybridThresholds,
    bands: usize,
    scratch: &mut Vec<Vec<P>>,
) {
    let m = resolve_method(method, window, thresholds.wx0);
    debug_assert!(
        !separable::takes_sandwich(m, simd, vertical),
        "sandwich configurations are fused over the transposed stack"
    );
    fused_pass_into(
        pool,
        srcs,
        dsts,
        window,
        0,
        bands,
        1,
        scratch,
        move |sv, chunk, _skip, slot| {
            separable::pass_cols_direct_into(
                &mut Native,
                sv,
                chunk,
                window,
                op,
                m,
                simd,
                vertical,
                thresholds,
                slot,
            );
        },
    );
}

/// Full separable 2-D morphology with both passes band-sharded into
/// `bands` bands.  Bit-identical to [`separable::morphology`].
pub fn morphology_banded<'a, P: MorphPixel>(
    pool: &BandPool,
    src: impl Into<ImageView<'a, P>>,
    op: MorphOp,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
    bands: usize,
) -> Image<P> {
    let src = src.into();
    let wing_x = super::wing_of(w_x, "w_x");
    let wing_y = super::wing_of(w_y, "w_y");
    if src.height() == 0 || src.width() == 0 {
        return src.to_image();
    }
    if cfg.border == super::Border::Replicate {
        let padded = super::replicate_pad(src, wing_x, wing_y);
        let mut inner = *cfg;
        inner.border = super::Border::Identity;
        let out = morphology_banded(pool, &padded, op, w_x, w_y, &inner, bands);
        return super::crop(out.view(), wing_y, wing_x, src.height(), src.width());
    }
    let after_rows = if w_y > 1 {
        pass_rows_banded(
            pool,
            src,
            w_y,
            op,
            cfg.method,
            cfg.simd,
            cfg.thresholds,
            bands,
        )
    } else {
        src.to_image()
    };
    if w_x > 1 {
        pass_cols_banded(
            pool,
            &after_rows,
            w_x,
            op,
            cfg.method,
            cfg.simd,
            cfg.vertical,
            cfg.thresholds,
            bands,
        )
    } else {
        after_rows
    }
}

// ---------------------------------------------------------------------------
// dispatch: the cost-model crossover
// ---------------------------------------------------------------------------

/// Band count a native execution of this shape should use, per
/// [`MorphConfig::parallelism`].  `Auto` prices the pass with the cost
/// model and picks the band count whose modeled parallel price beats
/// sequential by ≥10% (1 = stay sequential).
pub fn effective_bands<P: MorphPixel>(
    h: usize,
    w: usize,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> usize {
    match cfg.parallelism {
        Parallelism::Sequential => 1,
        Parallelism::Fixed(n) => n.max(1),
        Parallelism::Auto => {
            let pool = BandPool::global().size();
            if pool <= 1 {
                return 1;
            }
            let model = CostModel::exynos5422();
            let (compute_ns, memory_ns) = model.estimate_separable_cost(
                h,
                w,
                w_x,
                w_y,
                P::LANES,
                std::mem::size_of::<P>(),
                cfg.simd,
                cfg.method,
                cfg.vertical,
                &cfg.thresholds,
            );
            model.plan_workers(compute_ns, memory_ns, pool)
        }
    }
}

/// Band count a **standalone** transpose of this shape should use, per
/// [`MorphConfig::parallelism`].  `Auto` prices the §4 tile network
/// with [`CostModel::plan_transpose_workers`] — the transpose is
/// memory-heavy (its stream term does not scale with bands), so
/// paper-sized images are demoted to sequential; sandwich transposes
/// instead ride their plan's band count, where the fork is already
/// paid.
pub fn effective_transpose_bands<P: MorphPixel>(h: usize, w: usize, cfg: &MorphConfig) -> usize {
    match cfg.parallelism {
        Parallelism::Sequential => 1,
        Parallelism::Fixed(n) => n.max(1),
        Parallelism::Auto => {
            let pool = BandPool::global().size();
            if pool <= 1 {
                return 1;
            }
            let model = CostModel::exynos5422();
            model.plan_transpose_workers(h, w, P::LANES, std::mem::size_of::<P>(), pool)
        }
    }
}

/// Native-speed separable morphology with automatic band-sharding —
/// the crate's production entry point ([`super::erode`]/[`super::dilate`]
/// and the coordinator's `NativeEngine` route through here).  Since the
/// plan–execute redesign this is a thin wrapper over a **one-shot
/// [`FilterSpec`] plan** (resolve → run → drop); callers that filter
/// more than once should build the spec themselves and reuse the
/// [`super::plan::FilterPlan`].  Output is bit-identical to
/// `separable::morphology(&mut Native, ..)` for every configuration.
pub fn filter_native<'a, P: MorphPixel>(
    src: impl Into<ImageView<'a, P>>,
    op: MorphOp,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    let src = src.into();
    let fop = match op {
        MorphOp::Erode => FilterOp::Erode,
        MorphOp::Dilate => FilterOp::Dilate,
    };
    FilterSpec::new(fop, w_x, w_y)
        .with_config(*cfg)
        .run_once(src)
        .unwrap_or_else(|e| panic!("{e}"))
}

/// Region-of-interest filtering: exactly the pixels
/// `crop(filter_native(full), roi)` would produce, computed from a
/// borrowed haloed sub-view — work is bounded by the haloed block, i.e.
/// only `(roi.height + w_y - 1) × (roi.width + w_x - 1)` source pixels
/// are ever read or filtered (the wing-wide ring of block outputs
/// around the ROI is computed and cropped away), never the full image.
///
/// Correctness is the band-halo argument lifted to 2-D: every ROI
/// output's window extends at most `wing` past the ROI, i.e. stays
/// inside the haloed block wherever the block edge is interior; and
/// wherever the halo was clamped, the block edge *coincides with the
/// image edge*, so the kernel's border handling (identity padding, or
/// replicate pre-padding of the block) reproduces the full-image
/// behaviour exactly.  Holds for every ROI position, both borders and
/// both pixel depths (`rust/tests/roi_views.rs`).
pub fn filter_roi<'a, P: MorphPixel>(
    src: impl Into<ImageView<'a, P>>,
    op: MorphOp,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
    roi: Roi,
) -> Image<P> {
    let src = src.into();
    let fop = match op {
        MorphOp::Erode => FilterOp::Erode,
        MorphOp::Dilate => FilterOp::Dilate,
    };
    FilterSpec::new(fop, w_x, w_y)
        .with_config(*cfg)
        .with_roi(roi)
        .run_once(src)
        .unwrap_or_else(|e| panic!("{e}"))
}

// -- parallel-aware derived operations: one-shot plans of the derived
//    ops (matching `super::derived` bit for bit) ---------------------------

fn derived_native<'a, P: MorphPixel>(
    src: impl Into<ImageView<'a, P>>,
    op: FilterOp,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    FilterSpec::new(op, w_x, w_y)
        .with_config(*cfg)
        .run_once(src.into())
        .unwrap_or_else(|e| panic!("{e}"))
}

/// Banded opening: dilation of the erosion.
pub fn opening_native<'a, P: MorphPixel>(
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    derived_native(src, FilterOp::Open, w_x, w_y, cfg)
}

/// Banded closing: erosion of the dilation.
pub fn closing_native<'a, P: MorphPixel>(
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    derived_native(src, FilterOp::Close, w_x, w_y, cfg)
}

/// Banded morphological gradient: dilation − erosion.
pub fn gradient_native<'a, P: MorphPixel>(
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    derived_native(src, FilterOp::Gradient, w_x, w_y, cfg)
}

/// Banded white top-hat: src − opening.
pub fn tophat_native<'a, P: MorphPixel>(
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    derived_native(src, FilterOp::TopHat, w_x, w_y, cfg)
}

/// Banded black top-hat: closing − src.
pub fn blackhat_native<'a, P: MorphPixel>(
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    derived_native(src, FilterOp::BlackHat, w_x, w_y, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::morphology::{Border, Representation};
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn split_bands_cover_and_are_disjoint() {
        for &(len, parts) in &[(10, 3), (1, 4), (7, 7), (7, 20), (600, 8), (16, 1)] {
            let plan = split_bands(len, parts);
            assert!(plan.len() <= parts.max(1));
            assert_eq!(plan.first().unwrap().start, 0);
            assert_eq!(plan.last().unwrap().end, len);
            for w in plan.windows(2) {
                assert_eq!(w[0].end, w[1].start, "bands must tile contiguously");
            }
            for b in &plan {
                assert!(!b.is_empty());
            }
        }
        assert!(split_bands(0, 4).is_empty());
    }

    #[test]
    fn aligned_bands_respect_alignment() {
        let plan = split_bands_aligned(100, 3, 16);
        assert_eq!(plan.last().unwrap().end, 100);
        for b in &plan[..plan.len() - 1] {
            assert_eq!(b.end % 16, 0, "interior boundary must be tile-aligned");
        }
        // alignment larger than the split collapses to fewer bands
        let tiny = split_bands_aligned(10, 4, 16);
        assert_eq!(tiny.len(), 1);
        assert_eq!(tiny[0], 0..10);
    }

    #[test]
    fn fused_bands_cover_tile_and_fence_seams() {
        for &(n, h, parts, align) in &[
            (1usize, 10usize, 3usize, 1usize),
            (4, 10, 3, 1),
            (4, 1, 3, 1), // degenerate 1-row images
            (8, 7, 5, 16),
            (3, 33, 4, 16),
            (64, 5, 8, 1),
            (2, 100, 1, 1),
        ] {
            let plan = split_fused_bands(n, h, parts, align);
            assert!(plan.len() <= parts.max(1));
            // fused coverage: concatenated segments tile [0, n*h)
            let mut pos = 0usize;
            for band in &plan {
                assert!(!band.is_empty());
                for (img, rows) in band {
                    assert!(!rows.is_empty());
                    assert!(rows.end <= h);
                    assert_eq!(img * h + rows.start, pos, "segments must tile the fused extent");
                    pos = img * h + rows.end;
                }
            }
            assert_eq!(pos, n * h);
            // seam fence: no segment crosses an image boundary, and
            // interior cuts are align-multiples within their image
            let mut per_img: Vec<Vec<Range<usize>>> = vec![Vec::new(); n];
            for band in &plan {
                for (img, rows) in band {
                    per_img[*img].push(rows.clone());
                }
            }
            for rows in &per_img {
                assert_eq!(rows.first().unwrap().start, 0);
                assert_eq!(rows.last().unwrap().end, h);
                for pair in rows.windows(2) {
                    assert_eq!(pair[0].end, pair[1].start);
                    assert_eq!(pair[0].end % align, 0, "interior cut must be image-locally aligned");
                }
            }
        }
        assert!(split_fused_bands(0, 10, 4, 1).is_empty());
        assert!(split_fused_bands(4, 0, 4, 1).is_empty());
    }

    #[test]
    fn fused_rows_pass_matches_per_image_bitwise() {
        let pool = BandPool::new(4);
        let th = HybridThresholds::paper();
        let imgs: Vec<Image<u8>> = (0..5).map(|i| synth::noise(13, 21, 0xF00D + i)).collect();
        for &window in &[3, 9] {
            for &bands in &[1, 3, 7] {
                let want: Vec<Image<u8>> = imgs
                    .iter()
                    .map(|im| {
                        separable::pass_rows(
                            &mut Native,
                            im,
                            window,
                            MorphOp::Erode,
                            PassMethod::Linear,
                            true,
                            th,
                        )
                    })
                    .collect();
                let mut out: Vec<Image<u8>> = imgs.iter().map(|_| Image::zeros(13, 21)).collect();
                let srcs: Vec<ImageView<'_, u8>> = imgs.iter().map(|im| im.view()).collect();
                let dsts: Vec<ImageViewMut<'_, u8>> =
                    out.iter_mut().map(|im| im.view_mut()).collect();
                pass_rows_fused_into(
                    &pool,
                    &srcs,
                    dsts,
                    window,
                    MorphOp::Erode,
                    PassMethod::Linear,
                    true,
                    th,
                    bands,
                    1,
                    &mut Vec::new(),
                );
                for (got, want) in out.iter().zip(&want) {
                    assert!(
                        got.same_pixels(want),
                        "w={window} bands={bands}: {:?}",
                        got.first_diff(want)
                    );
                }
            }
        }
    }

    #[test]
    fn fused_cols_pass_matches_per_image_bitwise() {
        let pool = BandPool::new(3);
        let th = HybridThresholds::paper();
        let imgs: Vec<Image<u8>> = (0..4).map(|i| synth::noise(9, 30, 0xCAFE + i)).collect();
        let want: Vec<Image<u8>> = imgs
            .iter()
            .map(|im| {
                separable::pass_cols(
                    &mut Native,
                    im,
                    7,
                    MorphOp::Dilate,
                    PassMethod::Linear,
                    true,
                    VerticalStrategy::Direct,
                    th,
                )
            })
            .collect();
        let mut out: Vec<Image<u8>> = imgs.iter().map(|_| Image::zeros(9, 30)).collect();
        let srcs: Vec<ImageView<'_, u8>> = imgs.iter().map(|im| im.view()).collect();
        let dsts: Vec<ImageViewMut<'_, u8>> = out.iter_mut().map(|im| im.view_mut()).collect();
        pass_cols_direct_fused_into(
            &pool,
            &srcs,
            dsts,
            7,
            MorphOp::Dilate,
            PassMethod::Linear,
            true,
            VerticalStrategy::Direct,
            th,
            5,
            &mut Vec::new(),
        );
        for (got, want) in out.iter().zip(&want) {
            assert!(got.same_pixels(want), "{:?}", got.first_diff(want));
        }
    }

    #[test]
    fn halo_clamps_at_edges() {
        assert_eq!(halo(&(0..10), 3, 100), 0..13);
        assert_eq!(halo(&(50..60), 3, 100), 47..63);
        assert_eq!(halo(&(90..100), 3, 100), 87..100);
        assert_eq!(halo(&(0..5), 7, 5), 0..5);
    }

    #[test]
    fn scope_runs_every_job() {
        let pool = BandPool::new(3);
        let counter = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..17)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 17);
    }

    #[test]
    fn scope_jobs_may_borrow_and_mutate_disjoint_slices() {
        let pool = BandPool::new(2);
        let mut data = vec![0u32; 64];
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (i, chunk) in data.chunks_mut(16).enumerate() {
                jobs.push(Box::new(move || {
                    for v in chunk.iter_mut() {
                        *v = i as u32 + 1;
                    }
                }));
            }
            pool.scope(jobs);
        }
        assert_eq!(data[0], 1);
        assert_eq!(data[63], 4);
    }

    #[test]
    #[should_panic(expected = "band job panicked")]
    fn scope_propagates_worker_panics() {
        let pool = BandPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| {}),
            Box::new(|| panic!("boom")),
            Box::new(|| {}),
        ];
        pool.scope(jobs);
    }

    #[test]
    fn banded_rows_match_sequential_bitwise() {
        let pool = BandPool::new(4);
        let img = synth::noise(37, 41, 0xBAD5EED);
        let th = HybridThresholds::paper();
        for &window in &[3, 9, 15] {
            for &bands in &[1, 2, 3, 7, 37, 50] {
                for op in [MorphOp::Erode, MorphOp::Dilate] {
                    let want = separable::pass_rows(
                        &mut Native,
                        &img,
                        window,
                        op,
                        PassMethod::Linear,
                        true,
                        th,
                    );
                    let got = pass_rows_banded(
                        &pool,
                        &img,
                        window,
                        op,
                        PassMethod::Linear,
                        true,
                        th,
                        bands,
                    );
                    assert!(
                        got.same_pixels(&want),
                        "rows w={window} bands={bands} {op:?}: {:?}",
                        got.first_diff(&want)
                    );
                }
            }
        }
    }

    #[test]
    fn banded_passes_accept_strided_views() {
        // the zero-copy path must honour non-compact source strides
        let pool = BandPool::new(3);
        let img = synth::noise(33, 40, 0x57E1D);
        let padded = img.with_stride(64, 0xAB);
        let th = HybridThresholds::paper();
        for method in [PassMethod::Linear, PassMethod::Vhgw] {
            let want = separable::pass_rows(&mut Native, &img, 9, MorphOp::Erode, method, true, th);
            let got = pass_rows_banded(&pool, &padded, 9, MorphOp::Erode, method, true, th, 4);
            assert!(got.same_pixels(&want), "{method:?}: {:?}", got.first_diff(&want));
        }
    }

    #[test]
    fn banded_morphology_matches_sequential_bitwise() {
        let pool = BandPool::new(3);
        let img = synth::noise(29, 33, 7);
        for method in [PassMethod::Linear, PassMethod::Vhgw, PassMethod::Hybrid] {
            for vertical in [VerticalStrategy::Direct, VerticalStrategy::Transpose] {
                let cfg = MorphConfig {
                    method,
                    vertical,
                    simd: true,
                    border: Border::Identity,
                    thresholds: HybridThresholds::paper(),
                    parallelism: Parallelism::Sequential,
                    representation: Representation::Dense,
                };
                let want = separable::morphology(&mut Native, &img, MorphOp::Erode, 5, 7, &cfg);
                let got = morphology_banded(&pool, &img, MorphOp::Erode, 5, 7, &cfg, 4);
                assert!(
                    got.same_pixels(&want),
                    "{method:?}/{vertical:?}: {:?}",
                    got.first_diff(&want)
                );
            }
        }
    }

    #[test]
    fn fixed_parallelism_routes_through_bands() {
        let img = synth::noise(40, 48, 3);
        let cfg = MorphConfig {
            parallelism: Parallelism::Fixed(3),
            ..MorphConfig::default()
        };
        let got = filter_native(&img, MorphOp::Erode, 5, 5, &cfg);
        let seq = MorphConfig {
            parallelism: Parallelism::Sequential,
            ..cfg
        };
        let want = filter_native(&img, MorphOp::Erode, 5, 5, &seq);
        assert!(got.same_pixels(&want));
    }

    #[test]
    fn auto_stays_sequential_on_tiny_images() {
        let cfg = MorphConfig::default();
        assert_eq!(effective_bands::<u8>(16, 16, 3, 3, &cfg), 1);
    }

    #[test]
    fn filter_roi_equals_cropped_filter_all_positions() {
        // corner, edge-touching and interior ROIs; banded and sequential
        let img = synth::noise(36, 44, 0x201);
        for parallelism in [Parallelism::Sequential, Parallelism::Fixed(3)] {
            let cfg = MorphConfig {
                parallelism,
                ..MorphConfig::default()
            };
            let full = filter_native(&img, MorphOp::Erode, 5, 7, &cfg);
            for roi in [
                Roi::new(0, 0, 10, 12),
                Roi::new(0, 30, 8, 14),
                Roi::new(26, 0, 10, 9),
                Roi::new(9, 11, 15, 20),
                Roi::full(36, 44),
            ] {
                let want = full
                    .view()
                    .sub_rect(roi.y, roi.x, roi.height, roi.width)
                    .to_image();
                let got = filter_roi(&img, MorphOp::Erode, 5, 7, &cfg, roi);
                assert!(
                    got.same_pixels(&want),
                    "{parallelism:?} {roi:?}: {:?}",
                    got.first_diff(&want)
                );
            }
        }
    }

    #[test]
    fn filter_roi_empty_and_oversized() {
        let img = synth::noise(10, 10, 1);
        let cfg = MorphConfig::default();
        let empty = filter_roi(&img, MorphOp::Erode, 3, 3, &cfg, Roi::new(2, 2, 0, 5));
        assert_eq!(empty.pixels(), 0);
        let r = std::panic::catch_unwind(|| {
            filter_roi(&img, MorphOp::Erode, 3, 3, &cfg, Roi::new(5, 5, 8, 8))
        });
        assert!(r.is_err(), "out-of-bounds ROI must panic");
    }

    #[test]
    fn derived_native_match_sequential_derived() {
        let img = synth::noise(26, 31, 21);
        let cfg = MorphConfig {
            parallelism: Parallelism::Fixed(3),
            ..MorphConfig::default()
        };
        let seq = MorphConfig {
            parallelism: Parallelism::Sequential,
            ..cfg
        };
        let b = &mut Native;
        assert!(opening_native(&img, 5, 3, &cfg)
            .same_pixels(&super::super::opening(b, &img, 5, 3, &seq)));
        assert!(closing_native(&img, 3, 5, &cfg)
            .same_pixels(&super::super::closing(b, &img, 3, 5, &seq)));
        assert!(gradient_native(&img, 3, 3, &cfg)
            .same_pixels(&super::super::gradient(b, &img, 3, 3, &seq)));
        assert!(tophat_native(&img, 5, 5, &cfg)
            .same_pixels(&super::super::tophat(b, &img, 5, 5, &seq)));
        assert!(blackhat_native(&img, 5, 5, &cfg)
            .same_pixels(&super::super::blackhat(b, &img, 5, 5, &seq)));
    }
}
