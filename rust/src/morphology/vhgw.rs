//! van Herk / Gil-Werman 1-D passes (§5.1.1): O(1) combines per pixel
//! independent of window size, at the price of extra memory ("doubled
//! image size", §5.1.1) and extra streaming traffic.
//!
//! Decomposition: over the identity-padded axis split into segments of
//! length `w`, with `R` the per-segment prefix reduction and `S` the
//! per-segment suffix reduction,
//!
//! ```text
//! out[i] = comb(S[i], R[i + w - 1])        (window = [i, i + w))
//! ```
//!
//! Our implementation materializes `R` (one padded image) and fuses the
//! `S` scan with the merge, carrying the running suffix in a single row
//! buffer — 3 combines per point, the classic vHGW census.  The `R`
//! buffer is the algorithm's inherent "doubled image size" cost, not a
//! staging copy — the `_into` forms write their output straight into a
//! caller-provided [`ImageViewMut`] and take `R` as **caller-provided
//! scratch** (`&mut Vec<P>`, grown on first use and reused verbatim
//! after), so a caller that holds the scratch — a
//! [`super::plan::FilterPlan`] arena, a band job's per-band slot —
//! allocates nothing on reuse.  The owned wrappers allocate a fresh
//! scratch per call, preserving the historical behaviour.
//!
//! The rows-window pass vectorizes trivially ([`MorphPixel::LANES`]
//! columns per `vminq`, all aligned); the cols-window scalar pass is the
//! paper's "vertical without SIMD" comparator (its SIMD counterpart is
//! the §5.2.1 transpose sandwich in [`super::separable`]).  All passes
//! are generic over the pixel depth and read borrowed [`ImageView`]s.

use super::{wing_of, MorphOp, MorphPixel};
use crate::image::{Image, ImageView, ImageViewMut};
use crate::neon::Backend;

/// Segment count covering `n + 2*wing` samples with segment length `w`.
#[inline]
pub(crate) fn seg_count(n: usize, window: usize) -> usize {
    let wing = window / 2;
    (n + 2 * wing).div_ceil(window)
}

/// Grow `scratch` to at least `n` elements and return the prefix.  Every
/// element is fully overwritten before it is read, so stale contents
/// from a previous (smaller or different-op) use are harmless; once the
/// scratch has reached its high-water mark, reuse allocates nothing.
#[inline]
fn scratch_slice<P: MorphPixel>(scratch: &mut Vec<P>, n: usize) -> &mut [P] {
    if scratch.len() < n {
        scratch.resize(n, P::default());
    }
    &mut scratch[..n]
}

/// Padded virtual source row of the rows-window scans:
/// `P(i) = src[i - wing]`, `ident_row` outside the image.
#[inline]
fn padded_row<'a, P: MorphPixel>(
    src: ImageView<'a, P>,
    ident_row: &'a [P],
    wing: usize,
    h: usize,
    i: usize,
) -> &'a [P] {
    if (wing..wing + h).contains(&i) {
        src.row(i - wing)
    } else {
        ident_row
    }
}

/// Rows-window vHGW pass, NEON (the §5.1.1 baseline *with* SIMD).
pub fn rows_simd_vhgw<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    let src = src.into();
    let _ = wing_of(window, "w_y");
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.to_image();
    }
    let mut dst = Image::zeros(h, w);
    rows_simd_vhgw_into(b, src, dst.view_mut(), 0, window, op, &mut Vec::new());
    dst
}

/// [`rows_simd_vhgw`] writing output rows `y0 .. y0 + dst.height()` of
/// the `src` filtering directly into `dst` (band jobs pass a haloed
/// `src` view and their disjoint destination band).  `scratch` receives
/// the `R` prefix-reduction buffer (`seg_count(h) × window × w`
/// elements) — pass a retained `Vec` to make reuse allocation-free.
pub fn rows_simd_vhgw_into<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: ImageView<'_, P>,
    mut dst: ImageViewMut<'_, P>,
    y0: usize,
    window: usize,
    op: MorphOp,
    scratch: &mut Vec<P>,
) {
    let wing = wing_of(window, "w_y");
    let (h, w) = (src.height(), src.width());
    let n = dst.height();
    debug_assert_eq!(dst.width(), w);
    debug_assert!(y0 + n <= h);
    if n == 0 || w == 0 {
        return;
    }
    if window == 1 {
        dst.copy_rows_from(src, y0);
        return;
    }
    let nseg = seg_count(h, window);
    let ph = nseg * window; // padded height
    let px = std::mem::size_of::<P>() as u64;
    let wv = w - w % P::LANES;

    // streaming: src read twice (R scan + S scan), R written + read,
    // dst written — the "additional memory = doubled image size" cost
    b.record_stream(
        (2 * h * w + ph * w) as u64 * px,
        (ph * w + n * w) as u64 * px,
    );

    // padded virtual source row: P(i) = src[i - wing], identity outside
    let ident_row = vec![op.identity::<P>(); w];
    let prow = |i: usize| padded_row(src, &ident_row, wing, h, i);

    // R: per-segment prefix reduction, ascending, streaming by rows
    // (arena-owned when the caller retains the scratch)
    let r = scratch_slice(scratch, ph * w);
    for i in 0..ph {
        let p = prow(i);
        if i % window == 0 {
            // segment start: copy
            let (head, tail) = r.split_at_mut(i * w);
            let _ = head;
            let row_i = &mut tail[..w];
            let mut x = 0;
            while x < wv {
                b.scalar_overhead(1);
                let v = P::vload(b, &p[x..]);
                P::vstore(b, &mut row_i[x..], v);
                x += P::LANES;
            }
            for x in wv..w {
                let v = P::load(b, p, x);
                P::store(b, row_i, x, v);
            }
        } else {
            let (prev, cur) = r.split_at_mut(i * w);
            let prev_row = &prev[(i - 1) * w..];
            let cur_row = &mut cur[..w];
            let mut x = 0;
            while x < wv {
                b.scalar_overhead(1);
                let a = P::vload(b, &prev_row[x..]);
                let v = P::vload(b, &p[x..]);
                let m = op.simd::<P, _>(b, a, v);
                P::vstore(b, &mut cur_row[x..], m);
                x += P::LANES;
            }
            for x in wv..w {
                let a = P::load(b, prev_row, x);
                let v = P::load(b, p, x);
                let m = op.scalar(b, a, v);
                P::store(b, cur_row, x, m);
            }
        }
    }

    // S scan fused with merge, descending with a carried row buffer
    let mut s_row = vec![op.identity::<P>(); w];
    for i in (0..ph).rev() {
        let p = prow(i);
        let seg_last = i % window == window - 1;
        let emit = (y0..y0 + n).contains(&i);
        let mut x = 0;
        while x < wv {
            b.scalar_overhead(1);
            let v = P::vload(b, &p[x..]);
            let s = if seg_last {
                v
            } else {
                let prev = P::vload(b, &s_row[x..]);
                op.simd::<P, _>(b, prev, v)
            };
            P::vstore(b, &mut s_row[x..], s);
            if emit {
                // out[i] = comb(S[i], R[i + window - 1])
                let rr = P::vload(b, &r[(i + window - 1) * w + x..]);
                let o = op.simd::<P, _>(b, s, rr);
                P::vstore(b, &mut dst.row_mut(i - y0)[x..], o);
            }
            x += P::LANES;
        }
        for x in wv..w {
            let v = P::load(b, p, x);
            let s = if seg_last {
                v
            } else {
                let prev = P::load(b, &s_row, x);
                op.scalar(b, prev, v)
            };
            P::store(b, &mut s_row, x, s);
            if emit {
                let rr = P::load(b, &r, (i + window - 1) * w + x);
                let o = op.scalar(b, s, rr);
                P::store(b, dst.row_mut(i - y0), x, o);
            }
        }
    }
}

/// Rows-window vHGW pass, scalar (the paper's Fig. 3 "without SIMD"
/// baseline).
pub fn rows_scalar_vhgw<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    let src = src.into();
    let _ = wing_of(window, "w_y");
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.to_image();
    }
    let mut dst = Image::zeros(h, w);
    rows_scalar_vhgw_into(b, src, dst.view_mut(), 0, window, op, &mut Vec::new());
    dst
}

/// [`rows_scalar_vhgw`] writing output rows `y0 .. y0 + dst.height()`
/// directly into `dst`.  `scratch` receives the `R` buffer, as in
/// [`rows_simd_vhgw_into`].
pub fn rows_scalar_vhgw_into<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: ImageView<'_, P>,
    mut dst: ImageViewMut<'_, P>,
    y0: usize,
    window: usize,
    op: MorphOp,
    scratch: &mut Vec<P>,
) {
    let wing = wing_of(window, "w_y");
    let (h, w) = (src.height(), src.width());
    let n = dst.height();
    debug_assert_eq!(dst.width(), w);
    debug_assert!(y0 + n <= h);
    if n == 0 || w == 0 {
        return;
    }
    if window == 1 {
        dst.copy_rows_from(src, y0);
        return;
    }
    let nseg = seg_count(h, window);
    let ph = nseg * window;
    let px = std::mem::size_of::<P>() as u64;
    b.record_stream(
        (2 * h * w + ph * w) as u64 * px,
        (ph * w + n * w) as u64 * px,
    );

    let ident_row = vec![op.identity::<P>(); w];
    let prow = |i: usize| padded_row(src, &ident_row, wing, h, i);

    let r = scratch_slice(scratch, ph * w);
    for i in 0..ph {
        let p = prow(i);
        b.scalar_overhead(1);
        if i % window == 0 {
            for x in 0..w {
                let v = P::load(b, p, x);
                P::store(b, &mut r[i * w..], x, v);
            }
        } else {
            for x in 0..w {
                b.scalar_overhead(1);
                let a = P::load(b, &r, (i - 1) * w + x);
                let v = P::load(b, p, x);
                let m = op.scalar(b, a, v);
                P::store(b, &mut r[i * w..], x, m);
            }
        }
    }

    let mut s_row = vec![op.identity::<P>(); w];
    for i in (0..ph).rev() {
        let p = prow(i);
        let seg_last = i % window == window - 1;
        let emit = (y0..y0 + n).contains(&i);
        b.scalar_overhead(1);
        for x in 0..w {
            b.scalar_overhead(1);
            let v = P::load(b, p, x);
            let s = if seg_last {
                v
            } else {
                let prev = P::load(b, &s_row, x);
                op.scalar(b, prev, v)
            };
            P::store(b, &mut s_row, x, s);
            if emit {
                let rr = P::load(b, &r, (i + window - 1) * w + x);
                let o = op.scalar(b, s, rr);
                P::store(b, dst.row_mut(i - y0), x, o);
            }
        }
    }
}

/// Cols-window vHGW pass, scalar, direct (the paper's Fig. 4 "without
/// SIMD" comparator).  Per-row 1-D problems; the R buffer is one padded
/// row, reused (cache-resident).
pub fn cols_scalar_vhgw<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    let src = src.into();
    let _ = wing_of(window, "w_x");
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.to_image();
    }
    let mut dst = Image::zeros(h, w);
    cols_scalar_vhgw_into(b, src, dst.view_mut(), window, op, &mut Vec::new());
    dst
}

/// [`cols_scalar_vhgw`] writing directly into `dst` (same shape as
/// `src`; rows are independent).  `scratch` receives the one padded-row
/// `R` buffer (reused across rows, cache-resident).
pub fn cols_scalar_vhgw_into<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: ImageView<'_, P>,
    mut dst: ImageViewMut<'_, P>,
    window: usize,
    op: MorphOp,
    scratch: &mut Vec<P>,
) {
    let wing = wing_of(window, "w_x");
    let (h, w) = (src.height(), src.width());
    debug_assert_eq!((dst.height(), dst.width()), (h, w));
    if h == 0 || w == 0 {
        return;
    }
    if window == 1 {
        dst.copy_rows_from(src, 0);
        return;
    }
    let nseg = seg_count(w, window);
    let pw = nseg * window;
    let px = std::mem::size_of::<P>() as u64;
    // src read twice, dst written; R is cache-resident per row
    b.record_stream((2 * h * w) as u64 * px, (h * w) as u64 * px);

    let r = scratch_slice(scratch, pw);
    for y in 0..h {
        let row = src.row(y);
        let pval = |b: &mut B, j: usize| -> P {
            if (wing..wing + w).contains(&j) {
                P::load(b, row, j - wing)
            } else {
                op.identity()
            }
        };
        // R: per-segment prefix, ascending
        for j in 0..pw {
            b.scalar_overhead(1);
            let v = pval(b, j);
            let val = if j % window == 0 {
                v
            } else {
                let a = P::load(b, &r, j - 1);
                op.scalar(b, a, v)
            };
            P::store(b, &mut r, j, val);
        }
        // S fused with merge, descending with a scalar carry
        let mut s: P = op.identity();
        for j in (0..pw).rev() {
            b.scalar_overhead(1);
            let v = pval(b, j);
            s = if j % window == window - 1 {
                v
            } else {
                op.scalar(b, s, v)
            };
            if j < w {
                let rr = P::load(b, &r, j + window - 1);
                let o = op.scalar(b, s, rr);
                P::store(b, dst.row_mut(y), j, o);
            }
        }
    }
}

/// Expose the per-chunk combine census for documentation/tests: vHGW
/// performs 3 combines per point regardless of window size.
pub fn combines_per_point() -> u64 {
    3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::image::Image;
    use crate::morphology::naive;
    use crate::neon::{Counting, InstrClass, Native};

    fn check_rows(h: usize, w: usize, window: usize, op: MorphOp, seed: u64) {
        let img = synth::noise(h, w, seed);
        let want = naive::rows_naive(&mut Native, &img, window, op);
        let simd = rows_simd_vhgw(&mut Native, &img, window, op);
        let scal = rows_scalar_vhgw(&mut Native, &img, window, op);
        assert!(
            simd.same_pixels(&want),
            "vhgw rows simd {h}x{w} w={window} {op:?}: {:?}",
            simd.first_diff(&want)
        );
        assert!(
            scal.same_pixels(&want),
            "vhgw rows scalar {h}x{w} w={window} {op:?}: {:?}",
            scal.first_diff(&want)
        );
    }

    #[test]
    fn rows_matches_naive_across_windows() {
        for &window in &[1, 3, 5, 7, 15, 31, 61] {
            check_rows(29, 37, window, MorphOp::Erode, 1);
            check_rows(29, 37, window, MorphOp::Dilate, 2);
        }
    }

    #[test]
    fn cols_matches_naive_across_windows() {
        for &window in &[1, 3, 5, 7, 15, 31, 61] {
            for &op in &[MorphOp::Erode, MorphOp::Dilate] {
                let img = synth::noise(21, 43, window as u64);
                let want = naive::cols_naive(&mut Native, &img, window, op);
                let got = cols_scalar_vhgw(&mut Native, &img, window, op);
                assert!(
                    got.same_pixels(&want),
                    "vhgw cols w={window} {op:?}: {:?}",
                    got.first_diff(&want)
                );
            }
        }
    }

    #[test]
    fn u16_vhgw_matches_naive() {
        for &window in &[3, 7, 15] {
            for op in [MorphOp::Erode, MorphOp::Dilate] {
                let img = synth::noise_u16(19, 23, window as u64 + 7);
                let want_r = naive::rows_naive(&mut Native, &img, window, op);
                assert!(rows_simd_vhgw(&mut Native, &img, window, op).same_pixels(&want_r));
                assert!(rows_scalar_vhgw(&mut Native, &img, window, op).same_pixels(&want_r));
                let want_c = naive::cols_naive(&mut Native, &img, window, op);
                assert!(cols_scalar_vhgw(&mut Native, &img, window, op).same_pixels(&want_c));
            }
        }
    }

    #[test]
    fn window_spanning_whole_image() {
        check_rows(5, 24, 15, MorphOp::Erode, 3);
        let img = synth::noise(24, 5, 4);
        let want = naive::cols_naive(&mut Native, &img, 15, MorphOp::Dilate);
        let got = cols_scalar_vhgw(&mut Native, &img, 15, MorphOp::Dilate);
        assert!(got.same_pixels(&want));
    }

    #[test]
    fn segment_boundary_sizes() {
        // heights that are exact multiples / off-by-one of the segment
        for &h in &[14, 15, 16, 29, 30, 31] {
            check_rows(h, 20, 5, MorphOp::Erode, h as u64);
        }
    }

    #[test]
    fn into_variant_emits_requested_rows_only() {
        // the banding contract: a haloed view + row offset reproduces
        // exactly the full pass's core rows
        let img = synth::noise(26, 19, 9);
        for window in [5usize, 9] {
            let wing = window / 2;
            let full = rows_simd_vhgw(&mut Native, &img, window, MorphOp::Erode);
            let band = 10..17usize;
            let lo = band.start - wing;
            let hi = (band.end + wing).min(26);
            let sub = img.view().sub_rows(lo..hi);
            let mut out = Image::zeros(band.len(), 19);
            rows_simd_vhgw_into(
                &mut Native,
                sub,
                out.view_mut(),
                band.start - lo,
                window,
                MorphOp::Erode,
                &mut Vec::new(),
            );
            for (i, y) in band.clone().enumerate() {
                assert_eq!(out.row(i), full.row(y), "w={window} row {y}");
            }
        }
    }

    #[test]
    fn scratch_reuse_across_ops_and_shapes_is_stale_safe() {
        // one retained scratch Vec across different shapes, windows and
        // ops — stale R contents must never leak into outputs
        let mut scratch = Vec::new();
        let mut col_scratch = Vec::new();
        for &(h, w, window, op) in &[
            (26usize, 19usize, 9usize, MorphOp::Erode),
            (14, 31, 5, MorphOp::Dilate),
            (26, 19, 9, MorphOp::Dilate),
            (7, 7, 3, MorphOp::Erode),
        ] {
            let img = synth::noise(h, w, (h * 131 + w) as u64);
            let want = naive::rows_naive(&mut Native, &img, window, op);
            let mut out = Image::zeros(h, w);
            rows_simd_vhgw_into(
                &mut Native,
                img.view(),
                out.view_mut(),
                0,
                window,
                op,
                &mut scratch,
            );
            assert!(out.same_pixels(&want), "rows {h}x{w} w={window} {op:?}");
            let want_c = naive::cols_naive(&mut Native, &img, window, op);
            let mut out_c = Image::zeros(h, w);
            cols_scalar_vhgw_into(
                &mut Native,
                img.view(),
                out_c.view_mut(),
                window,
                op,
                &mut col_scratch,
            );
            assert!(out_c.same_pixels(&want_c), "cols {h}x{w} w={window} {op:?}");
        }
        // the scratch grew to its high-water mark and was reused
        assert!(scratch.len() >= 26 * 19);
    }

    #[test]
    fn simd_combine_count_is_window_independent() {
        // the defining vHGW property: combines per pixel ~3, flat in w
        // combine-flatness needs h >> w (padding quantization); the probe
        // is tall but narrow to keep debug builds fast
        let img = synth::noise(360, 160, 5);
        let count = |window: usize| {
            let mut c = Counting::new();
            let _ = rows_simd_vhgw(&mut c, &img, window, MorphOp::Erode);
            c.mix.get(InstrClass::SimdMinMax) as f64
        };
        let at5 = count(5);
        let at61 = count(61);
        assert!(
            (at61 / at5) < 1.35,
            "vHGW combines should be ~flat in window: {at5} vs {at61}"
        );
    }

    #[test]
    fn impulse_propagates_exactly_window() {
        let mut img = Image::filled(31, 20, 200u8);
        img.set(15, 10, 7);
        let out = rows_simd_vhgw(&mut Native, &img, 9, MorphOp::Erode);
        for y in 0..31 {
            let want = if (11..=19).contains(&y) { 7 } else { 200 };
            assert_eq!(out.get(y, 10), want, "row {y}");
            assert_eq!(out.get(y, 9), 200); // columns untouched
        }
    }
}
