//! The paper's *linear* 1-D passes (§5.1.2 horizontal, §5.2.2 vertical):
//! O(w) combines per pixel but branch-free and perfectly data-parallel.
//!
//! Horizontal (rows-window) pass: the §5.1.2 listing fills **two
//! adjacent output rows per iteration** — their windows share `w_y - 2`
//! rows, so the shared reduction is computed once (`w_y` combines for 2
//! rows ≈ `w_y/2` per row instead of `w_y - 1`).
//!
//! Vertical (cols-window) pass: the §5.2.2 listing — for each
//! [`MorphPixel::LANES`]-pixel chunk the window reduction is an unrolled
//! chain of *offset* vector loads (`vld1q(src + x - wing + j)`), which
//! are unaligned; this is the memory asymmetry that makes w_x⁰ < w_y⁰
//! (§5.3).
//!
//! Both passes exist in scalar form (the "without SIMD" baselines) and
//! NEON form, all four generic over [`Backend`] *and* over
//! [`MorphPixel`]: the same code processes 16 `u8` lanes or 8 `u16`
//! lanes per vector op.

use super::{wing_of, MorphOp, MorphPixel};
use crate::image::Image;
use crate::neon::Backend;

/// Rows-window pass, NEON, two output rows per iteration (§5.1.2).
pub fn rows_simd_linear<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: &Image<P>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    let wing = wing_of(window, "w_y");
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.clone();
    }
    let px = std::mem::size_of::<P>() as u64;
    let mut dst = Image::zeros(h, w);
    b.record_stream((h * w) as u64 * px, (h * w) as u64 * px);
    let wv = w - w % P::LANES;

    let mut y = 0usize;
    while y < h {
        let pair = y + 1 < h; // last row of odd-height images is alone
        // common rows shared by outputs y and y+1: [y-wing+1, y+wing]
        let c0 = (y + 1).saturating_sub(wing);
        let c1 = (y + wing).min(h - 1);
        // the extreme rows each output owns exclusively
        let top = if y >= wing { Some(y - wing) } else { None };
        let bot = if y + wing + 1 < h { Some(y + wing + 1) } else { None };

        let mut x = 0usize;
        while x < wv {
            b.scalar_overhead(2); // chunk loop + address arithmetic
            let mut val = P::vload(b, &src.row(c0)[x..]);
            for k in c0 + 1..=c1 {
                let v = P::vload(b, &src.row(k)[x..]);
                val = op.simd::<P, _>(b, val, v);
            }
            let out0 = match top {
                Some(t) => {
                    let v = P::vload(b, &src.row(t)[x..]);
                    op.simd::<P, _>(b, val, v)
                }
                None => val,
            };
            P::vstore(b, &mut dst.row_mut(y)[x..], out0);
            if pair {
                let out1 = match bot {
                    Some(t) => {
                        let v = P::vload(b, &src.row(t)[x..]);
                        op.simd::<P, _>(b, val, v)
                    }
                    None => val,
                };
                P::vstore(b, &mut dst.row_mut(y + 1)[x..], out1);
            }
            x += P::LANES;
        }
        // right-edge tail: same structure, scalar ("edges processed
        // separately")
        for x in wv..w {
            b.scalar_overhead(2);
            let mut val = P::load(b, src.row(c0), x);
            for k in c0 + 1..=c1 {
                let v = P::load(b, src.row(k), x);
                val = op.scalar(b, val, v);
            }
            let out0 = match top {
                Some(t) => {
                    let v = P::load(b, src.row(t), x);
                    op.scalar(b, val, v)
                }
                None => val,
            };
            P::store(b, dst.row_mut(y), x, out0);
            if pair {
                let out1 = match bot {
                    Some(t) => {
                        let v = P::load(b, src.row(t), x);
                        op.scalar(b, val, v)
                    }
                    None => val,
                };
                P::store(b, dst.row_mut(y + 1), x, out1);
            }
        }
        y += 2;
    }
    dst
}

/// ABLATION variant: rows-window pass, NEON, one output row at a time —
/// no shared-reduction trick, `w_y - 1` combines per row instead of
/// ~`w_y/2 + 1`.  Exists to quantify the §5.1.2 two-row optimization
/// (see `cargo bench --bench ablations`).
pub fn rows_simd_linear_single<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: &Image<P>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    let wing = wing_of(window, "w_y");
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.clone();
    }
    let px = std::mem::size_of::<P>() as u64;
    let mut dst = Image::zeros(h, w);
    b.record_stream((h * w) as u64 * px, (h * w) as u64 * px);
    let wv = w - w % P::LANES;

    for y in 0..h {
        let y0 = y.saturating_sub(wing);
        let y1 = (y + wing).min(h - 1);
        let mut x = 0usize;
        while x < wv {
            b.scalar_overhead(2);
            let mut val = P::vload(b, &src.row(y0)[x..]);
            for k in y0 + 1..=y1 {
                let v = P::vload(b, &src.row(k)[x..]);
                val = op.simd::<P, _>(b, val, v);
            }
            P::vstore(b, &mut dst.row_mut(y)[x..], val);
            x += P::LANES;
        }
        for x in wv..w {
            b.scalar_overhead(1);
            let mut val = P::load(b, src.row(y0), x);
            for k in y0 + 1..=y1 {
                let v = P::load(b, src.row(k), x);
                val = op.scalar(b, val, v);
            }
            P::store(b, dst.row_mut(y), x, val);
        }
    }
    dst
}

/// Rows-window pass, scalar (the "without SIMD" comparator with the same
/// two-row structure).
pub fn rows_scalar_linear<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: &Image<P>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    let wing = wing_of(window, "w_y");
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.clone();
    }
    let px = std::mem::size_of::<P>() as u64;
    let mut dst = Image::zeros(h, w);
    b.record_stream((h * w) as u64 * px, (h * w) as u64 * px);

    let mut y = 0usize;
    while y < h {
        let pair = y + 1 < h;
        let c0 = (y + 1).saturating_sub(wing);
        let c1 = (y + wing).min(h - 1);
        let top = if y >= wing { Some(y - wing) } else { None };
        let bot = if y + wing + 1 < h { Some(y + wing + 1) } else { None };
        for x in 0..w {
            b.scalar_overhead(1);
            let mut val = P::load(b, src.row(c0), x);
            for k in c0 + 1..=c1 {
                b.scalar_overhead(1);
                let v = P::load(b, src.row(k), x);
                val = op.scalar(b, val, v);
            }
            let out0 = match top {
                Some(t) => {
                    let v = P::load(b, src.row(t), x);
                    op.scalar(b, val, v)
                }
                None => val,
            };
            P::store(b, dst.row_mut(y), x, out0);
            if pair {
                let out1 = match bot {
                    Some(t) => {
                        let v = P::load(b, src.row(t), x);
                        op.scalar(b, val, v)
                    }
                    None => val,
                };
                P::store(b, dst.row_mut(y + 1), x, out1);
            }
        }
        y += 2;
    }
    dst
}

/// Cols-window pass, NEON, direct strategy with offset loads (§5.2.2).
///
/// Each source row is staged once into an identity-padded row buffer
/// (cache-resident, reused across rows) so the unrolled offset loads
/// never leave the buffer; all window loads are unaligned, matching the
/// `vld1q(src + x - wing + j)` pattern of the listing.
pub fn cols_simd_linear<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: &Image<P>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    let wing = wing_of(window, "w_x");
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.clone();
    }
    let px = std::mem::size_of::<P>() as u64;
    let mut dst = Image::zeros(h, w);
    b.record_stream((h * w) as u64 * px, (h * w) as u64 * px);
    let wv = w - w % P::LANES;
    let ident: P = op.identity();
    // padded row buffer: buf[j] = src[y][j - wing], identity outside
    let mut buf = vec![ident; w + 2 * wing + P::LANES];

    for y in 0..h {
        buf[..wing].fill(ident);
        buf[wing..wing + w].copy_from_slice(src.row(y));
        buf[wing + w..].fill(ident);
        b.record_bytes(w as u64 * px, w as u64 * px); // cache-resident staging copy

        let mut x = 0usize;
        while x < wv {
            b.scalar_overhead(2);
            // window for output x covers src columns [x-wing, x+wing]
            // = buf[x .. x+window)
            let mut val = P::vload_unaligned(b, &buf[x..]);
            for j in 1..window {
                let v = P::vload_unaligned(b, &buf[x + j..]);
                val = op.simd::<P, _>(b, val, v);
            }
            P::vstore(b, &mut dst.row_mut(y)[x..], val);
            x += P::LANES;
        }
        for x in wv..w {
            b.scalar_overhead(1);
            let mut val = P::load(b, &buf, x);
            for j in 1..window {
                let v = P::load(b, &buf, x + j);
                val = op.scalar(b, val, v);
            }
            P::store(b, dst.row_mut(y), x, val);
        }
    }
    dst
}

/// Cols-window pass, scalar.
pub fn cols_scalar_linear<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: &Image<P>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    let wing = wing_of(window, "w_x");
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.clone();
    }
    let px = std::mem::size_of::<P>() as u64;
    let mut dst = Image::zeros(h, w);
    b.record_stream((h * w) as u64 * px, (h * w) as u64 * px);

    for y in 0..h {
        let row = src.row(y);
        for x in 0..w {
            b.scalar_overhead(1);
            let x0 = x.saturating_sub(wing);
            let x1 = (x + wing).min(w - 1);
            let mut val = P::load(b, row, x0);
            for j in x0 + 1..=x1 {
                b.scalar_overhead(1);
                let v = P::load(b, row, j);
                val = op.scalar(b, val, v);
            }
            P::store(b, dst.row_mut(y), x, val);
        }
    }
    dst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::morphology::naive;
    use crate::neon::{Counting, InstrClass, Native};

    fn check_rows(h: usize, w: usize, window: usize, op: MorphOp, seed: u64) {
        let img = synth::noise(h, w, seed);
        let want = naive::rows_naive(&mut Native, &img, window, op);
        let simd = rows_simd_linear(&mut Native, &img, window, op);
        let scal = rows_scalar_linear(&mut Native, &img, window, op);
        assert!(
            simd.same_pixels(&want),
            "rows simd {h}x{w} w={window} {op:?}: {:?}",
            simd.first_diff(&want)
        );
        assert!(
            scal.same_pixels(&want),
            "rows scalar {h}x{w} w={window} {op:?}: {:?}",
            scal.first_diff(&want)
        );
    }

    fn check_cols(h: usize, w: usize, window: usize, op: MorphOp, seed: u64) {
        let img = synth::noise(h, w, seed);
        let want = naive::cols_naive(&mut Native, &img, window, op);
        let simd = cols_simd_linear(&mut Native, &img, window, op);
        let scal = cols_scalar_linear(&mut Native, &img, window, op);
        assert!(
            simd.same_pixels(&want),
            "cols simd {h}x{w} w={window} {op:?}: {:?}",
            simd.first_diff(&want)
        );
        assert!(
            scal.same_pixels(&want),
            "cols scalar {h}x{w} w={window} {op:?}: {:?}",
            scal.first_diff(&want)
        );
    }

    #[test]
    fn rows_matches_naive_across_windows() {
        for &window in &[1, 3, 5, 9, 15, 31] {
            check_rows(23, 37, window, MorphOp::Erode, 1);
            check_rows(23, 37, window, MorphOp::Dilate, 2);
        }
    }

    #[test]
    fn cols_matches_naive_across_windows() {
        for &window in &[1, 3, 5, 9, 15, 31] {
            check_cols(19, 41, window, MorphOp::Erode, 3);
            check_cols(19, 41, window, MorphOp::Dilate, 4);
        }
    }

    #[test]
    fn window_larger_than_image() {
        check_rows(5, 20, 13, MorphOp::Erode, 5);
        check_cols(20, 5, 13, MorphOp::Dilate, 6);
    }

    #[test]
    fn simd_aligned_widths_and_tails() {
        for &w in &[16, 32, 48, 17, 31, 15, 1] {
            check_rows(8, w, 5, MorphOp::Erode, w as u64);
            check_cols(8, w, 5, MorphOp::Erode, w as u64 + 100);
        }
    }

    #[test]
    fn odd_and_even_heights() {
        // the two-row trick must handle the odd last row
        for &h in &[1, 2, 3, 7, 8] {
            check_rows(h, 20, 3, MorphOp::Erode, h as u64);
        }
    }

    #[test]
    fn u16_rows_and_cols_match_naive() {
        // the same generic code at 16-bit depth (8 lanes/op)
        for &(h, w) in &[(9, 24), (7, 13), (16, 8)] {
            let img = synth::noise_u16(h, w, (h * 100 + w) as u64);
            for &window in &[3, 5, 9] {
                for op in [MorphOp::Erode, MorphOp::Dilate] {
                    let want_r = naive::rows_naive(&mut Native, &img, window, op);
                    let got_r = rows_simd_linear(&mut Native, &img, window, op);
                    assert!(got_r.same_pixels(&want_r), "u16 rows {h}x{w} w={window}");
                    let want_c = naive::cols_naive(&mut Native, &img, window, op);
                    let got_c = cols_simd_linear(&mut Native, &img, window, op);
                    assert!(got_c.same_pixels(&want_c), "u16 cols {h}x{w} w={window}");
                }
            }
        }
    }

    #[test]
    fn cols_pass_loads_are_unaligned_class() {
        let img = synth::noise(4, 32, 11);
        let mut c = Counting::new();
        let _ = cols_simd_linear(&mut c, &img, 5, MorphOp::Erode);
        assert!(c.mix.get(InstrClass::SimdLoadUnaligned) > 0);
        assert_eq!(c.mix.get(InstrClass::SimdLoad), 0);
        // rows pass: all aligned
        let mut c = Counting::new();
        let _ = rows_simd_linear(&mut c, &img, 5, MorphOp::Erode);
        assert!(c.mix.get(InstrClass::SimdLoad) > 0);
        assert_eq!(c.mix.get(InstrClass::SimdLoadUnaligned), 0);
    }

    #[test]
    fn two_row_trick_saves_combines() {
        // per 2 output rows the shared reduction is computed once:
        // combines ≈ w_y per 2 rows (+2 edge combines), vs 2(w_y-1) naive
        let img = synth::noise(64, 64, 12);
        let mut c = Counting::new();
        let _ = rows_simd_linear(&mut c, &img, 15, MorphOp::Erode);
        let per_chunk =
            c.mix.get(InstrClass::SimdMinMax) as f64 / (64.0 / 2.0 * 64.0 / 16.0);
        assert!(
            per_chunk < 16.5,
            "expected ~w_y+1 combines per 2-row chunk, got {per_chunk}"
        );
    }
}
