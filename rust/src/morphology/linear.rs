//! The paper's *linear* 1-D passes (§5.1.2 horizontal, §5.2.2 vertical):
//! O(w) combines per pixel but branch-free and perfectly data-parallel.
//!
//! Horizontal (rows-window) pass: the §5.1.2 listing fills **two
//! adjacent output rows per iteration** — their windows share `w_y - 2`
//! rows, so the shared reduction is computed once (`w_y` combines for 2
//! rows ≈ `w_y/2` per row instead of `w_y - 1`).
//!
//! Vertical (cols-window) pass: the §5.2.2 listing — for each
//! [`MorphPixel::LANES`]-pixel chunk the window reduction is an unrolled
//! chain of *offset* vector loads (`vld1q(src + x - wing + j)`), which
//! are unaligned; this is the memory asymmetry that makes w_x⁰ < w_y⁰
//! (§5.3).
//!
//! Both passes exist in scalar form (the "without SIMD" baselines) and
//! NEON form, all four generic over [`Backend`] *and* over
//! [`MorphPixel`]: the same code processes 16 `u8` lanes or 8 `u16`
//! lanes per vector op.
//!
//! ## View contract
//!
//! Every kernel reads a borrowed [`ImageView`] (a `&Image` coerces at
//! the call site).  Each pass also has an `_into` form writing straight
//! into a caller-provided [`ImageViewMut`] — the zero-copy primitive the
//! band-parallel executor is built on: a rows `_into` kernel computes
//! output rows `y0 .. y0 + dst.height()` of filtering `src` (so a band
//! job hands it a *haloed* source view and its disjoint slice of the
//! destination), and the allocating wrappers are just
//! `_into(src, whole_dst, y0 = 0)`.

use super::{wing_of, MorphOp, MorphPixel};
use crate::image::{Image, ImageView, ImageViewMut};
use crate::neon::Backend;

/// Rows-window pass, NEON, two output rows per iteration (§5.1.2).
pub fn rows_simd_linear<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    let src = src.into();
    let _ = wing_of(window, "w_y");
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.to_image();
    }
    let mut dst = Image::zeros(h, w);
    rows_simd_linear_into(b, src, dst.view_mut(), 0, window, op);
    dst
}

/// [`rows_simd_linear`] writing output rows `y0 .. y0 + dst.height()`
/// of the `src` filtering directly into `dst` (no allocation).
pub fn rows_simd_linear_into<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: ImageView<'_, P>,
    mut dst: ImageViewMut<'_, P>,
    y0: usize,
    window: usize,
    op: MorphOp,
) {
    let wing = wing_of(window, "w_y");
    let (h, w) = (src.height(), src.width());
    let n = dst.height();
    debug_assert_eq!(dst.width(), w, "dst width must match src");
    debug_assert!(y0 + n <= h, "output rows {y0}..{} exceed src height {h}", y0 + n);
    if n == 0 || w == 0 {
        return;
    }
    if window == 1 {
        dst.copy_rows_from(src, y0);
        return;
    }
    let px = std::mem::size_of::<P>() as u64;
    b.record_stream((n * w) as u64 * px, (n * w) as u64 * px);
    let wv = w - w % P::LANES;
    let end = y0 + n;

    let mut y = y0;
    while y < end {
        let pair = y + 1 < end; // last row of odd-count outputs is alone
        // common rows shared by outputs y and y+1: [y-wing+1, y+wing]
        let c0 = (y + 1).saturating_sub(wing);
        let c1 = (y + wing).min(h - 1);
        // the extreme rows each output owns exclusively
        let top = if y >= wing { Some(y - wing) } else { None };
        let bot = if y + wing + 1 < h { Some(y + wing + 1) } else { None };

        let mut x = 0usize;
        while x < wv {
            b.scalar_overhead(2); // chunk loop + address arithmetic
            let mut val = P::vload(b, &src.row(c0)[x..]);
            for k in c0 + 1..=c1 {
                let v = P::vload(b, &src.row(k)[x..]);
                val = op.simd::<P, _>(b, val, v);
            }
            let out0 = match top {
                Some(t) => {
                    let v = P::vload(b, &src.row(t)[x..]);
                    op.simd::<P, _>(b, val, v)
                }
                None => val,
            };
            P::vstore(b, &mut dst.row_mut(y - y0)[x..], out0);
            if pair {
                let out1 = match bot {
                    Some(t) => {
                        let v = P::vload(b, &src.row(t)[x..]);
                        op.simd::<P, _>(b, val, v)
                    }
                    None => val,
                };
                P::vstore(b, &mut dst.row_mut(y + 1 - y0)[x..], out1);
            }
            x += P::LANES;
        }
        // right-edge tail: same structure, scalar ("edges processed
        // separately")
        for x in wv..w {
            b.scalar_overhead(2);
            let mut val = P::load(b, src.row(c0), x);
            for k in c0 + 1..=c1 {
                let v = P::load(b, src.row(k), x);
                val = op.scalar(b, val, v);
            }
            let out0 = match top {
                Some(t) => {
                    let v = P::load(b, src.row(t), x);
                    op.scalar(b, val, v)
                }
                None => val,
            };
            P::store(b, dst.row_mut(y - y0), x, out0);
            if pair {
                let out1 = match bot {
                    Some(t) => {
                        let v = P::load(b, src.row(t), x);
                        op.scalar(b, val, v)
                    }
                    None => val,
                };
                P::store(b, dst.row_mut(y + 1 - y0), x, out1);
            }
        }
        y += 2;
    }
}

/// ABLATION variant: rows-window pass, NEON, one output row at a time —
/// no shared-reduction trick, `w_y - 1` combines per row instead of
/// ~`w_y/2 + 1`.  Exists to quantify the §5.1.2 two-row optimization
/// (see `cargo bench --bench ablations`).
pub fn rows_simd_linear_single<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    let src = src.into();
    let wing = wing_of(window, "w_y");
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.to_image();
    }
    let px = std::mem::size_of::<P>() as u64;
    let mut dst = Image::zeros(h, w);
    b.record_stream((h * w) as u64 * px, (h * w) as u64 * px);
    let wv = w - w % P::LANES;

    for y in 0..h {
        let y0 = y.saturating_sub(wing);
        let y1 = (y + wing).min(h - 1);
        let mut x = 0usize;
        while x < wv {
            b.scalar_overhead(2);
            let mut val = P::vload(b, &src.row(y0)[x..]);
            for k in y0 + 1..=y1 {
                let v = P::vload(b, &src.row(k)[x..]);
                val = op.simd::<P, _>(b, val, v);
            }
            P::vstore(b, &mut dst.row_mut(y)[x..], val);
            x += P::LANES;
        }
        for x in wv..w {
            b.scalar_overhead(1);
            let mut val = P::load(b, src.row(y0), x);
            for k in y0 + 1..=y1 {
                let v = P::load(b, src.row(k), x);
                val = op.scalar(b, val, v);
            }
            P::store(b, dst.row_mut(y), x, val);
        }
    }
    dst
}

/// Rows-window pass, scalar (the "without SIMD" comparator with the same
/// two-row structure).
pub fn rows_scalar_linear<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    let src = src.into();
    let _ = wing_of(window, "w_y");
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.to_image();
    }
    let mut dst = Image::zeros(h, w);
    rows_scalar_linear_into(b, src, dst.view_mut(), 0, window, op);
    dst
}

/// [`rows_scalar_linear`] writing output rows `y0 .. y0 + dst.height()`
/// directly into `dst`.
pub fn rows_scalar_linear_into<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: ImageView<'_, P>,
    mut dst: ImageViewMut<'_, P>,
    y0: usize,
    window: usize,
    op: MorphOp,
) {
    let wing = wing_of(window, "w_y");
    let (h, w) = (src.height(), src.width());
    let n = dst.height();
    debug_assert_eq!(dst.width(), w);
    debug_assert!(y0 + n <= h);
    if n == 0 || w == 0 {
        return;
    }
    if window == 1 {
        dst.copy_rows_from(src, y0);
        return;
    }
    let px = std::mem::size_of::<P>() as u64;
    b.record_stream((n * w) as u64 * px, (n * w) as u64 * px);
    let end = y0 + n;

    let mut y = y0;
    while y < end {
        let pair = y + 1 < end;
        let c0 = (y + 1).saturating_sub(wing);
        let c1 = (y + wing).min(h - 1);
        let top = if y >= wing { Some(y - wing) } else { None };
        let bot = if y + wing + 1 < h { Some(y + wing + 1) } else { None };
        for x in 0..w {
            b.scalar_overhead(1);
            let mut val = P::load(b, src.row(c0), x);
            for k in c0 + 1..=c1 {
                b.scalar_overhead(1);
                let v = P::load(b, src.row(k), x);
                val = op.scalar(b, val, v);
            }
            let out0 = match top {
                Some(t) => {
                    let v = P::load(b, src.row(t), x);
                    op.scalar(b, val, v)
                }
                None => val,
            };
            P::store(b, dst.row_mut(y - y0), x, out0);
            if pair {
                let out1 = match bot {
                    Some(t) => {
                        let v = P::load(b, src.row(t), x);
                        op.scalar(b, val, v)
                    }
                    None => val,
                };
                P::store(b, dst.row_mut(y + 1 - y0), x, out1);
            }
        }
        y += 2;
    }
}

/// Cols-window pass, NEON, direct strategy with offset loads (§5.2.2).
///
/// Each source row is staged once into an identity-padded row buffer
/// (cache-resident, reused across rows) so the unrolled offset loads
/// never leave the buffer; all window loads are unaligned, matching the
/// `vld1q(src + x - wing + j)` pattern of the listing.
pub fn cols_simd_linear<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    let src = src.into();
    let _ = wing_of(window, "w_x");
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.to_image();
    }
    let mut dst = Image::zeros(h, w);
    cols_simd_linear_into(b, src, dst.view_mut(), window, op, &mut Vec::new());
    dst
}

/// [`cols_simd_linear`] writing directly into `dst` (same shape as
/// `src`; rows are independent, so there is no row offset).
///
/// `scratch` holds the identity-padded staging row (grown on first use,
/// reused verbatim after — every cell is rewritten per row, so a
/// retained slot is stale-safe).  Callers that keep the slot alive
/// (plan arenas, band-job slots) make the pass allocation-free on
/// reuse; one-shot callers pass a fresh `Vec`.
pub fn cols_simd_linear_into<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: ImageView<'_, P>,
    mut dst: ImageViewMut<'_, P>,
    window: usize,
    op: MorphOp,
    scratch: &mut Vec<P>,
) {
    let wing = wing_of(window, "w_x");
    let (h, w) = (src.height(), src.width());
    debug_assert_eq!((dst.height(), dst.width()), (h, w));
    if h == 0 || w == 0 {
        return;
    }
    if window == 1 {
        dst.copy_rows_from(src, 0);
        return;
    }
    let px = std::mem::size_of::<P>() as u64;
    b.record_stream((h * w) as u64 * px, (h * w) as u64 * px);
    let wv = w - w % P::LANES;
    let ident: P = op.identity();
    // padded row buffer: buf[j] = src[y][j - wing], identity outside
    let need = w + 2 * wing + P::LANES;
    if scratch.len() < need {
        scratch.resize(need, ident);
    }
    let buf = &mut scratch[..need];

    for y in 0..h {
        buf[..wing].fill(ident);
        buf[wing..wing + w].copy_from_slice(src.row(y));
        buf[wing + w..].fill(ident);
        b.record_bytes(w as u64 * px, w as u64 * px); // cache-resident staging copy

        let mut x = 0usize;
        while x < wv {
            b.scalar_overhead(2);
            // window for output x covers src columns [x-wing, x+wing]
            // = buf[x .. x+window)
            let mut val = P::vload_unaligned(b, &buf[x..]);
            for j in 1..window {
                let v = P::vload_unaligned(b, &buf[x + j..]);
                val = op.simd::<P, _>(b, val, v);
            }
            P::vstore(b, &mut dst.row_mut(y)[x..], val);
            x += P::LANES;
        }
        for x in wv..w {
            b.scalar_overhead(1);
            let mut val = P::load(b, &buf, x);
            for j in 1..window {
                let v = P::load(b, &buf, x + j);
                val = op.scalar(b, val, v);
            }
            P::store(b, dst.row_mut(y), x, val);
        }
    }
}

/// Cols-window pass, scalar.
pub fn cols_scalar_linear<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    let src = src.into();
    let _ = wing_of(window, "w_x");
    let (h, w) = (src.height(), src.width());
    if window == 1 || h == 0 || w == 0 {
        return src.to_image();
    }
    let mut dst = Image::zeros(h, w);
    cols_scalar_linear_into(b, src, dst.view_mut(), window, op);
    dst
}

/// [`cols_scalar_linear`] writing directly into `dst`.
pub fn cols_scalar_linear_into<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: ImageView<'_, P>,
    mut dst: ImageViewMut<'_, P>,
    window: usize,
    op: MorphOp,
) {
    let wing = wing_of(window, "w_x");
    let (h, w) = (src.height(), src.width());
    debug_assert_eq!((dst.height(), dst.width()), (h, w));
    if h == 0 || w == 0 {
        return;
    }
    if window == 1 {
        dst.copy_rows_from(src, 0);
        return;
    }
    let px = std::mem::size_of::<P>() as u64;
    b.record_stream((h * w) as u64 * px, (h * w) as u64 * px);

    for y in 0..h {
        let row = src.row(y);
        for x in 0..w {
            b.scalar_overhead(1);
            let x0 = x.saturating_sub(wing);
            let x1 = (x + wing).min(w - 1);
            let mut val = P::load(b, row, x0);
            for j in x0 + 1..=x1 {
                b.scalar_overhead(1);
                let v = P::load(b, row, j);
                val = op.scalar(b, val, v);
            }
            P::store(b, dst.row_mut(y), x, val);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::morphology::naive;
    use crate::neon::{Counting, InstrClass, Native};

    fn check_rows(h: usize, w: usize, window: usize, op: MorphOp, seed: u64) {
        let img = synth::noise(h, w, seed);
        let want = naive::rows_naive(&mut Native, &img, window, op);
        let simd = rows_simd_linear(&mut Native, &img, window, op);
        let scal = rows_scalar_linear(&mut Native, &img, window, op);
        assert!(
            simd.same_pixels(&want),
            "rows simd {h}x{w} w={window} {op:?}: {:?}",
            simd.first_diff(&want)
        );
        assert!(
            scal.same_pixels(&want),
            "rows scalar {h}x{w} w={window} {op:?}: {:?}",
            scal.first_diff(&want)
        );
    }

    fn check_cols(h: usize, w: usize, window: usize, op: MorphOp, seed: u64) {
        let img = synth::noise(h, w, seed);
        let want = naive::cols_naive(&mut Native, &img, window, op);
        let simd = cols_simd_linear(&mut Native, &img, window, op);
        let scal = cols_scalar_linear(&mut Native, &img, window, op);
        assert!(
            simd.same_pixels(&want),
            "cols simd {h}x{w} w={window} {op:?}: {:?}",
            simd.first_diff(&want)
        );
        assert!(
            scal.same_pixels(&want),
            "cols scalar {h}x{w} w={window} {op:?}: {:?}",
            scal.first_diff(&want)
        );
    }

    #[test]
    fn rows_matches_naive_across_windows() {
        for &window in &[1, 3, 5, 9, 15, 31] {
            check_rows(23, 37, window, MorphOp::Erode, 1);
            check_rows(23, 37, window, MorphOp::Dilate, 2);
        }
    }

    #[test]
    fn cols_matches_naive_across_windows() {
        for &window in &[1, 3, 5, 9, 15, 31] {
            check_cols(19, 41, window, MorphOp::Erode, 3);
            check_cols(19, 41, window, MorphOp::Dilate, 4);
        }
    }

    #[test]
    fn window_larger_than_image() {
        check_rows(5, 20, 13, MorphOp::Erode, 5);
        check_cols(20, 5, 13, MorphOp::Dilate, 6);
    }

    #[test]
    fn simd_aligned_widths_and_tails() {
        for &w in &[16, 32, 48, 17, 31, 15, 1] {
            check_rows(8, w, 5, MorphOp::Erode, w as u64);
            check_cols(8, w, 5, MorphOp::Erode, w as u64 + 100);
        }
    }

    #[test]
    fn odd_and_even_heights() {
        // the two-row trick must handle the odd last row
        for &h in &[1, 2, 3, 7, 8] {
            check_rows(h, 20, 3, MorphOp::Erode, h as u64);
        }
    }

    #[test]
    fn into_variants_band_equals_full_pass_rows() {
        // the zero-copy banding primitive: output rows [y0, y0+n) of a
        // haloed sub-view must equal rows [y0, y0+n) of the full pass
        let img = synth::noise(21, 24, 77);
        for window in [3usize, 7] {
            for op in [MorphOp::Erode, MorphOp::Dilate] {
                let full = rows_simd_linear(&mut Native, &img, window, op);
                let mut out = Image::zeros(6, 24);
                // band rows 8..14, halo window/2 each side
                let wing = window / 2;
                let lo = 8 - wing;
                let sub = img.view().sub_rows(lo..(14 + wing).min(21));
                rows_simd_linear_into(&mut Native, sub, out.view_mut(), 8 - lo, window, op);
                for (i, y) in (8..14).enumerate() {
                    assert_eq!(out.row(i), full.row(y), "w={window} {op:?} row {y}");
                }
                // scalar variant too
                let fulls = rows_scalar_linear(&mut Native, &img, window, op);
                let mut outs = Image::zeros(6, 24);
                rows_scalar_linear_into(&mut Native, sub, outs.view_mut(), 8 - lo, window, op);
                for (i, y) in (8..14).enumerate() {
                    assert_eq!(outs.row(i), fulls.row(y), "scalar w={window} row {y}");
                }
            }
        }
    }

    #[test]
    fn passes_accept_strided_views() {
        // kernels must honour the view's stride (no compact assumption)
        let img = synth::noise(12, 20, 5);
        let padded = img.with_stride(32, 0xCC);
        for window in [3usize, 9] {
            let want = rows_simd_linear(&mut Native, &img, window, MorphOp::Erode);
            let got = rows_simd_linear(&mut Native, &padded, window, MorphOp::Erode);
            assert!(got.same_pixels(&want), "rows via padded view, w={window}");
            let wantc = cols_simd_linear(&mut Native, &img, window, MorphOp::Dilate);
            let gotc = cols_simd_linear(&mut Native, &padded, window, MorphOp::Dilate);
            assert!(gotc.same_pixels(&wantc), "cols via padded view, w={window}");
        }
    }

    #[test]
    fn u16_rows_and_cols_match_naive() {
        // the same generic code at 16-bit depth (8 lanes/op)
        for &(h, w) in &[(9, 24), (7, 13), (16, 8)] {
            let img = synth::noise_u16(h, w, (h * 100 + w) as u64);
            for &window in &[3, 5, 9] {
                for op in [MorphOp::Erode, MorphOp::Dilate] {
                    let want_r = naive::rows_naive(&mut Native, &img, window, op);
                    let got_r = rows_simd_linear(&mut Native, &img, window, op);
                    assert!(got_r.same_pixels(&want_r), "u16 rows {h}x{w} w={window}");
                    let want_c = naive::cols_naive(&mut Native, &img, window, op);
                    let got_c = cols_simd_linear(&mut Native, &img, window, op);
                    assert!(got_c.same_pixels(&want_c), "u16 cols {h}x{w} w={window}");
                }
            }
        }
    }

    #[test]
    fn cols_pass_loads_are_unaligned_class() {
        let img = synth::noise(4, 32, 11);
        let mut c = Counting::new();
        let _ = cols_simd_linear(&mut c, &img, 5, MorphOp::Erode);
        assert!(c.mix.get(InstrClass::SimdLoadUnaligned) > 0);
        assert_eq!(c.mix.get(InstrClass::SimdLoad), 0);
        // rows pass: all aligned
        let mut c = Counting::new();
        let _ = rows_simd_linear(&mut c, &img, 5, MorphOp::Erode);
        assert!(c.mix.get(InstrClass::SimdLoad) > 0);
        assert_eq!(c.mix.get(InstrClass::SimdLoadUnaligned), 0);
    }

    #[test]
    fn two_row_trick_saves_combines() {
        // per 2 output rows the shared reduction is computed once:
        // combines ≈ w_y per 2 rows (+2 edge combines), vs 2(w_y-1) naive
        let img = synth::noise(64, 64, 12);
        let mut c = Counting::new();
        let _ = rows_simd_linear(&mut c, &img, 15, MorphOp::Erode);
        let per_chunk =
            c.mix.get(InstrClass::SimdMinMax) as f64 / (64.0 / 2.0 * 64.0 / 16.0);
        assert!(
            per_chunk < 16.5,
            "expected ~w_y+1 combines per 2-row chunk, got {per_chunk}"
        );
    }
}
