//! Separable composition (§5): a 2-D `w_x × w_y` erosion/dilation as a
//! rows-window pass followed by a cols-window pass, with the §5.2
//! vertical strategies and the §5.3 hybrid dispatch — generic over the
//! pixel depth ([`MorphPixel`]): the same pass code serves `u8` (16
//! SIMD lanes, 16×16.8 transpose tiles) and `u16` (8 lanes, 8×8.16
//! tiles).
//!
//! Every pass reads a borrowed [`ImageView`] (a `&Image` coerces at the
//! call site), and the 1-D passes also exist as `_into` forms writing
//! straight into a caller-provided [`ImageViewMut`] — the zero-copy
//! contract [`super::parallel`] band jobs rely on.

use super::hybrid::resolve_method;
use super::{linear, vhgw, wing_of};
use super::{Border, MorphConfig, MorphOp, MorphPixel, PassMethod, Roi, VerticalStrategy};
use crate::image::{Image, ImageView, ImageViewMut};
use crate::neon::Backend;

/// One rows-window (paper "horizontal") pass with a *resolved* method.
pub fn pass_rows<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    window: usize,
    op: MorphOp,
    method: PassMethod,
    simd: bool,
    thresholds: super::HybridThresholds,
) -> Image<P> {
    let src = src.into();
    let m = resolve_method(method, window, thresholds.wy0);
    match (m, simd) {
        (PassMethod::Linear, true) => linear::rows_simd_linear(b, src, window, op),
        (PassMethod::Linear, false) => linear::rows_scalar_linear(b, src, window, op),
        (PassMethod::Vhgw, true) => vhgw::rows_simd_vhgw(b, src, window, op),
        (PassMethod::Vhgw, false) => vhgw::rows_scalar_vhgw(b, src, window, op),
        (PassMethod::Hybrid, _) => unreachable!("resolve_method returns concrete"),
    }
}

/// [`pass_rows`] writing output rows `y0 .. y0 + dst.height()` of the
/// `src` filtering directly into `dst` — the zero-copy band primitive
/// (band jobs pass a haloed source view and their disjoint destination
/// band; `window == 1` degrades to a row copy).
///
/// `scratch` is the vHGW `R`-buffer slot (grown on first use, reused
/// verbatim after — see [`vhgw::rows_simd_vhgw_into`]); the linear
/// kernels ignore it.  Callers that retain the scratch (plan arenas,
/// band-job slots) make every method allocation-free on reuse.
pub fn pass_rows_into<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: ImageView<'_, P>,
    dst: ImageViewMut<'_, P>,
    y0: usize,
    window: usize,
    op: MorphOp,
    method: PassMethod,
    simd: bool,
    thresholds: super::HybridThresholds,
    scratch: &mut Vec<P>,
) {
    let m = resolve_method(method, window, thresholds.wy0);
    match (m, simd) {
        (PassMethod::Linear, true) => linear::rows_simd_linear_into(b, src, dst, y0, window, op),
        (PassMethod::Linear, false) => {
            linear::rows_scalar_linear_into(b, src, dst, y0, window, op)
        }
        (PassMethod::Vhgw, true) => {
            vhgw::rows_simd_vhgw_into(b, src, dst, y0, window, op, scratch)
        }
        (PassMethod::Vhgw, false) => {
            vhgw::rows_scalar_vhgw_into(b, src, dst, y0, window, op, scratch)
        }
        (PassMethod::Hybrid, _) => unreachable!("resolve_method returns concrete"),
    }
}

/// One cols-window (paper "vertical") pass with a *resolved* method.
///
/// * `simd == false` → direct scalar implementations (the paper's
///   "without SIMD" comparators never transpose).
/// * `simd == true`, [`VerticalStrategy::Transpose`] → the §5.2.1
///   sandwich: NEON tiled transpose at this pixel depth, SIMD rows
///   pass, transpose back.
/// * `simd == true`, [`VerticalStrategy::Direct`] → §5.2.2 offset-load
///   linear pass; vHGW has no direct SIMD form in the paper, so it falls
///   back to the transpose sandwich.
pub fn pass_cols<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    window: usize,
    op: MorphOp,
    method: PassMethod,
    simd: bool,
    vertical: VerticalStrategy,
    thresholds: super::HybridThresholds,
) -> Image<P> {
    let src = src.into();
    let m = resolve_method(method, window, thresholds.wx0);
    if !simd {
        return match m {
            PassMethod::Linear => linear::cols_scalar_linear(b, src, window, op),
            PassMethod::Vhgw => vhgw::cols_scalar_vhgw(b, src, window, op),
            PassMethod::Hybrid => unreachable!(),
        };
    }
    match m {
        PassMethod::Hybrid => unreachable!("resolve_method returns concrete"),
        m if takes_sandwich(m, true, vertical) => {
            transpose_sandwich(b, src, window, op, m, thresholds)
        }
        _ => linear::cols_simd_linear(b, src, window, op),
    }
}

/// The *direct* (non-sandwich) cols-window forms of [`pass_cols`],
/// writing straight into `dst` — rows are independent, so band jobs
/// pass zero-halo source bands.  Callers must have excluded the §5.2.1
/// sandwich case with [`takes_sandwich`] first (the sandwich transposes
/// whole images and is banded on the *transposed* buffer instead).
///
/// `scratch` serves whichever kernel the dispatch lands on — the vHGW
/// `R`-row slot (see [`pass_rows_into`]) or the SIMD-linear kernel's
/// identity-padded staging row ([`linear::cols_simd_linear_into`]); the
/// dispatches are mutually exclusive, so one retained slot makes every
/// cols method allocation-free on reuse.
pub fn pass_cols_direct_into<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: ImageView<'_, P>,
    dst: ImageViewMut<'_, P>,
    window: usize,
    op: MorphOp,
    method: PassMethod,
    simd: bool,
    vertical: VerticalStrategy,
    thresholds: super::HybridThresholds,
    scratch: &mut Vec<P>,
) {
    let m = resolve_method(method, window, thresholds.wx0);
    debug_assert!(
        !takes_sandwich(m, simd, vertical),
        "sandwich configurations have no direct _into form"
    );
    if !simd {
        match m {
            PassMethod::Linear => linear::cols_scalar_linear_into(b, src, dst, window, op),
            PassMethod::Vhgw => vhgw::cols_scalar_vhgw_into(b, src, dst, window, op, scratch),
            PassMethod::Hybrid => unreachable!(),
        }
        return;
    }
    linear::cols_simd_linear_into(b, src, dst, window, op, scratch);
}

/// Whether a *resolved* cols-window method executes as the §5.2.1
/// transpose sandwich: SIMD vHGW always (it has no direct SIMD form in
/// the paper), SIMD linear only under [`VerticalStrategy::Transpose`].
/// Single source of the strategy predicate — shared with the banded
/// path (`super::parallel`) and the cost-model dispatch estimator.
pub fn takes_sandwich(resolved: PassMethod, simd: bool, vertical: VerticalStrategy) -> bool {
    simd && matches!(
        (resolved, vertical),
        (PassMethod::Vhgw, _) | (PassMethod::Linear, VerticalStrategy::Transpose)
    )
}

/// §5.2.1: transpose → SIMD rows pass → transpose back, with the §4 NEON
/// transpose tiles of this depth (16×16.8 for `u8`, 8×8.16 for `u16` —
/// dispatched through [`MorphPixel::transpose_image`]).
fn transpose_sandwich<P: MorphPixel, B: Backend>(
    b: &mut B,
    src: ImageView<'_, P>,
    window: usize,
    op: MorphOp,
    method: PassMethod,
    thresholds: super::HybridThresholds,
) -> Image<P> {
    let t = P::transpose_image(b, src);
    let filtered = pass_rows(b, &t, window, op, method, true, thresholds);
    P::transpose_image(b, filtered.view())
}

/// Full separable 2-D morphology under a [`MorphConfig`], at either
/// pixel depth, on any borrowed view (whole image, row band or ROI
/// sub-rectangle alike).
pub fn morphology<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    op: MorphOp,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Image<P> {
    let src = src.into();
    let wing_x = wing_of(w_x, "w_x");
    let wing_y = wing_of(w_y, "w_y");
    if src.height() == 0 || src.width() == 0 {
        return src.to_image();
    }

    if cfg.border == Border::Replicate {
        let padded = super::replicate_pad(src, wing_x, wing_y);
        let mut inner = *cfg;
        inner.border = Border::Identity;
        let out = morphology(b, &padded, op, w_x, w_y, &inner);
        return super::crop(out.view(), wing_y, wing_x, src.height(), src.width());
    }

    let after_rows = if w_y > 1 {
        pass_rows(b, src, w_y, op, cfg.method, cfg.simd, cfg.thresholds)
    } else {
        src.to_image()
    };
    if w_x > 1 {
        pass_cols(
            b,
            &after_rows,
            w_x,
            op,
            cfg.method,
            cfg.simd,
            cfg.vertical,
            cfg.thresholds,
        )
    } else {
        after_rows
    }
}

/// Erosion with the paper's final (§5.3) configuration, native speed,
/// at either pixel depth.  Large images are band-sharded across the
/// shared worker pool when the cost model predicts a win (bit-identical
/// output; see [`super::parallel`]).
pub fn erode<'a, P: MorphPixel>(
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
) -> Image<P> {
    super::parallel::filter_native(src, MorphOp::Erode, w_x, w_y, &MorphConfig::default())
}

/// Dilation with the paper's final (§5.3) configuration, native speed,
/// at either pixel depth.  Band-sharded like [`erode`].
pub fn dilate<'a, P: MorphPixel>(
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
) -> Image<P> {
    super::parallel::filter_native(src, MorphOp::Dilate, w_x, w_y, &MorphConfig::default())
}

/// Region-of-interest erosion: computes exactly the `roi` rectangle of
/// `erode(src)` — identical to cropping the full result, but all reads
/// and compute are bounded by the ROI plus its `wing`-sized halo, never
/// the full image (see [`super::parallel::filter_roi`] for the halo
/// argument).
pub fn erode_roi<'a, P: MorphPixel>(
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    roi: Roi,
) -> Image<P> {
    super::parallel::filter_roi(src, MorphOp::Erode, w_x, w_y, &MorphConfig::default(), roi)
}

/// Region-of-interest dilation — the [`erode_roi`] counterpart.
pub fn dilate_roi<'a, P: MorphPixel>(
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    roi: Roi,
) -> Image<P> {
    super::parallel::filter_roi(src, MorphOp::Dilate, w_x, w_y, &MorphConfig::default(), roi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::morphology::naive;
    use crate::neon::Native;

    fn configs() -> Vec<MorphConfig> {
        let mut out = Vec::new();
        for method in [PassMethod::Linear, PassMethod::Vhgw, PassMethod::Hybrid] {
            for vertical in [VerticalStrategy::Transpose, VerticalStrategy::Direct] {
                for simd in [false, true] {
                    out.push(MorphConfig {
                        method,
                        vertical,
                        simd,
                        border: Border::Identity,
                        thresholds: super::super::HybridThresholds::paper(),
                        parallelism: super::super::Parallelism::Sequential,
                        representation: super::super::Representation::Dense,
                    });
                }
            }
        }
        out
    }

    #[test]
    fn all_configs_match_naive() {
        let img = synth::noise(37, 45, 77);
        for &(w_x, w_y) in &[(3, 3), (5, 9), (9, 5), (1, 7), (7, 1), (15, 15)] {
            for op in [MorphOp::Erode, MorphOp::Dilate] {
                let want = naive::morph2d_naive(&mut Native, &img, w_x, w_y, op);
                for cfg in configs() {
                    let got = morphology(&mut Native, &img, op, w_x, w_y, &cfg);
                    assert!(
                        got.same_pixels(&want),
                        "{op:?} {w_x}x{w_y} cfg={cfg:?} diff={:?}",
                        got.first_diff(&want)
                    );
                }
            }
        }
    }

    #[test]
    fn all_configs_match_naive_u16() {
        // the same exhaustive config sweep at 16-bit depth
        let img = synth::noise_u16(21, 27, 78);
        for &(w_x, w_y) in &[(3, 3), (5, 9), (1, 7), (7, 1)] {
            for op in [MorphOp::Erode, MorphOp::Dilate] {
                let want = naive::morph2d_naive(&mut Native, &img, w_x, w_y, op);
                for cfg in configs() {
                    let got = morphology(&mut Native, &img, op, w_x, w_y, &cfg);
                    assert!(
                        got.same_pixels(&want),
                        "u16 {op:?} {w_x}x{w_y} cfg={cfg:?} diff={:?}",
                        got.first_diff(&want)
                    );
                }
            }
        }
    }

    #[test]
    fn simple_api_matches_naive() {
        let img = synth::document(60, 80, 3);
        let e = erode(&img, 5, 3);
        let d = dilate(&img, 3, 5);
        assert!(e.same_pixels(&naive::morph2d_naive(&mut Native, &img, 5, 3, MorphOp::Erode)));
        assert!(d.same_pixels(&naive::morph2d_naive(&mut Native, &img, 3, 5, MorphOp::Dilate)));
    }

    #[test]
    fn simple_api_matches_naive_u16() {
        let img = synth::noise_u16(40, 56, 9);
        let e = erode(&img, 5, 3);
        let d = dilate(&img, 3, 5);
        assert!(e.same_pixels(&naive::morph2d_naive(&mut Native, &img, 5, 3, MorphOp::Erode)));
        assert!(d.same_pixels(&naive::morph2d_naive(&mut Native, &img, 3, 5, MorphOp::Dilate)));
    }

    #[test]
    fn roi_api_equals_cropped_full_filter() {
        let img = synth::noise(40, 52, 31);
        let roi = Roi::new(7, 9, 20, 24);
        let full = erode(&img, 5, 7);
        let want = full.view().sub_rect(7, 9, 20, 24).to_image();
        let got = erode_roi(&img, 5, 7, roi);
        assert!(got.same_pixels(&want), "{:?}", got.first_diff(&want));
        let fulld = dilate(&img, 7, 3);
        let wantd = fulld.view().sub_rect(7, 9, 20, 24).to_image();
        let gotd = dilate_roi(&img, 7, 3, roi);
        assert!(gotd.same_pixels(&wantd));
    }

    #[test]
    fn morphology_on_sub_view_matches_cropped_oracle() {
        // filtering a borrowed sub-rectangle == filtering its owned copy
        let img = synth::noise(30, 33, 12);
        let view = img.view().sub_rect(4, 6, 18, 21);
        let owned = view.to_image();
        let cfg = MorphConfig::default();
        let got = morphology(&mut Native, view, MorphOp::Erode, 5, 5, &cfg);
        let want = morphology(&mut Native, &owned, MorphOp::Erode, 5, 5, &cfg);
        assert!(got.same_pixels(&want));
    }

    #[test]
    fn replicate_border_differs_from_identity_only_at_edges() {
        let img = synth::noise(20, 20, 9);
        let mut cfg = MorphConfig::default();
        let ident = morphology(&mut Native, &img, MorphOp::Erode, 5, 5, &cfg);
        cfg.border = Border::Replicate;
        let repl = morphology(&mut Native, &img, MorphOp::Erode, 5, 5, &cfg);
        // interior must agree
        for y in 2..18 {
            for x in 2..18 {
                assert_eq!(ident.get(y, x), repl.get(y, x), "interior ({y},{x})");
            }
        }
        // replicate never exceeds identity for erosion (identity pads 255)
        for y in 0..20 {
            for x in 0..20 {
                assert!(repl.get(y, x) <= ident.get(y, x));
            }
        }
    }

    #[test]
    fn erosion_dilation_duality() {
        // erode(img) == MAX - dilate(MAX - img) for symmetric SEs
        let img = synth::noise(24, 31, 21);
        let inv = crate::image::Image::from_fn(24, 31, |y, x| 255 - img.get(y, x));
        let e = erode(&img, 7, 5);
        let d = dilate(&inv, 7, 5);
        for y in 0..24 {
            for x in 0..31 {
                assert_eq!(e.get(y, x), 255 - d.get(y, x));
            }
        }
    }

    #[test]
    fn degenerate_1x1_is_identity() {
        let img = synth::noise(10, 10, 1);
        assert!(erode(&img, 1, 1).same_pixels(&img));
        assert!(dilate(&img, 1, 1).same_pixels(&img));
        let img16 = synth::noise_u16(10, 10, 1);
        assert!(erode(&img16, 1, 1).same_pixels(&img16));
        assert!(dilate(&img16, 1, 1).same_pixels(&img16));
    }
}
