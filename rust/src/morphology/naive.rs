//! Naive 2-D sliding-window erosion/dilation — the §2 definition,
//! computed directly.  O(w_x·w_y) per pixel; exists as the correctness
//! oracle every fast implementation is tested against (at both pixel
//! depths), and as the "non-separable" comparator proving the
//! separability claim.

use super::{wing_of, MorphOp, MorphPixel};
use crate::image::{Image, ImageView};
use crate::neon::Backend;

/// Direct 2-D windowed reduction with identity borders.  Like every
/// kernel, takes a borrowed [`ImageView`] (a `&Image` coerces).
pub fn morph2d_naive<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    op: MorphOp,
) -> Image<P> {
    let src = src.into();
    let wing_x = wing_of(w_x, "w_x");
    let wing_y = wing_of(w_y, "w_y");
    let (h, w) = (src.height(), src.width());
    let px = std::mem::size_of::<P>() as u64;
    let mut dst = Image::zeros(h, w);
    b.record_stream((h * w) as u64 * px, (h * w) as u64 * px);
    for y in 0..h {
        let y0 = y.saturating_sub(wing_y);
        let y1 = (y + wing_y).min(h.saturating_sub(1));
        for x in 0..w {
            let x0 = x.saturating_sub(wing_x);
            let x1 = (x + wing_x).min(w.saturating_sub(1));
            let mut acc: P = op.identity();
            for yy in y0..=y1 {
                let row = src.row(yy);
                for xx in x0..=x1 {
                    let v = P::load(b, row, xx);
                    acc = op.scalar(b, acc, v);
                }
            }
            P::store(b, dst.row_mut(y), x, acc);
        }
    }
    dst
}

/// Naive 1-D reduction over a window of ROWS (oracle for the fast rows
/// passes).
pub fn rows_naive<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    morph2d_naive(b, src, 1, window, op)
}

/// Naive 1-D reduction over a window of COLUMNS (oracle for the fast
/// cols passes).
pub fn cols_naive<'a, P: MorphPixel, B: Backend>(
    b: &mut B,
    src: impl Into<ImageView<'a, P>>,
    window: usize,
    op: MorphOp,
) -> Image<P> {
    morph2d_naive(b, src, window, 1, op)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::neon::Native;

    #[test]
    fn erosion_of_impulse_is_window_footprint() {
        // A single dark pixel must erode to exactly a w_x × w_y block.
        let mut img = Image::filled(11, 11, 200u8);
        img.set(5, 5, 10);
        let out = morph2d_naive(&mut Native, &img, 3, 5, MorphOp::Erode);
        for y in 0..11 {
            for x in 0..11 {
                let inside = (3..=7).contains(&y) && (4..=6).contains(&x);
                assert_eq!(out.get(y, x), if inside { 10 } else { 200 }, "at ({y},{x})");
            }
        }
    }

    #[test]
    fn dilation_of_impulse_is_window_footprint() {
        let mut img = Image::filled(9, 9, 50u8);
        img.set(4, 4, 250);
        let out = morph2d_naive(&mut Native, &img, 5, 3, MorphOp::Dilate);
        for y in 0..9 {
            for x in 0..9 {
                let inside = (3..=5).contains(&y) && (2..=6).contains(&x);
                assert_eq!(out.get(y, x), if inside { 250 } else { 50 });
            }
        }
    }

    #[test]
    fn u16_impulse_footprint() {
        // same law at 16-bit depth, with values above u8 range
        let mut img = Image::filled(9, 9, 40_000u16);
        img.set(4, 4, 300);
        let out = morph2d_naive(&mut Native, &img, 3, 3, MorphOp::Erode);
        for y in 0..9 {
            for x in 0..9 {
                let inside = (3..=5).contains(&y) && (3..=5).contains(&x);
                assert_eq!(out.get(y, x), if inside { 300 } else { 40_000 });
            }
        }
    }

    #[test]
    fn window_one_is_identity() {
        let img = synth::noise(13, 17, 5);
        let out = morph2d_naive(&mut Native, &img, 1, 1, MorphOp::Erode);
        assert!(out.same_pixels(&img));
        let img16 = synth::noise_u16(13, 17, 5);
        let out16 = morph2d_naive(&mut Native, &img16, 1, 1, MorphOp::Dilate);
        assert!(out16.same_pixels(&img16));
    }

    #[test]
    fn borders_use_identity_not_wraparound() {
        // all-dark image: erosion must stay dark at the borders (identity
        // padding only shrinks the window, it never injects MAX into the
        // output because min(MAX, dark) = dark)
        let img = Image::filled(5, 5, 3u8);
        let out = morph2d_naive(&mut Native, &img, 5, 5, MorphOp::Erode);
        assert!(out.same_pixels(&img));
        // all-bright: dilation symmetric
        let img = Image::filled(5, 5, 250u8);
        let out = morph2d_naive(&mut Native, &img, 5, 5, MorphOp::Dilate);
        assert!(out.same_pixels(&img));
    }

    #[test]
    fn rows_then_cols_equals_2d() {
        // separability at the oracle level
        let img = synth::noise(20, 24, 8);
        let a = morph2d_naive(&mut Native, &img, 5, 7, MorphOp::Erode);
        let r = rows_naive(&mut Native, &img, 7, MorphOp::Erode);
        let c = cols_naive(&mut Native, &r, 5, MorphOp::Erode);
        assert!(a.same_pixels(&c));
    }
}
