//! Geodesic morphology and reconstruction — the iterate-to-stability
//! scenario engine (arXiv 1911.13074).
//!
//! A **geodesic dilation** of a marker image under a mask is one
//! elementary dilation clamped back under the mask
//! (`min(dilate(marker), mask)`); **morphological reconstruction by
//! dilation** iterates geodesic dilations until nothing changes — the
//! core primitive of hole filling, border clearing and marker-based
//! segmentation.  Reconstruction by erosion is the lattice dual
//! (`max(erode(marker), mask)` iterated from above).
//!
//! ## Execution model
//!
//! Each sweep is one ordinary [`FilterPlan`] dilation/erosion — so it
//! runs as **banded passes on the shared
//! [`super::parallel::BandPool`]** whenever the plan's parallelism
//! policy bands it (halo = the SE wing, 1 for the canonical 3×3 SE),
//! inheriting the plan layer's zero-allocation arena and bit-identical
//! banding guarantee.  The clamp + change count is a pointwise
//! post-step; the loop terminates because each sweep is monotone
//! (nondecreasing for dilation, nonincreasing for erosion) and bounded
//! by the mask.
//!
//! ## Convergence and sweep counting
//!
//! The reported sweep count is the number of *executed* sweeps,
//! including the final sweep that proves the fixpoint (changed == 0) —
//! ≥ 1 for any non-empty image, 0 for empty ones.  The fixpoint itself
//! is independent of banding and of the sweep SE decomposition order,
//! and is pinned against a naive iterate-to-stability oracle in
//! `rust/tests/rle_geodesic.rs` and the python mirror.

use super::plan::{FilterOp, FilterPlan, FilterSpec, PlanError};
use super::{MorphConfig, MorphOp, MorphPixel};
use crate::image::{Image, ImageView, ImageViewMut};

/// Pointwise clamp of `v` against the mask value: under the mask for
/// dilation (`min`), over it for erosion (`max`).
#[inline(always)]
fn clamp_to_mask<P: MorphPixel>(op: MorphOp, v: P, m: P) -> P {
    match op {
        MorphOp::Dilate => {
            if v < m {
                v
            } else {
                m
            }
        }
        MorphOp::Erode => {
            if v > m {
                v
            } else {
                m
            }
        }
    }
}

/// Core reconstruction loop shared by the library entry points and
/// [`FilterPlan::run_reconstruct`]: iterate `sweep` (an elementary
/// dilate/erode plan matching `op`) from `min/max(marker, mask)` until
/// a sweep changes nothing, using the caller's `cur`/`next` buffers
/// (arena-owned in the plan path), and write the fixpoint into `dst`.
/// Returns the executed sweep count.
pub(crate) fn reconstruct_with_plan<P: MorphPixel>(
    sweep: &mut FilterPlan<P>,
    op: MorphOp,
    marker: ImageView<'_, P>,
    mask: ImageView<'_, P>,
    cur: &mut Vec<P>,
    next: &mut Vec<P>,
    dst: &mut ImageViewMut<'_, P>,
) -> usize {
    let (h, w) = (mask.height(), mask.width());
    assert_eq!(
        (marker.height(), marker.width()),
        (h, w),
        "reconstruction marker must match the mask shape"
    );
    assert_eq!(
        (dst.height(), dst.width()),
        (h, w),
        "reconstruction output must match the mask shape"
    );
    if h == 0 || w == 0 {
        return 0;
    }
    let px = h * w;
    cur.resize(px, P::MIN_VALUE);
    next.resize(px, P::MIN_VALUE);
    // cur_0: the marker clamped against the mask (the loop invariant
    // "cur is between marker's clamp and the fixpoint" starts here)
    for y in 0..h {
        let (mrow, krow) = (marker.row(y), mask.row(y));
        for x in 0..w {
            cur[y * w + x] = clamp_to_mask(op, mrow[x], krow[x]);
        }
    }
    let mut sweeps = 0usize;
    loop {
        sweeps += 1;
        sweep.run(
            ImageView::from_slice(cur, h, w, w),
            ImageViewMut::from_slice_mut(next, h, w, w),
        );
        let mut changed = 0usize;
        for y in 0..h {
            let krow = mask.row(y);
            let base = y * w;
            for x in 0..w {
                let v = clamp_to_mask(op, next[base + x], krow[x]);
                if v != cur[base + x] {
                    changed += 1;
                }
                next[base + x] = v;
            }
        }
        std::mem::swap(cur, next);
        if changed == 0 {
            break;
        }
    }
    for y in 0..h {
        dst.row_mut(y).copy_from_slice(&cur[y * w..y * w + w]);
    }
    sweeps
}

fn check_shapes<P: MorphPixel>(
    marker: ImageView<'_, P>,
    mask: ImageView<'_, P>,
) -> Result<(), PlanError> {
    if (marker.height(), marker.width()) != (mask.height(), mask.width()) {
        return Err(PlanError(format!(
            "marker {}x{} does not match mask {}x{}",
            marker.height(),
            marker.width(),
            mask.height(),
            mask.width()
        )));
    }
    Ok(())
}

fn sweep_plan<P: MorphPixel>(
    op: MorphOp,
    h: usize,
    w: usize,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Result<FilterPlan<P>, PlanError> {
    let fop = match op {
        MorphOp::Dilate => FilterOp::Dilate,
        MorphOp::Erode => FilterOp::Erode,
    };
    FilterSpec::new(fop, w_x, w_y).with_config(*cfg).plan(h, w)
}

fn geodesic_step<P: MorphPixel>(
    op: MorphOp,
    marker: ImageView<'_, P>,
    mask: ImageView<'_, P>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Result<Image<P>, PlanError> {
    check_shapes(marker, mask)?;
    let (h, w) = (mask.height(), mask.width());
    let mut plan = sweep_plan::<P>(op, h, w, w_x, w_y, cfg)?;
    let mut out = plan.run_owned(marker);
    for y in 0..h {
        let krow = mask.row(y);
        for (x, v) in out.row_mut(y).iter_mut().enumerate() {
            *v = clamp_to_mask(op, *v, krow[x]);
        }
    }
    Ok(out)
}

/// One geodesic dilation of `marker` under `mask`:
/// `min(dilate(marker), mask)` with the spec's `w_x × w_y` SE.
pub fn geodesic_dilate<'a, P: MorphPixel>(
    marker: impl Into<ImageView<'a, P>>,
    mask: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Result<Image<P>, PlanError> {
    geodesic_step(MorphOp::Dilate, marker.into(), mask.into(), w_x, w_y, cfg)
}

/// One geodesic erosion of `marker` over `mask`:
/// `max(erode(marker), mask)` — the lattice dual of
/// [`geodesic_dilate`].
pub fn geodesic_erode<'a, P: MorphPixel>(
    marker: impl Into<ImageView<'a, P>>,
    mask: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Result<Image<P>, PlanError> {
    geodesic_step(MorphOp::Erode, marker.into(), mask.into(), w_x, w_y, cfg)
}

fn reconstruct<P: MorphPixel>(
    op: MorphOp,
    marker: ImageView<'_, P>,
    mask: ImageView<'_, P>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Result<(Image<P>, usize), PlanError> {
    check_shapes(marker, mask)?;
    let (h, w) = (mask.height(), mask.width());
    let mut plan = sweep_plan::<P>(op, h, w, w_x, w_y, cfg)?;
    let (mut cur, mut next) = (Vec::new(), Vec::new());
    let mut out = Image::zeros(h, w);
    let sweeps = reconstruct_with_plan(
        &mut plan,
        op,
        marker,
        mask,
        &mut cur,
        &mut next,
        &mut out.view_mut(),
    );
    Ok((out, sweeps))
}

/// Morphological reconstruction by dilation: iterate geodesic dilations
/// of `marker` under `mask` (SE `w_x × w_y`) to stability.  Returns the
/// fixpoint and the executed sweep count.  This is the operation
/// [`super::FilterOp::Reconstruct`] specs resolve to — the plan/engine
/// path is bit-identical to this call.
pub fn reconstruct_by_dilation<'a, P: MorphPixel>(
    marker: impl Into<ImageView<'a, P>>,
    mask: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Result<(Image<P>, usize), PlanError> {
    reconstruct(MorphOp::Dilate, marker.into(), mask.into(), w_x, w_y, cfg)
}

/// Morphological reconstruction by erosion: iterate geodesic erosions
/// of `marker` over `mask` to stability — the dual of
/// [`reconstruct_by_dilation`].
pub fn reconstruct_by_erosion<'a, P: MorphPixel>(
    marker: impl Into<ImageView<'a, P>>,
    mask: impl Into<ImageView<'a, P>>,
    w_x: usize,
    w_y: usize,
    cfg: &MorphConfig,
) -> Result<(Image<P>, usize), PlanError> {
    reconstruct(MorphOp::Erode, marker.into(), mask.into(), w_x, w_y, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::morphology::{separable, Parallelism};
    use crate::neon::Native;

    fn seq_cfg() -> MorphConfig {
        MorphConfig {
            parallelism: Parallelism::Sequential,
            ..MorphConfig::default()
        }
    }

    /// Naive iterate-to-stability oracle: dense sweeps + pointwise
    /// clamp, counting executed sweeps exactly like the engine.
    fn naive_reconstruct(
        op: MorphOp,
        marker: &Image<u8>,
        mask: &Image<u8>,
        w_x: usize,
        w_y: usize,
        cfg: &MorphConfig,
    ) -> (Image<u8>, usize) {
        let (h, w) = (mask.height(), mask.width());
        let mut cur = Image::from_fn(h, w, |y, x| {
            clamp_to_mask(op, marker.get(y, x), mask.get(y, x))
        });
        let mut sweeps = 0;
        loop {
            sweeps += 1;
            let swept = separable::morphology(&mut Native, &cur, op, w_x, w_y, cfg);
            let next = Image::from_fn(h, w, |y, x| {
                clamp_to_mask(op, swept.get(y, x), mask.get(y, x))
            });
            let changed = !next.same_pixels(&cur);
            cur = next;
            if !changed {
                return (cur, sweeps);
            }
        }
    }

    #[test]
    fn single_marker_floods_its_component_only() {
        // two FG blobs; a marker inside one reconstructs exactly it
        let mut mask = Image::<u8>::zeros(20, 20);
        for y in 2..8 {
            for x in 2..8 {
                mask.set(y, x, 255);
            }
        }
        for y in 12..18 {
            for x in 12..18 {
                mask.set(y, x, 255);
            }
        }
        let mut marker = Image::<u8>::zeros(20, 20);
        marker.set(4, 4, 255);
        let (rec, sweeps) = reconstruct_by_dilation(&marker, &mask, 3, 3, &seq_cfg()).unwrap();
        assert!(sweeps >= 2, "flooding a 6x6 blob takes several sweeps, got {sweeps}");
        assert_eq!(rec.get(3, 3), 255, "marked component floods");
        assert_eq!(rec.get(14, 14), 0, "unmarked component stays empty");
        let fg = rec.to_vec().iter().filter(|&&v| v == 255).count();
        assert_eq!(fg, 36, "exactly the marked 6x6 component");
    }

    #[test]
    fn matches_naive_oracle_on_gray_images() {
        let cfg = seq_cfg();
        let mask = synth::noise(24, 29, 11);
        // marker must start under the mask somewhere meaningful: use a
        // darkened copy
        let marker = Image::from_fn(24, 29, |y, x| mask.get(y, x).saturating_sub(60));
        for (wx, wy) in [(3usize, 3usize), (5, 3), (3, 7)] {
            let (want, want_sweeps) = naive_reconstruct(MorphOp::Dilate, &marker, &mask, wx, wy, &cfg);
            let (got, got_sweeps) = reconstruct_by_dilation(&marker, &mask, wx, wy, &cfg).unwrap();
            assert!(got.same_pixels(&want), "{wx}x{wy}: {:?}", got.first_diff(&want));
            assert_eq!(got_sweeps, want_sweeps, "{wx}x{wy} sweep count");
        }
    }

    #[test]
    fn erosion_reconstruction_is_the_dual() {
        let cfg = seq_cfg();
        let mask = synth::noise(18, 21, 5);
        let marker = Image::from_fn(18, 21, |y, x| mask.get(y, x).saturating_add(50));
        let (want, want_sweeps) = naive_reconstruct(MorphOp::Erode, &marker, &mask, 3, 3, &cfg);
        let (got, got_sweeps) = reconstruct_by_erosion(&marker, &mask, 3, 3, &cfg).unwrap();
        assert!(got.same_pixels(&want), "{:?}", got.first_diff(&want));
        assert_eq!(got_sweeps, want_sweeps);
        // duality through inversion: rec_by_erosion(m, k) ==
        // invert(rec_by_dilation(invert(m), invert(k)))
        let inv = |img: &Image<u8>| Image::from_fn(img.height(), img.width(), |y, x| 255 - img.get(y, x));
        let (dual, _) = reconstruct_by_dilation(&inv(&marker), &inv(&mask), 3, 3, &cfg).unwrap();
        assert!(inv(&dual).same_pixels(&got));
    }

    #[test]
    fn banded_sweeps_match_sequential() {
        let mask = synth::noise(40, 50, 9);
        let marker = Image::from_fn(40, 50, |y, x| mask.get(y, x).saturating_sub(40));
        let seq = reconstruct_by_dilation(&marker, &mask, 3, 3, &seq_cfg()).unwrap();
        let banded_cfg = MorphConfig {
            parallelism: Parallelism::Fixed(4),
            ..MorphConfig::default()
        };
        let banded = reconstruct_by_dilation(&marker, &mask, 3, 3, &banded_cfg).unwrap();
        assert!(banded.0.same_pixels(&seq.0), "banding must stay bit-identical");
        assert_eq!(banded.1, seq.1, "sweep counts agree across banding");
    }

    #[test]
    fn geodesic_single_steps() {
        let cfg = seq_cfg();
        let mask = synth::noise(15, 17, 2);
        let marker = Image::from_fn(15, 17, |y, x| mask.get(y, x).saturating_sub(30));
        let gd = geodesic_dilate(&marker, &mask, 3, 3, &cfg).unwrap();
        let plain = separable::morphology(&mut Native, &marker, MorphOp::Dilate, 3, 3, &cfg);
        for y in 0..15 {
            for x in 0..17 {
                assert_eq!(gd.get(y, x), plain.get(y, x).min(mask.get(y, x)));
            }
        }
        let ge = geodesic_erode(&mask, &marker, 3, 3, &cfg).unwrap();
        let er = separable::morphology(&mut Native, &mask, MorphOp::Erode, 3, 3, &cfg);
        for y in 0..15 {
            for x in 0..17 {
                assert_eq!(ge.get(y, x), er.get(y, x).max(marker.get(y, x)));
            }
        }
    }

    #[test]
    fn shape_mismatch_and_empty_images() {
        let a = Image::<u8>::zeros(4, 4);
        let b = Image::<u8>::zeros(4, 5);
        assert!(reconstruct_by_dilation(&a, &b, 3, 3, &seq_cfg()).is_err());
        assert!(geodesic_dilate(&a, &b, 3, 3, &seq_cfg()).is_err());
        let empty = Image::<u8>::zeros(0, 7);
        let (out, sweeps) = reconstruct_by_dilation(&empty, &empty, 3, 3, &seq_cfg()).unwrap();
        assert_eq!((out.height(), out.width()), (0, 7));
        assert_eq!(sweeps, 0, "empty images take zero sweeps");
    }

    #[test]
    fn reconstruction_works_on_u16() {
        let cfg = seq_cfg();
        let mask = synth::noise_u16(12, 14, 3);
        let marker = Image::from_fn(12, 14, |y, x| mask.get(y, x).saturating_sub(9000));
        let (got, sweeps) = reconstruct_by_dilation(&marker, &mask, 3, 3, &cfg).unwrap();
        assert!(sweeps >= 1);
        // fixpoint property: one more geodesic dilation changes nothing
        let again = geodesic_dilate(&got, &mask, 3, 3, &cfg).unwrap();
        assert!(again.same_pixels(&got));
    }
}
