//! Run-length binary morphology — the sparse-mask scenario engine
//! (arXiv 1504.01052).
//!
//! A 0/255 (more generally `MIN`/`MAX`-valued) image is represented as
//! per-row sorted foreground intervals ([`RleImage`]); rectangular-SE
//! erosion and dilation then become **interval arithmetic** instead of
//! dense pixel passes:
//!
//! * horizontal erode: each run `[s, e)` shrinks by the wing on every
//!   side that does not touch the image border (identity borders pad
//!   with the erosion identity `MAX`, so border-touching ends do not
//!   shrink);
//! * horizontal dilate: each run grows by the wing, clamped to the
//!   image, and overlapping/adjacent runs coalesce;
//! * vertical erode: row `y` is the interval **intersection** of the
//!   `w_y` rows around it (out-of-image rows count as full-foreground,
//!   the erosion identity);
//! * vertical dilate: row `y` is the interval **union** of the in-image
//!   rows around it.
//!
//! On sparse document masks this is 10-100× cheaper than the dense
//! passes — work scales with the number of *runs*, not pixels — and the
//! result is **bit-identical** to the dense binary path (pinned by
//! `rust/tests/rle_geodesic.rs` and the `python/tests/test_rle_geodesic.py`
//! mirror).
//!
//! ## Representation invariants
//!
//! Every row's runs are sorted, pairwise disjoint, non-empty, and
//! separated by at least one background pixel (i.e. they are the
//! *maximal* foreground intervals of the row).  [`RleImage::from_view`]
//! establishes the invariant and every operator preserves it: erosion
//! only grows gaps, dilation coalesces touching runs, intersection of
//! maximal run lists is maximal, and the union path re-coalesces.
//!
//! ## Border semantics
//!
//! The interval rules above implement [`super::Border::Identity`]
//! exactly.  For *whole-image* rectangular-SE min/max they are also
//! bit-identical under [`super::Border::Replicate`]: every replicated
//! out-of-image tap duplicates an edge pixel that is itself inside the
//! window, so the windowed min/max is unchanged.  The plan dispatch
//! ([`try_run_chain_rle`]) therefore accepts both borders (plans with a
//! ROI never dispatch here).

use std::marker::PhantomData;

use super::plan::{FilterOp, FilterSpec};
use super::{wing_of, MorphOp, MorphPixel, Representation};
use crate::image::{Image, ImageView, ImageViewMut};

/// One maximal foreground interval `[start, end)` of a row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Run {
    pub start: usize,
    pub end: usize,
}

impl Run {
    pub fn new(start: usize, end: usize) -> Run {
        Run { start, end }
    }

    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.end <= self.start
    }
}

/// Run-length representation of a binary (`MIN`/`MAX`-valued) image:
/// per-row sorted maximal foreground intervals.  See the module docs
/// for the invariants and the interval-arithmetic operator rules.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RleImage<P: MorphPixel> {
    height: usize,
    width: usize,
    rows: Vec<Vec<Run>>,
    _pixel: PhantomData<P>,
}

impl<P: MorphPixel> RleImage<P> {
    /// Encode a binary view (`P::MIN_VALUE` background, `P::MAX_VALUE`
    /// foreground).  Returns `None` if any pixel holds another value —
    /// the caller's cue to stay on the dense path.
    pub fn from_view<'a>(src: impl Into<ImageView<'a, P>>) -> Option<RleImage<P>> {
        let src = src.into();
        let (h, w) = (src.height(), src.width());
        let mut rows = Vec::with_capacity(h);
        for y in 0..h {
            let mut runs = Vec::new();
            let mut open: Option<usize> = None;
            for (x, &v) in src.row(y).iter().enumerate() {
                if v == P::MAX_VALUE {
                    if open.is_none() {
                        open = Some(x);
                    }
                } else if v == P::MIN_VALUE {
                    if let Some(s) = open.take() {
                        runs.push(Run::new(s, x));
                    }
                } else {
                    return None;
                }
            }
            if let Some(s) = open {
                runs.push(Run::new(s, w));
            }
            rows.push(runs);
        }
        Some(RleImage {
            height: h,
            width: w,
            rows,
            _pixel: PhantomData,
        })
    }

    /// An all-background image.
    pub fn empty(height: usize, width: usize) -> RleImage<P> {
        RleImage {
            height,
            width,
            rows: (0..height).map(|_| Vec::new()).collect(),
            _pixel: PhantomData,
        }
    }

    pub fn height(&self) -> usize {
        self.height
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// The runs of row `y`.
    pub fn row_runs(&self, y: usize) -> &[Run] {
        &self.rows[y]
    }

    /// Total runs across all rows — the quantity RLE work scales with.
    pub fn run_count(&self) -> usize {
        self.rows.iter().map(Vec::len).sum()
    }

    /// Total foreground pixels.
    pub fn fg_pixels(&self) -> usize {
        self.rows
            .iter()
            .flat_map(|r| r.iter())
            .map(Run::len)
            .sum()
    }

    /// Foreground fraction in `[0, 1]` (0 for an empty image) — the
    /// cost model's representation-dispatch input.
    pub fn density(&self) -> f64 {
        let px = self.height * self.width;
        if px == 0 {
            0.0
        } else {
            self.fg_pixels() as f64 / px as f64
        }
    }

    /// Decode back to a dense image.
    pub fn to_image(&self) -> Image<P> {
        let mut out = Image::zeros(self.height, self.width);
        self.write_into(&mut out.view_mut());
        out
    }

    /// Decode into a caller-provided same-shape destination.
    pub fn write_into(&self, dst: &mut ImageViewMut<'_, P>) {
        assert_eq!(
            (dst.height(), dst.width()),
            (self.height, self.width),
            "RLE decode destination must be {}x{}",
            self.height,
            self.width
        );
        for y in 0..self.height {
            let row = dst.row_mut(y);
            for v in row.iter_mut() {
                *v = P::MIN_VALUE;
            }
            for r in &self.rows[y] {
                for v in row[r.start..r.end].iter_mut() {
                    *v = P::MAX_VALUE;
                }
            }
        }
    }

    /// Erosion by a `w_x × w_y` rectangular SE (identity borders):
    /// horizontal interval shrink, then `w_y`-row interval
    /// intersection.  Bit-identical to the dense separable erosion.
    pub fn erode(&self, w_x: usize, w_y: usize) -> RleImage<P> {
        let wing_x = wing_of(w_x, "w_x");
        let wing_y = wing_of(w_y, "w_y");
        let shrunk = self.map_rows(|runs| shrink_row(runs, wing_x, self.width));
        shrunk.fold_rows(wing_y, true)
    }

    /// Dilation by a `w_x × w_y` rectangular SE: horizontal interval
    /// grow + coalesce, then `w_y`-row interval union.  Bit-identical
    /// to the dense separable dilation.
    pub fn dilate(&self, w_x: usize, w_y: usize) -> RleImage<P> {
        let wing_x = wing_of(w_x, "w_x");
        let wing_y = wing_of(w_y, "w_y");
        let grown = self.map_rows(|runs| grow_row(runs, wing_x, self.width));
        grown.fold_rows(wing_y, false)
    }

    /// [`RleImage::erode`] / [`RleImage::dilate`] selected by op.
    pub fn apply(&self, op: MorphOp, w_x: usize, w_y: usize) -> RleImage<P> {
        match op {
            MorphOp::Erode => self.erode(w_x, w_y),
            MorphOp::Dilate => self.dilate(w_x, w_y),
        }
    }

    fn map_rows(&self, f: impl Fn(&[Run]) -> Vec<Run>) -> RleImage<P> {
        RleImage {
            height: self.height,
            width: self.width,
            rows: self.rows.iter().map(|r| f(r)).collect(),
            _pixel: PhantomData,
        }
    }

    /// Vertical pass: output row `y` combines the in-image rows
    /// `y−wing ..= y+wing` — intersection for erosion (out-of-image
    /// rows are the full-foreground identity and drop out), union for
    /// dilation (out-of-image rows are empty and drop out).
    fn fold_rows(&self, wing: usize, erode: bool) -> RleImage<P> {
        if wing == 0 || self.height == 0 {
            return self.clone();
        }
        let full = vec![Run::new(0, self.width)];
        let mut rows = Vec::with_capacity(self.height);
        for y in 0..self.height {
            let lo = y.saturating_sub(wing);
            let hi = (y + wing).min(self.height - 1);
            if erode {
                let mut acc = if self.width > 0 { full.clone() } else { Vec::new() };
                for yy in lo..=hi {
                    if acc.is_empty() {
                        break;
                    }
                    acc = intersect_runs(&acc, &self.rows[yy]);
                }
                rows.push(acc);
            } else {
                rows.push(union_runs((lo..=hi).map(|yy| self.rows[yy].as_slice())));
            }
        }
        RleImage {
            height: self.height,
            width: self.width,
            rows,
            _pixel: PhantomData,
        }
    }
}

/// Horizontal erosion of one row's runs: each run loses `wing` pixels
/// per side, except at a side flush with the image border (identity
/// padding is full-foreground there).
fn shrink_row(runs: &[Run], wing: usize, width: usize) -> Vec<Run> {
    if wing == 0 {
        return runs.to_vec();
    }
    let mut out = Vec::with_capacity(runs.len());
    for r in runs {
        let s = if r.start == 0 { 0 } else { r.start + wing };
        let e = if r.end == width {
            width
        } else {
            r.end.saturating_sub(wing)
        };
        if s < e {
            out.push(Run::new(s, e));
        }
    }
    out
}

/// Horizontal dilation of one row's runs: each run grows by `wing` per
/// side (clamped to the image) and touching runs coalesce.
fn grow_row(runs: &[Run], wing: usize, width: usize) -> Vec<Run> {
    if wing == 0 {
        return runs.to_vec();
    }
    let mut out: Vec<Run> = Vec::with_capacity(runs.len());
    for r in runs {
        let s = r.start.saturating_sub(wing);
        let e = (r.end + wing).min(width);
        match out.last_mut() {
            Some(last) if s <= last.end => last.end = last.end.max(e),
            _ => out.push(Run::new(s, e)),
        }
    }
    out
}

/// Interval intersection of two sorted maximal run lists (two-pointer
/// sweep; the result is again sorted and maximal).
fn intersect_runs(a: &[Run], b: &[Run]) -> Vec<Run> {
    let (mut i, mut j) = (0usize, 0usize);
    let mut out = Vec::new();
    while i < a.len() && j < b.len() {
        let s = a[i].start.max(b[j].start);
        let e = a[i].end.min(b[j].end);
        if s < e {
            out.push(Run::new(s, e));
        }
        if a[i].end <= b[j].end {
            i += 1;
        } else {
            j += 1;
        }
    }
    out
}

/// Interval union of several sorted run lists: merge by start, coalesce
/// overlapping/adjacent intervals back to maximal runs.
fn union_runs<'a>(lists: impl Iterator<Item = &'a [Run]>) -> Vec<Run> {
    let mut all: Vec<Run> = lists.flat_map(|l| l.iter().copied()).collect();
    all.sort_unstable_by_key(|r| r.start);
    let mut out: Vec<Run> = Vec::with_capacity(all.len());
    for r in all {
        match out.last_mut() {
            Some(last) if r.start <= last.end => last.end = last.end.max(r.end),
            _ => out.push(r),
        }
    }
    out
}

/// The primitive erode/dilate sequence a spec's op chain lowers to on
/// the RLE engine, or `None` if any op has no pure-morph lowering
/// (subtraction chains — gradient/top-hat/black-hat — and the special
/// transpose/reconstruct ops stay dense).  Mirrors
/// [`super::plan::lower`] step for step on the eligible ops.
pub fn rle_op_sequence(ops: &[FilterOp]) -> Option<Vec<MorphOp>> {
    let mut seq = Vec::with_capacity(ops.len() * 2);
    for op in ops {
        match op {
            FilterOp::Erode => seq.push(MorphOp::Erode),
            FilterOp::Dilate => seq.push(MorphOp::Dilate),
            FilterOp::Open => {
                seq.push(MorphOp::Erode);
                seq.push(MorphOp::Dilate);
            }
            FilterOp::Close => {
                seq.push(MorphOp::Dilate);
                seq.push(MorphOp::Erode);
            }
            _ => return None,
        }
    }
    Some(seq)
}

/// Plan-layer dispatch: run `spec`'s whole op chain as interval
/// arithmetic if the spec's [`Representation`] and the source allow it.
/// Returns `true` when `dst` was written (bit-identical to the dense
/// path); `false` means "stay dense" — non-binary source, ineligible op
/// chain, `Representation::Dense`, or an `Auto` decision in favour of
/// the dense passes.  Callers guarantee a whole-image (no-ROI) plan.
pub(crate) fn try_run_chain_rle<P: MorphPixel>(
    spec: &FilterSpec,
    src: ImageView<'_, P>,
    dst: &mut ImageViewMut<'_, P>,
) -> bool {
    if spec.config.representation == Representation::Dense {
        return false;
    }
    let Some(seq) = rle_op_sequence(spec.ops.as_slice()) else {
        return false;
    };
    let Some(mut rle) = RleImage::<P>::from_view(src) else {
        return false;
    };
    if spec.config.representation == Representation::Auto {
        let model = crate::costmodel::CostModel::exynos5422();
        let speedup = model.rle_speedup(
            src.height(),
            src.width(),
            spec.w_x,
            spec.w_y,
            seq.len(),
            rle.density(),
            std::mem::size_of::<P>(),
            &spec.config,
        );
        if speedup <= 1.0 {
            return false;
        }
    }
    for op in seq {
        rle = rle.apply(op, spec.w_x, spec.w_y);
    }
    rle.write_into(dst);
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::morphology::{separable, MorphConfig, Parallelism};
    use crate::neon::Native;

    fn mask_u8(h: usize, w: usize, density_pct: u8, seed: u64) -> Image<u8> {
        let mut rng = synth::Rng::new(seed);
        Image::from_fn(h, w, |_, _| {
            if (rng.next_u64() % 100) < density_pct as u64 {
                255
            } else {
                0
            }
        })
    }

    fn seq_cfg() -> MorphConfig {
        MorphConfig {
            parallelism: Parallelism::Sequential,
            ..MorphConfig::default()
        }
    }

    #[test]
    fn round_trip_is_lossless() {
        for density in [0u8, 1, 5, 50, 95, 100] {
            let img = mask_u8(23, 31, density, 7 + density as u64);
            let rle = RleImage::from_view(&img).expect("binary image must encode");
            assert!(rle.to_image().same_pixels(&img), "density {density}");
            assert_eq!(rle.fg_pixels(), img.to_vec().iter().filter(|&&v| v == 255).count());
        }
    }

    #[test]
    fn non_binary_images_refuse_to_encode() {
        let mut img = mask_u8(8, 8, 50, 3);
        img.set(4, 4, 17);
        assert!(RleImage::from_view(&img).is_none());
        // u16 binary uses the u16 identities, not 0/255
        let img16 = Image::<u16>::from_fn(4, 4, |y, x| if (y + x) % 2 == 0 { 65535 } else { 0 });
        assert!(RleImage::from_view(&img16).is_some());
        let img16_u8_style = Image::<u16>::from_fn(4, 4, |_, _| 255);
        assert!(RleImage::from_view(&img16_u8_style).is_none());
    }

    #[test]
    fn runs_stay_maximal_through_every_operator() {
        let img = mask_u8(20, 40, 30, 0xBEEF);
        let rle = RleImage::from_view(&img).unwrap();
        for r in [rle.erode(5, 3), rle.dilate(5, 3), rle.erode(1, 7), rle.dilate(7, 1)] {
            for y in 0..r.height() {
                let runs = r.row_runs(y);
                for win in runs.windows(2) {
                    assert!(
                        win[0].end < win[1].start,
                        "row {y}: runs {win:?} must be sorted with a gap"
                    );
                }
                for run in runs {
                    assert!(!run.is_empty());
                    assert!(run.end <= r.width());
                }
            }
        }
    }

    #[test]
    fn interval_erode_dilate_match_dense_u8() {
        let cfg = seq_cfg();
        for (density, seed) in [(0u8, 1u64), (3, 2), (25, 3), (60, 4), (97, 5), (100, 6)] {
            let img = mask_u8(26, 33, density, seed);
            let rle = RleImage::from_view(&img).unwrap();
            for &(wx, wy) in &[(1usize, 1usize), (3, 3), (7, 3), (1, 9), (9, 1), (5, 7)] {
                let want_e = separable::morphology(&mut Native, &img, MorphOp::Erode, wx, wy, &cfg);
                let got_e = rle.erode(wx, wy).to_image();
                assert!(
                    got_e.same_pixels(&want_e),
                    "erode {wx}x{wy} d={density}: {:?}",
                    got_e.first_diff(&want_e)
                );
                let want_d = separable::morphology(&mut Native, &img, MorphOp::Dilate, wx, wy, &cfg);
                let got_d = rle.dilate(wx, wy).to_image();
                assert!(
                    got_d.same_pixels(&want_d),
                    "dilate {wx}x{wy} d={density}: {:?}",
                    got_d.first_diff(&want_d)
                );
            }
        }
    }

    #[test]
    fn interval_ops_match_dense_u16() {
        let cfg = seq_cfg();
        let mut rng = synth::Rng::new(42);
        let img = Image::<u16>::from_fn(17, 22, |_, _| {
            if rng.next_u64() % 10 < 3 {
                u16::MAX
            } else {
                0
            }
        });
        let rle = RleImage::from_view(&img).unwrap();
        for op in [MorphOp::Erode, MorphOp::Dilate] {
            let want = separable::morphology(&mut Native, &img, op, 5, 3, &cfg);
            let got = rle.apply(op, 5, 3).to_image();
            assert!(got.same_pixels(&want), "{op:?}: {:?}", got.first_diff(&want));
        }
    }

    #[test]
    fn degenerate_shapes_and_rows() {
        // empty image
        let empty = Image::<u8>::zeros(0, 5);
        let rle = RleImage::from_view(&empty).unwrap();
        assert_eq!(rle.erode(3, 3).to_image().pixels(), 0);
        // single 1-px run in an otherwise empty image
        let mut img = Image::<u8>::zeros(9, 9);
        img.set(4, 4, 255);
        let rle = RleImage::from_view(&img).unwrap();
        assert_eq!(rle.erode(3, 3).fg_pixels(), 0, "1-px run dies under 3x3 erosion");
        assert_eq!(rle.dilate(3, 3).fg_pixels(), 9, "1-px run grows to the SE footprint");
        // full-width runs survive erosion at the borders (identity pad)
        let full = Image::<u8>::from_fn(5, 8, |_, _| 255);
        let rle = RleImage::from_view(&full).unwrap();
        assert_eq!(rle.erode(5, 5).fg_pixels(), 40, "all-FG stays all-FG");
    }

    #[test]
    fn op_sequence_mirrors_plan_lowering() {
        use MorphOp::{Dilate as D, Erode as E};
        assert_eq!(rle_op_sequence(&[FilterOp::Erode]), Some(vec![E]));
        assert_eq!(rle_op_sequence(&[FilterOp::Open]), Some(vec![E, D]));
        assert_eq!(rle_op_sequence(&[FilterOp::Close]), Some(vec![D, E]));
        assert_eq!(
            rle_op_sequence(&[FilterOp::Open, FilterOp::Dilate]),
            Some(vec![E, D, D])
        );
        for dense_only in [
            FilterOp::Gradient,
            FilterOp::TopHat,
            FilterOp::BlackHat,
            FilterOp::Transpose,
        ] {
            assert_eq!(rle_op_sequence(&[dense_only]), None, "{dense_only:?}");
        }
    }

    #[test]
    fn strided_source_views_encode_correctly() {
        // encode a sub-rect view (stride > width) and compare against
        // the compacted copy
        let img = mask_u8(20, 30, 40, 0xACE);
        let view = img.view().sub_rect(3, 5, 10, 12);
        let rle = RleImage::from_view(view).unwrap();
        let compact = view.to_image();
        assert!(rle.to_image().same_pixels(&compact));
    }
}
