//! Service metrics: counters + log-bucketed latency histograms, all
//! lock-free (atomics) so the hot path never contends.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log2 latency buckets (ns): bucket i covers [2^i, 2^{i+1}).
const BUCKETS: usize = 48;

/// Lock-free histogram of nanosecond latencies with power-of-two
/// buckets.
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    sum_ns: AtomicU64,
    n: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            n: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, ns: u64) {
        let idx = (64 - ns.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.n.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate quantile (bucket upper bound), q in [0, 1].
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((n as f64) * q.clamp(0.0, 1.0)).ceil() as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }
}

/// Number of pipeline stages instrumented with depth/occupancy
/// counters, in flow order: ingress (validate/decode), plan-resolve,
/// banded-execute, reply.
pub const PIPELINE_STAGES: usize = 4;

/// Stage indices into the per-stage arrays.
pub const STAGE_INGRESS: usize = 0;
pub const STAGE_RESOLVE: usize = 1;
pub const STAGE_EXECUTE: usize = 2;
pub const STAGE_REPLY: usize = 3;

/// All service-level metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    /// Requests rejected by backpressure (queue full).
    pub shed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Native-engine plan-cache misses aggregated across workers: how
    /// often serving a request had to *resolve* a fresh
    /// [`crate::morphology::FilterPlan`].  Position-independent plans
    /// plus canonical cache keys push `plan_resolutions / completed`
    /// toward `distinct plan families / requests` — the
    /// `BENCH_serve.json` headline.
    pub plan_resolutions: AtomicU64,
    /// Native-engine plan-cache hits aggregated across workers.
    pub plan_hits: AtomicU64,
    /// Batches served through a fused super-pass: one banded execution
    /// spanning every image of a same-key batch
    /// ([`crate::morphology::FusedPlan`]).
    pub fused_batches: AtomicU64,
    /// Requests inside those fused batches.
    pub fused_requests: AtomicU64,
    pub queue_latency: Histogram,
    pub exec_latency: Histogram,
    pub total_latency: Histogram,
    /// Requests currently **in** each pipeline stage (entered, not yet
    /// handed to the next stage).  Bounded by the stage's channel
    /// capacity plus its worker count — the backpressure invariant the
    /// pipeline tests assert.
    pub stage_depth: [AtomicU64; PIPELINE_STAGES],
    /// High-water mark of `stage_depth` per stage.
    pub stage_peak: [AtomicU64; PIPELINE_STAGES],
    /// Inter-stage sends that found the downstream channel full and had
    /// to wait (the backpressure-propagation signal: non-zero under a
    /// saturating producer, zero when the pipeline keeps up).
    pub stage_blocked_sends: [AtomicU64; PIPELINE_STAGES],
}

impl Metrics {
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// A request entered stage `i`: bump the live depth and fold it
    /// into the stage's high-water mark.
    pub fn stage_enter(&self, i: usize) {
        let d = self.stage_depth[i].fetch_add(1, Ordering::Relaxed) + 1;
        self.stage_peak[i].fetch_max(d, Ordering::Relaxed);
    }

    /// A request left stage `i` (handed downstream or replied).
    pub fn stage_exit(&self, i: usize) {
        self.stage_depth[i].fetch_sub(1, Ordering::Relaxed);
    }

    /// Total requests currently inside the pipeline (sum of live stage
    /// depths).
    pub fn pipeline_depth(&self) -> u64 {
        self.stage_depth
            .iter()
            .map(|d| d.load(Ordering::Relaxed))
            .sum()
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            plan_resolutions: self.plan_resolutions.load(Ordering::Relaxed),
            plan_hits: self.plan_hits.load(Ordering::Relaxed),
            fused_batches: self.fused_batches.load(Ordering::Relaxed),
            fused_requests: self.fused_requests.load(Ordering::Relaxed),
            queue_p50_us: self.queue_latency.quantile_ns(0.5) as f64 / 1e3,
            queue_p99_us: self.queue_latency.quantile_ns(0.99) as f64 / 1e3,
            exec_p50_us: self.exec_latency.quantile_ns(0.5) as f64 / 1e3,
            exec_p99_us: self.exec_latency.quantile_ns(0.99) as f64 / 1e3,
            total_mean_us: self.total_latency.mean_ns() / 1e3,
            total_p50_us: self.total_latency.quantile_ns(0.5) as f64 / 1e3,
            total_p99_us: self.total_latency.quantile_ns(0.99) as f64 / 1e3,
            stage_depth: std::array::from_fn(|i| self.stage_depth[i].load(Ordering::Relaxed)),
            stage_peak: std::array::from_fn(|i| self.stage_peak[i].load(Ordering::Relaxed)),
            stage_blocked_sends: std::array::from_fn(|i| {
                self.stage_blocked_sends[i].load(Ordering::Relaxed)
            }),
        }
    }
}

/// Point-in-time metric values for reporting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub shed: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub plan_resolutions: u64,
    pub plan_hits: u64,
    pub fused_batches: u64,
    pub fused_requests: u64,
    pub queue_p50_us: f64,
    pub queue_p99_us: f64,
    pub exec_p50_us: f64,
    pub exec_p99_us: f64,
    pub total_mean_us: f64,
    pub total_p50_us: f64,
    pub total_p99_us: f64,
    /// Live per-stage depths at snapshot time (ingress, resolve,
    /// execute, reply).
    pub stage_depth: [u64; PIPELINE_STAGES],
    /// Per-stage depth high-water marks.
    pub stage_peak: [u64; PIPELINE_STAGES],
    /// Per-stage counts of downstream sends that had to wait on a full
    /// channel.
    pub stage_blocked_sends: [u64; PIPELINE_STAGES],
}

impl Snapshot {
    /// Mean requests per batch (batching effectiveness).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// Fresh plan resolutions per completed request — the streaming
    /// headline: near 0 when the plan cache and position-independent
    /// keys are doing their job, 1.0 when every request re-plans.
    pub fn plan_resolutions_per_request(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.plan_resolutions as f64 / self.completed as f64
        }
    }
}

impl std::fmt::Display for Snapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "submitted={} completed={} failed={} shed={} batches={} (mean size {:.2}) \
             fused batches/requests = {}/{} \
             plans resolved/hit = {}/{} ({:.4} resolutions/req) \
             queue p50/p99 = {:.0}/{:.0} µs, exec p50/p99 = {:.0}/{:.0} µs, \
             total mean/p50/p99 = {:.0}/{:.0}/{:.0} µs \
             stage peaks [in/res/exec/reply] = {:?} blocked sends = {:?}",
            self.submitted,
            self.completed,
            self.failed,
            self.shed,
            self.batches,
            self.mean_batch_size(),
            self.fused_batches,
            self.fused_requests,
            self.plan_resolutions,
            self.plan_hits,
            self.plan_resolutions_per_request(),
            self.queue_p50_us,
            self.queue_p99_us,
            self.exec_p50_us,
            self.exec_p99_us,
            self.total_mean_us,
            self.total_p50_us,
            self.total_p99_us,
            self.stage_peak,
            self.stage_blocked_sends,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_monotone() {
        let h = Histogram::default();
        for ns in [100u64, 200, 400, 800, 1600, 3200, 100_000] {
            h.record(ns);
        }
        assert_eq!(h.count(), 7);
        let p50 = h.quantile_ns(0.5);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= 400 && p50 <= 2048, "p50 bucket bound: {p50}");
    }

    #[test]
    fn histogram_mean() {
        let h = Histogram::default();
        h.record(1000);
        h.record(3000);
        assert!((h.mean_ns() - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn zero_latency_is_safe() {
        let h = Histogram::default();
        h.record(0);
        assert_eq!(h.count(), 1);
        assert!(h.quantile_ns(0.5) >= 1);
    }

    #[test]
    fn stage_depth_tracks_enter_exit_and_peak() {
        let m = Metrics::default();
        m.stage_enter(STAGE_EXECUTE);
        m.stage_enter(STAGE_EXECUTE);
        m.stage_enter(STAGE_INGRESS);
        assert_eq!(m.pipeline_depth(), 3);
        m.stage_exit(STAGE_EXECUTE);
        let s = m.snapshot();
        assert_eq!(s.stage_depth, [1, 0, 1, 0]);
        assert_eq!(s.stage_peak, [1, 0, 2, 0]);
        assert_eq!(s.stage_blocked_sends, [0; PIPELINE_STAGES]);
        m.stage_exit(STAGE_EXECUTE);
        m.stage_exit(STAGE_INGRESS);
        assert_eq!(m.pipeline_depth(), 0);
        // peaks are sticky
        assert_eq!(m.snapshot().stage_peak[STAGE_EXECUTE], 2);
    }

    #[test]
    fn snapshot_batch_size() {
        let m = Metrics::default();
        m.batches.store(4, Ordering::Relaxed);
        m.batched_requests.store(10, Ordering::Relaxed);
        assert!((m.snapshot().mean_batch_size() - 2.5).abs() < 1e-12);
    }
}
