//! Request/response types of the filtering service.
//!
//! Since the plan–execute redesign a request carries a full
//! [`FilterSpec`] (op chain + window + configuration + optional ROI)
//! and a depth-tagged payload ([`ImagePayload`]): the same service
//! filters `u8` and `u16` images through **one** depth-erased
//! [`super::Coordinator::submit`].
//!
//! ## Batch keys
//!
//! Requests are grouped by the typed [`BatchKey`] — `Copy`/`Eq`/`Hash`
//! with **no per-submit heap allocation** (the PR-1..3 era key was a
//! formatted `String` built on every push/pull).  Two requests share a
//! key iff they would run the same resolved plan family:
//!
//! * pixel depth (a u8 batch and a u16 batch never mix — different
//!   SIMD lane widths / compiled executables),
//! * image shape,
//! * op chain + window,
//! * configuration (method/vertical/simd/border/thresholds/parallelism),
//! * ROI **shape** (not position) — server-side ROI batching groups
//!   same-size crops from document pipelines even when they land at
//!   different offsets; the engine's plan cache keys on the full spec,
//!   so clamped edge blocks still resolve their own plans.

use std::fmt;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::image::Image;
use crate::morphology::{FilterSpec, MorphPixel};

/// Pixel depth of a request payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PixelDepth {
    U8,
    U16,
}

impl PixelDepth {
    /// dtype tag used in batch keys and artifact manifests (sourced
    /// from [`MorphPixel::DTYPE`] — single point of truth).
    pub fn dtype(self) -> &'static str {
        match self {
            PixelDepth::U8 => <u8 as MorphPixel>::DTYPE,
            PixelDepth::U16 => <u16 as MorphPixel>::DTYPE,
        }
    }

    /// SIMD lanes of one 128-bit op at this depth (sourced from
    /// [`MorphPixel::LANES`]).
    pub fn lanes(self) -> usize {
        match self {
            PixelDepth::U8 => <u8 as MorphPixel>::LANES,
            PixelDepth::U16 => <u16 as MorphPixel>::LANES,
        }
    }
}

/// Shared, zero-copy input image at either pixel depth.
#[derive(Clone, Debug)]
pub enum ImagePayload {
    U8(Arc<Image<u8>>),
    U16(Arc<Image<u16>>),
}

impl ImagePayload {
    pub fn height(&self) -> usize {
        match self {
            ImagePayload::U8(img) => img.height(),
            ImagePayload::U16(img) => img.height(),
        }
    }

    pub fn width(&self) -> usize {
        match self {
            ImagePayload::U8(img) => img.width(),
            ImagePayload::U16(img) => img.width(),
        }
    }

    pub fn depth(&self) -> PixelDepth {
        match self {
            ImagePayload::U8(_) => PixelDepth::U8,
            ImagePayload::U16(_) => PixelDepth::U16,
        }
    }

    pub fn dtype(&self) -> &'static str {
        self.depth().dtype()
    }
}

impl From<Arc<Image<u8>>> for ImagePayload {
    fn from(img: Arc<Image<u8>>) -> Self {
        ImagePayload::U8(img)
    }
}

impl From<Arc<Image<u16>>> for ImagePayload {
    fn from(img: Arc<Image<u16>>) -> Self {
        ImagePayload::U16(img)
    }
}

/// Typed batching key — see the module docs for the grouping contract.
/// `Copy` and heap-free: pushing, pulling and worker affinity never
/// allocate (pinned by the allocation-counter test in
/// `rust/tests/zero_copy_alloc.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub depth: PixelDepth,
    pub height: usize,
    pub width: usize,
    pub spec_shape: SpecShape,
}

/// The spec portion of a [`BatchKey`]: everything of a [`FilterSpec`]
/// except the ROI *position*.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SpecShape {
    pub ops: crate::morphology::OpChain,
    pub w_x: usize,
    pub w_y: usize,
    pub config: crate::morphology::MorphConfig,
    /// `(height, width)` of the ROI, if any.
    pub roi_shape: Option<(usize, usize)>,
}

impl BatchKey {
    /// Key for `spec` applied to an `height × width` image at `depth`.
    pub fn of(spec: &FilterSpec, depth: PixelDepth, height: usize, width: usize) -> BatchKey {
        BatchKey {
            depth,
            height,
            width,
            spec_shape: SpecShape {
                ops: spec.ops,
                w_x: spec.w_x,
                w_y: spec.w_y,
                config: spec.config,
                roi_shape: spec.roi.map(|r| (r.height, r.width)),
            },
        }
    }
}

impl fmt::Display for BatchKey {
    /// Legacy-shaped rendering for logs/metrics:
    /// `erode:u8:600x800:w5x3` (+ `:roiHxW` when present).  Display is
    /// for humans only — grouping always uses the typed key.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}:{}x{}:w{}x{}",
            self.spec_shape.ops,
            self.depth.dtype(),
            self.height,
            self.width,
            self.spec_shape.w_x,
            self.spec_shape.w_y
        )?;
        if let Some((h, w)) = self.spec_shape.roi_shape {
            write!(f, ":roi{h}x{w}")?;
        }
        Ok(())
    }
}

/// A filtering request: apply `spec` to `image`.
#[derive(Clone, Debug)]
pub struct FilterRequest {
    pub id: u64,
    /// Full pipeline description (op chain, window, config, ROI).
    pub spec: FilterSpec,
    /// Shared, zero-copy, depth-tagged input image.  For
    /// [`FilterOp::Reconstruct`](crate::morphology::FilterOp) specs this
    /// is the geodesic **mask** (the clamp bound).
    pub image: ImagePayload,
    /// Second payload of a reconstruct spec: the marker to propagate
    /// under `image`.  Must match `image` in depth and shape; required
    /// iff the spec is a reconstruct (validated at ingress).
    pub marker: Option<ImagePayload>,
    pub enqueued: Instant,
}

impl FilterRequest {
    /// Batching key: requests with the same key run the same compiled
    /// executable / resolved plan family, so grouping them maximizes
    /// executable- and plan-cache affinity.
    pub fn batch_key(&self) -> BatchKey {
        BatchKey::of(
            &self.spec,
            self.image.depth(),
            self.image.height(),
            self.image.width(),
        )
    }
}

/// A completed request's image result, depth-tagged.
#[derive(Clone, Debug)]
pub enum FilterOutput {
    U8(Image<u8>),
    U16(Image<u16>),
}

impl FilterOutput {
    pub fn dtype(&self) -> &'static str {
        match self {
            FilterOutput::U8(_) => PixelDepth::U8.dtype(),
            FilterOutput::U16(_) => PixelDepth::U16.dtype(),
        }
    }

    pub fn dims(&self) -> (usize, usize) {
        match self {
            FilterOutput::U8(img) => (img.height(), img.width()),
            FilterOutput::U16(img) => (img.height(), img.width()),
        }
    }

    /// Unwrap a u8 result, or report the actual depth as an error
    /// (submitting a u8 payload always yields u8, so a mismatch means
    /// the caller mixed tickets up).
    pub fn into_u8(self) -> anyhow::Result<Image<u8>> {
        match self {
            FilterOutput::U8(img) => Ok(img),
            FilterOutput::U16(_) => Err(anyhow::anyhow!("u16 response where u8 was expected")),
        }
    }

    /// Unwrap a u16 result, or report the actual depth as an error.
    pub fn into_u16(self) -> anyhow::Result<Image<u16>> {
        match self {
            FilterOutput::U16(img) => Ok(img),
            FilterOutput::U8(_) => Err(anyhow::anyhow!("u8 response where u16 was expected")),
        }
    }

}

/// Completed request.
#[derive(Debug)]
pub struct FilterResponse {
    pub id: u64,
    pub result: anyhow::Result<FilterOutput>,
    /// Time spent queued before a worker picked the request up.
    pub queue_ns: u64,
    /// Execution time inside the engine.
    pub exec_ns: u64,
    /// Which engine ran it ("xla-pjrt" or "native").
    pub backend: &'static str,
    /// Worker that executed the request.
    pub worker: usize,
}

/// A submitted request paired with its response channel.
pub(crate) struct Pending {
    pub req: FilterRequest,
    pub reply: mpsc::Sender<FilterResponse>,
}

/// Ticket returned by `submit`: await the response.
pub struct Ticket {
    pub id: u64,
    pub(crate) rx: mpsc::Receiver<FilterResponse>,
}

impl Ticket {
    /// Block until the response arrives.
    pub fn wait(self) -> anyhow::Result<FilterResponse> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator dropped request {}", self.id))
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<FilterResponse> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::morphology::{FilterOp, MorphConfig, Roi, VerticalStrategy};

    fn mk(spec: FilterSpec, image: ImagePayload) -> FilterRequest {
        FilterRequest {
            id: 0,
            spec,
            image,
            marker: None,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn batch_key_groups_identical_work() {
        let img = Arc::new(synth::noise(10, 12, 1));
        let key = |spec: FilterSpec| mk(spec, img.clone().into()).batch_key();
        let e33 = FilterSpec::new(FilterOp::Erode, 3, 3);
        assert_eq!(key(e33), key(e33));
        assert_ne!(key(e33), key(FilterSpec::new(FilterOp::Erode, 5, 3)));
        assert_ne!(key(e33), key(FilterSpec::new(FilterOp::Dilate, 3, 3)));
        // config is part of the key: a different vertical strategy is a
        // different plan family
        let mut cfg = MorphConfig::default();
        cfg.vertical = VerticalStrategy::Transpose;
        assert_ne!(key(e33), key(e33.with_config(cfg)));
        // chains key differently from their heads
        assert_ne!(key(e33), key(e33.then(FilterOp::Dilate)));
    }

    #[test]
    fn batch_key_groups_roi_by_shape_not_position() {
        let img = Arc::new(synth::noise(32, 32, 1));
        let key = |spec: FilterSpec| mk(spec, img.clone().into()).batch_key();
        let base = FilterSpec::new(FilterOp::Erode, 3, 3);
        let a = base.with_roi(Roi::new(0, 0, 8, 10));
        let b = base.with_roi(Roi::new(12, 9, 8, 10));
        let c = base.with_roi(Roi::new(0, 0, 8, 11));
        assert_eq!(key(a), key(b), "same ROI shape must batch together");
        assert_ne!(key(a), key(c), "different ROI shape must not");
        assert_ne!(key(a), key(base), "ROI and full-image must not mix");
    }

    #[test]
    fn batch_key_separates_depths() {
        let img8 = Arc::new(synth::noise(10, 12, 1));
        let img16 = Arc::new(synth::noise_u16(10, 12, 1));
        let spec = FilterSpec::new(FilterOp::Erode, 3, 3);
        let k8 = mk(spec, img8.into()).batch_key();
        let k16 = mk(spec, img16.into()).batch_key();
        assert_ne!(k8, k16, "depth must be part of the batch key");
        assert!(format!("{k8}").contains(":u8:"), "{k8}");
        assert!(format!("{k16}").contains(":u16:"), "{k16}");
    }

    #[test]
    fn batch_key_display_is_legacy_shaped() {
        let img = Arc::new(synth::noise(10, 12, 1));
        let k = mk(FilterSpec::new(FilterOp::Erode, 5, 3), img.clone().into()).batch_key();
        assert_eq!(format!("{k}"), "erode:u8:10x12:w5x3");
        let kr = mk(
            FilterSpec::new(FilterOp::TopHat, 3, 3).with_roi(Roi::new(1, 2, 4, 5)),
            img.into(),
        )
        .batch_key();
        assert_eq!(format!("{kr}"), "tophat:u8:10x12:w3x3:roi4x5");
    }

    #[test]
    fn payload_reports_depth_and_dims() {
        let p: ImagePayload = Arc::new(synth::noise_u16(5, 7, 2)).into();
        assert_eq!(p.depth(), PixelDepth::U16);
        assert_eq!((p.height(), p.width()), (5, 7));
        assert_eq!(p.dtype(), "u16");
        assert_eq!(PixelDepth::U8.lanes(), 16);
        assert_eq!(PixelDepth::U16.lanes(), 8);
    }

    #[test]
    fn output_unwrappers() {
        let o = FilterOutput::U8(synth::noise(3, 4, 1));
        assert_eq!(o.dtype(), "u8");
        assert_eq!(o.dims(), (3, 4));
        let img = o.into_u8().unwrap();
        assert_eq!(img.height(), 3);
        let o16 = FilterOutput::U16(synth::noise_u16(3, 4, 1));
        assert_eq!(o16.into_u16().unwrap().width(), 4);
        // mismatches error instead of panicking
        assert!(FilterOutput::U8(synth::noise(3, 4, 1)).into_u16().is_err());
        assert!(FilterOutput::U16(synth::noise_u16(3, 4, 1)).into_u8().is_err());
    }

}
