//! Request/response types of the filtering service.
//!
//! Requests carry a depth-tagged payload ([`ImagePayload`]): the same
//! service filters `u8` and `u16` images, and the batch key includes the
//! dtype so a batch never mixes depths (different depths run different
//! compiled executables / kernel instantiations).

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::image::Image;
use crate::morphology::MorphPixel;

/// Pixel depth of a request payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PixelDepth {
    U8,
    U16,
}

impl PixelDepth {
    /// dtype tag used in batch keys and artifact manifests (sourced
    /// from [`MorphPixel::DTYPE`] — single point of truth).
    pub fn dtype(self) -> &'static str {
        match self {
            PixelDepth::U8 => <u8 as MorphPixel>::DTYPE,
            PixelDepth::U16 => <u16 as MorphPixel>::DTYPE,
        }
    }

    /// SIMD lanes of one 128-bit op at this depth (sourced from
    /// [`MorphPixel::LANES`]).
    pub fn lanes(self) -> usize {
        match self {
            PixelDepth::U8 => <u8 as MorphPixel>::LANES,
            PixelDepth::U16 => <u16 as MorphPixel>::LANES,
        }
    }
}

/// Shared, zero-copy input image at either pixel depth.
#[derive(Clone, Debug)]
pub enum ImagePayload {
    U8(Arc<Image<u8>>),
    U16(Arc<Image<u16>>),
}

impl ImagePayload {
    pub fn height(&self) -> usize {
        match self {
            ImagePayload::U8(img) => img.height(),
            ImagePayload::U16(img) => img.height(),
        }
    }

    pub fn width(&self) -> usize {
        match self {
            ImagePayload::U8(img) => img.width(),
            ImagePayload::U16(img) => img.width(),
        }
    }

    pub fn depth(&self) -> PixelDepth {
        match self {
            ImagePayload::U8(_) => PixelDepth::U8,
            ImagePayload::U16(_) => PixelDepth::U16,
        }
    }

    pub fn dtype(&self) -> &'static str {
        self.depth().dtype()
    }
}

impl From<Arc<Image<u8>>> for ImagePayload {
    fn from(img: Arc<Image<u8>>) -> Self {
        ImagePayload::U8(img)
    }
}

impl From<Arc<Image<u16>>> for ImagePayload {
    fn from(img: Arc<Image<u16>>) -> Self {
        ImagePayload::U16(img)
    }
}

/// A filtering request: apply `op` with a `w_x × w_y` SE to `image`.
#[derive(Clone, Debug)]
pub struct FilterRequest {
    pub id: u64,
    /// erode / dilate / opening / closing / gradient / tophat /
    /// blackhat / transpose.
    pub op: String,
    pub w_x: usize,
    pub w_y: usize,
    /// Shared, zero-copy, depth-tagged input image.
    pub image: ImagePayload,
    pub enqueued: Instant,
}

impl FilterRequest {
    /// Batching key: requests with the same key run the same compiled
    /// executable (same op, dtype, shape and window), so grouping them
    /// maximizes executable-cache affinity.  Depth is part of the key —
    /// a u8 batch and a u16 batch never mix.
    pub fn batch_key(&self) -> String {
        format!(
            "{}:{}:{}x{}:w{}x{}",
            self.op,
            self.image.dtype(),
            self.image.height(),
            self.image.width(),
            self.w_x,
            self.w_y
        )
    }
}

/// A completed request's image result, depth-tagged.
#[derive(Clone, Debug)]
pub enum FilterOutput {
    U8(Image<u8>),
    U16(Image<u16>),
}

impl FilterOutput {
    pub fn dtype(&self) -> &'static str {
        match self {
            FilterOutput::U8(_) => PixelDepth::U8.dtype(),
            FilterOutput::U16(_) => PixelDepth::U16.dtype(),
        }
    }

    pub fn dims(&self) -> (usize, usize) {
        match self {
            FilterOutput::U8(img) => (img.height(), img.width()),
            FilterOutput::U16(img) => (img.height(), img.width()),
        }
    }

    /// Unwrap a u8 result; panics on a u16 payload (submitting u8 always
    /// yields u8).
    pub fn expect_u8(self) -> Image<u8> {
        match self {
            FilterOutput::U8(img) => img,
            FilterOutput::U16(_) => panic!("u16 response where u8 was expected"),
        }
    }

    /// Unwrap a u16 result; panics on a u8 payload.
    pub fn expect_u16(self) -> Image<u16> {
        match self {
            FilterOutput::U16(img) => img,
            FilterOutput::U8(_) => panic!("u8 response where u16 was expected"),
        }
    }
}

/// Completed request.
#[derive(Debug)]
pub struct FilterResponse {
    pub id: u64,
    pub result: anyhow::Result<FilterOutput>,
    /// Time spent queued before a worker picked the request up.
    pub queue_ns: u64,
    /// Execution time inside the engine.
    pub exec_ns: u64,
    /// Which engine ran it ("xla-pjrt" or "native").
    pub backend: &'static str,
    /// Worker that executed the request.
    pub worker: usize,
}

/// A submitted request paired with its response channel.
pub(crate) struct Pending {
    pub req: FilterRequest,
    pub reply: mpsc::Sender<FilterResponse>,
}

/// Ticket returned by `submit`: await the response.
pub struct Ticket {
    pub id: u64,
    pub(crate) rx: mpsc::Receiver<FilterResponse>,
}

impl Ticket {
    /// Block until the response arrives.
    pub fn wait(self) -> anyhow::Result<FilterResponse> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator dropped request {}", self.id))
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<FilterResponse> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;

    #[test]
    fn batch_key_groups_identical_work() {
        let img = Arc::new(synth::noise(10, 12, 1));
        let mk = |op: &str, wx, wy| FilterRequest {
            id: 0,
            op: op.into(),
            w_x: wx,
            w_y: wy,
            image: img.clone().into(),
            enqueued: Instant::now(),
        };
        assert_eq!(mk("erode", 3, 3).batch_key(), mk("erode", 3, 3).batch_key());
        assert_ne!(mk("erode", 3, 3).batch_key(), mk("erode", 5, 3).batch_key());
        assert_ne!(mk("erode", 3, 3).batch_key(), mk("dilate", 3, 3).batch_key());
    }

    #[test]
    fn batch_key_separates_depths() {
        let img8 = Arc::new(synth::noise(10, 12, 1));
        let img16 = Arc::new(synth::noise_u16(10, 12, 1));
        let mk = |image: ImagePayload| FilterRequest {
            id: 0,
            op: "erode".into(),
            w_x: 3,
            w_y: 3,
            image,
            enqueued: Instant::now(),
        };
        let k8 = mk(img8.into()).batch_key();
        let k16 = mk(img16.into()).batch_key();
        assert_ne!(k8, k16, "depth must be part of the batch key");
        assert!(k8.contains(":u8:"), "{k8}");
        assert!(k16.contains(":u16:"), "{k16}");
    }

    #[test]
    fn payload_reports_depth_and_dims() {
        let p: ImagePayload = Arc::new(synth::noise_u16(5, 7, 2)).into();
        assert_eq!(p.depth(), PixelDepth::U16);
        assert_eq!((p.height(), p.width()), (5, 7));
        assert_eq!(p.dtype(), "u16");
        assert_eq!(PixelDepth::U8.lanes(), 16);
        assert_eq!(PixelDepth::U16.lanes(), 8);
    }

    #[test]
    fn output_unwrappers() {
        let o = FilterOutput::U8(synth::noise(3, 4, 1));
        assert_eq!(o.dtype(), "u8");
        assert_eq!(o.dims(), (3, 4));
        let img = o.expect_u8();
        assert_eq!(img.height(), 3);
        let o16 = FilterOutput::U16(synth::noise_u16(3, 4, 1));
        assert_eq!(o16.expect_u16().width(), 4);
    }
}
