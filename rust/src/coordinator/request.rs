//! Request/response types of the filtering service.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::image::Image;

/// A filtering request: apply `op` with a `w_x × w_y` SE to `image`.
#[derive(Clone, Debug)]
pub struct FilterRequest {
    pub id: u64,
    /// erode / dilate / opening / closing / gradient / tophat /
    /// blackhat / transpose.
    pub op: String,
    pub w_x: usize,
    pub w_y: usize,
    /// Shared, zero-copy input image.
    pub image: Arc<Image<u8>>,
    pub enqueued: Instant,
}

impl FilterRequest {
    /// Batching key: requests with the same key run the same compiled
    /// executable (same op, shape and window), so grouping them
    /// maximizes executable-cache affinity.
    pub fn batch_key(&self) -> String {
        format!(
            "{}:{}x{}:w{}x{}",
            self.op,
            self.image.height(),
            self.image.width(),
            self.w_x,
            self.w_y
        )
    }
}

/// Completed request.
#[derive(Debug)]
pub struct FilterResponse {
    pub id: u64,
    pub result: anyhow::Result<Image<u8>>,
    /// Time spent queued before a worker picked the request up.
    pub queue_ns: u64,
    /// Execution time inside the engine.
    pub exec_ns: u64,
    /// Which engine ran it ("xla-pjrt" or "native").
    pub backend: &'static str,
    /// Worker that executed the request.
    pub worker: usize,
}

/// A submitted request paired with its response channel.
pub(crate) struct Pending {
    pub req: FilterRequest,
    pub reply: mpsc::Sender<FilterResponse>,
}

/// Ticket returned by `submit`: await the response.
pub struct Ticket {
    pub id: u64,
    pub(crate) rx: mpsc::Receiver<FilterResponse>,
}

impl Ticket {
    /// Block until the response arrives.
    pub fn wait(self) -> anyhow::Result<FilterResponse> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator dropped request {}", self.id))
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<FilterResponse> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;

    #[test]
    fn batch_key_groups_identical_work() {
        let img = Arc::new(synth::noise(10, 12, 1));
        let mk = |op: &str, wx, wy| FilterRequest {
            id: 0,
            op: op.into(),
            w_x: wx,
            w_y: wy,
            image: img.clone(),
            enqueued: Instant::now(),
        };
        assert_eq!(mk("erode", 3, 3).batch_key(), mk("erode", 3, 3).batch_key());
        assert_ne!(mk("erode", 3, 3).batch_key(), mk("erode", 5, 3).batch_key());
        assert_ne!(mk("erode", 3, 3).batch_key(), mk("dilate", 3, 3).batch_key());
    }
}
