//! L3 coordinator: the morphology filtering service.
//!
//! Architecture (std threads; the offline build has no tokio, and the
//! PJRT CPU client is synchronous anyway):
//!
//! ```text
//!  submit() ──► BatchQueue (bounded, key-grouped)  ──► worker 0 ─► reply
//!     │               │  backpressure: reject when full  worker 1 ─► reply
//!     └─ Ticket ◄─────┘  batches keyed by (op, shape, w) ...
//! ```
//!
//! Each worker owns its engines — an optional [`XlaRuntime`] (PJRT,
//! executing the python-AOT artifacts; `PjRtLoadedExecutable` is not
//! `Sync`, so runtimes are never shared) and a [`NativeEngine`]
//! (pure-rust §5.3 hybrid morphology).  The **router** picks per
//! request: an artifact match on the XLA backend when available, native
//! otherwise (or as directed by [`BackendChoice`]).

pub mod metrics;
pub mod queue;
pub mod request;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::image::Image;
use crate::morphology::MorphConfig;
use crate::runtime::{ArtifactMeta, Engine, Manifest, NativeEngine, XlaRuntime};
use metrics::{Metrics, Snapshot};
use queue::{BatchQueue, Pull};
use request::{FilterRequest, FilterResponse, Pending, Ticket};

/// Which engine(s) the router may use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// XLA for shapes with artifacts, native for everything else.
    Auto,
    /// Never touch PJRT (no artifacts needed).
    NativeOnly,
    /// Only run requests that have a compiled artifact; others fail.
    XlaOnly,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Bound on queued requests (backpressure limit).
    pub queue_capacity: usize,
    /// Max same-key requests a worker takes per pull.
    pub max_batch: usize,
    pub backend: BackendChoice,
    /// Artifact directory (required unless `NativeOnly`).
    pub artifact_dir: Option<PathBuf>,
    /// Configuration of the native engine.
    pub morph: MorphConfig,
    /// Compile all artifacts at startup instead of lazily.
    pub precompile: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: std::thread::available_parallelism().map_or(2, |n| n.get().min(8)),
            queue_capacity: 1024,
            max_batch: 16,
            backend: BackendChoice::Auto,
            artifact_dir: Some(PathBuf::from("artifacts")),
            morph: MorphConfig::default(),
            precompile: false,
        }
    }
}

/// The running service.
pub struct Coordinator {
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    manifest: Option<Arc<Manifest>>,
    next_id: AtomicU64,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn workers and return the running coordinator.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let manifest = match (&cfg.backend, &cfg.artifact_dir) {
            (BackendChoice::NativeOnly, _) => None,
            (_, Some(dir)) => match Manifest::load(dir) {
                Ok(m) => Some(Arc::new(m)),
                Err(e) if cfg.backend == BackendChoice::XlaOnly => {
                    return Err(e.context("XlaOnly backend requires artifacts"));
                }
                Err(_) => None, // Auto degrades to native
            },
            (BackendChoice::XlaOnly, None) => {
                return Err(anyhow!("XlaOnly backend requires artifact_dir"));
            }
            (_, None) => None,
        };

        let queue = Arc::new(BatchQueue::new(cfg.queue_capacity, cfg.max_batch));
        let metrics = Arc::new(Metrics::default());
        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let manifest = manifest.clone();
            let cfg = cfg.clone();
            let handle = std::thread::Builder::new()
                .name(format!("morph-worker-{wid}"))
                .spawn(move || worker_loop(wid, &cfg, manifest, &queue, &metrics))
                .context("spawning worker")?;
            workers.push(handle);
        }
        Ok(Coordinator {
            queue,
            metrics,
            manifest,
            next_id: AtomicU64::new(1),
            workers,
        })
    }

    /// Convenience: start with defaults and `NativeOnly` backend.
    pub fn start_native(workers: usize) -> Result<Coordinator> {
        Coordinator::start(CoordinatorConfig {
            workers,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            ..CoordinatorConfig::default()
        })
    }

    /// Submit a request.  Fails fast when the queue is full
    /// (backpressure) or closed.
    pub fn submit(
        &self,
        op: &str,
        w_x: usize,
        w_y: usize,
        image: Arc<Image<u8>>,
    ) -> Result<Ticket> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let pending = Pending {
            req: FilterRequest {
                id,
                op: op.to_string(),
                w_x,
                w_y,
                image,
                enqueued: Instant::now(),
            },
            reply: tx,
        };
        match self.queue.push(pending) {
            Ok(()) => {
                Metrics::inc(&self.metrics.submitted);
                Ok(Ticket { id, rx })
            }
            Err(_) => {
                Metrics::inc(&self.metrics.shed);
                Err(anyhow!("queue full: request shed (backpressure)"))
            }
        }
    }

    /// Submit and block for the result.
    pub fn filter(
        &self,
        op: &str,
        w_x: usize,
        w_y: usize,
        image: Arc<Image<u8>>,
    ) -> Result<FilterResponse> {
        self.submit(op, w_x, w_y, image)?.wait()
    }

    pub fn metrics(&self) -> Snapshot {
        self.metrics.snapshot()
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_deref()
    }

    /// Close the queue, drain and join workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Build the native-path artifact description for a request with no
/// compiled artifact.
fn synthetic_meta(req: &FilterRequest) -> ArtifactMeta {
    let (h, w) = (req.image.height(), req.image.width());
    ArtifactMeta {
        name: req.batch_key(),
        kind: if req.op == "transpose" {
            "transpose".into()
        } else {
            "morphology".into()
        },
        op: req.op.clone(),
        height: h,
        width: w,
        w_x: req.w_x,
        w_y: req.w_y,
        method: "hybrid".into(),
        vertical: "transpose".into(),
        dtype: "u8".into(),
        file: String::new(),
        out_shape: if req.op == "transpose" { (w, h) } else { (h, w) },
    }
}

fn worker_loop(
    wid: usize,
    cfg: &CoordinatorConfig,
    manifest: Option<Arc<Manifest>>,
    queue: &BatchQueue,
    metrics: &Metrics,
) {
    let mut native = NativeEngine::new(cfg.morph);
    let mut xla: Option<XlaRuntime> = match (&cfg.backend, &cfg.artifact_dir, &manifest) {
        (BackendChoice::NativeOnly, _, _) | (_, _, None) => None,
        (_, Some(dir), Some(_)) => XlaRuntime::new(dir).ok(),
        (_, None, _) => None,
    };
    if cfg.precompile {
        if let Some(rt) = xla.as_mut() {
            let _ = rt.precompile(|_| true);
        }
    }

    let mut affinity: Option<String> = None;
    loop {
        match queue.pull(affinity.as_deref(), Duration::from_millis(100)) {
            Pull::Closed => break,
            Pull::Batch(batch) => {
                Metrics::inc(&metrics.batches);
                metrics
                    .batched_requests
                    .fetch_add(batch.len() as u64, Ordering::Relaxed);
                affinity = batch.first().map(|p| p.req.batch_key());
                for p in batch {
                    serve_one(wid, cfg, &manifest, &mut native, &mut xla, metrics, p);
                }
            }
        }
    }
}

fn serve_one(
    wid: usize,
    cfg: &CoordinatorConfig,
    manifest: &Option<Arc<Manifest>>,
    native: &mut NativeEngine,
    xla: &mut Option<XlaRuntime>,
    metrics: &Metrics,
    p: Pending,
) {
    let queue_ns = p.req.enqueued.elapsed().as_nanos() as u64;
    let (h, w) = (p.req.image.height(), p.req.image.width());
    let compiled = manifest
        .as_ref()
        .and_then(|m| m.find(&p.req.op, h, w, p.req.w_x, p.req.w_y).cloned());

    let t = Instant::now();
    let (result, backend): (Result<Image<u8>>, &'static str) =
        if cfg.backend == BackendChoice::XlaOnly {
            match (compiled, xla.as_mut()) {
                (Some(meta), Some(rt)) => (rt.run(&meta, &p.req.image), rt.backend_name()),
                (None, _) => (
                    Err(anyhow!("no artifact for {} (XlaOnly backend)", p.req.batch_key())),
                    "xla-pjrt",
                ),
                (Some(_), None) => (
                    Err(anyhow!("XLA runtime unavailable on worker {wid}")),
                    "xla-pjrt",
                ),
            }
        } else if let (Some(meta), Some(rt)) = (compiled.as_ref(), xla.as_mut()) {
            match rt.run(meta, &p.req.image) {
                // Auto: degrade to native on runtime errors
                Err(_) => (
                    native.run(&synthetic_meta(&p.req), &p.req.image),
                    native.backend_name(),
                ),
                ok => (ok, rt.backend_name()),
            }
        } else {
            (
                native.run(&synthetic_meta(&p.req), &p.req.image),
                native.backend_name(),
            )
        };
    let exec_ns = t.elapsed().as_nanos() as u64;

    metrics.queue_latency.record(queue_ns);
    metrics.exec_latency.record(exec_ns);
    metrics.total_latency.record(queue_ns + exec_ns);
    if result.is_ok() {
        Metrics::inc(&metrics.completed);
    } else {
        Metrics::inc(&metrics.failed);
    }
    // receiver may have given up; dropping the response is fine
    let _ = p.reply.send(FilterResponse {
        id: p.req.id,
        result,
        queue_ns,
        exec_ns,
        backend,
        worker: wid,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::morphology;
    use crate::neon::Native;

    #[test]
    fn native_coordinator_round_trip() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(32, 48, 5));
        let resp = coord.filter("erode", 5, 3, img.clone()).unwrap();
        assert_eq!(resp.backend, "native");
        let want = morphology::erode(&img, 5, 3);
        assert!(resp.result.unwrap().same_pixels(&want));
        let snap = coord.metrics();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let coord = Coordinator::start_native(4).unwrap();
        let img = Arc::new(synth::noise(24, 24, 6));
        let tickets: Vec<_> = (0..40)
            .map(|i| {
                let op = if i % 2 == 0 { "erode" } else { "dilate" };
                coord.submit(op, 3, 3, img.clone()).unwrap()
            })
            .collect();
        for t in tickets {
            let r = t.wait().unwrap();
            assert!(r.result.is_ok());
        }
        let snap = coord.metrics();
        assert_eq!(snap.completed, 40);
        assert!(snap.batches <= 40);
        coord.shutdown();
    }

    #[test]
    fn unknown_op_fails_cleanly() {
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise(8, 8, 2));
        let resp = coord.filter("sharpen", 3, 3, img).unwrap();
        assert!(resp.result.is_err());
        assert_eq!(coord.metrics().failed, 1);
        coord.shutdown();
    }

    #[test]
    fn backpressure_sheds_when_overloaded() {
        // 1 worker, tiny queue, many submissions of slow-ish work
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            queue_capacity: 2,
            max_batch: 1,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            morph: MorphConfig::default(),
            precompile: false,
        })
        .unwrap();
        let img = Arc::new(synth::paper_image(3));
        let mut shed = 0;
        let mut tickets = Vec::new();
        for _ in 0..64 {
            match coord.submit("opening", 15, 15, img.clone()) {
                Ok(t) => tickets.push(t),
                Err(_) => shed += 1,
            }
        }
        assert!(shed > 0, "expected at least one shed under overload");
        assert_eq!(coord.metrics().shed, shed);
        for t in tickets {
            assert!(t.wait().unwrap().result.is_ok());
        }
        coord.shutdown();
    }

    #[test]
    fn transpose_request_swaps_dims() {
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise(10, 20, 8));
        let out = coord.filter("transpose", 0, 0, img.clone()).unwrap().result.unwrap();
        assert_eq!((out.height(), out.width()), (20, 10));
        let want = crate::transpose::transpose_image(&mut Native, &img);
        assert!(out.same_pixels(&want));
        coord.shutdown();
    }

    #[test]
    fn drop_shuts_down_workers() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(8, 8, 1));
        let _ = coord.filter("erode", 3, 3, img);
        drop(coord); // must not hang
    }
}
