//! L3 coordinator: the morphology filtering service.
//!
//! Architecture (std threads; the offline build has no tokio, and the
//! PJRT CPU client is synchronous anyway):
//!
//! ```text
//!  submit() ──► BatchQueue (bounded, key-grouped)  ──► worker 0 ─► reply
//!     │               │  backpressure: reject when full  worker 1 ─► reply
//!     └─ Ticket ◄─────┘  batches keyed by (op, dtype, shape, w) ...
//! ```
//!
//! Each worker owns its engines — an optional [`XlaRuntime`] (PJRT,
//! executing the python-AOT artifacts; `PjRtLoadedExecutable` is not
//! `Sync`, so runtimes are never shared) and a [`NativeEngine`]
//! (pure-rust §5.3 hybrid morphology).  The **router** picks per
//! request: an artifact match on the XLA backend when available, native
//! otherwise (or as directed by [`BackendChoice`]).
//!
//! Depth routing: requests carry a depth-tagged
//! [`request::ImagePayload`] (`u8` or `u16`); batch keys include the
//! dtype so batches never mix depths.  AOT artifacts exist only for
//! `u8`, so u16 requests always execute on the native engine (and fail
//! under [`BackendChoice::XlaOnly`]).
//!
//! Intra-image parallelism: native executions band-shard large images
//! across the process-wide
//! [`crate::morphology::parallel::BandPool`] (policy:
//! `CoordinatorConfig::morph.parallelism`, default `Auto` — the cost
//! model keeps small requests sequential).  Coordinator workers and
//! band jobs share that one pool, so serving many small requests and
//! splitting a few large ones use the same cores instead of
//! oversubscribing them; results are bit-identical either way.

pub mod metrics;
pub mod queue;
pub mod request;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::image::Image;
use crate::morphology::MorphConfig;
use crate::runtime::{ArtifactMeta, Engine, Manifest, NativeEngine, XlaRuntime};
use metrics::{Metrics, Snapshot};
use queue::{BatchQueue, Pull};
use request::{FilterOutput, FilterRequest, FilterResponse, ImagePayload, Pending, Ticket};

/// Which engine(s) the router may use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// XLA for shapes with artifacts, native for everything else.
    Auto,
    /// Never touch PJRT (no artifacts needed).
    NativeOnly,
    /// Only run requests that have a compiled artifact; others fail.
    XlaOnly,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Bound on queued requests (backpressure limit).
    pub queue_capacity: usize,
    /// Max same-key requests a worker takes per pull.
    pub max_batch: usize,
    pub backend: BackendChoice,
    /// Artifact directory (required unless `NativeOnly`).
    pub artifact_dir: Option<PathBuf>,
    /// Configuration of the native engine.
    pub morph: MorphConfig,
    /// Compile all artifacts at startup instead of lazily.
    pub precompile: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: std::thread::available_parallelism().map_or(2, |n| n.get().min(8)),
            queue_capacity: 1024,
            max_batch: 16,
            backend: BackendChoice::Auto,
            artifact_dir: Some(PathBuf::from("artifacts")),
            morph: MorphConfig::default(),
            precompile: false,
        }
    }
}

/// The running service.
pub struct Coordinator {
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    manifest: Option<Arc<Manifest>>,
    next_id: AtomicU64,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn workers and return the running coordinator.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let manifest = match (&cfg.backend, &cfg.artifact_dir) {
            (BackendChoice::NativeOnly, _) => None,
            (_, Some(dir)) => match Manifest::load(dir) {
                Ok(m) => Some(Arc::new(m)),
                Err(e) if cfg.backend == BackendChoice::XlaOnly => {
                    return Err(e.context("XlaOnly backend requires artifacts"));
                }
                Err(_) => None, // Auto degrades to native
            },
            (BackendChoice::XlaOnly, None) => {
                return Err(anyhow!("XlaOnly backend requires artifact_dir"));
            }
            (_, None) => None,
        };

        let queue = Arc::new(BatchQueue::new(cfg.queue_capacity, cfg.max_batch));
        let metrics = Arc::new(Metrics::default());
        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let manifest = manifest.clone();
            let cfg = cfg.clone();
            let handle = std::thread::Builder::new()
                .name(format!("morph-worker-{wid}"))
                .spawn(move || worker_loop(wid, &cfg, manifest, &queue, &metrics))
                .context("spawning worker")?;
            workers.push(handle);
        }
        Ok(Coordinator {
            queue,
            metrics,
            manifest,
            next_id: AtomicU64::new(1),
            workers,
        })
    }

    /// Convenience: start with defaults and `NativeOnly` backend.
    pub fn start_native(workers: usize) -> Result<Coordinator> {
        Coordinator::start(CoordinatorConfig {
            workers,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            ..CoordinatorConfig::default()
        })
    }

    /// Submit a request with a depth-tagged payload.  Fails fast when
    /// the queue is full (backpressure) or closed.
    pub fn submit_image(
        &self,
        op: &str,
        w_x: usize,
        w_y: usize,
        image: impl Into<ImagePayload>,
    ) -> Result<Ticket> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let pending = Pending {
            req: FilterRequest {
                id,
                op: op.to_string(),
                w_x,
                w_y,
                image: image.into(),
                enqueued: Instant::now(),
            },
            reply: tx,
        };
        match self.queue.push(pending) {
            Ok(()) => {
                Metrics::inc(&self.metrics.submitted);
                Ok(Ticket { id, rx })
            }
            Err(_) => {
                Metrics::inc(&self.metrics.shed);
                Err(anyhow!("queue full: request shed (backpressure)"))
            }
        }
    }

    /// Submit a u8 request.
    pub fn submit(
        &self,
        op: &str,
        w_x: usize,
        w_y: usize,
        image: Arc<Image<u8>>,
    ) -> Result<Ticket> {
        self.submit_image(op, w_x, w_y, image)
    }

    /// Submit a u16 request.
    pub fn submit_u16(
        &self,
        op: &str,
        w_x: usize,
        w_y: usize,
        image: Arc<Image<u16>>,
    ) -> Result<Ticket> {
        self.submit_image(op, w_x, w_y, image)
    }

    /// Submit a u8 request and block for the result.
    pub fn filter(
        &self,
        op: &str,
        w_x: usize,
        w_y: usize,
        image: Arc<Image<u8>>,
    ) -> Result<FilterResponse> {
        self.submit(op, w_x, w_y, image)?.wait()
    }

    /// Submit a u16 request and block for the result.
    pub fn filter_u16(
        &self,
        op: &str,
        w_x: usize,
        w_y: usize,
        image: Arc<Image<u16>>,
    ) -> Result<FilterResponse> {
        self.submit_u16(op, w_x, w_y, image)?.wait()
    }

    pub fn metrics(&self) -> Snapshot {
        self.metrics.snapshot()
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_deref()
    }

    /// Close the queue, drain and join workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Build the native-path artifact description for a request with no
/// compiled artifact.
fn synthetic_meta(req: &FilterRequest) -> ArtifactMeta {
    let (h, w) = (req.image.height(), req.image.width());
    ArtifactMeta {
        name: req.batch_key(),
        kind: if req.op == "transpose" {
            "transpose".into()
        } else {
            "morphology".into()
        },
        op: req.op.clone(),
        height: h,
        width: w,
        w_x: req.w_x,
        w_y: req.w_y,
        method: "hybrid".into(),
        vertical: "transpose".into(),
        dtype: req.image.dtype().into(),
        file: String::new(),
        out_shape: if req.op == "transpose" { (w, h) } else { (h, w) },
    }
}

fn worker_loop(
    wid: usize,
    cfg: &CoordinatorConfig,
    manifest: Option<Arc<Manifest>>,
    queue: &BatchQueue,
    metrics: &Metrics,
) {
    let mut native = NativeEngine::new(cfg.morph);
    let mut xla: Option<XlaRuntime> = match (&cfg.backend, &cfg.artifact_dir, &manifest) {
        (BackendChoice::NativeOnly, _, _) | (_, _, None) => None,
        (_, Some(dir), Some(_)) => XlaRuntime::new(dir).ok(),
        (_, None, _) => None,
    };
    if cfg.precompile {
        if let Some(rt) = xla.as_mut() {
            let _ = rt.precompile(|_| true);
        }
    }

    let mut affinity: Option<String> = None;
    loop {
        match queue.pull(affinity.as_deref(), Duration::from_millis(100)) {
            Pull::Closed => break,
            Pull::Batch(batch) => {
                Metrics::inc(&metrics.batches);
                metrics
                    .batched_requests
                    .fetch_add(batch.len() as u64, Ordering::Relaxed);
                affinity = batch.first().map(|p| p.req.batch_key());
                for p in batch {
                    serve_one(wid, cfg, &manifest, &mut native, &mut xla, metrics, p);
                }
            }
        }
    }
}

fn serve_one(
    wid: usize,
    cfg: &CoordinatorConfig,
    manifest: &Option<Arc<Manifest>>,
    native: &mut NativeEngine,
    xla: &mut Option<XlaRuntime>,
    metrics: &Metrics,
    p: Pending,
) {
    let queue_ns = p.req.enqueued.elapsed().as_nanos() as u64;
    let (h, w) = (p.req.image.height(), p.req.image.width());
    // compiled artifacts exist only for u8 payloads
    let compiled = match &p.req.image {
        ImagePayload::U8(_) => manifest
            .as_ref()
            .and_then(|m| m.find(&p.req.op, h, w, p.req.w_x, p.req.w_y).cloned()),
        ImagePayload::U16(_) => None,
    };

    let t = Instant::now();
    let (result, backend): (Result<FilterOutput>, &'static str) = match &p.req.image {
        ImagePayload::U8(img) => {
            if cfg.backend == BackendChoice::XlaOnly {
                match (compiled, xla.as_mut()) {
                    (Some(meta), Some(rt)) => (
                        rt.run(&meta, img).map(FilterOutput::U8),
                        rt.backend_name(),
                    ),
                    (None, _) => (
                        Err(anyhow!("no artifact for {} (XlaOnly backend)", p.req.batch_key())),
                        "xla-pjrt",
                    ),
                    (Some(_), None) => (
                        Err(anyhow!("XLA runtime unavailable on worker {wid}")),
                        "xla-pjrt",
                    ),
                }
            } else if let (Some(meta), Some(rt)) = (compiled.as_ref(), xla.as_mut()) {
                match rt.run(meta, img) {
                    // Auto: degrade to native on runtime errors
                    Err(_) => (
                        native.run(&synthetic_meta(&p.req), img).map(FilterOutput::U8),
                        native.backend_name(),
                    ),
                    ok => (ok.map(FilterOutput::U8), rt.backend_name()),
                }
            } else {
                (
                    native.run(&synthetic_meta(&p.req), img).map(FilterOutput::U8),
                    native.backend_name(),
                )
            }
        }
        ImagePayload::U16(img) => {
            if cfg.backend == BackendChoice::XlaOnly {
                (
                    Err(anyhow!(
                        "no u16 artifacts exist (XlaOnly backend, {})",
                        p.req.batch_key()
                    )),
                    "xla-pjrt",
                )
            } else {
                (
                    native
                        .run_u16(&synthetic_meta(&p.req), img)
                        .map(FilterOutput::U16),
                    native.backend_name(),
                )
            }
        }
    };
    let exec_ns = t.elapsed().as_nanos() as u64;

    metrics.queue_latency.record(queue_ns);
    metrics.exec_latency.record(exec_ns);
    metrics.total_latency.record(queue_ns + exec_ns);
    if result.is_ok() {
        Metrics::inc(&metrics.completed);
    } else {
        Metrics::inc(&metrics.failed);
    }
    // receiver may have given up; dropping the response is fine
    let _ = p.reply.send(FilterResponse {
        id: p.req.id,
        result,
        queue_ns,
        exec_ns,
        backend,
        worker: wid,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::morphology;
    use crate::neon::Native;

    #[test]
    fn native_coordinator_round_trip() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(32, 48, 5));
        let resp = coord.filter("erode", 5, 3, img.clone()).unwrap();
        assert_eq!(resp.backend, "native");
        let want = morphology::erode(img.view(), 5, 3);
        assert!(resp.result.unwrap().expect_u8().same_pixels(&want));
        let snap = coord.metrics();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn u16_coordinator_round_trip() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise_u16(32, 48, 5));
        let resp = coord.filter_u16("erode", 5, 3, img.clone()).unwrap();
        assert_eq!(resp.backend, "native");
        let want = morphology::erode(img.view(), 5, 3);
        assert!(resp.result.unwrap().expect_u16().same_pixels(&want));
        let snap = coord.metrics();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn mixed_depth_requests_batch_separately() {
        let coord = Coordinator::start_native(2).unwrap();
        let img8 = Arc::new(synth::noise(24, 24, 6));
        let img16 = Arc::new(synth::noise_u16(24, 24, 6));
        let mut tickets = Vec::new();
        for i in 0..20 {
            let t = if i % 2 == 0 {
                coord.submit("erode", 3, 3, img8.clone()).unwrap()
            } else {
                coord.submit_u16("erode", 3, 3, img16.clone()).unwrap()
            };
            tickets.push((i, t));
        }
        for (i, t) in tickets {
            let r = t.wait().unwrap();
            let out = r.result.unwrap();
            assert_eq!(out.dtype(), if i % 2 == 0 { "u8" } else { "u16" });
        }
        let snap = coord.metrics();
        assert_eq!(snap.completed, 20);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let coord = Coordinator::start_native(4).unwrap();
        let img = Arc::new(synth::noise(24, 24, 6));
        let tickets: Vec<_> = (0..40)
            .map(|i| {
                let op = if i % 2 == 0 { "erode" } else { "dilate" };
                coord.submit(op, 3, 3, img.clone()).unwrap()
            })
            .collect();
        for t in tickets {
            let r = t.wait().unwrap();
            assert!(r.result.is_ok());
        }
        let snap = coord.metrics();
        assert_eq!(snap.completed, 40);
        assert!(snap.batches <= 40);
        coord.shutdown();
    }

    #[test]
    fn unknown_op_fails_cleanly() {
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise(8, 8, 2));
        let resp = coord.filter("sharpen", 3, 3, img).unwrap();
        assert!(resp.result.is_err());
        assert_eq!(coord.metrics().failed, 1);
        coord.shutdown();
    }

    #[test]
    fn backpressure_sheds_when_overloaded() {
        // 1 worker, tiny queue, many submissions of slow-ish work
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            queue_capacity: 2,
            max_batch: 1,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            morph: MorphConfig::default(),
            precompile: false,
        })
        .unwrap();
        let img = Arc::new(synth::paper_image(3));
        let mut shed = 0;
        let mut tickets = Vec::new();
        for _ in 0..64 {
            match coord.submit("opening", 15, 15, img.clone()) {
                Ok(t) => tickets.push(t),
                Err(_) => shed += 1,
            }
        }
        assert!(shed > 0, "expected at least one shed under overload");
        assert_eq!(coord.metrics().shed, shed);
        for t in tickets {
            assert!(t.wait().unwrap().result.is_ok());
        }
        coord.shutdown();
    }

    #[test]
    fn transpose_request_swaps_dims() {
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise(10, 20, 8));
        let out = coord
            .filter("transpose", 0, 0, img.clone())
            .unwrap()
            .result
            .unwrap()
            .expect_u8();
        assert_eq!((out.height(), out.width()), (20, 10));
        let want = crate::transpose::transpose_image(&mut Native, img.view());
        assert!(out.same_pixels(&want));
        coord.shutdown();
    }

    #[test]
    fn u16_transpose_uses_8x8_tiles_end_to_end() {
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise_u16(16, 24, 8));
        let out = coord
            .filter_u16("transpose", 0, 0, img.clone())
            .unwrap()
            .result
            .unwrap()
            .expect_u16();
        assert_eq!((out.height(), out.width()), (24, 16));
        let want = crate::transpose::transpose_image_u16(&mut Native, &img);
        assert!(out.same_pixels(&want));
        coord.shutdown();
    }

    #[test]
    fn drop_shuts_down_workers() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(8, 8, 1));
        let _ = coord.filter("erode", 3, 3, img);
        drop(coord); // must not hang
    }
}
