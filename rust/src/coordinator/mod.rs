//! L3 coordinator: the morphology filtering service.
//!
//! Architecture (std threads; the offline build has no tokio, and the
//! PJRT CPU client is synchronous anyway):
//!
//! ```text
//!  submit(FilterSpec, payload) ──► BatchQueue (bounded, key-grouped)
//!     │               │  backpressure: reject when full   worker 0 ─► reply
//!     └─ Ticket ◄─────┘  batches keyed by typed BatchKey  worker 1 ─► reply
//!                         (depth, shape, op chain, config, ROI shape)
//! ```
//!
//! Requests carry a full [`crate::morphology::FilterSpec`] — op chain
//! (including derived ops and multi-op pipelines), window,
//! configuration and optional ROI — through **one** depth-erased
//! [`Coordinator::submit`].  The historical per-op × per-depth surface
//! (`filter`/`filter_u16` with string ops) survives as thin wrappers
//! that build single-op specs with the coordinator's default
//! [`MorphConfig`].
//!
//! Each worker owns its engines — an optional [`XlaRuntime`] (PJRT,
//! executing the python-AOT artifacts; `PjRtLoadedExecutable` is not
//! `Sync`, so runtimes are never shared) and a [`NativeEngine`] (§5.3
//! hybrid morphology behind a **plan cache**: each `(spec, shape)` is
//! resolved once into a `FilterPlan` and reused across the batch — the
//! queue's key-affinity makes consecutive pulls hit the same plan.
//! Caveat: the plan cache keys on the *exact* spec, ROI position
//! included (an edge-clamped block resolves different geometry), so a
//! ROI batch only reuses plans across same-position crops;
//! position-independent ROI plans are a ROADMAP follow-on).
//! The **router** picks per request: an artifact match on the XLA
//! backend when available (single-op, no-ROI, u8 specs only — the only
//! shapes the AOT pipeline lowers), native otherwise (or as directed by
//! [`BackendChoice`]).
//!
//! Depth routing: payloads are depth-tagged
//! ([`request::ImagePayload`]); batch keys include the dtype so batches
//! never mix depths, and u16 requests always execute on the native
//! engine (and fail under [`BackendChoice::XlaOnly`]).
//!
//! Spec validation happens on the worker: an invalid spec (even window,
//! out-of-bounds ROI) completes its ticket with an error result and
//! counts toward the `failed` metric, exactly like the stringly
//! "unknown op" requests of the previous API.
//!
//! Intra-image parallelism: native plans band-shard large images across
//! the process-wide [`crate::morphology::parallel::BandPool`] (policy:
//! the spec's `config.parallelism`, default `Auto` — the cost model
//! keeps small requests sequential, resolved once at plan time).
//! Coordinator workers and band jobs share that one pool, so serving
//! many small requests and splitting a few large ones use the same
//! cores instead of oversubscribing them; results are bit-identical
//! either way.

pub mod metrics;
pub mod queue;
pub mod request;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::image::Image;
use crate::morphology::{FilterOp, FilterSpec, MorphConfig};
use crate::runtime::{Engine, Manifest, NativeEngine, XlaRuntime};
use metrics::{Metrics, Snapshot};
use queue::{BatchQueue, Pull};
use request::{BatchKey, FilterOutput, FilterResponse, ImagePayload, Pending, Ticket};

/// Which engine(s) the router may use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// XLA for specs with artifacts, native for everything else.
    Auto,
    /// Never touch PJRT (no artifacts needed).
    NativeOnly,
    /// Only run requests that have a compiled artifact; others fail.
    XlaOnly,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Bound on queued requests (backpressure limit).
    pub queue_capacity: usize,
    /// Max same-key requests a worker takes per pull.
    pub max_batch: usize,
    pub backend: BackendChoice,
    /// Artifact directory (required unless `NativeOnly`).
    pub artifact_dir: Option<PathBuf>,
    /// Default configuration applied by the legacy string-op wrappers
    /// (`filter`/`filter_u16`); spec submissions carry their own.
    pub morph: MorphConfig,
    /// Compile all artifacts at startup instead of lazily.
    pub precompile: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: std::thread::available_parallelism().map_or(2, |n| n.get().min(8)),
            queue_capacity: 1024,
            max_batch: 16,
            backend: BackendChoice::Auto,
            artifact_dir: Some(PathBuf::from("artifacts")),
            morph: MorphConfig::default(),
            precompile: false,
        }
    }
}

/// The running service.
pub struct Coordinator {
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    manifest: Option<Arc<Manifest>>,
    default_morph: MorphConfig,
    next_id: AtomicU64,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn workers and return the running coordinator.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let manifest = match (&cfg.backend, &cfg.artifact_dir) {
            (BackendChoice::NativeOnly, _) => None,
            (_, Some(dir)) => match Manifest::load(dir) {
                Ok(m) => Some(Arc::new(m)),
                Err(e) if cfg.backend == BackendChoice::XlaOnly => {
                    return Err(e.context("XlaOnly backend requires artifacts"));
                }
                Err(_) => None, // Auto degrades to native
            },
            (BackendChoice::XlaOnly, None) => {
                return Err(anyhow!("XlaOnly backend requires artifact_dir"));
            }
            (_, None) => None,
        };

        let queue = Arc::new(BatchQueue::new(cfg.queue_capacity, cfg.max_batch));
        let metrics = Arc::new(Metrics::default());
        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let manifest = manifest.clone();
            let cfg = cfg.clone();
            let handle = std::thread::Builder::new()
                .name(format!("morph-worker-{wid}"))
                .spawn(move || worker_loop(wid, &cfg, manifest, &queue, &metrics))
                .context("spawning worker")?;
            workers.push(handle);
        }
        Ok(Coordinator {
            queue,
            metrics,
            manifest,
            default_morph: cfg.morph,
            next_id: AtomicU64::new(1),
            workers,
        })
    }

    /// Convenience: start with defaults and `NativeOnly` backend.
    pub fn start_native(workers: usize) -> Result<Coordinator> {
        Coordinator::start(CoordinatorConfig {
            workers,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            ..CoordinatorConfig::default()
        })
    }

    /// Submit a spec with a depth-tagged payload — the one submission
    /// path for every op chain, depth and ROI.  Fails fast when the
    /// queue is full (backpressure) or closed; spec validity is checked
    /// by the executing worker (the ticket then carries the error).
    pub fn submit(&self, spec: FilterSpec, image: impl Into<ImagePayload>) -> Result<Ticket> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let pending = Pending {
            req: request::FilterRequest {
                id,
                spec,
                image: image.into(),
                enqueued: Instant::now(),
            },
            reply: tx,
        };
        match self.queue.push(pending) {
            Ok(()) => {
                Metrics::inc(&self.metrics.submitted);
                Ok(Ticket { id, rx })
            }
            Err(_) => {
                Metrics::inc(&self.metrics.shed);
                Err(anyhow!("queue full: request shed (backpressure)"))
            }
        }
    }

    /// Submit a spec and block for the result.
    pub fn filter_spec(
        &self,
        spec: FilterSpec,
        image: impl Into<ImagePayload>,
    ) -> Result<FilterResponse> {
        self.submit(spec, image)?.wait()
    }

    /// Build the single-op spec a legacy string-op call denotes, using
    /// the coordinator's default morph configuration.
    fn legacy_spec(&self, op: &str, w_x: usize, w_y: usize) -> Result<FilterSpec> {
        let op: FilterOp = op.parse().map_err(|e| anyhow!("{e}"))?;
        Ok(FilterSpec::new(op, w_x, w_y).with_config(self.default_morph))
    }

    /// Legacy wrapper: submit a u8 request by op name and block for the
    /// result.  Bit-identical to `filter_spec` with the equivalent
    /// single-op spec.
    pub fn filter(
        &self,
        op: &str,
        w_x: usize,
        w_y: usize,
        image: Arc<Image<u8>>,
    ) -> Result<FilterResponse> {
        self.filter_spec(self.legacy_spec(op, w_x, w_y)?, image)
    }

    /// Legacy wrapper: submit a u16 request by op name and block.
    pub fn filter_u16(
        &self,
        op: &str,
        w_x: usize,
        w_y: usize,
        image: Arc<Image<u16>>,
    ) -> Result<FilterResponse> {
        self.filter_spec(self.legacy_spec(op, w_x, w_y)?, image)
    }

    pub fn metrics(&self) -> Snapshot {
        self.metrics.snapshot()
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_deref()
    }

    /// Close the queue, drain and join workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    wid: usize,
    cfg: &CoordinatorConfig,
    manifest: Option<Arc<Manifest>>,
    queue: &BatchQueue,
    metrics: &Metrics,
) {
    let mut native = NativeEngine::new(cfg.morph);
    let mut xla: Option<XlaRuntime> = match (&cfg.backend, &cfg.artifact_dir, &manifest) {
        (BackendChoice::NativeOnly, _, _) | (_, _, None) => None,
        (_, Some(dir), Some(_)) => XlaRuntime::new(dir).ok(),
        (_, None, _) => None,
    };
    if cfg.precompile {
        if let Some(rt) = xla.as_mut() {
            let _ = rt.precompile(|_| true);
        }
    }

    let mut affinity: Option<BatchKey> = None;
    loop {
        match queue.pull(affinity.as_ref(), Duration::from_millis(100)) {
            Pull::Closed => break,
            Pull::Batch(batch) => {
                Metrics::inc(&metrics.batches);
                metrics
                    .batched_requests
                    .fetch_add(batch.len() as u64, Ordering::Relaxed);
                affinity = batch.first().map(|p| p.req.batch_key());
                for p in batch {
                    serve_one(wid, cfg, &manifest, &mut native, &mut xla, metrics, p);
                }
            }
        }
    }
}

fn serve_one(
    wid: usize,
    cfg: &CoordinatorConfig,
    manifest: &Option<Arc<Manifest>>,
    native: &mut NativeEngine,
    xla: &mut Option<XlaRuntime>,
    metrics: &Metrics,
    p: Pending,
) {
    let queue_ns = p.req.enqueued.elapsed().as_nanos() as u64;
    let spec = p.req.spec;
    let (h, w) = (p.req.image.height(), p.req.image.width());
    // compiled artifacts exist only for u8 specs in canonical form
    // (single op, no ROI, identity border — the shared predicate
    // `FilterSpec::single_identity_op`; a replicate-border spec must
    // never take the XLA path, its output pixels differ at the edges)
    let compiled = match (&p.req.image, spec.single_identity_op()) {
        (ImagePayload::U8(_), Some(op)) => manifest
            .as_ref()
            .and_then(|m| m.find(op.name(), h, w, spec.w_x, spec.w_y).cloned()),
        _ => None,
    };

    let t = Instant::now();
    let (result, backend): (Result<FilterOutput>, &'static str) = match &p.req.image {
        ImagePayload::U8(img) => {
            if cfg.backend == BackendChoice::XlaOnly {
                match (compiled, xla.as_mut()) {
                    (Some(meta), Some(rt)) => {
                        (rt.run_u8(&meta, img).map(FilterOutput::U8), rt.backend_name())
                    }
                    (None, _) => (
                        Err(anyhow!(
                            "no artifact for {} (XlaOnly backend)",
                            p.req.batch_key()
                        )),
                        "xla-pjrt",
                    ),
                    (Some(_), None) => (
                        Err(anyhow!("XLA runtime unavailable on worker {wid}")),
                        "xla-pjrt",
                    ),
                }
            } else if let (Some(meta), Some(rt)) = (compiled.as_ref(), xla.as_mut()) {
                match rt.run_u8(meta, img) {
                    // Auto: degrade to native on runtime errors
                    Err(_) => (
                        native.run_spec(&spec, img).map(FilterOutput::U8),
                        native.backend_name(),
                    ),
                    ok => (ok.map(FilterOutput::U8), rt.backend_name()),
                }
            } else {
                (
                    native.run_spec(&spec, img).map(FilterOutput::U8),
                    native.backend_name(),
                )
            }
        }
        ImagePayload::U16(img) => {
            if cfg.backend == BackendChoice::XlaOnly {
                (
                    Err(anyhow!(
                        "no u16 artifacts exist (XlaOnly backend, {})",
                        p.req.batch_key()
                    )),
                    "xla-pjrt",
                )
            } else {
                (
                    native.run_spec_u16(&spec, img).map(FilterOutput::U16),
                    native.backend_name(),
                )
            }
        }
    };
    let exec_ns = t.elapsed().as_nanos() as u64;

    metrics.queue_latency.record(queue_ns);
    metrics.exec_latency.record(exec_ns);
    metrics.total_latency.record(queue_ns + exec_ns);
    if result.is_ok() {
        Metrics::inc(&metrics.completed);
    } else {
        Metrics::inc(&metrics.failed);
    }
    // receiver may have given up; dropping the response is fine
    let _ = p.reply.send(FilterResponse {
        id: p.req.id,
        result,
        queue_ns,
        exec_ns,
        backend,
        worker: wid,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::morphology::{self, Roi};
    use crate::neon::Native;

    #[test]
    fn native_coordinator_round_trip() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(32, 48, 5));
        let resp = coord.filter("erode", 5, 3, img.clone()).unwrap();
        assert_eq!(resp.backend, "native");
        let want = morphology::erode(img.view(), 5, 3);
        assert!(resp.result.unwrap().into_u8().unwrap().same_pixels(&want));
        let snap = coord.metrics();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn u16_coordinator_round_trip() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise_u16(32, 48, 5));
        let resp = coord.filter_u16("erode", 5, 3, img.clone()).unwrap();
        assert_eq!(resp.backend, "native");
        let want = morphology::erode(img.view(), 5, 3);
        assert!(resp.result.unwrap().into_u16().unwrap().same_pixels(&want));
        let snap = coord.metrics();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn spec_submission_runs_chains_and_rois() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(40, 40, 9));
        // a derived op with a ROI — inexpressible in the legacy API
        let spec = FilterSpec::new(FilterOp::TopHat, 5, 5).with_roi(Roi::new(3, 4, 20, 22));
        let resp = coord.filter_spec(spec, img.clone()).unwrap();
        let out = resp.result.unwrap().into_u8().unwrap();
        let full = morphology::parallel::tophat_native(&*img, 5, 5, &MorphConfig::default());
        assert!(out.same_pixels(&full.view().sub_rect(3, 4, 20, 22).to_image()));
        // a two-op chain
        let chain = FilterSpec::new(FilterOp::Open, 3, 3).then(FilterOp::Gradient);
        let resp = coord.filter_spec(chain, img.clone()).unwrap();
        let o = morphology::opening(&mut Native, &*img, 3, 3, &MorphConfig::default());
        let g = morphology::gradient(&mut Native, &o, 3, 3, &MorphConfig::default());
        assert!(resp.result.unwrap().into_u8().unwrap().same_pixels(&g));
        coord.shutdown();
    }

    #[test]
    fn mixed_depth_requests_batch_separately() {
        let coord = Coordinator::start_native(2).unwrap();
        let img8 = Arc::new(synth::noise(24, 24, 6));
        let img16 = Arc::new(synth::noise_u16(24, 24, 6));
        let spec = FilterSpec::new(FilterOp::Erode, 3, 3);
        let mut tickets = Vec::new();
        for i in 0..20 {
            let t = if i % 2 == 0 {
                coord.submit(spec, img8.clone()).unwrap()
            } else {
                coord.submit(spec, img16.clone()).unwrap()
            };
            tickets.push((i, t));
        }
        for (i, t) in tickets {
            let r = t.wait().unwrap();
            let out = r.result.unwrap();
            assert_eq!(out.dtype(), if i % 2 == 0 { "u8" } else { "u16" });
        }
        let snap = coord.metrics();
        assert_eq!(snap.completed, 20);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let coord = Coordinator::start_native(4).unwrap();
        let img = Arc::new(synth::noise(24, 24, 6));
        let tickets: Vec<_> = (0..40)
            .map(|i| {
                let op = if i % 2 == 0 { FilterOp::Erode } else { FilterOp::Dilate };
                coord.submit(FilterSpec::new(op, 3, 3), img.clone()).unwrap()
            })
            .collect();
        for t in tickets {
            let r = t.wait().unwrap();
            assert!(r.result.is_ok());
        }
        let snap = coord.metrics();
        assert_eq!(snap.completed, 40);
        assert!(snap.batches <= 40);
        coord.shutdown();
    }

    #[test]
    fn unknown_op_rejected_at_submission() {
        // the typed spec API surfaces bad op names before queueing
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise(8, 8, 2));
        let err = coord.filter("sharpen", 3, 3, img).unwrap_err();
        assert!(format!("{err:#}").contains("unknown op"));
        assert_eq!(coord.metrics().failed, 0);
        assert_eq!(coord.metrics().submitted, 0);
        coord.shutdown();
    }

    #[test]
    fn invalid_spec_fails_on_the_worker() {
        // spec validity (window parity, ROI bounds) is checked at plan
        // time on the worker: the ticket completes with an error and
        // the failure is metered
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise(8, 8, 2));
        let resp = coord
            .filter_spec(FilterSpec::new(FilterOp::Erode, 4, 4), img.clone())
            .unwrap();
        assert!(resp.result.is_err());
        let resp = coord
            .filter_spec(
                FilterSpec::new(FilterOp::Erode, 3, 3).with_roi(Roi::new(6, 6, 5, 5)),
                img,
            )
            .unwrap();
        assert!(resp.result.is_err());
        assert_eq!(coord.metrics().failed, 2);
        coord.shutdown();
    }

    #[test]
    fn backpressure_sheds_when_overloaded() {
        // 1 worker, tiny queue, many submissions of slow-ish work
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            queue_capacity: 2,
            max_batch: 1,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            morph: MorphConfig::default(),
            precompile: false,
        })
        .unwrap();
        let img = Arc::new(synth::paper_image(3));
        let spec = FilterSpec::new(FilterOp::Open, 15, 15);
        let mut shed = 0;
        let mut tickets = Vec::new();
        for _ in 0..64 {
            match coord.submit(spec, img.clone()) {
                Ok(t) => tickets.push(t),
                Err(_) => shed += 1,
            }
        }
        assert!(shed > 0, "expected at least one shed under overload");
        assert_eq!(coord.metrics().shed, shed);
        for t in tickets {
            assert!(t.wait().unwrap().result.is_ok());
        }
        coord.shutdown();
    }

    #[test]
    fn transpose_request_swaps_dims() {
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise(10, 20, 8));
        let out = coord
            .filter("transpose", 0, 0, img.clone())
            .unwrap()
            .result
            .unwrap()
            .into_u8()
            .unwrap();
        assert_eq!((out.height(), out.width()), (20, 10));
        let want = crate::transpose::transpose_image(&mut Native, img.view());
        assert!(out.same_pixels(&want));
        coord.shutdown();
    }

    #[test]
    fn u16_transpose_uses_8x8_tiles_end_to_end() {
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise_u16(16, 24, 8));
        let out = coord
            .filter_u16("transpose", 0, 0, img.clone())
            .unwrap()
            .result
            .unwrap()
            .into_u16()
            .unwrap();
        assert_eq!((out.height(), out.width()), (24, 16));
        let want = crate::transpose::transpose_image_u16(&mut Native, &*img);
        assert!(out.same_pixels(&want));
        coord.shutdown();
    }

    #[test]
    fn drop_shuts_down_workers() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(8, 8, 1));
        let _ = coord.filter("erode", 3, 3, img);
        drop(coord); // must not hang
    }
}
