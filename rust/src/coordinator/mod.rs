//! L3 coordinator: the morphology filtering service, served by a
//! **staged pipeline** (std threads; the offline build has no tokio,
//! and the PJRT CPU client is synchronous anyway):
//!
//! ```text
//!  submit(FilterSpec, payload) ──► admit: try_send + per-key budget —
//!     │                            the ONLY lossy door (sheds, never
//!     │                            blocks the caller)
//!     │                              │ bounded channel
//!     │                         [ingress]      validate the spec
//!     │                              │ bounded channel, blocking send
//!     │                         [plan-resolve] warm the plan on the
//!     │                              │         lane it will run on
//!     │                              │ per-lane BatchQueue (key-affine)
//!     │                         [execute ×N]   fused / per-request
//!     │                              │ bounded channel
//!     └─ Ticket ◄──────────────  [reply]       budget release + send
//! ```
//!
//! Each stage is a small worker set over a bounded channel
//! ([`pipeline`]): past admission, stage-to-stage sends **block** (with
//! a deadline backstop), so backpressure propagates stage-to-stage and
//! queue pulls overlap in-flight band execution — the plan-resolve
//! stage runs ahead of execute, so hot keys are warm before their
//! batch lands.  Every admitted request is replied **exactly once**,
//! even across panics while serving (stage-local isolation rebuilds
//! the poisoned engine and answers the request with an error).
//!
//! Requests carry a full [`crate::morphology::FilterSpec`] — op chain
//! (including derived ops and multi-op pipelines), window,
//! configuration and optional ROI — through **one** depth-erased
//! submission path.  [`Coordinator::submit`] is the fire-and-wait form
//! (one ticket, one reply channel); [`Coordinator::stream`] /
//! [`Coordinator::submit_many`] are the **streaming** form: producers
//! enqueue without blocking per ticket and responses flow back over one
//! shared channel in *completion* order (each
//! [`request::FilterResponse`] carries its request id).  The client
//! API is **spec-only**: string op names enter through
//! [`crate::morphology::FilterSpec::parse_op`], which builds the same
//! typed spec every other entry point uses.
//!
//! ## Plan-pinned lanes
//!
//! Each execute lane owns its engines — an optional [`XlaRuntime`]
//! (PJRT, executing the python-AOT artifacts; `PjRtLoadedExecutable` is
//! not `Sync`, so runtimes are never shared) and a [`NativeEngine`]
//! (§5.3 hybrid morphology behind a **plan cache** keyed on the
//! *canonical* spec, [`crate::morphology::FilterSpec::canonical_for`]).
//! One [`request::BatchKey`] always routes to one lane, so a lane pulls
//! a same-key batch whose plan the resolve stage already warmed, and
//! the whole batch — plus every following same-key batch the affinity
//! pull keeps returning — runs **pinned to that one plan**.  Because
//! plans are position-independent, this holds across an ROI crop
//! *sweep*: all interior same-shape crops hit one plan
//! (`plan_resolutions` / `plan_hits` in [`metrics::Snapshot`] meter it,
//! warm-ahead included — `G` same-family requests score `1` resolution
//! + `2G − 1` hits; `BENCH_serve.json` gates resolutions-per-request in
//! CI).  The lane queue's FIFO aging ([`queue`]) bounds how long a
//! pinned lane may ride one hot key while colder keys wait.
//!
//! ## Fused super-passes
//!
//! A pulled batch of **more than one** full-image, non-transpose,
//! native-routed request is served as ONE fused execution
//! ([`NativeEngine::run_spec_batch`] →
//! [`crate::morphology::FusedPlan`]): the batch's images stack into a
//! virtual `n·h × w` image, bands span image boundaries, and a single
//! fork-join runs the whole batch — amortizing per-pass fork overhead
//! that small images otherwise pay per request.  Outputs are
//! bit-identical to per-image serving; [`metrics::Metrics`] counts
//! `fused_batches` / `fused_requests`.  ROI or transpose specs, mixed
//! shapes, XLA-routed batches and singletons keep the per-request path.
//!
//! The **router** picks per request: an artifact match on the XLA
//! backend when available (single-op, no-ROI, u8 specs only — the only
//! shapes the AOT pipeline lowers), native otherwise (or as directed by
//! [`BackendChoice`]).
//!
//! Depth routing: payloads are depth-tagged
//! ([`request::ImagePayload`]); batch keys include the dtype so batches
//! never mix depths, and u16 requests always execute on the native
//! engine (and fail under [`BackendChoice::XlaOnly`]).
//!
//! Spec validation happens at **ingress**: an invalid spec (even
//! window, out-of-bounds ROI) completes its ticket with an error result
//! and counts toward the `failed` metric without ever touching an
//! engine.
//!
//! ## Band budget
//!
//! Native plans band-shard large images across the process-wide
//! [`crate::morphology::parallel::BandPool`].  Under streaming load,
//! `workers` concurrent requests each banding to the full pool would
//! oversubscribe every core, so
//! [`CoordinatorConfig::max_bands_per_request`] caps the bands any one
//! request may use — by default `cores / workers` (so
//! `workers × max_bands_per_request ≤ cores`), overridable in the
//! config or with the `NEON_MORPH_MAX_BANDS` environment variable (and
//! `NEON_MORPH_BAND_WORKERS` sizes the pool itself,
//! [`crate::morphology::parallel::BandPool::with_workers`]).  The cap
//! only clamps the band *count*; outputs stay bit-identical.

pub mod metrics;
pub mod pipeline;
pub mod queue;
pub mod request;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::morphology::{FilterOp, FilterSpec, MorphConfig};
use crate::runtime::{Manifest, NativeEngine, XlaRuntime};
use metrics::{Metrics, Snapshot};
use pipeline::{Pipeline, Shed};
use request::{FilterResponse, ImagePayload, Pending, Ticket};

/// Which engine(s) the router may use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// XLA for specs with artifacts, native for everything else.
    Auto,
    /// Never touch PJRT (no artifacts needed).
    NativeOnly,
    /// Only run requests that have a compiled artifact; others fail.
    XlaOnly,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Execute lanes (each with its own engines and batch queue).
    pub workers: usize,
    /// Bound on requests waiting at admission (the global backpressure
    /// limit: a full admission channel sheds).
    pub queue_capacity: usize,
    /// Max same-key requests a lane takes per pull.
    pub max_batch: usize,
    pub backend: BackendChoice,
    /// Artifact directory (required unless `NativeOnly`).
    pub artifact_dir: Option<PathBuf>,
    /// Engine-level configuration for the lanes' [`NativeEngine`]s
    /// (applied by their legacy artifact wrappers); spec submissions
    /// carry their own configuration.
    pub morph: MorphConfig,
    /// Compile all artifacts at startup instead of lazily.
    pub precompile: bool,
    /// Intra-image band budget per request: no single request may shard
    /// across more bands than this, so one giant image cannot
    /// monopolize the shared
    /// [`crate::morphology::parallel::BandPool`] under streaming
    /// load.  `0` (the default) derives `cores / workers` (≥ 1) at
    /// startup, keeping `workers × max_bands_per_request ≤ cores`; a
    /// nonzero `NEON_MORPH_MAX_BANDS` environment variable overrides
    /// both (`0` in the env also means "derive").  Clamping the band
    /// count never changes output pixels.
    pub max_bands_per_request: usize,
    /// Per-key admission budget: at most this many requests of one
    /// [`request::BatchKey`] may be in flight (admitted, not yet
    /// replied) at once; further same-key submissions shed with an
    /// error until replies free slots.  `0` (the default) disables the
    /// budget.  Bounds how far ahead one hot key can fill the pipeline
    /// before the lane queues' FIFO aging even sees it.
    pub admission_budget: usize,
    /// Capacity of each inter-stage channel (ingress→resolve, each
    /// resolve→execute lane queue, execute→reply).  `0` (the default)
    /// derives `queue_capacity.clamp(1, 32)`.  Per-stage depths are
    /// bounded by this plus the stage's sender count — the invariant
    /// the pipeline tests assert.
    pub stage_capacity: usize,
    /// Stall backstop on stage-to-stage handoffs: a blocked send that
    /// outlives this deadline fails its request with a
    /// pipeline-stalled error instead of wedging the stage forever.
    /// Zero means the default (60 s — generous on purpose: it exists
    /// to catch wedges, not to pace load; pacing is the channel
    /// bounds' job).
    pub stage_deadline: Duration,
    /// Test-only fault injection: panic while serving any request
    /// whose spec is exactly this single op (both the fused and the
    /// per-request path), exercising the pipeline's panic isolation.
    #[doc(hidden)]
    pub debug_fault_op: Option<FilterOp>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: std::thread::available_parallelism().map_or(2, |n| n.get().min(8)),
            queue_capacity: 1024,
            max_batch: 16,
            backend: BackendChoice::Auto,
            artifact_dir: Some(PathBuf::from("artifacts")),
            morph: MorphConfig::default(),
            precompile: false,
            max_bands_per_request: 0,
            admission_budget: 0,
            stage_capacity: 0,
            stage_deadline: Duration::from_secs(60),
            debug_fault_op: None,
        }
    }
}

/// Resolve the effective per-request band cap for `cfg` (see
/// [`CoordinatorConfig::max_bands_per_request`]).
pub(crate) fn resolve_band_cap(cfg: &CoordinatorConfig) -> usize {
    // env 0 means the same as config 0 — "derive" — never "cap at 1"
    if let Some(n) = std::env::var("NEON_MORPH_MAX_BANDS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|n| *n > 0)
    {
        return n;
    }
    if cfg.max_bands_per_request > 0 {
        return cfg.max_bands_per_request;
    }
    let cores = std::thread::available_parallelism().map_or(2, |n| n.get());
    (cores / cfg.workers.max(1)).max(1)
}

/// The running service.
pub struct Coordinator {
    pipeline: Pipeline,
    metrics: Arc<Metrics>,
    manifest: Option<Arc<Manifest>>,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Spawn the pipeline stages and return the running coordinator.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let manifest = match (&cfg.backend, &cfg.artifact_dir) {
            (BackendChoice::NativeOnly, _) => None,
            (_, Some(dir)) => match Manifest::load(dir) {
                Ok(m) => Some(Arc::new(m)),
                Err(e) if cfg.backend == BackendChoice::XlaOnly => {
                    return Err(e.context("XlaOnly backend requires artifacts"));
                }
                Err(_) => None, // Auto degrades to native
            },
            (BackendChoice::XlaOnly, None) => {
                return Err(anyhow!("XlaOnly backend requires artifact_dir"));
            }
            (_, None) => None,
        };

        let metrics = Arc::new(Metrics::default());
        let pipeline = Pipeline::start(&cfg, manifest.clone(), metrics.clone())?;
        Ok(Coordinator {
            pipeline,
            metrics,
            manifest,
            next_id: AtomicU64::new(1),
        })
    }

    /// Convenience: start with defaults and `NativeOnly` backend.
    pub fn start_native(workers: usize) -> Result<Coordinator> {
        Coordinator::start(CoordinatorConfig {
            workers,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            ..CoordinatorConfig::default()
        })
    }

    /// Admit one request whose response goes to `reply` — the shared
    /// non-blocking core of [`Coordinator::submit`] (fresh channel per
    /// ticket) and [`SubmitStream::send`] (one channel per stream).
    fn enqueue(
        &self,
        spec: FilterSpec,
        image: ImagePayload,
        marker: Option<ImagePayload>,
        reply: mpsc::Sender<FilterResponse>,
    ) -> Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let pending = Pending {
            req: request::FilterRequest {
                id,
                spec,
                image,
                marker,
                enqueued: Instant::now(),
            },
            reply,
        };
        let key = pending.req.batch_key();
        match self.pipeline.admit(pending) {
            Ok(()) => {
                Metrics::inc(&self.metrics.submitted);
                Ok(id)
            }
            Err(shed) => {
                Metrics::inc(&self.metrics.shed);
                Err(match shed {
                    Shed::Full => anyhow!("queue full: request shed (backpressure)"),
                    Shed::Budget => anyhow!(
                        "admission budget exhausted for {key}: request shed (backpressure)"
                    ),
                    Shed::Closed => anyhow!("pipeline is shut down: request shed"),
                })
            }
        }
    }

    /// Submit a spec with a depth-tagged payload — the one submission
    /// path for every op chain, depth and ROI.  Fails fast when
    /// admission sheds (full pipeline, exhausted per-key budget) or the
    /// pipeline is closed; spec validity is checked by the ingress
    /// stage (the ticket then carries the error).
    pub fn submit(&self, spec: FilterSpec, image: impl Into<ImagePayload>) -> Result<Ticket> {
        let (tx, rx) = mpsc::channel();
        let id = self.enqueue(spec, image.into(), None, tx)?;
        Ok(Ticket { id, rx })
    }

    /// Submit a two-payload request — the entry point for
    /// [`FilterOp::Reconstruct`] specs, whose `image` is the geodesic
    /// mask and `marker` the seed to propagate under it.  The ingress
    /// stage validates the pairing (reconstruct specs require a
    /// depth/shape-matched marker; every other spec must come without
    /// one), so a mispaired submission costs a ticket error, never an
    /// engine touch.
    pub fn submit_with_marker(
        &self,
        spec: FilterSpec,
        image: impl Into<ImagePayload>,
        marker: impl Into<ImagePayload>,
    ) -> Result<Ticket> {
        let (tx, rx) = mpsc::channel();
        let id = self.enqueue(spec, image.into(), Some(marker.into()), tx)?;
        Ok(Ticket { id, rx })
    }

    /// Submit a two-payload request and block for the result.
    pub fn filter_spec_with_marker(
        &self,
        spec: FilterSpec,
        image: impl Into<ImagePayload>,
        marker: impl Into<ImagePayload>,
    ) -> Result<FilterResponse> {
        self.submit_with_marker(spec, image, marker)?.wait()
    }

    /// Open a streaming submission handle: [`SubmitStream::send`]
    /// enqueues without blocking (no per-ticket channel), and
    /// [`SubmitStream::recv`] yields responses in *completion* order —
    /// the producer keeps the pipeline full while lanes drain whole
    /// same-key runs through their pinned plans.
    pub fn stream(&self) -> SubmitStream<'_> {
        let (tx, rx) = mpsc::channel();
        SubmitStream {
            coord: self,
            tx,
            rx,
            sent: 0,
            received: 0,
            shed: 0,
        }
    }

    /// Stream a whole batch of requests at once: every item is enqueued
    /// (items shed by backpressure are counted on the returned stream,
    /// [`SubmitStream::shed`]) and the stream then yields the
    /// responses.  Equivalent to `stream()` + `send` per item.
    pub fn submit_many<I>(&self, reqs: I) -> SubmitStream<'_>
    where
        I: IntoIterator<Item = (FilterSpec, ImagePayload)>,
    {
        let mut s = self.stream();
        for (spec, image) in reqs {
            let _ = s.send(spec, image);
        }
        s
    }

    /// Submit a spec and block for the result.
    pub fn filter_spec(
        &self,
        spec: FilterSpec,
        image: impl Into<ImagePayload>,
    ) -> Result<FilterResponse> {
        self.submit(spec, image)?.wait()
    }

    pub fn metrics(&self) -> Snapshot {
        self.metrics.snapshot()
    }

    /// Requests currently inside the pipeline (sum of live stage
    /// depths).
    pub fn queue_depth(&self) -> usize {
        self.metrics.pipeline_depth() as usize
    }

    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_deref()
    }

    /// Close admission, drain every stage and join the cascade.
    pub fn shutdown(mut self) {
        self.pipeline.shutdown();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.pipeline.shutdown();
    }
}

/// Streaming submission handle ([`Coordinator::stream`]): enqueue many
/// requests without a per-ticket channel, then collect responses in
/// completion order.
///
/// A stream is a single-producer handle (one per producer thread; the
/// coordinator itself is shared, `&Coordinator` is `Sync`).  Responses
/// are matched to submissions by [`request::FilterResponse::id`] — with
/// key-grouped batching, completion order is deliberately *not*
/// submission order.  Dropping a stream mid-flight is safe: in-flight
/// requests still execute and their responses are discarded (stages
/// never block on a gone consumer), so shutting the coordinator down
/// with a live-then-dropped stream drains gracefully.
pub struct SubmitStream<'c> {
    coord: &'c Coordinator,
    tx: mpsc::Sender<FilterResponse>,
    rx: mpsc::Receiver<FilterResponse>,
    sent: u64,
    received: u64,
    shed: u64,
}

impl SubmitStream<'_> {
    /// Enqueue one request (non-blocking; returns its id).  On
    /// backpressure the request is shed, counted, and the error
    /// returned — the stream stays usable.
    pub fn send(&mut self, spec: FilterSpec, image: impl Into<ImagePayload>) -> Result<u64> {
        match self.coord.enqueue(spec, image.into(), None, self.tx.clone()) {
            Ok(id) => {
                self.sent += 1;
                Ok(id)
            }
            Err(e) => {
                self.shed += 1;
                Err(e)
            }
        }
    }

    /// Requests successfully enqueued so far.
    pub fn sent(&self) -> u64 {
        self.sent
    }

    /// Requests rejected by backpressure so far.
    pub fn shed(&self) -> u64 {
        self.shed
    }

    /// Responses not yet received.
    pub fn in_flight(&self) -> u64 {
        self.sent - self.received
    }

    /// Block for the next completed response; `None` once every sent
    /// request has been received.  Cannot hang on accepted work: the
    /// pipeline answers every admitted request exactly once, turning
    /// even a panic while serving into an error response.
    pub fn recv(&mut self) -> Option<FilterResponse> {
        if self.received == self.sent {
            return None;
        }
        match self.rx.recv() {
            Ok(r) => {
                self.received += 1;
                Some(r)
            }
            Err(_) => None,
        }
    }

    /// Like [`SubmitStream::recv`] with an upper bound on the wait —
    /// `None` means nothing in flight *or* the timeout elapsed.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Option<FilterResponse> {
        if self.received == self.sent {
            return None;
        }
        match self.rx.recv_timeout(timeout) {
            Ok(r) => {
                self.received += 1;
                Some(r)
            }
            Err(_) => None,
        }
    }

    /// Non-blocking poll for a completed response.
    pub fn try_recv(&mut self) -> Option<FilterResponse> {
        if self.received == self.sent {
            return None;
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.received += 1;
                Some(r)
            }
            Err(_) => None,
        }
    }

    /// Block until every in-flight response has arrived and return them
    /// (completion order).
    pub fn drain(&mut self) -> Vec<FilterResponse> {
        let mut out = Vec::with_capacity(self.in_flight() as usize);
        while let Some(r) = self.recv() {
            out.push(r);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::image::Image;
    use crate::morphology::{self, Roi};
    use crate::neon::Native;

    #[test]
    fn native_coordinator_round_trip() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(32, 48, 5));
        let spec = FilterSpec::parse_op("erode", 5, 3).unwrap();
        let resp = coord.filter_spec(spec, img.clone()).unwrap();
        assert_eq!(resp.backend, "native");
        let want = morphology::erode(img.view(), 5, 3);
        assert!(resp.result.unwrap().into_u8().unwrap().same_pixels(&want));
        let snap = coord.metrics();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn u16_coordinator_round_trip() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise_u16(32, 48, 5));
        let spec = FilterSpec::parse_op("erode", 5, 3).unwrap();
        let resp = coord.filter_spec(spec, img.clone()).unwrap();
        assert_eq!(resp.backend, "native");
        let want = morphology::erode(img.view(), 5, 3);
        assert!(resp.result.unwrap().into_u16().unwrap().same_pixels(&want));
        let snap = coord.metrics();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn spec_submission_runs_chains_and_rois() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(40, 40, 9));
        // a derived op with a ROI — inexpressible by op name alone
        let spec = FilterSpec::new(FilterOp::TopHat, 5, 5).with_roi(Roi::new(3, 4, 20, 22));
        let resp = coord.filter_spec(spec, img.clone()).unwrap();
        let out = resp.result.unwrap().into_u8().unwrap();
        let full = morphology::parallel::tophat_native(&*img, 5, 5, &MorphConfig::default());
        assert!(out.same_pixels(&full.view().sub_rect(3, 4, 20, 22).to_image()));
        // a two-op chain
        let chain = FilterSpec::new(FilterOp::Open, 3, 3).then(FilterOp::Gradient);
        let resp = coord.filter_spec(chain, img.clone()).unwrap();
        let o = morphology::opening(&mut Native, &*img, 3, 3, &MorphConfig::default());
        let g = morphology::gradient(&mut Native, &o, 3, 3, &MorphConfig::default());
        assert!(resp.result.unwrap().into_u8().unwrap().same_pixels(&g));
        coord.shutdown();
    }

    #[test]
    fn mixed_depth_requests_batch_separately() {
        let coord = Coordinator::start_native(2).unwrap();
        let img8 = Arc::new(synth::noise(24, 24, 6));
        let img16 = Arc::new(synth::noise_u16(24, 24, 6));
        let spec = FilterSpec::new(FilterOp::Erode, 3, 3);
        let mut tickets = Vec::new();
        for i in 0..20 {
            let t = if i % 2 == 0 {
                coord.submit(spec, img8.clone()).unwrap()
            } else {
                coord.submit(spec, img16.clone()).unwrap()
            };
            tickets.push((i, t));
        }
        for (i, t) in tickets {
            let r = t.wait().unwrap();
            let out = r.result.unwrap();
            assert_eq!(out.dtype(), if i % 2 == 0 { "u8" } else { "u16" });
        }
        let snap = coord.metrics();
        assert_eq!(snap.completed, 20);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let coord = Coordinator::start_native(4).unwrap();
        let img = Arc::new(synth::noise(24, 24, 6));
        let tickets: Vec<_> = (0..40)
            .map(|i| {
                let op = if i % 2 == 0 { FilterOp::Erode } else { FilterOp::Dilate };
                coord.submit(FilterSpec::new(op, 3, 3), img.clone()).unwrap()
            })
            .collect();
        for t in tickets {
            let r = t.wait().unwrap();
            assert!(r.result.is_ok());
        }
        let snap = coord.metrics();
        assert_eq!(snap.completed, 40);
        assert!(snap.batches <= 40);
        coord.shutdown();
    }

    #[test]
    fn unknown_op_rejected_before_submission() {
        // the spec-only API surfaces bad op names before anything is
        // submitted: parse_op is the string-typed door
        let coord = Coordinator::start_native(1).unwrap();
        let err = FilterSpec::parse_op("sharpen", 3, 3).unwrap_err();
        assert!(format!("{err}").contains("unknown op"));
        assert_eq!(coord.metrics().failed, 0);
        assert_eq!(coord.metrics().submitted, 0);
        coord.shutdown();
    }

    #[test]
    fn invalid_spec_fails_at_ingress() {
        // spec validity (window parity, ROI bounds) is checked by the
        // ingress stage: the ticket completes with an error, the
        // failure is metered, and no engine is ever touched
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise(8, 8, 2));
        let resp = coord
            .filter_spec(FilterSpec::new(FilterOp::Erode, 4, 4), img.clone())
            .unwrap();
        assert!(resp.result.is_err());
        assert_eq!(resp.backend, "ingress");
        let resp = coord
            .filter_spec(
                FilterSpec::new(FilterOp::Erode, 3, 3).with_roi(Roi::new(6, 6, 5, 5)),
                img,
            )
            .unwrap();
        assert!(resp.result.is_err());
        let snap = coord.metrics();
        assert_eq!(snap.failed, 2);
        assert_eq!(snap.plan_resolutions, 0, "invalid specs never reach an engine");
        coord.shutdown();
    }

    #[test]
    fn backpressure_sheds_when_overloaded() {
        // 1 lane, tiny admission channel, many submissions of slow-ish
        // work: admission must shed, and only admission (every accepted
        // ticket still completes)
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            queue_capacity: 2,
            max_batch: 1,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let img = Arc::new(synth::paper_image(3));
        let spec = FilterSpec::new(FilterOp::Open, 15, 15);
        let mut shed = 0;
        let mut tickets = Vec::new();
        for _ in 0..64 {
            match coord.submit(spec, img.clone()) {
                Ok(t) => tickets.push(t),
                Err(_) => shed += 1,
            }
        }
        assert!(shed > 0, "expected at least one shed under overload");
        assert_eq!(coord.metrics().shed, shed);
        for t in tickets {
            assert!(t.wait().unwrap().result.is_ok());
        }
        coord.shutdown();
    }

    #[test]
    fn admission_budget_sheds_per_key_and_frees_on_reply() {
        // budget 2, one slow key: the 3rd same-key submission in flight
        // must shed with the budget error; once replies land, the key
        // admits again
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            max_batch: 1,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            admission_budget: 2,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let img = Arc::new(synth::paper_image(7));
        let spec = FilterSpec::new(FilterOp::Open, 15, 15);
        let t1 = coord.submit(spec, img.clone()).unwrap();
        let t2 = coord.submit(spec, img.clone()).unwrap();
        let err = coord.submit(spec, img.clone()).unwrap_err();
        assert!(
            format!("{err:#}").contains("admission budget"),
            "unexpected shed error: {err:#}"
        );
        // a different key is not throttled by the hot key's budget
        let other = coord
            .submit(FilterSpec::new(FilterOp::Erode, 3, 3), Arc::new(synth::noise(16, 16, 1)))
            .unwrap();
        assert!(other.wait().unwrap().result.is_ok());
        assert!(t1.wait().unwrap().result.is_ok());
        assert!(t2.wait().unwrap().result.is_ok());
        // both replies landed: the key's budget slots are free again
        let t3 = coord.submit(spec, img).unwrap();
        assert!(t3.wait().unwrap().result.is_ok());
        assert_eq!(coord.metrics().shed, 1);
        coord.shutdown();
    }

    #[test]
    fn reconstruct_round_trip_validates_marker_pairing() {
        let coord = Coordinator::start_native(2).unwrap();
        let mask = Arc::new(synth::noise(24, 32, 0x33));
        let mut seed = Image::<u8>::zeros(24, 32);
        seed.row_mut(0).copy_from_slice(mask.row(0));
        let marker = Arc::new(seed);
        let spec = FilterSpec::new(FilterOp::Reconstruct, 3, 3);
        let resp = coord
            .filter_spec_with_marker(spec, mask.clone(), marker.clone())
            .unwrap();
        assert_eq!(resp.backend, "native");
        let (want, _) = morphology::reconstruct_by_dilation(
            &**marker,
            &**mask,
            3,
            3,
            &MorphConfig::default(),
        )
        .unwrap();
        assert!(resp.result.unwrap().into_u8().unwrap().same_pixels(&want));
        // markerless reconstruct fails at ingress without an engine touch
        let r = coord.filter_spec(spec, mask.clone()).unwrap();
        assert!(r.result.is_err());
        assert_eq!(r.backend, "ingress");
        // a marker on a non-reconstruct spec fails the same way
        let r = coord
            .filter_spec_with_marker(FilterSpec::new(FilterOp::Erode, 3, 3), mask.clone(), marker)
            .unwrap();
        assert!(r.result.is_err());
        // shape-mismatched marker
        let r = coord
            .filter_spec_with_marker(spec, mask, Arc::new(synth::noise(8, 8, 1)))
            .unwrap();
        assert!(r.result.is_err());
        let snap = coord.metrics();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 3);
        coord.shutdown();
    }

    #[test]
    fn transpose_request_swaps_dims() {
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise(10, 20, 8));
        let spec = FilterSpec::parse_op("transpose", 0, 0).unwrap();
        let out = coord
            .filter_spec(spec, img.clone())
            .unwrap()
            .result
            .unwrap()
            .into_u8()
            .unwrap();
        assert_eq!((out.height(), out.width()), (20, 10));
        let want = crate::transpose::transpose_image(&mut Native, img.view());
        assert!(out.same_pixels(&want));
        coord.shutdown();
    }

    #[test]
    fn u16_transpose_uses_8x8_tiles_end_to_end() {
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise_u16(16, 24, 8));
        let spec = FilterSpec::parse_op("transpose", 0, 0).unwrap();
        let out = coord
            .filter_spec(spec, img.clone())
            .unwrap()
            .result
            .unwrap()
            .into_u16()
            .unwrap();
        assert_eq!((out.height(), out.width()), (24, 16));
        let want = crate::transpose::transpose_image_u16(&mut Native, &*img);
        assert!(out.same_pixels(&want));
        coord.shutdown();
    }

    #[test]
    fn drop_shuts_down_workers() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(8, 8, 1));
        let _ = coord.filter_spec(FilterSpec::parse_op("erode", 3, 3).unwrap(), img);
        drop(coord); // must not hang
    }

    #[test]
    fn stream_round_trips_and_matches_submit() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(24, 28, 0x51));
        let specs = [
            FilterSpec::new(FilterOp::Erode, 5, 3),
            FilterSpec::new(FilterOp::Gradient, 3, 3),
            FilterSpec::new(FilterOp::TopHat, 5, 5).with_roi(Roi::new(5, 6, 10, 12)),
        ];
        let mut stream = coord.stream();
        let mut want_by_id = std::collections::HashMap::new();
        for _ in 0..4 {
            for spec in specs {
                let id = stream.send(spec, img.clone()).unwrap();
                // oracle: the fire-and-wait path
                let want = coord
                    .filter_spec(spec, img.clone())
                    .unwrap()
                    .result
                    .unwrap()
                    .into_u8()
                    .unwrap();
                want_by_id.insert(id, want);
            }
        }
        assert_eq!(stream.sent(), 12);
        let responses = stream.drain();
        assert_eq!(responses.len(), 12);
        assert_eq!(stream.in_flight(), 0);
        for r in responses {
            let got = r.result.unwrap().into_u8().unwrap();
            let want = want_by_id.remove(&r.id).expect("unknown response id");
            assert!(got.same_pixels(&want), "request {}", r.id);
        }
        assert!(want_by_id.is_empty());
        // recv on a drained stream is None, not a hang
        assert!(stream.recv().is_none());
        drop(stream);
        coord.shutdown();
    }

    #[test]
    fn submit_many_counts_sheds_and_still_yields_accepted() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            queue_capacity: 2,
            max_batch: 1,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let img = Arc::new(synth::paper_image(9));
        let spec = FilterSpec::new(FilterOp::Open, 15, 15);
        let reqs: Vec<_> = (0..32)
            .map(|_| (spec, ImagePayload::from(img.clone())))
            .collect();
        let mut stream = coord.submit_many(reqs);
        let accepted = stream.sent();
        let shed = stream.shed();
        assert_eq!(accepted + shed, 32);
        let responses = stream.drain();
        assert_eq!(responses.len(), accepted as usize);
        assert!(responses.iter().all(|r| r.result.is_ok()));
        drop(stream);
        coord.shutdown();
    }

    #[test]
    fn dropping_stream_mid_flight_shuts_down_gracefully() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::paper_image(3));
        {
            let mut stream = coord.stream();
            for _ in 0..24 {
                let _ = stream.send(FilterSpec::new(FilterOp::Close, 9, 9), img.clone());
            }
            // consume a couple, then abandon the rest in flight
            let _ = stream.recv_timeout(Duration::from_secs(30));
            let _ = stream.try_recv();
        } // stream dropped here with work still queued/executing
        coord.shutdown(); // must drain and join without hanging
    }

    #[test]
    fn roi_sweep_over_stream_resolves_one_plan() {
        // streaming + position-independent plans: a same-shape interior
        // crop sweep is served by exactly one resolution; warm-ahead
        // doubles the touch count (each request = 1 warm + 1 exec), so
        // G requests score 1 resolution + 2G−1 hits
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let img = Arc::new(synth::noise(64, 64, 0x77));
        let base = FilterSpec::new(FilterOp::Erode, 5, 5); // halo (2, 2)
        let full = morphology::erode(img.view(), 5, 5);
        let mut stream = coord.stream();
        let mut wants = std::collections::HashMap::new();
        for (y, x) in [(2usize, 2usize), (10, 30), (30, 10), (64 - 16 - 2, 64 - 16 - 2)] {
            let id = stream.send(base.with_roi(Roi::new(y, x, 16, 16)), img.clone()).unwrap();
            wants.insert(id, full.view().sub_rect(y, x, 16, 16).to_image());
        }
        for r in stream.drain() {
            let got = r.result.unwrap().into_u8().unwrap();
            assert!(got.same_pixels(&wants[&r.id]));
        }
        drop(stream);
        let snap = coord.metrics();
        assert_eq!(snap.completed, 4);
        assert_eq!(snap.plan_resolutions, 1, "one plan must serve the sweep");
        assert_eq!(snap.plan_hits, 7, "4 warms + 4 executions − 1 resolution");
        assert!((snap.plan_resolutions_per_request() - 0.25).abs() < 1e-12);
        coord.shutdown();
    }

    fn pending_of(id: u64, spec: FilterSpec, img: &Arc<Image<u8>>) -> (Pending, mpsc::Receiver<FilterResponse>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                req: request::FilterRequest {
                    id,
                    spec,
                    image: ImagePayload::from(img.clone()),
                    marker: None,
                    enqueued: Instant::now(),
                },
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn fused_batch_serves_every_request_bit_identically() {
        // deterministic fused-path test: hand serve_fused a batch
        // directly instead of racing the queue's batch splits
        let cfg = CoordinatorConfig {
            workers: 1,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            ..CoordinatorConfig::default()
        };
        let mut native = NativeEngine::new(cfg.morph);
        let metrics = Metrics::default();
        let spec = FilterSpec::new(FilterOp::TopHat, 5, 3);
        let imgs: Vec<Arc<Image<u8>>> =
            (0..6).map(|i| Arc::new(synth::noise(24, 32, 0xF00 + i))).collect();
        let mut rxs = Vec::new();
        let batch: Vec<Pending> = imgs
            .iter()
            .enumerate()
            .map(|(i, img)| {
                let (p, rx) = pending_of(i as u64, spec, img);
                rxs.push(rx);
                p
            })
            .collect();
        let serveds = pipeline::serve_fused(0, &cfg, &None, &mut native, &None, &metrics, batch)
            .unwrap_or_else(|_| panic!("full-image multi-request batch must fuse"));
        assert_eq!(serveds.len(), 6);
        for s in serveds {
            pipeline::finish(&metrics, s);
        }
        for (i, (img, rx)) in imgs.iter().zip(&rxs).enumerate() {
            let r = rx.try_recv().expect("fused batch must answer every request");
            assert_eq!(r.id, i as u64);
            assert_eq!(r.backend, "native");
            let got = r.result.unwrap().into_u8().unwrap();
            let want =
                morphology::parallel::tophat_native(img.view(), 5, 3, &MorphConfig::default());
            assert!(got.same_pixels(&want), "request {i}");
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.completed, 6);
        assert_eq!(snap.fused_batches, 1);
        assert_eq!(snap.fused_requests, 6);
        // ineligible batches come back untouched: singletons…
        let (p, _rx) = pending_of(9, spec, &imgs[0]);
        assert!(
            pipeline::serve_fused(0, &cfg, &None, &mut native, &None, &metrics, vec![p]).is_err()
        );
        // …and ROI specs
        let roi_spec = spec.with_roi(Roi::new(2, 2, 8, 8));
        let batch: Vec<Pending> = (0..2)
            .map(|i| pending_of(10 + i, roi_spec, &imgs[0]).0)
            .collect();
        assert!(
            pipeline::serve_fused(0, &cfg, &None, &mut native, &None, &metrics, batch).is_err()
        );
        assert_eq!(metrics.snapshot().fused_batches, 1);
    }

    #[test]
    fn fused_stream_keeps_split_independent_plan_counts() {
        // end-to-end: however the queue splits a same-key stream into
        // batches (fused or not), the family resolves exactly once —
        // warm-ahead included, every request is 1 warm + 1 exec touch
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let spec = FilterSpec::new(FilterOp::Gradient, 5, 5);
        let imgs: Vec<Arc<Image<u8>>> =
            (0..8).map(|i| Arc::new(synth::noise(32, 40, 0xBEEF + i))).collect();
        let mut stream = coord.stream();
        let mut wants = std::collections::HashMap::new();
        for img in &imgs {
            let id = stream.send(spec, img.clone()).unwrap();
            wants.insert(
                id,
                morphology::parallel::gradient_native(img.view(), 5, 5, &MorphConfig::default()),
            );
        }
        for r in stream.drain() {
            let got = r.result.unwrap().into_u8().unwrap();
            assert!(got.same_pixels(&wants[&r.id]), "request {}", r.id);
        }
        drop(stream);
        let snap = coord.metrics();
        assert_eq!(snap.completed, 8);
        assert_eq!(snap.plan_resolutions, 1, "one family, one resolution");
        assert_eq!(snap.plan_hits, 15, "8 warms + 8 executions − 1 resolution");
        // fused counters are split-dependent (producer/worker race), but
        // they can never disagree with each other
        assert!(snap.fused_requests >= 2 * snap.fused_batches);
        coord.shutdown();
    }

    #[test]
    fn capped_spec_clamps_parallelism_bit_identically() {
        use crate::morphology::Parallelism;
        use pipeline::capped_spec;
        let img8: ImagePayload = Arc::new(synth::paper_image(5)).into();
        let auto = FilterSpec::new(FilterOp::Erode, 31, 31);
        // cap 1: Auto collapses to Sequential
        assert_eq!(
            capped_spec(&auto, &img8, 1).config.parallelism,
            Parallelism::Sequential
        );
        // unlimited: untouched
        assert_eq!(capped_spec(&auto, &img8, 0), auto);
        // Fixed above the cap clamps; below it passes through
        let mut f8 = auto;
        f8.config.parallelism = Parallelism::Fixed(8);
        assert_eq!(
            capped_spec(&f8, &img8, 2).config.parallelism,
            Parallelism::Fixed(2)
        );
        assert_eq!(
            capped_spec(&f8, &img8, 16).config.parallelism,
            Parallelism::Fixed(8)
        );
        // Sequential is never promoted
        let mut seq = auto;
        seq.config.parallelism = Parallelism::Sequential;
        assert_eq!(
            capped_spec(&seq, &img8, 4).config.parallelism,
            Parallelism::Sequential
        );
        // a tiny image's Auto stays Auto under a generous cap (the cost
        // model keeps it sequential anyway)
        let tiny: ImagePayload = Arc::new(synth::noise(16, 16, 1)).into();
        let small = FilterSpec::new(FilterOp::Erode, 3, 3);
        assert_eq!(
            capped_spec(&small, &tiny, 4).config.parallelism,
            Parallelism::Auto
        );
        // a small interior crop of a BIG image prices its haloed block,
        // not the full image: Auto must survive the cap (the block
        // dispatches sequentially; pinning Fixed(cap) would force
        // banding overhead onto every streamed crop)
        let crop = FilterSpec::new(FilterOp::Erode, 5, 5).with_roi(Roi::new(100, 100, 24, 24));
        assert_eq!(
            capped_spec(&crop, &img8, 2).config.parallelism,
            Parallelism::Auto
        );
        // and the clamp never changes pixels: serve the same request
        // through coordinators with different caps
        let img = Arc::new(synth::noise(80, 96, 0xBEEF));
        let mut outs = Vec::new();
        for cap in [1usize, 2, 0] {
            let coord = Coordinator::start(CoordinatorConfig {
                workers: 1,
                backend: BackendChoice::NativeOnly,
                artifact_dir: None,
                max_bands_per_request: cap,
                ..CoordinatorConfig::default()
            })
            .unwrap();
            let r = coord.filter_spec(auto, img.clone()).unwrap();
            outs.push(r.result.unwrap().into_u8().unwrap());
            coord.shutdown();
        }
        assert!(outs[0].same_pixels(&outs[1]));
        assert!(outs[0].same_pixels(&outs[2]));
    }
}
