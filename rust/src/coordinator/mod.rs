//! L3 coordinator: the morphology filtering service.
//!
//! Architecture (std threads; the offline build has no tokio, and the
//! PJRT CPU client is synchronous anyway):
//!
//! ```text
//!  submit(FilterSpec, payload) ──► BatchQueue (bounded, key-grouped,
//!     │               │            FIFO-aged across keys)
//!     └─ Ticket ◄─────┘                        worker 0 ─► reply
//!  stream() ──► SubmitStream::send ──► same queue, one shared
//!     │                                reply channel per stream
//!     └─ SubmitStream::recv ◄── completions, any order, tagged by id
//! ```
//!
//! Requests carry a full [`crate::morphology::FilterSpec`] — op chain
//! (including derived ops and multi-op pipelines), window,
//! configuration and optional ROI — through **one** depth-erased
//! submission path.  [`Coordinator::submit`] is the fire-and-wait form
//! (one ticket, one reply channel); [`Coordinator::stream`] /
//! [`Coordinator::submit_many`] are the **streaming** form: producers
//! enqueue without blocking per ticket and responses flow back over one
//! shared channel in *completion* order (each
//! [`request::FilterResponse`] carries its request id).  The historical
//! per-op × per-depth surface (`filter`/`filter_u16` with string ops)
//! survives as thin wrappers that build single-op specs with the
//! coordinator's default [`MorphConfig`].
//!
//! ## Plan-pinned worker batches
//!
//! Each worker owns its engines — an optional [`XlaRuntime`] (PJRT,
//! executing the python-AOT artifacts; `PjRtLoadedExecutable` is not
//! `Sync`, so runtimes are never shared) and a [`NativeEngine`] (§5.3
//! hybrid morphology behind a **plan cache** keyed on the *canonical*
//! spec, [`crate::morphology::FilterSpec::canonical_for`]).  A worker
//! pulls a same-key batch, the first request resolves the plan, and the
//! whole batch — plus every following same-key batch the affinity pull
//! keeps returning — runs **pinned to that one plan**.  Because plans
//! are position-independent, this holds across an ROI crop *sweep*: all
//! interior same-shape crops hit one plan (`plan_resolutions` /
//! `plan_hits` in [`metrics::Snapshot`] meter it; `BENCH_serve.json`
//! gates resolutions-per-request in CI).  The queue's FIFO aging
//! ([`queue`]) bounds how long a pinned worker may ride one hot key
//! while colder keys wait.
//!
//! ## Fused super-passes
//!
//! A pulled batch of **more than one** full-image, non-transpose,
//! native-routed request is served as ONE fused execution
//! ([`NativeEngine::run_spec_batch`] →
//! [`crate::morphology::FusedPlan`]): the batch's images stack into a
//! virtual `n·h × w` image, bands span image boundaries, and a single
//! fork-join runs the whole batch — amortizing per-pass fork overhead
//! that small images otherwise pay per request.  Outputs are
//! bit-identical to per-image serving; [`metrics::Metrics`] counts
//! `fused_batches` / `fused_requests`.  ROI or transpose specs, mixed
//! shapes, XLA-routed batches and singletons keep the per-request path.
//!
//! The **router** picks per request: an artifact match on the XLA
//! backend when available (single-op, no-ROI, u8 specs only — the only
//! shapes the AOT pipeline lowers), native otherwise (or as directed by
//! [`BackendChoice`]).
//!
//! Depth routing: payloads are depth-tagged
//! ([`request::ImagePayload`]); batch keys include the dtype so batches
//! never mix depths, and u16 requests always execute on the native
//! engine (and fail under [`BackendChoice::XlaOnly`]).
//!
//! Spec validation happens on the worker: an invalid spec (even window,
//! out-of-bounds ROI) completes its ticket with an error result and
//! counts toward the `failed` metric, exactly like the stringly
//! "unknown op" requests of the previous API.
//!
//! ## Band budget
//!
//! Native plans band-shard large images across the process-wide
//! [`crate::morphology::parallel::BandPool`].  Under streaming load,
//! `workers` concurrent requests each banding to the full pool would
//! oversubscribe every core, so
//! [`CoordinatorConfig::max_bands_per_request`] caps the bands any one
//! request may use — by default `cores / workers` (so
//! `workers × max_bands_per_request ≤ cores`), overridable in the
//! config or with the `NEON_MORPH_MAX_BANDS` environment variable (and
//! `NEON_MORPH_BAND_WORKERS` sizes the pool itself,
//! [`crate::morphology::parallel::BandPool::with_workers`]).  The cap
//! only clamps the band *count*; outputs stay bit-identical.

pub mod metrics;
pub mod queue;
pub mod request;

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::image::Image;
use crate::morphology::{parallel, FilterOp, FilterSpec, MorphConfig, Parallelism};
use crate::runtime::{Engine, Manifest, NativeEngine, XlaRuntime};
use metrics::{Metrics, Snapshot};
use queue::{BatchQueue, Pull};
use request::{BatchKey, FilterOutput, FilterResponse, ImagePayload, Pending, PixelDepth, Ticket};

/// Which engine(s) the router may use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// XLA for specs with artifacts, native for everything else.
    Auto,
    /// Never touch PJRT (no artifacts needed).
    NativeOnly,
    /// Only run requests that have a compiled artifact; others fail.
    XlaOnly,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Bound on queued requests (backpressure limit).
    pub queue_capacity: usize,
    /// Max same-key requests a worker takes per pull.
    pub max_batch: usize,
    pub backend: BackendChoice,
    /// Artifact directory (required unless `NativeOnly`).
    pub artifact_dir: Option<PathBuf>,
    /// Default configuration applied by the legacy string-op wrappers
    /// (`filter`/`filter_u16`); spec submissions carry their own.
    pub morph: MorphConfig,
    /// Compile all artifacts at startup instead of lazily.
    pub precompile: bool,
    /// Intra-image band budget per request: no single request may shard
    /// across more bands than this, so one giant image cannot
    /// monopolize the shared [`parallel::BandPool`] under streaming
    /// load.  `0` (the default) derives `cores / workers` (≥ 1) at
    /// startup, keeping `workers × max_bands_per_request ≤ cores`; a
    /// nonzero `NEON_MORPH_MAX_BANDS` environment variable overrides
    /// both (`0` in the env also means "derive").  Clamping the band
    /// count never changes output pixels.
    pub max_bands_per_request: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: std::thread::available_parallelism().map_or(2, |n| n.get().min(8)),
            queue_capacity: 1024,
            max_batch: 16,
            backend: BackendChoice::Auto,
            artifact_dir: Some(PathBuf::from("artifacts")),
            morph: MorphConfig::default(),
            precompile: false,
            max_bands_per_request: 0,
        }
    }
}

/// Resolve the effective per-request band cap for `cfg` (see
/// [`CoordinatorConfig::max_bands_per_request`]).
fn resolve_band_cap(cfg: &CoordinatorConfig) -> usize {
    // env 0 means the same as config 0 — "derive" — never "cap at 1"
    if let Some(n) = std::env::var("NEON_MORPH_MAX_BANDS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|n| *n > 0)
    {
        return n;
    }
    if cfg.max_bands_per_request > 0 {
        return cfg.max_bands_per_request;
    }
    let cores = std::thread::available_parallelism().map_or(2, |n| n.get());
    (cores / cfg.workers.max(1)).max(1)
}

/// The running service.
pub struct Coordinator {
    queue: Arc<BatchQueue>,
    metrics: Arc<Metrics>,
    manifest: Option<Arc<Manifest>>,
    default_morph: MorphConfig,
    next_id: AtomicU64,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn workers and return the running coordinator.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let manifest = match (&cfg.backend, &cfg.artifact_dir) {
            (BackendChoice::NativeOnly, _) => None,
            (_, Some(dir)) => match Manifest::load(dir) {
                Ok(m) => Some(Arc::new(m)),
                Err(e) if cfg.backend == BackendChoice::XlaOnly => {
                    return Err(e.context("XlaOnly backend requires artifacts"));
                }
                Err(_) => None, // Auto degrades to native
            },
            (BackendChoice::XlaOnly, None) => {
                return Err(anyhow!("XlaOnly backend requires artifact_dir"));
            }
            (_, None) => None,
        };

        let queue = Arc::new(BatchQueue::new(cfg.queue_capacity, cfg.max_batch));
        let metrics = Arc::new(Metrics::default());
        let mut workers = Vec::new();
        // workers see the *resolved* band budget (default: cores/workers)
        let band_cap = resolve_band_cap(&cfg);
        for wid in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let manifest = manifest.clone();
            let mut cfg = cfg.clone();
            cfg.max_bands_per_request = band_cap;
            let handle = std::thread::Builder::new()
                .name(format!("morph-worker-{wid}"))
                .spawn(move || worker_loop(wid, &cfg, manifest, &queue, &metrics))
                .context("spawning worker")?;
            workers.push(handle);
        }
        Ok(Coordinator {
            queue,
            metrics,
            manifest,
            default_morph: cfg.morph,
            next_id: AtomicU64::new(1),
            workers,
        })
    }

    /// Convenience: start with defaults and `NativeOnly` backend.
    pub fn start_native(workers: usize) -> Result<Coordinator> {
        Coordinator::start(CoordinatorConfig {
            workers,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            ..CoordinatorConfig::default()
        })
    }

    /// Enqueue one request whose response goes to `reply` — the shared
    /// non-blocking core of [`Coordinator::submit`] (fresh channel per
    /// ticket) and [`SubmitStream::send`] (one channel per stream).
    fn enqueue(
        &self,
        spec: FilterSpec,
        image: ImagePayload,
        reply: mpsc::Sender<FilterResponse>,
    ) -> Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let pending = Pending {
            req: request::FilterRequest {
                id,
                spec,
                image,
                enqueued: Instant::now(),
            },
            reply,
        };
        match self.queue.push(pending) {
            Ok(()) => {
                Metrics::inc(&self.metrics.submitted);
                Ok(id)
            }
            Err(_) => {
                Metrics::inc(&self.metrics.shed);
                Err(anyhow!("queue full: request shed (backpressure)"))
            }
        }
    }

    /// Submit a spec with a depth-tagged payload — the one submission
    /// path for every op chain, depth and ROI.  Fails fast when the
    /// queue is full (backpressure) or closed; spec validity is checked
    /// by the executing worker (the ticket then carries the error).
    pub fn submit(&self, spec: FilterSpec, image: impl Into<ImagePayload>) -> Result<Ticket> {
        let (tx, rx) = mpsc::channel();
        let id = self.enqueue(spec, image.into(), tx)?;
        Ok(Ticket { id, rx })
    }

    /// Open a streaming submission handle: [`SubmitStream::send`]
    /// enqueues without blocking (no per-ticket channel), and
    /// [`SubmitStream::recv`] yields responses in *completion* order —
    /// the producer keeps the queue full while workers drain whole
    /// same-key runs through their pinned plans.
    pub fn stream(&self) -> SubmitStream<'_> {
        let (tx, rx) = mpsc::channel();
        SubmitStream {
            coord: self,
            tx,
            rx,
            sent: 0,
            received: 0,
            shed: 0,
        }
    }

    /// Stream a whole batch of requests at once: every item is enqueued
    /// (items shed by backpressure are counted on the returned stream,
    /// [`SubmitStream::shed`]) and the stream then yields the
    /// responses.  Equivalent to `stream()` + `send` per item.
    pub fn submit_many<I>(&self, reqs: I) -> SubmitStream<'_>
    where
        I: IntoIterator<Item = (FilterSpec, ImagePayload)>,
    {
        let mut s = self.stream();
        for (spec, image) in reqs {
            let _ = s.send(spec, image);
        }
        s
    }

    /// Submit a spec and block for the result.
    pub fn filter_spec(
        &self,
        spec: FilterSpec,
        image: impl Into<ImagePayload>,
    ) -> Result<FilterResponse> {
        self.submit(spec, image)?.wait()
    }

    /// Build the single-op spec a legacy string-op call denotes, using
    /// the coordinator's default morph configuration.
    fn legacy_spec(&self, op: &str, w_x: usize, w_y: usize) -> Result<FilterSpec> {
        let op: FilterOp = op.parse().map_err(|e| anyhow!("{e}"))?;
        Ok(FilterSpec::new(op, w_x, w_y).with_config(self.default_morph))
    }

    /// Legacy wrapper: submit a u8 request by op name and block for the
    /// result.  Bit-identical to `filter_spec` with the equivalent
    /// single-op spec.
    pub fn filter(
        &self,
        op: &str,
        w_x: usize,
        w_y: usize,
        image: Arc<Image<u8>>,
    ) -> Result<FilterResponse> {
        self.filter_spec(self.legacy_spec(op, w_x, w_y)?, image)
    }

    /// Legacy wrapper: submit a u16 request by op name and block.
    pub fn filter_u16(
        &self,
        op: &str,
        w_x: usize,
        w_y: usize,
        image: Arc<Image<u16>>,
    ) -> Result<FilterResponse> {
        self.filter_spec(self.legacy_spec(op, w_x, w_y)?, image)
    }

    pub fn metrics(&self) -> Snapshot {
        self.metrics.snapshot()
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_deref()
    }

    /// Close the queue, drain and join workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Streaming submission handle ([`Coordinator::stream`]): enqueue many
/// requests without a per-ticket channel, then collect responses in
/// completion order.
///
/// A stream is a single-producer handle (one per producer thread; the
/// coordinator itself is shared, `&Coordinator` is `Sync`).  Responses
/// are matched to submissions by [`request::FilterResponse::id`] — with
/// key-grouped batching, completion order is deliberately *not*
/// submission order.  Dropping a stream mid-flight is safe: in-flight
/// requests still execute and their responses are discarded (workers
/// never block on a gone consumer), so shutting the coordinator down
/// with a live-then-dropped stream drains gracefully.
pub struct SubmitStream<'c> {
    coord: &'c Coordinator,
    tx: mpsc::Sender<FilterResponse>,
    rx: mpsc::Receiver<FilterResponse>,
    sent: u64,
    received: u64,
    shed: u64,
}

impl SubmitStream<'_> {
    /// Enqueue one request (non-blocking; returns its id).  On
    /// backpressure the request is shed, counted, and the error
    /// returned — the stream stays usable.
    pub fn send(&mut self, spec: FilterSpec, image: impl Into<ImagePayload>) -> Result<u64> {
        match self.coord.enqueue(spec, image.into(), self.tx.clone()) {
            Ok(id) => {
                self.sent += 1;
                Ok(id)
            }
            Err(e) => {
                self.shed += 1;
                Err(e)
            }
        }
    }

    /// Requests successfully enqueued so far.
    pub fn sent(&self) -> u64 {
        self.sent
    }

    /// Requests rejected by backpressure so far.
    pub fn shed(&self) -> u64 {
        self.shed
    }

    /// Responses not yet received.
    pub fn in_flight(&self) -> u64 {
        self.sent - self.received
    }

    /// Block for the next completed response; `None` once every sent
    /// request has been received.  Cannot hang on accepted work: the
    /// worker loop answers every enqueued request exactly once, turning
    /// even a panic while serving into an error response.
    pub fn recv(&mut self) -> Option<FilterResponse> {
        if self.received == self.sent {
            return None;
        }
        match self.rx.recv() {
            Ok(r) => {
                self.received += 1;
                Some(r)
            }
            Err(_) => None,
        }
    }

    /// Like [`SubmitStream::recv`] with an upper bound on the wait —
    /// `None` means nothing in flight *or* the timeout elapsed.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Option<FilterResponse> {
        if self.received == self.sent {
            return None;
        }
        match self.rx.recv_timeout(timeout) {
            Ok(r) => {
                self.received += 1;
                Some(r)
            }
            Err(_) => None,
        }
    }

    /// Non-blocking poll for a completed response.
    pub fn try_recv(&mut self) -> Option<FilterResponse> {
        if self.received == self.sent {
            return None;
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.received += 1;
                Some(r)
            }
            Err(_) => None,
        }
    }

    /// Block until every in-flight response has arrived and return them
    /// (completion order).
    pub fn drain(&mut self) -> Vec<FilterResponse> {
        let mut out = Vec::with_capacity(self.in_flight() as usize);
        while let Some(r) = self.recv() {
            out.push(r);
        }
        out
    }
}

fn worker_loop(
    wid: usize,
    cfg: &CoordinatorConfig,
    manifest: Option<Arc<Manifest>>,
    queue: &BatchQueue,
    metrics: &Metrics,
) {
    let mut native = NativeEngine::new(cfg.morph);
    let mut xla: Option<XlaRuntime> = match (&cfg.backend, &cfg.artifact_dir, &manifest) {
        (BackendChoice::NativeOnly, _, _) | (_, _, None) => None,
        (_, Some(dir), Some(_)) => XlaRuntime::new(dir).ok(),
        (_, None, _) => None,
    };
    if cfg.precompile {
        if let Some(rt) = xla.as_mut() {
            let _ = rt.precompile(|_| true);
        }
    }

    let mut affinity: Option<BatchKey> = None;
    loop {
        match queue.pull(affinity.as_ref(), Duration::from_millis(100)) {
            Pull::Closed => break,
            Pull::Batch(batch) => {
                Metrics::inc(&metrics.batches);
                metrics
                    .batched_requests
                    .fetch_add(batch.len() as u64, Ordering::Relaxed);
                affinity = batch.first().map(|p| p.req.batch_key());
                // a same-key batch of full-image native-routed requests
                // runs as ONE fused super-pass; everything else (below)
                // serves per request
                let batch = match try_serve_fused(
                    wid, cfg, &manifest, &mut native, &xla, metrics, batch,
                ) {
                    Ok(()) => Vec::new(),
                    Err(batch) => batch,
                };
                for p in batch {
                    let id = p.req.id;
                    let reply = p.reply.clone();
                    // a panic while serving must not kill the worker or
                    // orphan the request: streaming consumers block on
                    // one reply per send (a per-ticket channel would at
                    // least disconnect; the stream's shared channel
                    // cannot), so every Pending is answered exactly once
                    let panicked = catch_unwind(AssertUnwindSafe(|| {
                        serve_one(wid, cfg, &manifest, &mut native, &mut xla, metrics, p);
                    }))
                    .is_err();
                    if panicked {
                        // the engine may hold half-updated state (a plan
                        // arena taken mid-execution): rebuild it rather
                        // than reuse poisoned plans — draining its
                        // counters first, so the pre-panic requests stay
                        // in the metrics (resolutions + hits must keep
                        // accounting for every native-served request)
                        let stats = native.take_plan_stats();
                        metrics
                            .plan_resolutions
                            .fetch_add(stats.resolutions, Ordering::Relaxed);
                        metrics.plan_hits.fetch_add(stats.hits, Ordering::Relaxed);
                        native = NativeEngine::new(cfg.morph);
                        Metrics::inc(&metrics.failed);
                        let _ = reply.send(FilterResponse {
                            id,
                            result: Err(anyhow!(
                                "worker {wid} panicked while serving request {id}"
                            )),
                            queue_ns: 0,
                            exec_ns: 0,
                            backend: "panic",
                            worker: wid,
                        });
                    }
                }
                // aggregate this batch's plan-cache traffic: a same-key
                // run pinned to one plan shows up as 1 resolution + N-1
                // hits here
                let stats = native.take_plan_stats();
                metrics
                    .plan_resolutions
                    .fetch_add(stats.resolutions, Ordering::Relaxed);
                metrics.plan_hits.fetch_add(stats.hits, Ordering::Relaxed);
            }
        }
    }
}

/// Serve a whole same-key batch through the native engine's fused
/// super-pass ([`NativeEngine::run_spec_batch`]) when every request
/// would route native anyway.  The queue guarantees one `BatchKey` per
/// batch (same spec, shape and depth), so eligibility is a per-batch
/// decision: more than one request, a full-image non-transpose spec,
/// and no compiled-artifact route that could peel the batch onto the
/// XLA backend.  Returns the batch untouched (`Err`) when ineligible
/// and the caller serves it per request.
///
/// The fused run executes under the same [`capped_spec`] clamp as
/// per-request serving; its one band fork is shared by every request in
/// the batch, so per-request band pressure only drops relative to
/// per-image serving.  Outputs stay bit-identical either way.  The
/// super-pass execution time is attributed to requests in equal shares
/// (`exec_ns = total / n`).
fn try_serve_fused(
    wid: usize,
    cfg: &CoordinatorConfig,
    manifest: &Option<Arc<Manifest>>,
    native: &mut NativeEngine,
    xla: &Option<XlaRuntime>,
    metrics: &Metrics,
    batch: Vec<Pending>,
) -> std::result::Result<(), Vec<Pending>> {
    if batch.len() < 2 {
        return Err(batch);
    }
    let spec = batch[0].req.spec;
    if spec.roi.is_some() || spec.is_transpose() || cfg.backend == BackendChoice::XlaOnly {
        return Err(batch);
    }
    let (h, w) = (batch[0].req.image.height(), batch[0].req.image.width());
    // under Auto an artifact match routes u8 requests to the XLA
    // runtime — leave those batches to the per-request router
    if let (ImagePayload::U8(_), Some(op)) = (&batch[0].req.image, spec.single_identity_op()) {
        let has_artifact = xla.is_some()
            && manifest
                .as_ref()
                .is_some_and(|m| m.find(op.name(), h, w, spec.w_x, spec.w_y).is_some());
        if has_artifact {
            return Err(batch);
        }
    }

    let n = batch.len();
    let native_spec = capped_spec(&spec, &batch[0].req.image, cfg.max_bands_per_request);
    let queue_ns: Vec<u64> = batch
        .iter()
        .map(|p| p.req.enqueued.elapsed().as_nanos() as u64)
        .collect();
    let t = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| match &batch[0].req.image {
        ImagePayload::U8(_) => {
            let imgs: Vec<&Image<u8>> = batch
                .iter()
                .map(|p| match &p.req.image {
                    ImagePayload::U8(im) => &**im,
                    ImagePayload::U16(_) => unreachable!("batch keys include the dtype"),
                })
                .collect();
            native.run_spec_batch(&native_spec, &imgs).map(|(outs, fused)| {
                (outs.into_iter().map(FilterOutput::U8).collect::<Vec<_>>(), fused)
            })
        }
        ImagePayload::U16(_) => {
            let imgs: Vec<&Image<u16>> = batch
                .iter()
                .map(|p| match &p.req.image {
                    ImagePayload::U16(im) => &**im,
                    ImagePayload::U8(_) => unreachable!("batch keys include the dtype"),
                })
                .collect();
            native.run_spec_batch_u16(&native_spec, &imgs).map(|(outs, fused)| {
                (outs.into_iter().map(FilterOutput::U16).collect::<Vec<_>>(), fused)
            })
        }
    }));
    let exec_ns = t.elapsed().as_nanos() as u64 / n as u64;

    match outcome {
        Ok(Ok((outs, fused))) => {
            if fused {
                Metrics::inc(&metrics.fused_batches);
                metrics.fused_requests.fetch_add(n as u64, Ordering::Relaxed);
            }
            for ((p, out), q_ns) in batch.into_iter().zip(outs).zip(queue_ns) {
                metrics.queue_latency.record(q_ns);
                metrics.exec_latency.record(exec_ns);
                metrics.total_latency.record(q_ns + exec_ns);
                Metrics::inc(&metrics.completed);
                let _ = p.reply.send(FilterResponse {
                    id: p.req.id,
                    result: Ok(out),
                    queue_ns: q_ns,
                    exec_ns,
                    backend: "native",
                    worker: wid,
                });
            }
        }
        Ok(Err(e)) => {
            // plan-time rejection (invalid spec): every request of the
            // batch fails identically
            let msg = format!("{e:#}");
            for (p, q_ns) in batch.into_iter().zip(queue_ns) {
                metrics.queue_latency.record(q_ns);
                metrics.exec_latency.record(exec_ns);
                metrics.total_latency.record(q_ns + exec_ns);
                Metrics::inc(&metrics.failed);
                let _ = p.reply.send(FilterResponse {
                    id: p.req.id,
                    result: Err(anyhow!("{msg}")),
                    queue_ns: q_ns,
                    exec_ns,
                    backend: "native",
                    worker: wid,
                });
            }
        }
        Err(_) => {
            // panic mid-super-pass: the engine may hold half-updated
            // state — drain its counters into the metrics (pre-panic
            // requests stay accounted for), rebuild it, and fail every
            // request of the batch
            let stats = native.take_plan_stats();
            metrics
                .plan_resolutions
                .fetch_add(stats.resolutions, Ordering::Relaxed);
            metrics.plan_hits.fetch_add(stats.hits, Ordering::Relaxed);
            *native = NativeEngine::new(cfg.morph);
            for p in batch {
                Metrics::inc(&metrics.failed);
                let _ = p.reply.send(FilterResponse {
                    id: p.req.id,
                    result: Err(anyhow!(
                        "worker {wid} panicked while serving request {}",
                        p.req.id
                    )),
                    queue_ns: 0,
                    exec_ns: 0,
                    backend: "panic",
                    worker: wid,
                });
            }
        }
    }
    Ok(())
}

/// Clamp a spec's intra-image parallelism to the coordinator's
/// per-request band budget (`cap`; 0 = unlimited).  `Auto` stays `Auto`
/// when the cost model would pick at most `cap` bands anyway (so small
/// images keep their sequential dispatch) and is pinned to
/// `Fixed(cap)` otherwise; band counts never change output pixels.
///
/// ROI specs are priced on their **haloed block** — the shape the plan
/// actually bands — not the full image, so a small crop of a huge image
/// is not needlessly pinned to `Fixed(cap)` when its block would have
/// dispatched sequentially anyway.
fn capped_spec(spec: &FilterSpec, image: &ImagePayload, cap: usize) -> FilterSpec {
    if cap == 0 || spec.is_transpose() {
        return *spec;
    }
    let mut s = *spec;
    s.config.parallelism = match s.config.parallelism {
        Parallelism::Sequential => Parallelism::Sequential,
        Parallelism::Fixed(n) => Parallelism::Fixed(n.clamp(1, cap)),
        Parallelism::Auto if cap == 1 => Parallelism::Sequential,
        Parallelism::Auto => {
            // price the banding once, on the shape the plan will band;
            // unplannable specs (even windows, out-of-bounds ROIs —
            // the one validity predicate, `FilterSpec::validate`) fall
            // through and fail at plan time as before
            let (h, w) = (image.height(), image.width());
            let bands = if s.validate(h, w).is_ok() {
                let (bh, bw) = match s.roi {
                    None => (h, w),
                    Some(r) => {
                        let (hx, hy) = s.roi_halo();
                        let b = crate::morphology::plan::haloed_block(r, h, w, hx, hy);
                        (b.height, b.width)
                    }
                };
                match image.depth() {
                    PixelDepth::U8 => {
                        parallel::effective_bands::<u8>(bh, bw, s.w_x, s.w_y, &s.config)
                    }
                    PixelDepth::U16 => {
                        parallel::effective_bands::<u16>(bh, bw, s.w_x, s.w_y, &s.config)
                    }
                }
            } else {
                1
            };
            if bands <= cap {
                Parallelism::Auto
            } else {
                Parallelism::Fixed(cap)
            }
        }
    };
    s
}

fn serve_one(
    wid: usize,
    cfg: &CoordinatorConfig,
    manifest: &Option<Arc<Manifest>>,
    native: &mut NativeEngine,
    xla: &mut Option<XlaRuntime>,
    metrics: &Metrics,
    p: Pending,
) {
    let queue_ns = p.req.enqueued.elapsed().as_nanos() as u64;
    let spec = p.req.spec;
    // native executions honour the per-request band budget (routing and
    // batch keys always use the submitted spec; the clamp is
    // bit-identical)
    let native_spec = capped_spec(&spec, &p.req.image, cfg.max_bands_per_request);
    let (h, w) = (p.req.image.height(), p.req.image.width());
    // compiled artifacts exist only for u8 specs in canonical form
    // (single op, no ROI, identity border — the shared predicate
    // `FilterSpec::single_identity_op`; a replicate-border spec must
    // never take the XLA path, its output pixels differ at the edges)
    let compiled = match (&p.req.image, spec.single_identity_op()) {
        (ImagePayload::U8(_), Some(op)) => manifest
            .as_ref()
            .and_then(|m| m.find(op.name(), h, w, spec.w_x, spec.w_y).cloned()),
        _ => None,
    };

    let t = Instant::now();
    let (result, backend): (Result<FilterOutput>, &'static str) = match &p.req.image {
        ImagePayload::U8(img) => {
            if cfg.backend == BackendChoice::XlaOnly {
                match (compiled, xla.as_mut()) {
                    (Some(meta), Some(rt)) => {
                        (rt.run_u8(&meta, img).map(FilterOutput::U8), rt.backend_name())
                    }
                    (None, _) => (
                        Err(anyhow!(
                            "no artifact for {} (XlaOnly backend)",
                            p.req.batch_key()
                        )),
                        "xla-pjrt",
                    ),
                    (Some(_), None) => (
                        Err(anyhow!("XLA runtime unavailable on worker {wid}")),
                        "xla-pjrt",
                    ),
                }
            } else if let (Some(meta), Some(rt)) = (compiled.as_ref(), xla.as_mut()) {
                match rt.run_u8(meta, img) {
                    // Auto: degrade to native on runtime errors
                    Err(_) => (
                        native.run_spec(&native_spec, img).map(FilterOutput::U8),
                        native.backend_name(),
                    ),
                    ok => (ok.map(FilterOutput::U8), rt.backend_name()),
                }
            } else {
                (
                    native.run_spec(&native_spec, img).map(FilterOutput::U8),
                    native.backend_name(),
                )
            }
        }
        ImagePayload::U16(img) => {
            if cfg.backend == BackendChoice::XlaOnly {
                (
                    Err(anyhow!(
                        "no u16 artifacts exist (XlaOnly backend, {})",
                        p.req.batch_key()
                    )),
                    "xla-pjrt",
                )
            } else {
                (
                    native.run_spec_u16(&native_spec, img).map(FilterOutput::U16),
                    native.backend_name(),
                )
            }
        }
    };
    let exec_ns = t.elapsed().as_nanos() as u64;

    metrics.queue_latency.record(queue_ns);
    metrics.exec_latency.record(exec_ns);
    metrics.total_latency.record(queue_ns + exec_ns);
    if result.is_ok() {
        Metrics::inc(&metrics.completed);
    } else {
        Metrics::inc(&metrics.failed);
    }
    // receiver may have given up; dropping the response is fine
    let _ = p.reply.send(FilterResponse {
        id: p.req.id,
        result,
        queue_ns,
        exec_ns,
        backend,
        worker: wid,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::morphology::{self, Roi};
    use crate::neon::Native;

    #[test]
    fn native_coordinator_round_trip() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(32, 48, 5));
        let resp = coord.filter("erode", 5, 3, img.clone()).unwrap();
        assert_eq!(resp.backend, "native");
        let want = morphology::erode(img.view(), 5, 3);
        assert!(resp.result.unwrap().into_u8().unwrap().same_pixels(&want));
        let snap = coord.metrics();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn u16_coordinator_round_trip() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise_u16(32, 48, 5));
        let resp = coord.filter_u16("erode", 5, 3, img.clone()).unwrap();
        assert_eq!(resp.backend, "native");
        let want = morphology::erode(img.view(), 5, 3);
        assert!(resp.result.unwrap().into_u16().unwrap().same_pixels(&want));
        let snap = coord.metrics();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn spec_submission_runs_chains_and_rois() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(40, 40, 9));
        // a derived op with a ROI — inexpressible in the legacy API
        let spec = FilterSpec::new(FilterOp::TopHat, 5, 5).with_roi(Roi::new(3, 4, 20, 22));
        let resp = coord.filter_spec(spec, img.clone()).unwrap();
        let out = resp.result.unwrap().into_u8().unwrap();
        let full = morphology::parallel::tophat_native(&*img, 5, 5, &MorphConfig::default());
        assert!(out.same_pixels(&full.view().sub_rect(3, 4, 20, 22).to_image()));
        // a two-op chain
        let chain = FilterSpec::new(FilterOp::Open, 3, 3).then(FilterOp::Gradient);
        let resp = coord.filter_spec(chain, img.clone()).unwrap();
        let o = morphology::opening(&mut Native, &*img, 3, 3, &MorphConfig::default());
        let g = morphology::gradient(&mut Native, &o, 3, 3, &MorphConfig::default());
        assert!(resp.result.unwrap().into_u8().unwrap().same_pixels(&g));
        coord.shutdown();
    }

    #[test]
    fn mixed_depth_requests_batch_separately() {
        let coord = Coordinator::start_native(2).unwrap();
        let img8 = Arc::new(synth::noise(24, 24, 6));
        let img16 = Arc::new(synth::noise_u16(24, 24, 6));
        let spec = FilterSpec::new(FilterOp::Erode, 3, 3);
        let mut tickets = Vec::new();
        for i in 0..20 {
            let t = if i % 2 == 0 {
                coord.submit(spec, img8.clone()).unwrap()
            } else {
                coord.submit(spec, img16.clone()).unwrap()
            };
            tickets.push((i, t));
        }
        for (i, t) in tickets {
            let r = t.wait().unwrap();
            let out = r.result.unwrap();
            assert_eq!(out.dtype(), if i % 2 == 0 { "u8" } else { "u16" });
        }
        let snap = coord.metrics();
        assert_eq!(snap.completed, 20);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let coord = Coordinator::start_native(4).unwrap();
        let img = Arc::new(synth::noise(24, 24, 6));
        let tickets: Vec<_> = (0..40)
            .map(|i| {
                let op = if i % 2 == 0 { FilterOp::Erode } else { FilterOp::Dilate };
                coord.submit(FilterSpec::new(op, 3, 3), img.clone()).unwrap()
            })
            .collect();
        for t in tickets {
            let r = t.wait().unwrap();
            assert!(r.result.is_ok());
        }
        let snap = coord.metrics();
        assert_eq!(snap.completed, 40);
        assert!(snap.batches <= 40);
        coord.shutdown();
    }

    #[test]
    fn unknown_op_rejected_at_submission() {
        // the typed spec API surfaces bad op names before queueing
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise(8, 8, 2));
        let err = coord.filter("sharpen", 3, 3, img).unwrap_err();
        assert!(format!("{err:#}").contains("unknown op"));
        assert_eq!(coord.metrics().failed, 0);
        assert_eq!(coord.metrics().submitted, 0);
        coord.shutdown();
    }

    #[test]
    fn invalid_spec_fails_on_the_worker() {
        // spec validity (window parity, ROI bounds) is checked at plan
        // time on the worker: the ticket completes with an error and
        // the failure is metered
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise(8, 8, 2));
        let resp = coord
            .filter_spec(FilterSpec::new(FilterOp::Erode, 4, 4), img.clone())
            .unwrap();
        assert!(resp.result.is_err());
        let resp = coord
            .filter_spec(
                FilterSpec::new(FilterOp::Erode, 3, 3).with_roi(Roi::new(6, 6, 5, 5)),
                img,
            )
            .unwrap();
        assert!(resp.result.is_err());
        assert_eq!(coord.metrics().failed, 2);
        coord.shutdown();
    }

    #[test]
    fn backpressure_sheds_when_overloaded() {
        // 1 worker, tiny queue, many submissions of slow-ish work
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            queue_capacity: 2,
            max_batch: 1,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            morph: MorphConfig::default(),
            precompile: false,
            max_bands_per_request: 0,
        })
        .unwrap();
        let img = Arc::new(synth::paper_image(3));
        let spec = FilterSpec::new(FilterOp::Open, 15, 15);
        let mut shed = 0;
        let mut tickets = Vec::new();
        for _ in 0..64 {
            match coord.submit(spec, img.clone()) {
                Ok(t) => tickets.push(t),
                Err(_) => shed += 1,
            }
        }
        assert!(shed > 0, "expected at least one shed under overload");
        assert_eq!(coord.metrics().shed, shed);
        for t in tickets {
            assert!(t.wait().unwrap().result.is_ok());
        }
        coord.shutdown();
    }

    #[test]
    fn transpose_request_swaps_dims() {
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise(10, 20, 8));
        let out = coord
            .filter("transpose", 0, 0, img.clone())
            .unwrap()
            .result
            .unwrap()
            .into_u8()
            .unwrap();
        assert_eq!((out.height(), out.width()), (20, 10));
        let want = crate::transpose::transpose_image(&mut Native, img.view());
        assert!(out.same_pixels(&want));
        coord.shutdown();
    }

    #[test]
    fn u16_transpose_uses_8x8_tiles_end_to_end() {
        let coord = Coordinator::start_native(1).unwrap();
        let img = Arc::new(synth::noise_u16(16, 24, 8));
        let out = coord
            .filter_u16("transpose", 0, 0, img.clone())
            .unwrap()
            .result
            .unwrap()
            .into_u16()
            .unwrap();
        assert_eq!((out.height(), out.width()), (24, 16));
        let want = crate::transpose::transpose_image_u16(&mut Native, &*img);
        assert!(out.same_pixels(&want));
        coord.shutdown();
    }

    #[test]
    fn drop_shuts_down_workers() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(8, 8, 1));
        let _ = coord.filter("erode", 3, 3, img);
        drop(coord); // must not hang
    }

    #[test]
    fn stream_round_trips_and_matches_submit() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::noise(24, 28, 0x51));
        let specs = [
            FilterSpec::new(FilterOp::Erode, 5, 3),
            FilterSpec::new(FilterOp::Gradient, 3, 3),
            FilterSpec::new(FilterOp::TopHat, 5, 5).with_roi(Roi::new(5, 6, 10, 12)),
        ];
        let mut stream = coord.stream();
        let mut want_by_id = std::collections::HashMap::new();
        for _ in 0..4 {
            for spec in specs {
                let id = stream.send(spec, img.clone()).unwrap();
                // oracle: the fire-and-wait path
                let want = coord
                    .filter_spec(spec, img.clone())
                    .unwrap()
                    .result
                    .unwrap()
                    .into_u8()
                    .unwrap();
                want_by_id.insert(id, want);
            }
        }
        assert_eq!(stream.sent(), 12);
        let responses = stream.drain();
        assert_eq!(responses.len(), 12);
        assert_eq!(stream.in_flight(), 0);
        for r in responses {
            let got = r.result.unwrap().into_u8().unwrap();
            let want = want_by_id.remove(&r.id).expect("unknown response id");
            assert!(got.same_pixels(&want), "request {}", r.id);
        }
        assert!(want_by_id.is_empty());
        // recv on a drained stream is None, not a hang
        assert!(stream.recv().is_none());
        drop(stream);
        coord.shutdown();
    }

    #[test]
    fn submit_many_counts_sheds_and_still_yields_accepted() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            queue_capacity: 2,
            max_batch: 1,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            morph: MorphConfig::default(),
            precompile: false,
            max_bands_per_request: 0,
        })
        .unwrap();
        let img = Arc::new(synth::paper_image(9));
        let spec = FilterSpec::new(FilterOp::Open, 15, 15);
        let reqs: Vec<_> = (0..32)
            .map(|_| (spec, ImagePayload::from(img.clone())))
            .collect();
        let mut stream = coord.submit_many(reqs);
        let accepted = stream.sent();
        let shed = stream.shed();
        assert_eq!(accepted + shed, 32);
        let responses = stream.drain();
        assert_eq!(responses.len(), accepted as usize);
        assert!(responses.iter().all(|r| r.result.is_ok()));
        drop(stream);
        coord.shutdown();
    }

    #[test]
    fn dropping_stream_mid_flight_shuts_down_gracefully() {
        let coord = Coordinator::start_native(2).unwrap();
        let img = Arc::new(synth::paper_image(3));
        {
            let mut stream = coord.stream();
            for _ in 0..24 {
                let _ = stream.send(FilterSpec::new(FilterOp::Close, 9, 9), img.clone());
            }
            // consume a couple, then abandon the rest in flight
            let _ = stream.recv_timeout(Duration::from_secs(30));
            let _ = stream.try_recv();
        } // stream dropped here with work still queued/executing
        coord.shutdown(); // must drain and join without hanging
    }

    #[test]
    fn roi_sweep_over_stream_resolves_one_plan() {
        // streaming + position-independent plans: a same-shape interior
        // crop sweep on ONE worker is served by exactly one resolution
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let img = Arc::new(synth::noise(64, 64, 0x77));
        let base = FilterSpec::new(FilterOp::Erode, 5, 5); // halo (2, 2)
        let full = morphology::erode(img.view(), 5, 5);
        let mut stream = coord.stream();
        let mut wants = std::collections::HashMap::new();
        for (y, x) in [(2usize, 2usize), (10, 30), (30, 10), (64 - 16 - 2, 64 - 16 - 2)] {
            let id = stream.send(base.with_roi(Roi::new(y, x, 16, 16)), img.clone()).unwrap();
            wants.insert(id, full.view().sub_rect(y, x, 16, 16).to_image());
        }
        for r in stream.drain() {
            let got = r.result.unwrap().into_u8().unwrap();
            assert!(got.same_pixels(&wants[&r.id]));
        }
        drop(stream);
        let snap = coord.metrics();
        assert_eq!(snap.completed, 4);
        assert_eq!(snap.plan_resolutions, 1, "one plan must serve the sweep");
        assert_eq!(snap.plan_hits, 3);
        assert!((snap.plan_resolutions_per_request() - 0.25).abs() < 1e-12);
        coord.shutdown();
    }

    fn pending_of(id: u64, spec: FilterSpec, img: &Arc<Image<u8>>) -> (Pending, mpsc::Receiver<FilterResponse>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                req: request::FilterRequest {
                    id,
                    spec,
                    image: ImagePayload::from(img.clone()),
                    enqueued: Instant::now(),
                },
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn fused_batch_serves_every_request_bit_identically() {
        // deterministic fused-path test: hand try_serve_fused a batch
        // directly instead of racing the queue's batch splits
        let cfg = CoordinatorConfig {
            workers: 1,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            ..CoordinatorConfig::default()
        };
        let mut native = NativeEngine::new(cfg.morph);
        let metrics = Metrics::default();
        let spec = FilterSpec::new(FilterOp::TopHat, 5, 3);
        let imgs: Vec<Arc<Image<u8>>> =
            (0..6).map(|i| Arc::new(synth::noise(24, 32, 0xF00 + i))).collect();
        let mut rxs = Vec::new();
        let batch: Vec<Pending> = imgs
            .iter()
            .enumerate()
            .map(|(i, img)| {
                let (p, rx) = pending_of(i as u64, spec, img);
                rxs.push(rx);
                p
            })
            .collect();
        assert!(try_serve_fused(0, &cfg, &None, &mut native, &None, &metrics, batch).is_ok());
        for (i, (img, rx)) in imgs.iter().zip(&rxs).enumerate() {
            let r = rx.try_recv().expect("fused batch must answer every request");
            assert_eq!(r.id, i as u64);
            assert_eq!(r.backend, "native");
            let got = r.result.unwrap().into_u8().unwrap();
            let want =
                morphology::parallel::tophat_native(img.view(), 5, 3, &MorphConfig::default());
            assert!(got.same_pixels(&want), "request {i}");
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.completed, 6);
        assert_eq!(snap.fused_batches, 1);
        assert_eq!(snap.fused_requests, 6);
        // ineligible batches come back untouched: singletons…
        let (p, _rx) = pending_of(9, spec, &imgs[0]);
        assert!(try_serve_fused(0, &cfg, &None, &mut native, &None, &metrics, vec![p]).is_err());
        // …and ROI specs
        let roi_spec = spec.with_roi(Roi::new(2, 2, 8, 8));
        let batch: Vec<Pending> = (0..2)
            .map(|i| pending_of(10 + i, roi_spec, &imgs[0]).0)
            .collect();
        assert!(try_serve_fused(0, &cfg, &None, &mut native, &None, &metrics, batch).is_err());
        assert_eq!(metrics.snapshot().fused_batches, 1);
    }

    #[test]
    fn fused_stream_keeps_split_independent_plan_counts() {
        // end-to-end: however the queue splits a same-key stream into
        // batches (fused or not), the family resolves exactly once
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            backend: BackendChoice::NativeOnly,
            artifact_dir: None,
            ..CoordinatorConfig::default()
        })
        .unwrap();
        let spec = FilterSpec::new(FilterOp::Gradient, 5, 5);
        let imgs: Vec<Arc<Image<u8>>> =
            (0..8).map(|i| Arc::new(synth::noise(32, 40, 0xBEEF + i))).collect();
        let mut stream = coord.stream();
        let mut wants = std::collections::HashMap::new();
        for img in &imgs {
            let id = stream.send(spec, img.clone()).unwrap();
            wants.insert(
                id,
                morphology::parallel::gradient_native(img.view(), 5, 5, &MorphConfig::default()),
            );
        }
        for r in stream.drain() {
            let got = r.result.unwrap().into_u8().unwrap();
            assert!(got.same_pixels(&wants[&r.id]), "request {}", r.id);
        }
        drop(stream);
        let snap = coord.metrics();
        assert_eq!(snap.completed, 8);
        assert_eq!(snap.plan_resolutions, 1, "one family, one resolution");
        assert_eq!(snap.plan_hits, 7);
        // fused counters are split-dependent (producer/worker race), but
        // they can never disagree with each other
        assert!(snap.fused_requests >= 2 * snap.fused_batches);
        coord.shutdown();
    }

    #[test]
    fn capped_spec_clamps_parallelism_bit_identically() {
        use crate::morphology::Parallelism;
        let img8: ImagePayload = Arc::new(synth::paper_image(5)).into();
        let auto = FilterSpec::new(FilterOp::Erode, 31, 31);
        // cap 1: Auto collapses to Sequential
        assert_eq!(
            capped_spec(&auto, &img8, 1).config.parallelism,
            Parallelism::Sequential
        );
        // unlimited: untouched
        assert_eq!(capped_spec(&auto, &img8, 0), auto);
        // Fixed above the cap clamps; below it passes through
        let mut f8 = auto;
        f8.config.parallelism = Parallelism::Fixed(8);
        assert_eq!(
            capped_spec(&f8, &img8, 2).config.parallelism,
            Parallelism::Fixed(2)
        );
        assert_eq!(
            capped_spec(&f8, &img8, 16).config.parallelism,
            Parallelism::Fixed(8)
        );
        // Sequential is never promoted
        let mut seq = auto;
        seq.config.parallelism = Parallelism::Sequential;
        assert_eq!(
            capped_spec(&seq, &img8, 4).config.parallelism,
            Parallelism::Sequential
        );
        // a tiny image's Auto stays Auto under a generous cap (the cost
        // model keeps it sequential anyway)
        let tiny: ImagePayload = Arc::new(synth::noise(16, 16, 1)).into();
        let small = FilterSpec::new(FilterOp::Erode, 3, 3);
        assert_eq!(
            capped_spec(&small, &tiny, 4).config.parallelism,
            Parallelism::Auto
        );
        // a small interior crop of a BIG image prices its haloed block,
        // not the full image: Auto must survive the cap (the block
        // dispatches sequentially; pinning Fixed(cap) would force
        // banding overhead onto every streamed crop)
        let crop = FilterSpec::new(FilterOp::Erode, 5, 5).with_roi(Roi::new(100, 100, 24, 24));
        assert_eq!(
            capped_spec(&crop, &img8, 2).config.parallelism,
            Parallelism::Auto
        );
        // and the clamp never changes pixels: serve the same request
        // through coordinators with different caps
        let img = Arc::new(synth::noise(80, 96, 0xBEEF));
        let mut outs = Vec::new();
        for cap in [1usize, 2, 0] {
            let coord = Coordinator::start(CoordinatorConfig {
                workers: 1,
                backend: BackendChoice::NativeOnly,
                artifact_dir: None,
                max_bands_per_request: cap,
                ..CoordinatorConfig::default()
            })
            .unwrap();
            let r = coord.filter_spec(auto, img.clone()).unwrap();
            outs.push(r.result.unwrap().into_u8().unwrap());
            coord.shutdown();
        }
        assert!(outs[0].same_pixels(&outs[1]));
        assert!(outs[0].same_pixels(&outs[2]));
    }
}
