//! The staged serving pipeline behind [`super::Coordinator`].
//!
//! ```text
//!             try_send            bounded send        key-affine push
//!  admit() ──► [ingress] ────────► [plan-resolve] ────► lane queues ─► [execute ×N] ─► [reply]
//!  budget +    validate spec       warm the plan        BatchQueue      fused / per-    release
//!  try_send    (invalid → reply)   (ahead of its        per lane        request serve   budget,
//!  (sheds)                          batch landing)      (blocks full)   (own engines)   send resp
//! ```
//!
//! Every stage is a small worker set over a **bounded** channel:
//! admission is the only lossy door (a full channel or an exhausted
//! per-key budget sheds the request with an error the caller sees);
//! past it, stage-to-stage sends **block** — with a per-stage deadline
//! as a stall backstop — so backpressure propagates upstream instead of
//! dropping accepted work.  The per-stage depth counters in
//! [`Metrics`] meter exactly this: each depth is bounded by the stage's
//! channel capacity plus its sender count, and `stage_blocked_sends`
//! counts the sends that had to wait (non-zero under a saturating
//! producer, zero when the pipeline keeps up).
//!
//! ## Exactly-once replies
//!
//! Every admitted request terminates in **exactly one**
//! [`FilterResponse`], whatever path it takes: invalid specs reply from
//! ingress, stalled sends reply with a deadline error, panics while
//! serving are caught per request (the lane's engine is rebuilt, the
//! request replies with backend `"panic"`), and everything else flows
//! through execute → reply.  Stage panics are isolated: a poisoned
//! request cannot stall its stage or orphan its ticket.
//!
//! ## Warm-ahead plan resolution
//!
//! The plan-resolve stage runs **ahead of** execute: it resolves (and
//! caches) the request's [`crate::morphology::FilterPlan`] on the lane
//! engine the request will execute on, so hot keys are warm before
//! their batch lands.  Warming counts exactly like execution on the
//! engine's `PlanStats` (cold family → one resolution, warm → a hit),
//! so `G` same-family requests score `1` resolution + `2G − 1` hits —
//! split- and path-independently — which the serving tests pin.
//!
//! ## Mutability split
//!
//! A request's context (`Pending`: spec, payload, reply handle) is
//! **immutable** as it flows; all mutable state is stage-local (each
//! lane's `NativeEngine` behind its own mutex, shared only with the
//! resolve stage's warm-ahead) or a shared accumulator with interior
//! mutability ([`Metrics`] atomics, the admission-budget map).  Lanes
//! never touch each other's engines; one [`BatchKey`] always hashes to
//! one lane, so plan pinning and batch fusion survive the pipeline
//! split.
//!
//! Head-of-line note: the resolve stage is single-threaded, so one
//! request blocked on a full lane queue delays later requests bound for
//! *other* lanes.  The block is bounded by the stage deadline
//! ([`super::CoordinatorConfig::stage_deadline`]) and only occurs once
//! execute is already saturated — the regime where admission should be
//! shedding anyway.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::metrics::{
    Metrics, STAGE_EXECUTE, STAGE_INGRESS, STAGE_REPLY, STAGE_RESOLVE,
};
use super::queue::{BatchQueue, Pull};
use super::request::{BatchKey, FilterOutput, FilterResponse, ImagePayload, Pending, PixelDepth};
use super::{BackendChoice, CoordinatorConfig};
use crate::image::Image;
use crate::morphology::{parallel, FilterSpec, Parallelism};
use crate::runtime::{Engine, Manifest, NativeEngine, XlaRuntime};

/// Why admission rejected a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Shed {
    /// The admission channel is full (global backpressure).
    Full,
    /// The request's key already has `admission_budget` requests in
    /// flight (per-key backpressure).
    Budget,
    /// The pipeline is shut down.
    Closed,
}

/// A served request on its way to the reply stage: the response plus
/// what the reply stage needs to close out the request (its batch key
/// for the budget release, its reply channel).
pub(crate) struct Served {
    key: BatchKey,
    reply: mpsc::Sender<FilterResponse>,
    resp: FilterResponse,
}

/// One pipeline stage: a worker thread draining one bounded channel.
/// `run` handles one item; `finish` runs once after the channel
/// disconnects (the shutdown cascade hook).
trait Stage: Send + 'static {
    type In: Send + 'static;
    fn run(&mut self, item: Self::In);
    fn finish(&mut self) {}
}

/// Drive `stage` on its own named thread until the channel disconnects.
fn spawn_stage<S: Stage>(name: &str, rx: Receiver<S::In>, mut stage: S) -> Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name(name.to_string())
        .spawn(move || {
            while let Ok(item) = rx.recv() {
                stage.run(item);
            }
            stage.finish();
        })
        .with_context(|| format!("spawning pipeline stage {name:?}"))
}

/// Lock a mutex, riding through poisoning: a panic while serving is
/// already isolated per request (the engine is rebuilt), so a poisoned
/// lock only means "a panic happened", not "the data is gone".
fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Deterministic key → lane routing: one key always executes on one
/// lane, so plan pinning and batch fusion survive the fan-out.
fn lane_of(key: &BatchKey, lanes: usize) -> usize {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() % lanes.max(1) as u64) as usize
}

/// Bounded-channel send with a deadline: try, then poll-wait until the
/// deadline.  Returns `(Err(item), _)` when the deadline expired or the
/// receiver is gone; the `bool` reports whether the send ever found the
/// channel full (the blocked-send metric).
fn send_deadline<T>(tx: &SyncSender<T>, item: T, deadline: Instant) -> (std::result::Result<(), T>, bool) {
    let mut item = match tx.try_send(item) {
        Ok(()) => return (Ok(()), false),
        Err(TrySendError::Disconnected(it)) => return (Err(it), false),
        Err(TrySendError::Full(it)) => it,
    };
    loop {
        if Instant::now() >= deadline {
            return (Err(item), true);
        }
        std::thread::sleep(Duration::from_millis(1));
        match tx.try_send(item) {
            Ok(()) => return (Ok(()), true),
            Err(TrySendError::Full(it)) => item = it,
            Err(TrySendError::Disconnected(it)) => return (Err(it), true),
        }
    }
}

/// Hand a [`Served`] to the reply stage.  The request enters the REPLY
/// stage *before* the send so the depth counter never underflows; a
/// full reply channel blocks (counting a blocked send against
/// `from_stage`) — backpressure, never loss.
fn send_reply(tx: &SyncSender<Served>, metrics: &Metrics, from_stage: usize, s: Served) {
    metrics.stage_enter(STAGE_REPLY);
    match tx.try_send(s) {
        Ok(()) => {}
        Err(TrySendError::Full(s)) => {
            metrics.stage_blocked_sends[from_stage].fetch_add(1, Ordering::Relaxed);
            if tx.send(s).is_err() {
                metrics.stage_exit(STAGE_REPLY);
            }
        }
        Err(TrySendError::Disconnected(_)) => {
            metrics.stage_exit(STAGE_REPLY);
        }
    }
}

/// Close out one served request: record its latencies (panic replies
/// carry no meaningful timings), bump completed/failed, send the
/// response.  The receiver may have given up; dropping the response is
/// fine.
pub(crate) fn finish(metrics: &Metrics, s: Served) {
    let resp = s.resp;
    if resp.backend != "panic" {
        metrics.queue_latency.record(resp.queue_ns);
        metrics.exec_latency.record(resp.exec_ns);
        metrics.total_latency.record(resp.queue_ns + resp.exec_ns);
    }
    if resp.result.is_ok() {
        Metrics::inc(&metrics.completed);
    } else {
        Metrics::inc(&metrics.failed);
    }
    let _ = s.reply.send(resp);
}

/// Terminate a request with an error before it reached execute.
fn error_served(p: Pending, err: anyhow::Error, backend: &'static str) -> Served {
    let key = p.req.batch_key();
    Served {
        key,
        resp: FilterResponse {
            id: p.req.id,
            result: Err(err),
            queue_ns: p.req.enqueued.elapsed().as_nanos() as u64,
            exec_ns: 0,
            backend,
            worker: 0,
        },
        reply: p.reply,
    }
}

/// Decrement (and at zero, drop) a key's in-flight admission count.
fn release_key(inflight: &Mutex<HashMap<BatchKey, u64>>, key: &BatchKey) {
    let mut m = lock_unpoisoned(inflight);
    if let Some(n) = m.get_mut(key) {
        *n -= 1;
        if *n == 0 {
            m.remove(key);
        }
    }
}

/// Will this request execute on the native engine?  The warm-ahead
/// predicate: `false` exactly when the router would send it to the XLA
/// backend (XlaOnly, or an Auto artifact match on a u8
/// single-identity-op spec), so warming never touches plan counters for
/// requests that never reach the native plan cache.
fn routes_native(cfg: &CoordinatorConfig, manifest: &Option<Arc<Manifest>>, p: &Pending) -> bool {
    if cfg.backend == BackendChoice::XlaOnly {
        return false;
    }
    if let (ImagePayload::U8(_), Some(op)) = (&p.req.image, p.req.spec.single_identity_op()) {
        let (h, w) = (p.req.image.height(), p.req.image.width());
        if manifest
            .as_ref()
            .is_some_and(|m| m.find(op.name(), h, w, p.req.spec.w_x, p.req.spec.w_y).is_some())
        {
            return false;
        }
    }
    true
}

/// Validate a request's marker pairing (the ingress-stage rule): a
/// [`FilterOp::Reconstruct`](crate::morphology::FilterOp) spec requires
/// a marker matching the mask image in depth and shape; any other spec
/// must not carry one.
fn check_marker(p: &Pending) -> std::result::Result<(), String> {
    match (&p.req.marker, p.req.spec.is_reconstruct()) {
        (None, false) => Ok(()),
        (None, true) => Err("reconstruct spec requires a marker payload".into()),
        (Some(_), false) => Err("marker payloads only pair with reconstruct specs".into()),
        (Some(m), true) => {
            if m.depth() != p.req.image.depth() {
                Err(format!(
                    "marker depth {} does not match the {} mask image",
                    m.dtype(),
                    p.req.image.dtype()
                ))
            } else if (m.height(), m.width()) != (p.req.image.height(), p.req.image.width()) {
                Err(format!(
                    "marker {}x{} does not match the {}x{} mask image",
                    m.height(),
                    m.width(),
                    p.req.image.height(),
                    p.req.image.width()
                ))
            } else {
                Ok(())
            }
        }
    }
}

/// One execute lane's shared handles: its batch queue (fed by resolve)
/// and its native engine (shared with resolve for warm-ahead only).
struct Lane {
    queue: Arc<BatchQueue>,
    engine: Arc<Mutex<NativeEngine>>,
}

/// Stage 1 — ingress: validate the spec (the one validity predicate,
/// [`FilterSpec::validate`]); invalid requests reply immediately and
/// never touch an engine.  Valid requests move to resolve over a
/// bounded channel (blocking send, deadline backstop).
struct Ingress {
    deadline: Duration,
    resolve_tx: SyncSender<Pending>,
    reply_tx: SyncSender<Served>,
    metrics: Arc<Metrics>,
}

impl Stage for Ingress {
    type In = Pending;

    fn run(&mut self, p: Pending) {
        let (h, w) = (p.req.image.height(), p.req.image.width());
        if let Err(e) = p.req.spec.validate(h, w) {
            self.metrics.stage_exit(STAGE_INGRESS);
            let s = error_served(p, anyhow!(e), "ingress");
            send_reply(&self.reply_tx, &self.metrics, STAGE_INGRESS, s);
            return;
        }
        // marker pairing is part of request validity: a reconstruct
        // spec requires a depth/shape-matched marker, every other spec
        // must come without one — rejected here, before any engine
        if let Err(msg) = check_marker(&p) {
            self.metrics.stage_exit(STAGE_INGRESS);
            let s = error_served(p, anyhow!(msg), "ingress");
            send_reply(&self.reply_tx, &self.metrics, STAGE_INGRESS, s);
            return;
        }
        // enter the downstream stage BEFORE the send: the consumer may
        // exit the stage the instant the item lands, and the depth
        // counter must never go negative
        self.metrics.stage_exit(STAGE_INGRESS);
        self.metrics.stage_enter(STAGE_RESOLVE);
        let deadline = Instant::now() + self.deadline;
        let (res, blocked) = send_deadline(&self.resolve_tx, p, deadline);
        if blocked {
            self.metrics.stage_blocked_sends[STAGE_INGRESS].fetch_add(1, Ordering::Relaxed);
        }
        if let Err(p) = res {
            self.metrics.stage_exit(STAGE_RESOLVE);
            let s = error_served(
                p,
                anyhow!("pipeline stalled: ingress→resolve handoff exceeded the stage deadline"),
                "ingress",
            );
            send_reply(&self.reply_tx, &self.metrics, STAGE_INGRESS, s);
        }
    }
}

/// Stage 2 — plan-resolve: route the request to its lane and warm the
/// plan on that lane's engine **before** the request lands in the
/// lane's queue.  Pushes block when the lane is full (deadline
/// backstop); closing the lane queues on channel disconnect is the
/// shutdown cascade's next link.
struct Resolve {
    cfg: CoordinatorConfig,
    manifest: Option<Arc<Manifest>>,
    deadline: Duration,
    lanes: Vec<Lane>,
    reply_tx: SyncSender<Served>,
    metrics: Arc<Metrics>,
}

impl Stage for Resolve {
    type In = Pending;

    fn run(&mut self, p: Pending) {
        self.metrics.stage_exit(STAGE_RESOLVE);
        let key = p.req.batch_key();
        let lane = &self.lanes[lane_of(&key, self.lanes.len())];
        if routes_native(&self.cfg, &self.manifest, &p) {
            // warm with the same capped spec execute will run, so the
            // cache key matches; warm errors are ignored — execute
            // surfaces them as the request's error
            let spec = capped_spec(&p.req.spec, &p.req.image, self.cfg.max_bands_per_request);
            let (h, w) = (p.req.image.height(), p.req.image.width());
            let mut eng = lock_unpoisoned(&lane.engine);
            let _ = match &p.req.image {
                ImagePayload::U8(_) => eng.warm_spec(&spec, h, w),
                ImagePayload::U16(_) => eng.warm_spec_u16(&spec, h, w),
            };
        }
        self.metrics.stage_enter(STAGE_EXECUTE);
        if let Err(p) = lane.queue.push(p) {
            self.metrics.stage_blocked_sends[STAGE_RESOLVE].fetch_add(1, Ordering::Relaxed);
            let deadline = Instant::now() + self.deadline;
            if let Err(p) = lane.queue.push_wait(p, deadline) {
                self.metrics.stage_exit(STAGE_EXECUTE);
                let s = error_served(
                    p,
                    anyhow!("pipeline stalled: resolve→execute handoff exceeded the stage deadline"),
                    "resolve",
                );
                send_reply(&self.reply_tx, &self.metrics, STAGE_RESOLVE, s);
            }
        }
    }

    fn finish(&mut self) {
        for lane in &self.lanes {
            lane.queue.close();
        }
    }
}

/// Stage 4 — reply: release the request's admission-budget slot, record
/// its outcome and send the response.  Runs after execute so a client
/// that sees its last response observes final plan counters (the lanes
/// drain `PlanStats` before handing replies over).
struct Reply {
    metrics: Arc<Metrics>,
    inflight: Arc<Mutex<HashMap<BatchKey, u64>>>,
    budget: u64,
}

impl Stage for Reply {
    type In = Served;

    fn run(&mut self, s: Served) {
        self.metrics.stage_exit(STAGE_REPLY);
        if self.budget > 0 {
            release_key(&self.inflight, &s.key);
        }
        finish(&self.metrics, s);
    }
}

/// Stage 3 — execute: one lane per worker, each with its own engines,
/// pulling key-affine batches from its own [`BatchQueue`].  A same-key
/// batch tries the fused super-pass first; otherwise requests serve one
/// at a time with per-request panic isolation.  Plan-cache counters
/// drain into the metrics **before** the batch's replies go out.
#[allow(clippy::too_many_arguments)]
fn execute_lane(
    wid: usize,
    cfg: CoordinatorConfig,
    manifest: Option<Arc<Manifest>>,
    queue: Arc<BatchQueue>,
    engine: Arc<Mutex<NativeEngine>>,
    metrics: Arc<Metrics>,
    reply_tx: SyncSender<Served>,
) {
    let mut xla: Option<XlaRuntime> = match (&cfg.backend, &cfg.artifact_dir, &manifest) {
        (BackendChoice::NativeOnly, _, _) | (_, _, None) => None,
        (_, Some(dir), Some(_)) => XlaRuntime::new(dir).ok(),
        (_, None, _) => None,
    };
    if cfg.precompile {
        if let Some(rt) = xla.as_mut() {
            let _ = rt.precompile(|_| true);
        }
    }

    let mut affinity: Option<BatchKey> = None;
    loop {
        match queue.pull(affinity.as_ref(), Duration::from_millis(100)) {
            Pull::Closed => break,
            Pull::Batch(batch) => {
                Metrics::inc(&metrics.batches);
                metrics
                    .batched_requests
                    .fetch_add(batch.len() as u64, Ordering::Relaxed);
                affinity = batch.first().map(|p| p.req.batch_key());
                let batch_len = batch.len();
                let mut native = lock_unpoisoned(&engine);
                let serveds = match serve_fused(
                    wid, &cfg, &manifest, &mut native, &xla, &metrics, batch,
                ) {
                    Ok(serveds) => serveds,
                    Err(batch) => {
                        let mut serveds = Vec::with_capacity(batch.len());
                        for p in batch {
                            let id = p.req.id;
                            let key = p.req.batch_key();
                            let reply = p.reply.clone();
                            // a panic while serving must not kill the
                            // lane or orphan the request: every Pending
                            // is answered exactly once
                            let outcome = catch_unwind(AssertUnwindSafe(|| {
                                serve_request(wid, &cfg, &manifest, &mut native, &mut xla, p)
                            }));
                            match outcome {
                                Ok(s) => serveds.push(s),
                                Err(_) => {
                                    // the engine may hold half-updated
                                    // state: drain its counters (the
                                    // pre-panic requests stay accounted
                                    // for), then rebuild it
                                    let stats = native.take_plan_stats();
                                    metrics
                                        .plan_resolutions
                                        .fetch_add(stats.resolutions, Ordering::Relaxed);
                                    metrics.plan_hits.fetch_add(stats.hits, Ordering::Relaxed);
                                    *native = NativeEngine::new(cfg.morph);
                                    serveds.push(Served {
                                        key,
                                        reply,
                                        resp: FilterResponse {
                                            id,
                                            result: Err(anyhow!(
                                                "worker {wid} panicked while serving request {id}"
                                            )),
                                            queue_ns: 0,
                                            exec_ns: 0,
                                            backend: "panic",
                                            worker: wid,
                                        },
                                    });
                                }
                            }
                        }
                        serveds
                    }
                };
                for _ in 0..batch_len {
                    metrics.stage_exit(STAGE_EXECUTE);
                }
                // drain plan-cache traffic BEFORE the replies go out: a
                // client observing its last response must see final
                // counters (a same-key run pinned to one plan shows up
                // as warm-ahead + execution touches here)
                let stats = native.take_plan_stats();
                metrics
                    .plan_resolutions
                    .fetch_add(stats.resolutions, Ordering::Relaxed);
                metrics.plan_hits.fetch_add(stats.hits, Ordering::Relaxed);
                drop(native);
                for s in serveds {
                    send_reply(&reply_tx, &metrics, STAGE_EXECUTE, s);
                }
            }
        }
    }
    // shutdown: anything the warm-ahead resolved after the last batch
    // still belongs in the totals
    let mut native = lock_unpoisoned(&engine);
    let stats = native.take_plan_stats();
    metrics
        .plan_resolutions
        .fetch_add(stats.resolutions, Ordering::Relaxed);
    metrics.plan_hits.fetch_add(stats.hits, Ordering::Relaxed);
}

/// Serve a whole same-key batch through the native engine's fused
/// super-pass ([`NativeEngine::run_spec_batch`]) when every request
/// would route native anyway.  The queue guarantees one `BatchKey` per
/// batch (same spec, shape and depth), so eligibility is a per-batch
/// decision: more than one request, a full-image non-transpose spec,
/// and no compiled-artifact route that could peel the batch onto the
/// XLA backend.  Returns the batch untouched (`Err`) when ineligible
/// and the caller serves it per request.
///
/// The fused run executes under the same [`capped_spec`] clamp as
/// per-request serving; its one band fork is shared by every request in
/// the batch, so per-request band pressure only drops relative to
/// per-image serving.  Outputs stay bit-identical either way.  The
/// super-pass execution time is attributed to requests in equal shares
/// (`exec_ns = total / n`).
pub(crate) fn serve_fused(
    wid: usize,
    cfg: &CoordinatorConfig,
    manifest: &Option<Arc<Manifest>>,
    native: &mut NativeEngine,
    xla: &Option<XlaRuntime>,
    metrics: &Metrics,
    batch: Vec<Pending>,
) -> std::result::Result<Vec<Served>, Vec<Pending>> {
    if batch.len() < 2 {
        return Err(batch);
    }
    let spec = batch[0].req.spec;
    if spec.roi.is_some()
        || spec.is_transpose()
        || spec.is_reconstruct()
        || cfg.backend == BackendChoice::XlaOnly
    {
        return Err(batch);
    }
    let (h, w) = (batch[0].req.image.height(), batch[0].req.image.width());
    // under Auto an artifact match routes u8 requests to the XLA
    // runtime — leave those batches to the per-request router
    if let (ImagePayload::U8(_), Some(op)) = (&batch[0].req.image, spec.single_identity_op()) {
        let has_artifact = xla.is_some()
            && manifest
                .as_ref()
                .is_some_and(|m| m.find(op.name(), h, w, spec.w_x, spec.w_y).is_some());
        if has_artifact {
            return Err(batch);
        }
    }

    let n = batch.len();
    let native_spec = capped_spec(&spec, &batch[0].req.image, cfg.max_bands_per_request);
    let queue_ns: Vec<u64> = batch
        .iter()
        .map(|p| p.req.enqueued.elapsed().as_nanos() as u64)
        .collect();
    let t = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        if cfg.debug_fault_op.is_some() && cfg.debug_fault_op == spec.single_op() {
            panic!("debug fault injected into fused serving");
        }
        match &batch[0].req.image {
            ImagePayload::U8(_) => {
                let imgs: Vec<&Image<u8>> = batch
                    .iter()
                    .map(|p| match &p.req.image {
                        ImagePayload::U8(im) => &**im,
                        ImagePayload::U16(_) => unreachable!("batch keys include the dtype"),
                    })
                    .collect();
                native.run_spec_batch(&native_spec, &imgs).map(|(outs, fused)| {
                    (outs.into_iter().map(FilterOutput::U8).collect::<Vec<_>>(), fused)
                })
            }
            ImagePayload::U16(_) => {
                let imgs: Vec<&Image<u16>> = batch
                    .iter()
                    .map(|p| match &p.req.image {
                        ImagePayload::U16(im) => &**im,
                        ImagePayload::U8(_) => unreachable!("batch keys include the dtype"),
                    })
                    .collect();
                native.run_spec_batch_u16(&native_spec, &imgs).map(|(outs, fused)| {
                    (outs.into_iter().map(FilterOutput::U16).collect::<Vec<_>>(), fused)
                })
            }
        }
    }));
    let exec_ns = t.elapsed().as_nanos() as u64 / n as u64;

    match outcome {
        Ok(Ok((outs, fused))) => {
            if fused {
                Metrics::inc(&metrics.fused_batches);
                metrics.fused_requests.fetch_add(n as u64, Ordering::Relaxed);
            }
            Ok(batch
                .into_iter()
                .zip(outs)
                .zip(queue_ns)
                .map(|((p, out), q_ns)| Served {
                    key: p.req.batch_key(),
                    resp: FilterResponse {
                        id: p.req.id,
                        result: Ok(out),
                        queue_ns: q_ns,
                        exec_ns,
                        backend: "native",
                        worker: wid,
                    },
                    reply: p.reply,
                })
                .collect())
        }
        Ok(Err(e)) => {
            // plan-time rejection: every request of the batch fails
            // identically
            let msg = format!("{e:#}");
            Ok(batch
                .into_iter()
                .zip(queue_ns)
                .map(|(p, q_ns)| Served {
                    key: p.req.batch_key(),
                    resp: FilterResponse {
                        id: p.req.id,
                        result: Err(anyhow!("{msg}")),
                        queue_ns: q_ns,
                        exec_ns,
                        backend: "native",
                        worker: wid,
                    },
                    reply: p.reply,
                })
                .collect())
        }
        Err(_) => {
            // panic mid-super-pass: the engine may hold half-updated
            // state — drain its counters into the metrics (pre-panic
            // requests stay accounted for), rebuild it, and fail every
            // request of the batch
            let stats = native.take_plan_stats();
            metrics
                .plan_resolutions
                .fetch_add(stats.resolutions, Ordering::Relaxed);
            metrics.plan_hits.fetch_add(stats.hits, Ordering::Relaxed);
            *native = NativeEngine::new(cfg.morph);
            Ok(batch
                .into_iter()
                .map(|p| Served {
                    key: p.req.batch_key(),
                    resp: FilterResponse {
                        id: p.req.id,
                        result: Err(anyhow!(
                            "worker {wid} panicked while serving request {}",
                            p.req.id
                        )),
                        queue_ns: 0,
                        exec_ns: 0,
                        backend: "panic",
                        worker: wid,
                    },
                    reply: p.reply,
                })
                .collect())
        }
    }
}

/// Clamp a spec's intra-image parallelism to the coordinator's
/// per-request band budget (`cap`; 0 = unlimited).  `Auto` stays `Auto`
/// when the cost model would pick at most `cap` bands anyway (so small
/// images keep their sequential dispatch) and is pinned to
/// `Fixed(cap)` otherwise; band counts never change output pixels.
///
/// ROI specs are priced on their **haloed block** — the shape the plan
/// actually bands — not the full image, so a small crop of a huge image
/// is not needlessly pinned to `Fixed(cap)` when its block would have
/// dispatched sequentially anyway.
pub(crate) fn capped_spec(spec: &FilterSpec, image: &ImagePayload, cap: usize) -> FilterSpec {
    if cap == 0 || spec.is_transpose() {
        return *spec;
    }
    let mut s = *spec;
    s.config.parallelism = match s.config.parallelism {
        Parallelism::Sequential => Parallelism::Sequential,
        Parallelism::Fixed(n) => Parallelism::Fixed(n.clamp(1, cap)),
        Parallelism::Auto if cap == 1 => Parallelism::Sequential,
        Parallelism::Auto => {
            // price the banding once, on the shape the plan will band;
            // unplannable specs (even windows, out-of-bounds ROIs —
            // the one validity predicate, `FilterSpec::validate`) fall
            // through and fail at plan time as before
            let (h, w) = (image.height(), image.width());
            let bands = if s.validate(h, w).is_ok() {
                let (bh, bw) = match s.roi {
                    None => (h, w),
                    Some(r) => {
                        let (hx, hy) = s.roi_halo();
                        let b = crate::morphology::plan::haloed_block(r, h, w, hx, hy);
                        (b.height, b.width)
                    }
                };
                match image.depth() {
                    PixelDepth::U8 => {
                        parallel::effective_bands::<u8>(bh, bw, s.w_x, s.w_y, &s.config)
                    }
                    PixelDepth::U16 => {
                        parallel::effective_bands::<u16>(bh, bw, s.w_x, s.w_y, &s.config)
                    }
                }
            } else {
                1
            };
            if bands <= cap {
                Parallelism::Auto
            } else {
                Parallelism::Fixed(cap)
            }
        }
    };
    s
}

/// Per-request serving — routing, execution and timing for ONE request,
/// with **no** side effects on metrics or channels (the caller owns
/// those): the pipeline's pure core, also the panic-isolation unit.
pub(crate) fn serve_request(
    wid: usize,
    cfg: &CoordinatorConfig,
    manifest: &Option<Arc<Manifest>>,
    native: &mut NativeEngine,
    xla: &mut Option<XlaRuntime>,
    p: Pending,
) -> Served {
    if cfg.debug_fault_op.is_some() && cfg.debug_fault_op == p.req.spec.single_op() {
        panic!("debug fault injected into per-request serving");
    }
    let queue_ns = p.req.enqueued.elapsed().as_nanos() as u64;
    let key = p.req.batch_key();
    let spec = p.req.spec;
    // native executions honour the per-request band budget (routing and
    // batch keys always use the submitted spec; the clamp is
    // bit-identical)
    let native_spec = capped_spec(&spec, &p.req.image, cfg.max_bands_per_request);
    let (h, w) = (p.req.image.height(), p.req.image.width());
    // compiled artifacts exist only for u8 specs in canonical form
    // (single op, no ROI, identity border — the shared predicate
    // `FilterSpec::single_identity_op`; a replicate-border spec must
    // never take the XLA path, its output pixels differ at the edges)
    let compiled = match (&p.req.image, spec.single_identity_op()) {
        (ImagePayload::U8(_), Some(op)) => manifest
            .as_ref()
            .and_then(|m| m.find(op.name(), h, w, spec.w_x, spec.w_y).cloned()),
        _ => None,
    };

    let t = Instant::now();
    let (result, backend): (Result<FilterOutput>, &'static str) = if spec.is_reconstruct() {
        // reconstruction is native-only (no AOT artifacts carry a
        // second payload); ingress validated the marker pairing, but
        // direct callers of this function get the same checks as errors
        if cfg.backend == BackendChoice::XlaOnly {
            (
                Err(anyhow!("no reconstruct artifacts exist (XlaOnly backend, {key})")),
                "xla-pjrt",
            )
        } else {
            match (&p.req.image, &p.req.marker) {
                (ImagePayload::U8(img), Some(ImagePayload::U8(mk))) => (
                    native
                        .run_spec_reconstruct(&native_spec, img, mk)
                        .map(|(out, _sweeps)| FilterOutput::U8(out)),
                    native.backend_name(),
                ),
                (ImagePayload::U16(img), Some(ImagePayload::U16(mk))) => (
                    native
                        .run_spec_reconstruct_u16(&native_spec, img, mk)
                        .map(|(out, _sweeps)| FilterOutput::U16(out)),
                    native.backend_name(),
                ),
                _ => (
                    Err(anyhow!("reconstruct request {key} has no depth-matched marker")),
                    native.backend_name(),
                ),
            }
        }
    } else {
        match &p.req.image {
            ImagePayload::U8(img) => {
                if cfg.backend == BackendChoice::XlaOnly {
                    match (compiled, xla.as_mut()) {
                        (Some(meta), Some(rt)) => {
                            (rt.run_u8(&meta, img).map(FilterOutput::U8), rt.backend_name())
                        }
                        (None, _) => (
                            Err(anyhow!("no artifact for {key} (XlaOnly backend)")),
                            "xla-pjrt",
                        ),
                        (Some(_), None) => (
                            Err(anyhow!("XLA runtime unavailable on worker {wid}")),
                            "xla-pjrt",
                        ),
                    }
                } else if let (Some(meta), Some(rt)) = (compiled.as_ref(), xla.as_mut()) {
                    match rt.run_u8(meta, img) {
                        // Auto: degrade to native on runtime errors
                        Err(_) => (
                            native.run_spec(&native_spec, img).map(FilterOutput::U8),
                            native.backend_name(),
                        ),
                        ok => (ok.map(FilterOutput::U8), rt.backend_name()),
                    }
                } else {
                    (
                        native.run_spec(&native_spec, img).map(FilterOutput::U8),
                        native.backend_name(),
                    )
                }
            }
            ImagePayload::U16(img) => {
                if cfg.backend == BackendChoice::XlaOnly {
                    (
                        Err(anyhow!("no u16 artifacts exist (XlaOnly backend, {key})")),
                        "xla-pjrt",
                    )
                } else {
                    (
                        native.run_spec_u16(&native_spec, img).map(FilterOutput::U16),
                        native.backend_name(),
                    )
                }
            }
        }
    };
    let exec_ns = t.elapsed().as_nanos() as u64;

    Served {
        key,
        resp: FilterResponse {
            id: p.req.id,
            result,
            queue_ns,
            exec_ns,
            backend,
            worker: wid,
        },
        reply: p.reply,
    }
}

/// The running staged pipeline: the admission door plus its four stage
/// thread sets.  Owned by [`super::Coordinator`]; dropping the
/// admission sender starts the shutdown cascade (ingress drains and
/// exits → resolve drains, closes the lane queues → lanes drain →
/// reply drains) and [`Pipeline::shutdown`] joins it.
pub(crate) struct Pipeline {
    admission: Option<SyncSender<Pending>>,
    inflight: Arc<Mutex<HashMap<BatchKey, u64>>>,
    budget: u64,
    metrics: Arc<Metrics>,
    threads: Vec<JoinHandle<()>>,
}

impl Pipeline {
    /// Build the stage graph and spawn every stage thread.
    pub(crate) fn start(
        cfg: &CoordinatorConfig,
        manifest: Option<Arc<Manifest>>,
        metrics: Arc<Metrics>,
    ) -> Result<Pipeline> {
        // stages see the *resolved* band budget (default: cores/workers)
        let mut cfg = cfg.clone();
        cfg.max_bands_per_request = super::resolve_band_cap(&cfg);
        let stage_cap = if cfg.stage_capacity > 0 {
            cfg.stage_capacity
        } else {
            cfg.queue_capacity.clamp(1, 32)
        };
        let deadline = if cfg.stage_deadline.is_zero() {
            Duration::from_secs(60)
        } else {
            cfg.stage_deadline
        };
        let lanes = cfg.workers.max(1);
        let budget = cfg.admission_budget as u64;
        let inflight = Arc::new(Mutex::new(HashMap::new()));

        let (admit_tx, admit_rx) = mpsc::sync_channel::<Pending>(cfg.queue_capacity.max(1));
        let (resolve_tx, resolve_rx) = mpsc::sync_channel::<Pending>(stage_cap);
        let (reply_tx, reply_rx) = mpsc::sync_channel::<Served>(stage_cap);

        let lane_queues: Vec<Arc<BatchQueue>> = (0..lanes)
            .map(|_| Arc::new(BatchQueue::new(stage_cap, cfg.max_batch)))
            .collect();
        let lane_engines: Vec<Arc<Mutex<NativeEngine>>> = (0..lanes)
            .map(|_| Arc::new(Mutex::new(NativeEngine::new(cfg.morph))))
            .collect();

        let mut threads = Vec::new();
        threads.push(spawn_stage(
            "morph-ingress",
            admit_rx,
            Ingress {
                deadline,
                resolve_tx,
                reply_tx: reply_tx.clone(),
                metrics: metrics.clone(),
            },
        )?);
        threads.push(spawn_stage(
            "morph-resolve",
            resolve_rx,
            Resolve {
                cfg: cfg.clone(),
                manifest: manifest.clone(),
                deadline,
                lanes: lane_queues
                    .iter()
                    .zip(&lane_engines)
                    .map(|(queue, engine)| Lane {
                        queue: queue.clone(),
                        engine: engine.clone(),
                    })
                    .collect(),
                reply_tx: reply_tx.clone(),
                metrics: metrics.clone(),
            },
        )?);
        for (wid, (queue, engine)) in lane_queues.iter().zip(&lane_engines).enumerate() {
            let cfg = cfg.clone();
            let manifest = manifest.clone();
            let queue = queue.clone();
            let engine = engine.clone();
            let metrics = metrics.clone();
            let reply_tx = reply_tx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("morph-lane-{wid}"))
                    .spawn(move || {
                        execute_lane(wid, cfg, manifest, queue, engine, metrics, reply_tx)
                    })
                    .context("spawning execute lane")?,
            );
        }
        // the stages hold the only reply senders now: when the last lane
        // exits, the reply stage drains and exits
        drop(reply_tx);
        threads.push(spawn_stage(
            "morph-reply",
            reply_rx,
            Reply {
                metrics: metrics.clone(),
                inflight: inflight.clone(),
                budget,
            },
        )?);

        Ok(Pipeline {
            admission: Some(admit_tx),
            inflight,
            budget,
            metrics,
            threads,
        })
    }

    /// Admit one request into the pipeline — the only lossy door.
    /// Sheds (never blocks) when the admission channel is full, the
    /// request's key has exhausted its in-flight budget, or the
    /// pipeline is shut down.
    pub(crate) fn admit(&self, p: Pending) -> std::result::Result<(), Shed> {
        let Some(tx) = self.admission.as_ref() else {
            return Err(Shed::Closed);
        };
        let key = p.req.batch_key();
        if self.budget > 0 {
            let mut inflight = lock_unpoisoned(&self.inflight);
            let n = inflight.entry(key).or_insert(0);
            if *n >= self.budget {
                return Err(Shed::Budget);
            }
            *n += 1;
        }
        // enter INGRESS before the send (see the ordering note in
        // `Ingress::run`); undo on failure
        self.metrics.stage_enter(STAGE_INGRESS);
        match tx.try_send(p) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.metrics.stage_exit(STAGE_INGRESS);
                if self.budget > 0 {
                    release_key(&self.inflight, &key);
                }
                match e {
                    TrySendError::Full(_) => Err(Shed::Full),
                    TrySendError::Disconnected(_) => Err(Shed::Closed),
                }
            }
        }
    }

    /// Close the admission door and join the whole cascade.  Idempotent
    /// (both [`super::Coordinator::shutdown`] and its `Drop` call it).
    pub(crate) fn shutdown(&mut self) {
        self.admission = None;
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::morphology::FilterOp;

    fn key_of(op: FilterOp, w: usize) -> BatchKey {
        let img: ImagePayload = Arc::new(synth::noise(16, 16, 1)).into();
        BatchKey::of(&FilterSpec::new(op, w, w), img.depth(), 16, 16)
    }

    #[test]
    fn lane_routing_is_stable_and_in_range() {
        for lanes in [1usize, 2, 3, 8] {
            for op in [FilterOp::Erode, FilterOp::Dilate, FilterOp::TopHat] {
                let k = key_of(op, 5);
                let lane = lane_of(&k, lanes);
                assert!(lane < lanes);
                // same key, same lane — every time (plan pinning)
                assert_eq!(lane, lane_of(&k, lanes));
            }
        }
        // lanes == 0 must not divide by zero (degenerate config)
        assert_eq!(lane_of(&key_of(FilterOp::Erode, 3), 0), 0);
    }

    #[test]
    fn send_deadline_delivers_reports_blocking_and_expires() {
        let (tx, rx) = mpsc::sync_channel::<u32>(1);
        // room available: immediate, unblocked
        let (r, blocked) = send_deadline(&tx, 1, Instant::now() + Duration::from_secs(1));
        assert!(r.is_ok() && !blocked);
        // full: blocks until the consumer frees room
        let consumer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            rx.recv().unwrap()
        });
        let (r, blocked) = send_deadline(&tx, 2, Instant::now() + Duration::from_secs(5));
        assert!(r.is_ok() && blocked, "send must wait out the full channel");
        assert_eq!(consumer.join().unwrap(), 1);
        // full with nobody pulling: the deadline hands the item back
        let t0 = Instant::now();
        let (r, blocked) = send_deadline(&tx, 3, t0 + Duration::from_millis(30));
        assert_eq!(r, Err(3));
        assert!(blocked);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn release_key_drops_entry_at_zero_and_tolerates_missing() {
        let key = key_of(FilterOp::Erode, 3);
        let inflight = Mutex::new(HashMap::from([(key, 2u64)]));
        release_key(&inflight, &key);
        assert_eq!(lock_unpoisoned(&inflight)[&key], 1);
        release_key(&inflight, &key);
        assert!(lock_unpoisoned(&inflight).is_empty());
        // releasing an unknown key (budget disabled) is a no-op
        release_key(&inflight, &key);
        assert!(lock_unpoisoned(&inflight).is_empty());
    }
}
