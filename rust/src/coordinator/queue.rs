//! The dynamic batching queue.
//!
//! Requests are grouped by the typed [`BatchKey`]
//! ([`super::request::FilterRequest::batch_key`]); a worker pull
//! returns up to `max_batch` requests *of one key*, preferring the key
//! the worker executed last (executable-/plan-cache affinity — on the
//! XLA backend switching keys means touching a different compiled
//! module, on the native engine a different resolved
//! [`crate::morphology::FilterPlan`]).  Keys are `Copy` and hash
//! without heap allocation, so grouping never allocates per request
//! beyond the queue nodes themselves (allocation-counter test in
//! `rust/tests/zero_copy_alloc.rs`).  Total occupancy is bounded:
//! pushes beyond `capacity` are rejected so overload sheds load at the
//! front door instead of growing latency without bound (backpressure).
//!
//! ## Fairness: FIFO aging across keys
//!
//! The HashMap grouping has no inherent order, and pure plan-affinity
//! would let a continuously-refilled hot key starve every other key.
//! Two rules bound waiting time:
//!
//! * **non-affinity pulls take the oldest-waiting key** — every request
//!   carries an arrival sequence number, and the key whose *head*
//!   (oldest pending) request has the smallest sequence wins (not the
//!   longest queue: length favours exactly the hot keys that need no
//!   help);
//! * **affinity yields after bounded bypassing** — a worker may keep
//!   draining its pinned key (plan-cache affinity is the whole point of
//!   batching), but the queue counts every pull that *bypasses* the
//!   oldest-waiting key; once [`MAX_BYPASS_PULLS`] consecutive pulls
//!   have done so, the next pull serves the oldest key regardless of
//!   affinity.  Counting bypasses (rather than one key's streak) makes
//!   the bound independent of how many workers are pinned to how many
//!   hot keys: two workers ping-ponging between two hot keys still
//!   advance the same counter, so a third, cold key is reached within
//!   the same bound.
//!
//! Worst-case wait for a cold request is therefore
//! `MAX_BYPASS_PULLS × max_batch` hot requests once it becomes the
//! oldest, regression-tested by the starvation scenarios
//! (`hot_key_cannot_starve_cold_key`,
//! `two_hot_keys_cannot_starve_cold_key`).

use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::request::{BatchKey, Pending};

/// Consecutive pulls (across ALL workers) that may bypass the
/// oldest-waiting key for affinity before fairness forces it.  Large
/// enough to amortize plan pinning, small enough that a cold key waits
/// at most `MAX_BYPASS_PULLS × max_batch` requests once it is oldest.
pub(crate) const MAX_BYPASS_PULLS: u32 = 4;

/// Pop result.
pub(crate) enum Pull {
    /// A batch of same-key requests.
    Batch(Vec<Pending>),
    /// Queue is shut down and drained.
    Closed,
}

struct State {
    by_key: HashMap<BatchKey, VecDeque<(u64, Pending)>>,
    len: usize,
    closed: bool,
    /// Arrival stamp of the next push (FIFO aging).
    next_seq: u64,
    /// Consecutive pulls that served some key *other than* the
    /// oldest-waiting one (queue-global, so many workers pinned to many
    /// hot keys share one fairness budget).
    bypass_pulls: u32,
}

impl State {
    /// The key whose oldest pending request arrived first.  O(1) for
    /// the dominant single-key case; otherwise a head scan over the
    /// distinct keys (bounded by the key diversity of the in-flight
    /// window, not the queue depth — an incremental minimum would only
    /// pay off under very wide key mixes).
    fn oldest_key(&self) -> Option<BatchKey> {
        if self.by_key.len() <= 1 {
            return self.by_key.keys().next().copied();
        }
        self.by_key
            .iter()
            .filter_map(|(k, q)| q.front().map(|(seq, _)| (*seq, *k)))
            .min_by_key(|(seq, _)| *seq)
            .map(|(_, k)| k)
    }
}

/// Bounded, key-grouping MPMC queue with FIFO aging across keys.
pub(crate) struct BatchQueue {
    state: Mutex<State>,
    nonempty: Condvar,
    nonfull: Condvar,
    capacity: usize,
    max_batch: usize,
}

impl BatchQueue {
    pub fn new(capacity: usize, max_batch: usize) -> Self {
        BatchQueue {
            state: Mutex::new(State {
                by_key: HashMap::new(),
                len: 0,
                closed: false,
                next_seq: 0,
                bypass_pulls: 0,
            }),
            nonempty: Condvar::new(),
            nonfull: Condvar::new(),
            capacity: capacity.max(1),
            max_batch: max_batch.max(1),
        }
    }

    /// Enqueue; `Err(p)` gives the request back when full or closed.
    pub fn push(&self, p: Pending) -> Result<(), Pending> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.len >= self.capacity {
            return Err(p);
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.by_key
            .entry(p.req.batch_key())
            .or_default()
            .push_back((seq, p));
        st.len += 1;
        drop(st);
        self.nonempty.notify_one();
        Ok(())
    }

    /// Enqueue, **blocking** until room frees up or `deadline` passes —
    /// the pipeline's stage-to-stage send: upstream stages propagate
    /// backpressure by waiting here instead of shedding (admission is
    /// the only lossy door).  `Err(p)` gives the request back when the
    /// queue is closed or the deadline expires while still full.
    pub fn push_wait(&self, p: Pending, deadline: std::time::Instant) -> Result<(), Pending> {
        let mut st = self.state.lock().unwrap();
        while !st.closed && st.len >= self.capacity {
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(p);
            }
            let (next, _) = self.nonfull.wait_timeout(st, deadline - now).unwrap();
            st = next;
        }
        if st.closed {
            return Err(p);
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.by_key
            .entry(p.req.batch_key())
            .or_default()
            .push_back((seq, p));
        st.len += 1;
        drop(st);
        self.nonempty.notify_one();
        Ok(())
    }

    /// Dequeue a batch, blocking up to `wait` when empty.
    ///
    /// `affinity` is the key the caller last served; if it still has
    /// pending requests it is preferred (plan-cache locality) unless
    /// the fairness rule fires — see the module docs.
    pub fn pull(&self, affinity: Option<&BatchKey>, wait: Duration) -> Pull {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.len > 0 {
                let oldest = st.oldest_key();
                let aff = affinity
                    .filter(|k| st.by_key.get(*k).is_some_and(|q| !q.is_empty()))
                    .copied();
                let key = match (aff, oldest) {
                    // fairness: the oldest key has been bypassed long
                    // enough — serve it regardless of affinity
                    (Some(a), Some(old)) if old != a && st.bypass_pulls >= MAX_BYPASS_PULLS => {
                        Some(old)
                    }
                    (Some(a), _) => Some(a),
                    (None, old) => old,
                };
                if let Some(key) = key {
                    if oldest.is_some_and(|old| old != key) {
                        st.bypass_pulls = st.bypass_pulls.saturating_add(1);
                    } else {
                        st.bypass_pulls = 0;
                    }
                    let max_batch = self.max_batch;
                    let q = st.by_key.get_mut(&key).unwrap();
                    let n = q.len().min(max_batch);
                    let batch: Vec<Pending> = q.drain(..n).map(|(_, p)| p).collect();
                    if q.is_empty() {
                        st.by_key.remove(&key);
                    }
                    st.len -= batch.len();
                    drop(st);
                    self.nonfull.notify_all();
                    return Pull::Batch(batch);
                }
            }
            if st.closed {
                return Pull::Closed;
            }
            let (next, timeout) = self.nonempty.wait_timeout(st, wait).unwrap();
            st = next;
            if timeout.timed_out() && st.len == 0 {
                if st.closed {
                    return Pull::Closed;
                }
                // spurious empty wakeup: loop again (callers rely on
                // pull blocking until work or close)
            }
        }
    }

    /// Current occupancy.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().len
    }

    /// Close the queue; pending work is still drained by `pull`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.nonempty.notify_all();
        self.nonfull.notify_all(); // blocked push_wait callers must see closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::image::Image;
    use crate::morphology::{FilterOp, FilterSpec};
    use std::sync::mpsc;
    use std::sync::Arc;
    use std::time::Instant;

    fn pending(op: &str, w: usize, img: &Arc<Image<u8>>) -> Pending {
        let (tx, _rx) = mpsc::channel();
        std::mem::forget(_rx);
        let op: FilterOp = op.parse().unwrap();
        Pending {
            req: super::super::request::FilterRequest {
                id: 0,
                spec: FilterSpec::new(op, w, w),
                image: img.clone().into(),
                enqueued: Instant::now(),
            },
            reply: tx,
        }
    }

    #[test]
    fn batches_group_by_key() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = BatchQueue::new(100, 8);
        for _ in 0..3 {
            q.push(pending("erode", 3, &img)).ok().unwrap();
        }
        for _ in 0..2 {
            q.push(pending("dilate", 3, &img)).ok().unwrap();
        }
        let Pull::Batch(b1) = q.pull(None, Duration::from_millis(10)) else {
            panic!("expected batch");
        };
        assert_eq!(b1.len(), 3); // erode arrived first (oldest key wins)
        assert!(b1
            .iter()
            .all(|p| p.req.spec.single_op() == Some(FilterOp::Erode)));
        let Pull::Batch(b2) = q.pull(None, Duration::from_millis(10)) else {
            panic!("expected batch");
        };
        assert_eq!(b2.len(), 2);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn non_affinity_pull_takes_oldest_waiting_key() {
        // dilate has the LONGER queue but erode arrived first: FIFO
        // aging must pick erode (length favours hot keys)
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = BatchQueue::new(100, 8);
        q.push(pending("erode", 3, &img)).ok().unwrap();
        for _ in 0..5 {
            q.push(pending("dilate", 3, &img)).ok().unwrap();
        }
        let Pull::Batch(b) = q.pull(None, Duration::from_millis(10)) else {
            panic!();
        };
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].req.spec.single_op(), Some(FilterOp::Erode));
    }

    #[test]
    fn max_batch_respected() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = BatchQueue::new(100, 4);
        for _ in 0..10 {
            q.push(pending("erode", 3, &img)).ok().unwrap();
        }
        let Pull::Batch(b) = q.pull(None, Duration::from_millis(10)) else {
            panic!();
        };
        assert_eq!(b.len(), 4);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn affinity_preferred() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = BatchQueue::new(100, 8);
        for _ in 0..5 {
            q.push(pending("erode", 3, &img)).ok().unwrap();
        }
        q.push(pending("dilate", 3, &img)).ok().unwrap();
        let key = pending("dilate", 3, &img).req.batch_key();
        let Pull::Batch(b) = q.pull(Some(&key), Duration::from_millis(10)) else {
            panic!();
        };
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].req.spec.single_op(), Some(FilterOp::Dilate));
    }

    #[test]
    fn hot_key_cannot_starve_cold_key() {
        // the two-key starvation regression: a worker with affinity for
        // a continuously-hot key must still serve the cold key within
        // MAX_BYPASS_PULLS batches of it becoming the oldest
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = BatchQueue::new(1000, 2);
        let hot_key = pending("erode", 3, &img).req.batch_key();
        for _ in 0..4 {
            q.push(pending("erode", 3, &img)).ok().unwrap();
        }
        q.push(pending("dilate", 3, &img)).ok().unwrap(); // the cold one
        let mut pulls_until_cold = 0u32;
        loop {
            // keep the hot key continuously refilled — pure affinity
            // would never switch
            q.push(pending("erode", 3, &img)).ok().unwrap();
            q.push(pending("erode", 3, &img)).ok().unwrap();
            let Pull::Batch(b) = q.pull(Some(&hot_key), Duration::from_millis(10)) else {
                panic!();
            };
            pulls_until_cold += 1;
            if b[0].req.spec.single_op() == Some(FilterOp::Dilate) {
                break;
            }
            assert!(
                pulls_until_cold <= MAX_BYPASS_PULLS + 2,
                "cold key starved: {pulls_until_cold} hot batches and counting"
            );
        }
        // and after the fairness pull the worker goes back to its key
        let Pull::Batch(b) = q.pull(Some(&hot_key), Duration::from_millis(10)) else {
            panic!();
        };
        assert_eq!(b[0].req.spec.single_op(), Some(FilterOp::Erode));
    }

    #[test]
    fn two_hot_keys_cannot_starve_cold_key() {
        // multi-worker shape: two affinity pullers ping-pong between
        // two continuously-hot keys; the bypass counter is shared, so
        // the cold third key is still served within the global bound
        // (a per-key streak would reset on every alternation and never
        // fire)
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = BatchQueue::new(1000, 2);
        let k_erode = pending("erode", 3, &img).req.batch_key();
        let k_open = pending("opening", 3, &img).req.batch_key();
        for _ in 0..2 {
            q.push(pending("erode", 3, &img)).ok().unwrap();
            q.push(pending("opening", 3, &img)).ok().unwrap();
        }
        q.push(pending("dilate", 3, &img)).ok().unwrap(); // the cold one
        let mut pulls_until_cold = 0u32;
        loop {
            q.push(pending("erode", 3, &img)).ok().unwrap();
            q.push(pending("opening", 3, &img)).ok().unwrap();
            // alternate the two pinned workers
            let aff = if pulls_until_cold % 2 == 0 { &k_erode } else { &k_open };
            let Pull::Batch(b) = q.pull(Some(aff), Duration::from_millis(10)) else {
                panic!();
            };
            pulls_until_cold += 1;
            if b[0].req.spec.single_op() == Some(FilterOp::Dilate) {
                break;
            }
            assert!(
                pulls_until_cold <= MAX_BYPASS_PULLS + 2,
                "cold key starved by alternating hot keys: {pulls_until_cold} batches"
            );
        }
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = BatchQueue::new(2, 8);
        assert!(q.push(pending("erode", 3, &img)).is_ok());
        assert!(q.push(pending("erode", 3, &img)).is_ok());
        assert!(q.push(pending("erode", 3, &img)).is_err());
    }

    #[test]
    fn push_wait_blocks_until_pull_frees_room() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = Arc::new(BatchQueue::new(1, 8));
        q.push(pending("erode", 3, &img)).ok().unwrap();
        // a generous deadline: the push must ride out the full queue
        // until the puller drains it, NOT time out
        let q2 = q.clone();
        let p = pending("dilate", 3, &img);
        let h = std::thread::spawn(move || {
            q2.push_wait(p, Instant::now() + Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(20));
        let Pull::Batch(b) = q.pull(None, Duration::from_millis(100)) else {
            panic!();
        };
        assert_eq!(b[0].req.spec.single_op(), Some(FilterOp::Erode));
        assert!(h.join().unwrap().is_ok(), "push_wait must land after the pull");
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn push_wait_times_out_when_still_full() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = BatchQueue::new(1, 8);
        q.push(pending("erode", 3, &img)).ok().unwrap();
        let t0 = Instant::now();
        let r = q.push_wait(pending("dilate", 3, &img), t0 + Duration::from_millis(30));
        assert!(r.is_err(), "deadline expiry must hand the request back");
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn push_wait_wakes_on_close() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = Arc::new(BatchQueue::new(1, 8));
        q.push(pending("erode", 3, &img)).ok().unwrap();
        let q2 = q.clone();
        let p = pending("dilate", 3, &img);
        let h = std::thread::spawn(move || {
            q2.push_wait(p, Instant::now() + Duration::from_secs(30))
        });
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_err(), "close must fail blocked pushes promptly");
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = BatchQueue::new(10, 8);
        q.push(pending("erode", 3, &img)).ok().unwrap();
        q.close();
        assert!(q.push(pending("erode", 3, &img)).is_err());
        assert!(matches!(q.pull(None, Duration::from_millis(1)), Pull::Batch(_)));
        assert!(matches!(q.pull(None, Duration::from_millis(1)), Pull::Closed));
    }

    #[test]
    fn pull_wakes_on_push_from_other_thread() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = Arc::new(BatchQueue::new(10, 8));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pull(None, Duration::from_millis(50)));
        std::thread::sleep(Duration::from_millis(20));
        q.push(pending("erode", 3, &img)).ok().unwrap();
        match h.join().unwrap() {
            Pull::Batch(b) => assert_eq!(b.len(), 1),
            Pull::Closed => panic!("should have received the batch"),
        }
    }
}
