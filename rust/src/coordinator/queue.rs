//! The dynamic batching queue.
//!
//! Requests are grouped by the typed [`BatchKey`]
//! ([`super::request::FilterRequest::batch_key`]); a worker pull
//! returns up to `max_batch` requests *of one key*, preferring the key
//! the worker executed last (executable-/plan-cache affinity — on the
//! XLA backend switching keys means touching a different compiled
//! module, on the native engine a different resolved
//! [`crate::morphology::FilterPlan`]).  Keys are `Copy` and hash
//! without heap allocation, so grouping never allocates per request
//! beyond the queue nodes themselves (allocation-counter test in
//! `rust/tests/zero_copy_alloc.rs`).  Total occupancy is bounded:
//! pushes beyond `capacity` are rejected so overload sheds load at the
//! front door instead of growing latency without bound (backpressure).

use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::request::{BatchKey, Pending};

/// Pop result.
pub(crate) enum Pull {
    /// A batch of same-key requests.
    Batch(Vec<Pending>),
    /// Queue is shut down and drained.
    Closed,
}

struct State {
    by_key: HashMap<BatchKey, VecDeque<Pending>>,
    len: usize,
    closed: bool,
}

/// Bounded, key-grouping MPMC queue.
pub(crate) struct BatchQueue {
    state: Mutex<State>,
    nonempty: Condvar,
    capacity: usize,
    max_batch: usize,
}

impl BatchQueue {
    pub fn new(capacity: usize, max_batch: usize) -> Self {
        BatchQueue {
            state: Mutex::new(State {
                by_key: HashMap::new(),
                len: 0,
                closed: false,
            }),
            nonempty: Condvar::new(),
            capacity: capacity.max(1),
            max_batch: max_batch.max(1),
        }
    }

    /// Enqueue; `Err(p)` gives the request back when full or closed.
    pub fn push(&self, p: Pending) -> Result<(), Pending> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.len >= self.capacity {
            return Err(p);
        }
        st.by_key.entry(p.req.batch_key()).or_default().push_back(p);
        st.len += 1;
        drop(st);
        self.nonempty.notify_one();
        Ok(())
    }

    /// Dequeue a batch, blocking up to `wait` when empty.
    ///
    /// `affinity` is the key the caller last served; if it still has
    /// pending requests it is preferred, otherwise the longest queue is
    /// taken (drains hot keys first).
    pub fn pull(&self, affinity: Option<&BatchKey>, wait: Duration) -> Pull {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.len > 0 {
                let key = affinity
                    .filter(|k| st.by_key.get(*k).is_some_and(|q| !q.is_empty()))
                    .copied()
                    .or_else(|| {
                        st.by_key
                            .iter()
                            .max_by_key(|(_, q)| q.len())
                            .map(|(k, _)| *k)
                    });
                if let Some(key) = key {
                    let q = st.by_key.get_mut(&key).unwrap();
                    let n = q.len().min(self.max_batch);
                    let batch: Vec<Pending> = q.drain(..n).collect();
                    if q.is_empty() {
                        st.by_key.remove(&key);
                    }
                    st.len -= batch.len();
                    return Pull::Batch(batch);
                }
            }
            if st.closed {
                return Pull::Closed;
            }
            let (next, timeout) = self.nonempty.wait_timeout(st, wait).unwrap();
            st = next;
            if timeout.timed_out() && st.len == 0 {
                if st.closed {
                    return Pull::Closed;
                }
                // spurious empty wakeup: loop again (callers rely on
                // pull blocking until work or close)
            }
        }
    }

    /// Current occupancy.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().len
    }

    /// Close the queue; pending work is still drained by `pull`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.nonempty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::image::Image;
    use crate::morphology::{FilterOp, FilterSpec};
    use std::sync::mpsc;
    use std::sync::Arc;
    use std::time::Instant;

    fn pending(op: &str, w: usize, img: &Arc<Image<u8>>) -> Pending {
        let (tx, _rx) = mpsc::channel();
        std::mem::forget(_rx);
        let op: FilterOp = op.parse().unwrap();
        Pending {
            req: super::super::request::FilterRequest {
                id: 0,
                spec: FilterSpec::new(op, w, w),
                image: img.clone().into(),
                enqueued: Instant::now(),
            },
            reply: tx,
        }
    }

    #[test]
    fn batches_group_by_key() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = BatchQueue::new(100, 8);
        for _ in 0..3 {
            q.push(pending("erode", 3, &img)).ok().unwrap();
        }
        for _ in 0..2 {
            q.push(pending("dilate", 3, &img)).ok().unwrap();
        }
        let Pull::Batch(b1) = q.pull(None, Duration::from_millis(10)) else {
            panic!("expected batch");
        };
        assert_eq!(b1.len(), 3); // longest queue first
        assert!(b1
            .iter()
            .all(|p| p.req.spec.single_op() == Some(FilterOp::Erode)));
        let Pull::Batch(b2) = q.pull(None, Duration::from_millis(10)) else {
            panic!("expected batch");
        };
        assert_eq!(b2.len(), 2);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn max_batch_respected() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = BatchQueue::new(100, 4);
        for _ in 0..10 {
            q.push(pending("erode", 3, &img)).ok().unwrap();
        }
        let Pull::Batch(b) = q.pull(None, Duration::from_millis(10)) else {
            panic!();
        };
        assert_eq!(b.len(), 4);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn affinity_preferred() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = BatchQueue::new(100, 8);
        for _ in 0..5 {
            q.push(pending("erode", 3, &img)).ok().unwrap();
        }
        q.push(pending("dilate", 3, &img)).ok().unwrap();
        let key = pending("dilate", 3, &img).req.batch_key();
        let Pull::Batch(b) = q.pull(Some(&key), Duration::from_millis(10)) else {
            panic!();
        };
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].req.spec.single_op(), Some(FilterOp::Dilate));
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = BatchQueue::new(2, 8);
        assert!(q.push(pending("erode", 3, &img)).is_ok());
        assert!(q.push(pending("erode", 3, &img)).is_ok());
        assert!(q.push(pending("erode", 3, &img)).is_err());
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = BatchQueue::new(10, 8);
        q.push(pending("erode", 3, &img)).ok().unwrap();
        q.close();
        assert!(q.push(pending("erode", 3, &img)).is_err());
        assert!(matches!(q.pull(None, Duration::from_millis(1)), Pull::Batch(_)));
        assert!(matches!(q.pull(None, Duration::from_millis(1)), Pull::Closed));
    }

    #[test]
    fn pull_wakes_on_push_from_other_thread() {
        let img = Arc::new(synth::noise(8, 8, 1));
        let q = Arc::new(BatchQueue::new(10, 8));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pull(None, Duration::from_millis(50)));
        std::thread::sleep(Duration::from_millis(20));
        q.push(pending("erode", 3, &img)).ok().unwrap();
        match h.join().unwrap() {
            Pull::Batch(b) => assert_eq!(b.len(), 1),
            Pull::Closed => panic!("should have received the batch"),
        }
    }
}
