//! Execution engines: the [`Engine`] trait abstracts "run artifact
//! `name` on an image" so the coordinator can run against the real PJRT
//! runtime ([`super::XlaRuntime`]) or the in-process native
//! implementation ([`NativeEngine`]) — the latter both serves as the
//! router's fast path for shapes without artifacts and lets coordinator
//! tests run without compiled artifacts.
//!
//! Depth dispatch: [`Engine::run`] serves u8 images, [`Engine::run_u16`]
//! serves u16 ones.  The native engine implements both through one
//! generic body ([`MorphPixel`]); the XLA runtime only has u8 artifacts
//! and keeps the default erroring `run_u16`, so the coordinator routes
//! u16 requests to the native engine.

use anyhow::{anyhow, Result};

use super::manifest::ArtifactMeta;
use crate::image::Image;
use crate::morphology::{parallel, MorphConfig, MorphOp, MorphPixel};
use crate::neon::Native;

/// Something that can execute a named morphology/transpose artifact.
pub trait Engine: Send {
    /// Execute the operation described by `meta` on a u8 image.
    fn run(&mut self, meta: &ArtifactMeta, img: &Image<u8>) -> Result<Image<u8>>;

    /// Execute on a u16 image.  Backends without 16-bit support keep
    /// this default and the router falls back to the native engine.
    fn run_u16(&mut self, meta: &ArtifactMeta, img: &Image<u16>) -> Result<Image<u16>> {
        let _ = (meta, img);
        Err(anyhow!(
            "backend {:?} has no u16 support",
            self.backend_name()
        ))
    }

    /// Short backend label for metrics/logs.
    fn backend_name(&self) -> &'static str;
}

/// Pure-rust engine: executes the op with the crate's native morphology
/// (paper §5.3 final configuration) at either pixel depth.  Large
/// images are band-sharded across the process-wide worker pool when the
/// cost-model crossover predicts a win (`MorphConfig::parallelism`,
/// default `Auto`) — output stays bit-identical to sequential
/// execution, so the router's backend choice never changes results.
#[derive(Clone, Debug, Default)]
pub struct NativeEngine {
    cfg: MorphConfig,
}

impl NativeEngine {
    pub fn new(cfg: MorphConfig) -> Self {
        NativeEngine { cfg }
    }

    /// Depth-generic execution body shared by `run` and `run_u16`.
    /// Routes every morphology op through the band-parallel entry
    /// points ([`parallel::filter_native`] and the `*_native` derived
    /// compositions).
    fn run_any<P: MorphPixel>(&self, meta: &ArtifactMeta, img: &Image<P>) -> Result<Image<P>> {
        if img.height() != meta.height || img.width() != meta.width {
            return Err(anyhow!(
                "image {}x{} does not match artifact {} ({}x{})",
                img.height(),
                img.width(),
                meta.name,
                meta.height,
                meta.width
            ));
        }
        let (w_x, w_y) = (meta.w_x, meta.w_y);
        let cfg = &self.cfg;
        let out = match meta.op.as_str() {
            "erode" => parallel::filter_native(img, MorphOp::Erode, w_x, w_y, cfg),
            "dilate" => parallel::filter_native(img, MorphOp::Dilate, w_x, w_y, cfg),
            "opening" => parallel::opening_native(img, w_x, w_y, cfg),
            "closing" => parallel::closing_native(img, w_x, w_y, cfg),
            "gradient" => parallel::gradient_native(img, w_x, w_y, cfg),
            "tophat" => parallel::tophat_native(img, w_x, w_y, cfg),
            "blackhat" => parallel::blackhat_native(img, w_x, w_y, cfg),
            "transpose" => P::transpose_image(&mut Native, img.view()),
            other => return Err(anyhow!("unknown op {other:?}")),
        };
        Ok(out)
    }
}

impl Engine for NativeEngine {
    fn run(&mut self, meta: &ArtifactMeta, img: &Image<u8>) -> Result<Image<u8>> {
        self.run_any(meta, img)
    }

    fn run_u16(&mut self, meta: &ArtifactMeta, img: &Image<u16>) -> Result<Image<u16>> {
        self.run_any(meta, img)
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;

    fn meta(op: &str, h: usize, w: usize, wx: usize, wy: usize) -> ArtifactMeta {
        meta_dtype(op, h, w, wx, wy, "u8")
    }

    fn meta_dtype(op: &str, h: usize, w: usize, wx: usize, wy: usize, dt: &str) -> ArtifactMeta {
        ArtifactMeta {
            name: format!("{op}_{h}x{w}_w{wx}x{wy}_{dt}"),
            kind: "morphology".into(),
            op: op.into(),
            height: h,
            width: w,
            w_x: wx,
            w_y: wy,
            method: "hybrid".into(),
            vertical: "transpose".into(),
            dtype: dt.into(),
            file: String::new(),
            out_shape: if op == "transpose" { (w, h) } else { (h, w) },
        }
    }

    #[test]
    fn native_engine_runs_all_ops() {
        let img = synth::noise(32, 48, 3);
        let mut e = NativeEngine::default();
        for op in ["erode", "dilate", "opening", "closing", "gradient", "tophat", "blackhat"] {
            let out = e.run(&meta(op, 32, 48, 3, 3), &img).unwrap();
            assert_eq!((out.height(), out.width()), (32, 48), "{op}");
        }
        let t = e.run(&meta("transpose", 32, 48, 0, 0), &img).unwrap();
        assert_eq!((t.height(), t.width()), (48, 32));
    }

    #[test]
    fn native_engine_runs_all_ops_u16() {
        let img = synth::noise_u16(24, 32, 3);
        let mut e = NativeEngine::default();
        for op in ["erode", "dilate", "opening", "closing", "gradient", "tophat", "blackhat"] {
            let out = e.run_u16(&meta_dtype(op, 24, 32, 3, 3, "u16"), &img).unwrap();
            assert_eq!((out.height(), out.width()), (24, 32), "{op}");
        }
        let t = e
            .run_u16(&meta_dtype("transpose", 24, 32, 0, 0, "u16"), &img)
            .unwrap();
        assert_eq!((t.height(), t.width()), (32, 24));
        assert!(t.same_pixels(&img.transposed()));
    }

    #[test]
    fn native_engine_checks_shape() {
        let img = synth::noise(8, 8, 1);
        let mut e = NativeEngine::default();
        assert!(e.run(&meta("erode", 16, 16, 3, 3), &img).is_err());
    }

    #[test]
    fn native_engine_rejects_unknown_op() {
        let img = synth::noise(8, 8, 1);
        let mut e = NativeEngine::default();
        assert!(e.run(&meta("sharpen", 8, 8, 3, 3), &img).is_err());
    }

    #[test]
    fn native_matches_direct_call() {
        let img = synth::noise(24, 40, 9);
        let mut e = NativeEngine::default();
        let got = e.run(&meta("erode", 24, 40, 5, 7), &img).unwrap();
        let want = crate::morphology::erode(&img, 5, 7);
        assert!(got.same_pixels(&want));
    }

    #[test]
    fn native_matches_direct_call_u16() {
        let img = synth::noise_u16(24, 40, 9);
        let mut e = NativeEngine::default();
        let got = e
            .run_u16(&meta_dtype("erode", 24, 40, 5, 7, "u16"), &img)
            .unwrap();
        let want = crate::morphology::erode(&img, 5, 7);
        assert!(got.same_pixels(&want));
    }
}
