//! Execution engines: the [`Engine`] trait abstracts "run a
//! [`FilterSpec`] on an image" so the coordinator can run against the
//! real PJRT runtime ([`super::XlaRuntime`]) or the in-process native
//! implementation ([`NativeEngine`]) — the latter both serves as the
//! router's fast path for specs without artifacts and lets coordinator
//! tests run without compiled artifacts.
//!
//! Depth dispatch: [`Engine::run_spec`] serves u8 images,
//! [`Engine::run_spec_u16`] serves u16 ones.  The native engine
//! implements both through one generic body; the XLA runtime only has
//! u8 artifacts (single-op, no ROI) and keeps the default erroring
//! `run_spec_u16`, so the coordinator routes u16 requests to the native
//! engine.
//!
//! ## Plan cache
//!
//! The native engine resolves each **canonical** `(spec, shape)` pair
//! **once** into a [`FilterPlan`] and reuses it across requests — the
//! serving-side payoff of the plan–execute API: a worker draining a
//! same-key batch re-runs one resolved plan (methods, band geometry and
//! scratch arena already fixed) instead of re-dispatching per request.
//!
//! Keys are canonicalized with
//! [`FilterSpec::canonical_for`](crate::morphology::FilterSpec::canonical_for):
//! plans are position-independent, so an *interior* ROI keys on its
//! shape at the canonical anchor and a same-shape crop sweep resolves
//! **exactly one plan** regardless of offsets (the actual position is
//! supplied at run time through `FilterPlan::run_at`); edge-clamped
//! ROIs resolve different block geometry and keep their own entries.
//! [`NativeEngine::plan_stats`] / [`NativeEngine::take_plan_stats`]
//! count resolutions vs cache hits — the coordinator aggregates them
//! into its metrics and `BENCH_serve.json` gates the
//! resolutions-per-request headline.
//!
//! The legacy `(op, w)`-pair surface survives as the [`ArtifactMeta`]
//! wrappers ([`NativeEngine::run`] / [`NativeEngine::run_u16`]), which
//! build a spec from the meta and execute it through the same cache.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::manifest::ArtifactMeta;
use crate::image::{Image, ImageView};
use crate::morphology::{FilterPlan, FilterSpec, FusedPlan, MorphConfig, MorphPixel};

/// Bound on cached plans per depth (cleared wholesale when exceeded).
pub const PLAN_CACHE_CAP: usize = 64;

/// Bound on the total scratch-arena bytes a per-depth plan cache may
/// pin.  Plans own preallocated intermediates — a multi-slot chain on a
/// large image holds several image-sized buffers — so the cache is
/// bounded by retained bytes, not just entry count (ROI specs key on
/// position and could otherwise pin hundreds of near-identical multi-MB
/// arenas).  Enforcement: entries are evicted one at a time until a new
/// plan fits (never a wholesale clear, so position-churning ROI specs
/// cannot flush hot full-image plans), and a plan whose arena alone
/// exceeds the whole budget runs **uncached** so its memory is freed
/// immediately.
pub const PLAN_CACHE_MAX_BYTES: usize = 32 << 20;

/// Something that can execute a filter spec.
pub trait Engine: Send {
    /// Execute `spec` on a u8 image.
    fn run_spec(&mut self, spec: &FilterSpec, img: &Image<u8>) -> Result<Image<u8>>;

    /// Execute `spec` on a u16 image.  Backends without 16-bit support
    /// keep this default and the router falls back to the native engine.
    fn run_spec_u16(&mut self, spec: &FilterSpec, img: &Image<u16>) -> Result<Image<u16>> {
        let _ = (spec, img);
        Err(anyhow!(
            "backend {:?} has no u16 support",
            self.backend_name()
        ))
    }

    /// Short backend label for metrics/logs.
    fn backend_name(&self) -> &'static str;
}

/// Plan-cache key: the **canonical** spec
/// ([`FilterSpec::canonical_for`] — interior ROIs keyed by shape at the
/// canonical anchor, edge-clamped ones by their own position) plus the
/// image shape.
type PlanKey = (FilterSpec, usize, usize);

/// Plan-cache counters: how many requests resolved a fresh plan vs ran
/// on a cached one (uncached oversized plans count as resolutions).
///
/// Counting is **per plan family** (canonical `(spec, shape)` key), not
/// per cached object: an entry's first-seen request is the resolution
/// and every later request against the same key is a hit — including
/// requests that lazily build the entry's *other* execution variant
/// (single ↔ fused).  That keeps the `BENCH_serve.json` counts exact
/// functions of the request mix, independent of how the queue happened
/// to split batches.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanStats {
    pub resolutions: u64,
    pub hits: u64,
}

/// One plan-cache entry: the per-image [`FilterPlan`] and/or the
/// batch-fused [`FusedPlan`] for one canonical `(spec, shape)` family.
/// Variants are built lazily on first use; whichever arrives first
/// creates the entry (and counts the family's one resolution).
#[derive(Debug)]
struct PlanEntry<P: MorphPixel> {
    single: Option<FilterPlan<P>>,
    fused: Option<FusedPlan<P>>,
}

impl<P: MorphPixel> PlanEntry<P> {
    fn scratch_bytes(&self) -> usize {
        self.single.as_ref().map_or(0, FilterPlan::scratch_bytes)
            + self.fused.as_ref().map_or(0, FusedPlan::scratch_bytes)
    }
}

/// Pure-rust engine: executes specs with the crate's native morphology
/// through cached [`FilterPlan`]s.  Large images are band-sharded
/// across the process-wide worker pool when the plan's cost-model
/// crossover predicts a win — output stays bit-identical to sequential
/// execution, so the router's backend choice never changes results.
///
/// [`NativeEngine::run_spec_batch`] serves whole same-key batches: a
/// full-image batch of more than one image runs through the entry's
/// [`FusedPlan`] — ONE banded execution spanning every image — and
/// falls back to per-image plans otherwise (ROI or transpose specs,
/// mixed shapes, singleton batches).
#[derive(Debug, Default)]
pub struct NativeEngine {
    cfg: MorphConfig,
    plans_u8: HashMap<PlanKey, PlanEntry<u8>>,
    plans_u16: HashMap<PlanKey, PlanEntry<u16>>,
    stats: PlanStats,
}

impl NativeEngine {
    /// An engine whose [`ArtifactMeta`] wrappers apply `cfg` (specs
    /// carry their own configuration and ignore it).
    pub fn new(cfg: MorphConfig) -> Self {
        NativeEngine {
            cfg,
            plans_u8: HashMap::new(),
            plans_u16: HashMap::new(),
            stats: PlanStats::default(),
        }
    }

    /// Resolved plans currently cached (both depths).
    pub fn cached_plans(&self) -> usize {
        self.plans_u8.len() + self.plans_u16.len()
    }

    /// Cumulative plan-cache counters since construction (or the last
    /// [`NativeEngine::take_plan_stats`]).
    pub fn plan_stats(&self) -> PlanStats {
        self.stats
    }

    /// Drain the counters (the coordinator pulls per-batch deltas into
    /// its service metrics).
    pub fn take_plan_stats(&mut self) -> PlanStats {
        std::mem::take(&mut self.stats)
    }

    /// Depth-generic execution body shared by `run_spec` and
    /// `run_spec_u16`: plan once per canonical `(spec, shape)`, run
    /// many — `run_at` supplies the request's actual ROI position.
    fn run_any<P: MorphPixel>(
        cache: &mut HashMap<PlanKey, PlanEntry<P>>,
        stats: &mut PlanStats,
        spec: &FilterSpec,
        img: &Image<P>,
    ) -> Result<Image<P>> {
        if spec.is_reconstruct() {
            return Err(anyhow!(
                "reconstruct spec needs a marker; use run_spec_reconstruct"
            ));
        }
        let (h, w) = (img.height(), img.width());
        // position-independent keying: an interior ROI keys on its
        // shape; the true position is re-applied at run time by
        // `exec_cached`
        let canon = spec.canonical_for(h, w);
        let key = (canon, h, w);
        if let Some(entry) = cache.get_mut(&key) {
            stats.hits += 1;
            if entry.single.is_none() {
                // warm family, cold variant (the family was first seen
                // as a fused batch): build the per-image plan without a
                // resolution — counting is per family, not per object
                entry.single = Some(canon.plan::<P>(h, w)?);
            }
            return Ok(exec_cached(entry.single.as_mut().unwrap(), spec, img));
        }
        stats.resolutions += 1;
        let mut plan = canon.plan::<P>(h, w)?;
        let new_bytes = plan.scratch_bytes();
        if new_bytes > PLAN_CACHE_MAX_BYTES {
            // bigger than the whole budget: run one-shot, never pin
            return Ok(exec_cached(&mut plan, spec, img));
        }
        evict_until_fits(cache, new_bytes);
        let entry = cache.entry(key).or_insert(PlanEntry {
            single: Some(plan),
            fused: None,
        });
        Ok(exec_cached(entry.single.as_mut().unwrap(), spec, img))
    }

    /// Depth-generic warm-ahead body: resolve (and cache) the plan for a
    /// canonical `(spec, shape)` family **without executing anything**.
    /// Counting mirrors [`NativeEngine::run_any`] exactly — a cold
    /// family costs one resolution, a warm one is a hit — so a pipeline
    /// that warms every request before executing it doubles the
    /// per-family touch count deterministically: G requests of one
    /// family score 1 resolution + (2G − 1) hits regardless of batch
    /// splits or execution path.
    fn warm_any<P: MorphPixel>(
        cache: &mut HashMap<PlanKey, PlanEntry<P>>,
        stats: &mut PlanStats,
        spec: &FilterSpec,
        h: usize,
        w: usize,
    ) -> Result<()> {
        let canon = spec.canonical_for(h, w);
        let key = (canon, h, w);
        if let Some(entry) = cache.get_mut(&key) {
            stats.hits += 1;
            if entry.single.is_none() {
                entry.single = Some(canon.plan::<P>(h, w)?);
            }
            return Ok(());
        }
        stats.resolutions += 1;
        let plan = canon.plan::<P>(h, w)?;
        let new_bytes = plan.scratch_bytes();
        if new_bytes > PLAN_CACHE_MAX_BYTES {
            // bigger than the whole budget: nothing to pin — the
            // execute stage will run it one-shot (and count the next
            // touch as another resolution, exactly like `run_any`)
            return Ok(());
        }
        evict_until_fits(cache, new_bytes);
        cache.insert(
            key,
            PlanEntry {
                single: Some(plan),
                fused: None,
            },
        );
        Ok(())
    }

    /// Resolve the u8 plan for `spec` on an `h × w` image ahead of
    /// execution (the pipeline's plan-resolve stage).  See
    /// [`NativeEngine::warm_any`] for the counting contract.
    pub fn warm_spec(&mut self, spec: &FilterSpec, h: usize, w: usize) -> Result<()> {
        Self::warm_any(&mut self.plans_u8, &mut self.stats, spec, h, w)
    }

    /// [`NativeEngine::warm_spec`] at 16-bit depth.
    pub fn warm_spec_u16(&mut self, spec: &FilterSpec, h: usize, w: usize) -> Result<()> {
        Self::warm_any(&mut self.plans_u16, &mut self.stats, spec, h, w)
    }

    /// Depth-generic **batch** body: a same-key batch of more than one
    /// same-shape full-image request runs through the family's
    /// [`FusedPlan`] (ONE banded execution spanning every image);
    /// anything else — singleton batches, ROI or transpose specs, mixed
    /// shapes — degrades to per-image [`NativeEngine::run_any`].
    /// Returns `(outputs, fused)`, where `fused` says whether the fused
    /// path actually ran (the coordinator's metrics counter).
    fn run_batch_any<P: MorphPixel>(
        cache: &mut HashMap<PlanKey, PlanEntry<P>>,
        stats: &mut PlanStats,
        spec: &FilterSpec,
        imgs: &[&Image<P>],
    ) -> Result<(Vec<Image<P>>, bool)> {
        let n = imgs.len();
        if n == 0 {
            return Ok((Vec::new(), false));
        }
        let (h, w) = (imgs[0].height(), imgs[0].width());
        let fusable = n > 1
            && spec.roi.is_none()
            && !spec.is_transpose()
            && !spec.is_reconstruct()
            && imgs.iter().all(|im| (im.height(), im.width()) == (h, w));
        if !fusable {
            let outs = imgs
                .iter()
                .map(|im| Self::run_any(cache, stats, spec, im))
                .collect::<Result<Vec<_>>>()?;
            return Ok((outs, false));
        }
        let canon = spec.canonical_for(h, w);
        let key = (canon, h, w);
        let srcs: Vec<ImageView<'_, P>> = imgs.iter().map(|im| im.view()).collect();
        if let Some(entry) = cache.get_mut(&key) {
            // every request of a warm-family batch is a hit, however
            // the queue split the stream into batches
            stats.hits += n as u64;
            if entry.fused.is_none() {
                entry.fused = Some(canon.plan_fused::<P>(h, w, n)?);
            }
            let fused = entry.fused.as_mut().unwrap();
            return Ok((fused.run_batch_owned(&srcs), true));
        }
        // cold family: the batch's first request is the resolution, the
        // rest are hits (split-independent counting)
        stats.resolutions += 1;
        stats.hits += n as u64 - 1;
        let mut fused = canon.plan_fused::<P>(h, w, n)?;
        let out = fused.run_batch_owned(&srcs);
        let new_bytes = fused.scratch_bytes();
        if new_bytes <= PLAN_CACHE_MAX_BYTES {
            evict_until_fits(cache, new_bytes);
            cache.insert(
                key,
                PlanEntry {
                    single: None,
                    fused: Some(fused),
                },
            );
        }
        Ok((out, true))
    }

    /// Depth-generic reconstruction body: plan-cached like
    /// [`NativeEngine::run_any`] (same per-family hit/resolution
    /// counting), but executes through
    /// [`FilterPlan::run_reconstruct`] — the request image is the
    /// geodesic **mask**, `marker` the second payload.  Returns the
    /// fixpoint and the executed sweep count.
    fn run_reconstruct_any<P: MorphPixel>(
        cache: &mut HashMap<PlanKey, PlanEntry<P>>,
        stats: &mut PlanStats,
        spec: &FilterSpec,
        img: &Image<P>,
        marker: &Image<P>,
    ) -> Result<(Image<P>, usize)> {
        if !spec.is_reconstruct() {
            return Err(anyhow!(
                "run_spec_reconstruct serves reconstruct specs only; got {:?}",
                spec.ops.as_slice()
            ));
        }
        let (h, w) = (img.height(), img.width());
        if (marker.height(), marker.width()) != (h, w) {
            return Err(anyhow!(
                "marker {}x{} does not match the {h}x{w} mask image",
                marker.height(),
                marker.width()
            ));
        }
        let canon = spec.canonical_for(h, w);
        let key = (canon, h, w);
        if let Some(entry) = cache.get_mut(&key) {
            stats.hits += 1;
            if entry.single.is_none() {
                entry.single = Some(canon.plan::<P>(h, w)?);
            }
            return Ok(entry.single.as_mut().unwrap().run_reconstruct_owned(img, marker));
        }
        stats.resolutions += 1;
        let mut plan = canon.plan::<P>(h, w)?;
        let new_bytes = plan.scratch_bytes();
        if new_bytes > PLAN_CACHE_MAX_BYTES {
            return Ok(plan.run_reconstruct_owned(img, marker));
        }
        evict_until_fits(cache, new_bytes);
        let entry = cache.entry(key).or_insert(PlanEntry {
            single: Some(plan),
            fused: None,
        });
        Ok(entry.single.as_mut().unwrap().run_reconstruct_owned(img, marker))
    }

    /// Serve a u8 [`FilterOp::Reconstruct`](crate::morphology::FilterOp)
    /// request: reconstruct `marker` by geodesic dilation under `img`.
    /// See [`NativeEngine::run_reconstruct_any`].
    pub fn run_spec_reconstruct(
        &mut self,
        spec: &FilterSpec,
        img: &Image<u8>,
        marker: &Image<u8>,
    ) -> Result<(Image<u8>, usize)> {
        Self::run_reconstruct_any(&mut self.plans_u8, &mut self.stats, spec, img, marker)
    }

    /// [`NativeEngine::run_spec_reconstruct`] at 16-bit depth.
    pub fn run_spec_reconstruct_u16(
        &mut self,
        spec: &FilterSpec,
        img: &Image<u16>,
        marker: &Image<u16>,
    ) -> Result<(Image<u16>, usize)> {
        Self::run_reconstruct_any(&mut self.plans_u16, &mut self.stats, spec, img, marker)
    }

    /// Serve a whole same-spec u8 batch, fusing when possible.  See
    /// [`NativeEngine::run_batch_any`] for the fusion predicate and the
    /// returned `fused` flag.
    pub fn run_spec_batch(
        &mut self,
        spec: &FilterSpec,
        imgs: &[&Image<u8>],
    ) -> Result<(Vec<Image<u8>>, bool)> {
        Self::run_batch_any(&mut self.plans_u8, &mut self.stats, spec, imgs)
    }

    /// [`NativeEngine::run_spec_batch`] at 16-bit depth.
    pub fn run_spec_batch_u16(
        &mut self,
        spec: &FilterSpec,
        imgs: &[&Image<u16>],
    ) -> Result<(Vec<Image<u16>>, bool)> {
        Self::run_batch_any(&mut self.plans_u16, &mut self.stats, spec, imgs)
    }

    /// Build the spec a legacy artifact description denotes, using this
    /// engine's configuration.
    fn spec_of(&self, meta: &ArtifactMeta) -> Result<FilterSpec> {
        let op = meta
            .op
            .parse::<crate::morphology::FilterOp>()
            .map_err(|e| anyhow!("artifact {}: {e}", meta.name))?;
        Ok(FilterSpec::new(op, meta.w_x, meta.w_y).with_config(self.cfg))
    }

    fn check_shape<P: MorphPixel>(meta: &ArtifactMeta, img: &Image<P>) -> Result<()> {
        if img.height() != meta.height || img.width() != meta.width {
            return Err(anyhow!(
                "image {}x{} does not match artifact {} ({}x{})",
                img.height(),
                img.width(),
                meta.name,
                meta.height,
                meta.width
            ));
        }
        Ok(())
    }

    /// Legacy surface: execute the op described by an [`ArtifactMeta`]
    /// on a u8 image (spec built from the meta + engine config).
    pub fn run(&mut self, meta: &ArtifactMeta, img: &Image<u8>) -> Result<Image<u8>> {
        Self::check_shape(meta, img)?;
        let spec = self.spec_of(meta)?;
        Self::run_any(&mut self.plans_u8, &mut self.stats, &spec, img)
    }

    /// Legacy surface at 16-bit depth.
    pub fn run_u16(&mut self, meta: &ArtifactMeta, img: &Image<u16>) -> Result<Image<u16>> {
        Self::check_shape(meta, img)?;
        let spec = self.spec_of(meta)?;
        Self::run_any(&mut self.plans_u16, &mut self.stats, &spec, img)
    }
}

/// Evict entries one at a time until `new_bytes` more fit under both
/// cache bounds — never wholesale, so key churn cannot flush hot plans.
fn evict_until_fits<P: MorphPixel>(cache: &mut HashMap<PlanKey, PlanEntry<P>>, new_bytes: usize) {
    let mut resident: usize = cache.values().map(PlanEntry::scratch_bytes).sum();
    while !cache.is_empty()
        && (cache.len() >= PLAN_CACHE_CAP || resident + new_bytes > PLAN_CACHE_MAX_BYTES)
    {
        let victim = *cache.keys().next().unwrap();
        if let Some(evicted) = cache.remove(&victim) {
            resident -= evicted.scratch_bytes();
        }
    }
}

/// Execute a cached (canonical-key) plan for the *submitted* spec: a
/// plan canonicalized to a different ROI position runs at the request's
/// actual position ([`FilterPlan::run_at`]); everything else runs
/// as resolved.
fn exec_cached<P: MorphPixel>(
    plan: &mut FilterPlan<P>,
    spec: &FilterSpec,
    img: &Image<P>,
) -> Image<P> {
    match spec.roi {
        Some(roi) if plan.spec().roi != spec.roi => plan.run_owned_at(img, roi),
        _ => plan.run_owned(img),
    }
}

impl Engine for NativeEngine {
    fn run_spec(&mut self, spec: &FilterSpec, img: &Image<u8>) -> Result<Image<u8>> {
        Self::run_any(&mut self.plans_u8, &mut self.stats, spec, img)
    }

    fn run_spec_u16(&mut self, spec: &FilterSpec, img: &Image<u16>) -> Result<Image<u16>> {
        Self::run_any(&mut self.plans_u16, &mut self.stats, spec, img)
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::morphology::{FilterOp, Roi};

    fn meta(op: &str, h: usize, w: usize, wx: usize, wy: usize) -> ArtifactMeta {
        meta_dtype(op, h, w, wx, wy, "u8")
    }

    fn meta_dtype(op: &str, h: usize, w: usize, wx: usize, wy: usize, dt: &str) -> ArtifactMeta {
        ArtifactMeta {
            name: format!("{op}_{h}x{w}_w{wx}x{wy}_{dt}"),
            kind: "morphology".into(),
            op: op.into(),
            height: h,
            width: w,
            w_x: wx,
            w_y: wy,
            method: "hybrid".into(),
            vertical: "transpose".into(),
            dtype: dt.into(),
            file: String::new(),
            out_shape: if op == "transpose" { (w, h) } else { (h, w) },
        }
    }

    #[test]
    fn native_engine_runs_all_ops() {
        let img = synth::noise(32, 48, 3);
        let mut e = NativeEngine::default();
        for op in ["erode", "dilate", "opening", "closing", "gradient", "tophat", "blackhat"] {
            let out = e.run(&meta(op, 32, 48, 3, 3), &img).unwrap();
            assert_eq!((out.height(), out.width()), (32, 48), "{op}");
        }
        let t = e.run(&meta("transpose", 32, 48, 0, 0), &img).unwrap();
        assert_eq!((t.height(), t.width()), (48, 32));
    }

    #[test]
    fn native_engine_runs_all_ops_u16() {
        let img = synth::noise_u16(24, 32, 3);
        let mut e = NativeEngine::default();
        for op in ["erode", "dilate", "opening", "closing", "gradient", "tophat", "blackhat"] {
            let out = e.run_u16(&meta_dtype(op, 24, 32, 3, 3, "u16"), &img).unwrap();
            assert_eq!((out.height(), out.width()), (24, 32), "{op}");
        }
        let t = e
            .run_u16(&meta_dtype("transpose", 24, 32, 0, 0, "u16"), &img)
            .unwrap();
        assert_eq!((t.height(), t.width()), (32, 24));
        assert!(t.same_pixels(&img.transposed()));
    }

    #[test]
    fn native_engine_checks_shape() {
        let img = synth::noise(8, 8, 1);
        let mut e = NativeEngine::default();
        assert!(e.run(&meta("erode", 16, 16, 3, 3), &img).is_err());
    }

    #[test]
    fn native_engine_rejects_unknown_op() {
        let img = synth::noise(8, 8, 1);
        let mut e = NativeEngine::default();
        assert!(e.run(&meta("sharpen", 8, 8, 3, 3), &img).is_err());
    }

    #[test]
    fn native_matches_direct_call() {
        let img = synth::noise(24, 40, 9);
        let mut e = NativeEngine::default();
        let got = e.run(&meta("erode", 24, 40, 5, 7), &img).unwrap();
        let want = crate::morphology::erode(&img, 5, 7);
        assert!(got.same_pixels(&want));
    }

    #[test]
    fn native_matches_direct_call_u16() {
        let img = synth::noise_u16(24, 40, 9);
        let mut e = NativeEngine::default();
        let got = e
            .run_u16(&meta_dtype("erode", 24, 40, 5, 7, "u16"), &img)
            .unwrap();
        let want = crate::morphology::erode(&img, 5, 7);
        assert!(got.same_pixels(&want));
    }

    #[test]
    fn run_spec_reuses_cached_plans() {
        let mut e = NativeEngine::default();
        let spec = FilterSpec::new(FilterOp::TopHat, 5, 3);
        let a = synth::noise(20, 28, 1);
        let b = synth::noise(20, 28, 2);
        let ra = e.run_spec(&spec, &a).unwrap();
        assert_eq!(e.cached_plans(), 1);
        let _rb = e.run_spec(&spec, &b).unwrap();
        assert_eq!(e.cached_plans(), 1, "same (spec, shape) must reuse the plan");
        let ra2 = e.run_spec(&spec, &a).unwrap();
        assert!(ra.same_pixels(&ra2));
        // a different shape resolves its own plan
        let c = synth::noise(10, 12, 3);
        let _ = e.run_spec(&spec, &c).unwrap();
        assert_eq!(e.cached_plans(), 2);
        let want = crate::morphology::parallel::tophat_native(&a, 5, 3, &MorphConfig::default());
        assert!(ra.same_pixels(&want));
    }

    #[test]
    fn run_spec_handles_roi_and_errors() {
        let mut e = NativeEngine::default();
        let img = synth::noise(30, 30, 4);
        let spec = FilterSpec::new(FilterOp::Erode, 5, 5).with_roi(Roi::new(4, 6, 10, 12));
        let got = e.run_spec(&spec, &img).unwrap();
        let full = crate::morphology::erode(&img, 5, 5);
        assert!(got.same_pixels(&full.view().sub_rect(4, 6, 10, 12).to_image()));
        // invalid spec surfaces as an error, not a panic
        let bad = FilterSpec::new(FilterOp::Erode, 4, 4);
        assert!(e.run_spec(&bad, &img).is_err());
        let oob = FilterSpec::new(FilterOp::Erode, 3, 3).with_roi(Roi::new(25, 25, 10, 10));
        assert!(e.run_spec(&oob, &img).is_err());
    }

    #[test]
    fn interior_roi_sweep_resolves_exactly_one_plan() {
        // the position-independence acceptance criterion: N same-shape
        // interior ROIs over one image hit ONE cached plan
        let mut e = NativeEngine::default();
        let img = synth::noise(64, 72, 0x404);
        let base = FilterSpec::new(FilterOp::TopHat, 5, 7); // halo (4, 6)
        let full = crate::morphology::parallel::tophat_native(&img, 5, 7, &MorphConfig::default());
        let positions = [(6, 4), (6, 30), (20, 19), (34, 40), (64 - 12 - 6, 72 - 16 - 4)];
        for &(y, x) in &positions {
            let spec = base.with_roi(Roi::new(y, x, 12, 16));
            let got = e.run_spec(&spec, &img).unwrap();
            let want = full.view().sub_rect(y, x, 12, 16).to_image();
            assert!(got.same_pixels(&want), "roi at ({y},{x})");
        }
        assert_eq!(e.cached_plans(), 1, "one plan must serve every interior position");
        let stats = e.plan_stats();
        assert_eq!(stats.resolutions, 1);
        assert_eq!(stats.hits, positions.len() as u64 - 1);
        // an edge-clamped position resolves its own plan
        let clamped = base.with_roi(Roi::new(0, 0, 12, 16));
        let got = e.run_spec(&clamped, &img).unwrap();
        assert!(got.same_pixels(&full.view().sub_rect(0, 0, 12, 16).to_image()));
        assert_eq!(e.cached_plans(), 2);
        assert_eq!(e.plan_stats().resolutions, 2);
    }

    #[test]
    fn take_plan_stats_drains_counters() {
        let mut e = NativeEngine::default();
        let img = synth::noise(16, 16, 2);
        let spec = FilterSpec::new(FilterOp::Erode, 3, 3);
        let _ = e.run_spec(&spec, &img).unwrap();
        let _ = e.run_spec(&spec, &img).unwrap();
        let s = e.take_plan_stats();
        assert_eq!(s, PlanStats { resolutions: 1, hits: 1 });
        assert_eq!(e.plan_stats(), PlanStats::default());
        let _ = e.run_spec(&spec, &img).unwrap();
        assert_eq!(e.plan_stats().hits, 1, "cache itself survives the drain");
    }

    #[test]
    fn fused_batches_match_per_image_and_count_per_family() {
        let mut e = NativeEngine::default();
        let spec = FilterSpec::new(FilterOp::Erode, 5, 5);
        let imgs: Vec<Image<u8>> = (0..4).map(|i| synth::noise(20, 28, i as u64)).collect();
        let refs: Vec<&Image<u8>> = imgs.iter().collect();
        let (outs, fused) = e.run_spec_batch(&spec, &refs).unwrap();
        assert!(fused, "same-shape full-image batch must fuse");
        assert_eq!(e.plan_stats(), PlanStats { resolutions: 1, hits: 3 });
        for (img, out) in imgs.iter().zip(&outs) {
            let want = crate::morphology::erode(img, 5, 5);
            assert!(out.same_pixels(&want), "fused output must be bit-identical");
        }
        // a warm-family singleton lazily builds the single variant — a
        // hit, not a second resolution
        let one = e.run_spec(&spec, &imgs[0]).unwrap();
        assert!(one.same_pixels(&outs[0]));
        assert_eq!(e.plan_stats(), PlanStats { resolutions: 1, hits: 4 });
        assert_eq!(e.cached_plans(), 1, "both variants share one family entry");
        // split-independence: any later batch of the family is all hits
        let (_, fused2) = e.run_spec_batch(&spec, &refs[..2]).unwrap();
        assert!(fused2);
        assert_eq!(e.plan_stats(), PlanStats { resolutions: 1, hits: 6 });
    }

    #[test]
    fn non_fusable_batches_fall_back_per_image() {
        let mut e = NativeEngine::default();
        let spec = FilterSpec::new(FilterOp::Erode, 3, 3);
        let a = synth::noise(16, 16, 1);
        let b = synth::noise(12, 20, 2);
        // mixed shapes: per-image path, one resolution per shape
        let (outs, fused) = e.run_spec_batch(&spec, &[&a, &b]).unwrap();
        assert!(!fused);
        assert_eq!(outs.len(), 2);
        assert_eq!(e.plan_stats(), PlanStats { resolutions: 2, hits: 0 });
        // singleton batches never fuse
        let (_, f1) = e.run_spec_batch(&spec, &[&a]).unwrap();
        assert!(!f1);
        assert_eq!(e.plan_stats().hits, 1);
        // ROI specs run per image (fused plans are full-image only)
        let roi_spec = spec.with_roi(Roi::new(4, 4, 6, 6));
        let c = synth::noise(16, 16, 3);
        let (roi_outs, fr) = e.run_spec_batch(&roi_spec, &[&a, &c]).unwrap();
        assert!(!fr);
        assert_eq!(roi_outs[0].height(), 6);
    }

    #[test]
    fn warm_spec_counts_like_run_spec() {
        let mut e = NativeEngine::default();
        let img = synth::noise(20, 24, 5);
        let spec = FilterSpec::new(FilterOp::Erode, 5, 5);
        e.warm_spec(&spec, 20, 24).unwrap();
        assert_eq!(e.plan_stats(), PlanStats { resolutions: 1, hits: 0 });
        assert_eq!(e.cached_plans(), 1);
        // execution after a warm is a pure cache hit
        let got = e.run_spec(&spec, &img).unwrap();
        assert!(got.same_pixels(&crate::morphology::erode(&img, 5, 5)));
        assert_eq!(e.plan_stats(), PlanStats { resolutions: 1, hits: 1 });
        // re-warming a warm family is a hit too: warm+exec per request
        // means G requests of one family score 1 + (2G - 1) touches
        e.warm_spec(&spec, 20, 24).unwrap();
        assert_eq!(e.plan_stats(), PlanStats { resolutions: 1, hits: 2 });
        // u16 warms its own cache with its own resolution
        e.warm_spec_u16(&spec, 20, 24).unwrap();
        assert_eq!(e.cached_plans(), 2);
        assert_eq!(e.plan_stats(), PlanStats { resolutions: 2, hits: 2 });
        // interior ROIs canonicalize per ROI shape: a position sweep
        // warms one family and every later position is a hit
        let base = spec.with_roi(crate::morphology::Roi::new(6, 6, 8, 8));
        e.warm_spec(&base, 20, 24).unwrap();
        let moved = spec.with_roi(crate::morphology::Roi::new(7, 9, 8, 8));
        e.warm_spec(&moved, 20, 24).unwrap();
        assert_eq!(e.plan_stats(), PlanStats { resolutions: 3, hits: 3 });
        // plan errors surface without poisoning the cache
        assert!(e.warm_spec(&FilterSpec::new(FilterOp::Erode, 4, 4), 20, 24).is_err());
    }

    #[test]
    fn reconstruct_requests_cache_plans_and_match_the_library() {
        let mut e = NativeEngine::default();
        let mask = synth::noise(18, 26, 7);
        let mut marker = Image::<u8>::zeros(18, 26);
        marker.row_mut(0).copy_from_slice(mask.row(0));
        let spec = FilterSpec::new(FilterOp::Reconstruct, 3, 3);
        let (got, sweeps) = e.run_spec_reconstruct(&spec, &mask, &marker).unwrap();
        let (want, want_sweeps) = crate::morphology::reconstruct_by_dilation(
            &marker,
            &mask,
            3,
            3,
            &MorphConfig::default(),
        )
        .unwrap();
        assert!(got.same_pixels(&want));
        assert_eq!(sweeps, want_sweeps);
        assert_eq!(e.plan_stats(), PlanStats { resolutions: 1, hits: 0 });
        // warm family: later requests are hits on the cached plan
        let (got2, _) = e.run_spec_reconstruct(&spec, &mask, &marker).unwrap();
        assert!(got2.same_pixels(&want));
        assert_eq!(e.plan_stats(), PlanStats { resolutions: 1, hits: 1 });
        assert_eq!(e.cached_plans(), 1);
        // markerless entry points refuse reconstruct specs...
        assert!(e.run_spec(&spec, &mask).is_err());
        // ...and the marker entry point refuses everything else
        let erode = FilterSpec::new(FilterOp::Erode, 3, 3);
        assert!(e.run_spec_reconstruct(&erode, &mask, &marker).is_err());
        // shape-mismatched markers error instead of panicking
        let small = synth::noise(6, 6, 1);
        assert!(e.run_spec_reconstruct(&spec, &mask, &small).is_err());
    }

    #[test]
    fn reconstruct_works_at_u16_depth() {
        let mut e = NativeEngine::default();
        let mask = synth::noise_u16(12, 16, 5);
        let mut marker = Image::<u16>::zeros(12, 16);
        marker.row_mut(0).copy_from_slice(mask.row(0));
        let spec = FilterSpec::new(FilterOp::Reconstruct, 3, 3);
        let (got, _) = e.run_spec_reconstruct_u16(&spec, &mask, &marker).unwrap();
        let (want, _) = crate::morphology::reconstruct_by_dilation(
            &marker,
            &mask,
            3,
            3,
            &MorphConfig::default(),
        )
        .unwrap();
        assert!(got.same_pixels(&want));
    }

    #[test]
    fn plan_cache_is_bounded() {
        let mut e = NativeEngine::default();
        let img = synth::noise(12, 12, 7);
        for w in 0..PLAN_CACHE_CAP + 3 {
            let spec = FilterSpec::new(FilterOp::Erode, 2 * w + 1, 3);
            let _ = e.run_spec(&spec, &img).unwrap();
        }
        assert!(e.cached_plans() <= PLAN_CACHE_CAP);
    }
}
