//! Runtime layer: everything needed to execute the python-AOT-lowered
//! JAX/Pallas artifacts from rust — python is never on the request path.
//!
//! * [`manifest`] — parse `artifacts/manifest.json` (the python↔rust
//!   contract) and index artifacts by (op, shape, window).
//! * [`xla_rt`] — [`XlaRuntime`]: PJRT CPU client + lazy compile cache;
//!   `run_u8` feeds a u8 image literal through a compiled HLO module.
//! * [`engine`] — the [`Engine`] abstraction + [`NativeEngine`] (pure
//!   rust fallback/fast path) so the coordinator is backend-agnostic.

pub mod engine;
pub mod manifest;
pub mod xla_rt;

pub use engine::{Engine, NativeEngine, PlanStats};
pub use manifest::{ArtifactMeta, Manifest};
pub use xla_rt::XlaRuntime;
