//! `artifacts/manifest.json` — the contract between the python AOT
//! compile path (`python/compile/aot.py`) and the rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Metadata of one AOT artifact (one lowered HLO module).
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    /// "morphology" or "transpose".
    pub kind: String,
    /// erode / dilate / opening / closing / gradient / transpose.
    pub op: String,
    pub height: usize,
    pub width: usize,
    pub w_x: usize,
    pub w_y: usize,
    pub method: String,
    pub vertical: String,
    pub dtype: String,
    /// File name (relative to the manifest directory).
    pub file: String,
    /// Output shape `[rows, cols]`.
    pub out_shape: (usize, usize),
}

impl ArtifactMeta {
    fn from_json(v: &Json) -> Result<ArtifactMeta> {
        let f = |k: &str| {
            v.str_field(k)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("artifact missing string field {k:?}"))
        };
        let u = |k: &str| {
            v.usize_field(k)
                .ok_or_else(|| anyhow!("artifact missing integer field {k:?}"))
        };
        let out = v
            .get("output")
            .and_then(|o| o.get("shape"))
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("artifact missing output.shape"))?;
        if out.len() != 2 {
            bail!("output.shape must be rank 2");
        }
        let out_shape = (
            out[0].as_usize().ok_or_else(|| anyhow!("bad output.shape[0]"))?,
            out[1].as_usize().ok_or_else(|| anyhow!("bad output.shape[1]"))?,
        );
        Ok(ArtifactMeta {
            name: f("name")?,
            kind: f("kind")?,
            op: f("op")?,
            height: u("height")?,
            width: u("width")?,
            w_x: u("w_x")?,
            w_y: u("w_y")?,
            method: f("method")?,
            vertical: f("vertical")?,
            dtype: f("dtype")?,
            file: f("file")?,
            out_shape,
        })
    }
}

/// The parsed manifest: artifact index keyed by name.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dtype: String,
    by_name: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`?)"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (factored out for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = json::parse(text).context("parsing manifest.json")?;
        let format = root
            .usize_field("format")
            .ok_or_else(|| anyhow!("manifest missing format"))?;
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let dtype = root
            .str_field("dtype")
            .ok_or_else(|| anyhow!("manifest missing dtype"))?
            .to_string();
        let arts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts array"))?;
        let mut by_name = BTreeMap::new();
        for a in arts {
            let meta = ArtifactMeta::from_json(a)?;
            if by_name.insert(meta.name.clone(), meta.clone()).is_some() {
                bail!("duplicate artifact name {:?}", meta.name);
            }
        }
        Ok(Manifest {
            dir,
            dtype,
            by_name,
        })
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.by_name.keys().map(String::as_str)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.by_name.get(name)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Find the artifact for (op, image shape, window).
    pub fn find(
        &self,
        op: &str,
        height: usize,
        width: usize,
        w_x: usize,
        w_y: usize,
    ) -> Option<&ArtifactMeta> {
        self.by_name.values().find(|m| {
            m.op == op && m.height == height && m.width == width && m.w_x == w_x && m.w_y == w_y
        })
    }

    /// All distinct (op, w_x, w_y) combinations served for a shape.
    pub fn ops_for_shape(&self, height: usize, width: usize) -> Vec<&ArtifactMeta> {
        self.by_name
            .values()
            .filter(|m| m.height == height && m.width == width)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "dtype": "u8",
      "artifacts": [
        {"name": "erode_256x256_w3x3", "kind": "morphology", "op": "erode",
         "height": 256, "width": 256, "w_x": 3, "w_y": 3,
         "method": "hybrid", "vertical": "transpose", "dtype": "u8",
         "input": {"shape": [256, 256], "dtype": "u8"},
         "output": {"shape": [256, 256], "dtype": "u8"},
         "file": "erode_256x256_w3x3.hlo.txt"},
        {"name": "transpose_256x256", "kind": "transpose", "op": "transpose",
         "height": 256, "width": 256, "w_x": 0, "w_y": 0,
         "method": "tiled", "vertical": "-", "dtype": "u8",
         "input": {"shape": [256, 256], "dtype": "u8"},
         "output": {"shape": [256, 256], "dtype": "u8"},
         "file": "transpose_256x256.hlo.txt"}
      ]
    }"#;

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m.dtype, "u8");
        let e = m.find("erode", 256, 256, 3, 3).unwrap();
        assert_eq!(e.name, "erode_256x256_w3x3");
        assert_eq!(e.out_shape, (256, 256));
        assert_eq!(m.path_of(e), PathBuf::from("/tmp/a/erode_256x256_w3x3.hlo.txt"));
        assert!(m.find("erode", 256, 256, 5, 5).is_none());
        assert_eq!(m.ops_for_shape(256, 256).len(), 2);
    }

    #[test]
    fn rejects_bad_format() {
        let bad = SAMPLE.replace("\"format\": 1", "\"format\": 9");
        assert!(Manifest::parse(&bad, PathBuf::new()).is_err());
    }

    #[test]
    fn rejects_duplicates() {
        let dup = SAMPLE.replace("transpose_256x256\", \"kind\": \"transpose",
                                 "erode_256x256_w3x3\", \"kind\": \"transpose");
        assert!(Manifest::parse(&dup, PathBuf::new()).is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // integration-level check, skipped when artifacts aren't built
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(!m.is_empty());
            assert!(m.find("erode", 256, 256, 3, 3).is_some());
        }
    }
}
