//! The PJRT runtime: load AOT-lowered HLO text, compile once per
//! artifact on the CPU PJRT client, execute from the rust hot path.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! DESIGN.md): jax ≥ 0.5 emits 64-bit instruction ids in serialized
//! protos which xla_extension 0.5.1 rejects; the text parser reassigns
//! ids.  Each artifact is compiled lazily on first use and cached.
//!
//! `PjRtLoadedExecutable` wraps a raw pointer and is not `Send`, so a
//! runtime instance is thread-local by construction; the coordinator
//! gives each worker thread its own [`XlaRuntime`] (the PJRT CPU client
//! is cheap and the compiled executables share nothing mutable).
//!
//! ## Offline stub
//!
//! The real implementation needs the external `xla` crate, which the
//! offline build cannot fetch, so it is gated behind the `pjrt` cargo
//! feature (see `rust/Cargo.toml`).  Without the feature this module
//! exports a stub [`XlaRuntime`] whose constructor always errors; the
//! coordinator's `Auto` routing then degrades to the native engine and
//! every integration test that needs real artifacts skips cleanly.
//! Artifacts are u8-only either way — u16 requests always run native.

#[cfg(feature = "pjrt")]
mod real {
    use std::collections::HashMap;

    use anyhow::{anyhow, Context, Result};

    use super::super::engine::Engine;
    use super::super::manifest::{ArtifactMeta, Manifest};
    use crate::image::Image;

    /// PJRT-backed artifact executor with a compile cache.
    pub struct XlaRuntime {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl XlaRuntime {
        /// Create a CPU PJRT client over the given artifact directory.
        pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<XlaRuntime> {
            let manifest = Manifest::load(artifact_dir)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(XlaRuntime {
                client,
                manifest,
                cache: HashMap::new(),
            })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Number of executables compiled so far (cache size).
        pub fn compiled_count(&self) -> usize {
            self.cache.len()
        }

        /// Compile (or fetch from cache) the executable for `meta`.
        fn executable(&mut self, meta: &ArtifactMeta) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.cache.contains_key(&meta.name) {
                let path = self.manifest.path_of(meta);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
                )
                .with_context(|| format!("loading HLO text {path:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .with_context(|| format!("compiling artifact {}", meta.name))?;
                self.cache.insert(meta.name.clone(), exe);
            }
            Ok(&self.cache[&meta.name])
        }

        /// Warm the cache for every artifact matching `pred`.
        pub fn precompile(&mut self, pred: impl Fn(&ArtifactMeta) -> bool) -> Result<usize> {
            let metas: Vec<ArtifactMeta> = self
                .manifest
                .names()
                .filter_map(|n| self.manifest.get(n).cloned())
                .filter(|m| pred(m))
                .collect();
            let mut n = 0;
            for m in &metas {
                self.executable(m)?;
                n += 1;
            }
            Ok(n)
        }

        /// Execute artifact `meta` on a u8 image, returning the u8 image
        /// result (the lowered functions return a 1-tuple).
        pub fn run_u8(&mut self, meta: &ArtifactMeta, img: &Image<u8>) -> Result<Image<u8>> {
            if img.height() != meta.height || img.width() != meta.width {
                return Err(anyhow!(
                    "image {}x{} does not match artifact {} ({}x{})",
                    img.height(),
                    img.width(),
                    meta.name,
                    meta.height,
                    meta.width
                ));
            }
            let compact;
            let img = if img.stride() == img.width() {
                img
            } else {
                compact = img.compact();
                &compact
            };
            let input = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::U8,
                &[meta.height, meta.width],
                img.as_bytes(),
            )
            .context("creating input literal")?;

            let (out_h, out_w) = meta.out_shape;
            let exe = self.executable(meta)?;
            let result = exe
                .execute::<xla::Literal>(&[input])
                .with_context(|| format!("executing {}", meta.name))?[0][0]
                .to_literal_sync()?;
            let out = result.to_tuple1().context("unwrapping 1-tuple output")?;

            let n = out.element_count();
            if n != out_h * out_w {
                return Err(anyhow!(
                    "artifact {} returned {} elements, expected {}x{}",
                    meta.name,
                    n,
                    out_h,
                    out_w
                ));
            }
            let data: Vec<u8> = out.to_vec().context("copying output literal")?;
            Ok(Image::from_vec(out_h, out_w, data))
        }
    }

    impl XlaRuntime {
        /// Artifact matching a spec, gated on the shared canonical-form
        /// predicate ([`crate::morphology::FilterSpec::single_identity_op`]
        /// — the same rule the coordinator's router applies, so the two
        /// can never drift).
        fn artifact_for(
            &self,
            spec: &crate::morphology::FilterSpec,
            h: usize,
            w: usize,
        ) -> Option<ArtifactMeta> {
            let op = spec.single_identity_op()?;
            self.manifest
                .find(op.name(), h, w, spec.w_x, spec.w_y)
                .cloned()
        }
    }

    impl Engine for XlaRuntime {
        fn run_spec(
            &mut self,
            spec: &crate::morphology::FilterSpec,
            img: &Image<u8>,
        ) -> Result<Image<u8>> {
            match self.artifact_for(spec, img.height(), img.width()) {
                Some(meta) => self.run_u8(&meta, img),
                None => Err(anyhow!(
                    "no compiled artifact matches spec {spec:?} on {}x{}",
                    img.height(),
                    img.width()
                )),
            }
        }

        fn backend_name(&self) -> &'static str {
            "xla-pjrt"
        }
    }

    // `xla::PjRtClient`/`PjRtLoadedExecutable` wrap C++ objects that the
    // PJRT CPU plugin allows to be *used* from one thread at a time but
    // *moved* between threads; the coordinator moves each runtime into its
    // worker thread at spawn and never shares it.
    unsafe impl Send for XlaRuntime {}
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use anyhow::{bail, Result};

    use super::super::engine::Engine;
    use super::super::manifest::{ArtifactMeta, Manifest};
    use crate::image::Image;

    /// Offline stub: construction always fails, so `Auto` routing
    /// degrades to the native engine and artifact-dependent tests skip.
    pub struct XlaRuntime {
        manifest: Manifest,
    }

    impl XlaRuntime {
        pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<XlaRuntime> {
            // Load (and validate) the manifest first so the error message
            // distinguishes "no artifacts" from "no PJRT support".
            let _manifest = Manifest::load(artifact_dir)?;
            bail!(
                "PJRT support is not compiled in (build with --features pjrt \
                 and a vendored `xla` crate)"
            );
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            "unavailable (pjrt feature disabled)".to_string()
        }

        pub fn compiled_count(&self) -> usize {
            0
        }

        pub fn precompile(&mut self, _pred: impl Fn(&ArtifactMeta) -> bool) -> Result<usize> {
            Ok(0)
        }

        pub fn run_u8(&mut self, meta: &ArtifactMeta, _img: &Image<u8>) -> Result<Image<u8>> {
            bail!("PJRT support not compiled in (artifact {})", meta.name)
        }
    }

    impl Engine for XlaRuntime {
        fn run_spec(
            &mut self,
            spec: &crate::morphology::FilterSpec,
            img: &Image<u8>,
        ) -> Result<Image<u8>> {
            let _ = (spec, img);
            bail!("PJRT support not compiled in")
        }

        fn backend_name(&self) -> &'static str {
            "xla-pjrt"
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_constructor_always_errors() {
            // without artifacts: the manifest error surfaces
            assert!(XlaRuntime::new("/nonexistent/artifacts").is_err());
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::XlaRuntime;
#[cfg(not(feature = "pjrt"))]
pub use stub::XlaRuntime;
