//! # neon-morph
//!
//! Production reproduction of *“Fast Implementation of Morphological
//! Filtering Using ARM NEON Extension”* (Limonova, Terekhin, Nikolaev,
//! Arlazarov — CS.DC 2020) as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper speeds up erosion/dilation with rectangular structuring
//! elements by (1) exploiting separability into 1-D passes, (2) choosing
//! per pass between the van Herk/Gil-Werman algorithm (O(1) comparisons
//! per pixel) and a *linear* algorithm (O(w) comparisons but perfectly
//! SIMD-parallel), with a measured crossover (w_y⁰ = 69, w_x⁰ = 59 on
//! Exynos 5422), and (3) fast SIMD matrix transpose (8×8.16 / 16×16.8
//! vtrn networks) so the vertical pass can reuse the horizontal code.
//!
//! Crate layout (see `DESIGN.md` for the full inventory):
//!
//! * [`image`] — stride-aware `u8`/`u16` image containers, PGM I/O,
//!   synthetic workload generators (the paper's 800×600 gray input).
//! * [`neon`] — an ARM NEON *simulator*: 128-bit register types plus the
//!   instruction subset the paper uses, behind a [`neon::Backend`] trait
//!   with a fast native implementation and a counting implementation
//!   that records the exact instruction mix (the substituted hardware
//!   substrate — we have no Exynos 5422; see DESIGN.md §Substitutions).
//! * [`costmodel`] — per-instruction-class latencies (Cortex-A15-like)
//!   that price an instruction mix in nanoseconds, reproducing the
//!   paper's Table 1 / Fig 3 / Fig 4 scales and crossovers.
//! * [`transpose`] — scalar, cache-blocked and NEON 8×8.16 / 16×16.8
//!   tile transposes (§4), plus whole-image tiled transpose.
//! * [`morphology`] — the paper's algorithm suite: naive 2-D baseline,
//!   vHGW and linear 1-D passes (scalar + SIMD), separable composition,
//!   the §5.3 hybrid dispatch, and derived operations.
//! * [`runtime`] — PJRT bridge executing the AOT-lowered JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) from Rust; python is never on the
//!   request path.
//! * [`coordinator`] — the serving layer: router, dynamic batcher,
//!   worker pool, backpressure and metrics.
//! * [`bench_harness`] — sweep drivers that regenerate every table and
//!   figure of the paper's evaluation (Table 1, Fig 3, Fig 4).

pub mod bench_harness;
pub mod coordinator;
pub mod costmodel;
pub mod image;
pub mod morphology;
pub mod neon;
pub mod runtime;
pub mod util;
pub mod transpose;

pub use image::Image;
pub use morphology::{Border, MorphOp, PassMethod, VerticalStrategy};
